"""AOT: lower the L2 model (with its Pallas kernels) to HLO text artifacts.

Run as ``python -m compile.aot --out-dir ../artifacts`` (what `make
artifacts` does). Emits:

* ``qpn_sweep.hlo.txt``  — the Figure 6 discrete-time simulation sweep
* ``mva_solver.hlo.txt`` — the analytic MVA fixed point over the same grid

HLO **text** (not ``lowered.compile().serialize()`` nor the serialized
``HloModuleProto``) is the interchange format: jax ≥ 0.5 emits protos with
64-bit instruction ids which the Rust side's xla_extension 0.5.1 rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Inputs of both artifacts: six float32 [B] vectors
    (h, ncores, nops, z, thit, tmem)
Outputs: a tuple of float32 [B] vectors
    qpn_sweep  -> (X msgs/s, U, F)
    mva_solver -> (X msgs/s, U, F, Q)
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Static batch the artifacts are built for; the Rust side pads its grids to
# this size (runtime::ArtifactSpec documents the contract).
BATCH = 256

# The sweep artifact simulates fewer steps than the interactive default so
# the artifact compiles and executes quickly on the CPU client; the shape of
# the Figure 6 curves is converged well before this horizon.
SWEEP_OUTER = 512
SWEEP_INNER = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_qpn_sweep(batch: int = BATCH):
    spec = jax.ShapeDtypeStruct((batch,), jnp.float32)

    def fn(h, ncores, nops, z, thit, tmem):
        return model.qpn_sweep(
            h, ncores, nops, z, thit, tmem, outer=SWEEP_OUTER, inner=SWEEP_INNER
        )

    return jax.jit(fn).lower(spec, spec, spec, spec, spec, spec)


def lower_mva(batch: int = BATCH):
    spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    return jax.jit(model.mva_solve).lower(spec, spec, spec, spec, spec, spec)


def write_artifact(name: str, lowered, out_dir: str) -> str:
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, name)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    print(f"wrote {path} ({len(text)} chars)")
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    write_artifact("mva_solver.hlo.txt", lower_mva(args.batch), args.out_dir)
    write_artifact("qpn_sweep.hlo.txt", lower_qpn_sweep(args.batch), args.out_dir)


if __name__ == "__main__":
    main()
