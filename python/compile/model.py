"""L2: the paper's Section 5 performance model as a JAX computation.

The Queueing-Petri-Net of the paper has a single queueing resource — the
shared memory bus — through which every cache miss of every message
exchange must pass, plus ``C`` core tokens. This module exposes the two
AOT entry points executed by the Rust coordinator:

* ``qpn_sweep`` — the discrete-time token simulation (driven by the Pallas
  ``qpn_step`` kernel) over a parameter grid; regenerates Figure 6.
* ``mva_solve`` — the analytic Mean Value Analysis fixed point over the
  same grid (Pallas ``mva_kernel``); the cross-check and the source of the
  theoretical-maximum throughput / refactoring stop criterion.

Both take flat float32 vectors so the PJRT bridge on the Rust side stays
dtype-trivial. Python never runs on the request path: `compile/aot.py`
lowers these functions to HLO text once, at build time.

Calibration (documented in EXPERIMENTS.md): with the default workload
constants below, the model's zero-contention exchange time is
``z + nops*(h*thit + (1-h)*tmem)`` ≈ 1.59 µs at h=0.95, i.e. a theoretical
maximum of ~630 k messages/s — the figure the paper reports.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from .kernels import qpn_step as k
from .kernels import ref

# ---------------------------------------------------------------------------
# Workload constants (ns), derived as in the paper from static analysis of
# the send+receive paths. One "memory operation" is one cache-line touch.
# ---------------------------------------------------------------------------
DEFAULTS = {
    "message": {"nops": 52, "z": 1300, "thit": 2, "tmem": 60},
    "packet": {"nops": 60, "z": 1400, "thit": 2, "tmem": 60},
    "scalar": {"nops": 24, "z": 900, "thit": 2, "tmem": 60},
}

# Simulated nanoseconds per inner Pallas call and number of outer scan steps
# for the AOT sweep artifact: 64 * 4096 ≈ 262 µs of simulated time per lane,
# a few hundred message exchanges — enough for steady state at these rates.
INNER_STEPS = 64
OUTER_STEPS = 4096


def _int_params(h, ncores, nops, z, thit, tmem):
    """f32 workload vectors -> int32 simulation parameter dict."""
    to = lambda a: jnp.asarray(a).astype(jnp.int32)
    missf = ((1.0 - jnp.asarray(h, jnp.float32)) * ref.CARRY_ONE).astype(jnp.int32)
    return {
        "ncores": to(ncores),
        "z": to(z),
        "nops": to(nops),
        "thit": to(thit),
        "tbus": to(tmem),
        "missf": missf,
    }


def qpn_sweep(h, ncores, nops, z, thit, tmem, *, outer=OUTER_STEPS, inner=INNER_STEPS):
    """Discrete-time QPN simulation over the grid (Pallas-kernel driven).

    All inputs are float32 [B] (B a multiple of the kernel tile).
    Returns (X msgs/s, U bus utilization, F throughput fraction of target),
    each float32 [B]. ``z`` is the *per-core* think time; the workload
    generator demands one message per ``z/ncores`` ns system-wide, so the
    target rate ``ncores/z`` is the same line for every core configuration
    (Figure 6's 100%) and the single-core configuration tops out around
    95% of it — exactly the paper's observation.
    """
    params = _int_params(h, ncores, nops, z, thit, tmem)
    state = ref.init_state(params["ncores"].shape[0])

    def body(st, _):
        return k.qpn_step(st, params, steps=inner), None

    state, _ = lax.scan(body, state, None, length=outer)
    steps = jnp.float32(outer * inner)
    x = state["done"].astype(jnp.float32) / steps * 1e9
    u = state["busy"].astype(jnp.float32) / steps
    frac = x / _target_rate(ncores, z)
    return x, u, frac


def _target_rate(ncores, z):
    """Workload target rate (msgs/s): one message per z/ncores ns."""
    return (
        jnp.asarray(ncores, jnp.float32) / jnp.asarray(z, jnp.float32) * 1e9
    )


def mva_solve(h, ncores, nops, z, thit, tmem):
    """Analytic MVA over the grid (Pallas-kernel driven).

    Same signature/outputs as ``qpn_sweep`` plus the mean bus queue length:
    (X msgs/s, U, F, Q).
    """
    d_think, d_bus = ref.demands(h, nops, z, thit, tmem)
    x, u, q = k.mva_kernel(d_think, d_bus, jnp.asarray(ncores, jnp.float32))
    frac = x / _target_rate(ncores, z)
    return x, u, frac, q


def figure6_grid(msg_type: str = "message", cores=(1, 2), hits=None, pad_to: int = 256):
    """Build the Figure 6 parameter grid as f32 vectors.

    Returns a dict of float32 [pad_to] arrays plus the number of valid
    lanes. Lanes beyond the valid count replicate the last point (padding
    keeps the AOT shape static).
    """
    if hits is None:
        hits = [0.5 + 0.02 * i for i in range(26)]  # 0.50 .. 1.00
    w = DEFAULTS[msg_type]
    rows = [(hh, cc) for cc in cores for hh in hits]
    n = len(rows)
    assert n <= pad_to, (n, pad_to)
    rows = rows + [rows[-1]] * (pad_to - n)
    h = jnp.asarray([r[0] for r in rows], jnp.float32)
    c = jnp.asarray([r[1] for r in rows], jnp.float32)
    const = lambda v: jnp.full((pad_to,), v, jnp.float32)
    return {
        "h": h,
        "ncores": c,
        # Per-core think time scales with the core count so the *system*
        # demand (the Figure 6 target line) is identical for every core
        # configuration.
        "z": c * w["z"],
        "nops": const(w["nops"]),
        "thit": const(w["thit"]),
        "tmem": const(w["tmem"]),
        "valid": n,
    }
