"""Pallas kernels for the QPN performance model (the L1 hot spot).

Two kernels:

* ``qpn_step`` — advances the discrete-time queueing-network simulation by
  ``steps`` nanoseconds for a tile of parameter-grid lanes. This is the hot
  loop of the Figure 6 sweep: everything is element-wise lane arithmetic
  over an int32 state block, so the TPU mapping is VPU work with one
  [TILE, KMAX] state tile resident in VMEM per program instance.
* ``mva_kernel`` — the batched Mean Value Analysis fixed point (unrolled to
  ``KMAX`` populations with masking), the analytic cross-check.

Kernels are lowered with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and the AOT artifact must run on the Rust CPU
client. On a real TPU the same kernels compile with ``interpret=False``;
the BlockSpec tiling below is already chosen for that case (see
DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from . import ref

# Grid tile: lanes per Pallas program instance. 128 matches the TPU lane
# width; the per-instance VMEM footprint is
#   4 state blocks [128, 8] i32 + 4 lane vectors + 6 param vectors ≈ 21 KiB.
TILE = 128

KMAX = ref.KMAX
CARRY_ONE = ref.CARRY_ONE

# State tensors carried between steps, in kernel argument order.
STATE2D = ("phase", "timer", "ops_left", "carry")  # [B, KMAX] i32
STATE1D = ("serving", "rr", "busy", "done")  # [B] i32
PARAMS = ("ncores", "z", "nops", "thit", "tbus", "missf")  # [B] i32


def _step_body(state, params, kmax):
    """One simulation nanosecond; identical math to ref.qpn_step_ref."""
    return ref.qpn_step_ref(state, params, kmax)


def _qpn_kernel(*refs, steps: int, kmax: int):
    """Pallas kernel body: run ``steps`` ns for one [TILE] lane block."""
    n2, n1, npar = len(STATE2D), len(STATE1D), len(PARAMS)
    in_refs = refs[: n2 + n1 + npar]
    out_refs = refs[n2 + n1 + npar :]

    state = {k: in_refs[i][...] for i, k in enumerate(STATE2D)}
    state.update({k: in_refs[n2 + i][...] for i, k in enumerate(STATE1D)})
    params = {k: in_refs[n2 + n1 + i][...] for i, k in enumerate(PARAMS)}

    def body(_, st):
        return _step_body(st, params, kmax)

    state = lax.fori_loop(0, steps, body, state)

    for i, k in enumerate(STATE2D):
        out_refs[i][...] = state[k]
    for i, k in enumerate(STATE1D):
        out_refs[n2 + i][...] = state[k]


@functools.partial(jax.jit, static_argnames=("steps", "kmax", "tile"))
def qpn_step(state, params, steps: int, kmax: int = KMAX, tile: int = TILE):
    """Advance the batched simulation ``steps`` ns with the Pallas kernel.

    ``state``/``params`` are the dicts from ``ref.init_state`` /
    the int32 parameter arrays; batch must be a multiple of ``tile``.
    Returns the advanced state dict.
    """
    batch = state["phase"].shape[0]
    assert batch % tile == 0, f"batch {batch} not a multiple of tile {tile}"
    grid = (batch // tile,)

    spec2d = pl.BlockSpec((tile, kmax), lambda i: (i, 0))
    spec1d = pl.BlockSpec((tile,), lambda i: (i,))

    in_specs = (
        [spec2d] * len(STATE2D) + [spec1d] * len(STATE1D) + [spec1d] * len(PARAMS)
    )
    out_specs = [spec2d] * len(STATE2D) + [spec1d] * len(STATE1D)
    out_shape = [
        jax.ShapeDtypeStruct((batch, kmax), jnp.int32) for _ in STATE2D
    ] + [jax.ShapeDtypeStruct((batch,), jnp.int32) for _ in STATE1D]

    args = (
        [state[k] for k in STATE2D]
        + [state[k] for k in STATE1D]
        + [params[k] for k in PARAMS]
    )

    outs = pl.pallas_call(
        functools.partial(_qpn_kernel, steps=steps, kmax=kmax),
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )(*args)

    new_state = {k: outs[i] for i, k in enumerate(STATE2D)}
    new_state.update(
        {k: outs[len(STATE2D) + i] for i, k in enumerate(STATE1D)}
    )
    return new_state


def _mva_kernel(d_think_ref, d_bus_ref, n_ref, x_ref, u_ref, q_ref, *, kmax):
    """Pallas kernel body: exact MVA, population unrolled to kmax."""
    d_think = d_think_ref[...]
    d_bus = d_bus_ref[...]
    n = n_ref[...]
    q = jnp.zeros_like(d_think)
    x = jnp.zeros_like(d_think)
    for i in range(1, kmax + 1):
        r_bus = d_bus * (1.0 + q)
        x_i = i / (d_think + r_bus)
        q_i = x_i * r_bus
        use = (i <= n).astype(jnp.float32)
        x = use * x_i + (1.0 - use) * x
        q = use * q_i + (1.0 - use) * q
    x_ref[...] = x * 1e9
    u_ref[...] = jnp.clip(x * d_bus, 0.0, 1.0)
    q_ref[...] = q


@functools.partial(jax.jit, static_argnames=("kmax", "tile"))
def mva_kernel(d_think, d_bus, n, kmax: int = KMAX, tile: int = TILE):
    """Batched MVA via Pallas; f32 [B] inputs, batch multiple of tile.

    Returns (X msgs/s, U utilization, Q mean queue length).
    """
    batch = d_think.shape[0]
    assert batch % tile == 0, f"batch {batch} not a multiple of tile {tile}"
    spec = pl.BlockSpec((tile,), lambda i: (i,))
    outs = pl.pallas_call(
        functools.partial(_mva_kernel, kmax=kmax),
        grid=(batch // tile,),
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((batch,), jnp.float32)] * 3,
        interpret=True,
    )(
        d_think.astype(jnp.float32),
        d_bus.astype(jnp.float32),
        n.astype(jnp.float32),
    )
    return tuple(outs)
