"""AOT artifact tests: HLO-text emission contract with the Rust loader."""

from __future__ import annotations

import os

import numpy as np
import jax
import jax.numpy as jnp

from compile import aot, model


class TestHloTextEmission:
    def test_mva_artifact_text(self, tmp_path):
        path = aot.write_artifact("mva_solver.hlo.txt", aot.lower_mva(256), str(tmp_path))
        text = open(path).read()
        # HLO text module header — what HloModuleProto::from_text_file parses.
        assert text.startswith("HloModule")
        # Six f32[256] parameters.
        assert text.count("f32[256]") >= 6
        # Never the 64-bit-id proto path.
        assert "\x00" not in text

    def test_sweep_artifact_text(self, tmp_path):
        path = aot.write_artifact(
            "qpn_sweep.hlo.txt", aot.lower_qpn_sweep(256), str(tmp_path)
        )
        text = open(path).read()
        assert text.startswith("HloModule")
        # The artifact embeds the scan loop (lowered as a while op).
        assert "while" in text

    def test_atomic_replace(self, tmp_path):
        aot.write_artifact("x.hlo.txt", aot.lower_mva(256), str(tmp_path))
        assert not os.path.exists(tmp_path / "x.hlo.txt.tmp")


class TestArtifactSemantics:
    """Round-trip the lowered module through XLA's own runtime: the numbers
    the Rust client will read must equal calling the model directly."""

    def test_mva_roundtrip_equals_direct(self):
        g = model.figure6_grid(pad_to=256)
        args = (g["h"], g["ncores"], g["nops"], g["z"], g["thit"], g["tmem"])
        direct = model.mva_solve(*args)
        compiled = jax.jit(model.mva_solve).lower(*args).compile()
        via = compiled(*args)
        for d, v in zip(direct, via):
            np.testing.assert_allclose(d, v, rtol=1e-6)

    def test_sweep_deterministic_across_lowerings(self):
        g = model.figure6_grid(cores=(2,), hits=[0.8], pad_to=256)
        args = (g["h"], g["ncores"], g["nops"], g["z"], g["thit"], g["tmem"])

        def fn(*a):
            return model.qpn_sweep(*a, outer=64, inner=64)

        a = jax.jit(fn)(*args)
        b = jax.jit(fn)(*args)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)

    def test_default_batch_matches_rust_contract(self):
        # rust/src/runtime/artifact.rs documents BATCH=256; keep in sync.
        assert aot.BATCH == 256
