"""L2 model tests: Figure 6 semantics, calibration, and sweep physics."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile.kernels import ref


def grid(msg="message", cores=(1, 2), pad=256):
    return model.figure6_grid(msg_type=msg, cores=cores, pad_to=pad)


def mva(g):
    return model.mva_solve(g["h"], g["ncores"], g["nops"], g["z"], g["thit"], g["tmem"])


class TestFigure6Grid:
    def test_shapes_and_padding(self):
        g = grid()
        assert g["h"].shape == (256,)
        assert g["valid"] == 52  # 26 hit rates x 2 core configs
        # Padding replicates the last valid lane.
        np.testing.assert_allclose(g["h"][g["valid"] :], g["h"][g["valid"] - 1])

    def test_message_types_have_distinct_demands(self):
        gm, gp, gs = grid("message"), grid("packet"), grid("scalar")
        assert float(gm["nops"][0]) != float(gp["nops"][0])
        assert float(gs["nops"][0]) < float(gm["nops"][0])

    def test_unknown_type_raises(self):
        with pytest.raises(KeyError):
            grid("bogus")


class TestMvaFigure6:
    def test_throughput_fraction_increases_with_hit_rate(self):
        g = grid()
        _, _, frac, _ = mva(g)
        f = np.asarray(frac[:26])  # single-core series, h ascending
        assert np.all(np.diff(f) >= -1e-6)

    def test_two_cores_raise_bus_utilization(self):
        g = grid()
        _, u, _, _ = mva(g)
        one = np.asarray(u[:26])
        two = np.asarray(u[26:52])
        assert np.all(two >= one - 1e-6)

    def test_single_core_cannot_reach_target(self):
        # Paper: "we do not attain the target throughput rate but only about
        # 95%" — the hit-path cost keeps the fraction below 1 even at h=1.
        g = grid()
        _, _, frac, _ = mva(g)
        f1 = np.asarray(frac[:26])
        assert np.all(f1 < 1.0)
        assert f1[-1] > 0.9  # but close at h=1.0

    def test_theoretical_max_near_630k(self):
        # Calibration check: zero-contention exchange at h=0.95 ~ 630 kmsg/s.
        g = model.figure6_grid(cores=(1,), hits=[0.95], pad_to=256)
        x, _, _, _ = mva(g)
        assert 500_000 < float(x[0]) < 800_000

    def test_two_core_fraction_exceeds_single_at_high_h(self):
        # Paper: adding a second core improves throughput toward the target
        # at high hit rates.
        g = grid()
        _, _, frac, _ = mva(g)
        f1 = np.asarray(frac[:26])
        f2 = np.asarray(frac[26:52])
        assert f2[-1] > f1[-1]


class TestSweepVsMva:
    def test_simulation_tracks_analytic_shape(self):
        # The discrete-time simulation and MVA disagree in absolute value
        # (FIFO vs product-form assumptions) but must agree in shape: same
        # ordering across hit rates, utilization within 15 points.
        hits = [0.6, 0.8, 0.95]
        g = model.figure6_grid(cores=(2,), hits=hits, pad_to=256)
        xs, us, _ = model.qpn_sweep(
            g["h"], g["ncores"], g["nops"], g["z"], g["thit"], g["tmem"],
            outer=256, inner=64,
        )
        xm, um, _, _ = mva(g)
        xs, us = np.asarray(xs[:3]), np.asarray(us[:3])
        xm, um = np.asarray(xm[:3]), np.asarray(um[:3])
        assert np.all(np.diff(xs) > 0) and np.all(np.diff(xm) > 0)
        # FIFO/deterministic service vs product-form: absolute values drift
        # but stay within 20 utilization points and converge at high h.
        assert np.all(np.abs(us - um) < 0.20)
        assert abs(us[-1] - um[-1]) < 0.05

    def test_sweep_utilization_bounded(self):
        g = model.figure6_grid(cores=(1, 2), hits=[0.5, 0.9], pad_to=256)
        _, u, _ = model.qpn_sweep(
            g["h"], g["ncores"], g["nops"], g["z"], g["thit"], g["tmem"],
            outer=128, inner=64,
        )
        u = np.asarray(u)
        assert np.all(u >= 0.0) and np.all(u <= 1.0)


class TestCalibrationConstants:
    def test_scalar_cheaper_than_message_cheaper_than_packet(self):
        d = model.DEFAULTS
        assert d["scalar"]["nops"] < d["message"]["nops"] <= d["packet"]["nops"]

    def test_exchange_time_microseconds_scale(self):
        # Sanity: one message exchange costs on the order of a microsecond,
        # matching the paper's measured 7 µs lock-free latency within 10x.
        w = model.DEFAULTS["message"]
        t = w["z"] + w["nops"] * (0.95 * w["thit"] + 0.05 * w["tmem"])
        assert 1_000 < t < 3_000  # ns
