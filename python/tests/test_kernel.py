"""L1 correctness: Pallas kernels vs. the pure-jnp oracle.

The discrete-time QPN step kernel must be *bit-exact* against the reference
(all state is int32 and the step logic is identical arithmetic), across
parameter ranges swept by hypothesis. The MVA kernel is float32 and is
checked with allclose.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import qpn_step as k

TILE = k.TILE


def make_params(batch, ncores, z, nops, thit, tbus, missf):
    full = lambda v: jnp.full((batch,), v, jnp.int32)
    return {
        "ncores": full(ncores),
        "z": full(z),
        "nops": full(nops),
        "thit": full(thit),
        "tbus": full(tbus),
        "missf": full(missf),
    }


def state_equal(a, b):
    for key in a:
        if not np.array_equal(np.asarray(a[key]), np.asarray(b[key])):
            return key
    return None


params_strategy = st.fixed_dictionaries(
    {
        "ncores": st.integers(1, ref.KMAX),
        "z": st.integers(1, 50),
        "nops": st.integers(1, 16),
        "thit": st.integers(1, 4),
        "tbus": st.integers(1, 20),
        "missf": st.integers(0, ref.CARRY_ONE),
    }
)


class TestQpnStepKernel:
    @settings(max_examples=12, deadline=None)
    @given(p=params_strategy, steps=st.integers(1, 96))
    def test_bit_exact_vs_ref(self, p, steps):
        params = make_params(TILE, **p)
        st_ref = ref.init_state(TILE)
        for _ in range(steps):
            st_ref = ref.qpn_step_ref(st_ref, params)
        st_ker = k.qpn_step(ref.init_state(TILE), params, steps=steps)
        assert state_equal(st_ref, st_ker) is None

    def test_multi_tile_grid(self):
        # Two grid tiles with *different* parameters per lane must not leak
        # state across tiles.
        batch = 2 * TILE
        params = {
            key: jnp.concatenate([a, b])
            for (key, a), (_, b) in zip(
                make_params(TILE, 2, 10, 4, 2, 8, 300_000).items(),
                make_params(TILE, 1, 5, 2, 1, 3, 700_000).items(),
            )
        }
        st_ref = ref.init_state(batch)
        for _ in range(64):
            st_ref = ref.qpn_step_ref(st_ref, params)
        st_ker = k.qpn_step(ref.init_state(batch), params, steps=64)
        assert state_equal(st_ref, st_ker) is None

    def test_chunked_equals_monolithic(self):
        params = make_params(TILE, 3, 7, 5, 2, 9, 450_000)
        a = k.qpn_step(ref.init_state(TILE), params, steps=60)
        b = ref.init_state(TILE)
        for _ in range(6):
            b = k.qpn_step(b, params, steps=10)
        assert state_equal(a, b) is None

    def test_batch_must_be_tile_multiple(self):
        params = make_params(TILE + 1, 1, 5, 2, 1, 3, 0)
        with pytest.raises(AssertionError):
            k.qpn_step(ref.init_state(TILE + 1), params, steps=1)


class TestSimulationInvariants:
    """Physics of the simulated network, independent of the oracle."""

    def run(self, steps=4000, **p):
        params = make_params(TILE, **p)
        state = ref.init_state(TILE)
        state = k.qpn_step(state, params, steps=steps)
        return state, params

    def test_bus_busy_bounded_by_time(self):
        state, _ = self.run(ncores=4, z=5, nops=8, thit=1, tbus=12, missf=500_000)
        assert int(state["busy"][0]) <= 4000

    def test_zero_miss_never_uses_bus(self):
        state, _ = self.run(ncores=4, z=5, nops=8, thit=1, tbus=12, missf=0)
        assert int(state["busy"][0]) == 0
        assert int(state["done"][0]) > 0

    def test_all_miss_bus_utilization_near_one(self):
        state, _ = self.run(
            steps=8000, ncores=4, z=1, nops=16, thit=1, tbus=20, missf=ref.CARRY_ONE
        )
        u = float(state["busy"][0]) / 8000.0
        assert u > 0.9

    def test_throughput_scales_with_cores_when_bus_idle(self):
        one, _ = self.run(ncores=1, z=20, nops=2, thit=1, tbus=4, missf=100_000)
        four, _ = self.run(ncores=4, z=20, nops=2, thit=1, tbus=4, missf=100_000)
        assert int(four["done"][0]) > 3 * int(one["done"][0])

    def test_deterministic(self):
        a, _ = self.run(ncores=3, z=9, nops=6, thit=2, tbus=7, missf=250_000)
        b, _ = self.run(ncores=3, z=9, nops=6, thit=2, tbus=7, missf=250_000)
        assert state_equal(a, b) is None


class TestMvaKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        d_think=st.floats(1.0, 1e4),
        d_bus=st.floats(0.0, 1e4),
        n=st.integers(1, ref.KMAX),
    )
    def test_matches_ref(self, d_think, d_bus, n):
        dt = jnp.full((TILE,), d_think, jnp.float32)
        db = jnp.full((TILE,), d_bus, jnp.float32)
        nn = jnp.full((TILE,), n, jnp.int32)
        x, u, q = k.mva_kernel(dt, db, nn.astype(jnp.float32))
        xr, ur, qr = ref.mva_ref(dt, db, nn)
        np.testing.assert_allclose(x, xr, rtol=1e-6)
        np.testing.assert_allclose(u, ur, rtol=1e-6)
        np.testing.assert_allclose(q, qr, rtol=1e-6)

    def test_single_customer_closed_form(self):
        # With one customer there is no queueing: X = 1/(d_think + d_bus).
        dt = jnp.full((TILE,), 100.0, jnp.float32)
        db = jnp.full((TILE,), 50.0, jnp.float32)
        x, u, q = k.mva_kernel(dt, db, jnp.ones((TILE,), jnp.float32))
        np.testing.assert_allclose(x, 1e9 / 150.0, rtol=1e-6)
        np.testing.assert_allclose(u, 50.0 / 150.0, rtol=1e-6)

    def test_utilization_monotone_in_population(self):
        dt = jnp.full((TILE,), 200.0, jnp.float32)
        db = jnp.full((TILE,), 100.0, jnp.float32)
        us = []
        for n in range(1, ref.KMAX + 1):
            _, u, _ = k.mva_kernel(dt, db, jnp.full((TILE,), n, jnp.float32))
            us.append(float(u[0]))
        assert all(b >= a - 1e-6 for a, b in zip(us, us[1:]))
        assert us[-1] <= 1.0 + 1e-6

    def test_zero_bus_demand_delay_station_only(self):
        dt = jnp.full((TILE,), 500.0, jnp.float32)
        db = jnp.zeros((TILE,), jnp.float32)
        for n in (1, 4):
            x, u, _ = k.mva_kernel(dt, db, jnp.full((TILE,), n, jnp.float32))
            np.testing.assert_allclose(x, n * 1e9 / 500.0, rtol=1e-6)
            np.testing.assert_allclose(u, 0.0, atol=1e-9)
