//! A three-stage processing pipeline over MCAPI channels, run on the
//! deterministic SMP simulator — the "industrial deployment" shape the
//! paper's introduction motivates (sensor → filter → actuator).
//!
//! Stage 0 produces raw samples on a scalar channel; stage 1 filters and
//! forwards packets; stage 2 consumes and checks. The same binary runs
//! the pipeline on 1 and 4 simulated cores with both backends and prints
//! the virtual-time comparison — the paper's headline effect on a
//! workload that is *not* the stress topology.
//!
//! Run with: `cargo run --release --example pipeline`

use mcapi::coordinator::{run_stress_sim, MsgKind, StressOpts, Topology};
use mcapi::mcapi::types::{BackendKind, RuntimeCfg};
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::sim::{Machine, MachineCfg};

const SAMPLES: u64 = 500;

fn pipeline_topology() -> Topology {
    // node 0 --scalar--> node 1 --packet--> node 2
    let mut t = Topology::default();
    t.channels.push(mcapi::coordinator::ChannelSpec {
        from: (0, 1),
        to: (1, 1),
        kind: MsgKind::Scalar,
        count: SAMPLES,
    });
    t.channels.push(mcapi::coordinator::ChannelSpec {
        from: (1, 2),
        to: (2, 1),
        kind: MsgKind::Packet,
        count: SAMPLES,
    });
    t
}

fn run(backend: BackendKind, cores: usize) -> (f64, u64) {
    let machine = Machine::new(MachineCfg::new(
        cores,
        OsProfile::linux_rt(),
        if cores == 1 { AffinityMode::SingleCore } else { AffinityMode::PinnedSpread },
    ));
    let report = run_stress_sim(
        &machine,
        RuntimeCfg::with_backend(backend),
        &pipeline_topology(),
        StressOpts::default(),
    );
    assert_eq!(report.delivered, 2 * SAMPLES);
    assert_eq!(report.order_violations, 0);
    (report.kmsgs_per_s(), report.elapsed_ns)
}

fn main() {
    println!("three-stage pipeline, {SAMPLES} samples end-to-end\n");
    println!("| backend | cores | throughput (kmsg/s) | virtual time (us) |");
    println!("|---|---|---|---|");
    let mut results = Vec::new();
    for backend in [BackendKind::Locked, BackendKind::LockFree] {
        for cores in [1usize, 4] {
            let (kmsgs, ns) = run(backend, cores);
            println!(
                "| {} | {} | {:.1} | {:.1} |",
                backend.label(),
                cores,
                kmsgs,
                ns as f64 / 1e3
            );
            results.push((backend, cores, ns));
        }
    }
    // The paper's conclusions, on a pipeline instead of a point-to-point
    // stress: lock-based gets *slower* with more cores; lock-free gets
    // faster; lock-free multicore beats lock-based multicore convincingly.
    let time = |b: BackendKind, c: usize| {
        results.iter().find(|r| r.0 == b && r.1 == c).unwrap().2 as f64
    };
    let locked_penalty = time(BackendKind::Locked, 4) / time(BackendKind::Locked, 1);
    let lockfree_gain = time(BackendKind::LockFree, 1) / time(BackendKind::LockFree, 4);
    let multicore_win = time(BackendKind::Locked, 4) / time(BackendKind::LockFree, 4);
    println!("\nlock-based multicore slowdown : {locked_penalty:.2}x (>1 = migration penalty)");
    println!("lock-free multicore speedup   : {lockfree_gain:.2}x");
    println!("lock-free vs lock-based @4c   : {multicore_win:.1}x faster");
    assert!(locked_penalty > 1.0, "pipeline must reproduce the migration penalty");
    assert!(multicore_win > 2.0, "lock-free must win on multicore");
    println!("pipeline OK");
}
