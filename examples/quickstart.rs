//! Quickstart: the MCAPI public API in five minutes.
//!
//! Creates a lock-free runtime, two endpoints, and exchanges all three
//! MCAPI payload kinds (connection-less messages, packet channel, scalar
//! channel) between two threads on the real host.
//!
//! Run with: `cargo run --release --example quickstart`

use mcapi::lockfree::RealWorld;
use mcapi::mcapi::types::{BackendKind, ChannelKind, EndpointId, RuntimeCfg, Status};
use mcapi::mcapi::McapiRuntime;

fn main() {
    // 1. One shared-memory communication domain, lock-free data path.
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg::with_backend(BackendKind::LockFree));

    // 2. Endpoints are (domain, node, port) triples; `owner` is the dense
    //    node slot used as the producer lane.
    let producer_ep = EndpointId::new(0, 1, 10);
    let consumer_ep = EndpointId::new(0, 2, 10);
    rt.create_endpoint(producer_ep, 1).expect("producer endpoint");
    let rx = rt.create_endpoint(consumer_ep, 2).expect("consumer endpoint");

    // 3. Connection-less messages with priorities (0 = highest).
    rt.msg_send(1, consumer_ep, b"low priority", 2).unwrap();
    rt.msg_send(1, consumer_ep, b"high priority", 0).unwrap();
    let mut buf = [0u8; 64];
    let n = rt.msg_recv(rx, &mut buf).unwrap();
    println!("first message out: {:?}", std::str::from_utf8(&buf[..n]).unwrap());
    assert_eq!(&buf[..n], b"high priority");
    let n = rt.msg_recv(rx, &mut buf).unwrap();
    println!("second message out: {:?}", std::str::from_utf8(&buf[..n]).unwrap());

    // 4. A connected packet channel (receive buffers come from the pool).
    let ch = rt.connect(producer_ep, consumer_ep, ChannelKind::Packet).unwrap();
    rt.open_send(ch).unwrap();
    rt.open_recv(ch).unwrap();

    // Producer and consumer on separate threads, non-blocking + yield —
    // exactly the paper's Section 4 processing discipline.
    let rt2 = rt.clone();
    let producer = std::thread::spawn(move || {
        for i in 0..100u32 {
            let payload = format!("packet #{i}");
            loop {
                match rt2.pkt_send(ch, payload.as_bytes()) {
                    Ok(()) => break,
                    Err(s) if s.is_would_block() || s == Status::MemLimit => {
                        std::thread::yield_now()
                    }
                    Err(e) => panic!("send: {e:?}"),
                }
            }
        }
    });
    let mut received = 0;
    while received < 100 {
        match rt.pkt_recv(ch, &mut buf) {
            Ok(n) => {
                if received == 0 || received == 99 {
                    println!("packet: {:?}", std::str::from_utf8(&buf[..n]).unwrap());
                }
                received += 1;
            }
            Err(s) if s.is_would_block() => std::thread::yield_now(),
            Err(e) => panic!("recv: {e:?}"),
        }
    }
    producer.join().unwrap();
    rt.close(ch).unwrap();

    // 5. Scalar channel: 8/16/32/64-bit values, no buffers at all.
    let ch = rt.connect(producer_ep, consumer_ep, ChannelKind::Scalar).unwrap();
    rt.open_send(ch).unwrap();
    rt.open_recv(ch).unwrap();
    rt.sclr_send(ch, 0xFEED_F00D).unwrap();
    println!("scalar: {:#x}", rt.sclr_recv(ch).unwrap());
    // Width-typed scalars are checked end to end (MCAPI scalar sizes).
    rt.sclr_send8(ch, 0x5A).unwrap();
    assert_eq!(rt.sclr_recv8(ch).unwrap(), 0x5A);

    // 5b. Batched submission/completion on connected channels: one API
    //     call moves many payloads (amortized ring counter stores on the
    //     lock-free fast path; see also pkt_send_batch/pkt_recv_batch).
    let sent = rt.sclr_send_batch(ch, &[1, 2, 3]).unwrap();
    let mut vals = Vec::new();
    rt.sclr_recv_batch(ch, &mut vals, 8).unwrap();
    assert_eq!((sent, vals.as_slice()), (3, &[1u64, 2, 3][..]));
    println!("scalar batch: {vals:?}");

    // 6. Asynchronous operations: issue, test, wait (Figure 3 lifecycle).
    let h = rt.msg_recv_i(rx).unwrap();
    assert!(!rt.test(h));
    rt.msg_send(1, consumer_ep, b"async hello", 0).unwrap();
    let n = rt.wait_recv(h, &mut buf, 1_000_000_000).unwrap();
    println!("async message: {:?}", std::str::from_utf8(&buf[..n]).unwrap());

    println!("quickstart OK");
}
