//! End-to-end driver: the full reproduction in one binary.
//!
//! Exercises every layer on a real (small) workload and proves they
//! compose:
//!
//! 1. **L3 coordinator + simulator** — runs the paper's Section 6 stress
//!    matrix (Table 2 + Figures 7/8) on the deterministic SMP machine.
//! 2. **L1/L2 via PJRT** — loads the JAX/Pallas performance model
//!    artifacts (`make artifacts`) and produces the Figure 6 curves,
//!    cross-checked against the native MVA solver.
//! 3. **Stop criterion** — feeds the *measured* lock-free ping-pong
//!    latency back into the model, closing the Section 5 loop.
//!
//! The output of this run is recorded in EXPERIMENTS.md.
//!
//! Run with: `make artifacts && cargo run --release --example end_to_end`

use mcapi::coordinator::experiment::{
    print_fig7, print_fig8, print_table2, run_cell_latency, Cell, Matrix, MULTI_CORES,
};
use mcapi::coordinator::MsgKind;
use mcapi::mcapi::types::BackendKind;
use mcapi::model::stopcrit::REFERENCE_HIT_RATE;
use mcapi::model::{stop_criterion, QpnModel, Workload};
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::runtime::PjrtRuntime;

const TX: u64 = 1000;

fn main() {
    println!("=== mcapi-lockfree end-to-end reproduction ===\n");
    let matrix = Matrix::new(TX);

    // ----- Table 2 ---------------------------------------------------------
    println!("--- Table 2: lock-based multicore penalty (paper: Win 0.67-0.80x, Linux 0.21-0.24x)\n");
    let t2 = matrix.table2();
    println!("{}", print_table2(&t2));
    for (os, kind, task, aff) in &t2 {
        assert!(*task < 1.0 && *aff < 1.0, "{os}/{kind}: no penalty?");
    }
    let avg = |os: &str| {
        let v: Vec<f64> = t2.iter().filter(|r| r.0 == os).map(|r| r.2).collect();
        v.iter().sum::<f64>() / v.len() as f64
    };
    assert!(
        avg("linux") < 0.5 * avg("windows"),
        "Linux penalty must be much harsher (paper: ~3x)"
    );

    // ----- Figure 7 --------------------------------------------------------
    println!("--- Figure 7: throughput matrix (kmsg/s)\n");
    let f7 = matrix.fig7();
    println!("{}", print_fig7(&f7));

    // ----- Figure 8 --------------------------------------------------------
    println!("--- Figure 8: lock-free latency speedup (paper: ~2x single-core .. 25x multicore)\n");
    let f8 = matrix.fig8();
    println!("{}", print_fig8(&f8));
    let max_speedup = f8.iter().map(|r| r.2).fold(0.0f64, f64::max);
    let single_core: Vec<f64> =
        f8.iter().filter(|r| r.0.contains("/1c/")).map(|r| r.2).collect();
    let multi_core: Vec<f64> =
        f8.iter().filter(|r| !r.0.contains("/1c/")).map(|r| r.2).collect();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    println!(
        "single-core mean speedup {:.1}x, multicore mean {:.1}x, max {:.1}x\n",
        mean(&single_core),
        mean(&multi_core),
        max_speedup
    );
    assert!(mean(&multi_core) > 3.0 * mean(&single_core), "multicore payoff dominates");
    assert!(max_speedup > 10.0, "double-digit speedup expected (paper: 25x)");

    // ----- Figure 6 (PJRT artifacts) ----------------------------------------
    println!("--- Figure 6: QPN model via AOT artifacts (JAX/Pallas -> XLA -> PJRT)\n");
    let rt = PjrtRuntime::cpu().expect("PJRT CPU client");
    let model = QpnModel::load(&rt).expect("artifacts (run `make artifacts`)");
    let w = Workload::message();
    let hits: Vec<f64> = (0..6).map(|i| 0.5 + 0.1 * i as f64).collect();
    let pts = model.fig6_mva(&w, &[1, 2], &hits).expect("artifact MVA");
    println!("| hit rate | cores | bus util | % of target |");
    println!("|---|---|---|---|");
    for p in &pts {
        // Cross-check against the native solver as we print.
        let scaled = Workload { z: w.z * p.cores as f64, ..w };
        let native = mcapi::model::analytic::mva(&scaled, p.hit_rate, p.cores);
        assert!(
            (p.throughput - native.throughput).abs() / native.throughput < 1e-3,
            "artifact disagrees with native MVA"
        );
        println!(
            "| {:.2} | {} | {:.3} | {:.1}% |",
            p.hit_rate,
            p.cores,
            p.utilization,
            p.target_fraction * 100.0
        );
    }
    println!("\n(artifact values match the native MVA solver to <0.1%)\n");
    if model.has_sweep() {
        let sw = model.fig6_sweep(&w, &[2], &[0.5, 0.7, 0.9]).expect("sweep");
        println!("discrete-time sweep (Pallas kernel) spot check @2 cores:");
        for p in &sw {
            println!(
                "  h={:.1}: util {:.2}, {:.0}% of target",
                p.hit_rate,
                p.utilization,
                p.target_fraction * 100.0
            );
        }
        println!();
    }

    // ----- Stop criterion ----------------------------------------------------
    println!("--- Section 5 stop criterion (model vs measured lock-free latency)\n");
    let lf = run_cell_latency(
        Cell {
            os: OsProfile::linux_rt(),
            cores: MULTI_CORES,
            kind: MsgKind::Message,
            backend: BackendKind::LockFree,
            affinity: AffinityMode::PinnedSpread,
        },
        400,
    );
    let measured_min = lf.min() as f64;
    let verdict = stop_criterion(&w, REFERENCE_HIT_RATE, measured_min);
    println!("model memory-bound minimum : {:.2} us/message", verdict.model_min_ns / 1e3);
    println!("measured lock-free minimum : {:.2} us (sim, Linux 4c)", measured_min / 1e3);
    println!("gap                        : {:.1}x (budget {:.0}x)", verdict.ratio, mcapi::model::stopcrit::GAP_BUDGET);
    println!(
        "verdict                    : {}",
        if verdict.stop { "STOP refactoring (gap = CPU cost, not locks)" } else { "CONTINUE" }
    );
    assert!(
        verdict.stop,
        "the lock-free implementation must pass the paper's stop criterion"
    );

    println!("\nend_to_end OK");
}
