//! Publish/subscribe and state messages: composing the paper's lock-free
//! primitives (Kim's NBB composition + Kopetz's NBW).
//!
//! * **Broadcast (event messages)** — one publisher fans out to N
//!   subscribers through one NBB per subscriber, as Kim et al. describe
//!   for publish/subscribe and broadcast connections.
//! * **State message (NBW)** — the publisher also maintains a "current
//!   sensor reading" that subscribers sample at their own rate; readers
//!   never block the writer and always see an uncorrupted, freshest
//!   value.
//!
//! Run with: `cargo run --release --example pubsub`

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use mcapi::lockfree::{Nbb, Nbw, ReadStatus, RealWorld};

const SUBSCRIBERS: usize = 3;
const EVENTS: u64 = 10_000;

fn main() {
    // Event plane: one SPSC NBB per subscriber (fan-out composition).
    let lanes: Vec<Arc<Nbb<u64, RealWorld>>> =
        (0..SUBSCRIBERS).map(|_| Arc::new(Nbb::new(64))).collect();
    // State plane: NBW with 4 buffers; value = (seq, seq * 3) checked by
    // readers for torn reads.
    let state = Arc::new(Nbw::<[u64; 2], RealWorld>::new(4, [0, 0]));
    let done = Arc::new(AtomicBool::new(false));

    let subscribers: Vec<_> = (0..SUBSCRIBERS)
        .map(|id| {
            let lane = lanes[id].clone();
            let state = state.clone();
            let done = done.clone();
            std::thread::spawn(move || {
                let mut expected = 1u64;
                let mut freshest_seen = 0u64;
                let mut samples = 0u64;
                while expected <= EVENTS {
                    // Drain events (FIFO, per-subscriber lane).
                    match lane.read() {
                        ReadStatus::Ok(v) => {
                            assert_eq!(v, expected, "subscriber {id}: FIFO violated");
                            expected += 1;
                        }
                        _ => std::thread::yield_now(),
                    }
                    // Sample the state message occasionally; it may skip
                    // ahead (state semantics) but never tears or goes back.
                    if expected % 64 == 0 {
                        if let (Some([seq, checksum]), _) = state.read() {
                            assert_eq!(checksum, seq.wrapping_mul(3), "torn state read");
                            assert!(seq >= freshest_seen, "state went backwards");
                            freshest_seen = seq;
                            samples += 1;
                        }
                    }
                }
                while !done.load(Ordering::Relaxed) {
                    std::thread::yield_now();
                }
                (expected - 1, freshest_seen, samples)
            })
        })
        .collect();

    // Publisher: every event goes to all lanes; every 10th event also
    // publishes a state update.
    for seq in 1..=EVENTS {
        for lane in &lanes {
            lane.insert_until(seq);
        }
        if seq % 10 == 0 {
            state.write([seq, seq.wrapping_mul(3)]);
        }
    }
    done.store(true, Ordering::Relaxed);

    for (id, sub) in subscribers.into_iter().enumerate() {
        let (events, freshest, samples) = sub.join().unwrap();
        println!(
            "subscriber {id}: {events} events in order, {samples} state samples, freshest state seq {freshest}"
        );
        assert_eq!(events, EVENTS);
    }
    println!("state writer published {} versions, never blocked", state.writes());
    println!("pubsub OK");
}
