//! CI-required property gates for the automatic liveness plane
//! (`src/mcapi/liveness.rs` + the watchdog/fencing wiring):
//!
//! 1. the **zero-perturbation gate**, sim-asserted: the same SPSC packet
//!    workload reports byte-identical `MachineStats` with the heartbeat
//!    watchdog disarmed and armed — heartbeat bumps and watchdog scans
//!    ride entirely on unpriced host atomics, adding zero priced
//!    simulator operations, not merely "few",
//! 2. epoch fencing end to end: a declared-dead node's sends fail fast
//!    with `NodeFenced` while its committed data stays drainable, and
//!    `rejoin` restores it under a bumped epoch,
//! 3. delay sweeps: a delayed-but-alive victim at *every* priced-op
//!    index inside the probed operation is never confirmed dead by the
//!    armed watchdog (the false-positive bar),
//! 4. real-thread abandonment: an OS thread that parks forever is
//!    detected, fenced and recovered by the watchdog alone — the
//!    scenario contains zero explicit `declare_node_dead` calls.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

use mcapi::coordinator::chaos::{run_delay_sweep, Scenario, Victim};
use mcapi::coordinator::{run_abandon, run_abandon_seeded, AbandonOpts, AbandonRole};
use mcapi::lockfree::mem::RealWorld;
use mcapi::lockfree::World;
use mcapi::mcapi::liveness::LivenessCfg;
use mcapi::mcapi::types::{BackendKind, ChannelKind, EndpointId, RuntimeCfg, Status};
use mcapi::mcapi::McapiRuntime;
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::sim::{Machine, MachineCfg, MachineStats, SimWorld};

const NODE_PROD: usize = 1;
const NODE_CONS: usize = 2;

/// A fixed SPSC packet exchange through the full `McapiRuntime` on the
/// deterministic machine: producer streams `n` sequenced frames, the
/// consumer checks order, a monitor task does the setup and (when
/// `armed`) drives `watchdog_scan_once` on every poll until both
/// workers finish. Returns the machine stats plus the runtime for
/// post-run liveness assertions.
fn spsc_mcapi_run(n: u64, armed: bool) -> (MachineStats, Arc<McapiRuntime<SimWorld>>) {
    let m = Machine::new(MachineCfg::new(4, OsProfile::linux_rt(), AffinityMode::PinnedSpread));
    let rt = McapiRuntime::<SimWorld>::new(RuntimeCfg {
        backend: BackendKind::LockFree,
        max_nodes: 4,
        nbb_capacity: 8,
        // An hour of virtual silence before suspicion: the gate compares
        // scan overhead, and a confirm would do real (priced) repair
        // work by design.
        liveness: LivenessCfg { deadline_ns: 3_600_000_000_000, confirm_scans: 3 },
        ..Default::default()
    });
    let src = EndpointId::new(0, NODE_PROD as u16, 9);
    let dst = EndpointId::new(0, NODE_CONS as u16, 9);
    let ready = Arc::new(AtomicBool::new(false));
    let target = Arc::new(AtomicUsize::new(usize::MAX));

    let producer = {
        let (rt, ready, target) = (rt.clone(), ready.clone(), target.clone());
        m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let ch = target.load(Ordering::SeqCst);
            let mut buf = [0u8; 16];
            for i in 0..n {
                buf[..8].copy_from_slice(&i.to_le_bytes());
                while rt.pkt_send(ch, &buf).is_err() {
                    SimWorld::yield_now();
                }
            }
        })
    };
    let consumer = {
        let (rt, ready, target) = (rt.clone(), ready.clone(), target.clone());
        m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let ch = target.load(Ordering::SeqCst);
            let mut buf = [0u8; 64];
            for i in 0..n {
                loop {
                    match rt.pkt_recv(ch, &mut buf) {
                        Ok(len) => {
                            let got = u64::from_le_bytes(buf[..8].try_into().unwrap());
                            assert_eq!((len, got), (16, i));
                            break;
                        }
                        Err(_) => SimWorld::yield_now(),
                    }
                }
            }
        })
    };
    let monitor = {
        let (rt, ready, target) = (rt.clone(), ready.clone(), target.clone());
        m.spawn(move || {
            rt.create_endpoint(src, NODE_PROD).unwrap();
            rt.create_endpoint(dst, NODE_CONS).unwrap();
            let ch = rt.connect(src, dst, ChannelKind::Packet).unwrap();
            rt.open_send(ch).unwrap();
            rt.open_recv(ch).unwrap();
            target.store(ch, Ordering::SeqCst);
            ready.store(true, Ordering::SeqCst);
            let mut wd = armed.then(|| rt.new_watchdog());
            while !(SimWorld::task_done(0) && SimWorld::task_done(1)) {
                if let Some(w) = wd.as_mut() {
                    rt.watchdog_scan_once(w);
                }
                SimWorld::yield_now();
            }
        })
    };
    (m.run(vec![producer, consumer, monitor]), rt)
}

#[test]
fn armed_watchdog_adds_zero_priced_operations_in_sim() {
    let (off, _) = spsc_mcapi_run(200, false);
    let (on, rt) = spsc_mcapi_run(200, true);
    // The tentpole's pricing contract: heartbeat bumps and watchdog
    // scans live on host atomics only — identical cache-line accesses,
    // context switches, syscalls and virtual time, byte for byte.
    assert_eq!(off, on, "armed watchdog must not perturb the priced simulation");
    // And the plane was genuinely observing, not compiled away:
    assert!(rt.heartbeat_peek(NODE_PROD) > 0, "producer beats recorded");
    assert!(rt.heartbeat_peek(NODE_CONS) > 0, "consumer beats recorded");
    assert_eq!(rt.confirms_observed(), 0, "nobody died in a steady run");
    assert!(rt.node_alive(NODE_PROD) && rt.node_alive(NODE_CONS));
}

#[test]
fn fenced_node_sends_fail_fast_and_rejoin_restores() {
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
        backend: BackendKind::LockFree,
        max_nodes: 4,
        ..Default::default()
    });
    let src = EndpointId::new(0, NODE_PROD as u16, 40);
    let dst = EndpointId::new(0, NODE_CONS as u16, 40);
    rt.create_endpoint(src, NODE_PROD).unwrap();
    rt.create_endpoint(dst, NODE_CONS).unwrap();
    let ch = rt.connect(src, dst, ChannelKind::Packet).unwrap();
    rt.open_send(ch).unwrap();
    rt.open_recv(ch).unwrap();
    rt.pkt_send(ch, b"pre").unwrap();

    rt.declare_node_dead(NODE_PROD);
    let epoch_dead = rt.liveness_epoch(NODE_PROD);
    // The fence outranks every other failure: a zombie fails fast
    // without touching ring state, on the connected and the
    // connectionless path alike.
    assert_eq!(rt.pkt_send(ch, b"zombie"), Err(Status::NodeFenced));
    assert_eq!(rt.msg_send(NODE_PROD, dst, b"zombie", 0), Err(Status::NodeFenced));
    assert!(rt.fence_rejects_observed() >= 2);
    // Committed data outlives its producer: receives are never fenced.
    let mut buf = [0u8; 16];
    let n = rt.pkt_recv(ch, &mut buf).unwrap();
    assert_eq!(&buf[..n], b"pre");

    rt.rejoin(NODE_PROD).unwrap();
    assert!(rt.node_alive(NODE_PROD));
    assert!(rt.liveness_epoch(NODE_PROD) > epoch_dead, "rejoin bumps the epoch");
    assert_eq!(rt.rejoin(usize::MAX), Err(Status::InvalidEndpoint));
}

#[test]
fn delay_sweep_producer_is_never_falsely_confirmed() {
    let r = run_delay_sweep(Scenario::Pkt, Victim::Producer, 12, 40_000);
    assert!(r.pass, "delay sweep failed:\n{}", r.text);
    let points = r.text.lines().filter(|l| l.trim_start().starts_with("delay@")).count();
    assert!(points >= 4, "suspiciously small sweep ({points} points):\n{}", r.text);
}

#[test]
fn delay_sweep_consumer_is_never_falsely_confirmed() {
    let r = run_delay_sweep(Scenario::Pkt, Victim::Consumer, 12, 40_000);
    assert!(r.pass, "delay sweep failed:\n{}", r.text);
}

#[test]
fn abandoned_threads_are_recovered_by_the_watchdog_alone() {
    for role in [AbandonRole::Producer, AbandonRole::Consumer] {
        let r = run_abandon(&AbandonOpts { role, ..Default::default() });
        assert!(r.pass, "{}", r.text);
        assert!(r.text.contains("verdict=PASS"), "{}", r.text);
    }
}

#[test]
fn seeded_abandonment_verdicts_are_stable() {
    // Wall-clock timings make the text non-reproducible; the verdict
    // and the invariants behind it must hold for any seed.
    for seed in [1u64, 2] {
        let r = run_abandon_seeded(seed);
        assert!(r.pass, "seed {seed}: {}", r.text);
    }
}
