//! Integration: the stress coordinator over the full public API, on both
//! execution planes and both backends.

use mcapi::coordinator::{
    run_pingpong_real, run_pingpong_sim, run_stress_real, run_stress_sim, MsgKind, StressOpts,
    Topology,
};
use mcapi::mcapi::types::{BackendKind, RuntimeCfg};
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::sim::{Machine, MachineCfg};

fn sim_machine(cores: usize) -> Machine {
    Machine::new(MachineCfg::new(cores, OsProfile::linux_rt(), AffinityMode::PinnedSpread))
}

#[test]
fn real_plane_all_kinds_all_backends() {
    for backend in [BackendKind::Locked, BackendKind::LockFree] {
        for kind in MsgKind::all() {
            let topo = Topology::one_way(kind, 250);
            let r = run_stress_real(RuntimeCfg::with_backend(backend), &topo, StressOpts::default());
            assert_eq!(r.delivered, 250, "{backend:?}/{kind:?}");
            assert_eq!(r.order_violations, 0, "{backend:?}/{kind:?}");
            assert_eq!(r.latency.count(), 250);
            assert!(r.throughput() > 0.0);
        }
    }
}

#[test]
fn sim_plane_deterministic_across_runs_and_backends() {
    for backend in [BackendKind::Locked, BackendKind::LockFree] {
        let run = || {
            let m = sim_machine(2);
            run_stress_sim(
                &m,
                RuntimeCfg::with_backend(backend),
                &Topology::one_way(MsgKind::Packet, 120),
                StressOpts::default(),
            )
        };
        let a = run();
        let b = run();
        assert_eq!(a.elapsed_ns, b.elapsed_ns, "{backend:?} must be deterministic");
        assert_eq!(a.sim.unwrap(), b.sim.unwrap());
        assert_eq!(a.delivered, 120);
    }
}

#[test]
fn fan_in_preserves_per_producer_fifo() {
    // 4 producers, one consumer — the NBB lane composition under real
    // thread nondeterminism.
    let topo = Topology::fan_in(4, MsgKind::Message, 150);
    let r = run_stress_real(RuntimeCfg::default(), &topo, StressOpts::default());
    assert_eq!(r.delivered, 600);
    assert_eq!(r.order_violations, 0);
}

#[test]
fn ping_pong_real_and_sim() {
    let h = run_pingpong_real(RuntimeCfg::default(), MsgKind::Message, 100);
    assert_eq!(h.count(), 100);
    assert!(h.mean() > 0.0);

    let m = sim_machine(4);
    let (h, stats) = run_pingpong_sim(&m, RuntimeCfg::default(), MsgKind::Scalar, 100);
    assert_eq!(h.count(), 100);
    assert!(stats.virtual_ns > 0);
    // Lock-free ping-pong must not enter the kernel on the data path.
    assert_eq!(stats.syscalls, 0, "lock-free data path must be syscall-free");
}

#[test]
fn locked_pingpong_hits_the_kernel_lockfree_does_not() {
    let run = |backend| {
        let m = sim_machine(4);
        let (_h, stats) = run_pingpong_sim(
            &m,
            RuntimeCfg::with_backend(backend),
            MsgKind::Message,
            50,
        );
        stats
    };
    let locked = run(BackendKind::Locked);
    let lockfree = run(BackendKind::LockFree);
    assert!(locked.syscalls > 100, "locked path must convoy through the kernel: {locked:?}");
    assert_eq!(lockfree.syscalls, 0, "{lockfree:?}");
}

#[test]
fn topology_file_roundtrip() {
    let text = r#"
        [[channel]]
        from = "0:1"
        to = "1:1"
        kind = "scalar"
        count = 80
        [[channel]]
        from = "1:9"
        to = "0:9"
        kind = "message"
        count = 40
    "#;
    let topo = Topology::parse(text).unwrap();
    let r = run_stress_real(RuntimeCfg::default(), &topo, StressOpts::default());
    assert_eq!(r.delivered, 120);
    assert_eq!(r.order_violations, 0);
}

#[test]
fn single_core_sim_interleaves_by_quantum() {
    // Both tasks pinned to one core: the run must still complete (quantum
    // preemption breaks the polling) and context switches must occur.
    let m = Machine::new(MachineCfg::new(1, OsProfile::windows(), AffinityMode::SingleCore));
    let r = run_stress_sim(
        &m,
        RuntimeCfg::default(),
        &Topology::one_way(MsgKind::Message, 100),
        StressOpts::default(),
    );
    assert_eq!(r.delivered, 100);
    assert!(r.sim.unwrap().ctx_switches > 0);
}

#[test]
fn larger_payloads_still_roundtrip() {
    let r = run_stress_real(
        RuntimeCfg::default(),
        &Topology::one_way(MsgKind::Packet, 100),
        StressOpts { payload_len: 192, ..Default::default() },
    );
    assert_eq!(r.delivered, 100);
    assert_eq!(r.order_violations, 0);
}

#[test]
fn state_exchange_beats_fifo_scalar() {
    // Paper §7 future work: "We expect to see a speed-up with the state
    // message exchange policy, because it drops the FIFO requirement."
    // Implemented here (NBW-backed state channels); verify the prediction
    // on the simulator.
    use mcapi::mcapi::types::{ChannelKind, EndpointId};
    use mcapi::mcapi::McapiRuntime;
    use mcapi::sim::SimWorld;
    use std::sync::Arc;

    const N: u64 = 1000;

    // State exchange: writer publishes N values (never blocks), reader
    // samples until it observes the final one.
    let machine = sim_machine(4);
    let rt = McapiRuntime::<SimWorld>::new(RuntimeCfg::default());
    let a = EndpointId::new(0, 0, 1);
    let b = EndpointId::new(0, 1, 1);
    let rt1 = rt.clone();
    let flag = Arc::new(std::sync::atomic::AtomicU32::new(0));
    let f1 = flag.clone();
    let writer = machine.spawn(move || {
        rt1.create_endpoint(a, 0).unwrap();
        while f1.load(std::sync::atomic::Ordering::Relaxed) == 0 {
            <SimWorld as mcapi::lockfree::World>::yield_now();
        }
        let ch = rt1.connect(a, b, ChannelKind::State).unwrap();
        rt1.open_send(ch).unwrap();
        f1.store(ch as u32 + 2, std::sync::atomic::Ordering::Relaxed);
        while f1.load(std::sync::atomic::Ordering::Relaxed) != ch as u32 + 3 {
            <SimWorld as mcapi::lockfree::World>::yield_now();
        }
        for i in 1..=N {
            rt1.state_send(ch, i).unwrap();
        }
    });
    let rt2 = rt.clone();
    let f2 = flag.clone();
    let reader = machine.spawn(move || {
        rt2.create_endpoint(b, 1).unwrap();
        f2.store(1, std::sync::atomic::Ordering::Relaxed);
        let ch;
        loop {
            let v = f2.load(std::sync::atomic::Ordering::Relaxed);
            if v >= 2 {
                ch = (v - 2) as usize;
                break;
            }
            <SimWorld as mcapi::lockfree::World>::yield_now();
        }
        rt2.open_recv(ch).unwrap();
        f2.store(ch as u32 + 3, std::sync::atomic::Ordering::Relaxed);
        loop {
            match rt2.state_recv(ch) {
                Ok(v) if v == N => break,
                Ok(_) | Err(mcapi::mcapi::types::Status::WouldBlock) => {
                    <SimWorld as mcapi::lockfree::World>::yield_now()
                }
                Err(e) => panic!("{e:?}"),
            }
        }
    });
    let state_stats = machine.run(vec![writer, reader]);

    // FIFO scalar exchange of the same N transactions.
    let machine = sim_machine(4);
    let fifo = run_stress_sim(
        &machine,
        RuntimeCfg::default(),
        &Topology::one_way(MsgKind::Scalar, N),
        StressOpts::default(),
    );

    assert!(
        state_stats.virtual_ns < fifo.elapsed_ns,
        "state exchange ({} ns) must beat FIFO scalar ({} ns)",
        state_stats.virtual_ns,
        fifo.elapsed_ns
    );
}
