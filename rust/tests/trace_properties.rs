//! CI-required property gates for the observability plane (`src/obs/`):
//!
//! 1. record codec roundtrip across every event kind,
//! 2. exact, never-silent lane-ring overflow accounting,
//! 3. the **zero-perturbation gate**, sim-asserted: the pinned SPSC
//!    coherence workload reports byte-identical `MachineStats` with
//!    tracing disabled and enabled — instrumentation adds zero priced
//!    operations, not merely "few",
//! 4. end-to-end traced runs: a steady stress populates all four stage
//!    histograms and passes the event-stream replay check; a chaos seed
//!    passes it under fault injection.
//!
//! The plane is process-global, so every test that arms it serializes
//! on [`mcapi::obs::test_guard`].

use std::sync::Arc;

use mcapi::coordinator::{run_traced_chaos, run_traced_stress, TraceOpts};
use mcapi::lockfree::{ChannelRing, World};
use mcapi::mcapi::types::RuntimeCfg;
use mcapi::obs::{self, Event, EventKind, EventRing, CH_ENDPOINT_BIT};
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::sim::{Machine, MachineCfg, MachineStats, SimWorld};

#[test]
fn event_codec_roundtrips_every_kind() {
    for (i, kind) in EventKind::all().into_iter().enumerate() {
        let ev = Event {
            kind,
            channel: CH_ENDPOINT_BIT | (i as u32),
            seq: u64::MAX - i as u64,
            ts_ns: 1_000_000_007 * (i as u64 + 1),
            aux: 0xDEAD_0000 | i as u32,
            lane: 0,
        };
        let back = Event::decode(&ev.encode()).expect("decode");
        assert_eq!(back, ev, "{kind:?}");
    }
    // An unknown kind byte must decode to None, not garbage.
    let mut bad = Event {
        kind: EventKind::SendEnter,
        channel: 0,
        seq: 0,
        ts_ns: 0,
        aux: 0,
        lane: 0,
    }
    .encode();
    bad[0] = 0xEE;
    assert!(Event::decode(&bad).is_none());
}

#[test]
fn lane_ring_overflow_is_exact_and_recovers() {
    let r = EventRing::new(16);
    let rec = |seq: u64| {
        Event { kind: EventKind::QueuePush, channel: 3, seq, ts_ns: seq, aux: 0, lane: 0 }
            .encode()
    };
    let mut accepted = 0u64;
    for i in 0..40u64 {
        if r.push(&rec(i)) {
            accepted += 1;
        }
    }
    assert_eq!(accepted, 16, "exactly cap records fit");
    assert_eq!(r.dropped(), 24, "every rejected push counted exactly once");
    for want in 0..16u64 {
        let got = Event::decode(&r.pop().unwrap()).unwrap();
        assert_eq!(got.seq, want, "survivors are the oldest, in order");
    }
    assert!(r.pop().is_none());
    assert!(r.push(&rec(100)), "space freed: pushes flow again");
    assert_eq!(r.dropped(), 24, "drop counter stands still");
}

/// The pinned coherence workload (`cached_counters_bound_cross_core_
/// traffic_in_sim`, PR 1–2): a 400-message SPSC packet exchange on a
/// 2-core machine. Returns the full machine stats.
fn spsc_coherence_run() -> MachineStats {
    const N: u64 = 400;
    let m = Machine::new(MachineCfg::new(2, OsProfile::linux_rt(), AffinityMode::PinnedSpread));
    let r = Arc::new(ChannelRing::<SimWorld>::new(64, 32));
    let r1 = r.clone();
    let producer = m.spawn(move || {
        let mut buf = [0u8; 24];
        for i in 0..N {
            buf[..8].copy_from_slice(&i.to_le_bytes());
            while r1.send(&buf).is_err() {
                SimWorld::yield_now();
            }
        }
    });
    let r2 = r.clone();
    let consumer = m.spawn(move || {
        for i in 0..N {
            loop {
                let got = r2.recv_with(|b| u64::from_le_bytes(b[..8].try_into().unwrap()));
                match got {
                    Ok(v) => {
                        assert_eq!(v, i);
                        break;
                    }
                    Err(_) => SimWorld::yield_now(),
                }
            }
        }
    });
    m.run(vec![producer, consumer])
}

#[test]
fn tracing_adds_zero_priced_operations_in_sim() {
    let _g = obs::test_guard();
    obs::set_enabled(false);
    obs::reset();
    let off = spsc_coherence_run();
    assert!(obs::drain().is_empty(), "disabled run must emit nothing");

    obs::reset();
    let on_effective = obs::set_enabled(true);
    let on = spsc_coherence_run();
    obs::set_enabled(false);
    let events = obs::drain();
    obs::reset();

    // The whole point of the plane: not "cheap", but *absent* from the
    // priced machine — identical line accesses, context switches,
    // syscalls and virtual time, with the event stream riding on
    // unpriced host atomics.
    assert_eq!(off, on, "tracing must not perturb the priced simulation");
    let per_msg = (on.hits + on.misses) as f64 / 400.0;
    assert!(per_msg < 10.0, "pinned budget holds with tracing on: {per_msg:.1}");
    if on_effective {
        // send + recv marks for 400 messages (trace_id is CH_NONE here —
        // bare-ring events skip stage pairing but are still emitted).
        assert!(events.len() >= 800, "enabled run should emit, got {}", events.len());
    } else {
        assert!(events.is_empty(), "obs-trace compiled out");
    }
}

#[cfg(feature = "obs-trace")]
#[test]
fn traced_steady_stress_populates_stages_and_replays_clean() {
    let _g = obs::test_guard();
    let run = run_traced_stress(
        RuntimeCfg::default(),
        TraceOpts { tx: 128, ..TraceOpts::default() },
    );
    assert_eq!(run.stress.as_ref().unwrap().delivered, 128);
    assert_eq!(run.dropped, 0, "no lane overflow in a 128-tx run");
    assert!(run.replay.pass, "steady replay must pass strictly: {}", run.replay.text);
    let m = run.collector.merged_stages();
    for (h, name) in m.by_stage().iter().zip(obs::STAGES) {
        assert_eq!(h.count(), 128, "stage {name} must have one sample per message");
    }
    // Valid chrome-trace shape: instants + duration spans, one JSON object.
    let chrome = run.collector.chrome_trace_json();
    assert!(chrome.starts_with('{') && chrome.trim_end().ends_with('}'));
    assert!(chrome.contains("\"traceEvents\""));
    assert!(chrome.contains("\"ph\":\"X\""));
    for name in obs::STAGES {
        assert!(chrome.contains(name), "missing stage {name} in chrome trace");
    }
    assert!(run.bench_json_line().contains("\"trace_replay_pass\": 1"));
}

#[cfg(feature = "obs-trace")]
#[test]
fn traced_chaos_seed_replays_clean_under_faults() {
    let _g = obs::test_guard();
    let run = run_traced_chaos(1);
    let chaos = run.chaos.as_ref().unwrap();
    assert!(chaos.pass, "chaos harness verdict: {}", chaos.text);
    assert!(run.replay_pass(), "trace replay verdict: {}", run.replay.text);
    assert!(run.events() > 0, "chaos run should leave a trace");
    assert_eq!(run.replay.dups, 0, "duplicates are never admissible");
}
