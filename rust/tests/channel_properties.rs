//! Integration properties of the connected-channel fast path: ring-backed
//! packet/scalar channels, batched submission/completion, asynchronous
//! packet requests, the doorbell board, and the pool-isolation guarantees
//! (a steady-state SPSC exchange performs **zero** pool/lease operations).
//!
//! Required by CI alongside the tier-1 suite (`.github/workflows/ci.yml`).

use std::sync::Arc;

use mcapi::lockfree::{Atom32, RealWorld, World};
use mcapi::mcapi::types::{BackendKind, ChannelKind, EndpointId, RuntimeCfg, Status};
use mcapi::mcapi::McapiRuntime;
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::sim::{Machine, MachineCfg, SimWorld};

fn both() -> [Arc<McapiRuntime<RealWorld>>; 2] {
    [
        McapiRuntime::new(RuntimeCfg::with_backend(BackendKind::Locked)),
        McapiRuntime::new(RuntimeCfg::with_backend(BackendKind::LockFree)),
    ]
}

/// Create two endpoints, connect and open a channel of `kind`.
fn open_channel<W: World>(
    rt: &McapiRuntime<W>,
    kind: ChannelKind,
    port: u16,
) -> usize {
    let a = EndpointId::new(0, 1, port);
    let b = EndpointId::new(0, 2, port);
    rt.create_endpoint(a, 0).unwrap();
    rt.create_endpoint(b, 1).unwrap();
    let ch = rt.connect(a, b, kind).unwrap();
    rt.open_send(ch).unwrap();
    rt.open_recv(ch).unwrap();
    ch
}

// ---------------------------------------------------------------------------
// Batched submission / completion.
// ---------------------------------------------------------------------------

#[test]
fn packet_batch_roundtrip_both_backends() {
    for rt in both() {
        let ch = open_channel(&rt, ChannelKind::Packet, 1);
        let payloads: Vec<Vec<u8>> = (0u8..6).map(|i| vec![i; (i + 1) as usize]).collect();
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        assert_eq!(rt.pkt_send_batch(ch, &refs), Ok(6));
        assert_eq!(rt.chan_available(ch).unwrap(), 6);
        let mut out = Vec::new();
        assert_eq!(rt.pkt_recv_batch(ch, &mut out, 4), Ok(4));
        assert_eq!(rt.pkt_recv_batch(ch, &mut out, 10), Ok(2));
        assert_eq!(out, payloads, "batch FIFO and payload integrity");
        assert_eq!(rt.pkt_recv_batch(ch, &mut out, 1).unwrap_err(), Status::WouldBlock);
        assert_eq!(rt.pkt_send_batch(ch, &[]), Ok(0));
        assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers, "no leaked leases");
    }
}

#[test]
fn packet_batch_partial_on_full_ring_and_oversize() {
    for backend in [BackendKind::Locked, BackendKind::LockFree] {
        let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
            backend,
            nbb_capacity: 4,
            ..Default::default()
        });
        let ch = open_channel(&rt, ChannelKind::Packet, 1);
        let payloads: Vec<Vec<u8>> = (0u8..6).map(|i| vec![i; 4]).collect();
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        // Only the ring/lane capacity fits; the rest stays with the caller.
        assert_eq!(rt.pkt_send_batch(ch, &refs), Ok(4), "{backend:?}");
        assert_eq!(rt.pkt_send_batch(ch, &refs[4..]).unwrap_err(), Status::WouldBlock);
        let mut out = Vec::new();
        assert_eq!(rt.pkt_recv_batch(ch, &mut out, usize::MAX), Ok(4));
        assert_eq!(rt.pkt_send_batch(ch, &refs[4..]), Ok(2));
        assert_eq!(rt.pkt_recv_batch(ch, &mut out, usize::MAX), Ok(2));
        assert_eq!(out, payloads);
        // An oversized head payload rejects the batch outright.
        let big = vec![0u8; rt.cfg().buf_len + 1];
        assert_eq!(
            rt.pkt_send_batch(ch, &[big.as_slice()]).unwrap_err(),
            Status::MessageLimit,
            "{backend:?}"
        );
        assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers);
    }
}

#[test]
fn scalar_batch_roundtrip_both_backends() {
    for rt in both() {
        let ch = open_channel(&rt, ChannelKind::Scalar, 2);
        let vals: Vec<u64> = (100..106).collect();
        assert_eq!(rt.sclr_send_batch(ch, &vals), Ok(6));
        let mut out = Vec::new();
        assert_eq!(rt.sclr_recv_batch(ch, &mut out, 4), Ok(4));
        assert_eq!(rt.sclr_recv_batch(ch, &mut out, 4), Ok(2));
        assert_eq!(out, vals, "scalar batch FIFO");
        assert_eq!(rt.sclr_recv_batch(ch, &mut out, 1).unwrap_err(), Status::WouldBlock);
        assert_eq!(rt.sclr_send_batch(ch, &[]), Ok(0));
    }
}

// ---------------------------------------------------------------------------
// Scalar widths (MCAPI 8/16/32/64-bit sizes).
// ---------------------------------------------------------------------------

#[test]
fn scalar_widths_roundtrip_both_backends() {
    for rt in both() {
        let ch = open_channel(&rt, ChannelKind::Scalar, 3);
        rt.sclr_send8(ch, 0xAB).unwrap();
        assert_eq!(rt.sclr_recv8(ch).unwrap(), 0xAB);
        rt.sclr_send16(ch, 0xBEEF).unwrap();
        assert_eq!(rt.sclr_recv16(ch).unwrap(), 0xBEEF);
        rt.sclr_send32(ch, 0xDEAD_BEEF).unwrap();
        assert_eq!(rt.sclr_recv32(ch).unwrap(), 0xDEAD_BEEF);
        rt.sclr_send64(ch, 0xFEED_F00D_DEAD_BEEF).unwrap();
        assert_eq!(rt.sclr_recv64(ch).unwrap(), 0xFEED_F00D_DEAD_BEEF);
        // The legacy 64-bit API is width 8 end to end.
        rt.sclr_send(ch, 77).unwrap();
        assert_eq!(rt.sclr_recv64(ch).unwrap(), 77);
        rt.sclr_send64(ch, 78).unwrap();
        assert_eq!(rt.sclr_recv(ch).unwrap(), 78);
    }
}

#[test]
fn scalar_width_mismatch_is_rejected_and_consumed() {
    for rt in both() {
        let ch = open_channel(&rt, ChannelKind::Scalar, 4);
        rt.sclr_send8(ch, 5).unwrap();
        assert_eq!(rt.sclr_recv32(ch).unwrap_err(), Status::ScalarSizeMismatch);
        // The mismatched scalar was consumed, per the documented contract.
        assert_eq!(rt.sclr_recv8(ch).unwrap_err(), Status::WouldBlock);
        // A following correctly-sized exchange still works.
        rt.sclr_send16(ch, 900).unwrap();
        assert_eq!(rt.sclr_recv16(ch).unwrap(), 900);
    }
}

#[test]
fn scalar_batch_width_mismatch_parity_across_backends() {
    // The batch drain treats a width-mismatched scalar exactly like the
    // single-receive loop on both backends: a leading mismatch errors
    // (and is consumed), a mid-batch mismatch stops the batch (and is
    // consumed), later scalars survive.
    for rt in both() {
        let ch = open_channel(&rt, ChannelKind::Scalar, 5);
        let mut out = Vec::new();
        // Leading mismatch.
        rt.sclr_send8(ch, 5).unwrap();
        assert_eq!(
            rt.sclr_recv_batch(ch, &mut out, 4).unwrap_err(),
            Status::ScalarSizeMismatch
        );
        assert_eq!(rt.sclr_recv_batch(ch, &mut out, 4).unwrap_err(), Status::WouldBlock);
        // Mid-batch mismatch: partial delivery, offender consumed.
        rt.sclr_send64(ch, 1).unwrap();
        rt.sclr_send8(ch, 2).unwrap();
        rt.sclr_send64(ch, 3).unwrap();
        assert_eq!(rt.sclr_recv_batch(ch, &mut out, 8), Ok(1));
        assert_eq!(out, vec![1]);
        assert_eq!(rt.sclr_recv_batch(ch, &mut out, 8), Ok(1));
        assert_eq!(out, vec![1, 3]);
    }
}

// ---------------------------------------------------------------------------
// Asynchronous packet operations (Figure 3 requests).
// ---------------------------------------------------------------------------

#[test]
fn async_packet_send_recv_wait_cancel() {
    for rt in both() {
        let ch = open_channel(&rt, ChannelKind::Packet, 5);
        let h = rt.pkt_send_i(ch, b"async pkt").unwrap();
        assert!(rt.test(h));
        assert_eq!(rt.wait_pkt_send(h, ch, b"async pkt", 1_000_000), Status::Success);
        // Nothing more pending: an async receive times out, then the
        // still-pending request can be cancelled... (timeout path)
        let mut buf = [0u8; 32];
        let hr = rt.pkt_recv_i(ch).unwrap();
        let n = rt.wait_pkt_recv(hr, &mut buf, 1_000_000).unwrap();
        assert_eq!(&buf[..n], b"async pkt");
        let ht = rt.pkt_recv_i(ch).unwrap();
        assert_eq!(rt.wait_pkt_recv(ht, &mut buf, 0).unwrap_err(), Status::Timeout);
        rt.cancel(ht).unwrap();
        assert_eq!(rt.requests_in_use(), 0);
        // Async ops on a bad channel are rejected up front.
        assert_eq!(rt.pkt_send_i(999, b"x").unwrap_err(), Status::InvalidChannel);
        assert_eq!(rt.pkt_recv_i(999).unwrap_err(), Status::InvalidChannel);
    }
}

// ---------------------------------------------------------------------------
// Pool isolation and lease restoration.
// ---------------------------------------------------------------------------

#[test]
fn locked_packet_push_failure_restores_lease() {
    // The reference path leases a pool buffer *before* the queue push;
    // when the push fails the lease must be aborted (Figure 4), not
    // leaked — on a tiny queue this is easy to provoke.
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
        backend: BackendKind::Locked,
        nbb_capacity: 2,
        ..Default::default()
    });
    let ch = open_channel(&rt, ChannelKind::Packet, 6);
    rt.pkt_send(ch, b"a").unwrap();
    rt.pkt_send(ch, b"b").unwrap();
    assert_eq!(rt.pkt_send(ch, b"c").unwrap_err(), Status::WouldBlock);
    assert_eq!(
        rt.buffers_available(),
        rt.cfg().pool_buffers - 2,
        "failed push must hand its lease back"
    );
    let mut buf = [0u8; 8];
    assert_eq!(rt.pkt_recv(ch, &mut buf).unwrap(), 1);
    assert_eq!(rt.pkt_recv(ch, &mut buf).unwrap(), 1);
    assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers);
}

#[test]
fn locked_packet_pool_exhaustion_reports_memlimit() {
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
        backend: BackendKind::Locked,
        pool_buffers: 1,
        nbb_capacity: 8,
        ..Default::default()
    });
    let ch = open_channel(&rt, ChannelKind::Packet, 7);
    rt.pkt_send(ch, b"a").unwrap();
    assert_eq!(rt.pkt_send(ch, b"b").unwrap_err(), Status::MemLimit);
    let mut buf = [0u8; 8];
    rt.pkt_recv(ch, &mut buf).unwrap();
    rt.pkt_send(ch, b"b").unwrap();
}

#[test]
fn lockfree_packet_path_never_touches_the_pool() {
    // The fast path carries payloads in the ring slots: filling the ring
    // to rejection and draining it must leave the pool untouched — no
    // lease, no abort path, MemLimit impossible.
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
        backend: BackendKind::LockFree,
        nbb_capacity: 4,
        ..Default::default()
    });
    let ch = open_channel(&rt, ChannelKind::Packet, 8);
    for i in 0..4u8 {
        rt.pkt_send(ch, &[i; 4]).unwrap();
    }
    assert_eq!(rt.pkt_send(ch, b"over").unwrap_err(), Status::WouldBlock);
    let mut buf = [0u8; 8];
    for i in 0..4u8 {
        assert_eq!(rt.pkt_recv(ch, &mut buf).unwrap(), 4);
        assert_eq!(buf[..4], [i; 4]);
    }
    assert_eq!(rt.pkt_recv(ch, &mut buf).unwrap_err(), Status::WouldBlock);
    assert_eq!(rt.pool_lease_ops(), 0, "packet fast path must perform zero lease ops");
    assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers);
    // Sanity that the counter *does* count: the message path leases.
    let dst = EndpointId::new(0, 3, 99);
    let ep = rt.create_endpoint(dst, 2).unwrap();
    rt.msg_send(0, dst, b"leased", 0).unwrap();
    assert!(rt.pool_lease_ops() > 0);
    let _ = rt.msg_recv(ep, &mut buf);
}

// ---------------------------------------------------------------------------
// Channel-slot reuse and the doorbell board.
// ---------------------------------------------------------------------------

#[test]
fn reconnected_channel_slot_delivers_no_stale_packets() {
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg::with_backend(BackendKind::LockFree));
    let ch = open_channel(&rt, ChannelKind::Packet, 9);
    rt.pkt_send(ch, b"stale1").unwrap();
    rt.pkt_send(ch, b"stale2").unwrap();
    rt.close(ch).unwrap();
    // The freed slot is reused by the next connect; its ring residue
    // must be drained before the channel goes CONNECTED.
    let c = EndpointId::new(0, 3, 10);
    let d = EndpointId::new(0, 4, 10);
    rt.create_endpoint(c, 2).unwrap();
    rt.create_endpoint(d, 3).unwrap();
    let ch2 = rt.connect(c, d, ChannelKind::Packet).unwrap();
    assert_eq!(ch2, ch, "first free slot is reused");
    rt.open_send(ch2).unwrap();
    rt.open_recv(ch2).unwrap();
    let mut buf = [0u8; 16];
    assert_eq!(rt.pkt_recv(ch2, &mut buf).unwrap_err(), Status::WouldBlock);
    rt.pkt_send(ch2, b"fresh").unwrap();
    assert_eq!(rt.pkt_recv(ch2, &mut buf).unwrap(), 5);
    assert_eq!(&buf[..5], b"fresh");
}

#[test]
fn doorbell_flags_pending_channels() {
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg::with_backend(BackendKind::LockFree));
    let ch0 = open_channel(&rt, ChannelKind::Packet, 11);
    let c = EndpointId::new(0, 3, 12);
    let d = EndpointId::new(0, 4, 12);
    rt.create_endpoint(c, 2).unwrap();
    rt.create_endpoint(d, 3).unwrap();
    let ch1 = rt.connect(c, d, ChannelKind::Scalar).unwrap();
    rt.open_send(ch1).unwrap();
    rt.open_recv(ch1).unwrap();

    assert_eq!(rt.chan_poll(&[ch0, ch1]), None, "idle board");
    rt.sclr_send(ch1, 9).unwrap();
    assert_eq!(rt.chan_poll(&[ch0, ch1]), Some(ch1));
    rt.pkt_send(ch0, b"p").unwrap();
    assert_eq!(rt.chan_poll(&[ch0, ch1]), Some(ch0), "first flagged in poll order");
    let mut buf = [0u8; 8];
    rt.pkt_recv(ch0, &mut buf).unwrap();
    assert_eq!(rt.sclr_recv(ch1).unwrap(), 9);
    // Consumed: the next empty probe clears each stale flag.
    assert_eq!(rt.pkt_recv(ch0, &mut buf).unwrap_err(), Status::WouldBlock);
    assert_eq!(rt.sclr_recv(ch1).unwrap_err(), Status::WouldBlock);
    assert_eq!(rt.chan_poll(&[ch0, ch1]), None, "empty probes clear the board");
    // Cleared flags lose nothing.
    rt.sclr_send(ch1, 10).unwrap();
    assert_eq!(rt.chan_poll(&[ch0, ch1]), Some(ch1));
    assert_eq!(rt.sclr_recv(ch1).unwrap(), 10);
    // Out-of-table indices are skipped, not a panic.
    assert_eq!(rt.chan_poll(&[9999, ch0]), None);
}

#[test]
fn close_clears_the_doorbell_bit() {
    // A channel closed with payloads still flagged must not shadow live
    // channels in a receiver's poll list forever.
    let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg::with_backend(BackendKind::LockFree));
    let ch0 = open_channel(&rt, ChannelKind::Packet, 13);
    let c = EndpointId::new(0, 3, 14);
    let d = EndpointId::new(0, 4, 14);
    rt.create_endpoint(c, 2).unwrap();
    rt.create_endpoint(d, 3).unwrap();
    let ch1 = rt.connect(c, d, ChannelKind::Scalar).unwrap();
    rt.open_send(ch1).unwrap();
    rt.open_recv(ch1).unwrap();

    rt.pkt_send(ch0, b"undrained").unwrap(); // flags ch0
    rt.close(ch0).unwrap();
    rt.sclr_send(ch1, 42).unwrap();
    assert_eq!(
        rt.chan_poll(&[ch0, ch1]),
        Some(ch1),
        "closed channel's stale flag must not starve live channels"
    );
    assert_eq!(rt.sclr_recv(ch1).unwrap(), 42);
}

// ---------------------------------------------------------------------------
// Simulator-asserted fast-path properties (acceptance gates).
// ---------------------------------------------------------------------------

#[test]
fn sim_steady_packet_exchange_is_pool_free_and_coherence_bounded() {
    // Acceptance: a steady-state SPSC packet exchange over the ring
    // performs ZERO pool/lease operations, and its coherence footprint
    // stays bounded (the cached peer counters re-load the shared word at
    // most once per ring wrap; see also the exact-budget ring unit test).
    const N: u64 = 400;
    let m = Machine::new(MachineCfg::new(
        2,
        OsProfile::linux_rt(),
        AffinityMode::PinnedSpread,
    ));
    let rt = McapiRuntime::<SimWorld>::new(RuntimeCfg::with_backend(BackendKind::LockFree));
    let ready = Arc::new(<SimWorld as World>::U32::new(0));
    let a = EndpointId::new(0, 1, 1);
    let b = EndpointId::new(0, 2, 1);
    let rt1 = rt.clone();
    let ready1 = ready.clone();
    let producer = m.spawn(move || {
        rt1.create_endpoint(a, 0).unwrap();
        rt1.create_endpoint(b, 1).unwrap();
        let ch = rt1.connect(a, b, ChannelKind::Packet).unwrap();
        rt1.open_send(ch).unwrap();
        rt1.open_recv(ch).unwrap();
        ready1.store(ch as u32 + 1);
        let mut buf = [0u8; 24];
        for i in 0..N {
            buf[..8].copy_from_slice(&i.to_le_bytes());
            loop {
                match rt1.pkt_send(ch, &buf) {
                    Ok(()) => break,
                    Err(s) if s.is_would_block() => <SimWorld as World>::yield_now(),
                    Err(s) => panic!("send: {s:?}"),
                }
            }
        }
    });
    let rt2 = rt.clone();
    let consumer = m.spawn(move || {
        while ready.load() == 0 {
            <SimWorld as World>::yield_now();
        }
        let ch = ready.load() as usize - 1;
        let mut buf = [0u8; 24];
        for i in 0..N {
            loop {
                match rt2.pkt_recv(ch, &mut buf) {
                    Ok(n) => {
                        assert_eq!(n, 24);
                        assert_eq!(u64::from_le_bytes(buf[..8].try_into().unwrap()), i);
                        break;
                    }
                    Err(s) if s.is_would_block() => <SimWorld as World>::yield_now(),
                    Err(s) => panic!("recv: {s:?}"),
                }
            }
        }
    });
    let stats = m.run(vec![producer, consumer]);
    assert_eq!(rt.pool_lease_ops(), 0, "fast path must never touch the pool");
    assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers);
    // Whole-run line-access budget (includes setup, channel_ready hits,
    // doorbell traffic and empty-poll retries): generous against
    // scheduling noise — the exact one-cross-core-load-per-wrap budget is
    // asserted at the ring level in `lockfree::ring`'s sim test.
    let per_msg = (stats.hits + stats.misses) as f64 / N as f64;
    assert!(
        per_msg < 64.0,
        "ring packet exchange should stay under 64 line accesses/msg, got {per_msg:.1} ({stats:?})"
    );
}

#[test]
fn sim_chan_poll_cost_is_constant_in_channel_count() {
    // Acceptance: an idle receiver polls ONE cache line regardless of how
    // many channels it serves — one relaxed word-load per poll at the
    // default channel-table size.
    let accesses = |channels: usize, polls: usize| -> u64 {
        let m = Machine::new(MachineCfg::new(
            1,
            OsProfile::linux_rt(),
            AffinityMode::SingleCore,
        ));
        let stats = m.run_tasks(1, |_| {
            move || {
                let rt =
                    McapiRuntime::<SimWorld>::new(RuntimeCfg::with_backend(BackendKind::LockFree));
                let mut chs = Vec::new();
                for i in 0..channels {
                    let a = EndpointId::new(0, 1, 20 + i as u16);
                    let b = EndpointId::new(0, 2, 20 + i as u16);
                    rt.create_endpoint(a, 0).unwrap();
                    rt.create_endpoint(b, 1).unwrap();
                    let ch = rt.connect(a, b, ChannelKind::Scalar).unwrap();
                    rt.open_send(ch).unwrap();
                    rt.open_recv(ch).unwrap();
                    chs.push(ch);
                }
                for _ in 0..polls {
                    assert_eq!(rt.chan_poll(&chs), None);
                }
            }
        });
        stats.hits + stats.misses
    };
    // Deltas cancel the (deterministic) setup cost exactly.
    let idle_2 = accesses(2, 200) - accesses(2, 0);
    let idle_8 = accesses(8, 200) - accesses(8, 0);
    assert_eq!(idle_2, idle_8, "idle poll cost must not scale with channel count");
    assert_eq!(idle_2, 200, "one word-load per idle poll");
}

#[test]
fn sim_batched_scalar_channel_amortizes_counter_stores() {
    // Acceptance (runtime level): driving the same scalar workload with
    // a larger batch must strictly reduce virtual completion time — the
    // O(1)-stores-per-batch property measured exactly at the ring level
    // (see lockfree::ring tests) shows through the full MCAPI stack.
    use mcapi::coordinator::{run_stress_sim, MsgKind, StressOpts, Topology};
    let run = |batch: usize| {
        let m = Machine::new(MachineCfg::new(
            2,
            OsProfile::linux_rt(),
            AffinityMode::PinnedSpread,
        ));
        let topo = Topology::one_way(MsgKind::Scalar, 400);
        run_stress_sim(&m, RuntimeCfg::default(), &topo, StressOpts::with_batch(batch))
    };
    let single = run(1);
    let batched = run(16);
    assert_eq!(single.delivered, batched.delivered);
    assert!(
        batched.elapsed_ns < single.elapsed_ns,
        "scalar batch 16 should finish sooner: {batched:?} vs {single:?}"
    );
}

// ---------------------------------------------------------------------------
// Zero-copy receive views (`pkt_recv_view`).
// ---------------------------------------------------------------------------

#[test]
fn pkt_recv_view_roundtrip_both_backends() {
    for rt in both() {
        let ch = open_channel(&rt, ChannelKind::Packet, 1);
        rt.pkt_send(ch, &[10, 20, 30]).unwrap();
        let seen = rt.pkt_recv_view(ch, |b| b.to_vec()).unwrap();
        assert_eq!(seen, vec![10, 20, 30], "view observes the exact payload bytes");
        // The view consumed the packet.
        let r = rt.pkt_recv_view(ch, |b| b.len());
        assert_eq!(r.unwrap_err(), Status::WouldBlock);
        assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers, "no leaked leases");
    }
}

#[test]
fn pkt_recv_view_lease_holds_the_slot_until_release() {
    // Borrow-until-release: while the view closure runs, the ring slot
    // is still leased to the consumer — a producer blocked on a full
    // ring must stay blocked until the closure returns, and succeed
    // right after.
    let cfg = RuntimeCfg { nbb_capacity: 2, ..RuntimeCfg::with_backend(BackendKind::LockFree) };
    let rt: Arc<McapiRuntime<RealWorld>> = McapiRuntime::new(cfg);
    let ch = open_channel(&rt, ChannelKind::Packet, 1);
    rt.pkt_send(ch, &[1]).unwrap();
    rt.pkt_send(ch, &[2]).unwrap();
    assert!(
        rt.pkt_send(ch, &[3]).unwrap_err().is_would_block(),
        "ring of two slots is full"
    );
    let (first, blocked_inside) = rt
        .pkt_recv_view(ch, |b| {
            // Still inside the borrow: the slot being viewed is not
            // yet recycled, so the ring is still effectively full.
            let r = rt.pkt_send(ch, &[3]);
            (b[0], r.err().is_some_and(|s| s.is_would_block()))
        })
        .unwrap();
    assert_eq!(first, 1);
    assert!(blocked_inside, "send inside the view must stay would-blocked");
    // Borrow released: the freed slot accepts the pending payload.
    rt.pkt_send(ch, &[3]).unwrap();
    let mut buf = [0u8; 8];
    assert_eq!(rt.pkt_recv(ch, &mut buf).unwrap(), 1);
    assert_eq!(buf[0], 2);
    assert_eq!(rt.pkt_recv_view(ch, |b| b[0]).unwrap(), 3);
}
