//! Chaos-sweep acceptance gate (required by CI).
//!
//! Sim-asserted kill-point sweep: kill the producer at **every** priced-op
//! index inside a mid-stream `pkt_send`, and the consumer inside
//! `pkt_recv`, one fresh deterministic machine per point. After the
//! watchdog declares the dead node and recovery runs, every point must
//! show: zero committed messages lost, zero duplicated, zero torn
//! payloads, zero leaked pool leases, and every blocked peer unblocked
//! with `EndpointDead`/`Timeout` (the run terminating at all proves no
//! deadlock — the scheduler panics on a deadlock with no timed waiter).
//! The same fault seed must reproduce an identical report byte-for-byte.

use mcapi::coordinator::chaos::{
    run_kill_sweep, run_seeded, run_stall_sweep, ChaosOpts, Scenario, Victim,
};

#[test]
fn kill_producer_at_every_op_inside_pkt_send() {
    let r = run_kill_sweep(Scenario::Pkt, Victim::Producer, 16);
    assert!(r.pass, "sweep failed:\n{}", r.text);
    // The bracketed send must span a non-trivial window of priced ops —
    // a degenerate 1-point sweep would mean the probe bracketed nothing.
    let points = r.text.lines().filter(|l| l.trim_start().starts_with("kill@")).count();
    assert!(points >= 4, "suspiciously small sweep ({points} points):\n{}", r.text);
}

#[test]
fn kill_consumer_at_every_op_inside_pkt_recv() {
    let r = run_kill_sweep(Scenario::Pkt, Victim::Consumer, 16);
    assert!(r.pass, "sweep failed:\n{}", r.text);
    let points = r.text.lines().filter(|l| l.trim_start().starts_with("kill@")).count();
    assert!(points >= 4, "suspiciously small sweep ({points} points):\n{}", r.text);
}

#[test]
fn kill_producer_at_every_op_inside_msg_send_reclaims_leases() {
    // The connectionless path exercises pool leases: a producer killed
    // mid-`msg_send` may die holding one; recovery must reclaim it
    // (leaked=0 is part of the per-point judgement).
    let r = run_kill_sweep(Scenario::Msg, Victim::Producer, 16);
    assert!(r.pass, "sweep failed:\n{}", r.text);
}

#[test]
fn seeded_reports_reproduce_byte_for_byte() {
    for scenario in [Scenario::Pkt, Scenario::Msg] {
        for seed in [1u64, 2, 3, 5, 8, 13] {
            let opts = ChaosOpts { scenario, seed, ..ChaosOpts::default() };
            let a = run_seeded(&opts);
            let b = run_seeded(&opts);
            assert!(a.pass, "seed {seed} {:?} failed: {}", scenario, a.text);
            assert_eq!(a.text, b.text, "seed {seed} report must be reproducible");
            assert!(a.text.contains(&format!("seed={seed}")));
            assert!(a.text.ends_with("verdict=PASS"));
        }
    }
}

// ---------------------------------------------------------------------------
// Stall sweeps: freeze — never kill — the victim at every priced-op
// index inside the probed operation. The bar is strictly higher than
// the kill sweep's: a stall loses nothing, so every point must deliver
// the complete stream in-band with both sides finishing clean. This
// pins the peer-active liveness handshakes (`WouldBlockPeerActive`,
// doorbell re-check) across the scalar-channel and batched paths.
// ---------------------------------------------------------------------------

/// Virtual-ns stall: long enough to cross scheduling quanta, far below
/// the 2 ms receive deadline so nothing times out terminally.
const STALL_NS: u64 = 40_000;

#[test]
fn stall_producer_inside_pkt_send_only_delays_the_stream() {
    let r = run_stall_sweep(Scenario::Pkt, Victim::Producer, 16, STALL_NS);
    assert!(r.pass, "stall sweep failed:\n{}", r.text);
    let points = r.text.lines().filter(|l| l.trim_start().starts_with("stall@")).count();
    assert!(points >= 4, "suspiciously small sweep ({points} points):\n{}", r.text);
}

#[test]
fn stall_consumer_inside_pkt_recv_only_delays_the_stream() {
    let r = run_stall_sweep(Scenario::Pkt, Victim::Consumer, 16, STALL_NS);
    assert!(r.pass, "stall sweep failed:\n{}", r.text);
}

#[test]
fn stall_sweep_covers_scalar_channels() {
    for victim in [Victim::Producer, Victim::Consumer] {
        let r = run_stall_sweep(Scenario::Sclr, victim, 16, STALL_NS);
        assert!(r.pass, "sclr {victim:?} stall sweep failed:\n{}", r.text);
    }
}

#[test]
fn stall_sweep_covers_batched_paths() {
    for victim in [Victim::Producer, Victim::Consumer] {
        let r = run_stall_sweep(Scenario::PktBatch, victim, 16, STALL_NS);
        assert!(r.pass, "pkt_batch {victim:?} stall sweep failed:\n{}", r.text);
    }
}

#[test]
fn kill_consumer_at_every_op_during_a_steal_storm() {
    // Single-producer hot lane + extra consumers: the swept victim's
    // first bracketed claim is a batch *steal* (its home deal misses
    // the hot lane), so every kill point lands inside the thief
    // protocol — claim CAS, stash staging, the committed flag, the
    // amortized ack advance. The judge is the same exactly-once
    // set-difference as every other sweep: per-role kill budgets bound
    // missing/extra frames, salvaged stash entries are re-enqueued.
    use mcapi::coordinator::{run_mpmc_steal_kill_sweep, MpmcOpts};
    let r = run_mpmc_steal_kill_sweep(&MpmcOpts { messages: 6, ..Default::default() });
    assert!(r.pass, "steal-storm kill sweep failed:\n{}", r.text);
    let points = r.text.lines().filter(|l| l.trim_start().starts_with("kill@")).count();
    assert!(points >= 4, "suspiciously small sweep ({points} points):\n{}", r.text);
}

#[test]
fn kill_consumer_inside_a_batched_drain_loses_at_most_one_batch() {
    // The batched drain acks a whole run with one counter pair, so a
    // consumer killed at the ack boundary may take up to one batch with
    // it — and nothing more (the generalized ack-hole judgement).
    let r = run_kill_sweep(Scenario::PktBatch, Victim::Consumer, 16);
    assert!(r.pass, "sweep failed:\n{}", r.text);
}
