//! Work-stealing acceptance gate (required by CI).
//!
//! Sim-asserted properties of the contention-adaptive MPMC plane
//! (per-producer SPSC lanes + home-lane assignment + batch stealing):
//!
//! * **Zero-RMW steady state** — a group member draining its home
//!   lanes performs *zero* shared-counter CAS/RMW operations (the
//!   priced-op accounting in the simulator proves it, not inspection).
//! * The dry path *does* pay RMWs (steal cursor + thief claim), so the
//!   zero above is a property of the protocol, not of the meter.
//! * Steal-storm exactly-once: one hot lane, many consumers, every
//!   frame delivered exactly once through batch steals.
//! * Skewed-consumer exactly-once: a deliberately slowed member's
//!   backlog is absorbed by its peers without loss or duplication.
//! * The `wake.misses` / `mpmc.steals` counters are registered in the
//!   obs plane (the targeted-doorbell re-ring proof instrument).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use mcapi::coordinator::{run_mpmc_skewed, run_mpmc_steal_storm, MpmcOpts};
use mcapi::lockfree::ShardedRing;
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::sim::{Machine, MachineCfg, SimWorld};

/// Payload codec for the raw-ring gates: 8-byte LE sequence numbers.
fn seq_payload(i: u64) -> [u8; 8] {
    i.to_le_bytes()
}

fn decode_seq(b: &[u8]) -> u64 {
    u64::from_le_bytes(b[..8].try_into().unwrap())
}

/// The tentpole acceptance gate: draining home lanes in steady state
/// costs **zero** atomic RMW operations. Producers publish with plain
/// stores (NBB counter protocol), the home member consumes with plain
/// loads/stores plus fences — the shared steal cursor is never touched
/// while home lanes have work.
#[test]
fn home_lane_drain_steady_state_costs_zero_rmws() {
    const MSGS: u64 = 8;
    let m = Machine::new(MachineCfg::new(1, OsProfile::linux_rt(), AffinityMode::SingleCore));
    let rmws = Arc::new(AtomicU64::new(u64::MAX));
    let ops = Arc::new(AtomicU64::new(0));
    let (rmws_out, ops_out) = (rmws.clone(), ops.clone());
    let h = m.spawn(move || {
        let ring: ShardedRing<SimWorld> = ShardedRing::new(4, 4, 16, 16);
        // Sole member: every lane is a home lane after the deal.
        ring.attach_member(0);
        assert_eq!(ring.home_of(1), Some(0));
        for i in 0..MSGS {
            ring.send(1, &seq_payload(i)).unwrap();
        }
        // Measured window: exactly the committed backlog, so the dry
        // (steal) path is never entered.
        let rmw_before = SimWorld::rmw_count();
        let op_before = SimWorld::op_count();
        for want in 0..MSGS {
            let got = ring.recv_as(0, decode_seq).expect("home lane holds the frame");
            assert_eq!(got, want, "home drain is per-lane FIFO");
        }
        rmws_out.store(SimWorld::rmw_count() - rmw_before, Ordering::SeqCst);
        ops_out.store(SimWorld::op_count() - op_before, Ordering::SeqCst);
    });
    m.run(vec![h]);
    assert_eq!(
        rmws.load(Ordering::SeqCst),
        0,
        "home-lane steady state must perform zero shared-counter RMWs"
    );
    assert!(
        ops.load(Ordering::SeqCst) >= MSGS,
        "the drain window must have been priced (meter sanity)"
    );
}

/// The converse meter-sanity gate: a dry member's batch steal *does*
/// pay RMWs (one steal-cursor `fetch_add` plus one thief-claim CAS at
/// minimum). If this ever reads zero, the RMW accounting is broken and
/// the gate above proves nothing.
#[test]
fn dry_path_steal_pays_the_only_rmws() {
    let m = Machine::new(MachineCfg::new(1, OsProfile::linux_rt(), AffinityMode::SingleCore));
    let rmws = Arc::new(AtomicU64::new(0));
    let out = rmws.clone();
    let h = m.spawn(move || {
        let ring: ShardedRing<SimWorld> = ShardedRing::new(4, 4, 16, 16);
        ring.attach_member(0); // homes every lane away from member 2
        ring.send(1, &seq_payload(7)).unwrap();
        let before = SimWorld::rmw_count();
        // Member 2 owns no home lanes: its pop must go through the
        // shared steal cursor and the thief-claim CAS.
        let got = ring.recv_as(2, decode_seq).expect("thief steals the backlog");
        out.store(SimWorld::rmw_count() - before, Ordering::SeqCst);
        assert_eq!(got, 7);
    });
    m.run(vec![h]);
    assert!(
        rmws.load(Ordering::SeqCst) >= 2,
        "a steal must pay at least the cursor fetch_add and the claim CAS, got {}",
        rmws.load(Ordering::SeqCst)
    );
}

#[test]
fn steal_storm_delivers_exactly_once() {
    // One producer, four consumers: one hot lane, so at most one member
    // drains it as home and the rest must steal to make progress.
    let opts = MpmcOpts { producers: 2, consumers: 4, messages: 12, ..Default::default() };
    let r = run_mpmc_steal_storm(&opts);
    assert!(r.pass, "steal storm failed:\n{}", r.text);
    assert_eq!(r.delivered, 24, "every frame in-band, exactly once:\n{}", r.text);
    assert!(
        r.text.contains("steal_batches>="),
        "storm report must carry the steal-batch floor:\n{}",
        r.text
    );
}

#[test]
fn skewed_consumer_stream_stays_exactly_once() {
    // Consumer 0 is slowed (yield-injected): its home lanes back up and
    // the symmetric members must absorb the backlog by stealing.
    let opts = MpmcOpts { producers: 2, consumers: 3, messages: 10, ..Default::default() };
    let r = run_mpmc_skewed(&opts);
    assert!(r.pass, "skewed run failed:\n{}", r.text);
    assert_eq!(r.delivered, 20, "slow member loses nothing:\n{}", r.text);
}

#[test]
fn skewed_report_reproduces_byte_for_byte() {
    let opts = MpmcOpts { messages: 8, ..Default::default() };
    let a = run_mpmc_skewed(&opts);
    let b = run_mpmc_skewed(&opts);
    assert!(a.pass, "skewed run failed:\n{}", a.text);
    assert_eq!(a.text, b.text, "skew report must reproduce exactly");
}

#[test]
fn steal_and_wake_counters_are_registered() {
    // The targeted doorbell (wake-one) counts re-rings in
    // `wake.misses`; steals count batches in `mpmc.steals`. Both must
    // exist in the obs registry so harnesses can prove no lost wakeups
    // without bespoke plumbing.
    let names: Vec<String> =
        mcapi::obs::counters_snapshot().into_iter().map(|(n, _)| n).collect();
    for want in ["wake.misses", "mpmc.steals"] {
        assert!(
            names.iter().any(|n| n == want),
            "counter {want:?} missing from the obs registry: {names:?}"
        );
    }
}
