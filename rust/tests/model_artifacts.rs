//! Integration: the AOT model artifacts (JAX/Pallas → HLO text → PJRT)
//! against the native analytic solver and the paper's Section 5 claims.
//!
//! The artifact-driven tests require `make artifacts` *and* a PJRT-capable
//! build (the `xla` crate); when either is missing they skip with a notice
//! instead of failing — the native-solver assertions below always run.

use mcapi::model::stopcrit::{stop_criterion, GAP_BUDGET, REFERENCE_HIT_RATE};
use mcapi::model::{analytic, QpnModel, Workload};
use mcapi::runtime::{ArtifactSpec, PjrtRuntime};

fn model() -> Option<(PjrtRuntime, QpnModel)> {
    if !ArtifactSpec::MvaSolver.exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    let Ok(rt) = PjrtRuntime::cpu() else {
        eprintln!("skipping: PJRT backend unavailable in this build");
        return None;
    };
    let m = QpnModel::load(&rt).expect("load artifacts");
    Some((rt, m))
}

#[test]
fn pjrt_platform_is_cpu() {
    let Some((rt, _)) = model() else { return };
    assert_eq!(rt.platform_name().to_lowercase(), "cpu");
    assert!(rt.device_count() >= 1);
}

#[test]
fn artifact_mva_matches_native_solver_across_workloads() {
    let Some((_rt, m)) = model() else { return };
    let hits = [0.5, 0.7, 0.9, 1.0];
    for name in ["message", "packet", "scalar"] {
        let w = Workload::by_name(name).unwrap();
        let pts = m.fig6_mva(&w, &[1, 2, 4], &hits).unwrap();
        assert_eq!(pts.len(), 12);
        for p in &pts {
            let scaled = Workload { z: w.z * p.cores as f64, ..w };
            let native = analytic::mva(&scaled, p.hit_rate, p.cores);
            let rel = (p.throughput - native.throughput).abs() / native.throughput;
            assert!(rel < 1e-3, "{name} h={} c={}: {rel}", p.hit_rate, p.cores);
            assert!((p.utilization - native.utilization).abs() < 1e-3);
        }
    }
}

#[test]
fn fig6_paper_shape_via_artifacts() {
    let Some((_rt, m)) = model() else { return };
    let w = Workload::message();
    let hits = QpnModel::default_hits();
    let pts = m.fig6_mva(&w, &[1, 2], &hits).unwrap();
    let n = hits.len();
    // Single core: fraction monotone in h, never reaches target, ends >85%.
    for i in 1..n {
        assert!(pts[i].target_fraction >= pts[i - 1].target_fraction - 1e-4);
    }
    assert!(pts[n - 1].target_fraction < 1.0 && pts[n - 1].target_fraction > 0.85);
    // Dual core: utilization >= single core at the same h; closer to target.
    for i in 0..n {
        assert!(pts[n + i].utilization >= pts[i].utilization - 1e-3);
    }
    assert!(pts[2 * n - 1].target_fraction > pts[n - 1].target_fraction);
}

#[test]
fn fig6_paper_shape_via_native_solver() {
    // The same shape assertions as the artifact test, against the native
    // MVA solver — this one always runs, keeping the Section 5 claims
    // regression-guarded in offline builds.
    let w = Workload::message();
    let hits = QpnModel::default_hits();
    let run = |cores: u32| -> Vec<analytic::MvaResult> {
        hits.iter()
            .map(|&h| {
                let scaled = Workload { z: w.z * cores as f64, ..w };
                analytic::mva(&scaled, h, cores)
            })
            .collect()
    };
    let single = run(1);
    let dual = run(2);
    let n = hits.len();
    for i in 1..n {
        assert!(single[i].target_fraction >= single[i - 1].target_fraction - 1e-4);
    }
    assert!(single[n - 1].target_fraction < 1.0 && single[n - 1].target_fraction > 0.85);
    for i in 0..n {
        assert!(dual[i].utilization >= single[i].utilization - 1e-3);
    }
    assert!(dual[n - 1].target_fraction > single[n - 1].target_fraction);
}

#[test]
fn sweep_artifact_tracks_mva_shape() {
    let Some((_rt, m)) = model() else { return };
    if !m.has_sweep() {
        eprintln!("sweep artifact missing; skipping");
        return;
    }
    let w = Workload::message();
    let hits = [0.5, 0.7, 0.9];
    let sweep = m.fig6_sweep(&w, &[2], &hits).unwrap();
    let mva = m.fig6_mva(&w, &[2], &hits).unwrap();
    for (s, a) in sweep.iter().zip(&mva) {
        assert!((s.utilization - a.utilization).abs() < 0.2, "h={}", s.hit_rate);
    }
    // Monotone throughput in h.
    assert!(sweep[2].throughput > sweep[0].throughput);
}

#[test]
fn theoretical_max_calibration_and_stop_criterion() {
    // ~630k msgs/s at the reference hit rate (paper Section 5).
    let w = Workload::message();
    let max = analytic::theoretical_max(&w, REFERENCE_HIT_RATE);
    assert!((500_000.0..800_000.0).contains(&max), "{max}");
    // The paper's own numbers: 7 us measured is within the budget, a
    // lock-dominated 100 us is not.
    assert!(stop_criterion(&w, REFERENCE_HIT_RATE, 7_000.0).stop);
    assert!(!stop_criterion(&w, REFERENCE_HIT_RATE, 100_000.0).stop);
    assert!(GAP_BUDGET > 1.0);
}

#[test]
fn artifact_execution_is_reentrant() {
    // Two executions of the same loaded executable must agree bit-for-bit
    // (PJRT buffers are not reused across calls).
    let Some((_rt, m)) = model() else { return };
    let w = Workload::scalar();
    let a = m.fig6_mva(&w, &[1], &[0.6, 0.8]).unwrap();
    let b = m.fig6_mva(&w, &[1], &[0.6, 0.8]).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.throughput, y.throughput);
        assert_eq!(x.utilization, y.utilization);
    }
}
