//! Integration: the Section 6 matrix reproduces the paper's *shape* —
//! who wins, by roughly what factor, where the contrasts lie. Reduced
//! transaction counts keep CI fast; the full counts run in `cargo bench`.

use mcapi::coordinator::experiment::{run_cell, run_cell_latency, Cell, Matrix, MULTI_CORES};
use mcapi::coordinator::MsgKind;
use mcapi::mcapi::types::BackendKind;
use mcapi::os::{AffinityMode, OsProfile};

const TX: u64 = 300;

fn cell(os: OsProfile, cores: usize, kind: MsgKind, backend: BackendKind) -> Cell {
    Cell { os, cores, kind, backend, affinity: AffinityMode::PinnedSpread }
}

#[test]
fn table2_shape_lockbased_penalty() {
    for os in [OsProfile::linux_rt(), OsProfile::windows()] {
        for kind in [MsgKind::Message, MsgKind::Scalar] {
            let single = run_cell(cell(os, 1, kind, BackendKind::Locked), TX);
            let multi = run_cell(cell(os, MULTI_CORES, kind, BackendKind::Locked), TX);
            let speedup = multi.report.throughput() / single.report.throughput();
            assert!(
                speedup < 0.9,
                "{}/{}: lock-based multicore must be slower (got {speedup:.2}x)",
                os.name,
                kind.label()
            );
        }
    }
}

#[test]
fn table2_linux_penalty_much_harsher_than_windows() {
    let penalty = |os: OsProfile| {
        let single = run_cell(cell(os, 1, MsgKind::Message, BackendKind::Locked), TX);
        let multi = run_cell(cell(os, MULTI_CORES, MsgKind::Message, BackendKind::Locked), TX);
        multi.report.throughput() / single.report.throughput()
    };
    let linux = penalty(OsProfile::linux_rt());
    let windows = penalty(OsProfile::windows());
    assert!(
        linux < 0.6 * windows,
        "paper: Linux penalty at least ~3x worse (linux {linux:.2}, windows {windows:.2})"
    );
}

#[test]
fn fig7_lockfree_beats_locked_everywhere() {
    for os in [OsProfile::linux_rt(), OsProfile::windows()] {
        for cores in [1usize, MULTI_CORES] {
            for kind in MsgKind::all() {
                let locked = run_cell(cell(os, cores, kind, BackendKind::Locked), TX);
                let lockfree = run_cell(cell(os, cores, kind, BackendKind::LockFree), TX);
                assert!(
                    lockfree.report.throughput() > locked.report.throughput(),
                    "{}/{}c/{}",
                    os.name,
                    cores,
                    kind.label()
                );
            }
        }
    }
}

#[test]
fn fig8_multicore_latency_speedup_dominates_single_core() {
    let speedup = |cores: usize| {
        let locked = run_cell_latency(
            cell(OsProfile::linux_rt(), cores, MsgKind::Message, BackendKind::Locked),
            200,
        );
        let lockfree = run_cell_latency(
            cell(OsProfile::linux_rt(), cores, MsgKind::Message, BackendKind::LockFree),
            200,
        );
        locked.mean() / lockfree.mean()
    };
    let single = speedup(1);
    let multi = speedup(MULTI_CORES);
    assert!(multi > 3.0 * single, "single {single:.1}x vs multi {multi:.1}x");
    assert!(multi > 8.0, "double-digit multicore payoff expected, got {multi:.1}x");
}

#[test]
fn lockfree_multicore_not_penalized() {
    // The paper: migration degrades lock-based and *increases* lock-free
    // performance. At minimum, lock-free must not collapse like the
    // lock-based path does.
    let single = run_cell(cell(OsProfile::linux_rt(), 1, MsgKind::Scalar, BackendKind::LockFree), TX);
    let multi = run_cell(
        cell(OsProfile::linux_rt(), MULTI_CORES, MsgKind::Scalar, BackendKind::LockFree),
        TX,
    );
    let speedup = multi.report.throughput() / single.report.throughput();
    assert!(speedup > 1.0, "lock-free scalar must speed up on multicore, got {speedup:.2}x");
}

#[test]
fn matrix_builders_cover_full_dimensions() {
    let m = Matrix::new(50);
    assert_eq!(m.table2().len(), 6); // 2 OS x 3 kinds
    assert_eq!(m.fig7().len(), 36); // 2 OS x 3 kinds x 2 backends x 3 configs
    assert_eq!(m.fig8().len(), 18); // 2 OS x 3 kinds x 3 configs
}
