//! MPMC endpoint-plane acceptance gate (required by CI).
//!
//! Sim-asserted properties of the multi-consumer work-distribution
//! plane: exactly-once delivery under N×M stress (no loss, no
//! duplicates, no torn frames, no leaked leases), kill-point sweeps
//! with either role as the victim (dead-consumer claims salvaged and
//! re-enqueued, dead-producer claims tombstoned), O(1) empty-poll cost
//! on the MPMC ring independent of capacity, the targeted doorbell
//! (wake-one with re-ring-on-miss: parked group consumers each claim a
//! frame, none sleeps through one), and fenced-member lane rebalance:
//! a declared-dead member's home lanes re-home onto survivors.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use mcapi::coordinator::{
    run_mpmc_chaos, run_mpmc_kill_sweep, run_mpmc_stress, MpmcOpts, Victim,
};
use mcapi::lockfree::{MpmcRing, World};
use mcapi::mcapi::types::{BackendKind, EndpointId, RuntimeCfg};
use mcapi::mcapi::McapiRuntime;
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::sim::{Machine, MachineCfg, SimWorld};

#[test]
fn nxm_sim_stress_delivers_exactly_once() {
    let opts = MpmcOpts { producers: 3, consumers: 3, messages: 16, ..Default::default() };
    let r = run_mpmc_stress(&opts);
    assert!(r.pass, "stress failed:\n{}", r.text);
    assert_eq!(r.delivered, 48, "every frame in-band, exactly once:\n{}", r.text);
}

#[test]
fn kill_consumer_at_every_op_inside_a_group_claim() {
    let opts = MpmcOpts { messages: 8, ..Default::default() };
    let r = run_mpmc_kill_sweep(Victim::Consumer, &opts);
    assert!(r.pass, "sweep failed:\n{}", r.text);
    // The bracketed claim must span a non-trivial window of priced ops —
    // a degenerate sweep would mean the probe bracketed nothing.
    let points = r.text.lines().filter(|l| l.trim_start().starts_with("kill@")).count();
    assert!(points >= 4, "suspiciously small sweep ({points} points):\n{}", r.text);
}

#[test]
fn kill_producer_at_every_op_inside_an_mpmc_send() {
    let opts = MpmcOpts { messages: 8, ..Default::default() };
    let r = run_mpmc_kill_sweep(Victim::Producer, &opts);
    assert!(r.pass, "sweep failed:\n{}", r.text);
    let points = r.text.lines().filter(|l| l.trim_start().starts_with("kill@")).count();
    assert!(points >= 4, "suspiciously small sweep ({points} points):\n{}", r.text);
}

#[test]
fn seeded_mpmc_chaos_passes_and_reproduces_byte_for_byte() {
    for seed in 1..=4u64 {
        let opts = MpmcOpts { seed, messages: 10, ..Default::default() };
        let a = run_mpmc_chaos(&opts);
        assert!(a.pass, "seed {seed}:\n{}", a.text);
        let b = run_mpmc_chaos(&opts);
        assert_eq!(a.text, b.text, "seed {seed} report must reproduce exactly");
    }
}

/// Priced simulator operations for 10 empty polls on a fresh ring of
/// `cap` slots.
fn empty_poll_ops(cap: usize) -> u64 {
    let m = Machine::new(MachineCfg::new(1, OsProfile::linux_rt(), AffinityMode::SingleCore));
    let ops = Arc::new(AtomicU64::new(0));
    let out = ops.clone();
    let h = m.spawn(move || {
        let ring: MpmcRing<SimWorld> = MpmcRing::new(cap, 16);
        let before = SimWorld::op_count();
        for _ in 0..10 {
            assert!(ring.recv_with(1, |_| ()).is_err(), "fresh ring must poll empty");
        }
        out.store(SimWorld::op_count() - before, Ordering::SeqCst);
    });
    m.run(vec![h]);
    ops.load(Ordering::SeqCst)
}

#[test]
fn mpmc_empty_poll_cost_is_constant_in_capacity() {
    let small = empty_poll_ops(2);
    let large = empty_poll_ops(512);
    assert_eq!(small, large, "empty poll must not scan the ring");
    // Two priced loads per poll: the shared head counter plus one
    // slot-sequence word — the consumer-side mirror of the SPSC plane's
    // O(1) empty-poll gate.
    assert_eq!(small, 20, "expected exactly 2 priced loads per empty poll");
}

#[test]
fn parked_group_consumers_wake_on_send_broadcast() {
    let m = Machine::new(MachineCfg::new(
        4,
        OsProfile::linux_rt(),
        AffinityMode::PinnedSpread,
    ));
    let cfg = RuntimeCfg {
        backend: BackendKind::LockFree,
        max_nodes: 4,
        nbb_capacity: 8,
        pool_buffers: 16,
        ..Default::default()
    };
    let rt = McapiRuntime::<SimWorld>::new(cfg);
    let dst = EndpointId::new(0, 1, 1);
    let ready = Arc::new(AtomicBool::new(false));
    let ep_slot = Arc::new(AtomicUsize::new(usize::MAX));
    let attached = Arc::new(AtomicU32::new(0));
    let got = Arc::new(Mutex::new(Vec::new()));

    let mut handles = Vec::new();
    // Two consumers: attach, then block in `wait_recv` until the
    // producer's targeted doorbell (`WaitCell::wake_one`) lands — the
    // woken member chains a wake to the next parked peer when backlog
    // remains, so both claim a frame without a thundering herd.
    for c in 0..2usize {
        let (rt, ready, ep_slot) = (rt.clone(), ready.clone(), ep_slot.clone());
        let (attached, got) = (attached.clone(), got.clone());
        handles.push(m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let ep = ep_slot.load(Ordering::SeqCst);
            rt.endpoint_attach_consumer(ep, 2 + c).unwrap();
            attached.fetch_add(1, Ordering::SeqCst);
            let h = rt.msg_recv_i(ep).unwrap();
            let mut buf = [0u8; 16];
            let n = rt.wait_recv(h, &mut buf, 50_000_000).unwrap();
            assert_eq!(n, 1);
            got.lock().unwrap().push(buf[0]);
        }));
    }
    // Producer: creates the endpoint, waits for both consumers to
    // attach, then sends two one-byte messages.
    {
        let (rt, ready, ep_slot, attached) =
            (rt.clone(), ready.clone(), ep_slot.clone(), attached.clone());
        handles.push(m.spawn(move || {
            let ep = rt.create_endpoint(dst, 1).unwrap();
            ep_slot.store(ep, Ordering::SeqCst);
            ready.store(true, Ordering::SeqCst);
            while attached.load(Ordering::SeqCst) < 2 {
                SimWorld::yield_now();
            }
            for b in [7u8, 9u8] {
                loop {
                    match rt.msg_send(0, dst, &[b], 0) {
                        Ok(()) => break,
                        Err(s) if s.is_would_block() => SimWorld::yield_now(),
                        Err(e) => panic!("send failed: {e:?}"),
                    }
                }
            }
        }));
    }
    m.run(handles);
    let mut seen = got.lock().unwrap().clone();
    seen.sort_unstable();
    assert_eq!(seen, vec![7, 9], "each parked consumer woke and claimed one message");
}

#[test]
fn fenced_member_lanes_rehome_and_survivor_drains() {
    // Two members attach; half the producer lanes are dealt to each.
    // Member B is then fenced (`declare_node_dead`, the watchdog's
    // confirm path) *before* anyone pops: its home lanes must re-home
    // onto the survivor, which drains the complete stream exactly once
    // — no frame is stranded on a lane homed to a corpse.
    const MSGS: u8 = 6;
    let m = Machine::new(MachineCfg::new(
        4,
        OsProfile::linux_rt(),
        AffinityMode::PinnedSpread,
    ));
    let cfg = RuntimeCfg {
        backend: BackendKind::LockFree,
        max_nodes: 4,
        nbb_capacity: 8,
        pool_buffers: 16,
        ..Default::default()
    };
    let rt = McapiRuntime::<SimWorld>::new(cfg);
    let dst = EndpointId::new(0, 1, 1);
    let ready = Arc::new(AtomicBool::new(false));
    let ep_slot = Arc::new(AtomicUsize::new(usize::MAX));
    let attached = Arc::new(AtomicU32::new(0));
    let fenced = Arc::new(AtomicBool::new(false));
    let got = Arc::new(Mutex::new(Vec::new()));

    let mut handles = Vec::new();
    // Survivor (node 2): attaches, then waits for the fence before
    // draining so every frame it claims crosses the rebalanced deal.
    {
        let (rt, ready, ep_slot) = (rt.clone(), ready.clone(), ep_slot.clone());
        let (attached, fenced, got) = (attached.clone(), fenced.clone(), got.clone());
        handles.push(m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let ep = ep_slot.load(Ordering::SeqCst);
            rt.endpoint_attach_consumer(ep, 2).unwrap();
            attached.fetch_add(1, Ordering::SeqCst);
            while !fenced.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let mut seen = Vec::new();
            while seen.len() < MSGS as usize {
                let h = rt.msg_recv_i(ep).unwrap();
                let mut buf = [0u8; 16];
                let n = rt.wait_recv(h, &mut buf, 50_000_000).unwrap();
                assert_eq!(n, 1);
                seen.push(buf[0]);
            }
            got.lock().unwrap().extend(seen);
        }));
    }
    // Doomed member (node 3): attaches so the deal splits the lanes,
    // never pops, and is fenced by the producer once the stream is in.
    {
        let (rt, ready, ep_slot, attached) =
            (rt.clone(), ready.clone(), ep_slot.clone(), attached.clone());
        handles.push(m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let ep = ep_slot.load(Ordering::SeqCst);
            rt.endpoint_attach_consumer(ep, 3).unwrap();
            attached.fetch_add(1, Ordering::SeqCst);
        }));
    }
    // Producer: sends the whole stream *as node 1* with both members
    // attached. The round-robin deal over the sorted member set {2, 3}
    // homes lane 1 to member 3 — the doomed one — so every frame lands
    // on a lane owned by the future corpse. The producer then declares
    // member 3 dead (recovery re-deals its lanes) and only after that
    // releases the survivor.
    {
        let (rt, ready, ep_slot) = (rt.clone(), ready.clone(), ep_slot.clone());
        let (attached, fenced) = (attached.clone(), fenced.clone());
        handles.push(m.spawn(move || {
            let ep = rt.create_endpoint(dst, 1).unwrap();
            ep_slot.store(ep, Ordering::SeqCst);
            ready.store(true, Ordering::SeqCst);
            while attached.load(Ordering::SeqCst) < 2 {
                SimWorld::yield_now();
            }
            for b in 0..MSGS {
                loop {
                    match rt.msg_send(1, dst, &[b], 0) {
                        Ok(()) => break,
                        Err(s) if s.is_would_block() => SimWorld::yield_now(),
                        Err(e) => panic!("send failed: {e:?}"),
                    }
                }
            }
            rt.declare_node_dead(3);
            fenced.store(true, Ordering::SeqCst);
        }));
    }
    m.run(handles);
    let mut seen = got.lock().unwrap().clone();
    seen.sort_unstable();
    assert_eq!(
        seen,
        (0..MSGS).collect::<Vec<_>>(),
        "survivor must drain the full stream exactly once after the re-deal"
    );
}
