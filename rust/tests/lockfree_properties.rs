//! Property-based integration tests over the lock-free toolbox and the
//! MCAPI runtime, using the in-tree property harness (`util::prop`).
//!
//! Each property runs dozens of randomized cases; failures print the seed
//! (replay with MCAPI_PROP_SEED=<seed>).

use mcapi::lockfree::{BitSet, FreeList, Nbb, Nbw, ReadStatus, RealWorld};
use mcapi::mcapi::types::{BackendKind, EndpointId, RuntimeCfg, Status};
use mcapi::mcapi::McapiRuntime;
use mcapi::util::prop::{check, check_res};
use mcapi::util::rng::XorShift;

#[test]
fn prop_nbb_is_a_fifo_queue() {
    check_res(
        "NBB behaves as a bounded FIFO under arbitrary op sequences",
        60,
        |rng: &mut XorShift| {
            let cap = rng.range(1, 16) as usize;
            let ops: Vec<bool> = (0..rng.range(1, 200)).map(|_| rng.chance(0.55)).collect();
            (cap, ops)
        },
        |(cap, ops)| {
            let q = Nbb::<u64, RealWorld>::new(*cap);
            let mut model = std::collections::VecDeque::new();
            let mut next = 0u64;
            for &push in ops {
                if push {
                    match q.insert(next) {
                        Ok(()) => {
                            model.push_back(next);
                            if model.len() > *cap {
                                return Err("exceeded capacity".into());
                            }
                        }
                        Err((_, v)) => {
                            if model.len() != *cap {
                                return Err(format!("spurious full at {}/{}", model.len(), cap));
                            }
                            if v != next {
                                return Err("lost item on failed insert".into());
                            }
                        }
                    }
                    next += 1;
                } else {
                    match q.read() {
                        ReadStatus::Ok(v) => {
                            let want = model.pop_front().ok_or("read from empty model")?;
                            if v != want {
                                return Err(format!("FIFO violated: got {v}, want {want}"));
                            }
                        }
                        ReadStatus::Empty => {
                            if !model.is_empty() {
                                return Err("spurious empty".into());
                            }
                        }
                        ReadStatus::EmptyButProducerInserting => {
                            return Err("peer-active status without a peer".into())
                        }
                    }
                }
                if q.len() != model.len() {
                    return Err(format!("len {} != model {}", q.len(), model.len()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_nbw_read_always_returns_last_write() {
    check(
        "NBW single-threaded read == last write",
        40,
        |rng: &mut XorShift| {
            let depth = rng.range(1, 8) as usize;
            let writes: Vec<u64> = (0..rng.range(1, 50)).map(|_| rng.next_u64()).collect();
            (depth, writes)
        },
        |(depth, writes)| {
            let n = Nbw::<u64, RealWorld>::new(*depth, 0);
            let mut last = None;
            for &w in writes {
                n.write(w);
                last = Some(w);
            }
            n.read().0 == last
        },
    );
}

#[test]
fn prop_bitset_alloc_free_bijective() {
    check_res(
        "bitset never double-allocates across random interleavings",
        50,
        |rng: &mut XorShift| {
            let bits = rng.range(1, 100) as usize;
            let steps: Vec<bool> = (0..rng.range(1, 300)).map(|_| rng.chance(0.6)).collect();
            (bits, steps)
        },
        |(bits, steps)| {
            let b = BitSet::<RealWorld>::new(*bits);
            let mut live = std::collections::BTreeSet::new();
            for &alloc in steps {
                if alloc {
                    match b.alloc() {
                        Some(i) => {
                            if !live.insert(i) {
                                return Err(format!("double alloc {i}"));
                            }
                            if i >= *bits {
                                return Err("out of range".into());
                            }
                        }
                        None => {
                            if live.len() != *bits {
                                return Err("spurious exhaustion".into());
                            }
                        }
                    }
                } else if let Some(&i) = live.iter().next() {
                    live.remove(&i);
                    if !b.free(i) {
                        return Err(format!("free({i}) found clear bit"));
                    }
                }
            }
            if b.count() != live.len() {
                return Err("count mismatch".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_freelist_conserves_indices() {
    check_res(
        "treiber free-list conserves the index set",
        40,
        |rng: &mut XorShift| {
            let cap = rng.range(1, 64) as usize;
            let steps: Vec<bool> = (0..rng.range(1, 200)).map(|_| rng.chance(0.5)).collect();
            (cap, steps)
        },
        |(cap, steps)| {
            let f = FreeList::<RealWorld>::new_full(*cap);
            let mut held = Vec::new();
            for &pop in steps {
                if pop {
                    if let Some(i) = f.pop() {
                        if held.contains(&i) {
                            return Err(format!("duplicate {i}"));
                        }
                        held.push(i);
                    } else if held.len() != *cap {
                        return Err("spurious exhaustion".into());
                    }
                } else if let Some(i) = held.pop() {
                    f.push(i);
                }
                if f.free_count() + held.len() != *cap {
                    return Err("index leak".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_mcapi_message_sequences_roundtrip() {
    check_res(
        "random message batches roundtrip on both backends",
        25,
        |rng: &mut XorShift| {
            let backend =
                if rng.chance(0.5) { BackendKind::Locked } else { BackendKind::LockFree };
            let batches: Vec<(u8, u8)> = (0..rng.range(1, 40))
                .map(|_| (rng.below(4) as u8, rng.range(1, 24) as u8))
                .collect();
            (backend, batches)
        },
        |(backend, batches)| {
            let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg::with_backend(*backend));
            let dst = EndpointId::new(0, 1, 1);
            let ep = rt.create_endpoint(dst, 1).map_err(|e| format!("{e:?}"))?;
            // Send batch (bounded by queue capacity), then drain and match.
            let mut sent: Vec<(u8, Vec<u8>)> = Vec::new();
            for (i, &(prio, len)) in batches.iter().enumerate() {
                let payload = vec![i as u8; len as usize];
                match rt.msg_send(0, dst, &payload, prio) {
                    Ok(()) => sent.push((prio % 4, payload)),
                    Err(s) if s.is_would_block() || s == Status::MemLimit => {}
                    Err(e) => return Err(format!("{e:?}")),
                }
            }
            // Drain: priority classes come out class-by-class ascending, and
            // FIFO within a class.
            let mut by_prio: Vec<Vec<Vec<u8>>> = vec![Vec::new(); 4];
            for (p, payload) in &sent {
                by_prio[*p as usize].push(payload.clone());
            }
            let expected: Vec<Vec<u8>> = by_prio.into_iter().flatten().collect();
            let mut got = Vec::new();
            let mut buf = [0u8; 64];
            loop {
                match rt.msg_recv(ep, &mut buf) {
                    Ok(n) => got.push(buf[..n].to_vec()),
                    Err(Status::WouldBlock) => break,
                    Err(e) => return Err(format!("recv {e:?}")),
                }
            }
            if got != expected {
                return Err(format!("drain mismatch: {} vs {} items", got.len(), expected.len()));
            }
            if rt.buffers_available() != rt.cfg().pool_buffers {
                return Err("buffer leak".into());
            }
            Ok(())
        },
    );
}

#[test]
fn mpsc_stress_over_occupancy_bitmap_queue() {
    // Many producers x several priority lanes through the occupancy-
    // bitmap LockFreeQueue under real thread nondeterminism: nothing is
    // lost (no lost-wakeup from the clear/re-check protocol), per-
    // (producer, priority) FIFO holds, and the drained queue is empty.
    use mcapi::mcapi::queue::{Entry, LockFreeQueue};
    use std::sync::Arc;

    const PRODUCERS: u32 = 4;
    const PER: u64 = 20_000;
    let q = Arc::new(LockFreeQueue::<RealWorld>::new(PRODUCERS as usize, 32));
    let handles: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..PER {
                    // Priority varies per message; scalar carries the
                    // per-(producer, priority) sequence number.
                    let prio = (i % 3) as u8;
                    let mut e = Entry::buffered(i as u32, 8, p, prio);
                    e.scalar = i / 3;
                    loop {
                        match q.push(e) {
                            Ok(()) => break,
                            Err((_, back)) => {
                                e = back;
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            })
        })
        .collect();
    let mut next = [[0u64; 4]; PRODUCERS as usize];
    let mut got = 0u64;
    while got < PRODUCERS as u64 * PER {
        match q.pop() {
            Ok(e) => {
                let lane = e.from_node as usize;
                let prio = e.priority as usize;
                assert_eq!(
                    e.scalar, next[lane][prio],
                    "per-(producer {lane}, priority {prio}) FIFO violated"
                );
                next[lane][prio] += 1;
                got += 1;
            }
            Err(_) => std::thread::yield_now(),
        }
    }
    for h in handles {
        h.join().unwrap();
    }
    assert_eq!(q.len(), 0);
    assert!(q.pop().is_err(), "drained queue must report would-block");
}

#[test]
fn spsc_batch_torn_write_and_fifo_property() {
    // Batched NBB transfer under concurrent single-producer/single-
    // consumer threads: payloads arrive whole (no torn writes across the
    // amortized enter/exit window), exactly once, in order — for a
    // spread of ring capacities and batch sizes.
    use std::sync::Arc;

    let mut rng = XorShift::new(0xBA7C4);
    for _case in 0..6 {
        let cap = rng.range(1, 32) as usize;
        let wbatch = rng.range(1, 24) as usize;
        let rbatch = rng.range(1, 24) as usize;
        const N: u64 = 30_000;
        let q = Arc::new(Nbb::<[u64; 4], RealWorld>::new(cap));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut next = 1u64;
                while next <= N {
                    let hi = (next + wbatch as u64 - 1).min(N);
                    let mut items: Vec<[u64; 4]> = (next..=hi)
                        .map(|i| [i, i.wrapping_mul(3), !i, i ^ 0xABCD])
                        .collect();
                    while !items.is_empty() {
                        if q.insert_batch(&mut items).is_err() {
                            std::hint::spin_loop();
                        }
                    }
                    next = hi + 1;
                }
            })
        };
        let mut expected = 1u64;
        let mut out = Vec::with_capacity(rbatch);
        while expected <= N {
            out.clear();
            if q.read_batch(&mut out, rbatch).is_ok() {
                for [a, b, c, d] in &out {
                    assert_eq!(*a, expected, "batch FIFO violated (cap {cap})");
                    assert_eq!(*b, a.wrapping_mul(3), "torn batch write");
                    assert_eq!(*c, !*a, "torn batch write");
                    assert_eq!(*d, *a ^ 0xABCD, "torn batch write");
                    expected += 1;
                }
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
        assert!(q.is_empty(), "cap {cap}: residue after full drain");
    }
}

#[test]
fn prop_batch_msg_roundtrip_matches_scalar_semantics() {
    // Random payload batches through msg_send_batch/msg_recv_batch on
    // both backends must drain exactly like the scalar API: priority
    // classes ascending, FIFO within a class, no buffer leaks.
    check_res(
        "batched message API preserves drain order and leases",
        15,
        |rng: &mut XorShift| {
            let backend =
                if rng.chance(0.5) { BackendKind::Locked } else { BackendKind::LockFree };
            let batches: Vec<(u8, u8)> = (0..rng.range(1, 30))
                .map(|_| (rng.below(4) as u8, rng.range(1, 24) as u8))
                .collect();
            let recv_batch = rng.range(1, 9) as usize;
            (backend, batches, recv_batch)
        },
        |(backend, batches, recv_batch)| {
            let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg::with_backend(*backend));
            let dst = EndpointId::new(0, 1, 1);
            let ep = rt.create_endpoint(dst, 1).map_err(|e| format!("{e:?}"))?;
            let mut sent: Vec<(u8, Vec<u8>)> = Vec::new();
            // Send per-priority groups through the batch API.
            for prio in 0u8..4 {
                let payloads: Vec<Vec<u8>> = batches
                    .iter()
                    .enumerate()
                    .filter(|(_, (p, _))| *p == prio)
                    .map(|(i, (_, len))| vec![i as u8; *len as usize])
                    .collect();
                if payloads.is_empty() {
                    continue;
                }
                let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                match rt.msg_send_batch(1, dst, &refs, prio) {
                    Ok(n) => sent.extend(
                        payloads.into_iter().take(n).map(|p| (prio, p)),
                    ),
                    Err(s) if s.is_would_block() || s == Status::MemLimit => {}
                    Err(e) => return Err(format!("{e:?}")),
                }
            }
            let mut by_prio: Vec<Vec<Vec<u8>>> = vec![Vec::new(); 4];
            for (p, payload) in &sent {
                by_prio[*p as usize].push(payload.clone());
            }
            let expected: Vec<Vec<u8>> = by_prio.into_iter().flatten().collect();
            let mut got = Vec::new();
            loop {
                match rt.msg_recv_batch(ep, &mut got, *recv_batch) {
                    Ok(_) => {}
                    Err(Status::WouldBlock) => break,
                    Err(e) => return Err(format!("recv {e:?}")),
                }
            }
            if got != expected {
                return Err(format!(
                    "drain mismatch: {} vs {} items",
                    got.len(),
                    expected.len()
                ));
            }
            if rt.buffers_available() != rt.cfg().pool_buffers {
                return Err("buffer leak".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_sim_stress_deterministic_for_any_small_topology() {
    use mcapi::coordinator::{run_stress_sim, ChannelSpec, MsgKind, StressOpts, Topology};
    use mcapi::os::{AffinityMode, OsProfile};
    use mcapi::sim::{Machine, MachineCfg};
    check_res(
        "random small topologies run deterministically on the simulator",
        8,
        |rng: &mut XorShift| {
            let kinds = [MsgKind::Message, MsgKind::Packet, MsgKind::Scalar];
            let n = rng.range(1, 3) as u16;
            let channels: Vec<ChannelSpec> = (0..n)
                .map(|i| ChannelSpec {
                    // Distinct ports per role: a chain node both sends and
                    // receives, and endpoints are unique by (node, port).
                    from: (i, 100 + i),
                    to: (i + 1, 1 + i),
                    kind: kinds[rng.below(3) as usize],
                    count: rng.range(20, 60),
                })
                .collect();
            let cores = rng.range(1, 4) as usize;
            (Topology { channels }, cores)
        },
        |(topo, cores)| {
            let run = || {
                let m = Machine::new(MachineCfg::new(
                    *cores,
                    OsProfile::linux_rt(),
                    AffinityMode::PinnedSpread,
                ));
                run_stress_sim(&m, RuntimeCfg::default(), topo, StressOpts::default())
            };
            let a = run();
            let b = run();
            if a.elapsed_ns != b.elapsed_ns {
                return Err(format!("nondeterministic: {} vs {}", a.elapsed_ns, b.elapsed_ns));
            }
            if a.delivered != topo.total_transactions() {
                return Err("lost messages".into());
            }
            if a.order_violations != 0 {
                return Err("order violations".into());
            }
            Ok(())
        },
    );
}
