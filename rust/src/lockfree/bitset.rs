//! Lock-free bit-set allocator (refactoring step 3).
//!
//! The paper first converted the request double-linked list to a lock-free
//! doubly linked list [25], then replaced it with a lock-free **bit set**
//! because lock-free doubly linked lists are not feasible in practice
//! [26]. A set bit means "slot in use"; allocation scans for a clear bit
//! and claims it with CAS; free clears it with fetch-AND. The `benches/
//! micro_lockfree` ablation compares this against a mutex-guarded free
//! list to show why the paper switched.

use super::mem::{Atom64, CachePadded, World};

/// Fixed-capacity lock-free bit set.
///
/// Besides the alloc/free protocol the paper's request pool needs, the
/// set doubles as a concurrent *flag board* (set/clear/snapshot) — the
/// occupancy bitmap behind `mcapi::queue::LockFreeQueue` uses one
/// instance per priority so an empty-queue poll costs one word load
/// instead of a scan over every producer lane.
pub struct BitSet<W: World> {
    /// Each word padded to its own line: adjacent words are hammered by
    /// unrelated allocator/producer cores, and false sharing between them
    /// would serialize otherwise-independent CAS loops.
    words: Box<[CachePadded<W::U64>]>,
    bits: usize,
}

impl<W: World> BitSet<W> {
    /// Set with `bits` slots, all clear.
    pub fn new(bits: usize) -> Self {
        assert!(bits >= 1);
        let words = (bits + 63) / 64;
        BitSet {
            words: (0..words).map(|_| CachePadded::new(W::U64::new(0))).collect(),
            bits,
        }
    }

    /// Capacity in bits.
    pub fn capacity(&self) -> usize {
        self.bits
    }

    /// Number of backing words (snapshot iteration bound).
    pub fn num_words(&self) -> usize {
        self.words.len()
    }

    /// Claim the lowest clear bit; `None` when all are set.
    pub fn alloc(&self) -> Option<usize> {
        for (wi, word) in self.words.iter().enumerate() {
            let mut cur = word.load();
            loop {
                let usable = self.usable_mask(wi);
                if cur & usable == usable {
                    break; // word exhausted, try next
                }
                let bit = (!cur & usable).trailing_zeros() as u64;
                match word.cas(cur, cur | (1 << bit)) {
                    Ok(_) => return Some(wi * 64 + bit as usize),
                    Err(actual) => cur = actual, // raced; rescan this word
                }
            }
        }
        None
    }

    /// Release a previously-claimed bit. Returns whether it was set.
    pub fn free(&self, idx: usize) -> bool {
        assert!(idx < self.bits, "bit {idx} out of range {}", self.bits);
        let prev = self.words[idx / 64].fetch_and(!(1u64 << (idx % 64)));
        prev & (1u64 << (idx % 64)) != 0
    }

    /// Set a specific bit (flag-board use: not an allocation — any caller
    /// may set any bit). Returns whether it was already set.
    pub fn set(&self, idx: usize) -> bool {
        assert!(idx < self.bits, "bit {idx} out of range {}", self.bits);
        let prev = self.words[idx / 64].fetch_or(1u64 << (idx % 64));
        prev & (1u64 << (idx % 64)) != 0
    }

    /// Test a bit.
    pub fn is_set(&self, idx: usize) -> bool {
        assert!(idx < self.bits);
        self.words[idx / 64].load() & (1u64 << (idx % 64)) != 0
    }

    /// Snapshot one backing word (bits `wi*64 ..`). Relaxed: flag-board
    /// consumers re-synchronize through the flagged structure's own
    /// acquire loads before trusting any bit.
    pub fn snapshot_word(&self, wi: usize) -> u64 {
        self.words[wi].load_relaxed() & self.usable_mask(wi)
    }

    /// Number of set bits (approximate under concurrency).
    pub fn count(&self) -> usize {
        self.words.iter().map(|w| w.load_relaxed().count_ones() as usize).sum()
    }

    /// Bits of word `wi` that map to valid slots (last word may be partial).
    fn usable_mask(&self, wi: usize) -> u64 {
        let remaining = self.bits - wi * 64;
        if remaining >= 64 {
            u64::MAX
        } else {
            (1u64 << remaining) - 1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::collections::HashSet;
    use std::sync::Arc;

    type RBitSet = BitSet<RealWorld>;

    #[test]
    fn alloc_until_exhausted() {
        let b = RBitSet::new(10);
        let got: Vec<_> = (0..10).map(|_| b.alloc().unwrap()).collect();
        let unique: HashSet<_> = got.iter().collect();
        assert_eq!(unique.len(), 10);
        assert_eq!(b.alloc(), None);
        assert_eq!(b.count(), 10);
    }

    #[test]
    fn free_makes_slot_reusable() {
        let b = RBitSet::new(3);
        let a = b.alloc().unwrap();
        let _ = b.alloc().unwrap();
        assert!(b.free(a));
        assert_eq!(b.alloc(), Some(a), "lowest bit is reused first");
    }

    #[test]
    fn double_free_reports_false() {
        let b = RBitSet::new(4);
        let a = b.alloc().unwrap();
        assert!(b.free(a));
        assert!(!b.free(a));
    }

    #[test]
    fn more_than_one_word() {
        let b = RBitSet::new(130);
        let mut got = HashSet::new();
        for _ in 0..130 {
            assert!(got.insert(b.alloc().unwrap()));
        }
        assert_eq!(b.alloc(), None);
        assert!(b.is_set(129));
        b.free(64);
        assert_eq!(b.alloc(), Some(64));
    }

    #[test]
    fn partial_last_word_bounds_allocation() {
        let b = RBitSet::new(65);
        for _ in 0..65 {
            assert!(b.alloc().is_some());
        }
        assert_eq!(b.alloc(), None);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn free_out_of_range_panics() {
        RBitSet::new(8).free(8);
    }

    #[test]
    fn flag_board_set_clear_snapshot() {
        let b = RBitSet::new(70); // spans two words
        assert_eq!(b.num_words(), 2);
        assert!(!b.set(3));
        assert!(b.set(3), "second set reports already-set");
        assert!(!b.set(69));
        assert_eq!(b.snapshot_word(0), 1 << 3);
        assert_eq!(b.snapshot_word(1), 1 << 5);
        assert!(b.free(3));
        assert_eq!(b.snapshot_word(0), 0);
        // Snapshot masks bits beyond capacity in the last word.
        assert_eq!(b.snapshot_word(1) & !((1u64 << 6) - 1), 0);
    }

    #[test]
    fn concurrent_alloc_no_duplicates() {
        const SLOTS: usize = 256;
        let b = Arc::new(RBitSet::new(SLOTS));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                let mut mine = Vec::new();
                while let Some(i) = b.alloc() {
                    mine.push(i);
                }
                mine
            }));
        }
        let mut all = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), SLOTS, "every slot allocated exactly once");
        let unique: HashSet<_> = all.iter().collect();
        assert_eq!(unique.len(), SLOTS);
    }

    #[test]
    fn concurrent_alloc_free_churn() {
        let b = Arc::new(RBitSet::new(32));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let b = b.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..10_000 {
                    if let Some(i) = b.alloc() {
                        assert!(b.is_set(i));
                        assert!(b.free(i));
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(b.count(), 0);
    }

    #[test]
    fn property_alloc_free_interleavings() {
        crate::util::prop::check_res(
            "bitset alloc/free interleavings keep count consistent",
            50,
            |rng| {
                let ops: Vec<bool> = (0..rng.range(1, 64)).map(|_| rng.chance(0.6)).collect();
                ops
            },
            |ops| {
                let b = RBitSet::new(16);
                let mut live: Vec<usize> = Vec::new();
                for &is_alloc in ops {
                    if is_alloc {
                        if let Some(i) = b.alloc() {
                            if live.contains(&i) {
                                return Err(format!("slot {i} double-allocated"));
                            }
                            live.push(i);
                        } else if live.len() != 16 {
                            return Err("spurious exhaustion".into());
                        }
                    } else if let Some(i) = live.pop() {
                        if !b.free(i) {
                            return Err(format!("free({i}) saw clear bit"));
                        }
                    }
                    if b.count() != live.len() {
                        return Err(format!("count {} != live {}", b.count(), live.len()));
                    }
                }
                Ok(())
            },
        );
    }
}
