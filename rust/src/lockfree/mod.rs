//! The paper's lock-free algorithm toolbox (Section 3).
//!
//! * [`nbw`] — Kopetz's Non-Blocking Write protocol for **state messages**
//!   (single atomic version counter over a buffer array; readers detect
//!   and retry collisions — optimistic concurrency).
//! * [`nbb`] — Kim's Non-Blocking Buffer for **event messages** (ring FIFO
//!   with writer/reader counters; the paper's Table 1 status semantics).
//! * [`bitset`] — the lock-free bit-set request allocator that replaced
//!   the infeasible lock-free doubly linked list (refactoring step 3).
//! * [`freelist`] — tagged-index Treiber stack for buffer pools (ABA-safe
//!   without hazard pointers because entries are indices, not pointers).
//! * [`fsm`] — CAS-verified finite state machines replacing boolean status
//!   flags (Figures 3 and 4).
//! * [`backoff`] — the bounded immediate-retry / yield policy Table 1
//!   prescribes for `*_BUT_*` statuses.
//!
//! Everything is generic over [`mem::World`] so identical code runs on
//! real hardware ([`mem::RealWorld`]) and on the deterministic SMP
//! simulator ([`crate::sim::SimWorld`]).

pub mod backoff;
pub mod bitset;
pub mod freelist;
pub mod fsm;
pub mod mem;
pub mod nbb;
pub mod nbw;

pub use backoff::Backoff;
pub use bitset::BitSet;
pub use freelist::FreeList;
pub use fsm::AtomicFsm;
pub use mem::{Atom32, Atom64, KernelLock, RealWorld, World};
pub use nbb::{InsertStatus, Nbb, ReadStatus};
pub use nbw::Nbw;
