//! The paper's lock-free algorithm toolbox (Section 3).
//!
//! * [`nbw`] — Kopetz's Non-Blocking Write protocol for **state messages**
//!   (single atomic version counter over a buffer array; readers detect
//!   and retry collisions — optimistic concurrency).
//! * [`nbb`] — Kim's Non-Blocking Buffer for **event messages** (ring FIFO
//!   with writer/reader counters; the paper's Table 1 status semantics).
//! * [`ring`] — the connected-channel SPSC ring: the NBB counter protocol
//!   with the payload carried **in the slots** (packet bytes / scalars
//!   written directly, no shared pool lease) plus batch submission and
//!   in-place zero-copy consumption — the fast path behind
//!   `mcapi::channel`.
//! * [`mpmc`] — the slot-sequence MPMC ring for multi-receiver endpoint
//!   profiles: per-slot [`mem::CachePadded`] sequence words arbitrate
//!   Vyukov-style claim/publish between N producers and M consumers,
//!   one shared-counter CAS per claim (amortized over a whole batch by
//!   [`mpmc::MpmcRing::send_batch`]), with claimant-board crash repair
//!   (`repair_dead`: tombstone dead-producer claims, salvage
//!   dead-consumer claims). Retained as the shared-counter baseline the
//!   `mpmc_steal_vs_shared` benchmark row measures against; the SPSC
//!   paths above stay untouched for 1:1 channels.
//! * [`lanes`] — the contention-adaptive MPMC plane that now backs
//!   `mcapi::queue::ConsumerGroup`: per-producer SPSC lanes (the same
//!   cached-peer-counter NBB protocol as [`ring`]) + home-lane consumer
//!   assignment + lock-free batch work-stealing. Steady-state draining
//!   performs **zero shared-counter RMWs** (sim-asserted); the shared
//!   steal cursor is touched only when a member's home lanes run dry.
//! * [`bitset`] — the lock-free bit-set request allocator that replaced
//!   the infeasible lock-free doubly linked list (refactoring step 3),
//!   doubling as the occupancy flag board for `mcapi::queue`.
//! * [`freelist`] — tagged-index Treiber stack for buffer pools (ABA-safe
//!   without hazard pointers because entries are indices, not pointers).
//! * [`fsm`] — CAS-verified finite state machines replacing boolean status
//!   flags (Figures 3 and 4).
//! * [`backoff`] — the bounded immediate-retry / yield policy Table 1
//!   prescribes for `*_BUT_*` statuses.
//!
//! Everything is generic over [`mem::World`] so identical code runs on
//! real hardware ([`mem::RealWorld`]) and on the deterministic SMP
//! simulator ([`crate::sim::SimWorld`]).
//!
//! # Coherence-optimization design notes
//!
//! Being lock-free is necessary but not sufficient for the paper's
//! "multicore migration gains" result: a lock-free structure whose hot
//! words share cache lines, or which re-loads its peer's counter on
//! every operation, still serializes on cache-line ownership transfer
//! (Virtual-Link, arXiv:2012.05181; Cederman et al., arXiv:1302.2757).
//! Three mechanisms in [`mem`] and [`nbb`] remove that traffic:
//!
//! 1. **[`mem::CachePadded`]** — every producer/consumer-split atomic
//!    pair lives on separate 64-byte lines (`Nbb` counters, `Nbw`
//!    version, `FreeList` head, each `BitSet` word, the MRAPI rwlock
//!    state words). False sharing between logically independent words is
//!    pure waste; padding is free at these object counts.
//! 2. **Cached peer counters** ([`nbb`]) — the producer re-loads the
//!    consumer's `ack` only when its private snapshot says *full*, the
//!    consumer re-loads `update` only when its snapshot says *empty*.
//!    Snapshots are conservative (counters only grow), so the safety
//!    argument is unchanged; the steady-state SPSC path performs one
//!    cross-core load per ring wrap instead of one (or two) per message.
//!    `Atom32::load_relaxed`/`Atom64::load_relaxed` support the
//!    monitoring/flag reads this enables; simulated worlds price them
//!    like any load (coherence cost is ordering-independent).
//! 3. **Batched exchange** ([`nbb::Nbb::insert_batch`] /
//!    [`nbb::Nbb::read_batch`]) — one enter/exit counter-store pair
//!    amortized over N items, preserving the Table 1 `*_BUT_*` statuses
//!    via [`nbb::BatchStatus`]. The MCAPI runtime surfaces this as
//!    `msg_send_batch`/`msg_recv_batch`.
//!
//! `benches/micro_lockfree` measures each mechanism against an
//! unpadded/uncached baseline and feeds `scripts/bench_snapshot.sh`
//! (`BENCH_micro.json`) so regressions are visible per-PR.
//!
//! # Failure modes and recovery
//!
//! Lock-free reads/writes never block, but a task that **dies
//! mid-operation** can leave a structure in a transient state (an odd
//! NBB counter, a leased-but-unqueued pool buffer). The runtime detects
//! the death (liveness epoch goes odd via
//! `McapiRuntime::declare_node_dead`), repairs the structure, and
//! surfaces the condition to blocked peers. The chaos harness
//! (`coordinator::chaos`) kills tasks at every priced-op index inside
//! these windows and asserts the recovery below:
//!
//! | fault point | transient state | detection | recovery | peer sees |
//! |---|---|---|---|---|
//! | producer dies inside [`ring`]/[`nbb`] insert (`update` odd) | torn slot, never committed | watchdog + liveness epoch | `repair_dead_producer`: roll `update` back to even — the torn insert is discarded; occupancy (floor `update/2 − ack/2`) never counted it | committed messages drain, then `EndpointDead` |
//! | consumer dies inside read (`ack` odd) | committed message half-consumed | same | `repair_dead_consumer`: roll `ack` back — the message is re-exposed and salvageable | sender unblocks (ring slot freed) or `EndpointDead` |
//! | consumer dies **after** ack, before returning payload to caller | message consumed by a corpse | sequence audit | none possible below the API: at most **one** message per kill is "delivered to the dead"; chaos asserts the gap is exactly that boundary case | ≤ 1 gap, only on consumer kill |
//! | task dies holding a [`freelist`] lease (buffer not yet queued / not yet released) | pool buffer leaked | custody shadow (`buffer_holder`) | dead holder's leases force-released back to the `FreeList`; `leases_reclaimed` counter | `buffers_available()` returns to pool size |
//! | task dies between retry attempts ([`backoff`]) | none — no shared state mid-flight | — | nothing to repair; peers' `*_BUT_*` statuses decay to plain would-block | spin → yield → park, woken by poison |
//! | peer stalls (alive but descheduled) | `*PeerActive` status persists | bounded immediate retries ([`Backoff`]) | escalate spin → `yield_now` → futex park with deadline | `Timeout` after its deadline, never a hang |
//! | producer dies inside an [`mpmc`] claim (slot seq parked at `p`) | claimed-unpublished slot wedges every later position | claimant board (`writers[idx] == who+1`, stamped kill-atomically with the claim CAS) | `MpmcRing::repair_dead`: publish a [`mpmc::TOMBSTONE`] length word — consumers consume and skip it, freeing the slot | consumers resume past the wedge; no payload existed to lose |
//! | consumer dies inside an [`mpmc`] claim (slot seq parked at `p+1`) | claimed-unconsumed payload wedges the slot's next lap | claimant board (`readers[idx]`) | `repair_dead` salvages the payload to the runtime (re-enqueued — the dead claim never completed, so exactly-once holds) and frees the slot | payload redelivered to a live consumer |
//! | home member dies inside a [`lanes`] pop (`ack` odd, `home_busy` parked) | half-consumed payload; thieves/rebalancers spin-bounded on the flag | watchdog + liveness epoch | `ShardedRing::repair_dead`: roll `ack` back (payload re-exposed), clear the flag, unassign the lane; caller rebalances | payload redelivered to the lane's next home |
//! | thief dies mid-steal (claim word wedged at `member+1`) | stage **uncommitted** (`ack` never advanced) or **committed** (stash holds the only copies) | claimant board (`thief` word) + stash `committed` mark, stamped kill-atomically around the single `ack` advance | uncommitted → discard the stage (payloads still in the lane); committed → re-enqueue every undelivered stash entry onto the **dead node's own lane** (its producer is the corpse, so repair is that lane's sole writer — a live producer's lane is never written); either way clear the claim word | lane unwedges; exactly-once holds (≤1 boundary delivery per kill, same budget as [`mpmc`]) |
//! | OS thread **abandons** its node (parks forever; no kill event) | silence — structures consistent but the stream wedges | heartbeat watchdog: per-node progress epochs scanned against a silence deadline with suspect→confirm hysteresis (`McapiRuntime::watchdog_scan_once`) | automatic `declare_node_dead` runs the full repair pipeline above; the node's liveness epoch goes odd, **fencing** every later send/claim from the zombie (`NodeFenced`, fail-fast, no ring state touched) | blocked peers unblock via poison; a woken zombie gets `NodeFenced` instead of corrupting the repaired stream |
//! | fenced node restarts (`McapiRuntime::rejoin`) | stale epoch | epoch parity | epoch bumps to the next even value; heartbeat lane resets so the watchdog re-baselines instead of instantly re-confirming | fresh endpoints/channels work; the old generation stays fenced |
//!
//! The repairs are sound because each NBB/ring counter has a **single
//! owner** (SPSC lanes) and occupancy uses floor division: an odd
//! counter computes the same occupancy as the even value it is rolled
//! back to, so concurrent peers never observed the transient state as
//! committed.

pub mod backoff;
pub mod bitset;
pub mod freelist;
pub mod fsm;
pub mod lanes;
pub mod mem;
pub mod mpmc;
pub mod nbb;
pub mod nbw;
pub mod ring;

pub use backoff::Backoff;
pub use bitset::BitSet;
pub use freelist::FreeList;
pub use fsm::AtomicFsm;
pub use lanes::{LaneRepair, ShardRecvError, ShardSendError, ShardedRing, STEAL_BATCH};
pub use mem::{Atom32, Atom64, CachePadded, KernelLock, RealWorld, World};
pub use mpmc::{MpmcError, MpmcRing};
pub use nbb::{BatchStatus, InsertStatus, Nbb, ReadStatus};
pub use nbw::Nbw;
pub use ring::{ChannelRing, RecvError, ScalarBatchError};
