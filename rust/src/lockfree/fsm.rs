//! Atomic finite state machines (Figures 3 and 4).
//!
//! The paper replaced the reference implementation's boolean status flags
//! (valid/completed/cancelled) with explicit state transition diagrams
//! verified by compare-and-swap: "verify with atomic compare-and-swap that
//! an object is in the expected state before changing to the next state".
//! This type is that mechanism; `mcapi::request` and `mcapi::queue` define
//! the concrete diagrams.

use super::mem::{Atom32, World};

/// A CAS-verified state cell. States are small u32 constants defined by
/// the embedding object together with a transition-legality function.
pub struct AtomicFsm<W: World> {
    state: W::U32,
}

impl<W: World> AtomicFsm<W> {
    /// Start in `initial`.
    pub fn new(initial: u32) -> Self {
        AtomicFsm { state: W::U32::new(initial) }
    }

    /// Current state (racy snapshot).
    pub fn state(&self) -> u32 {
        self.state.load()
    }

    /// Attempt `from -> to`. Fails with the actual observed state if the
    /// object was not in `from` — the caller's cue that another task won.
    pub fn transition(&self, from: u32, to: u32) -> Result<(), u32> {
        self.state.cas(from, to).map(|_| ()).map_err(|actual| actual)
    }

    /// Transition that must succeed (invariant violation otherwise) —
    /// used where the protocol guarantees exclusive ownership.
    pub fn transition_exact(&self, from: u32, to: u32) {
        if let Err(actual) = self.transition(from, to) {
            panic!("FSM invariant: expected state {from}, found {actual} (target {to})");
        }
    }

    /// Spin until the object reaches `target` (bounded by `max_spins`;
    /// returns false on budget exhaustion).
    pub fn await_state(&self, target: u32, max_spins: u64) -> bool {
        for _ in 0..max_spins {
            if self.state() == target {
                return true;
            }
            W::spin_hint();
        }
        self.state() == target
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    type RFsm = AtomicFsm<RealWorld>;

    const FREE: u32 = 0;
    const VALID: u32 = 1;
    const COMPLETED: u32 = 2;

    #[test]
    fn legal_transition_chain() {
        let f = RFsm::new(FREE);
        assert!(f.transition(FREE, VALID).is_ok());
        assert!(f.transition(VALID, COMPLETED).is_ok());
        assert_eq!(f.state(), COMPLETED);
    }

    #[test]
    fn wrong_from_state_reports_actual() {
        let f = RFsm::new(FREE);
        assert_eq!(f.transition(VALID, COMPLETED), Err(FREE));
        assert_eq!(f.state(), FREE);
    }

    #[test]
    #[should_panic(expected = "FSM invariant")]
    fn transition_exact_panics_on_violation() {
        RFsm::new(FREE).transition_exact(VALID, COMPLETED);
    }

    #[test]
    fn exactly_one_winner_under_contention() {
        let f = Arc::new(RFsm::new(FREE));
        let mut handles = Vec::new();
        for _ in 0..8 {
            let f = f.clone();
            handles.push(std::thread::spawn(move || {
                f.transition(FREE, VALID).is_ok() as u32
            }));
        }
        let winners: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(winners, 1, "CAS admits exactly one allocator");
    }

    #[test]
    fn await_state_observes_change() {
        let f = Arc::new(RFsm::new(FREE));
        let f2 = f.clone();
        let h = std::thread::spawn(move || {
            f2.transition_exact(FREE, VALID);
        });
        assert!(f.await_state(VALID, u64::MAX >> 1));
        h.join().unwrap();
    }

    #[test]
    fn await_state_budget_exhaustion() {
        let f = RFsm::new(FREE);
        assert!(!f.await_state(VALID, 10));
    }
}
