//! Lane-sharded MPMC ring with work-stealing consumers: the
//! contention-adaptive endpoint plane.
//!
//! The slot-sequence ring ([`super::mpmc`]) arbitrates every claim on
//! one shared head and one shared tail: with N producers and M
//! consumers each message costs at least two contended CASes on the
//! hottest words in the system, so the `mpmc_scaling_*` curve pays
//! O(contenders) coherence traffic — exactly the shared-counter
//! contention the paper blames for poor multicore messaging scaling.
//! Virtual-Link (arXiv:2012.05181) shows MPMC throughput scales when
//! the shared queue is decomposed into point-to-point links with
//! consumer-side selection; Cederman et al. (arXiv:1302.2757) catalog
//! work-stealing as the lock-free answer to the load imbalance that
//! decomposition creates. [`ShardedRing`] composes both:
//!
//! * **Per-producer SPSC lanes** — one NBB-protocol ring per producer
//!   slot (the [`super::ring`] counter protocol: `update`/`ack` odd/even
//!   windows, cache-padded lines, producer-cached peer counter). The
//!   producer side is byte-for-byte the SPSC fast path: stores only,
//!   one cross-core `ack` load per ring wrap.
//! * **Home-lane assignment** — every lane has at most one *home*
//!   consumer (a group member). A member drains its home lanes with
//!   **zero shared-counter RMW operations**: plain loads and stores
//!   only (sim-asserted via [`crate::sim::SimWorld::rmw_count`]). Home
//!   exclusivity against thieves and rebalancing uses a store/load
//!   Dekker on two per-lane words (`home_busy`, `thief`), not a CAS.
//! * **Lock-free work-stealing** — when a member's home lanes run dry
//!   it becomes a thief: it bumps the shared steal cursor (its only
//!   shared-counter RMW, paid exclusively on the dry path), picks the
//!   most-backlogged lane by unpriced occupancy peeks, claims the
//!   lane's `thief` word with a CAS, waits out the home's in-flight
//!   pop, and moves up to [`STEAL_BATCH`] payloads in one `ack`
//!   advance — batch amortization bounds how often a starving consumer
//!   touches shared words. Dry polls with nothing anywhere to steal
//!   skip even the cursor bump: an idle member's empty poll is an
//!   allocation-free sweep of unpriced peeks.
//!
//! # Crash consistency
//!
//! The claimant-board discipline from [`super::mpmc`] carries over:
//! every transient state is attributable to exactly one dense node
//! slot, and [`ShardedRing::repair_dead`] rolls it back or completes
//! it.
//!
//! * A producer dies mid-insert → its lane's `update` is odd → roll
//!   back (the torn insert was never committed).
//! * A home member dies mid-pop → the lane's `ack` is odd (and
//!   `home_busy` set) → roll both back; the payload is re-exposed (the
//!   dead pop never returned it, so exactly-once holds).
//! * A thief dies mid-steal → the steal is **kill-atomic** around the
//!   single `ack` advance: stolen payloads are staged into the thief's
//!   crash-visible [`Stash`] *before* the priced `ack` store, and the
//!   stash is marked committed by the host store immediately after it
//!   (kills fire at priced-op entry, so the commit mark and the `ack`
//!   advance are indivisible). Repair either discards the stage (ack
//!   never advanced — the payloads are still in the lane) or recovers
//!   every unconsumed staged payload (ack advanced — the stash is the
//!   only copy) by re-enqueueing it onto the **dead node's own lane**,
//!   whose producer is the corpse itself — never onto the original
//!   `from` lane, whose producer may be alive and mid-send. Either way
//!   the dead thief's `thief` claim word is cleared so the lane
//!   unwedges.
//!
//! Rebalancing a lane between two *live* members (fenced-member
//! recovery, late attach) rides the same thief claim word: the
//! rebalancer claims the lane, waits out the home's bounded critical
//! section, swaps the host-side assignment, and releases — and the home
//! pop re-checks its assignment *after* winning the Dekker, so a stale
//! home can never race the new one.

use std::cell::UnsafeCell;
use std::sync::atomic::{fence, AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};

use super::mem::{Atom32, Atom64, CachePadded, World};
use super::nbb::SideCache;
use crate::obs;
use crate::obs::EventKind;

/// Maximum payloads one steal moves (one `ack` advance covers all of
/// them). Bounds both the imbalance a single steal corrects and the
/// stash footprint.
pub const STEAL_BATCH: usize = 8;

/// `thief`-word sentinel for a rebalance handoff in progress (distinct
/// from every `member + 1` claim).
const REBALANCE_CLAIM: u32 = u32::MAX;

/// Bounded spin budget a thief waits for the home's in-flight pop
/// (`home_busy == 1`). A live home clears the flag within a handful of
/// operations; a dead home parks it until repair, and the thief must
/// not hang on a corpse.
const THIEF_SPIN_LIMIT: u32 = 256;

/// Why a sharded send enqueued nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardSendError {
    /// The producer's lane is full.
    Full,
    /// Lane full but a consumer is mid-pop: retry immediately, bounded
    /// (Table 1 `*_BUT_*`).
    FullButConsumerReading,
    /// `lane` is not a valid producer slot. Lane ids arrive from entry
    /// metadata (wire decode, test harnesses), so an out-of-range id is
    /// a rejectable input, not a panic.
    BadLane,
}

/// Why a sharded receive returned nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardRecvError {
    /// Every lane (home and steal candidates) was empty.
    Empty,
    /// Nothing claimable right now, but a peer was mid-operation
    /// (producer inserting, or another consumer holding a claim):
    /// retry immediately, bounded.
    PeerActive,
}

/// One per-producer SPSC lane plus the stealing control words.
struct Lane<W: World> {
    /// Writer counter — producer-owned line (NBB protocol: odd =
    /// insert in progress).
    update: CachePadded<W::U64>,
    /// Reader counter — advanced by the home consumer (`+1`/`+2`
    /// windows) or by a thief (one even batch step). Odd = home pop in
    /// progress.
    ack: CachePadded<W::U64>,
    /// Home-side Dekker flag: the home stores 1, *then* checks
    /// `thief`; a claimant stores its claim, *then* checks this.
    /// SeqCst fences on both sides make the store/load pairs a real
    /// Dekker — at least one side always sees the other.
    home_busy: CachePadded<W::U32>,
    /// Steal claim word: 0 = unclaimed, `member + 1`, or
    /// [`REBALANCE_CLAIM`]. The claimant board for crash repair.
    thief: CachePadded<W::U32>,
    /// Producer-private mirrors (own = `update`, peer = `ack`
    /// snapshot) — the PR 1 cached-peer-counter optimization.
    prod: CachePadded<SideCache>,
    /// Consumer-side cached `update` snapshot. A host atomic, not a
    /// `Cell`: home assignment migrates across threads on rebalance.
    /// `update` only grows, so a stale snapshot is conservative
    /// (under-reports occupancy, never fabricates it).
    peer_update: CachePadded<AtomicU64>,
    /// Home assignment: `member + 1`, 0 = unassigned. Host atomic —
    /// scanned on every pop, so it must stay unpriced; writes go
    /// through the claim-word handoff.
    home: AtomicU32,
    /// Per-slot payload length words.
    lens: Box<[UnsafeCell<u32>]>,
    /// Slot payload bytes: `cap * slot_len`, contiguous.
    bytes: Box<[UnsafeCell<u8>]>,
    /// Synthetic per-slot regions for simulator cost accounting.
    regions: Box<[u64]>,
}

impl<W: World> Lane<W> {
    fn new(cap: usize, slot_len: usize) -> Self {
        Lane {
            update: CachePadded::new(W::U64::new(0)),
            ack: CachePadded::new(W::U64::new(0)),
            home_busy: CachePadded::new(W::U32::new(0)),
            thief: CachePadded::new(W::U32::new(0)),
            prod: CachePadded::new(SideCache::new()),
            peer_update: CachePadded::new(AtomicU64::new(0)),
            home: AtomicU32::new(0),
            lens: (0..cap).map(|_| UnsafeCell::new(0u32)).collect(),
            bytes: (0..cap * slot_len).map(|_| UnsafeCell::new(0u8)).collect(),
            regions: (0..cap).map(|_| W::alloc_region(4 + slot_len)).collect(),
        }
    }

    /// Committed-but-unclaimed payloads (unpriced peeks; monitoring,
    /// victim selection and watchdogs only).
    fn backlog(&self) -> u64 {
        (self.update.peek() / 2).wrapping_sub(self.ack.peek() / 2)
    }
}

/// Per-member crash-visible staging area for stolen payloads. Stolen
/// batches land here *before* the lane's `ack` advances, so a thief
/// killed at any priced operation either left the payloads in the lane
/// (stage uncommitted) or left them fully salvageable here (stage
/// committed). All fields are host-side: staging and consuming are
/// exclusively the owning member's, and repair touches a stash only
/// after its owner is declared dead.
struct Stash {
    /// Staged entry count (0 = empty stage).
    count: AtomicUsize,
    /// Next staged entry to deliver; `next == count` = drained.
    next: AtomicUsize,
    /// True once the backing `ack` advance committed — set by the host
    /// store immediately after the priced `ack` store, so it is
    /// kill-atomic with the advance.
    committed: AtomicBool,
    /// Per-entry payload lengths.
    lens: Box<[UnsafeCell<u32>]>,
    /// Payload bytes: `STEAL_BATCH * slot_len`.
    bytes: Box<[UnsafeCell<u8>]>,
    slot_len: usize,
}

impl Stash {
    fn new(slot_len: usize) -> Self {
        Stash {
            count: AtomicUsize::new(0),
            next: AtomicUsize::new(0),
            committed: AtomicBool::new(false),
            lens: (0..STEAL_BATCH).map(|_| UnsafeCell::new(0u32)).collect(),
            bytes: (0..STEAL_BATCH * slot_len).map(|_| UnsafeCell::new(0u8)).collect(),
            slot_len,
        }
    }

    fn pending(&self) -> usize {
        // Saturating: `len()` sums pending across all stashes from
        // arbitrary threads, so a reader can interleave with `reset`
        // (new `count == 0`, old `next > 0`) or a concurrent claim and
        // observe `next > count` transiently. Clamp to 0 instead of
        // underflowing.
        self.count
            .load(Ordering::Acquire)
            .saturating_sub(self.next.load(Ordering::Acquire))
    }

    /// Stage slot `i` (host writes; made visible by the later `count`
    /// store in the stealing protocol).
    fn stage(&self, i: usize, payload: &[u8]) {
        unsafe {
            *self.lens[i].get() = payload.len() as u32;
            std::ptr::copy_nonoverlapping(
                payload.as_ptr(),
                self.bytes[i * self.slot_len].get(),
                payload.len(),
            );
        }
    }

    /// Deliver the next staged entry to `read`, if any. Entries are
    /// claimed with a CAS on `next`, so two drainers can never deliver
    /// the same staged payload: the owner in `recv_as` step 1 and
    /// `repair_dead`'s salvage can race — a fenced-but-still-running
    /// (zombie) member that passed `fence_check` before entering its
    /// pop is still draining when repair declares it dead — and each
    /// entry goes to exactly one of them.
    fn take<T>(&self, read: &mut dyn FnMut(&[u8]) -> T) -> Option<T> {
        loop {
            let next = self.next.load(Ordering::Acquire);
            if next >= self.count.load(Ordering::Acquire) {
                return None;
            }
            if self
                .next
                .compare_exchange_weak(next, next + 1, Ordering::AcqRel, Ordering::Acquire)
                .is_err()
            {
                continue; // lost the claim: another drainer took `next`
            }
            let len = unsafe { *self.lens[next].get() } as usize;
            let bytes = unsafe {
                std::slice::from_raw_parts(self.bytes[next * self.slot_len].get(), len)
            };
            return Some(read(bytes));
        }
    }

    fn reset(&self) {
        // `count` MUST drop to 0 before `next`: a concurrent `take`
        // re-checks `count` before its CAS, so zeroing `count` first
        // makes it see an empty stage. Zeroing `next` first would let
        // it claim slot 0 against the still-nonzero `count` and
        // re-deliver an already-delivered payload.
        self.count.store(0, Ordering::Release);
        self.next.store(0, Ordering::Release);
        self.committed.store(false, Ordering::Release);
    }
}

/// What [`ShardedRing::repair_dead`] did for one dead node.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneRepair {
    /// Torn producer inserts rolled back.
    pub torn_inserts: usize,
    /// Torn home pops rolled back (payload re-exposed in the lane).
    pub torn_pops: usize,
    /// Wedged thief claims cleared.
    pub cleared_claims: usize,
    /// Staged-but-uncommitted steals discarded (payloads still live in
    /// their lane).
    pub discarded_stages: usize,
    /// Committed-but-undelivered stolen payloads re-enqueued onto the
    /// dead node's own (producer-less) lane.
    pub requeued: usize,
    /// Committed-but-undelivered stolen payloads handed back to the
    /// caller because the dead node's lane could not absorb them
    /// (lane full, or the node has no lane slot).
    pub salvaged: usize,
}

/// Lane-sharded MPMC ring: `n_lanes` per-producer SPSC lanes,
/// `n_members` consumer identities with home-lane assignment, lock-free
/// batch stealing, and claimant-board crash repair. Producer and member
/// identities are **dense node slots** (the same space the runtime's
/// recovery machinery keys on).
pub struct ShardedRing<W: World> {
    lanes: Box<[Lane<W>]>,
    stashes: Box<[Stash]>,
    /// Which member slots are attached (host; rebalance input).
    member_active: Box<[AtomicBool]>,
    /// Shared steal cursor: rotates thieves' scan start so concurrent
    /// thieves fan out instead of convoying on one victim. The ONLY
    /// shared-counter RMW in the consumer plane, touched exclusively
    /// when a member's home lanes are dry.
    steal_cursor: CachePadded<W::U64>,
    slot_len: usize,
    cap: u64,
    /// Observability id for trace events (host; [`obs::CH_NONE`] until
    /// tagged).
    trace_id: AtomicU32,
}

unsafe impl<W: World> Send for ShardedRing<W> {}
unsafe impl<W: World> Sync for ShardedRing<W> {}

impl<W: World> ShardedRing<W> {
    /// Shard with `n_lanes` producer lanes of `cap` slots × `slot_len`
    /// payload bytes, and stash/assignment room for `n_members`
    /// consumer identities.
    pub fn new(n_lanes: usize, n_members: usize, cap: usize, slot_len: usize) -> Self {
        assert!(n_lanes >= 1, "shard needs at least one lane");
        assert!(n_members >= 1, "shard needs at least one member slot");
        assert!(cap >= 1, "lane capacity must be >= 1");
        assert!(slot_len >= 8, "lane slot must fit a 64-bit scalar");
        ShardedRing {
            lanes: (0..n_lanes).map(|_| Lane::new(cap, slot_len)).collect(),
            stashes: (0..n_members).map(|_| Stash::new(slot_len)).collect(),
            member_active: (0..n_members).map(|_| AtomicBool::new(false)).collect(),
            steal_cursor: CachePadded::new(W::U64::new(0)),
            slot_len,
            cap: cap as u64,
            trace_id: AtomicU32::new(obs::CH_NONE),
        }
    }

    /// Tag trace events with the owning channel/endpoint id.
    pub fn set_trace_id(&self, id: u32) {
        self.trace_id.store(id, Ordering::Relaxed);
    }

    fn trace_id_now(&self) -> u32 {
        self.trace_id.load(Ordering::Relaxed)
    }

    /// Producer lanes.
    pub fn lanes(&self) -> usize {
        self.lanes.len()
    }

    /// Member (stash/assignment) slots.
    pub fn members(&self) -> usize {
        self.stashes.len()
    }

    /// Payload bytes per slot.
    pub fn slot_len(&self) -> usize {
        self.slot_len
    }

    /// Committed-but-undelivered payloads across every lane and stash
    /// (approximate; unpriced peeks, safe from watchdogs).
    pub fn len(&self) -> usize {
        let lanes: u64 = self.lanes.iter().map(Lane::backlog).sum();
        let staged: usize = self.stashes.iter().map(Stash::pending).sum();
        lanes as usize + staged
    }

    /// True when nothing is buffered anywhere.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lane `lane`'s committed-but-unclaimed backlog (unpriced peek).
    pub fn lane_len(&self, lane: usize) -> usize {
        self.lanes.get(lane).map_or(0, |l| l.backlog() as usize)
    }

    /// Raw `(update, ack)` for lane `lane` (unpriced; post-run
    /// invariant checks only).
    pub fn lane_counters_peek(&self, lane: usize) -> (u64, u64) {
        let l = &self.lanes[lane];
        (l.update.peek(), l.ack.peek())
    }

    /// Home member of `lane` (`None` = unassigned). Host peek.
    pub fn home_of(&self, lane: usize) -> Option<u32> {
        match self.lanes.get(lane).map_or(0, |l| l.home.load(Ordering::Relaxed)) {
            0 => None,
            m => Some(m - 1),
        }
    }

    // -- producer side ------------------------------------------------------

    /// Insert `payload` into producer `lane`'s SPSC ring — the
    /// unchanged NBB fast path: stores only, one cross-core `ack` load
    /// per ring wrap. Single producer per lane (the SPSC contract; lane
    /// == the sender's dense node slot).
    ///
    /// Out-of-range lanes return [`ShardSendError::BadLane`] — lane
    /// ids travel in entry metadata, so they are validated, not
    /// trusted.
    ///
    /// # Panics
    /// If `payload` exceeds the slot length — a caller bug (the slot
    /// length is a construction-time constant the caller picked).
    pub fn send(&self, lane: u32, payload: &[u8]) -> Result<(), ShardSendError> {
        assert!(payload.len() <= self.slot_len, "payload exceeds lane slot");
        let l = self.lanes.get(lane as usize).ok_or(ShardSendError::BadLane)?;
        let u = l.prod.own.get();
        self.lane_free(l, u)?;
        l.update.store(u + 1); // enter: odd = insert in progress
        self.write_slot(l, ((u / 2) % self.cap) as usize, payload);
        l.update.store(u + 2); // exit: publish
        l.prod.own.set(u + 2);
        if obs::tracing() {
            obs::emit::<W>(EventKind::MpmcPublish, self.trace_id_now(), u / 2, lane);
            obs::bump(obs::ctr::MPMC_PUBLISH);
        }
        Ok(())
    }

    /// Batched insert into producer `lane`: one enter/exit counter
    /// store pair amortized over the whole prefix. Returns how many
    /// payloads went in (`Err` only when none fit).
    pub fn send_batch(&self, lane: u32, payloads: &[&[u8]]) -> Result<usize, ShardSendError> {
        if payloads.is_empty() {
            return Ok(0);
        }
        assert!(
            payloads.iter().all(|p| p.len() <= self.slot_len),
            "payload exceeds lane slot"
        );
        let l = self.lanes.get(lane as usize).ok_or(ShardSendError::BadLane)?;
        let u = l.prod.own.get();
        let free = self.lane_free(l, u)?;
        let k = (free as usize).min(payloads.len());
        l.update.store(u + 1); // enter once: odd across the whole batch
        for (i, p) in payloads[..k].iter().enumerate() {
            self.write_slot(l, ((u / 2 + i as u64) % self.cap) as usize, p);
        }
        let u2 = u + 2 * k as u64;
        l.update.store(u2); // exit: publishes all k at once
        l.prod.own.set(u2);
        if obs::tracing() {
            for i in 0..k as u64 {
                obs::emit::<W>(EventKind::MpmcPublish, self.trace_id_now(), u / 2 + i, lane);
            }
            obs::add(obs::ctr::MPMC_PUBLISH, k as u64);
        }
        Ok(k)
    }

    /// Producer-side free-slot count: cached consumer counter,
    /// re-loaded only on apparent full.
    fn lane_free(&self, l: &Lane<W>, u: u64) -> Result<u64, ShardSendError> {
        let mut a = l.prod.peer.get();
        let mut free = self.cap - (u / 2).wrapping_sub(a / 2);
        if free == 0 {
            a = l.ack.load();
            l.prod.peer.set(a);
            free = self.cap - (u / 2).wrapping_sub(a / 2);
            if free == 0 {
                return Err(if a & 1 == 1 {
                    ShardSendError::FullButConsumerReading
                } else {
                    ShardSendError::Full
                });
            }
        }
        Ok(free)
    }

    fn write_slot(&self, l: &Lane<W>, idx: usize, payload: &[u8]) {
        W::touch(l.regions[idx], 4 + payload.len().max(1), true);
        unsafe {
            *l.lens[idx].get() = payload.len() as u32;
            std::ptr::copy_nonoverlapping(
                payload.as_ptr(),
                l.bytes[idx * self.slot_len].get(),
                payload.len(),
            );
        }
    }

    /// Slot `idx` of lane `l` as a byte slice of its recorded length
    /// (inside an exclusion window; charges the slot read).
    fn read_slot<'a>(&self, l: &'a Lane<W>, idx: usize) -> &'a [u8] {
        let len = {
            W::touch(l.regions[idx], 4, false);
            (unsafe { *l.lens[idx].get() } as usize).min(self.slot_len)
        };
        W::touch(l.regions[idx], len.max(1), false);
        unsafe { std::slice::from_raw_parts(l.bytes[idx * self.slot_len].get(), len) }
    }

    // -- membership and home assignment -------------------------------------

    /// Mark member `m` attached and deal it a fair share of lanes
    /// (round-robin over attached members; live-lane moves go through
    /// the claim-word handoff, so this is safe mid-traffic).
    pub fn attach_member(&self, m: u32) {
        if let Some(cell) = self.member_active.get(m as usize) {
            cell.store(true, Ordering::SeqCst);
        }
        self.rebalance();
    }

    /// True when member `m` is attached.
    pub fn member_attached(&self, m: u32) -> bool {
        self.member_active.get(m as usize).map_or(false, |c| c.load(Ordering::SeqCst))
    }

    /// Re-deal every lane round-robin across the currently attached
    /// members (none attached → all lanes unassigned). Lanes already
    /// owned by their target member are untouched; every real move is
    /// a claim-word handoff that waits out the old home's in-flight
    /// pop, so two live members can never both believe they own a lane.
    ///
    /// Best-effort: a lane wedged by a dead-but-undeclared peer (claim
    /// word or busy flag parked) is **skipped** rather than waited on —
    /// assignment is a latency optimization, never a correctness
    /// dependency (an unassigned or stale-homed lane stays stealable),
    /// and the next repair/attach re-runs the deal.
    pub fn rebalance(&self) {
        let members: Vec<u32> = (0..self.stashes.len() as u32)
            .filter(|&m| self.member_attached(m))
            .collect();
        for (i, l) in self.lanes.iter().enumerate() {
            let want = members.get(i % members.len().max(1)).map_or(0, |&m| m + 1);
            if l.home.load(Ordering::SeqCst) != want {
                self.assign_home(l, want);
            }
        }
    }

    /// Move `l`'s home assignment to `want` (`member + 1`, 0 =
    /// unassign) through the claim-word handoff. Returns `false` if the
    /// lane was wedged (bounded spins exhausted) and the move skipped.
    fn assign_home(&self, l: &Lane<W>, want: u32) -> bool {
        // Claim the lane against thieves (and concurrent rebalancers).
        let mut spins = 0;
        while l.thief.cas(0, REBALANCE_CLAIM).is_err() {
            spins += 1;
            if spins >= THIEF_SPIN_LIMIT {
                return false;
            }
            W::spin_hint();
        }
        fence(Ordering::SeqCst);
        // Wait out the old home's in-flight pop: it set `home_busy`
        // before checking `thief`, so either it saw our claim and
        // backed off, or we see its flag and wait for the (bounded)
        // critical section to finish. A *dead* home's parked flag is
        // cleared by repair before the rebalance runs; one wedged by a
        // not-yet-declared corpse forfeits the move.
        spins = 0;
        while l.home_busy.load() != 0 {
            spins += 1;
            if spins >= THIEF_SPIN_LIMIT {
                l.thief.store(0);
                return false;
            }
            W::spin_hint();
        }
        l.home.store(want, Ordering::SeqCst);
        l.thief.store(0);
        true
    }

    // -- consumer side ------------------------------------------------------

    /// Pop one payload as member `me`: staged steals first (host-only
    /// delivery), then the home lanes (zero shared-counter RMW), then —
    /// only with every home lane dry — a batch steal from the most
    /// backlogged lane. `read` sees the payload bytes in place.
    pub fn recv_as<T>(&self, me: u32, mut read: impl FnMut(&[u8]) -> T) -> Result<T, ShardRecvError> {
        // 1) Deliver a previously stolen payload: pure host reads, the
        //    batch-steal amortization paying out.
        if let Some(stash) = self.stashes.get(me as usize) {
            if let Some(v) = stash.take(&mut |b| read(b)) {
                if obs::tracing() {
                    obs::bump(obs::ctr::MPMC_CONSUME);
                }
                return Ok(v);
            }
            if stash.pending() == 0 && stash.count.load(Ordering::Acquire) != 0 {
                stash.reset();
            }
        }
        // 2) Drain home lanes: zero shared-counter RMW in steady state.
        let mut peer_active = false;
        for (i, l) in self.lanes.iter().enumerate() {
            if l.home.load(Ordering::Relaxed) != me + 1 {
                continue;
            }
            match self.home_pop(l, me, &mut read) {
                Ok(v) => {
                    if obs::tracing() {
                        obs::emit::<W>(
                            EventKind::MpmcClaim,
                            self.trace_id_now(),
                            i as u64,
                            0,
                        );
                        obs::bump(obs::ctr::MPMC_CONSUME);
                    }
                    return Ok(v);
                }
                Err(ShardRecvError::PeerActive) => peer_active = true,
                Err(ShardRecvError::Empty) => {}
            }
        }
        // 3) Home lanes dry: steal. The cursor bump is the only shared
        //    RMW a consumer ever performs, and only on this path.
        match self.steal(me, &mut read) {
            Ok(v) => Ok(v),
            Err(ShardRecvError::PeerActive) => Err(ShardRecvError::PeerActive),
            Err(ShardRecvError::Empty) if peer_active => Err(ShardRecvError::PeerActive),
            Err(e) => Err(e),
        }
    }

    /// One home pop on lane `l` by member `me`. Plain loads/stores
    /// only — the Dekker against thieves replaces the shared-head CAS.
    fn home_pop<T>(
        &self,
        l: &Lane<W>,
        me: u32,
        read: &mut impl FnMut(&[u8]) -> T,
    ) -> Result<T, ShardRecvError> {
        l.home_busy.store(1);
        fence(Ordering::SeqCst);
        if l.thief.load() != 0 {
            // A thief (or rebalancer) holds the lane: back off and let
            // it finish — its claim is bounded.
            l.home_busy.store(0);
            return Err(ShardRecvError::PeerActive);
        }
        // Re-check the assignment *after* winning the Dekker: a
        // rebalance that completed between our scan and our flag store
        // has already moved this lane to another member.
        if l.home.load(Ordering::SeqCst) != me + 1 {
            l.home_busy.store(0);
            return Err(ShardRecvError::Empty);
        }
        // `ack` is exact here (thieves excluded); `update` goes through
        // the cached snapshot, re-loaded only on apparent empty.
        let a = l.ack.load();
        debug_assert_eq!(a & 1, 0, "home pop found a torn ack outside repair");
        let mut u = l.peer_update.load(Ordering::Relaxed);
        let mut avail = (u / 2).wrapping_sub(a / 2);
        if avail == 0 {
            u = l.update.load();
            l.peer_update.store(u, Ordering::Relaxed);
            avail = (u / 2).wrapping_sub(a / 2);
            if avail == 0 {
                l.home_busy.store(0);
                return Err(if u & 1 == 1 {
                    ShardRecvError::PeerActive
                } else {
                    ShardRecvError::Empty
                });
            }
        }
        l.ack.store(a + 1); // enter: odd = pop in progress
        let v = read(self.read_slot(l, ((a / 2) % self.cap) as usize));
        l.ack.store(a + 2); // exit
        l.home_busy.store(0);
        Ok(v)
    }

    /// Steal a batch as member `me`: bump the cursor, walk candidates
    /// from most- to least-backlogged, claim one, move up to
    /// [`STEAL_BATCH`] payloads through the crash-safe stash, and
    /// deliver the first.
    fn steal<T>(
        &self,
        me: u32,
        read: &mut impl FnMut(&[u8]) -> T,
    ) -> Result<T, ShardRecvError> {
        let n = self.lanes.len();
        // Empty-poll fast path: one allocation-free O(n) sweep of
        // unpriced peeks. An idle group polls through here on every
        // pop, so it must not pay the cursor RMW (or heap traffic)
        // just to discover there is nothing to steal.
        if self.lanes.iter().all(|l| l.backlog() == 0) {
            return Err(ShardRecvError::Empty);
        }
        let start = self.steal_cursor.fetch_add(1) as usize;
        let mut contended = false;
        // Up to n attempts: each picks the currently most-backlogged
        // lane in one O(n) pass of unpriced peeks (no allocation, no
        // sort), the cursor offset breaking ties so concurrent thieves
        // fan out. A lane that loses its claim race is skipped on the
        // next pass so a second-best victim gets tried.
        let mut skip = usize::MAX;
        for _ in 0..n {
            let mut best: Option<(u64, usize)> = None;
            for off in 0..n {
                let i = (start + off) % n;
                if i == skip {
                    continue;
                }
                let b = self.lanes[i].backlog();
                if b > 0 && best.map_or(true, |(bb, _)| b > bb) {
                    best = Some((b, i));
                }
            }
            let Some((_, i)) = best else { break };
            match self.steal_from(i, me, read) {
                Ok(v) => return Ok(v),
                Err(ShardRecvError::PeerActive) => {
                    contended = true;
                    skip = i;
                }
                Err(ShardRecvError::Empty) => skip = i,
            }
        }
        Err(if contended { ShardRecvError::PeerActive } else { ShardRecvError::Empty })
    }

    /// Claim lane `victim` and move up to [`STEAL_BATCH`] payloads into
    /// `me`'s stash; deliver the first. One thief-word CAS, one `ack`
    /// store — shared-RMW cost is O(1) per batch, not per payload.
    fn steal_from<T>(
        &self,
        victim: usize,
        me: u32,
        read: &mut impl FnMut(&[u8]) -> T,
    ) -> Result<T, ShardRecvError> {
        let l = &self.lanes[victim];
        let Some(stash) = self.stashes.get(me as usize) else {
            // No stash slot for this identity: it cannot stage a
            // crash-safe batch, so it must not steal.
            return Err(ShardRecvError::Empty);
        };
        if l.thief.cas(0, me + 1).is_err() {
            return Err(ShardRecvError::PeerActive);
        }
        fence(Ordering::SeqCst);
        // Wait out the home's in-flight pop (bounded; a dead home
        // parks the flag until repair, so give up rather than hang).
        let mut spins = 0;
        while l.home_busy.load() != 0 {
            spins += 1;
            if spins >= THIEF_SPIN_LIMIT {
                l.thief.store(0);
                return Err(ShardRecvError::PeerActive);
            }
            W::spin_hint();
        }
        let a = l.ack.load();
        if a & 1 == 1 {
            // Torn home pop (its owner died before repair ran): not
            // ours to fix.
            l.thief.store(0);
            return Err(ShardRecvError::PeerActive);
        }
        let u = l.update.load();
        let avail = (u / 2).wrapping_sub(a / 2);
        let k = (avail as usize).min(STEAL_BATCH);
        if k == 0 {
            l.thief.store(0);
            return Err(ShardRecvError::Empty);
        }
        // Stage into the crash-visible stash BEFORE the ack advance:
        // the `count` store publishes the stage, the `committed` store
        // right after the ack store marks it delivered-from-lane. A
        // kill at any priced op leaves repair an unambiguous state.
        stash.reset();
        for i in 0..k {
            let idx = ((a / 2 + i as u64) % self.cap) as usize;
            let bytes = self.read_slot(l, idx);
            stash.stage(i, bytes);
        }
        stash.count.store(k, Ordering::Release);
        l.ack.store(a + 2 * k as u64); // the single shared advance
        stash.committed.store(true, Ordering::Release);
        l.thief.store(0);
        obs::add(obs::ctr::MPMC_STEALS, 1);
        if obs::tracing() {
            obs::emit::<W>(EventKind::MpmcSteal, self.trace_id_now(), victim as u64, k as u32);
            obs::bump(obs::ctr::MPMC_CONSUME);
        }
        Ok(stash
            .take(&mut |b| read(b))
            .expect("a committed steal stages at least one payload"))
    }

    // -- crash repair --------------------------------------------------------

    /// Repair every transient state dead node `node` left behind, in
    /// all four roles it can hold (producer, home member, thief, stash
    /// owner). Committed-but-undelivered stolen payloads are
    /// re-enqueued onto the **dead node's own lane**: its producer is
    /// the corpse itself, so after the producer-role rollback the
    /// repairer is that lane's sole writer and the SPSC contract
    /// holds. (Re-enqueueing via the payloads' original `from` lanes
    /// would race those lanes' *live* producers — two writers on one
    /// SPSC lane is UB on the producer-private counter cache.) Only
    /// payloads the lane cannot absorb — lane full, or `node` has no
    /// lane slot — are handed back via `salvage`, and the caller must
    /// not re-enqueue them onto a live producer's lane either.
    ///
    /// Exclusivity: callers serialize repair per node (the runtime's
    /// liveness epoch flips odd exactly once per death), so there is
    /// never more than one repairer writing the dead lane.
    ///
    /// Detaches the member slot; the caller decides when to
    /// [`ShardedRing::rebalance`] the orphaned lanes (fence first,
    /// then re-deal — PR 6 ordering).
    pub fn repair_dead(&self, node: u32, mut salvage: impl FnMut(&[u8])) -> LaneRepair {
        let mut r = LaneRepair::default();
        // Producer role: roll back a torn insert on the node's own lane.
        if let Some(l) = self.lanes.get(node as usize) {
            let u = l.update.load();
            if u & 1 == 1 {
                l.update.store(u - 1);
                r.torn_inserts += 1;
            }
            l.prod.own.set(u & !1);
        }
        for l in self.lanes.iter() {
            // Home role: roll back a torn pop (payload re-exposed; the
            // dead pop never returned it) and clear the parked flag so
            // thieves and rebalancers stop waiting on a corpse.
            if l.home.load(Ordering::SeqCst) == node + 1 {
                let a = l.ack.load();
                if a & 1 == 1 {
                    l.ack.store(a - 1);
                    r.torn_pops += 1;
                }
                if l.home_busy.load() != 0 {
                    l.home_busy.store(0);
                }
                l.home.store(0, Ordering::SeqCst);
            }
            // Thief role: clear the wedged claim word (the stash
            // disposition below decides what happened to the payloads).
            if l.thief.load() == node + 1 {
                l.thief.store(0);
                r.cleared_claims += 1;
            }
        }
        // Stash owner role: a committed stage's remaining payloads
        // exist nowhere else — re-enqueue them onto the dead node's
        // own lane (producer rolled back above, so the repairer is its
        // sole writer; a live thief/home can drain it concurrently,
        // which the SPSC protocol allows). Overflow goes back to the
        // caller. An uncommitted stage's payloads are still in their
        // lane — discard the stage.
        if let Some(stash) = self.stashes.get(node as usize) {
            if stash.committed.load(Ordering::Acquire) {
                while let Some(()) = stash.take(&mut |b| {
                    if self.send(node, b).is_ok() {
                        r.requeued += 1;
                    } else {
                        salvage(b);
                        r.salvaged += 1;
                    }
                }) {}
            } else if stash.count.load(Ordering::Acquire) != 0 {
                r.discarded_stages += 1;
            }
            stash.reset();
        }
        if let Some(cell) = self.member_active.get(node as usize) {
            cell.store(false, Ordering::SeqCst);
        }
        let repairs = r.torn_inserts + r.torn_pops + r.cleared_claims + r.requeued + r.salvaged;
        if repairs > 0 {
            obs::add(obs::ctr::MPMC_REPAIRS, repairs as u64);
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::atomic::AtomicU64 as HostU64;
    use std::sync::Arc;

    type Shard = ShardedRing<RealWorld>;

    fn payload(i: u64) -> [u8; 8] {
        i.to_le_bytes()
    }

    fn decode(b: &[u8]) -> u64 {
        u64::from_le_bytes(b[..8].try_into().unwrap())
    }

    #[test]
    fn home_drain_is_fifo_per_lane() {
        let s = Shard::new(2, 2, 8, 8);
        s.attach_member(0);
        for i in 0..5u64 {
            s.send(0, &payload(i)).unwrap();
        }
        for i in 0..5u64 {
            assert_eq!(s.recv_as(0, decode), Ok(i), "home lane FIFO");
        }
        assert_eq!(s.recv_as(0, decode), Err(ShardRecvError::Empty));
    }

    #[test]
    fn lane_full_reports_table1_status() {
        let s = Shard::new(1, 1, 2, 8);
        s.attach_member(0);
        s.send(0, &payload(0)).unwrap();
        s.send(0, &payload(1)).unwrap();
        assert_eq!(s.send(0, &payload(2)), Err(ShardSendError::Full));
        assert_eq!(s.recv_as(0, decode), Ok(0));
        s.send(0, &payload(2)).unwrap();
    }

    #[test]
    fn batch_send_publishes_all_at_once() {
        let s = Shard::new(1, 1, 8, 8);
        s.attach_member(0);
        let bufs: Vec<[u8; 8]> = (0..5u64).map(payload).collect();
        let refs: Vec<&[u8]> = bufs.iter().map(|b| b.as_slice()).collect();
        assert_eq!(s.send_batch(0, &refs), Ok(5));
        assert_eq!(s.lane_len(0), 5);
        for i in 0..5u64 {
            assert_eq!(s.recv_as(0, decode), Ok(i));
        }
    }

    #[test]
    fn dry_member_steals_from_most_backlogged_lane() {
        let s = Shard::new(3, 2, 16, 8);
        s.attach_member(0);
        s.attach_member(1);
        // Round-robin: lanes 0 and 2 home to member 0, lane 1 to member 1.
        assert_eq!(s.home_of(0), Some(0));
        assert_eq!(s.home_of(1), Some(1));
        assert_eq!(s.home_of(2), Some(0));
        // Load only member 0's lane: member 1 must steal.
        for i in 0..12u64 {
            s.send(0, &payload(i)).unwrap();
        }
        let v = s.recv_as(1, decode).expect("dry member must steal");
        assert_eq!(v, 0, "steal takes the oldest committed payload");
        // The batch landed in member 1's stash: next pops are host-only.
        for want in 1..STEAL_BATCH as u64 {
            assert_eq!(s.recv_as(1, decode), Ok(want), "stash drains in order");
        }
        // Member 0 still drains the remainder from its home lane.
        let mut rest = Vec::new();
        while let Ok(v) = s.recv_as(0, decode) {
            rest.push(v);
        }
        assert_eq!(rest, (STEAL_BATCH as u64..12).collect::<Vec<_>>());
    }

    #[test]
    fn rebalance_moves_lanes_without_loss_under_traffic() {
        let s = Arc::new(Shard::new(4, 2, 64, 8));
        s.attach_member(0);
        const N: u64 = 4_000;
        let prod = {
            let s = s.clone();
            std::thread::spawn(move || {
                for i in 0..N {
                    let lane = (i % 4) as u32;
                    let b = payload(i);
                    while s.send(lane, &b).is_err() {
                        std::hint::spin_loop();
                    }
                    if i == N / 3 {
                        // Mid-traffic attach triggers a live rebalance.
                        s.attach_member(1);
                    }
                }
            })
        };
        let sum = Arc::new(HostU64::new(0));
        let cnt = Arc::new(HostU64::new(0));
        let mut handles = vec![prod];
        for m in 0..2u32 {
            let (s, sum, cnt) = (s.clone(), sum.clone(), cnt.clone());
            handles.push(std::thread::spawn(move || loop {
                match s.recv_as(m, decode) {
                    Ok(v) => {
                        sum.fetch_add(v, Ordering::Relaxed);
                        cnt.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        if cnt.load(Ordering::Relaxed) >= N {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cnt.load(Ordering::SeqCst), N, "lost or duplicated a payload");
        assert_eq!(sum.load(Ordering::SeqCst), N * (N - 1) / 2, "checksum mismatch");
    }

    #[test]
    fn repair_discards_uncommitted_stage_and_requeues_committed() {
        // Committed stage: ack advanced, stash holds the only copies.
        // Repair re-enqueues them onto the dead node's OWN lane (its
        // producer is the corpse) — never the original `from` lane,
        // whose producer may be alive and mid-send.
        let s = Shard::new(2, 2, 16, 8);
        s.attach_member(0);
        s.attach_member(1);
        for i in 0..6u64 {
            s.send(0, &payload(i)).unwrap();
        }
        // Member 1 steals a batch and consumes one payload, then "dies".
        assert_eq!(s.recv_as(1, decode), Ok(0));
        let mut salvaged = Vec::new();
        let r = s.repair_dead(1, |b| salvaged.push(decode(b)));
        assert_eq!(r.requeued, 5, "committed stage must requeue the remainder");
        assert_eq!(r.salvaged, 0, "dead lane had room: nothing handed back");
        assert!(salvaged.is_empty());
        assert_eq!(r.discarded_stages, 0);
        assert_eq!(s.lane_len(0), 0, "requeue must not write the live producer's lane");
        assert_eq!(s.lane_len(1), 5, "requeue lands on the dead node's lane");
        // The survivor drains the requeued payloads in stash order.
        for want in 1..6u64 {
            assert_eq!(s.recv_as(0, decode), Ok(want));
        }
        // Uncommitted stage: simulate by staging without the ack store.
        let s2 = Shard::new(1, 1, 8, 8);
        s2.attach_member(0);
        s2.send(0, &payload(9)).unwrap();
        s2.stashes[0].stage(0, &payload(9));
        s2.stashes[0].count.store(1, Ordering::Release);
        let mut sal2 = Vec::new();
        let r2 = s2.repair_dead(0, |b| sal2.push(decode(b)));
        assert_eq!(r2.discarded_stages, 1, "uncommitted stage must be discarded");
        assert!(sal2.is_empty(), "payload still lives in the lane");
        assert_eq!(s2.lane_len(0), 1);
    }

    #[test]
    fn repair_salvages_overflow_when_dead_lane_is_full() {
        let s = Shard::new(2, 2, 4, 8);
        s.attach_member(0);
        s.attach_member(1);
        for i in 0..4u64 {
            s.send(0, &payload(i)).unwrap();
        }
        // Member 1's home lane is dry: it steals lane 0's batch and
        // delivers one entry.
        assert_eq!(s.recv_as(1, decode), Ok(0));
        // Wedge the dead node's lane at capacity so requeue can't fit,
        // then declare it dead with the batch still staged.
        for i in 100..104u64 {
            s.send(1, &payload(i)).unwrap();
        }
        let mut salvaged = Vec::new();
        let r = s.repair_dead(1, |b| salvaged.push(decode(b)));
        assert_eq!(r.requeued, 0, "full dead lane absorbs nothing");
        assert_eq!(r.salvaged, 3, "overflow goes back to the caller");
        assert_eq!(salvaged, vec![1, 2, 3]);
        assert_eq!(s.lane_len(0), 0, "live producer's lane untouched");
        assert_eq!(s.lane_len(1), 4);
    }

    #[test]
    fn send_rejects_out_of_range_lane() {
        let s = Shard::new(2, 2, 4, 8);
        assert_eq!(s.send(2, &payload(0)), Err(ShardSendError::BadLane));
        let b = payload(0);
        let refs: Vec<&[u8]> = vec![&b];
        assert_eq!(s.send_batch(9, &refs), Err(ShardSendError::BadLane));
    }

    #[test]
    fn repair_rolls_back_torn_insert_and_torn_pop() {
        let s = Shard::new(2, 2, 8, 8);
        s.attach_member(0);
        s.send(0, &payload(0)).unwrap();
        // Torn insert: producer died inside the odd window.
        let (u, _) = s.lane_counters_peek(0);
        s.lanes[0].update.store(u + 1);
        // Torn pop: home died inside the odd window with the flag set.
        let (_, a) = s.lane_counters_peek(0);
        s.lanes[0].ack.store(a + 1);
        s.lanes[0].home_busy.store(1);
        let r = s.repair_dead(0, |_| {});
        assert_eq!((r.torn_inserts, r.torn_pops), (1, 1));
        let (u2, a2) = s.lane_counters_peek(0);
        assert_eq!(u2 % 2, 0);
        assert_eq!(a2 % 2, 0);
        assert_eq!(s.lane_len(0), 1, "committed payload survives repair");
        // Lane unwedged: a fresh member drains it.
        s.attach_member(1);
        assert_eq!(s.recv_as(1, decode), Ok(0));
    }

    #[test]
    fn repair_clears_dead_thief_claim() {
        let s = Shard::new(2, 2, 8, 8);
        s.attach_member(0);
        s.send(0, &payload(7)).unwrap();
        // Dead thief: claim word wedged, nothing staged.
        s.lanes[0].thief.store(2); // member 1's claim
        let r = s.repair_dead(1, |_| {});
        assert_eq!(r.cleared_claims, 1);
        assert_eq!(s.recv_as(0, decode), Ok(7), "lane unwedged for the home");
    }

    #[test]
    fn steal_storm_exactly_once_under_contention() {
        // One hot lane, four dry members: every pop is a steal.
        let s = Arc::new(Shard::new(4, 4, 64, 8));
        for m in 0..4 {
            s.attach_member(m);
        }
        const N: u64 = 8_000;
        let prod = {
            let s = s.clone();
            std::thread::spawn(move || {
                for i in 0..N {
                    let b = payload(i);
                    // Only lane 3 gets traffic; members 0..3 all go dry
                    // except lane 3's home.
                    while s.send(3, &b).is_err() {
                        std::hint::spin_loop();
                    }
                }
            })
        };
        let sum = Arc::new(HostU64::new(0));
        let cnt = Arc::new(HostU64::new(0));
        let mut handles = vec![prod];
        for m in 0..4u32 {
            let (s, sum, cnt) = (s.clone(), sum.clone(), cnt.clone());
            handles.push(std::thread::spawn(move || loop {
                match s.recv_as(m, decode) {
                    Ok(v) => {
                        sum.fetch_add(v, Ordering::Relaxed);
                        cnt.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        if cnt.load(Ordering::Relaxed) >= N {
                            break;
                        }
                        std::thread::yield_now();
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(cnt.load(Ordering::SeqCst), N, "steal storm lost or duplicated");
        assert_eq!(sum.load(Ordering::SeqCst), N * (N - 1) / 2, "checksum mismatch");
    }
}
