//! Bounded retry policy for the Table 1 `*_BUT_*` statuses.
//!
//! `BUFFER_FULL_BUT_CONSUMER_READING` / `BUFFER_EMPTY_BUT_PRODUCER_INSERTING`
//! mean the peer is *mid-operation*: the caller should retry immediately a
//! limited number of times with no delay. Plain `BUFFER_FULL`/`BUFFER_EMPTY`
//! mean the caller should yield the processor and retry later, perhaps
//! after a delay.

use super::mem::World;

/// Retry-budget tracker for one operation attempt sequence.
pub struct Backoff<W: World> {
    immediate_left: u32,
    yields: u32,
    _world: std::marker::PhantomData<W>,
}

/// Default bound on immediate (spinning) retries, per Table 1's "limited
/// number of times". Ablated by `micro_lockfree --ablate-retry`.
pub const DEFAULT_IMMEDIATE_RETRIES: u32 = 8;

impl<W: World> Backoff<W> {
    /// Fresh budget with the default immediate-retry bound.
    pub fn new() -> Self {
        Self::with_limit(DEFAULT_IMMEDIATE_RETRIES)
    }

    /// Fresh budget with an explicit immediate-retry bound.
    pub fn with_limit(limit: u32) -> Self {
        Backoff { immediate_left: limit, yields: 0, _world: std::marker::PhantomData }
    }

    /// Peer is mid-operation: spin once if budget remains. Returns false
    /// when the immediate budget is exhausted (caller should yield).
    pub fn immediate(&mut self) -> bool {
        if self.immediate_left == 0 {
            return false;
        }
        self.immediate_left -= 1;
        W::spin_hint();
        true
    }

    /// Buffer genuinely full/empty: yield the processor and retry.
    pub fn yield_now(&mut self) {
        self.yields += 1;
        W::yield_now();
        // A yield resets the immediate budget: conditions changed.
        self.immediate_left = DEFAULT_IMMEDIATE_RETRIES;
    }

    /// Number of yields performed (metric for the stress reports).
    pub fn yields(&self) -> u32 {
        self.yields
    }
}

impl<W: World> Default for Backoff<W> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;

    #[test]
    fn immediate_budget_is_bounded() {
        let mut b = Backoff::<RealWorld>::with_limit(3);
        assert!(b.immediate());
        assert!(b.immediate());
        assert!(b.immediate());
        assert!(!b.immediate());
        assert!(!b.immediate());
    }

    #[test]
    fn yield_resets_immediate_budget() {
        let mut b = Backoff::<RealWorld>::with_limit(1);
        assert!(b.immediate());
        assert!(!b.immediate());
        b.yield_now();
        assert!(b.immediate());
        assert_eq!(b.yields(), 1);
    }

    #[test]
    fn zero_limit_never_spins() {
        let mut b = Backoff::<RealWorld>::with_limit(0);
        assert!(!b.immediate());
    }

    #[test]
    fn prop_immediate_budget_is_exact_and_escalation_sticky() {
        use crate::util::prop::check_res;
        check_res(
            "backoff_immediate_budget",
            64,
            |r| r.below(64) as u32,
            |&limit| {
                let mut b = Backoff::<RealWorld>::with_limit(limit);
                let mut spins = 0;
                while b.immediate() {
                    spins += 1;
                    if spins > limit {
                        return Err(format!("spun {spins} times on a budget of {limit}"));
                    }
                }
                if spins != limit {
                    return Err(format!("budget {limit} allowed only {spins} spins"));
                }
                // Exhaustion is sticky until a yield...
                if b.immediate() {
                    return Err("immediate() true after exhaustion".into());
                }
                // ...and a yield restores the full default budget.
                b.yield_now();
                for _ in 0..DEFAULT_IMMEDIATE_RETRIES {
                    if !b.immediate() {
                        return Err("yield did not reset the immediate budget".into());
                    }
                }
                if b.immediate() {
                    return Err("reset budget exceeded the default bound".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_retry_sequence_terminates_under_peer_stall() {
        use crate::util::prop::check_res;
        // Model of a Table 1 `*_BUT_*` retry sequence against a peer
        // stalled mid-operation for `stall` scheduler grants: immediate
        // spins never advance the stalled peer, yields do (the peer gets
        // the processor). The sequence must terminate in bounded steps
        // with exactly one yield per grant.
        check_res(
            "backoff_terminates_under_stall",
            128,
            |r| (r.range(1, 200), r.below(16) as u32),
            |&(stall, limit)| {
                let mut b = Backoff::<RealWorld>::with_limit(limit);
                let mut remaining = stall;
                let mut steps = 0u64;
                let bound = u64::from(limit) + stall * u64::from(DEFAULT_IMMEDIATE_RETRIES + 1);
                while remaining > 0 {
                    steps += 1;
                    if steps > bound {
                        return Err(format!("no progress after {steps} steps (bound {bound})"));
                    }
                    if b.immediate() {
                        continue; // spin burns budget only
                    }
                    b.yield_now();
                    remaining -= 1;
                }
                if u64::from(b.yields()) != stall {
                    return Err(format!("{} yields for {stall} grants", b.yields()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn stalled_peer_escalates_immediate_to_yield_in_sim() {
        use crate::lockfree::ring::{ChannelRing, RecvError};
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{faults::FaultPlan, Machine, MachineCfg, SimWorld};
        use std::sync::{Arc, Mutex};
        // Stall the producer at every point inside its send window; the
        // consumer retries per Table 1 — spin while the peer is observed
        // mid-insert, yield otherwise — and must always terminate with
        // the payload intact and with spinning bounded by the budget.
        let mut escalated = false;
        for stall_at in 0..12u64 {
            let m = Machine::new(MachineCfg::new(
                2,
                OsProfile::linux_rt(),
                AffinityMode::PinnedSpread,
            ));
            let r = Arc::new(ChannelRing::<SimWorld>::new(8, 32));
            let r1 = r.clone();
            let producer = m.spawn(move || {
                r1.send(b"payload").unwrap();
            });
            let out = Arc::new(Mutex::new((0u32, 0u32, false)));
            let (r2, out2) = (r.clone(), out.clone());
            let consumer = m.spawn(move || {
                let mut bo = Backoff::<SimWorld>::new();
                let mut peer_active = 0u32;
                let mut buf = [0u8; 32];
                let n = loop {
                    match r2.recv(&mut buf) {
                        Ok(n) => break n,
                        Err(RecvError::EmptyButProducerInserting) => {
                            peer_active += 1;
                            if !bo.immediate() {
                                bo.yield_now();
                            }
                        }
                        Err(RecvError::Empty) => bo.yield_now(),
                    }
                };
                *out2.lock().unwrap() = (bo.yields(), peer_active, &buf[..n] == b"payload");
            });
            m.set_faults(FaultPlan::new().stall(0, stall_at, 200_000));
            m.run(vec![producer, consumer]);
            let (yields, peer_active, got) = *out.lock().unwrap();
            assert!(got, "stall@{stall_at}: payload must arrive intact");
            if peer_active > DEFAULT_IMMEDIATE_RETRIES {
                assert!(yields > 0, "stall@{stall_at}: spinning past the budget must yield");
            }
            escalated |= yields > 0;
        }
        assert!(escalated, "no stall point forced an immediate->yield escalation");
    }
}
