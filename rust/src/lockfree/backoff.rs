//! Bounded retry policy for the Table 1 `*_BUT_*` statuses.
//!
//! `BUFFER_FULL_BUT_CONSUMER_READING` / `BUFFER_EMPTY_BUT_PRODUCER_INSERTING`
//! mean the peer is *mid-operation*: the caller should retry immediately a
//! limited number of times with no delay. Plain `BUFFER_FULL`/`BUFFER_EMPTY`
//! mean the caller should yield the processor and retry later, perhaps
//! after a delay.

use super::mem::World;

/// Retry-budget tracker for one operation attempt sequence.
pub struct Backoff<W: World> {
    immediate_left: u32,
    yields: u32,
    _world: std::marker::PhantomData<W>,
}

/// Default bound on immediate (spinning) retries, per Table 1's "limited
/// number of times". Ablated by `micro_lockfree --ablate-retry`.
pub const DEFAULT_IMMEDIATE_RETRIES: u32 = 8;

impl<W: World> Backoff<W> {
    /// Fresh budget with the default immediate-retry bound.
    pub fn new() -> Self {
        Self::with_limit(DEFAULT_IMMEDIATE_RETRIES)
    }

    /// Fresh budget with an explicit immediate-retry bound.
    pub fn with_limit(limit: u32) -> Self {
        Backoff { immediate_left: limit, yields: 0, _world: std::marker::PhantomData }
    }

    /// Peer is mid-operation: spin once if budget remains. Returns false
    /// when the immediate budget is exhausted (caller should yield).
    pub fn immediate(&mut self) -> bool {
        if self.immediate_left == 0 {
            return false;
        }
        self.immediate_left -= 1;
        W::spin_hint();
        true
    }

    /// Buffer genuinely full/empty: yield the processor and retry.
    pub fn yield_now(&mut self) {
        self.yields += 1;
        W::yield_now();
        // A yield resets the immediate budget: conditions changed.
        self.immediate_left = DEFAULT_IMMEDIATE_RETRIES;
    }

    /// Number of yields performed (metric for the stress reports).
    pub fn yields(&self) -> u32 {
        self.yields
    }
}

impl<W: World> Default for Backoff<W> {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;

    #[test]
    fn immediate_budget_is_bounded() {
        let mut b = Backoff::<RealWorld>::with_limit(3);
        assert!(b.immediate());
        assert!(b.immediate());
        assert!(b.immediate());
        assert!(!b.immediate());
        assert!(!b.immediate());
    }

    #[test]
    fn yield_resets_immediate_budget() {
        let mut b = Backoff::<RealWorld>::with_limit(1);
        assert!(b.immediate());
        assert!(!b.immediate());
        b.yield_now();
        assert!(b.immediate());
        assert_eq!(b.yields(), 1);
    }

    #[test]
    fn zero_limit_never_spins() {
        let mut b = Backoff::<RealWorld>::with_limit(0);
        assert!(!b.immediate());
    }
}
