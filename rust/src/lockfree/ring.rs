//! Connected-channel SPSC ring: payload-carrying slots on the NBB
//! counter protocol — the zero-copy fast path for packet and scalar
//! channels.
//!
//! The generic MCAPI receive path ([`crate::mcapi::queue::LockFreeQueue`])
//! moves a 24-byte [`crate::mcapi::queue::Entry`] through an NBB lane and
//! keeps the payload in the shared buffer pool: every packet pays a pool
//! lease (Treiber pop), two Figure 4 FSM round-trips, the queue transfer,
//! the pool read, and a pool release (Treiber push) — plus an abort path
//! when the queue is full after the lease was taken. That design is what
//! connection-*less* messaging needs (any sender, any priority), but an
//! MCAPI **connected channel** is a point-to-point FIFO with exactly one
//! producer and one consumer, so the queue structure can be dedicated to
//! the link topology (the Virtual-Link argument, arXiv:2012.05181): one
//! SPSC ring whose slots hold the payload bytes themselves.
//!
//! * Packet bytes / scalars are written **directly into the slot** —
//!   no shared pool lease, no lease-abort failure path, no buffer-pool
//!   coherence traffic, and one fewer payload hop per packet.
//! * The counters use the exact NBB protocol from [`super::nbb`]
//!   (odd = operation in progress, Table 1 `*_BUT_*` statuses) with the
//!   PR 1 coherence fixes: [`CachePadded`] counter lines and cached peer
//!   counters, so the steady-state hot path performs **one cross-core
//!   counter load per ring wrap** and zero shared loads otherwise.
//! * [`ChannelRing::send_batch`] / [`ChannelRing::recv_batch`] amortize
//!   the enter/exit counter stores over N payloads: a batch of N sends
//!   issues O(1) shared-counter stores (two, to one line).
//! * [`ChannelRing::recv_with`] consumes a payload **in place** (the
//!   closure sees the slot bytes; nothing is copied until the caller
//!   decides to), which is what makes the receive side zero-copy.
//!
//! The MCAPI runtime mounts one ring per connected channel
//! (`mcapi::channel`); the connection-less message path keeps the generic
//! queue, and the `Locked` backend keeps the reference pool path so the
//! paper's comparison survives.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, Ordering};

use super::mem::{Atom64, CachePadded, World};
use super::nbb::{BatchStatus, InsertStatus, SideCache};
use crate::obs;
use crate::obs::EventKind;

/// Why a ring receive returned nothing — Kim's Table 1 read statuses
/// with the payload-carrying variant stripped (payloads are consumed in
/// place, not returned by value).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecvError {
    /// Ring empty; caller should yield the processor and retry.
    Empty,
    /// Ring empty but the producer is mid-insert: retry immediately,
    /// bounded (Table 1 `*_BUT_*`).
    EmptyButProducerInserting,
}

/// Why a width-checked scalar batch receive appended nothing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalarBatchError {
    /// Ring empty; caller should yield the processor and retry.
    Empty,
    /// Ring empty but the producer is mid-insert: retry immediately.
    EmptyButProducerInserting,
    /// The next scalar's width differed from the expected width; it was
    /// consumed and dropped (the MCAPI `MCAPI_ERR_SCL_SIZE` condition).
    SizeMismatch,
}

/// Single-producer single-consumer ring whose slots carry the payload:
/// up to `slot_len` packet bytes, or an MCAPI scalar (the per-slot length
/// word doubles as the scalar width).
///
/// The producer side is [`ChannelRing::send`] / [`ChannelRing::send_scalar`]
/// and their batch forms; the consumer side is [`ChannelRing::recv_with`] /
/// [`ChannelRing::recv`] / [`ChannelRing::recv_scalar`] and batch forms.
/// Only one thread may drive each side concurrently (SPSC contract).
pub struct ChannelRing<W: World> {
    /// Writer counter — producer-owned line.
    update: CachePadded<W::U64>,
    /// Reader counter — consumer-owned line.
    ack: CachePadded<W::U64>,
    /// Producer-private mirrors (own = `update`, peer = `ack` snapshot).
    prod: CachePadded<SideCache>,
    /// Consumer-private mirrors (own = `ack`, peer = `update` snapshot).
    cons: CachePadded<SideCache>,
    /// Per-slot payload length in bytes; for scalar slots this is the
    /// MCAPI scalar width (1/2/4/8).
    lens: Box<[UnsafeCell<u32>]>,
    /// Slot payload bytes: `cap * slot_len`, contiguous.
    bytes: Box<[UnsafeCell<u8>]>,
    /// Synthetic per-slot region (length word + payload) for simulator
    /// cost accounting.
    regions: Box<[u64]>,
    slot_len: usize,
    cap: u64,
    /// Observability channel id for trace events ([`obs::CH_NONE`] when
    /// unmounted). Host atomic: set once at channel connect, read with a
    /// relaxed load only when tracing is enabled — never priced.
    trace_id: AtomicU32,
}

unsafe impl<W: World> Send for ChannelRing<W> {}
unsafe impl<W: World> Sync for ChannelRing<W> {}

impl<W: World> ChannelRing<W> {
    /// Ring with `cap` slots of `slot_len` payload bytes each
    /// (`cap >= 1`, `slot_len >= 8` so a 64-bit scalar always fits).
    pub fn new(cap: usize, slot_len: usize) -> Self {
        assert!(cap >= 1, "ring capacity must be >= 1");
        assert!(slot_len >= 8, "ring slot must fit a 64-bit scalar");
        let lens = (0..cap).map(|_| UnsafeCell::new(0u32)).collect::<Vec<_>>();
        let bytes = (0..cap * slot_len)
            .map(|_| UnsafeCell::new(0u8))
            .collect::<Vec<_>>();
        let regions = (0..cap).map(|_| W::alloc_region(4 + slot_len)).collect::<Vec<_>>();
        ChannelRing {
            update: CachePadded::new(W::U64::new(0)),
            ack: CachePadded::new(W::U64::new(0)),
            prod: CachePadded::new(SideCache::new()),
            cons: CachePadded::new(SideCache::new()),
            lens: lens.into_boxed_slice(),
            bytes: bytes.into_boxed_slice(),
            regions: regions.into_boxed_slice(),
            slot_len,
            cap: cap as u64,
            trace_id: AtomicU32::new(obs::CH_NONE),
        }
    }

    /// Tag this ring with its channel id for trace events (called when
    /// the MCAPI runtime mounts the ring on a connected channel).
    pub fn set_trace_id(&self, id: u32) {
        self.trace_id.store(id, Ordering::Relaxed);
    }

    /// The channel id trace events carry ([`obs::CH_NONE`] = unmounted).
    pub fn trace_id(&self) -> u32 {
        self.trace_id.load(Ordering::Relaxed)
    }

    /// Ring capacity in slots.
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Payload bytes per slot (the channel's maximum packet size).
    pub fn slot_len(&self) -> usize {
        self.slot_len
    }

    /// Payloads currently buffered (approximate under concurrency;
    /// monitoring only, hence relaxed).
    pub fn len(&self) -> usize {
        let u = self.update.load_relaxed() / 2;
        let a = self.ack.load_relaxed() / 2;
        u.wrapping_sub(a) as usize
    }

    /// True when no payloads are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write `data` into slot `idx` with length word `len_word`
    /// (producer side, inside the odd counter window; callers have
    /// already validated `data` against `slot_len`).
    fn write_slot(&self, idx: usize, data: &[u8], len_word: u32) {
        debug_assert!(data.len() <= self.slot_len, "payload exceeds ring slot");
        W::touch(self.regions[idx], 4 + data.len().max(1), true);
        unsafe {
            *self.lens[idx].get() = len_word;
            std::ptr::copy_nonoverlapping(
                data.as_ptr(),
                self.bytes[idx * self.slot_len].get(),
                data.len(),
            );
        }
    }

    /// Producer-side free-slot count, re-loading the consumer's counter
    /// only when the cached snapshot says full (the single cross-core
    /// load per ring wrap). `Err` carries the Table 1 distinction.
    fn free_slots(&self, u: u64) -> Result<u64, BatchStatus> {
        let mut a = self.prod.peer.get();
        let mut free = self.cap - (u / 2).wrapping_sub(a / 2);
        if free == 0 {
            a = self.ack.load();
            self.prod.peer.set(a);
            free = self.cap - (u / 2).wrapping_sub(a / 2);
            if free == 0 {
                return Err(if a & 1 == 1 {
                    BatchStatus::PeerActive
                } else {
                    BatchStatus::WouldBlock
                });
            }
        }
        Ok(free)
    }

    /// Producer side: copy `data` into the next slot. On failure nothing
    /// is written and the Table 1 status says how to retry.
    ///
    /// # Panics
    /// If `data` exceeds `slot_len` — like [`crate::mrapi::shmem::
    /// Partition::write`], an oversized payload is a caller bug (the
    /// MCAPI runtime maps oversize to `MessageLimit` before calling).
    pub fn send(&self, data: &[u8]) -> Result<(), InsertStatus> {
        assert!(data.len() <= self.slot_len, "payload exceeds ring slot");
        let u = self.prod.own.get();
        if let Err(status) = self.free_slots(u) {
            return Err(match status {
                BatchStatus::PeerActive => InsertStatus::FullButConsumerReading,
                BatchStatus::WouldBlock => InsertStatus::Full,
            });
        }
        self.update.store(u + 1); // enter: odd = insert in progress
        let idx = ((u / 2) % self.cap) as usize;
        self.write_slot(idx, data, data.len() as u32);
        self.update.store(u + 2); // exit: publish
        self.prod.own.set(u + 2);
        if obs::tracing() {
            obs::emit::<W>(EventKind::SendCommit, self.trace_id(), u / 2, data.len() as u32);
            obs::bump(obs::ctr::RING_SEND);
        }
        Ok(())
    }

    /// Producer side: enqueue a prefix of `payloads`, amortizing the
    /// enter/exit counter stores over the whole prefix — a batch of N
    /// sends issues exactly two shared-counter stores. Returns how many
    /// payloads went in; `Err` only when the ring had room for none.
    ///
    /// # Panics
    /// If any payload exceeds `slot_len` (checked up front, before the
    /// counter window opens; see [`ChannelRing::send`]).
    pub fn send_batch(&self, payloads: &[&[u8]]) -> Result<usize, BatchStatus> {
        if payloads.is_empty() {
            return Ok(0);
        }
        assert!(
            payloads.iter().all(|d| d.len() <= self.slot_len),
            "payload exceeds ring slot"
        );
        let u = self.prod.own.get();
        let free = self.free_slots(u)?;
        let k = (free as usize).min(payloads.len());
        self.update.store(u + 1); // enter once: odd across the whole batch
        for (i, data) in payloads[..k].iter().enumerate() {
            let idx = ((u / 2 + i as u64) % self.cap) as usize;
            self.write_slot(idx, data, data.len() as u32);
        }
        let u2 = u + 2 * k as u64;
        self.update.store(u2); // exit: publishes all k payloads at once
        self.prod.own.set(u2);
        if obs::tracing() {
            for (i, data) in payloads[..k].iter().enumerate() {
                obs::emit::<W>(
                    EventKind::SendCommit,
                    self.trace_id(),
                    u / 2 + i as u64,
                    data.len() as u32,
                );
            }
            obs::add(obs::ctr::RING_SEND, k as u64);
        }
        Ok(k)
    }

    /// Producer side: enqueue a scalar of `width` bytes (1/2/4/8 per the
    /// MCAPI scalar sizes). The width travels in the slot's length word
    /// so the receive side can reject width mismatches.
    pub fn send_scalar(&self, value: u64, width: u32) -> Result<(), InsertStatus> {
        debug_assert!(matches!(width, 1 | 2 | 4 | 8), "bad scalar width {width}");
        self.send(&value.to_le_bytes()[..width as usize])
    }

    /// Producer side: enqueue a prefix of `values` as `width`-byte
    /// scalars with one enter/exit counter-store pair (O(1) shared
    /// stores for the whole batch). Returns how many went in.
    pub fn send_scalars(&self, values: &[u64], width: u32) -> Result<usize, BatchStatus> {
        debug_assert!(matches!(width, 1 | 2 | 4 | 8), "bad scalar width {width}");
        if values.is_empty() {
            return Ok(0);
        }
        let u = self.prod.own.get();
        let free = self.free_slots(u)?;
        let k = (free as usize).min(values.len());
        self.update.store(u + 1); // enter once
        for (i, v) in values[..k].iter().enumerate() {
            let idx = ((u / 2 + i as u64) % self.cap) as usize;
            self.write_slot(idx, &v.to_le_bytes()[..width as usize], width);
        }
        let u2 = u + 2 * k as u64;
        self.update.store(u2); // exit
        self.prod.own.set(u2);
        if obs::tracing() {
            for i in 0..k as u64 {
                obs::emit::<W>(EventKind::SendCommit, self.trace_id(), u / 2 + i, width);
            }
            obs::add(obs::ctr::RING_SEND, k as u64);
        }
        Ok(k)
    }

    /// Consumer-side available count, re-loading the producer's counter
    /// only when the cached snapshot says empty.
    fn avail_slots(&self, a: u64) -> Result<u64, RecvError> {
        let mut u = self.cons.peer.get();
        let mut avail = (u / 2).wrapping_sub(a / 2);
        if avail == 0 {
            u = self.update.load();
            self.cons.peer.set(u);
            avail = (u / 2).wrapping_sub(a / 2);
            if avail == 0 {
                return Err(if u & 1 == 1 {
                    RecvError::EmptyButProducerInserting
                } else {
                    RecvError::Empty
                });
            }
        }
        Ok(avail)
    }

    /// Slot `idx` as a byte slice of its recorded length (consumer side,
    /// inside the odd counter window).
    ///
    /// # Safety
    /// Caller must hold the consumer's odd-counter window for `idx`.
    unsafe fn slot_bytes(&self, idx: usize) -> &[u8] {
        let len = (*self.lens[idx].get() as usize).min(self.slot_len);
        W::touch(self.regions[idx], 4 + len.max(1), false);
        std::slice::from_raw_parts(self.bytes[idx * self.slot_len].get() as *const u8, len)
    }

    /// Consumer side: consume the next payload **in place** — `f` sees
    /// the slot bytes directly; nothing is copied unless `f` copies.
    pub fn recv_with<R>(&self, f: impl FnOnce(&[u8]) -> R) -> Result<R, RecvError> {
        let a = self.cons.own.get();
        self.avail_slots(a)?;
        // Wakeup mark: the consumer has *observed* the payload as
        // available — the doorbell→wakeup stage ends here.
        if obs::tracing() {
            obs::emit::<W>(EventKind::Wakeup, self.trace_id(), a / 2, 0);
        }
        self.ack.store(a + 1); // enter: odd = read in progress
        let idx = ((a / 2) % self.cap) as usize;
        let b = unsafe { self.slot_bytes(idx) };
        let blen = b.len() as u32;
        let r = f(b);
        self.ack.store(a + 2); // exit: acknowledge
        self.cons.own.set(a + 2);
        if obs::tracing() {
            obs::emit::<W>(EventKind::RecvReturn, self.trace_id(), a / 2, blen);
            obs::bump(obs::ctr::RING_RECV);
        }
        Ok(r)
    }

    /// Consumer side: copy the next payload into `out`; returns the byte
    /// count copied (`min(payload len, out.len())`).
    pub fn recv(&self, out: &mut [u8]) -> Result<usize, RecvError> {
        self.recv_with(|b| {
            let n = b.len().min(out.len());
            out[..n].copy_from_slice(&b[..n]);
            n
        })
    }

    /// Consumer side: dequeue the next scalar; returns `(value, width)`
    /// with the value zero-extended from its stored width.
    pub fn recv_scalar(&self) -> Result<(u64, u32), RecvError> {
        self.recv_with(|b| {
            let n = b.len().min(8);
            let mut le = [0u8; 8];
            le[..n].copy_from_slice(&b[..n]);
            (u64::from_le_bytes(le), n as u32)
        })
    }

    /// Consumer side: drain up to `max` payloads into `out` (one `Vec`
    /// per payload, FIFO order), amortizing the enter/exit counter
    /// stores. Returns how many were appended; `Err` when none were.
    pub fn recv_batch(&self, out: &mut Vec<Vec<u8>>, max: usize) -> Result<usize, BatchStatus> {
        if max == 0 {
            return Ok(0);
        }
        let a = self.cons.own.get();
        let avail = self.avail_slots(a).map_err(|e| match e {
            RecvError::EmptyButProducerInserting => BatchStatus::PeerActive,
            RecvError::Empty => BatchStatus::WouldBlock,
        })?;
        let k = (avail as usize).min(max);
        if obs::tracing() {
            for i in 0..k as u64 {
                obs::emit::<W>(EventKind::Wakeup, self.trace_id(), a / 2 + i, 0);
            }
        }
        self.ack.store(a + 1); // enter once
        for i in 0..k as u64 {
            let idx = ((a / 2 + i) % self.cap) as usize;
            out.push(unsafe { self.slot_bytes(idx) }.to_vec());
        }
        let a2 = a + 2 * k as u64;
        self.ack.store(a2); // exit: acknowledges all k payloads at once
        self.cons.own.set(a2);
        if obs::tracing() {
            for i in 0..k as u64 {
                let len = out[out.len() - k + i as usize].len() as u32;
                obs::emit::<W>(EventKind::RecvReturn, self.trace_id(), a / 2 + i, len);
            }
            obs::add(obs::ctr::RING_RECV, k as u64);
        }
        Ok(k)
    }

    /// Consumer side: drain up to `max` scalars of the expected `width`
    /// into `out`, amortizing the enter/exit counter stores. A scalar of
    /// a *different* width stops the batch: it is consumed and dropped
    /// (the MCAPI `MCAPI_ERR_SCL_SIZE` contract, mirroring the locked
    /// reference loop) — reported as `SizeMismatch` only when nothing
    /// was appended. Returns how many matching scalars were appended.
    pub fn recv_scalars(
        &self,
        out: &mut Vec<u64>,
        max: usize,
        width: u32,
    ) -> Result<usize, ScalarBatchError> {
        if max == 0 {
            return Ok(0);
        }
        let a = self.cons.own.get();
        let avail = self.avail_slots(a).map_err(|e| match e {
            RecvError::EmptyButProducerInserting => ScalarBatchError::EmptyButProducerInserting,
            RecvError::Empty => ScalarBatchError::Empty,
        })?;
        let k = (avail as usize).min(max);
        self.ack.store(a + 1); // enter once
        let mut consumed = 0u64;
        let mut matched = 0usize;
        let mut mismatched = false;
        for i in 0..k as u64 {
            let idx = ((a / 2 + i) % self.cap) as usize;
            let b = unsafe { self.slot_bytes(idx) };
            consumed += 1;
            if b.len() as u32 != width {
                mismatched = true;
                break; // consume the offender, deliver nothing past it
            }
            let n = b.len().min(8);
            let mut le = [0u8; 8];
            le[..n].copy_from_slice(&b[..n]);
            out.push(u64::from_le_bytes(le));
            matched += 1;
        }
        let a2 = a + 2 * consumed;
        self.ack.store(a2); // exit: acknowledges everything consumed
        self.cons.own.set(a2);
        if obs::tracing() {
            // One Wakeup+RecvReturn pair per consumed slot (a dropped
            // width-mismatch still consumed its sequence number — the
            // trace must account for it or replay flags a false gap).
            for i in 0..consumed {
                obs::emit::<W>(EventKind::Wakeup, self.trace_id(), a / 2 + i, 0);
                obs::emit::<W>(EventKind::RecvReturn, self.trace_id(), a / 2 + i, width);
            }
            obs::add(obs::ctr::RING_RECV, consumed);
        }
        if matched == 0 && mismatched {
            return Err(ScalarBatchError::SizeMismatch);
        }
        Ok(matched)
    }

    /// Consume and discard everything buffered; returns the number of
    /// discarded payloads. Reconnect hygiene: a reused channel slot must
    /// not deliver a previous connection's residue. Consumer side only
    /// (callers synchronize the hand-off through the channel FSM).
    pub fn drain(&self) -> usize {
        let mut n = 0;
        while self.recv_with(|_| ()).is_ok() {
            n += 1;
        }
        n
    }

    // -- crash repair ------------------------------------------------------
    //
    // A task killed inside its odd counter window leaves `update` (or
    // `ack`) parked at 2k+1 forever: the peer's `*_BUT_*` retry loop
    // would never terminate. Because each counter is single-owner, the
    // repair is a rollback, not a completion: 2k+1 -> 2k discards the
    // torn in-flight operation (an insert that never published / a read
    // that never acknowledged) while every *committed* payload keeps its
    // exact position — occupancy arithmetic uses `counter / 2`, so any
    // cached odd snapshot held by the surviving side computes the same
    // value as the repaired even one. The own-side mirror is resynced
    // unconditionally, covering a death between the exit store and the
    // mirror update.
    //
    // Callers must guarantee the dead side really is dead (these methods
    // *become* that side of the SPSC contract).

    /// Repair after the **producer** died: discard a torn in-flight
    /// insert and resync the producer mirror, so a future reconnect can
    /// reuse the side. Returns `true` when a torn insert was discarded.
    pub fn repair_dead_producer(&self) -> bool {
        let u = self.update.load();
        let torn = u & 1 == 1;
        if torn {
            self.update.store(u - 1);
        }
        self.prod.own.set(u & !1);
        torn
    }

    /// Repair after the **consumer** died: roll back a torn in-flight
    /// read (the unacknowledged payload was never delivered, so it
    /// becomes readable again — no loss, and no duplicate because the
    /// dead reader never returned it) and resync the consumer mirror.
    /// Returns `true` when a torn read was rolled back.
    pub fn repair_dead_consumer(&self) -> bool {
        let a = self.ack.load();
        let torn = a & 1 == 1;
        if torn {
            self.ack.store(a - 1);
        }
        self.cons.own.set(a & !1);
        torn
    }

    /// Raw `(update, ack)` counter values via [`Atom64::peek`] — unpriced,
    /// for post-run invariant checks only (committed inserts are
    /// `update / 2`, acknowledged reads `ack / 2`).
    pub fn counters_peek(&self) -> (u64, u64) {
        (self.update.peek(), self.ack.peek())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::{Arc, Mutex};

    type RRing = ChannelRing<RealWorld>;

    #[test]
    fn packet_fifo_and_full_status() {
        let r = RRing::new(2, 32);
        r.send(b"one").unwrap();
        r.send(b"two!").unwrap();
        assert_eq!(r.send(b"three"), Err(InsertStatus::Full));
        let mut buf = [0u8; 32];
        assert_eq!(r.recv(&mut buf), Ok(3));
        assert_eq!(&buf[..3], b"one");
        assert_eq!(r.recv(&mut buf), Ok(4));
        assert_eq!(&buf[..4], b"two!");
        assert_eq!(r.recv(&mut buf), Err(RecvError::Empty));
    }

    #[test]
    fn stale_full_snapshot_refreshes_on_send() {
        // Fill (producer's cached ack goes stale at "no room"), drain,
        // then send again: the re-load must notice the drain at once.
        let r = RRing::new(2, 16);
        r.send(b"a").unwrap();
        r.send(b"b").unwrap();
        assert!(r.send(b"c").is_err(), "ring is full");
        let mut buf = [0u8; 16];
        assert_eq!(r.recv(&mut buf), Ok(1));
        assert_eq!(r.recv(&mut buf), Ok(1));
        assert!(r.send(b"d").is_ok(), "stale cached ack must refresh");
        assert_eq!(r.recv(&mut buf), Ok(1));
        assert_eq!(&buf[..1], b"d");
    }

    #[test]
    fn wraparound_many_times() {
        let r = RRing::new(3, 16);
        let mut buf = [0u8; 16];
        for round in 0..100u64 {
            r.send(&round.to_le_bytes()).unwrap();
            assert_eq!(r.recv(&mut buf), Ok(8));
            assert_eq!(u64::from_le_bytes(buf[..8].try_into().unwrap()), round);
        }
    }

    #[test]
    fn recv_with_sees_slot_bytes_in_place() {
        let r = RRing::new(4, 16);
        r.send(b"zero-copy").unwrap();
        let len = r.recv_with(|b| {
            assert_eq!(b, b"zero-copy");
            b.len()
        });
        assert_eq!(len, Ok(9));
        assert_eq!(r.recv_with(|_| ()), Err(RecvError::Empty));
    }

    #[test]
    fn scalar_widths_roundtrip_and_zero_extend() {
        let r = RRing::new(8, 16);
        r.send_scalar(0xAB, 1).unwrap();
        r.send_scalar(0xBEEF, 2).unwrap();
        r.send_scalar(0xDEAD_BEEF, 4).unwrap();
        r.send_scalar(0xFEED_F00D_DEAD_BEEF, 8).unwrap();
        assert_eq!(r.recv_scalar(), Ok((0xAB, 1)));
        assert_eq!(r.recv_scalar(), Ok((0xBEEF, 2)));
        assert_eq!(r.recv_scalar(), Ok((0xDEAD_BEEF, 4)));
        assert_eq!(r.recv_scalar(), Ok((0xFEED_F00D_DEAD_BEEF, 8)));
        // Narrow widths truncate to their size on the wire.
        r.send_scalar(0x1FF, 1).unwrap();
        assert_eq!(r.recv_scalar(), Ok((0xFF, 1)));
    }

    #[test]
    fn batch_roundtrip_and_partial_send() {
        let r = RRing::new(4, 16);
        let payloads: Vec<Vec<u8>> = (0u8..6).map(|i| vec![i; (i + 1) as usize]).collect();
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        // Only 4 fit.
        assert_eq!(r.send_batch(&refs), Ok(4));
        assert_eq!(r.send_batch(&refs[4..]), Err(BatchStatus::WouldBlock));
        let mut out = Vec::new();
        assert_eq!(r.recv_batch(&mut out, 3), Ok(3));
        assert_eq!(r.recv_batch(&mut out, 8), Ok(1));
        assert_eq!(out, payloads[..4].to_vec());
        assert_eq!(r.recv_batch(&mut out, 8), Err(BatchStatus::WouldBlock));
        // Leftovers go in now that the ring drained.
        assert_eq!(r.send_batch(&refs[4..]), Ok(2));
        out.clear();
        assert_eq!(r.recv_batch(&mut out, 8), Ok(2));
        assert_eq!(out, payloads[4..].to_vec());
    }

    #[test]
    fn scalar_batch_roundtrip() {
        let r = RRing::new(8, 16);
        let vals: Vec<u64> = (10..16).collect();
        assert_eq!(r.send_scalars(&vals, 8), Ok(6));
        let mut out = Vec::new();
        assert_eq!(r.recv_scalars(&mut out, 4, 8), Ok(4));
        assert_eq!(r.recv_scalars(&mut out, 4, 8), Ok(2));
        assert_eq!(out, vals);
        assert_eq!(r.recv_scalars(&mut out, 1, 8), Err(ScalarBatchError::Empty));
    }

    #[test]
    fn scalar_batch_width_mismatch_consumes_and_stops() {
        let r = RRing::new(8, 16);
        r.send_scalar(1, 8).unwrap();
        r.send_scalar(2, 1).unwrap(); // wrong width for a 64-bit drain
        r.send_scalar(3, 8).unwrap();
        let mut out = Vec::new();
        // Batch stops at (and consumes) the mismatched scalar; the match
        // before it is still delivered.
        assert_eq!(r.recv_scalars(&mut out, 8, 8), Ok(1));
        assert_eq!(out, vec![1]);
        // The scalar after the offender is intact.
        assert_eq!(r.recv_scalars(&mut out, 8, 8), Ok(1));
        assert_eq!(out, vec![1, 3]);
        // A leading mismatch reports SizeMismatch and is consumed.
        r.send_scalar(4, 2).unwrap();
        assert_eq!(
            r.recv_scalars(&mut out, 8, 8),
            Err(ScalarBatchError::SizeMismatch)
        );
        assert_eq!(r.recv_scalars(&mut out, 8, 8), Err(ScalarBatchError::Empty));
    }

    #[test]
    fn empty_batch_calls_are_noops() {
        let r = RRing::new(2, 16);
        assert_eq!(r.send_batch(&[]), Ok(0));
        assert_eq!(r.send_scalars(&[], 8), Ok(0));
        let mut out = Vec::new();
        assert_eq!(r.recv_batch(&mut out, 0), Ok(0));
        let mut vals = Vec::new();
        assert_eq!(r.recv_scalars(&mut vals, 0, 8), Ok(0));
        assert!(out.is_empty() && vals.is_empty());
    }

    #[test]
    fn capacity_one_alternates() {
        let r = RRing::new(1, 16);
        r.send(b"x").unwrap();
        assert_eq!(r.send(b"y"), Err(InsertStatus::Full));
        let mut buf = [0u8; 16];
        assert_eq!(r.recv(&mut buf), Ok(1));
        assert!(r.send(b"y").is_ok());
    }

    #[test]
    fn drain_discards_residue() {
        let r = RRing::new(4, 16);
        r.send(b"stale1").unwrap();
        r.send_scalar(7, 8).unwrap();
        assert_eq!(r.drain(), 2);
        assert!(r.is_empty());
        assert_eq!(r.recv_with(|_| ()), Err(RecvError::Empty));
        // The ring stays usable after a drain.
        r.send(b"fresh").unwrap();
        let mut buf = [0u8; 16];
        assert_eq!(r.recv(&mut buf), Ok(5));
        assert_eq!(&buf[..5], b"fresh");
    }

    #[test]
    fn short_out_buffer_truncates() {
        let r = RRing::new(2, 32);
        r.send(b"0123456789").unwrap();
        let mut buf = [0u8; 4];
        assert_eq!(r.recv(&mut buf), Ok(4));
        assert_eq!(&buf, b"0123");
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = RRing::new(0, 16);
    }

    #[test]
    #[should_panic(expected = "scalar")]
    fn tiny_slot_rejected() {
        let _ = RRing::new(4, 4);
    }

    #[test]
    fn spsc_stress_payloads_arrive_whole_and_in_order() {
        const N: u64 = 120_000;
        let r = Arc::new(RRing::new(32, 32));
        let producer = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut buf = [0u8; 24];
                for i in 0..N {
                    buf[..8].copy_from_slice(&i.to_le_bytes());
                    buf[8..16].copy_from_slice(&i.wrapping_mul(3).to_le_bytes());
                    buf[16..24].copy_from_slice(&(!i).to_le_bytes());
                    while r.send(&buf).is_err() {
                        std::hint::spin_loop();
                    }
                }
            })
        };
        let mut expected = 0u64;
        while expected < N {
            let got = r.recv_with(|b| {
                assert_eq!(b.len(), 24, "torn length");
                let a = u64::from_le_bytes(b[..8].try_into().unwrap());
                let m = u64::from_le_bytes(b[8..16].try_into().unwrap());
                let c = u64::from_le_bytes(b[16..24].try_into().unwrap());
                assert_eq!(m, a.wrapping_mul(3), "torn payload");
                assert_eq!(c, !a, "torn payload");
                a
            });
            if let Ok(a) = got {
                assert_eq!(a, expected, "ring FIFO violated");
                expected += 1;
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn spsc_batch_stress_mixed_sizes() {
        const N: u64 = 60_000;
        let r = Arc::new(RRing::new(16, 16));
        let producer = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut next = 0u64;
                let mut size = 1usize;
                while next < N {
                    let hi = (next + size as u64).min(N);
                    let vals: Vec<u64> = (next..hi).collect();
                    let mut sent = 0;
                    while sent < vals.len() {
                        match r.send_scalars(&vals[sent..], 8) {
                            Ok(n) => sent += n,
                            Err(_) => std::hint::spin_loop(),
                        }
                    }
                    next = hi;
                    size = size % 5 + 1;
                }
            })
        };
        let mut expected = 0u64;
        let mut out = Vec::new();
        while expected < N {
            out.clear();
            if r.recv_scalars(&mut out, 7, 8).is_ok() {
                for &v in &out {
                    assert_eq!(v, expected, "batch scalar FIFO violated");
                    expected += 1;
                }
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
        assert!(r.is_empty());
    }

    #[test]
    fn repair_on_clean_ring_is_a_noop() {
        let r = RRing::new(4, 16);
        r.send(b"a").unwrap();
        assert!(!r.repair_dead_producer(), "no torn insert to discard");
        assert!(!r.repair_dead_consumer(), "no torn read to roll back");
        let mut buf = [0u8; 16];
        assert_eq!(r.recv(&mut buf), Ok(1), "committed payload survives repair");
        let (u, a) = r.counters_peek();
        assert_eq!((u, a), (2, 2));
    }

    #[test]
    fn repair_discards_torn_insert_and_keeps_committed() {
        // Sweep every kill point inside a producer send: a sim task dies
        // at each priced op; repair must leave exactly the committed
        // prefix readable and the ring reusable.
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{faults::FaultPlan, Machine, MachineCfg, SimWorld};
        for kill_at in 0..24u64 {
            let m = Machine::new(MachineCfg::new(
                2,
                OsProfile::linux_rt(),
                AffinityMode::PinnedSpread,
            ));
            let r = Arc::new(ChannelRing::<SimWorld>::new(8, 32));
            let r1 = r.clone();
            let producer = m.spawn(move || {
                for i in 0..3u64 {
                    let _ = r1.send(&i.to_le_bytes());
                }
            });
            m.set_faults(FaultPlan::new().kill(0, kill_at));
            m.run(vec![producer]);
            // Post-mortem repair from outside the sim uses real atomics
            // via peek-consistent rollback — emulate a live recovery by
            // running it on a fresh one-task machine.
            let r2 = r.clone();
            let reports = Arc::new(Mutex::new((false, 0usize, Vec::new())));
            let rep2 = reports.clone();
            let m2 = Machine::new(MachineCfg::new(
                1,
                OsProfile::linux_rt(),
                AffinityMode::SingleCore,
            ));
            let h = m2.spawn(move || {
                let torn = r2.repair_dead_producer();
                let mut got = Vec::new();
                let mut buf = [0u8; 32];
                while let Ok(n) = r2.recv(&mut buf) {
                    got.push(u64::from_le_bytes(buf[..n.min(8)].try_into().unwrap()));
                }
                // Ring stays usable after repair.
                r2.send(b"post").unwrap();
                let reused = r2.recv(&mut buf) == Ok(4) && &buf[..4] == b"post";
                *rep2.lock().unwrap() = (torn, reused as usize, got);
            });
            m2.run(vec![h]);
            let (u, a) = r.counters_peek();
            assert_eq!(u % 2, 0, "kill@{kill_at}: repaired update must be even");
            assert_eq!(a % 2, 0, "kill@{kill_at}: ack must be even");
            assert_eq!(u, a, "kill@{kill_at}: everything committed was drained");
            let (_, reused, got) = &*reports.lock().unwrap();
            assert_eq!(*reused, 1, "kill@{kill_at}: ring must be reusable");
            // Exactly the committed prefix, in order — no loss, no
            // duplicates, no tears. (u/2 counts the post-repair probe
            // send too, hence the -1.)
            let committed: Vec<u64> = (0..u / 2 - 1).collect();
            assert_eq!(*got, committed, "kill@{kill_at}: committed prefix must survive intact");
            assert!(got.len() <= 3);
        }
    }

    #[test]
    fn repair_rolls_back_torn_read_for_redelivery() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{faults::FaultPlan, Machine, MachineCfg, SimWorld};
        // Kill the consumer at every op inside its recv window; repair
        // must make the unacknowledged payload readable again exactly
        // once (no loss, no duplicate).
        for kill_at in 0..16u64 {
            let m = Machine::new(MachineCfg::new(
                2,
                OsProfile::linux_rt(),
                AffinityMode::PinnedSpread,
            ));
            let r = Arc::new(ChannelRing::<SimWorld>::new(8, 32));
            let delivered = Arc::new(Mutex::new(Vec::new()));
            let r1 = r.clone();
            let d1 = delivered.clone();
            let consumer = m.spawn(move || {
                let mut got = 0;
                while got < 2 {
                    match r1.recv_with(|b| u64::from_le_bytes(b[..8].try_into().unwrap())) {
                        Ok(v) => {
                            d1.lock().unwrap().push(v);
                            got += 1;
                        }
                        Err(_) => SimWorld::yield_now(),
                    }
                }
            });
            let r2 = r.clone();
            let producer = m.spawn(move || {
                for i in 0..2u64 {
                    while r2.send(&i.to_le_bytes()).is_err() {
                        SimWorld::yield_now();
                    }
                }
            });
            m.set_faults(FaultPlan::new().kill(0, kill_at));
            m.run(vec![consumer, producer]);
            let r3 = r.clone();
            let redelivered = Arc::new(Mutex::new(Vec::new()));
            let rd = redelivered.clone();
            let m2 = Machine::new(MachineCfg::new(
                1,
                OsProfile::linux_rt(),
                AffinityMode::SingleCore,
            ));
            let h = m2.spawn(move || {
                r3.repair_dead_consumer();
                let mut buf = [0u8; 32];
                while let Ok(n) = r3.recv(&mut buf) {
                    rd.lock()
                        .unwrap()
                        .push(u64::from_le_bytes(buf[..n.min(8)].try_into().unwrap()));
                }
            });
            m2.run(vec![h]);
            let mut all = delivered.lock().unwrap().clone();
            all.extend(redelivered.lock().unwrap().iter().copied());
            let (u, a) = r.counters_peek();
            assert_eq!(a % 2, 0, "kill@{kill_at}: repaired ack must be even");
            assert_eq!(u, a, "kill@{kill_at}: recovery drained everything committed");
            let committed: Vec<u64> = (0..u / 2).collect();
            // Exactly-once for every payload except possibly the single
            // one the dead consumer acknowledged without reporting (died
            // between its ack-exit store and the caller seeing the
            // value): that one may be missing, never duplicated.
            assert!(
                all.windows(2).all(|w| w[0] < w[1]),
                "kill@{kill_at}: duplicates or reordering: {all:?}"
            );
            assert!(
                all.iter().all(|v| committed.contains(v)),
                "kill@{kill_at}: delivered something never committed: {all:?}"
            );
            assert!(
                all.len() + 1 >= committed.len(),
                "kill@{kill_at}: more than the one in-flight payload lost: {all:?} vs {committed:?}"
            );
        }
    }

    #[test]
    fn cached_counters_bound_cross_core_traffic_in_sim() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        // Acceptance gate for the connected-channel fast path: a
        // steady-state SPSC packet exchange re-loads the peer counter at
        // most once per ring wrap, so the per-message line-access budget
        // matches the cached-counter NBB (< 10/msg; the pool-lease path
        // adds Treiber CAS traffic and two pool-line hops on top).
        const N: u64 = 400;
        let m = Machine::new(MachineCfg::new(
            2,
            OsProfile::linux_rt(),
            AffinityMode::PinnedSpread,
        ));
        let r = Arc::new(ChannelRing::<SimWorld>::new(64, 32));
        let r1 = r.clone();
        let producer = m.spawn(move || {
            let mut buf = [0u8; 24];
            for i in 0..N {
                buf[..8].copy_from_slice(&i.to_le_bytes());
                while r1.send(&buf).is_err() {
                    SimWorld::yield_now();
                }
            }
        });
        let r2 = r.clone();
        let consumer = m.spawn(move || {
            for i in 0..N {
                loop {
                    let got = r2.recv_with(|b| u64::from_le_bytes(b[..8].try_into().unwrap()));
                    match got {
                        Ok(v) => {
                            assert_eq!(v, i);
                            break;
                        }
                        Err(_) => SimWorld::yield_now(),
                    }
                }
            }
        });
        let stats = m.run(vec![producer, consumer]);
        let per_msg = (stats.hits + stats.misses) as f64 / N as f64;
        assert!(
            per_msg < 10.0,
            "ring fast path should average < 10 line accesses/msg, got {per_msg:.1} ({stats:?})"
        );
    }

    #[test]
    fn scalar_batch_issues_o1_shared_counter_stores_in_sim() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        // Acceptance gate: a batch of N scalar sends performs exactly two
        // shared-counter stores (one line) plus one payload line per
        // scalar — growing the batch adds only the payload lines.
        let accesses = |n: usize| {
            let m = Machine::new(MachineCfg::new(
                1,
                OsProfile::linux_rt(),
                AffinityMode::SingleCore,
            ));
            let stats = m.run_tasks(1, |_| {
                move || {
                    let r = ChannelRing::<SimWorld>::new(64, 64);
                    let vals = vec![7u64; n];
                    assert_eq!(r.send_scalars(&vals, 8), Ok(n));
                }
            });
            stats.hits + stats.misses
        };
        let small = accesses(8);
        let large = accesses(32);
        assert_eq!(
            large - small,
            24,
            "batch growth must cost only the per-scalar payload lines"
        );
        assert!(
            small <= 8 + 4,
            "counter overhead for a batch must be O(1) stores, got {} accesses for 8 scalars",
            small
        );
    }
}
