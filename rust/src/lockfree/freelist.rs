//! Tagged-index Treiber stack: the lock-free buffer free-list.
//!
//! MCAPI's packet receive buffers come from a shared pool; the lock-free
//! backend needs a lock-free allocator for them. A classic Treiber stack
//! over *indices* (not pointers) with a generation tag packed into the
//! same 64-bit head word sidesteps the ABA problem without hazard
//! pointers: `head = tag(32) | index+1(32)`, tag incremented on every
//! successful push/pop.

use super::mem::{Atom32, Atom64, CachePadded, World};

const NIL: u32 = 0;

/// Lock-free stack of slot indices `0..cap`.
pub struct FreeList<W: World> {
    /// `tag << 32 | (index + 1)`; index 0 encodes empty. Every pop and
    /// push from every core CASes this word — padding keeps that
    /// unavoidable contention from also invalidating the `next` links
    /// that sit behind it.
    head: CachePadded<W::U64>,
    next: Box<[W::U32]>,
}

impl<W: World> FreeList<W> {
    /// New pool with all `cap` indices free (popped in order 0, 1, ...).
    pub fn new_full(cap: usize) -> Self {
        assert!(cap >= 1 && cap < u32::MAX as usize - 1);
        // Chain i -> i+1, last -> NIL; head -> 0.
        let next = (0..cap)
            .map(|i| W::U32::new(if i + 1 < cap { (i + 2) as u32 } else { NIL }))
            .collect::<Vec<_>>();
        FreeList { head: CachePadded::new(W::U64::new(1)), next: next.into_boxed_slice() }
    }

    /// New pool with no free indices (fill with [`FreeList::push`]).
    pub fn new_empty(cap: usize) -> Self {
        assert!(cap >= 1 && cap < u32::MAX as usize - 1);
        let next = (0..cap).map(|_| W::U32::new(NIL)).collect::<Vec<_>>();
        FreeList { head: CachePadded::new(W::U64::new(0)), next: next.into_boxed_slice() }
    }

    /// Pool capacity.
    pub fn capacity(&self) -> usize {
        self.next.len()
    }

    /// Pop a free index, or `None` when exhausted.
    pub fn pop(&self) -> Option<usize> {
        loop {
            let head = self.head.load();
            let enc = (head & 0xFFFF_FFFF) as u32;
            if enc == NIL {
                return None;
            }
            let idx = (enc - 1) as usize;
            let next = self.next[idx].load();
            let tag = head >> 32;
            let new = ((tag + 1) << 32) | next as u64;
            if self.head.cas(head, new).is_ok() {
                return Some(idx);
            }
            W::spin_hint();
        }
    }

    /// Push an index back into the pool.
    pub fn push(&self, idx: usize) {
        assert!(idx < self.next.len(), "index {idx} out of range");
        let enc = (idx + 1) as u32;
        loop {
            let head = self.head.load();
            self.next[idx].store((head & 0xFFFF_FFFF) as u32);
            let tag = head >> 32;
            let new = ((tag + 1) << 32) | enc as u64;
            if self.head.cas(head, new).is_ok() {
                return;
            }
            W::spin_hint();
        }
    }

    /// Number of free indices (O(n) walk; approximate under concurrency —
    /// meant for tests and reports, not hot paths, hence relaxed loads).
    pub fn free_count(&self) -> usize {
        let mut n = 0;
        let mut enc = (self.head.load() & 0xFFFF_FFFF) as u32;
        while enc != NIL && n <= self.next.len() {
            n += 1;
            enc = self.next[(enc - 1) as usize].load_relaxed();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::collections::HashSet;
    use std::sync::Arc;

    type RFree = FreeList<RealWorld>;

    #[test]
    fn full_pool_pops_in_order() {
        let f = RFree::new_full(4);
        assert_eq!(f.pop(), Some(0));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn push_pop_lifo() {
        let f = RFree::new_empty(8);
        assert_eq!(f.pop(), None);
        f.push(5);
        f.push(2);
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), Some(5));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn free_count_tracks() {
        let f = RFree::new_full(6);
        assert_eq!(f.free_count(), 6);
        let _ = f.pop();
        let _ = f.pop();
        assert_eq!(f.free_count(), 4);
        f.push(0);
        assert_eq!(f.free_count(), 5);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn push_out_of_range_panics() {
        RFree::new_empty(2).push(2);
    }

    #[test]
    fn concurrent_churn_conserves_indices() {
        const CAP: usize = 64;
        let f = Arc::new(RFree::new_full(CAP));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let f = f.clone();
            handles.push(std::thread::spawn(move || {
                let mut held = Vec::new();
                for round in 0..20_000usize {
                    if round % 3 != 2 {
                        if let Some(i) = f.pop() {
                            held.push(i);
                        }
                    } else if let Some(i) = held.pop() {
                        f.push(i);
                    }
                }
                // Return everything.
                for i in held {
                    f.push(i);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(f.free_count(), CAP);
        // All indices distinct when fully drained.
        let mut seen = HashSet::new();
        while let Some(i) = f.pop() {
            assert!(seen.insert(i), "duplicate index {i}");
        }
        assert_eq!(seen.len(), CAP);
    }
}
