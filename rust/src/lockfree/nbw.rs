//! Kopetz's Non-Blocking Write protocol (NBW) for **state messages**.
//!
//! State messages carry "the current value" — order is indeterminate and
//! readers only ever want the freshest version. One atomic version counter
//! serializes nothing: the writer increments it before and after each
//! write (odd = write in progress); a reader snapshots the counter, reads
//! the newest completed buffer, re-checks the counter and retries on a
//! collision — optimistic concurrency, like database OCC [29].
//!
//! The paper's three properties hold by construction:
//! * **Safety** — a successful read returns an uncorrupted version
//!   (collision check).
//! * **Timeliness** — reads never block; retries are bounded in practice
//!   by the buffer depth (the more buffers, the fewer collisions).
//! * **Non-blocking** — the writer is never blocked by readers.
//!
//! Slot payloads are accessed with volatile copies: the protocol is
//! *designed* around potentially-torn concurrent access that is detected
//! and discarded via the version check.

use std::cell::UnsafeCell;

use super::mem::{Atom64, CachePadded, World};

/// A non-blocking state-message variable of depth `D` buffers.
pub struct Nbw<T: Copy, W: World> {
    /// Version counter on its own line: the writer bumps it around every
    /// write, readers poll it around every read — sharing a line with the
    /// slot metadata would drag the whole struct into the ping-pong.
    version: CachePadded<W::U64>,
    slots: Box<[UnsafeCell<T>]>,
    regions: Box<[u64]>,
}

unsafe impl<T: Copy + Send, W: World> Send for Nbw<T, W> {}
unsafe impl<T: Copy + Send, W: World> Sync for Nbw<T, W> {}

impl<T: Copy, W: World> Nbw<T, W> {
    /// Create with `depth` buffers, initialised to `init` (version 0 means
    /// "nothing published yet" — reads return `None` until first write).
    pub fn new(depth: usize, init: T) -> Self {
        assert!(depth >= 1, "NBW depth must be >= 1");
        let item = std::mem::size_of::<T>().max(1);
        Nbw {
            version: CachePadded::new(W::U64::new(0)),
            slots: (0..depth).map(|_| UnsafeCell::new(init)).collect(),
            regions: (0..depth).map(|_| W::alloc_region(item)).collect(),
        }
    }

    /// Buffer depth.
    pub fn depth(&self) -> usize {
        self.slots.len()
    }

    /// Number of completed writes (monitoring only, hence relaxed).
    pub fn writes(&self) -> u64 {
        self.version.load_relaxed() / 2
    }

    /// Publish a new state value. Single-writer; never blocks.
    pub fn write(&self, v: T) {
        let c = self.version.load();
        debug_assert_eq!(c & 1, 0, "concurrent writers on NBW");
        self.version.store(c + 1); // odd: write in progress
        let idx = ((c / 2) % self.slots.len() as u64) as usize;
        W::touch(self.regions[idx], std::mem::size_of::<T>().max(1), true);
        unsafe { std::ptr::write_volatile(self.slots[idx].get(), v) };
        self.version.store(c + 2);
    }

    /// Try to read the freshest completed value once. `Err(())` signals a
    /// collision (caller retries); `Ok(None)` means nothing was ever
    /// written.
    pub fn try_read(&self) -> Result<Option<T>, ()> {
        let c1 = self.version.load();
        if c1 == 0 {
            return Ok(None);
        }
        if c1 & 1 == 1 {
            return Err(()); // writer mid-flight on the newest slot
        }
        let n = c1 / 2; // completed writes
        let depth = self.slots.len() as u64;
        let idx = ((n - 1) % depth) as usize;
        W::touch(self.regions[idx], std::mem::size_of::<T>().max(1), false);
        let v = unsafe { std::ptr::read_volatile(self.slots[idx].get()) };
        let c2 = self.version.load();
        // Our slot is clobbered once the writer *starts* write number
        // (n-1) + depth, i.e. once the counter reaches 2*(n-1+depth)+1.
        if c2 >= 2 * (n - 1 + depth) + 1 {
            return Err(());
        }
        Ok(Some(v))
    }

    /// Read the freshest value, spinning through collisions. Returns
    /// `(value, retries)`; `None` if nothing was ever written.
    pub fn read(&self) -> (Option<T>, u32) {
        let mut retries = 0;
        loop {
            match self.try_read() {
                Ok(v) => return (v, retries),
                Err(()) => {
                    retries += 1;
                    W::spin_hint();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    type RNbw<T> = Nbw<T, RealWorld>;

    #[test]
    fn unwritten_reads_none() {
        let n = RNbw::new(4, 0u64);
        assert_eq!(n.read().0, None);
    }

    #[test]
    fn read_returns_latest() {
        let n = RNbw::new(2, 0u64);
        n.write(10);
        assert_eq!(n.read().0, Some(10));
        n.write(20);
        n.write(30);
        assert_eq!(n.read().0, Some(30));
        assert_eq!(n.writes(), 3);
    }

    #[test]
    fn depth_one_still_correct() {
        let n = RNbw::new(1, 0u32);
        for i in 1..50u32 {
            n.write(i);
            assert_eq!(n.read().0, Some(i));
        }
    }

    #[test]
    #[should_panic(expected = "depth")]
    fn zero_depth_rejected() {
        let _ = RNbw::new(0, 0u8);
    }

    /// Safety property under real concurrency: a reader never observes a
    /// torn state value (payload halves must always match).
    #[test]
    fn no_torn_reads_under_stress() {
        let n = Arc::new(RNbw::new(4, [0u64; 4]));
        let stop = Arc::new(AtomicBool::new(false));
        let writer = {
            let n = n.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut i = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    i += 1;
                    n.write([i, i.wrapping_mul(7), !i, i ^ 0xF00D]);
                }
                i
            })
        };
        let mut reads = 0u64;
        let mut last_seen = 0u64;
        while reads < 100_000 {
            if let Some([a, b, c, d]) = n.read().0 {
                assert_eq!(b, a.wrapping_mul(7), "torn read");
                assert_eq!(c, !a, "torn read");
                assert_eq!(d, a ^ 0xF00D, "torn read");
                // Freshness is monotone: state messages never go backwards.
                assert!(a >= last_seen, "stale reordering: {a} < {last_seen}");
                last_seen = a;
            }
            reads += 1;
        }
        stop.store(true, Ordering::Relaxed);
        let total = writer.join().unwrap();
        assert!(total > 0);
    }

    /// Non-blocking property: the writer makes progress even while readers
    /// hammer the variable continuously.
    #[test]
    fn writer_never_blocked() {
        let n = Arc::new(RNbw::new(2, 0u64));
        let stop = Arc::new(AtomicBool::new(false));
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let n = n.clone();
                let stop = stop.clone();
                std::thread::spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        let _ = n.read();
                    }
                })
            })
            .collect();
        for i in 1..=50_000u64 {
            n.write(i);
        }
        assert_eq!(n.writes(), 50_000);
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert_eq!(n.read().0, Some(50_000));
    }

    #[test]
    fn deeper_buffers_reduce_collisions() {
        // Deterministic check in the simulator would be ideal; on the real
        // host we only assert the retry counter is exposed and sane.
        let n = RNbw::new(8, 0u32);
        n.write(1);
        let (v, retries) = n.read();
        assert_eq!(v, Some(1));
        assert_eq!(retries, 0);
    }
}
