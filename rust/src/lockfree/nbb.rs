//! Kim's Non-Blocking Buffer (NBB): lock-free SPSC ring FIFO for event
//! messages, with the paper's Table 1 status semantics.
//!
//! Two atomic counters guard the ring: `update` (writer) and `ack`
//! (reader). Each is incremented **before** an operation starts and again
//! **after** it completes, so an odd value means the peer is mid-operation
//! — which is exactly the information the `*_BUT_*` statuses expose:
//!
//! | InsertItem                          | ReadItem                               |
//! |-------------------------------------|----------------------------------------|
//! | `BUFFER_FULL` — yield and retry     | `BUFFER_EMPTY` — yield and retry       |
//! | `BUFFER_FULL_BUT_CONSUMER_READING`  | `BUFFER_EMPTY_BUT_PRODUCER_INSERTING`  |
//! |   — retry immediately, bounded      |   — retry immediately, bounded         |
//!
//! `update/2` counts completed inserts, `ack/2` completed reads; the ring
//! holds `update/2 - ack/2` items. The writer and reader always address
//! different slots, so slot access is race-free (asserted by the paper's
//! Safety property; tested with torn-write detection below).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;

use super::backoff::Backoff;
use super::mem::{Atom64, World};

/// Failure reason of [`Nbb::insert`] (the item is handed back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertStatus {
    /// No room; caller should yield the processor and retry (Table 1).
    Full,
    /// No room but the consumer is mid-read: retry immediately, bounded.
    FullButConsumerReading,
}

/// Result of [`Nbb::read`].
#[derive(Debug, PartialEq, Eq)]
pub enum ReadStatus<T> {
    /// Item dequeued.
    Ok(T),
    /// Nothing pending; caller should yield the processor and retry.
    Empty,
    /// Nothing pending but the producer is mid-insert: retry immediately.
    EmptyButProducerInserting,
}

/// Single-producer single-consumer non-blocking ring buffer.
///
/// The MCAPI lock-free backend gives every channel (a point-to-point FIFO
/// by the MCAPI spec) its own NBB; fan-in endpoints compose one NBB per
/// producer lane (see `mcapi::lockfree_backend`).
pub struct Nbb<T, W: World> {
    update: W::U64,
    ack: W::U64,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// Synthetic payload region per slot (simulator cost accounting).
    regions: Box<[u64]>,
    cap: u64,
}

unsafe impl<T: Send, W: World> Send for Nbb<T, W> {}
unsafe impl<T: Send, W: World> Sync for Nbb<T, W> {}

impl<T, W: World> Nbb<T, W> {
    /// Ring with `cap` slots (`cap >= 1`). The paper sizes the NBB to
    /// absorb message bursts; `micro_lockfree --ablate-capacity` sweeps it.
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "NBB capacity must be >= 1");
        let slots = (0..cap)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        let item = std::mem::size_of::<T>().max(1);
        let regions = (0..cap).map(|_| W::alloc_region(item)).collect::<Vec<_>>();
        Nbb {
            update: W::U64::new(0),
            ack: W::U64::new(0),
            slots,
            regions: regions.into_boxed_slice(),
            cap: cap as u64,
        }
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Items currently buffered (approximate under concurrency).
    pub fn len(&self) -> usize {
        let u = self.update.load() / 2;
        let a = self.ack.load() / 2;
        u.wrapping_sub(a) as usize
    }

    /// True when no items are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Producer side: enqueue `v`; on failure the item is handed back with
    /// the Table 1 status. Only one thread may insert concurrently (SPSC).
    pub fn insert(&self, v: T) -> Result<(), (InsertStatus, T)> {
        let u = self.update.load();
        let a = self.ack.load();
        let filled = (u / 2).wrapping_sub(a / 2);
        if filled >= self.cap {
            let status = if a & 1 == 1 {
                InsertStatus::FullButConsumerReading
            } else {
                InsertStatus::Full
            };
            return Err((status, v));
        }
        self.update.store(u + 1); // enter: odd = insert in progress
        let idx = ((u / 2) % self.cap) as usize;
        W::touch(self.regions[idx], std::mem::size_of::<T>().max(1), true);
        unsafe { (*self.slots[idx].get()).write(v) };
        self.update.store(u + 2); // exit
        Ok(())
    }

    /// Consumer side: dequeue or report why not (Table 1).
    /// Only one thread may read concurrently (SPSC contract).
    pub fn read(&self) -> ReadStatus<T> {
        let a = self.ack.load();
        let u = self.update.load();
        let filled = (u / 2).wrapping_sub(a / 2);
        if filled == 0 {
            return if u & 1 == 1 {
                ReadStatus::EmptyButProducerInserting
            } else {
                ReadStatus::Empty
            };
        }
        self.ack.store(a + 1); // enter: odd = read in progress
        let idx = ((a / 2) % self.cap) as usize;
        W::touch(self.regions[idx], std::mem::size_of::<T>().max(1), false);
        let v = unsafe { (*self.slots[idx].get()).assume_init_read() };
        self.ack.store(a + 2); // exit
        ReadStatus::Ok(v)
    }

}

impl<T, W: World> Nbb<T, W> {
    /// Blocking insert honouring Table 1 retry semantics: immediate bounded
    /// retries while the consumer is mid-read, yields while genuinely full.
    /// Returns the number of yields performed.
    pub fn insert_until(&self, v: T) -> u32 {
        let mut backoff = Backoff::<W>::new();
        let mut item = v;
        loop {
            match self.insert(item) {
                Ok(()) => return backoff.yields(),
                Err((InsertStatus::FullButConsumerReading, back)) => {
                    item = back;
                    if !backoff.immediate() {
                        backoff.yield_now();
                    }
                }
                Err((InsertStatus::Full, back)) => {
                    item = back;
                    backoff.yield_now();
                }
            }
        }
    }

    /// Blocking read honouring Table 1 retry semantics.
    pub fn read_until(&self) -> (T, u32) {
        let mut backoff = Backoff::<W>::new();
        loop {
            match self.read() {
                ReadStatus::Ok(v) => return (v, backoff.yields()),
                ReadStatus::EmptyButProducerInserting => {
                    if !backoff.immediate() {
                        backoff.yield_now();
                    }
                }
                ReadStatus::Empty => backoff.yield_now(),
            }
        }
    }
}

impl<T, W: World> Drop for Nbb<T, W> {
    fn drop(&mut self) {
        // Drop any items still buffered. peek(): destructors may run on
        // threads without a simulator context.
        let mut a = self.ack.peek() / 2;
        let u = self.update.peek() / 2;
        while a != u {
            let idx = (a % self.cap) as usize;
            unsafe { (*self.slots[idx].get()).assume_init_drop() };
            a = a.wrapping_add(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    type RNbb<T> = Nbb<T, RealWorld>;

    #[test]
    fn fifo_order_single_thread() {
        let q = RNbb::new(4);
        for i in 0..4 {
            assert!(q.insert(i).is_ok());
        }
        assert_eq!(q.insert(9).unwrap_err(), (InsertStatus::Full, 9));
        for i in 0..4 {
            assert_eq!(q.read(), ReadStatus::Ok(i));
        }
        assert_eq!(q.read(), ReadStatus::<i32>::Empty);
    }

    #[test]
    fn len_tracks_inserts_and_reads() {
        let q = RNbb::new(8);
        assert!(q.is_empty());
        q.insert(1).unwrap();
        q.insert(2).unwrap();
        assert_eq!(q.len(), 2);
        let _ = q.read();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn wraparound_many_times() {
        let q = RNbb::new(3);
        for round in 0..100u64 {
            assert!(q.insert(round).is_ok());
            assert_eq!(q.read(), ReadStatus::Ok(round));
        }
    }

    #[test]
    fn capacity_one_alternates() {
        let q = RNbb::new(1);
        assert!(q.insert(7).is_ok());
        let (status, back) = q.insert(8).unwrap_err();
        assert_eq!((status, back), (InsertStatus::Full, 8));
        assert_eq!(q.read(), ReadStatus::Ok(7));
        assert!(q.insert(back).is_ok());
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = RNbb::<u8>::new(0);
    }

    #[test]
    fn drop_releases_buffered_items() {
        let item = Arc::new(());
        let q = RNbb::new(4);
        q.insert(item.clone()).map_err(|_| ()).unwrap();
        q.insert(item.clone()).map_err(|_| ()).unwrap();
        assert_eq!(Arc::strong_count(&item), 3);
        drop(q);
        assert_eq!(Arc::strong_count(&item), 1);
    }

    #[test]
    fn spsc_stress_preserves_fifo_and_loses_nothing() {
        const N: u64 = 200_000;
        let q = Arc::new(RNbb::<u64>::new(64));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..N {
                    q.insert_until(i);
                }
            })
        };
        let mut expected = 0u64;
        while expected < N {
            if let ReadStatus::Ok(v) = q.read() {
                assert_eq!(v, expected, "FIFO violated");
                expected += 1;
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
        assert_eq!(q.read(), ReadStatus::Empty);
    }

    #[test]
    fn torn_payloads_never_observed() {
        // Safety property: every item read must be one of the written
        // values in full (payload = value repeated, checked on read).
        const N: u64 = 50_000;
        let q = Arc::new(RNbb::<[u64; 4]>::new(8));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 1..=N {
                    q.insert_until([i, i.wrapping_mul(3), !i, i ^ 0xABCD]);
                }
            })
        };
        let mut got = 0;
        while got < N {
            if let ReadStatus::Ok([a, b, c, d]) = q.read() {
                assert_eq!(b, a.wrapping_mul(3));
                assert_eq!(c, !a);
                assert_eq!(d, a ^ 0xABCD);
                got += 1;
            }
        }
        producer.join().unwrap();
    }

    #[test]
    fn table1_statuses_in_sim() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        // In the deterministic simulator we can provoke BUFFER_FULL: the
        // reader (task 0) sleeps 10 us of virtual time before draining, so
        // the writer (task 1) finds the 1-slot ring occupied.
        let m = Machine::new(MachineCfg::new(
            2,
            OsProfile::linux_rt(),
            AffinityMode::PinnedSpread,
        ));
        let q = Arc::new(Nbb::<u64, SimWorld>::new(1));
        let q1 = q.clone();
        let reader = m.spawn(move || {
            <SimWorld as World>::work(10_000);
            let (v1, _) = q1.read_until();
            let (v2, _) = q1.read_until();
            assert_eq!((v1, v2), (1, 2));
        });
        let q2 = q.clone();
        let writer = m.spawn(move || {
            assert!(q2.insert(1).is_ok());
            let mut full_seen = false;
            let mut but_seen = false;
            let mut item = 2u64;
            loop {
                match q2.insert(item) {
                    Ok(()) => break,
                    Err((InsertStatus::Full, back)) => {
                        item = back;
                        full_seen = true;
                        SimWorld::yield_now();
                    }
                    Err((InsertStatus::FullButConsumerReading, back)) => {
                        item = back;
                        but_seen = true;
                        SimWorld::spin_hint();
                    }
                }
            }
            assert!(full_seen || but_seen, "writer never saw a Table 1 status");
        });
        m.run(vec![reader, writer]);
    }
}
