//! Kim's Non-Blocking Buffer (NBB): lock-free SPSC ring FIFO for event
//! messages, with the paper's Table 1 status semantics.
//!
//! Two atomic counters guard the ring: `update` (writer) and `ack`
//! (reader). Each is incremented **before** an operation starts and again
//! **after** it completes, so an odd value means the peer is mid-operation
//! — which is exactly the information the `*_BUT_*` statuses expose:
//!
//! | InsertItem                          | ReadItem                               |
//! |-------------------------------------|----------------------------------------|
//! | `BUFFER_FULL` — yield and retry     | `BUFFER_EMPTY` — yield and retry       |
//! | `BUFFER_FULL_BUT_CONSUMER_READING`  | `BUFFER_EMPTY_BUT_PRODUCER_INSERTING`  |
//! |   — retry immediately, bounded      |   — retry immediately, bounded         |
//!
//! `update/2` counts completed inserts, `ack/2` completed reads; the ring
//! holds `update/2 - ack/2` items. The writer and reader always address
//! different slots, so slot access is race-free (asserted by the paper's
//! Safety property; tested with torn-write detection below).
//!
//! # Coherence optimization (this is the hot path of the whole repo)
//!
//! The textbook implementation re-loads the *peer's* counter on every
//! operation, so every message moves both counters' cache lines between
//! the producer and consumer cores — the ping-pong that Virtual-Link
//! (arXiv:2012.05181) identifies as the dominant cost of cross-core
//! queues. This implementation applies the two standard fixes (Cederman
//! et al., arXiv:1302.2757; rigtorp/folly SPSC queues):
//!
//! * **Padding** — `update` and `ack` each live on their own cache line
//!   ([`CachePadded`]), so the producer's stores never invalidate the
//!   consumer's counter line and vice versa.
//! * **Cached peer counters** — each side keeps a private snapshot of the
//!   peer's counter and only re-loads the shared word when the snapshot
//!   says full (producer) / empty (consumer). The snapshot is
//!   conservative (the peer's counter only grows), so capacity and
//!   emptiness checks stay safe; in steady state the producer touches the
//!   consumer's line once per ring *wrap*, not once per message. Each
//!   side also mirrors its **own** counter privately so the hot path
//!   performs exactly two release stores and zero shared loads.
//! * **Batch transfer** — [`Nbb::insert_batch`] / [`Nbb::read_batch`]
//!   amortize the enter/exit counter stores over N items: one odd "in
//!   progress" store, N slot writes, one even "publish all" store. The
//!   Table 1 `*_BUT_*` distinction is preserved via [`BatchStatus`].

use std::cell::{Cell, UnsafeCell};
use std::mem::MaybeUninit;

use super::backoff::Backoff;
use super::mem::{Atom64, CachePadded, World};

/// Failure reason of [`Nbb::insert`] (the item is handed back).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InsertStatus {
    /// No room; caller should yield the processor and retry (Table 1).
    Full,
    /// No room but the consumer is mid-read: retry immediately, bounded.
    FullButConsumerReading,
}

/// Result of [`Nbb::read`].
#[derive(Debug, PartialEq, Eq)]
pub enum ReadStatus<T> {
    /// Item dequeued.
    Ok(T),
    /// Nothing pending; caller should yield the processor and retry.
    Empty,
    /// Nothing pending but the producer is mid-insert: retry immediately.
    EmptyButProducerInserting,
}

/// Why a batch operation moved zero items — the Table 1 statuses with the
/// item-carrying variants stripped (batch calls hand items back in the
/// caller's vector).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchStatus {
    /// Ring genuinely full/empty; yield the processor and retry.
    WouldBlock,
    /// Peer is mid-operation; retry immediately, bounded (Table 1
    /// `*_BUT_*`).
    PeerActive,
}

/// One side's private cache line: a mirror of that side's own shared
/// counter (so the owner never re-loads a word the peer polls) plus the
/// last observed value of the peer's counter (re-loaded only on apparent
/// full/empty). Plain `Cell`s are sound under the SPSC contract: exactly
/// one thread ever touches each side. Shared with the connected-channel
/// ring ([`super::ring`]), which runs the same counter protocol.
pub(super) struct SideCache {
    pub(super) own: Cell<u64>,
    pub(super) peer: Cell<u64>,
}

impl SideCache {
    pub(super) fn new() -> Self {
        SideCache { own: Cell::new(0), peer: Cell::new(0) }
    }
}

/// Single-producer single-consumer non-blocking ring buffer.
///
/// The MCAPI lock-free backend gives every channel (a point-to-point FIFO
/// by the MCAPI spec) its own NBB; fan-in endpoints compose one NBB per
/// producer lane (see `mcapi::lockfree_backend`).
pub struct Nbb<T, W: World> {
    /// Writer counter — producer-owned line.
    update: CachePadded<W::U64>,
    /// Reader counter — consumer-owned line.
    ack: CachePadded<W::U64>,
    /// Producer-private mirrors (own = `update`, peer = `ack` snapshot).
    prod: CachePadded<SideCache>,
    /// Consumer-private mirrors (own = `ack`, peer = `update` snapshot).
    cons: CachePadded<SideCache>,
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    /// Synthetic payload region per slot (simulator cost accounting).
    regions: Box<[u64]>,
    cap: u64,
}

unsafe impl<T: Send, W: World> Send for Nbb<T, W> {}
unsafe impl<T: Send, W: World> Sync for Nbb<T, W> {}

impl<T, W: World> Nbb<T, W> {
    /// Ring with `cap` slots (`cap >= 1`). The paper sizes the NBB to
    /// absorb message bursts; `micro_lockfree --ablate-capacity` sweeps it.
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "NBB capacity must be >= 1");
        let slots = (0..cap)
            .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        let item = std::mem::size_of::<T>().max(1);
        let regions = (0..cap).map(|_| W::alloc_region(item)).collect::<Vec<_>>();
        Nbb {
            update: CachePadded::new(W::U64::new(0)),
            ack: CachePadded::new(W::U64::new(0)),
            prod: CachePadded::new(SideCache::new()),
            cons: CachePadded::new(SideCache::new()),
            slots,
            regions: regions.into_boxed_slice(),
            cap: cap as u64,
        }
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Items currently buffered (approximate under concurrency;
    /// monitoring only, hence relaxed).
    pub fn len(&self) -> usize {
        let u = self.update.load_relaxed() / 2;
        let a = self.ack.load_relaxed() / 2;
        u.wrapping_sub(a) as usize
    }

    /// True when no items are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Producer side: enqueue `v`; on failure the item is handed back with
    /// the Table 1 status. Only one thread may insert concurrently (SPSC).
    pub fn insert(&self, v: T) -> Result<(), (InsertStatus, T)> {
        let u = self.prod.own.get();
        let mut a = self.prod.peer.get();
        if (u / 2).wrapping_sub(a / 2) >= self.cap {
            // The cached snapshot says full — the only case that justifies
            // touching the consumer's line. Re-load `ack` once before
            // rejecting, so a stale snapshot cannot spuriously return
            // `Full` after the consumer has already drained.
            a = self.ack.load();
            self.prod.peer.set(a);
            if (u / 2).wrapping_sub(a / 2) >= self.cap {
                let status = if a & 1 == 1 {
                    InsertStatus::FullButConsumerReading
                } else {
                    InsertStatus::Full
                };
                crate::obs::bump(crate::obs::ctr::NBB_FULL);
                return Err((status, v));
            }
        }
        self.update.store(u + 1); // enter: odd = insert in progress
        let idx = ((u / 2) % self.cap) as usize;
        W::touch(self.regions[idx], std::mem::size_of::<T>().max(1), true);
        unsafe { (*self.slots[idx].get()).write(v) };
        self.update.store(u + 2); // exit
        self.prod.own.set(u + 2);
        crate::obs::bump(crate::obs::ctr::NBB_INSERT);
        Ok(())
    }

    /// Consumer side: dequeue or report why not (Table 1).
    /// Only one thread may read concurrently (SPSC contract).
    pub fn read(&self) -> ReadStatus<T> {
        let a = self.cons.own.get();
        let mut u = self.cons.peer.get();
        if (u / 2).wrapping_sub(a / 2) == 0 {
            // Cached snapshot says empty: re-load `update` once (the
            // consumer's single cross-core load in steady state).
            u = self.update.load();
            self.cons.peer.set(u);
            if (u / 2).wrapping_sub(a / 2) == 0 {
                crate::obs::bump(crate::obs::ctr::NBB_EMPTY);
                return if u & 1 == 1 {
                    ReadStatus::EmptyButProducerInserting
                } else {
                    ReadStatus::Empty
                };
            }
        }
        self.ack.store(a + 1); // enter: odd = read in progress
        let idx = ((a / 2) % self.cap) as usize;
        W::touch(self.regions[idx], std::mem::size_of::<T>().max(1), false);
        let v = unsafe { (*self.slots[idx].get()).assume_init_read() };
        self.ack.store(a + 2); // exit
        self.cons.own.set(a + 2);
        crate::obs::bump(crate::obs::ctr::NBB_READ);
        ReadStatus::Ok(v)
    }

    /// Producer side: enqueue a prefix of `items`, amortizing the
    /// enter/exit counter stores over the whole prefix (one odd store, N
    /// slot writes, one publishing store). Inserted items are drained
    /// from the front of `items`; returns how many were enqueued.
    /// `Err` only when the ring had no room for even one item.
    pub fn insert_batch(&self, items: &mut Vec<T>) -> Result<usize, BatchStatus> {
        if items.is_empty() {
            return Ok(0);
        }
        let u = self.prod.own.get();
        let mut a = self.prod.peer.get();
        let mut free = self.cap - (u / 2).wrapping_sub(a / 2);
        if free == 0 {
            a = self.ack.load();
            self.prod.peer.set(a);
            free = self.cap - (u / 2).wrapping_sub(a / 2);
            if free == 0 {
                return Err(if a & 1 == 1 {
                    BatchStatus::PeerActive
                } else {
                    BatchStatus::WouldBlock
                });
            }
        }
        let k = (free as usize).min(items.len());
        self.update.store(u + 1); // enter once: odd across the whole batch
        let item_bytes = std::mem::size_of::<T>().max(1);
        for (i, v) in items.drain(..k).enumerate() {
            let idx = ((u / 2 + i as u64) % self.cap) as usize;
            W::touch(self.regions[idx], item_bytes, true);
            unsafe { (*self.slots[idx].get()).write(v) };
        }
        let u2 = u + 2 * k as u64;
        self.update.store(u2); // exit: publishes all k items at once
        self.prod.own.set(u2);
        crate::obs::add(crate::obs::ctr::NBB_INSERT, k as u64);
        Ok(k)
    }

    /// Consumer side: dequeue up to `max` items into `out`, amortizing
    /// the enter/exit counter stores. Returns how many were appended;
    /// `Err` when the ring held nothing (with the Table 1 distinction).
    pub fn read_batch(&self, out: &mut Vec<T>, max: usize) -> Result<usize, BatchStatus> {
        if max == 0 {
            return Ok(0);
        }
        let a = self.cons.own.get();
        let mut u = self.cons.peer.get();
        let mut avail = (u / 2).wrapping_sub(a / 2);
        if avail == 0 {
            u = self.update.load();
            self.cons.peer.set(u);
            avail = (u / 2).wrapping_sub(a / 2);
            if avail == 0 {
                return Err(if u & 1 == 1 {
                    BatchStatus::PeerActive
                } else {
                    BatchStatus::WouldBlock
                });
            }
        }
        let k = (avail as usize).min(max);
        self.ack.store(a + 1); // enter once
        let item_bytes = std::mem::size_of::<T>().max(1);
        for i in 0..k as u64 {
            let idx = ((a / 2 + i) % self.cap) as usize;
            W::touch(self.regions[idx], item_bytes, false);
            out.push(unsafe { (*self.slots[idx].get()).assume_init_read() });
        }
        let a2 = a + 2 * k as u64;
        self.ack.store(a2); // exit: acknowledges all k items at once
        self.cons.own.set(a2);
        crate::obs::add(crate::obs::ctr::NBB_READ, k as u64);
        Ok(k)
    }
}

impl<T, W: World> Nbb<T, W> {
    /// Blocking insert honouring Table 1 retry semantics: immediate bounded
    /// retries while the consumer is mid-read, yields while genuinely full.
    /// Returns the number of yields performed.
    pub fn insert_until(&self, v: T) -> u32 {
        let mut backoff = Backoff::<W>::new();
        let mut item = v;
        loop {
            match self.insert(item) {
                Ok(()) => return backoff.yields(),
                Err((InsertStatus::FullButConsumerReading, back)) => {
                    item = back;
                    if !backoff.immediate() {
                        backoff.yield_now();
                    }
                }
                Err((InsertStatus::Full, back)) => {
                    item = back;
                    backoff.yield_now();
                }
            }
        }
    }

    /// Blocking read honouring Table 1 retry semantics.
    pub fn read_until(&self) -> (T, u32) {
        let mut backoff = Backoff::<W>::new();
        loop {
            match self.read() {
                ReadStatus::Ok(v) => return (v, backoff.yields()),
                ReadStatus::EmptyButProducerInserting => {
                    if !backoff.immediate() {
                        backoff.yield_now();
                    }
                }
                ReadStatus::Empty => backoff.yield_now(),
            }
        }
    }
}

impl<T, W: World> Drop for Nbb<T, W> {
    fn drop(&mut self) {
        // Drop any items still buffered. peek(): destructors may run on
        // threads without a simulator context.
        let mut a = self.ack.peek() / 2;
        let u = self.update.peek() / 2;
        while a != u {
            let idx = (a % self.cap) as usize;
            unsafe { (*self.slots[idx].get()).assume_init_drop() };
            a = a.wrapping_add(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    type RNbb<T> = Nbb<T, RealWorld>;

    #[test]
    fn fifo_order_single_thread() {
        let q = RNbb::new(4);
        for i in 0..4 {
            assert!(q.insert(i).is_ok());
        }
        assert_eq!(q.insert(9).unwrap_err(), (InsertStatus::Full, 9));
        for i in 0..4 {
            assert_eq!(q.read(), ReadStatus::Ok(i));
        }
        assert_eq!(q.read(), ReadStatus::<i32>::Empty);
    }

    #[test]
    fn len_tracks_inserts_and_reads() {
        let q = RNbb::new(8);
        assert!(q.is_empty());
        q.insert(1).unwrap();
        q.insert(2).unwrap();
        assert_eq!(q.len(), 2);
        let _ = q.read();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn wraparound_many_times() {
        let q = RNbb::new(3);
        for round in 0..100u64 {
            assert!(q.insert(round).is_ok());
            assert_eq!(q.read(), ReadStatus::Ok(round));
        }
    }

    #[test]
    fn capacity_one_alternates() {
        let q = RNbb::new(1);
        assert!(q.insert(7).is_ok());
        let (status, back) = q.insert(8).unwrap_err();
        assert_eq!((status, back), (InsertStatus::Full, 8));
        assert_eq!(q.read(), ReadStatus::Ok(7));
        assert!(q.insert(back).is_ok());
    }

    #[test]
    fn stale_full_snapshot_refreshes_on_insert() {
        // Fill the ring (the producer's cached `ack` goes stale at "no
        // room"), drain it completely, then insert again: the re-load of
        // `ack` must notice the drain on the *first* attempt rather than
        // spuriously returning Full.
        let q = RNbb::new(2);
        q.insert(1).unwrap();
        q.insert(2).unwrap();
        assert!(q.insert(3).is_err(), "ring is full");
        assert_eq!(q.read(), ReadStatus::Ok(1));
        assert_eq!(q.read(), ReadStatus::Ok(2));
        assert!(q.insert(4).is_ok(), "stale cached ack must refresh");
        assert_eq!(q.read(), ReadStatus::Ok(4));
    }

    #[test]
    fn batch_roundtrip_and_partial_insert() {
        let q = RNbb::new(4);
        let mut items: Vec<u64> = (0..6).collect();
        // Only 4 fit; the rest stay in the caller's vector.
        assert_eq!(q.insert_batch(&mut items), Ok(4));
        assert_eq!(items, vec![4, 5]);
        assert_eq!(q.insert_batch(&mut items), Err(BatchStatus::WouldBlock));
        let mut out = Vec::new();
        assert_eq!(q.read_batch(&mut out, 3), Ok(3));
        assert_eq!(out, vec![0, 1, 2]);
        assert_eq!(q.read_batch(&mut out, 8), Ok(1));
        assert_eq!(out, vec![0, 1, 2, 3]);
        assert_eq!(q.read_batch(&mut out, 8), Err(BatchStatus::WouldBlock));
        // Leftovers go in now that the ring drained.
        assert_eq!(q.insert_batch(&mut items), Ok(2));
        assert!(items.is_empty());
        out.clear();
        assert_eq!(q.read_batch(&mut out, 8), Ok(2));
        assert_eq!(out, vec![4, 5]);
    }

    #[test]
    fn batch_wraparound_preserves_fifo() {
        let q = RNbb::new(3);
        let mut next = 0u64;
        let mut expect = 0u64;
        for _ in 0..50 {
            let mut items: Vec<u64> = (next..next + 2).collect();
            next += q.insert_batch(&mut items).unwrap() as u64;
            let mut out = Vec::new();
            q.read_batch(&mut out, 2).unwrap();
            for v in out {
                assert_eq!(v, expect, "batch FIFO violated");
                expect += 1;
            }
        }
        assert_eq!(next, expect + q.len() as u64);
    }

    #[test]
    fn batch_and_scalar_ops_interleave() {
        let q = RNbb::new(8);
        q.insert(0u64).unwrap();
        let mut items = vec![1, 2, 3];
        assert_eq!(q.insert_batch(&mut items), Ok(3));
        assert_eq!(q.read(), ReadStatus::Ok(0));
        let mut out = Vec::new();
        assert_eq!(q.read_batch(&mut out, 2), Ok(2));
        assert_eq!(out, vec![1, 2]);
        assert_eq!(q.read(), ReadStatus::Ok(3));
        assert_eq!(q.read(), ReadStatus::Empty);
    }

    #[test]
    fn empty_batch_calls_are_noops() {
        let q = RNbb::<u64, RealWorld>::new(2);
        let mut none: Vec<u64> = Vec::new();
        assert_eq!(q.insert_batch(&mut none), Ok(0));
        let mut out = Vec::new();
        assert_eq!(q.read_batch(&mut out, 0), Ok(0));
        assert!(out.is_empty());
    }

    #[test]
    #[should_panic(expected = "capacity")]
    fn zero_capacity_rejected() {
        let _ = RNbb::<u8>::new(0);
    }

    #[test]
    fn drop_releases_buffered_items() {
        let item = Arc::new(());
        let q = RNbb::new(4);
        q.insert(item.clone()).map_err(|_| ()).unwrap();
        q.insert(item.clone()).map_err(|_| ()).unwrap();
        assert_eq!(Arc::strong_count(&item), 3);
        drop(q);
        assert_eq!(Arc::strong_count(&item), 1);
    }

    #[test]
    fn drop_releases_batch_inserted_items() {
        let item = Arc::new(());
        let q = RNbb::new(4);
        let mut items = vec![item.clone(), item.clone(), item.clone()];
        assert_eq!(q.insert_batch(&mut items), Ok(3));
        assert_eq!(Arc::strong_count(&item), 4);
        drop(q);
        assert_eq!(Arc::strong_count(&item), 1);
    }

    #[test]
    fn spsc_stress_preserves_fifo_and_loses_nothing() {
        const N: u64 = 200_000;
        let q = Arc::new(RNbb::<u64>::new(64));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..N {
                    q.insert_until(i);
                }
            })
        };
        let mut expected = 0u64;
        while expected < N {
            if let ReadStatus::Ok(v) = q.read() {
                assert_eq!(v, expected, "FIFO violated");
                expected += 1;
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
        assert_eq!(q.read(), ReadStatus::Empty);
    }

    #[test]
    fn spsc_batch_stress_preserves_fifo() {
        // Mixed batch sizes on both sides, concurrent threads: everything
        // arrives exactly once, in order.
        const N: u64 = 120_000;
        let q = Arc::new(RNbb::<u64>::new(32));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                let mut next = 0u64;
                let mut size = 1usize;
                while next < N {
                    let hi = (next + size as u64).min(N);
                    let mut items: Vec<u64> = (next..hi).collect();
                    while !items.is_empty() {
                        if q.insert_batch(&mut items).is_err() {
                            std::hint::spin_loop();
                        }
                    }
                    next = hi;
                    size = size % 7 + 1; // 1..=7, varies the batch shape
                }
            })
        };
        let mut expected = 0u64;
        let mut out = Vec::new();
        while expected < N {
            out.clear();
            if q.read_batch(&mut out, 5).is_ok() {
                for v in &out {
                    assert_eq!(*v, expected, "batch FIFO violated");
                    expected += 1;
                }
            } else {
                std::hint::spin_loop();
            }
        }
        producer.join().unwrap();
        assert_eq!(q.read(), ReadStatus::Empty);
    }

    #[test]
    fn torn_payloads_never_observed() {
        // Safety property: every item read must be one of the written
        // values in full (payload = value repeated, checked on read).
        const N: u64 = 50_000;
        let q = Arc::new(RNbb::<[u64; 4]>::new(8));
        let producer = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 1..=N {
                    q.insert_until([i, i.wrapping_mul(3), !i, i ^ 0xABCD]);
                }
            })
        };
        let mut got = 0;
        while got < N {
            if let ReadStatus::Ok([a, b, c, d]) = q.read() {
                assert_eq!(b, a.wrapping_mul(3));
                assert_eq!(c, !a);
                assert_eq!(d, a ^ 0xABCD);
                got += 1;
            }
        }
        producer.join().unwrap();
    }

    #[test]
    fn table1_statuses_in_sim() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        // In the deterministic simulator we can provoke BUFFER_FULL: the
        // reader (task 0) sleeps 10 us of virtual time before draining, so
        // the writer (task 1) finds the 1-slot ring occupied.
        let m = Machine::new(MachineCfg::new(
            2,
            OsProfile::linux_rt(),
            AffinityMode::PinnedSpread,
        ));
        let q = Arc::new(Nbb::<u64, SimWorld>::new(1));
        let q1 = q.clone();
        let reader = m.spawn(move || {
            <SimWorld as World>::work(10_000);
            let (v1, _) = q1.read_until();
            let (v2, _) = q1.read_until();
            assert_eq!((v1, v2), (1, 2));
        });
        let q2 = q.clone();
        let writer = m.spawn(move || {
            assert!(q2.insert(1).is_ok());
            let mut full_seen = false;
            let mut but_seen = false;
            let mut item = 2u64;
            loop {
                match q2.insert(item) {
                    Ok(()) => break,
                    Err((InsertStatus::Full, back)) => {
                        item = back;
                        full_seen = true;
                        SimWorld::yield_now();
                    }
                    Err((InsertStatus::FullButConsumerReading, back)) => {
                        item = back;
                        but_seen = true;
                        SimWorld::spin_hint();
                    }
                }
            }
            assert!(full_seen || but_seen, "writer never saw a Table 1 status");
        });
        m.run(vec![reader, writer]);
    }

    #[test]
    fn cached_counters_cut_shared_loads_in_sim() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        // Steady-state SPSC streaming on two cores: with cached peer
        // counters the shared-counter traffic must stay far below one
        // peer load per message (the seed did >= 2 loads per op).
        const N: u64 = 400;
        let m = Machine::new(MachineCfg::new(
            2,
            OsProfile::linux_rt(),
            AffinityMode::PinnedSpread,
        ));
        let q = Arc::new(Nbb::<u64, SimWorld>::new(64));
        let q1 = q.clone();
        let producer = m.spawn(move || {
            for i in 0..N {
                q1.insert_until(i);
            }
        });
        let q2 = q.clone();
        let consumer = m.spawn(move || {
            for i in 0..N {
                let (v, _) = q2.read_until();
                assert_eq!(v, i);
            }
        });
        let stats = m.run(vec![producer, consumer]);
        // Success path: 3 line accesses per insert (2 counter stores + 1
        // payload line) and 3-4 per read; the uncached seed datapath adds
        // 2 peer/own loads to every operation (>= 10/msg before failed
        // polls, which cost 2 loads each instead of 1). A budget of 10
        // separates the two designs with headroom for empty-poll noise.
        let per_msg = (stats.hits + stats.misses) as f64 / N as f64;
        assert!(
            per_msg < 10.0,
            "cached-counter NBB should average < 10 line accesses/msg, got {per_msg:.1} ({stats:?})"
        );
    }
}
