//! The memory-backend abstraction (`World`) the lock-free algorithms are
//! generic over.
//!
//! The paper's point is that the *same algorithms* behave differently on
//! single-core and multicore machines. To reproduce that on a host with
//! any core count, every algorithm in [`crate::lockfree`] and every MCAPI
//! backend is written against this trait and instantiated twice:
//!
//! * [`RealWorld`] — zero-cost passthrough to `std::sync::atomic`; this is
//!   the deployable library.
//! * [`crate::sim::SimWorld`] — every operation charges virtual time on
//!   the deterministic SMP simulator (cache-line directory, memory-bus
//!   queue, OS cost profile), reproducing the paper's testbed.
//!
//! The trait surface is deliberately small: 32/64-bit atoms with the
//! operations the paper's algorithms need (load/store/CAS/fetch-ops), a
//! blocking kernel lock, yield/delay, bulk payload `touch`, and a
//! monotonic clock for latency stamping.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

/// Pads (and aligns) a value to a full cache line to prevent false
/// sharing between adjacent hot words. The paper's Section 6 observes
/// that the exchange cost is dominated by cache-line *ownership
/// transfer*; when a producer-written counter and a consumer-written
/// counter share a line, every operation on either side ping-pongs the
/// line between cores even though the words are logically independent.
/// Every producer/consumer-split atomic pair in this crate ([`crate::
/// lockfree::nbb::Nbb`], [`crate::lockfree::nbw::Nbw`],
/// [`crate::lockfree::freelist::FreeList`],
/// [`crate::lockfree::bitset::BitSet`], `mrapi::rwlock::RwLock`) wraps
/// its sides in this type.
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct CachePadded<T>(pub T);

impl<T> CachePadded<T> {
    /// Wrap a value.
    pub const fn new(value: T) -> Self {
        CachePadded(value)
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

/// A 32-bit atomic cell.
pub trait Atom32: Send + Sync + 'static {
    /// New cell; in simulated worlds this also assigns a cache-line address.
    fn new(v: u32) -> Self;
    /// Acquire load.
    fn load(&self) -> u32;
    /// Relaxed load — same coherence cost as [`Atom32::load`] (the line
    /// still has to be present), but no ordering: for monitoring reads
    /// and protocol words whose consumers re-synchronize through another
    /// acquire load before dereferencing anything. Priced by simulated
    /// worlds exactly like `load` (unlike [`Atom32::peek`]).
    fn load_relaxed(&self) -> u32;
    /// Release store.
    fn store(&self, v: u32);
    /// AcqRel compare-and-swap; `Ok(previous)` on success, `Err(actual)`.
    fn cas(&self, current: u32, new: u32) -> Result<u32, u32>;
    /// AcqRel fetch-add (wrapping).
    fn fetch_add(&self, v: u32) -> u32;
    /// AcqRel fetch-or.
    fn fetch_or(&self, v: u32) -> u32;
    /// AcqRel fetch-and.
    fn fetch_and(&self, v: u32) -> u32;
    /// Raw relaxed load that bypasses cost accounting — ONLY for
    /// destructors and post-run inspection (sim worlds have no task
    /// context there). Not part of any algorithm's protocol.
    fn peek(&self) -> u32;
}

/// A 64-bit atomic cell (same contract as [`Atom32`]).
pub trait Atom64: Send + Sync + 'static {
    /// New cell.
    fn new(v: u64) -> Self;
    /// Acquire load.
    fn load(&self) -> u64;
    /// Relaxed load (see [`Atom32::load_relaxed`]).
    fn load_relaxed(&self) -> u64;
    /// Release store.
    fn store(&self, v: u64);
    /// AcqRel compare-and-swap.
    fn cas(&self, current: u64, new: u64) -> Result<u64, u64>;
    /// AcqRel fetch-add (wrapping).
    fn fetch_add(&self, v: u64) -> u64;
    /// AcqRel fetch-or.
    fn fetch_or(&self, v: u64) -> u64;
    /// AcqRel fetch-and.
    fn fetch_and(&self, v: u64) -> u64;
    /// Raw relaxed load bypassing cost accounting (see [`Atom32::peek`]).
    fn peek(&self) -> u64;
}

/// A blocking kernel-mode lock (what MRAPI builds its user-mode
/// synchronization on, and what the lock-based baseline pays for).
pub trait KernelLock: Send + Sync + 'static {
    /// New, unlocked.
    fn new() -> Self;
    /// Block until acquired.
    fn acquire(&self);
    /// Release; wakes one waiter if any.
    fn release(&self);
}

/// An execution world: atoms + kernel lock + scheduling hooks.
pub trait World: Sized + Send + Sync + 'static {
    /// 32-bit atom type.
    type U32: Atom32;
    /// 64-bit atom type.
    type U64: Atom64;
    /// Kernel lock type.
    type Lock: KernelLock;

    /// Give up the processor (MRAPI explicit context switch).
    fn yield_now();
    /// Busy-wait hint between immediate retries (Table 1 semantics).
    fn spin_hint();
    /// Charge a bulk payload access of `bytes` (message copy). Real world:
    /// no-op (the copy itself is the cost); sim world: cache/bus charges.
    fn touch(region: u64, bytes: usize, write: bool);
    /// Charge `ns` of pure CPU work (per-API-call overhead modelling).
    fn work(ns: u64);
    /// Monotonic nanoseconds (virtual in the sim world) for latency stamps.
    fn now_ns() -> u64;
    /// Unpriced timestamp peek for the observability plane (`src/obs/`):
    /// wall-clock nanoseconds in the real world, the calling task's
    /// virtual clock in the simulator — read *without charging any priced
    /// operation*, so instrumented hot paths stay byte-identical in the
    /// sim's coherence accounting. Returns 0 when no clock is reachable
    /// (sim world off-plane). Never use for protocol decisions; use
    /// [`World::now_ns`], which is priced on purpose.
    fn timestamp_peek() -> u64;
    /// Allocate a synthetic address region for a payload buffer, used with
    /// [`World::touch`] and as a parking token for [`World::futex_wait`].
    fn alloc_region(bytes: usize) -> u64;

    /// Park the calling thread on token `addr` while `still` holds, until
    /// a [`World::futex_wake`] on the same token or the optional absolute
    /// `deadline_ns` (in [`World::now_ns`] time) passes. May wake
    /// spuriously — callers loop, re-checking their condition and the
    /// clock (standard futex contract).
    ///
    /// `still` is evaluated race-free with respect to wakers. In
    /// simulated worlds it runs *inside* the machine monitor: it must not
    /// call any priced operation (use [`Atom32::peek`] / raw host
    /// atomics), or the monitor self-deadlocks.
    ///
    /// The default is a degenerate poll (one yield) for worlds without a
    /// parker — correct, just not idle-friendly.
    fn futex_wait(_addr: u64, _deadline_ns: Option<u64>, still: impl FnOnce() -> bool) {
        if still() {
            Self::yield_now();
        }
    }

    /// Wake up to `n` threads parked on token `addr`. Default: no-op
    /// (pairs with the polling default of [`World::futex_wait`]).
    fn futex_wake(_addr: u64, _n: usize) {}
}

// ---------------------------------------------------------------------------
// RealWorld: the deployable backend.
// ---------------------------------------------------------------------------

/// Passthrough to the host's real atomics and scheduler.
pub struct RealWorld;

/// `std::sync::atomic::AtomicU32` with the trait's fixed orderings.
#[repr(transparent)]
pub struct RealAtom32(AtomicU32);

impl Atom32 for RealAtom32 {
    #[inline]
    fn new(v: u32) -> Self {
        RealAtom32(AtomicU32::new(v))
    }
    #[inline]
    fn load(&self) -> u32 {
        self.0.load(Ordering::Acquire)
    }
    #[inline]
    fn load_relaxed(&self) -> u32 {
        self.0.load(Ordering::Relaxed)
    }
    #[inline]
    fn store(&self, v: u32) {
        self.0.store(v, Ordering::Release)
    }
    #[inline]
    fn cas(&self, current: u32, new: u32) -> Result<u32, u32> {
        self.0
            .compare_exchange(current, new, Ordering::AcqRel, Ordering::Acquire)
    }
    #[inline]
    fn fetch_add(&self, v: u32) -> u32 {
        self.0.fetch_add(v, Ordering::AcqRel)
    }
    #[inline]
    fn fetch_or(&self, v: u32) -> u32 {
        self.0.fetch_or(v, Ordering::AcqRel)
    }
    #[inline]
    fn fetch_and(&self, v: u32) -> u32 {
        self.0.fetch_and(v, Ordering::AcqRel)
    }
    #[inline]
    fn peek(&self) -> u32 {
        self.0.load(Ordering::Relaxed)
    }
}

/// `std::sync::atomic::AtomicU64` with the trait's fixed orderings.
#[repr(transparent)]
pub struct RealAtom64(AtomicU64);

impl Atom64 for RealAtom64 {
    #[inline]
    fn new(v: u64) -> Self {
        RealAtom64(AtomicU64::new(v))
    }
    #[inline]
    fn load(&self) -> u64 {
        self.0.load(Ordering::Acquire)
    }
    #[inline]
    fn load_relaxed(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
    #[inline]
    fn store(&self, v: u64) {
        self.0.store(v, Ordering::Release)
    }
    #[inline]
    fn cas(&self, current: u64, new: u64) -> Result<u64, u64> {
        self.0
            .compare_exchange(current, new, Ordering::AcqRel, Ordering::Acquire)
    }
    #[inline]
    fn fetch_add(&self, v: u64) -> u64 {
        self.0.fetch_add(v, Ordering::AcqRel)
    }
    #[inline]
    fn fetch_or(&self, v: u64) -> u64 {
        self.0.fetch_or(v, Ordering::AcqRel)
    }
    #[inline]
    fn fetch_and(&self, v: u64) -> u64 {
        self.0.fetch_and(v, Ordering::AcqRel)
    }
    #[inline]
    fn peek(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Futex-style blocking mutex over `Mutex<bool>` + `Condvar` (what an OS
/// kernel lock costs on the real host).
pub struct RealKernelLock {
    held: Mutex<bool>,
    cv: Condvar,
}

impl KernelLock for RealKernelLock {
    fn new() -> Self {
        RealKernelLock { held: Mutex::new(false), cv: Condvar::new() }
    }

    fn acquire(&self) {
        let mut held = self.held.lock().unwrap();
        while *held {
            held = self.cv.wait(held).unwrap();
        }
        *held = true;
    }

    fn release(&self) {
        let mut held = self.held.lock().unwrap();
        assert!(*held, "release of unheld kernel lock");
        *held = false;
        drop(held);
        self.cv.notify_one();
    }
}

/// Process-global parking table for [`RealWorld::futex_wait`]: one
/// `Mutex` + `Condvar` cell per token. The cell mutex is held across the
/// `still` check and the (atomic) condvar release, so a waker that
/// publishes its condition *before* calling `futex_wake` can never slip
/// between the check and the park — the standard futex no-lost-wakeup
/// argument.
struct ParkCell {
    m: Mutex<()>,
    cv: Condvar,
}

fn park_cell(addr: u64) -> std::sync::Arc<ParkCell> {
    use std::collections::HashMap;
    use std::sync::{Arc, OnceLock};
    static TABLE: OnceLock<Mutex<HashMap<u64, Arc<ParkCell>>>> = OnceLock::new();
    let table = TABLE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = table.lock().unwrap_or_else(|e| e.into_inner());
    map.entry(addr)
        .or_insert_with(|| Arc::new(ParkCell { m: Mutex::new(()), cv: Condvar::new() }))
        .clone()
}

impl World for RealWorld {
    type U32 = RealAtom32;
    type U64 = RealAtom64;
    type Lock = RealKernelLock;

    #[inline]
    fn yield_now() {
        std::thread::yield_now();
    }
    #[inline]
    fn spin_hint() {
        std::hint::spin_loop();
    }
    #[inline]
    fn touch(_region: u64, _bytes: usize, _write: bool) {}
    #[inline]
    fn work(_ns: u64) {}
    #[inline]
    fn now_ns() -> u64 {
        crate::os::monotonic_ns()
    }
    #[inline]
    fn timestamp_peek() -> u64 {
        // Real world: the clock read *is* free of model cost.
        crate::os::monotonic_ns()
    }
    fn alloc_region(bytes: usize) -> u64 {
        // Unique token space (cache-line granular like the sim) so
        // distinct primitives never share a parking cell.
        static NEXT: AtomicU64 = AtomicU64::new(0x1000);
        let lines = ((bytes + 63) / 64).max(1) as u64;
        NEXT.fetch_add(lines * 64, Ordering::Relaxed)
    }

    fn futex_wait(addr: u64, deadline_ns: Option<u64>, still: impl FnOnce() -> bool) {
        use std::time::Duration;
        let cell = park_cell(addr);
        let guard = cell.m.lock().unwrap_or_else(|e| e.into_inner());
        if !still() {
            return;
        }
        // Bound every park (1 ms when no deadline): callers loop anyway,
        // and a capped sleep turns any lost-wake bug into latency rather
        // than a hang.
        let now = Self::now_ns();
        let ns = deadline_ns.map_or(1_000_000, |d| d.saturating_sub(now).min(1_000_000));
        if ns == 0 {
            return;
        }
        let _ = cell.cv.wait_timeout(guard, Duration::from_nanos(ns));
    }

    fn futex_wake(addr: u64, n: usize) {
        let cell = park_cell(addr);
        let _g = cell.m.lock().unwrap_or_else(|e| e.into_inner());
        if n >= 2 {
            cell.cv.notify_all();
        } else if n == 1 {
            cell.cv.notify_one();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn real_atom32_ops() {
        let a = RealAtom32::new(5);
        assert_eq!(a.load(), 5);
        a.store(9);
        assert_eq!(a.fetch_add(1), 9);
        assert_eq!(a.load(), 10);
        assert_eq!(a.cas(10, 20), Ok(10));
        assert_eq!(a.cas(10, 30), Err(20));
        assert_eq!(a.fetch_or(0b100), 20);
        assert_eq!(a.fetch_and(0b100), 20 | 0b100);
        assert_eq!(a.load(), 0b100);
    }

    #[test]
    fn real_atom64_wrapping_add() {
        let a = RealAtom64::new(u64::MAX);
        a.fetch_add(1);
        assert_eq!(a.load(), 0);
    }

    #[test]
    fn relaxed_load_observes_stores() {
        let a = RealAtom64::new(7);
        assert_eq!(a.load_relaxed(), 7);
        a.store(9);
        assert_eq!(a.load_relaxed(), 9);
        let b = RealAtom32::new(1);
        b.store(2);
        assert_eq!(b.load_relaxed(), 2);
    }

    #[test]
    fn cache_padded_separates_lines() {
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), 64);
        assert!(std::mem::size_of::<CachePadded<RealAtom64>>() >= 64);
        // Two padded atoms in one struct must not share a line.
        struct Pair {
            a: CachePadded<RealAtom64>,
            b: CachePadded<RealAtom64>,
        }
        let p = Pair {
            a: CachePadded::new(RealAtom64::new(0)),
            b: CachePadded::new(RealAtom64::new(0)),
        };
        let pa = &p.a.0 as *const _ as usize;
        let pb = &p.b.0 as *const _ as usize;
        assert!(pa.abs_diff(pb) >= 64, "padded atoms share a cache line");
        // Deref passes method calls through to the wrapped atom.
        p.a.store(3);
        assert_eq!(p.a.load(), 3);
    }

    #[test]
    fn kernel_lock_mutual_exclusion() {
        let lock = Arc::new(RealKernelLock::new());
        let counter = Arc::new(AtomicU64::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let lock = lock.clone();
            let counter = counter.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    lock.acquire();
                    // Non-atomic read-modify-write under the lock.
                    let v = counter.load(Ordering::Relaxed);
                    counter.store(v + 1, Ordering::Relaxed);
                    lock.release();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(counter.load(Ordering::Relaxed), 4000);
    }

    #[test]
    #[should_panic(expected = "unheld")]
    fn kernel_lock_release_unheld_panics() {
        RealKernelLock::new().release();
    }

    #[test]
    fn real_futex_park_wake_roundtrip() {
        let addr = RealWorld::alloc_region(64);
        let flag = Arc::new(AtomicU32::new(0));
        let f2 = flag.clone();
        let waiter = std::thread::spawn(move || {
            let deadline = RealWorld::now_ns() + 2_000_000_000;
            while f2.load(Ordering::Acquire) == 0 {
                assert!(RealWorld::now_ns() < deadline, "wake never arrived");
                let f3 = f2.clone();
                RealWorld::futex_wait(addr, Some(deadline), move || {
                    f3.load(Ordering::Acquire) == 0
                });
            }
        });
        std::thread::sleep(std::time::Duration::from_millis(5));
        flag.store(1, Ordering::Release);
        RealWorld::futex_wake(addr, usize::MAX);
        waiter.join().unwrap();
    }

    #[test]
    fn real_futex_wait_respects_deadline() {
        let addr = RealWorld::alloc_region(64);
        let t0 = RealWorld::now_ns();
        // Nobody wakes this token; the capped timed wait must return.
        RealWorld::futex_wait(addr, Some(t0 + 2_000_000), || true);
        assert!(RealWorld::now_ns() >= t0);
    }

    #[test]
    fn real_alloc_region_is_unique() {
        let a = RealWorld::alloc_region(1);
        let b = RealWorld::alloc_region(1);
        assert_ne!(a, b, "parking tokens must not collide");
    }
}
