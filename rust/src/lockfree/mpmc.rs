//! Slot-sequence MPMC ring: the first structure in the repo where
//! *both* sides contend — N producers claim slots with a shared tail
//! CAS, M consumers claim with a shared head CAS, and per-slot
//! sequence words arbitrate publication (the Vyukov bounded-queue
//! design the lock-free survey arXiv:1302.2757 frames as the
//! practical MPMC baseline; Virtual-Link arXiv:2012.05181 makes the
//! case that a purpose-built MPMC cross-core queue beats naive CAS
//! loops on coherence traffic).
//!
//! Contrast with the SPSC [`super::ring::ChannelRing`]: that design's
//! single-owner counters need no RMW at all, which is why the 1:1
//! connected-channel fast path keeps it untouched. This ring exists
//! for the MCAPI multi-receiver endpoint profile
//! ([`crate::mcapi::queue::ConsumerGroup`]): work distribution across
//! M consumers, exactly-once per payload, unordered across consumers
//! (each consumer still observes its own claims in claim order).
//!
//! Protocol (per slot, position `p`, capacity `cap`):
//!
//! * `seq == p`          — free: the producer claiming `p` may write.
//! * `seq == p + 1`      — published: the consumer claiming `p` may read.
//! * `seq == p + cap`    — consumed: free again for position `p + cap`.
//!
//! A producer claims position `p` by CAS on `tail` (only after seeing
//! `seq == p`, so the CAS never claims an unconsumed slot); it writes
//! the payload, then publishes with a release store `seq = p + 1`. A
//! consumer mirrors this on `head`/`seq = p + cap`. Each sequence word
//! sits on its own [`CachePadded`] line so publication traffic never
//! false-shares with neighbouring slots, and every shared access is a
//! priced [`World`] atom — the simulator sees the full coherence cost.
//!
//! [`MpmcRing::send_batch`] amortizes the shared-counter CAS: one
//! `tail` CAS claims a verified-free *run* of k slots, then each slot
//! is published independently — batch growth costs only per-slot
//! lines, sim-asserted in `batched_claim_amortizes_shared_cas_in_sim`.
//!
//! ## Crash repair (chaos/PR 3 machinery)
//!
//! A task killed between claim and publish (or claim and consume)
//! wedges the ring for everyone — Vyukov positions are strictly
//! ordered, so one missing publication blocks every later consumer.
//! Repair relies on *claimant boards*: host-side (unpriced) per-slot
//! `AtomicU32` words recording who holds an open claim. The injected-
//! kill model makes the board exact: faults fire at priced-op *entry*
//! ([`crate::sim::machine`]), so the host store announcing a claim —
//! placed immediately after the winning CAS with no priced op between
//! — is kill-atomic with the claim itself, and the host clear after
//! the publishing store is kill-atomic with publication. The clear
//! uses `compare_exchange` against the owner's own stamp so a delayed
//! clear can never erase a successor's claim on the recycled slot.
//!
//! [`MpmcRing::repair_dead`] then:
//! * tombstones a dead *producer's* claimed-unpublished slot (length
//!   word [`TOMBSTONE`]; consumers skip it and free the slot), and
//! * salvages a dead *consumer's* claimed-unconsumed payload to a
//!   closure (the runtime re-enqueues it — the dead claim never
//!   completed, so exactly-once is preserved) and frees the slot.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, Ordering};

use super::mem::{Atom64, CachePadded, World};
use crate::obs;
use crate::obs::EventKind;

/// Length-word sentinel marking a repaired (tombstoned) slot:
/// consumers consume and skip it without surfacing a payload.
pub const TOMBSTONE: u32 = u32::MAX;

/// Why an MPMC operation made no progress.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MpmcError {
    /// Every slot in the claim window is unconsumed; retry after a
    /// consumer frees one.
    Full,
    /// No published payload at the head position; retry after a
    /// producer publishes (or a wedged claim is repaired).
    Empty,
}

/// Bounded MPMC ring with per-slot sequence arbitration. `who`
/// arguments stamp the claimant boards for crash repair — any stable
/// small id works (the MCAPI layer passes node ids); producer and
/// consumer boards are separate, so the id spaces may overlap.
pub struct MpmcRing<W: World> {
    /// Producer claim counter (next position to claim) — own line.
    tail: CachePadded<W::U64>,
    /// Consumer claim counter — own line.
    head: CachePadded<W::U64>,
    /// Per-slot sequence words, one padded line each (see protocol
    /// table above).
    seqs: Box<[CachePadded<W::U64>]>,
    /// Per-slot payload length in bytes ([`TOMBSTONE`] = repaired).
    lens: Box<[UnsafeCell<u32>]>,
    /// Slot payload bytes: `cap * slot_len`, contiguous.
    bytes: Box<[UnsafeCell<u8>]>,
    /// Synthetic per-slot region (length word + payload) for
    /// simulator cost accounting.
    regions: Box<[u64]>,
    /// Producer claimant board: `who + 1` while a producer holds an
    /// open claim on the slot, 0 otherwise. Host-side and unpriced —
    /// repair metadata must not perturb the priced protocol.
    writers: Box<[AtomicU32]>,
    /// Consumer claimant board, same contract.
    readers: Box<[AtomicU32]>,
    slot_len: usize,
    cap: u64,
    /// Observability channel id for trace events ([`obs::CH_NONE`]
    /// when unmounted). Host atomic, never priced.
    trace_id: AtomicU32,
}

unsafe impl<W: World> Send for MpmcRing<W> {}
unsafe impl<W: World> Sync for MpmcRing<W> {}

impl<W: World> MpmcRing<W> {
    /// Ring with `cap` slots of `slot_len` payload bytes each.
    /// `cap >= 2`: with one slot, "published at p" and "free for
    /// p + cap" collapse onto the same sequence value.
    pub fn new(cap: usize, slot_len: usize) -> Self {
        assert!(cap >= 2, "mpmc ring capacity must be >= 2");
        assert!(slot_len >= 1, "mpmc ring slot must hold at least one byte");
        let seqs = (0..cap)
            .map(|i| CachePadded::new(W::U64::new(i as u64)))
            .collect::<Vec<_>>();
        let lens = (0..cap).map(|_| UnsafeCell::new(0u32)).collect::<Vec<_>>();
        let bytes = (0..cap * slot_len)
            .map(|_| UnsafeCell::new(0u8))
            .collect::<Vec<_>>();
        let regions = (0..cap).map(|_| W::alloc_region(4 + slot_len)).collect::<Vec<_>>();
        let writers = (0..cap).map(|_| AtomicU32::new(0)).collect::<Vec<_>>();
        let readers = (0..cap).map(|_| AtomicU32::new(0)).collect::<Vec<_>>();
        MpmcRing {
            tail: CachePadded::new(W::U64::new(0)),
            head: CachePadded::new(W::U64::new(0)),
            seqs: seqs.into_boxed_slice(),
            lens: lens.into_boxed_slice(),
            bytes: bytes.into_boxed_slice(),
            regions: regions.into_boxed_slice(),
            writers: writers.into_boxed_slice(),
            readers: readers.into_boxed_slice(),
            slot_len,
            cap: cap as u64,
            trace_id: AtomicU32::new(obs::CH_NONE),
        }
    }

    /// Tag this ring with its endpoint id for trace events.
    pub fn set_trace_id(&self, id: u32) {
        self.trace_id.store(id, Ordering::Relaxed);
    }

    /// The channel id trace events carry ([`obs::CH_NONE`] = unmounted).
    pub fn trace_id(&self) -> u32 {
        self.trace_id.load(Ordering::Relaxed)
    }

    /// Ring capacity in slots.
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Payload bytes per slot.
    pub fn slot_len(&self) -> usize {
        self.slot_len
    }

    /// Claims outstanding (approximate under concurrency — claim
    /// counters, not completions; includes tombstones not yet
    /// skipped). Monitoring only: unpriced peeks, safe from watchdogs.
    pub fn len(&self) -> usize {
        let t = self.tail.peek();
        let h = self.head.peek();
        t.wrapping_sub(h) as usize
    }

    /// True when no claims are outstanding (monitoring only).
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Raw `(tail, head)` claim counters — unpriced peeks for
    /// watchdogs and post-run assertions.
    pub fn counters_peek(&self) -> (u64, u64) {
        (self.tail.peek(), self.head.peek())
    }

    /// Write `data` into slot `idx` with length word `len_word`
    /// (inside an open producer claim).
    fn write_slot(&self, idx: usize, data: &[u8], len_word: u32) {
        debug_assert!(data.len() <= self.slot_len, "payload exceeds mpmc slot");
        W::touch(self.regions[idx], 4 + data.len().max(1), true);
        unsafe {
            *self.lens[idx].get() = len_word;
            std::ptr::copy_nonoverlapping(
                data.as_ptr(),
                self.bytes[idx * self.slot_len].get(),
                data.len(),
            );
        }
    }

    /// Slot `idx` as a byte slice of its recorded length (inside an
    /// open consumer claim).
    ///
    /// # Safety
    /// Caller must hold the consumer claim on `idx` (won the head CAS
    /// for its position and not yet released the sequence word).
    unsafe fn slot_bytes(&self, idx: usize, len: usize) -> &[u8] {
        let len = len.min(self.slot_len);
        W::touch(self.regions[idx], 4 + len.max(1), false);
        std::slice::from_raw_parts(self.bytes[idx * self.slot_len].get() as *const u8, len)
    }

    /// Stamp the claimant board for `idx` (host-side, kill-atomic with
    /// the claim CAS that immediately precedes it).
    #[inline]
    fn announce(board: &AtomicU32, who: u32) {
        board.store(who.wrapping_add(1), Ordering::Relaxed);
    }

    /// Clear the board only if it still carries our stamp — a delayed
    /// clear must never erase a successor's claim on the recycled slot.
    #[inline]
    fn retract(board: &AtomicU32, who: u32) {
        let _ = board.compare_exchange(
            who.wrapping_add(1),
            0,
            Ordering::Relaxed,
            Ordering::Relaxed,
        );
    }

    /// Producer side: claim one slot, copy `data` in, publish.
    ///
    /// # Panics
    /// If `data` exceeds `slot_len` (caller bug; the MCAPI layer maps
    /// oversize to `MessageLimit` before calling).
    pub fn send(&self, who: u32, data: &[u8]) -> Result<(), MpmcError> {
        assert!(data.len() <= self.slot_len, "payload exceeds mpmc slot");
        let mut pos = self.tail.load_relaxed();
        loop {
            let idx = (pos % self.cap) as usize;
            let seq = self.seqs[idx].load();
            if seq == pos {
                match self.tail.cas(pos, pos + 1) {
                    Ok(_) => {
                        // Claim won. Announce before any other priced
                        // op so a kill inside the write window is
                        // repairable (see module doc).
                        Self::announce(&self.writers[idx], who);
                        if obs::tracing() {
                            obs::emit::<W>(EventKind::MpmcClaim, self.trace_id(), pos, 1);
                        }
                        self.write_slot(idx, data, data.len() as u32);
                        self.seqs[idx].store(pos + 1); // publish
                        Self::retract(&self.writers[idx], who);
                        if obs::tracing() {
                            obs::emit::<W>(
                                EventKind::MpmcPublish,
                                self.trace_id(),
                                pos,
                                data.len() as u32,
                            );
                            obs::bump(obs::ctr::MPMC_PUBLISH);
                        }
                        return Ok(());
                    }
                    Err(actual) => {
                        pos = actual;
                        W::spin_hint();
                    }
                }
            } else if seq < pos {
                // Previous generation not yet consumed: full.
                return Err(MpmcError::Full);
            } else {
                // Another producer already claimed this position —
                // our tail snapshot is stale.
                pos = self.tail.load_relaxed();
            }
        }
    }

    /// Producer side: claim a verified-free *run* of up to
    /// `payloads.len()` slots with **one** tail CAS, then publish each
    /// slot independently. Returns how many went in; `Err(Full)` only
    /// when there was room for none.
    ///
    /// # Panics
    /// If any payload exceeds `slot_len` (checked up front).
    pub fn send_batch(&self, who: u32, payloads: &[&[u8]]) -> Result<usize, MpmcError> {
        if payloads.is_empty() {
            return Ok(0);
        }
        assert!(
            payloads.iter().all(|d| d.len() <= self.slot_len),
            "payload exceeds mpmc slot"
        );
        let mut pos = self.tail.load_relaxed();
        loop {
            let idx0 = (pos % self.cap) as usize;
            let s0 = self.seqs[idx0].load();
            if s0 != pos {
                if s0 < pos {
                    return Err(MpmcError::Full);
                }
                pos = self.tail.load_relaxed();
                continue;
            }
            // Extend the run while slots stay free (bounded by the
            // batch and one lap — a run can never wrap onto itself).
            let mut k = 1usize;
            while k < payloads.len() && (k as u64) < self.cap {
                let idx = ((pos + k as u64) % self.cap) as usize;
                if self.seqs[idx].load() != pos + k as u64 {
                    break;
                }
                k += 1;
            }
            match self.tail.cas(pos, pos + k as u64) {
                Ok(_) => {
                    if obs::tracing() {
                        obs::emit::<W>(EventKind::MpmcClaim, self.trace_id(), pos, k as u32);
                    }
                    for (i, data) in payloads[..k].iter().enumerate() {
                        let p = pos + i as u64;
                        let idx = (p % self.cap) as usize;
                        Self::announce(&self.writers[idx], who);
                        self.write_slot(idx, data, data.len() as u32);
                        self.seqs[idx].store(p + 1);
                        Self::retract(&self.writers[idx], who);
                        if obs::tracing() {
                            obs::emit::<W>(
                                EventKind::MpmcPublish,
                                self.trace_id(),
                                p,
                                data.len() as u32,
                            );
                        }
                    }
                    if obs::tracing() {
                        obs::add(obs::ctr::MPMC_PUBLISH, k as u64);
                    }
                    return Ok(k);
                }
                Err(actual) => {
                    pos = actual;
                    W::spin_hint();
                }
            }
        }
    }

    /// Consumer side: claim the next published payload and consume it
    /// **in place** — `f` sees the slot bytes directly. Tombstoned
    /// slots (dead-producer repairs) are consumed and skipped
    /// transparently.
    ///
    /// Empty-poll cost is O(1) words: one head load + one sequence
    /// load, independent of capacity, producers, and consumers
    /// (sim-asserted in `tests/mpmc_properties.rs`).
    pub fn recv_with<R>(&self, who: u32, f: impl FnOnce(&[u8]) -> R) -> Result<R, MpmcError> {
        let mut f = Some(f);
        let mut pos = self.head.load_relaxed();
        loop {
            let idx = (pos % self.cap) as usize;
            let seq = self.seqs[idx].load();
            if seq == pos + 1 {
                match self.head.cas(pos, pos + 1) {
                    Ok(_) => {
                        Self::announce(&self.readers[idx], who);
                        if obs::tracing() {
                            obs::emit::<W>(EventKind::MpmcSteal, self.trace_id(), pos, 0);
                        }
                        W::touch(self.regions[idx], 4, false);
                        let len = unsafe { *self.lens[idx].get() };
                        if len == TOMBSTONE {
                            // Dead-producer repair: free the slot and
                            // keep looking.
                            self.seqs[idx].store(pos + self.cap);
                            Self::retract(&self.readers[idx], who);
                            pos = self.head.load_relaxed();
                            continue;
                        }
                        let r = {
                            let b = unsafe { self.slot_bytes(idx, len as usize) };
                            (f.take().expect("mpmc closure consumed twice"))(b)
                        };
                        self.seqs[idx].store(pos + self.cap); // release
                        Self::retract(&self.readers[idx], who);
                        if obs::tracing() {
                            obs::bump(obs::ctr::MPMC_CONSUME);
                        }
                        return Ok(r);
                    }
                    Err(_) => {
                        pos = self.head.load_relaxed();
                        W::spin_hint();
                    }
                }
            } else if seq <= pos {
                // Not yet published at this position. (A wedged dead-
                // producer claim also parks consumers here until
                // repair_dead tombstones it — positions are strictly
                // ordered.)
                return Err(MpmcError::Empty);
            } else {
                // Already claimed past us — stale head snapshot.
                pos = self.head.load_relaxed();
            }
        }
    }

    /// Consumer side: copy the next payload into `out`; returns the
    /// byte count copied (`min(payload len, out.len())`).
    pub fn recv(&self, who: u32, out: &mut [u8]) -> Result<usize, MpmcError> {
        self.recv_with(who, |b| {
            let n = b.len().min(out.len());
            out[..n].copy_from_slice(&b[..n]);
            n
        })
    }

    /// Consumer side: claim a run of up to `max` published slots with
    /// one head CAS and append the payloads to `out` (tombstones are
    /// consumed silently). Returns how many were appended — `Ok(0)` is
    /// possible when the claimed run was all tombstones.
    pub fn recv_batch(
        &self,
        who: u32,
        out: &mut Vec<Vec<u8>>,
        max: usize,
    ) -> Result<usize, MpmcError> {
        if max == 0 {
            return Ok(0);
        }
        let mut pos = self.head.load_relaxed();
        loop {
            let idx0 = (pos % self.cap) as usize;
            let s0 = self.seqs[idx0].load();
            if s0 != pos + 1 {
                if s0 <= pos {
                    return Err(MpmcError::Empty);
                }
                pos = self.head.load_relaxed();
                continue;
            }
            let mut k = 1usize;
            while k < max && (k as u64) < self.cap {
                let idx = ((pos + k as u64) % self.cap) as usize;
                if self.seqs[idx].load() != pos + k as u64 + 1 {
                    break;
                }
                k += 1;
            }
            match self.head.cas(pos, pos + k as u64) {
                Ok(_) => {
                    let mut appended = 0usize;
                    for i in 0..k as u64 {
                        let p = pos + i;
                        let idx = (p % self.cap) as usize;
                        Self::announce(&self.readers[idx], who);
                        if obs::tracing() {
                            obs::emit::<W>(EventKind::MpmcSteal, self.trace_id(), p, 0);
                        }
                        W::touch(self.regions[idx], 4, false);
                        let len = unsafe { *self.lens[idx].get() };
                        if len != TOMBSTONE {
                            out.push(unsafe { self.slot_bytes(idx, len as usize) }.to_vec());
                            appended += 1;
                        }
                        self.seqs[idx].store(p + self.cap);
                        Self::retract(&self.readers[idx], who);
                    }
                    if obs::tracing() && appended > 0 {
                        obs::add(obs::ctr::MPMC_CONSUME, appended as u64);
                    }
                    return Ok(appended);
                }
                Err(_) => {
                    pos = self.head.load_relaxed();
                    W::spin_hint();
                }
            }
        }
    }

    /// Repair every claim the dead peer `who` left open: tombstone its
    /// claimed-unpublished producer slots (consumers will skip them)
    /// and salvage its claimed-unconsumed payloads to `salvage` (the
    /// caller re-enqueues them; the dead claim never completed, so
    /// exactly-once is preserved). Returns `(tombstoned, salvaged)`.
    ///
    /// Soundness: the claimant boards are stamped kill-atomically with
    /// the claim CAS and retracted kill-atomically with the release
    /// store (module doc), so `board == who + 1` identifies exactly
    /// the wedged claims — and a wedged claim blocks all later
    /// positions on its slot, so nobody can race the repair's
    /// sequence store. Call after the peer is dead (its thread
    /// unwound), never concurrently with the peer.
    pub fn repair_dead(&self, who: u32, mut salvage: impl FnMut(&[u8])) -> (usize, usize) {
        let stamp = who.wrapping_add(1);
        let mut tombstoned = 0usize;
        let mut salvaged = 0usize;
        for idx in 0..self.cap as usize {
            if self.writers[idx].load(Ordering::Relaxed) == stamp {
                // Claimed-unpublished: seq still equals the claimed
                // position p (and p maps to this slot).
                let p = self.seqs[idx].load();
                if (p % self.cap) as usize == idx && p < self.tail.load_relaxed() {
                    W::touch(self.regions[idx], 4, true);
                    unsafe {
                        *self.lens[idx].get() = TOMBSTONE;
                    }
                    self.seqs[idx].store(p + 1); // publish the tombstone
                    self.writers[idx].store(0, Ordering::Relaxed);
                    tombstoned += 1;
                }
            }
            if self.readers[idx].load(Ordering::Relaxed) == stamp {
                // Claimed-unconsumed: seq still equals p + 1 for the
                // claimed position p.
                let s = self.seqs[idx].load();
                if s >= 1 {
                    let p = s - 1;
                    if (p % self.cap) as usize == idx && p < self.head.load_relaxed() {
                        W::touch(self.regions[idx], 4, false);
                        let len = unsafe { *self.lens[idx].get() };
                        if len != TOMBSTONE {
                            let b = unsafe { self.slot_bytes(idx, len as usize) };
                            salvage(b);
                            salvaged += 1;
                        }
                        self.seqs[idx].store(p + self.cap); // free the slot
                        self.readers[idx].store(0, Ordering::Relaxed);
                    }
                }
            }
        }
        if obs::tracing() && tombstoned + salvaged > 0 {
            obs::add(obs::ctr::MPMC_REPAIRS, (tombstoned + salvaged) as u64);
        }
        (tombstoned, salvaged)
    }

    /// Test hook: win a producer claim on the next position and
    /// abandon it unpublished, as a task killed mid-`send` would —
    /// drives the repair path without a full fault-injected machine.
    #[cfg(test)]
    pub(crate) fn claim_and_abandon_producer(&self, who: u32) -> bool {
        let pos = self.tail.load_relaxed();
        let idx = (pos % self.cap) as usize;
        if self.seqs[idx].load() != pos {
            return false;
        }
        if self.tail.cas(pos, pos + 1).is_err() {
            return false;
        }
        Self::announce(&self.writers[idx], who);
        true
    }

    /// Test hook: win a consumer claim on the next published position
    /// and abandon it unconsumed, as a task killed mid-`recv` would.
    #[cfg(test)]
    pub(crate) fn claim_and_abandon_consumer(&self, who: u32) -> bool {
        let pos = self.head.load_relaxed();
        let idx = (pos % self.cap) as usize;
        if self.seqs[idx].load() != pos + 1 {
            return false;
        }
        if self.head.cas(pos, pos + 1).is_err() {
            return false;
        }
        Self::announce(&self.readers[idx], who);
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::{Arc, Mutex};

    #[test]
    fn single_thread_roundtrip_is_fifo() {
        let r = MpmcRing::<RealWorld>::new(4, 16);
        assert_eq!(r.recv_with(0, |_| ()), Err(MpmcError::Empty));
        for i in 0..4u64 {
            r.send(0, &i.to_le_bytes()).unwrap();
        }
        assert_eq!(r.send(0, b"overflow"), Err(MpmcError::Full));
        for i in 0..4u64 {
            let v = r
                .recv_with(9, |b| u64::from_le_bytes(b[..8].try_into().unwrap()))
                .unwrap();
            assert_eq!(v, i);
        }
        assert_eq!(r.recv_with(9, |_| ()), Err(MpmcError::Empty));
        // Wrap across many laps.
        for lap in 0..100u64 {
            r.send(1, &lap.to_le_bytes()).unwrap();
            let mut out = [0u8; 16];
            assert_eq!(r.recv(2, &mut out), Ok(8));
            assert_eq!(u64::from_le_bytes(out[..8].try_into().unwrap()), lap);
        }
        assert!(r.is_empty());
    }

    #[test]
    fn batch_claim_roundtrip_and_partial() {
        let r = MpmcRing::<RealWorld>::new(8, 16);
        let payloads: Vec<Vec<u8>> = (0..6u64).map(|i| i.to_le_bytes().to_vec()).collect();
        let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
        assert_eq!(r.send_batch(0, &refs), Ok(6));
        // Only 2 slots free: a batch of 6 goes partially in.
        assert_eq!(r.send_batch(0, &refs), Ok(2));
        assert_eq!(r.send_batch(0, &refs), Err(MpmcError::Full));
        let mut out = Vec::new();
        assert_eq!(r.recv_batch(1, &mut out, 16), Ok(8));
        let got: Vec<u64> = out
            .iter()
            .map(|b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5, 0, 1]);
        assert_eq!(r.recv_batch(1, &mut out, 16), Err(MpmcError::Empty));
        assert_eq!(r.send_batch(0, &[]), Ok(0));
        assert_eq!(r.recv_batch(1, &mut out, 0), Ok(0));
    }

    #[test]
    fn capacity_below_two_rejected() {
        let res = std::panic::catch_unwind(|| MpmcRing::<RealWorld>::new(1, 16));
        assert!(res.is_err(), "cap=1 collapses published/free states");
    }

    #[test]
    fn dead_producer_tombstone_unwedges_consumers() {
        let r = MpmcRing::<RealWorld>::new(4, 16);
        r.send(0, &1u64.to_le_bytes()).unwrap();
        // Producer 7 claims position 1 and dies before publishing;
        // producer 0 publishes position 2 behind the wedge.
        assert!(r.claim_and_abandon_producer(7));
        r.send(0, &3u64.to_le_bytes()).unwrap();
        // Position 0 delivers, then the wedge parks everyone.
        let v = r
            .recv_with(9, |b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .unwrap();
        assert_eq!(v, 1);
        assert_eq!(r.recv_with(9, |_| ()), Err(MpmcError::Empty));
        let (tomb, salv) = r.repair_dead(7, |_| panic!("nothing to salvage"));
        assert_eq!((tomb, salv), (1, 0));
        // The tombstone is skipped transparently; position 2 delivers.
        let v = r
            .recv_with(9, |b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .unwrap();
        assert_eq!(v, 3);
        // Ring stays usable across the repaired slot for many laps.
        for lap in 0..12u64 {
            r.send(0, &lap.to_le_bytes()).unwrap();
            let got = r
                .recv_with(9, |b| u64::from_le_bytes(b[..8].try_into().unwrap()))
                .unwrap();
            assert_eq!(got, lap);
        }
    }

    #[test]
    fn dead_consumer_salvage_preserves_payload_exactly_once() {
        let r = MpmcRing::<RealWorld>::new(4, 16);
        for i in 0..3u64 {
            r.send(0, &(100 + i).to_le_bytes()).unwrap();
        }
        // Consumer 5 claims position 0 and dies before consuming.
        assert!(r.claim_and_abandon_consumer(5));
        // A live consumer still gets positions 1 and 2.
        let mut live = Vec::new();
        while let Ok(v) = r.recv_with(6, |b| u64::from_le_bytes(b[..8].try_into().unwrap())) {
            live.push(v);
        }
        assert_eq!(live, vec![101, 102]);
        let salvaged = Arc::new(Mutex::new(Vec::new()));
        let s2 = salvaged.clone();
        let (tomb, salv) = r.repair_dead(5, move |b| {
            s2.lock()
                .unwrap()
                .push(u64::from_le_bytes(b[..8].try_into().unwrap()));
        });
        assert_eq!((tomb, salv), (0, 1));
        assert_eq!(*salvaged.lock().unwrap(), vec![100]);
        // The salvaged slot is free again: re-enqueue works.
        r.send(0, &100u64.to_le_bytes()).unwrap();
        let v = r
            .recv_with(6, |b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .unwrap();
        assert_eq!(v, 100);
        assert!(r.is_empty());
    }

    #[test]
    fn repair_for_live_peers_is_a_noop() {
        let r = MpmcRing::<RealWorld>::new(4, 16);
        r.send(3, &7u64.to_le_bytes()).unwrap();
        assert_eq!(r.repair_dead(3, |_| panic!("no wedged claim")), (0, 0));
        assert_eq!(r.repair_dead(99, |_| panic!("no wedged claim")), (0, 0));
        let v = r
            .recv_with(1, |b| u64::from_le_bytes(b[..8].try_into().unwrap()))
            .unwrap();
        assert_eq!(v, 7);
    }

    #[test]
    fn mpmc_threads_deliver_exactly_once() {
        // 3 producers × 3 consumers on real threads: every payload
        // arrives exactly once (set equality), unordered across
        // consumers.
        const PRODUCERS: u64 = 3;
        const CONSUMERS: usize = 3;
        const PER: u64 = 2000;
        let r = Arc::new(MpmcRing::<RealWorld>::new(16, 16));
        let got = Arc::new(Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for p in 0..PRODUCERS {
            let r = r.clone();
            handles.push(std::thread::spawn(move || {
                for j in 0..PER {
                    let v = p * PER + j;
                    while r.send(p as u32, &v.to_le_bytes()).is_err() {
                        std::hint::spin_loop();
                    }
                }
            }));
        }
        let total = PRODUCERS * PER;
        let taken = Arc::new(std::sync::atomic::AtomicU64::new(0));
        for c in 0..CONSUMERS {
            let r = r.clone();
            let got = got.clone();
            let taken = taken.clone();
            handles.push(std::thread::spawn(move || {
                let mut mine = Vec::new();
                while taken.load(Ordering::Relaxed) < total {
                    match r.recv_with(10 + c as u32, |b| {
                        u64::from_le_bytes(b[..8].try_into().unwrap())
                    }) {
                        Ok(v) => {
                            mine.push(v);
                            taken.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => std::hint::spin_loop(),
                    }
                }
                got.lock().unwrap().extend(mine);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = got.lock().unwrap().clone();
        all.sort_unstable();
        let expect: Vec<u64> = (0..total).collect();
        assert_eq!(all, expect, "lost or duplicated payloads");
    }

    #[test]
    fn empty_poll_is_two_priced_loads_in_sim() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        // Acceptance gate: an idle MPMC consumer pays one head load +
        // one sequence load per poll — O(1) words, independent of
        // capacity (and therefore of producer/consumer count).
        let poll_ops = |cap: usize| {
            let m = Machine::new(MachineCfg::new(
                1,
                OsProfile::linux_rt(),
                AffinityMode::SingleCore,
            ));
            let ops = Arc::new(std::sync::atomic::AtomicU64::new(0));
            let ops2 = ops.clone();
            let h = m.spawn(move || {
                let r = MpmcRing::<SimWorld>::new(cap, 32);
                let before = SimWorld::op_count();
                for _ in 0..10 {
                    assert_eq!(r.recv_with(0, |_| ()), Err(MpmcError::Empty));
                }
                ops2.store(SimWorld::op_count() - before, Ordering::SeqCst);
            });
            m.run(vec![h]);
            ops.load(Ordering::SeqCst)
        };
        let small = poll_ops(2);
        let large = poll_ops(512);
        assert_eq!(small, 20, "empty poll must cost exactly 2 priced loads");
        assert_eq!(small, large, "empty-poll cost must not scale with capacity");
    }

    #[test]
    fn batched_claim_amortizes_shared_cas_in_sim() {
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        // Acceptance gate: one tail CAS claims the whole batch — per
        // payload, the batch path saves exactly the tail load + tail
        // CAS that the one-at-a-time path pays.
        let send_ops = |batch: bool| {
            const K: u64 = 8;
            let m = Machine::new(MachineCfg::new(
                1,
                OsProfile::linux_rt(),
                AffinityMode::SingleCore,
            ));
            let ops = Arc::new(std::sync::atomic::AtomicU64::new(0));
            let ops2 = ops.clone();
            let h = m.spawn(move || {
                let r = MpmcRing::<SimWorld>::new(16, 32);
                let payloads: Vec<Vec<u8>> =
                    (0..K).map(|i| i.to_le_bytes().to_vec()).collect();
                let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
                let before = SimWorld::op_count();
                if batch {
                    assert_eq!(r.send_batch(0, &refs), Ok(K as usize));
                } else {
                    for d in &refs {
                        r.send(0, d).unwrap();
                    }
                }
                ops2.store(SimWorld::op_count() - before, Ordering::SeqCst);
            });
            m.run(vec![h]);
            ops.load(Ordering::SeqCst)
        };
        let singles = send_ops(false);
        let batched = send_ops(true);
        assert!(
            batched < singles,
            "batched claim must be cheaper ({batched} vs {singles})"
        );
        assert_eq!(
            singles - batched,
            2 * (8 - 1),
            "batch must save exactly one tail load + one tail CAS per extra payload"
        );
    }
}
