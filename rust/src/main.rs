//! `mcapi` — CLI for the lock-free MCAPI reproduction.
//!
//! Subcommands:
//!
//! * `stress`      — run a stress topology (built-in or from a TOML file)
//!   on the simulator or the real host and print the report.
//! * `experiment`  — regenerate the paper's evaluation artifacts:
//!   `table2`, `fig7`, `fig8`.
//! * `model`       — run the Section 5 performance model: `fig6`
//!   (artifact sweep + analytic cross-check), `stopcrit`.
//! * `chaos`       — fault-injection gate: seeded kill/stall plans, a
//!   full kill-point sweep, a delay sweep with the liveness watchdog
//!   armed (no false positives allowed), or the real-thread abandonment
//!   scenario (watchdog-only recovery), all with recovery-invariant
//!   checking and reproducible reports. Exits non-zero on failure.
//! * `trace`       — run a workload with the observability plane armed:
//!   per-stage latency attribution, NDJSON / chrome-trace / metrics
//!   exports, and the event-stream replay verdict. Exits non-zero when
//!   the replay check fails.
//! * `info`        — platform/runtime information.

use mcapi::coordinator::abandon::run_abandon_seeded;
use mcapi::coordinator::chaos::{
    run_delay_sweep, run_kill_sweep, run_seeded, ChaosOpts, Scenario, Victim,
};
use mcapi::coordinator::experiment::{print_fig7, print_fig8, print_table2, Matrix};
use mcapi::coordinator::{
    run_stress_real, run_stress_sim, run_traced_chaos, run_traced_stress, MsgKind, StressOpts,
    Topology, TraceOpts,
};
use mcapi::mcapi::types::{BackendKind, RuntimeCfg};
use mcapi::model::{stop_criterion, QpnModel, Workload};
use mcapi::os::{AffinityMode, OsProfile};
use mcapi::runtime::PjrtRuntime;
use mcapi::sim::{Machine, MachineCfg};
use mcapi::util::args::Args;

fn main() {
    let args = match Args::from_env() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            2
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> mcapi::Result<()> {
    match args.command.as_deref() {
        Some("stress") => cmd_stress(args),
        Some("experiment") => cmd_experiment(args),
        Some("model") => cmd_model(args),
        Some("chaos") => cmd_chaos(args),
        Some("trace") => cmd_trace(args),
        Some("info") => cmd_info(args),
        Some(other) => {
            eprintln!("unknown command `{other}`");
            usage();
            Ok(())
        }
        None => {
            usage();
            Ok(())
        }
    }
}

fn usage() {
    println!(
        "usage: mcapi <command> [options]\n\
         \n\
         commands:\n\
         \x20 stress      [topology.toml] --kind message|packet|scalar --tx N\n\
         \x20             --backend locked|lockfree --plane sim|real\n\
         \x20             --batch N (payloads per call: messages, packets, scalars)\n\
         \x20             --cores N --os linux|windows --affinity single|task|affinity\n\
         \x20 experiment  table2|fig7|fig8 [--tx N]\n\
         \x20 model       fig6 [--kind K] [--solver artifact|native|sweep] | stopcrit [--measured-ns X]\n\
         \x20 chaos       --faults seed=N | --seed N [--scenario pkt|msg] [--msgs N]\n\
         \x20             --sweep [--victim prod|cons] (kill at every priced op in the window)\n\
         \x20             --sweep-delay [--delay-ns N] (delay at every priced op; the armed\n\
         \x20             watchdog must never declare the delayed-but-alive victim dead)\n\
         \x20             --abandon (real-thread abandonment: OS thread parks forever, the\n\
         \x20             heartbeat watchdog alone must detect, fence and recover it)\n\
         \x20 trace       --kind message|packet|scalar --tx N --plane sim|real\n\
         \x20             --cores N --batch N [--chaos-seed N] [--out PREFIX]\n\
         \x20             (writes PREFIX.chrome.json / .ndjson / .metrics.json)\n\
         \x20 info"
    );
}

fn cmd_stress(args: &Args) -> mcapi::Result<()> {
    let kind = MsgKind::parse(&args.get_or("kind", "message"))
        .ok_or_else(|| mcapi::Error::Config("bad --kind".into()))?;
    let tx = args.get_u64_or("tx", 1000)?;
    let backend = BackendKind::parse(&args.get_or("backend", "lockfree"))
        .ok_or_else(|| mcapi::Error::Config("bad --backend".into()))?;
    let plane = args.get_or("plane", "sim");
    let cores = args.get_u64_or("cores", 4)? as usize;
    let os = OsProfile::parse(&args.get_or("os", "linux"))
        .ok_or_else(|| mcapi::Error::Config("bad --os".into()))?;
    let affinity = AffinityMode::parse(&args.get_or("affinity", "affinity"))
        .ok_or_else(|| mcapi::Error::Config("bad --affinity".into()))?;
    let batch = args.get_u64_or("batch", 1)? as usize;
    args.finish()?;

    let topo = match args.positional.first() {
        Some(path) => Topology::parse(&std::fs::read_to_string(path)?)?,
        None => Topology::one_way(kind, tx),
    };
    let cfg = RuntimeCfg::with_backend(backend);
    let opts = StressOpts::with_batch(batch);
    let report = match plane.as_str() {
        "real" => run_stress_real(cfg, &topo, opts),
        "sim" => {
            let machine = Machine::new(MachineCfg::new(cores, os, affinity));
            run_stress_sim(&machine, cfg, &topo, opts)
        }
        other => return Err(mcapi::Error::Config(format!("bad --plane `{other}`"))),
    };
    println!("plane={plane} backend={} cells:", backend.label());
    println!("  delivered      : {}", report.delivered);
    println!("  elapsed        : {} ns", report.elapsed_ns);
    println!("  throughput     : {:.1} kmsg/s", report.kmsgs_per_s());
    println!("  latency mean   : {:.0} ns", report.latency_mean_ns());
    println!(
        "  latency p50/p99/p999: {} / {} / {} ns",
        report.latency.p50(),
        report.latency.p99(),
        report.latency.p999()
    );
    println!("  yields         : {}", report.yields);
    println!("  order errors   : {}", report.order_violations);
    println!(
        "  robustness     : timeouts={} poisons={} leases_reclaimed={}",
        report.timeouts, report.poisons, report.leases_reclaimed
    );
    if let Some(s) = report.sim {
        println!(
            "  sim: misses={} hits={} ctx={} syscalls={} bus_util={:.2}",
            s.misses,
            s.hits,
            s.ctx_switches,
            s.syscalls,
            s.bus_utilization()
        );
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> mcapi::Result<()> {
    let tx = args.get_u64_or("tx", 1000)?;
    args.finish()?;
    let matrix = Matrix::new(tx);
    match args.positional.first().map(|s| s.as_str()) {
        Some("table2") => {
            println!("Table 2 — lock-based MCAPI multicore penalty (throughput speedup)\n");
            println!("{}", print_table2(&matrix.table2()));
        }
        Some("fig7") => {
            println!("Figure 7 — MCAPI data exchange throughput performance\n");
            println!("{}", print_fig7(&matrix.fig7()));
        }
        Some("fig8") => {
            println!("Figure 8 — lock-free MCAPI speedup (latency speedup at lock-free throughput)\n");
            println!("{}", print_fig8(&matrix.fig8()));
        }
        other => {
            return Err(mcapi::Error::Config(format!(
                "experiment needs table2|fig7|fig8, got {other:?}"
            )))
        }
    }
    Ok(())
}

fn cmd_model(args: &Args) -> mcapi::Result<()> {
    match args.positional.first().map(|s| s.as_str()) {
        Some("fig6") => {
            let kind = args.get_or("kind", "message");
            let solver = args.get_or("solver", "artifact");
            args.finish()?;
            let w = Workload::by_name(&kind)
                .ok_or_else(|| mcapi::Error::Config("bad --kind".into()))?;
            let hits = QpnModel::default_hits();
            println!(
                "Figure 6 — QPN model ({kind}, solver={solver}): utilization / throughput% vs hit rate\n"
            );
            println!("| hit rate | cores | bus util | throughput (% of target) | X (kmsg/s) |");
            println!("|---|---|---|---|---|");
            if solver == "native" {
                for &c in &[1u32, 2] {
                    for &h in &hits {
                        let scaled = Workload { z: w.z * c as f64, ..w };
                        let r = mcapi::model::analytic::mva(&scaled, h, c);
                        println!(
                            "| {h:.2} | {c} | {:.3} | {:.1}% | {:.1} |",
                            r.utilization,
                            r.target_fraction * 100.0,
                            r.throughput / 1e3
                        );
                    }
                }
            } else {
                let rt = PjrtRuntime::cpu()?;
                let model = QpnModel::load(&rt)?;
                let pts = if solver == "sweep" {
                    model.fig6_sweep(&w, &[1, 2], &hits)?
                } else {
                    model.fig6_mva(&w, &[1, 2], &hits)?
                };
                for p in pts {
                    println!(
                        "| {:.2} | {} | {:.3} | {:.1}% | {:.1} |",
                        p.hit_rate,
                        p.cores,
                        p.utilization,
                        p.target_fraction * 100.0,
                        p.throughput / 1e3
                    );
                }
            }
        }
        Some("stopcrit") => {
            let measured = args.get_f64_or("measured-ns", 7_000.0)?;
            let kind = args.get_or("kind", "message");
            args.finish()?;
            let w = Workload::by_name(&kind)
                .ok_or_else(|| mcapi::Error::Config("bad --kind".into()))?;
            let v = stop_criterion(&w, mcapi::model::stopcrit::REFERENCE_HIT_RATE, measured);
            println!(
                "stop criterion ({kind} @ h={}):",
                mcapi::model::stopcrit::REFERENCE_HIT_RATE
            );
            println!("  model minimum : {:.0} ns/message", v.model_min_ns);
            println!("  measured      : {:.0} ns", v.measured_min_ns);
            println!("  ratio         : {:.1}x", v.ratio);
            println!(
                "  verdict       : {}",
                if v.stop {
                    "STOP — residual gap within CPU/OS budget (paper Section 5)"
                } else {
                    "CONTINUE — latency still lock-dominated"
                }
            );
        }
        other => {
            return Err(mcapi::Error::Config(format!(
                "model needs fig6|stopcrit, got {other:?}"
            )))
        }
    }
    Ok(())
}

fn cmd_chaos(args: &Args) -> mcapi::Result<()> {
    let scenario = Scenario::parse(&args.get_or("scenario", "pkt"))
        .ok_or_else(|| mcapi::Error::Config("bad --scenario (pkt|msg)".into()))?;
    let messages = args.get_u64_or("msgs", 24)?;
    // `--faults seed=N` (the issue's spelling) and `--seed N` are synonyms.
    let seed = match args.get("faults") {
        Some(spec) => spec
            .strip_prefix("seed=")
            .and_then(|s| s.parse::<u64>().ok())
            .ok_or_else(|| mcapi::Error::Config("bad --faults (expected seed=N)".into()))?,
        None => args.get_u64_or("seed", 1)?,
    };
    let sweep = args.flag("sweep");
    let sweep_delay = args.flag("sweep-delay");
    let abandon = args.flag("abandon");
    let delay_ns = args.get_u64_or("delay-ns", 40_000)?;
    let victim = Victim::parse(&args.get_or("victim", "prod"))
        .ok_or_else(|| mcapi::Error::Config("bad --victim (prod|cons)".into()))?;
    args.finish()?;

    if abandon {
        let report = run_abandon_seeded(seed);
        println!("{}", report.text);
        if !report.pass {
            std::process::exit(1);
        }
        return Ok(());
    }
    let report = if sweep_delay {
        run_delay_sweep(scenario, victim, messages, delay_ns)
    } else if sweep {
        run_kill_sweep(scenario, victim, messages)
    } else {
        run_seeded(&ChaosOpts { scenario, seed, messages, ..ChaosOpts::default() })
    };
    println!("{}", report.text);
    if !report.pass {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_trace(args: &Args) -> mcapi::Result<()> {
    let kind = MsgKind::parse(&args.get_or("kind", "packet"))
        .ok_or_else(|| mcapi::Error::Config("bad --kind".into()))?;
    let tx = args.get_u64_or("tx", 400)?;
    let cores = args.get_u64_or("cores", 2)? as usize;
    let batch = args.get_u64_or("batch", 1)? as usize;
    let plane = args.get_or("plane", "sim");
    let chaos_seed = args.get_u64("chaos-seed")?;
    let out = args.get("out").map(str::to_owned);
    args.finish()?;

    let real = match plane.as_str() {
        "real" => true,
        "sim" => false,
        other => return Err(mcapi::Error::Config(format!("bad --plane `{other}`"))),
    };
    let run = match chaos_seed {
        Some(seed) => run_traced_chaos(seed),
        None => run_traced_stress(
            RuntimeCfg::default(),
            TraceOpts { kind, tx, cores, batch, real },
        ),
    };
    if let Some(r) = &run.stress {
        println!("plane={plane} kind={} tx={tx}: {r:?}", kind.label());
    }
    if let Some(c) = &run.chaos {
        println!("{}", c.text);
    }
    print!("{}", run.summary_text());
    if let Some(prefix) = out {
        std::fs::write(format!("{prefix}.chrome.json"), run.collector.chrome_trace_json())?;
        std::fs::write(format!("{prefix}.ndjson"), run.collector.ndjson())?;
        std::fs::write(
            format!("{prefix}.metrics.json"),
            run.collector.metrics_json(&run.counters, run.dropped, &run.lanes),
        )?;
        println!("wrote {prefix}.chrome.json / {prefix}.ndjson / {prefix}.metrics.json");
    }
    println!("{}", run.bench_json_line());
    if !run.replay_pass() {
        std::process::exit(1);
    }
    Ok(())
}

fn cmd_info(args: &Args) -> mcapi::Result<()> {
    args.finish()?;
    println!("mcapi-lockfree reproduction CLI");
    println!("host cores : {}", mcapi::os::available_cores());
    match PjrtRuntime::cpu() {
        Ok(rt) => println!(
            "pjrt       : platform={} devices={}",
            rt.platform_name(),
            rt.device_count()
        ),
        Err(e) => println!("pjrt       : unavailable ({e})"),
    }
    let have = mcapi::runtime::ArtifactSpec::MvaSolver.exists()
        && mcapi::runtime::ArtifactSpec::QpnSweep.exists();
    println!("artifacts  : {}", if have { "built" } else { "missing (run `make artifacts`)" });
    Ok(())
}
