//! PJRT client surface.
//!
//! The real backend wraps the `xla` crate's PJRT CPU client
//! (xla_extension 0.5.1). That crate links a prebuilt XLA distribution
//! and cannot be vendored into this fully-offline build, so this module
//! ships the same API as a **stub** that reports the backend as
//! unavailable: `PjrtRuntime::cpu()` returns `Err`, and every caller
//! (CLI `model` subcommand, `QpnModel`, the artifact tests) either falls
//! back to the native MVA solver or skips with a notice. Re-introducing
//! the real client is a drop-in replacement of this file plus an `xla`
//! dependency in Cargo.toml; the artifact contract is documented in
//! [`crate::model::qpn`].

use crate::{Error, Result};
use std::path::Path;

fn unavailable(what: &str) -> Error {
    Error::Runtime(format!(
        "{what}: PJRT/XLA backend not compiled in (offline build without the `xla` crate); \
         use the native solver (`model fig6 --solver native`)"
    ))
}

/// A process-wide PJRT runtime handle. Cheap to clone.
#[derive(Clone)]
pub struct PjrtRuntime {
    _priv: (),
}

impl PjrtRuntime {
    /// Create a CPU PJRT client. Always `Err` in the offline build.
    pub fn cpu() -> Result<Self> {
        Err(unavailable("PjRtClient::cpu"))
    }

    /// Platform name reported by PJRT (e.g. `"Host"`).
    pub fn platform_name(&self) -> String {
        "unavailable".into()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        0
    }

    /// Load an HLO **text** file (produced by `python/compile/aot.py`)
    /// and compile it into an [`Executable`].
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        Err(unavailable(&format!(
            "load_hlo_text {}",
            path.as_ref().display()
        )))
    }
}

/// A compiled XLA executable plus metadata. Cheap to clone.
#[derive(Clone)]
pub struct Executable {
    name: String,
}

impl Executable {
    /// Human-readable identifier (the artifact path it was loaded from).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with `f32` tensor inputs; returns every output tensor as a
    /// flat `f32` vector. Unreachable in the offline build (no
    /// `Executable` can be constructed without a client).
    pub fn run_f32(&self, _inputs: &[F32Input<'_>]) -> Result<Vec<Vec<f32>>> {
        Err(unavailable(&format!("execute {}", self.name)))
    }
}

/// A borrowed `f32` tensor input: flat data plus dims.
pub struct F32Input<'a> {
    /// Row-major data.
    pub data: &'a [f32],
    /// Tensor dimensions.
    pub dims: &'a [i64],
}

impl<'a> F32Input<'a> {
    /// 1-D input.
    pub fn vec(data: &'a [f32], dims: &'a [i64]) -> Self {
        Self { data, dims }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable_not_panic() {
        let e = PjrtRuntime::cpu().err().expect("stub must not succeed");
        let msg = e.to_string();
        assert!(msg.contains("native"), "must point at the fallback: {msg}");
    }
}
