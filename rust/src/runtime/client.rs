//! Thin, safe wrapper around the `xla` crate's PJRT CPU client.

use crate::{Error, Result};
use std::path::Path;
use std::sync::Arc;

fn rt_err<E: std::fmt::Debug>(what: &str) -> impl FnOnce(E) -> Error + '_ {
    move |e| Error::Runtime(format!("{what}: {e:?}"))
}

/// A process-wide PJRT runtime. Cheap to clone; the underlying client is
/// reference counted.
#[derive(Clone)]
pub struct PjrtRuntime {
    client: Arc<xla::PjRtClient>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(rt_err("PjRtClient::cpu"))?;
        Ok(Self { client: Arc::new(client) })
    }

    /// Platform name reported by PJRT (e.g. `"Host"`).
    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Number of addressable devices.
    pub fn device_count(&self) -> usize {
        self.client.device_count()
    }

    /// Load an HLO **text** file (produced by `python/compile/aot.py`) and
    /// compile it into an [`Executable`].
    pub fn load_hlo_text(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(rt_err(&format!("parse HLO text {}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(rt_err(&format!("compile {}", path.display())))?;
        Ok(Executable { exe: Arc::new(exe), name: path.display().to_string() })
    }
}

/// A compiled XLA executable plus metadata. Cheap to clone.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    name: String,
}

impl Executable {
    /// Human-readable identifier (the artifact path it was loaded from).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with `f32` tensor inputs; returns every output tensor as a
    /// flat `f32` vector (the module is lowered with `return_tuple=True`).
    pub fn run_f32(&self, inputs: &[F32Input<'_>]) -> Result<Vec<Vec<f32>>> {
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|inp| {
                let lit = xla::Literal::vec1(inp.data);
                if inp.dims.len() == 1 && inp.dims[0] as usize == inp.data.len() {
                    Ok(lit)
                } else {
                    lit.reshape(inp.dims).map_err(rt_err("reshape input"))
                }
            })
            .collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&lits)
            .map_err(rt_err(&format!("execute {}", self.name)))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(rt_err("to_literal_sync"))?;
        let outs = lit.to_tuple().map_err(rt_err("to_tuple"))?;
        outs.into_iter()
            .map(|o| o.to_vec::<f32>().map_err(rt_err("to_vec<f32>")))
            .collect()
    }
}

/// A borrowed `f32` tensor input: flat data plus dims.
pub struct F32Input<'a> {
    /// Row-major data.
    pub data: &'a [f32],
    /// Tensor dimensions.
    pub dims: &'a [i64],
}

impl<'a> F32Input<'a> {
    /// 1-D input.
    pub fn vec(data: &'a [f32], dims: &'a [i64]) -> Self {
        Self { data, dims }
    }
}
