//! Artifact discovery: locate `artifacts/*.hlo.txt` relative to the repo
//! root regardless of the current working directory (tests, benches and
//! examples all run from different places).

use std::path::{Path, PathBuf};

/// Known artifacts produced by `make artifacts` (`python/compile/aot.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactSpec {
    /// Figure 6 sweep: batched QPN simulation over a (hit-rate × cores)
    /// grid. Inputs: params grid; outputs: throughput + bus utilization.
    QpnSweep,
    /// Mean-value-analysis fixed point over the same grid (the analytic
    /// cross-check for the simulation).
    MvaSolver,
}

impl ArtifactSpec {
    /// File name under `artifacts/`.
    pub fn file_name(self) -> &'static str {
        match self {
            ArtifactSpec::QpnSweep => "qpn_sweep.hlo.txt",
            ArtifactSpec::MvaSolver => "mva_solver.hlo.txt",
        }
    }

    /// Absolute path, if the artifact directory can be located.
    pub fn path(self) -> Option<PathBuf> {
        artifact_dir().map(|d| d.join(self.file_name()))
    }

    /// True when the artifact exists on disk (i.e. `make artifacts` ran).
    pub fn exists(self) -> bool {
        self.path().map(|p| p.exists()).unwrap_or(false)
    }
}

/// Locate the `artifacts/` directory by walking up from both the current
/// working directory and the crate manifest directory.
pub fn artifact_dir() -> Option<PathBuf> {
    let mut starts: Vec<PathBuf> = Vec::new();
    if let Ok(cwd) = std::env::current_dir() {
        starts.push(cwd);
    }
    starts.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    for start in starts {
        let mut dir: &Path = &start;
        loop {
            let cand = dir.join("artifacts");
            if cand.is_dir() {
                return Some(cand);
            }
            match dir.parent() {
                Some(p) => dir = p,
                None => break,
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_file_names_are_distinct() {
        assert_ne!(
            ArtifactSpec::QpnSweep.file_name(),
            ArtifactSpec::MvaSolver.file_name()
        );
    }

    #[test]
    fn artifact_dir_found_from_manifest() {
        // The repo always contains artifacts/ (gitignored but created by the
        // build scaffolding), so discovery must succeed.
        assert!(artifact_dir().is_some());
    }
}
