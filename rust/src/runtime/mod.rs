//! PJRT bridge: load AOT-compiled XLA artifacts and execute them from Rust.
//!
//! The Python side (`python/compile/aot.py`) lowers the JAX performance
//! model — including its Pallas kernel — to **HLO text** under
//! `artifacts/`. This module loads those files with the `xla` crate
//! (xla_extension 0.5.1, PJRT CPU client), compiles them once, and executes
//! them from the coordinator with plain `f32`/`i32` buffers.
//!
//! In the fully-offline build the `xla` crate is not vendored and
//! [`client`] is a same-API stub whose `PjrtRuntime::cpu()` returns
//! `Err`; model consumers fall back to the native MVA solver.
//!
//! HLO *text* (not a serialized `HloModuleProto`) is the interchange format:
//! jax ≥ 0.5 emits protos with 64-bit instruction ids which XLA 0.5.1
//! rejects; the text parser reassigns ids and round-trips cleanly.

mod artifact;
mod client;

pub use artifact::{artifact_dir, ArtifactSpec};
pub use client::{Executable, F32Input, PjrtRuntime};
