//! MCAPI identifiers, status codes and configuration.

use super::liveness::LivenessCfg;

/// Maximum message priority lanes (MCAPI priorities 0 = highest .. 3).
pub const PRIORITIES: usize = 4;

/// Status codes (the subset of MCAPI's `mcapi_status_t` this runtime
/// produces, plus the Table 1 would-block distinctions surfaced to the
/// retry layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Status {
    /// Operation completed.
    Success,
    /// Queue full / empty right now; yield and retry (Table 1).
    WouldBlock,
    /// Queue full/empty but the peer is mid-operation; retry immediately.
    WouldBlockPeerActive,
    /// Buffer pool exhausted (MCAPI_ERR_MEM_LIMIT).
    MemLimit,
    /// Endpoint id invalid or not active.
    InvalidEndpoint,
    /// Channel handle invalid or in the wrong state.
    InvalidChannel,
    /// Endpoint already connected / port in use.
    Busy,
    /// Payload larger than the configured buffer size.
    MessageLimit,
    /// Scalar receive width differs from the sent width (the MCAPI
    /// `MCAPI_ERR_SCL_SIZE` condition). The mismatched scalar is
    /// consumed.
    ScalarSizeMismatch,
    /// Request handle invalid or not pending.
    InvalidRequest,
    /// Wait timed out.
    Timeout,
    /// Request was cancelled.
    Cancelled,
    /// Capacity exhausted (endpoints, channels or requests).
    Exhausted,
    /// The peer node was declared dead (liveness epoch went odd) while
    /// this operation needed it. Surfaced only after all *committed*
    /// messages have been drained: a consumer sees every payload its
    /// dead producer finished publishing before this poison appears.
    EndpointDead,
    /// The *calling* node has been declared dead — it is a fenced
    /// zombie: the watchdog (or an operator) flipped its liveness
    /// epoch while it was merely stalled, and its channels have been
    /// repaired around it. Sends and claims from a fenced node fail
    /// fast with this code so a wrongly-declared node can never
    /// corrupt repaired state; service resumes only through
    /// `McapiRuntime::rejoin` plus channel reconnect.
    NodeFenced,
}

impl Status {
    /// True for the two retryable would-block cases.
    pub fn is_would_block(self) -> bool {
        matches!(self, Status::WouldBlock | Status::WouldBlockPeerActive)
    }
}

/// Endpoint identifier: `(domain, node, port)` per the MCAPI spec.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct EndpointId {
    /// Domain id.
    pub domain: u16,
    /// Node id within the domain.
    pub node: u16,
    /// Port number on the node.
    pub port: u16,
}

impl EndpointId {
    /// Construct.
    pub fn new(domain: u16, node: u16, port: u16) -> Self {
        EndpointId { domain, node, port }
    }
}

impl std::fmt::Display for EndpointId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}:{}", self.domain, self.node, self.port)
    }
}

/// Channel payload kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChannelKind {
    /// Connected packet channel (pool-allocated receive buffers).
    Packet,
    /// Connected scalar channel (8/16/32/64-bit values).
    Scalar,
    /// Connected **state** channel (paper §7 future work): delivers "the
    /// current value" via the NBW protocol — order indeterminate, reads
    /// never block writes, FIFO requirement dropped.
    State,
}

/// Runtime capacities and backend selection.
#[derive(Debug, Clone, Copy)]
pub struct RuntimeCfg {
    /// Lock-based baseline or lock-free refactoring.
    pub backend: BackendKind,
    /// Endpoint table size.
    pub max_endpoints: usize,
    /// Channel table size.
    pub max_channels: usize,
    /// Dense node slots (producer lanes per endpoint).
    pub max_nodes: usize,
    /// Request pool size.
    pub max_requests: usize,
    /// Buffers in the shared pool.
    pub pool_buffers: usize,
    /// Bytes per pooled buffer (max message/packet size).
    pub buf_len: usize,
    /// NBB ring capacity per lane (lock-free backend).
    pub nbb_capacity: usize,
    /// CPU overhead charged per API call in simulated worlds (ns).
    pub api_overhead_ns: u64,
    /// Liveness plane tuning (heartbeat silence deadline, confirm
    /// hysteresis) for the watchdog scanner.
    pub liveness: LivenessCfg,
}

impl Default for RuntimeCfg {
    fn default() -> Self {
        RuntimeCfg {
            backend: BackendKind::LockFree,
            max_endpoints: 64,
            max_channels: 32,
            max_nodes: 8,
            max_requests: 256,
            pool_buffers: 512,
            buf_len: 256,
            nbb_capacity: 16,
            api_overhead_ns: 150,
            liveness: LivenessCfg::default(),
        }
    }
}

impl RuntimeCfg {
    /// Default configuration with the given backend.
    pub fn with_backend(backend: BackendKind) -> Self {
        RuntimeCfg { backend, ..Default::default() }
    }
}

/// Which data-path implementation the runtime uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Global reader/writer lock over one kernel lock (Figure 1 baseline).
    Locked,
    /// NBB / bit-set / FSM refactoring (Figure 2).
    LockFree,
}

impl BackendKind {
    /// Parse from CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "locked" | "lock-based" | "baseline" => Some(Self::Locked),
            "lockfree" | "lock-free" | "nbb" => Some(Self::LockFree),
            _ => None,
        }
    }

    /// Stable report label.
    pub fn label(&self) -> &'static str {
        match self {
            Self::Locked => "locked",
            Self::LockFree => "lockfree",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn endpoint_id_display_and_ord() {
        let a = EndpointId::new(0, 1, 2);
        assert_eq!(a.to_string(), "0:1:2");
        assert!(a < EndpointId::new(0, 1, 3));
        assert!(a < EndpointId::new(1, 0, 0));
    }

    #[test]
    fn status_would_block_classification() {
        assert!(Status::WouldBlock.is_would_block());
        assert!(Status::WouldBlockPeerActive.is_would_block());
        assert!(!Status::Success.is_would_block());
        assert!(!Status::MemLimit.is_would_block());
        assert!(!Status::NodeFenced.is_would_block(), "fencing is terminal, not a retry");
    }

    #[test]
    fn backend_parse() {
        assert_eq!(BackendKind::parse("locked"), Some(BackendKind::Locked));
        assert_eq!(BackendKind::parse("lock-free"), Some(BackendKind::LockFree));
        assert_eq!(BackendKind::parse("x"), None);
    }

    #[test]
    fn default_cfg_sane() {
        let c = RuntimeCfg::default();
        assert!(c.max_endpoints > 0 && c.pool_buffers > 0 && c.nbb_capacity > 0);
        assert!(c.buf_len >= 64, "must fit the paper's 24-byte messages");
        assert!(c.liveness.deadline_ns > 0 && c.liveness.confirm_scans > 0);
    }
}
