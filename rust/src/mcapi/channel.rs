//! The connected-channel fast path: per-channel SPSC rings, batched
//! submission/completion, asynchronous packet requests, and the doorbell
//! board.
//!
//! MCAPI packet and scalar channels are point-to-point FIFOs — exactly
//! one producer and one consumer once connected — so the lock-free
//! backend dedicates the queue structure to the link
//! ([`crate::lockfree::ring::ChannelRing`]) instead of funnelling through
//! the generic MPMC [`super::queue::LockFreeQueue`]. On a steady-state
//! packet exchange the fast path performs:
//!
//! * **zero** pool/lease operations (payload bytes live in the ring
//!   slots; no Treiber pop/push, no Figure 4 buffer FSM, no
//!   `abort_lease` failure path),
//! * at most **one** cross-core counter load per ring wrap (the cached
//!   peer counters from PR 1),
//! * O(1) shared-counter stores per *batch* via the submission/completion
//!   calls ([`super::McapiRuntime::pkt_send_batch`] and friends) — the
//!   io_uring shape: submit many, complete many, one doorbell.
//!
//! # Doorbell board
//!
//! An idle receiver serving many channels should not probe every ring's
//! `update` counter. The `Doorbell` reuses the flag-board trick from
//! `mcapi/queue.rs`: one bit per channel slot, set by the sender **after**
//! its ring publish, cleared by the receiver only when a ring probes
//! empty (clear-then-recheck, so no wakeup is ever lost). Polling N idle
//! channels costs one relaxed word-load per 64 channels — one cache line
//! regardless of channel count at the default table size.
//!
//! The `Locked` backend keeps the reference pool-lease path end to end,
//! and connection-less messages keep the generic queue — the paper's
//! lock-based/lock-free comparison is unchanged.

use std::sync::atomic::Ordering;

use crate::lockfree::bitset::BitSet;
use crate::lockfree::mem::{Atom32, World};
use crate::lockfree::nbb::{BatchStatus, InsertStatus};
use crate::lockfree::ring::{ChannelRing, RecvError, ScalarBatchError};
use crate::obs;
use crate::obs::EventKind;

use super::liveness::RetryBackoff;
use super::queue::Entry;
use super::request::{PendingOp, RequestHandle};
use super::types::{BackendKind, ChannelKind, Status};
use super::{McapiRuntime, QueueImpl, POISON_RX_DEAD, POISON_TX_DEAD};

/// One doorbell bit per channel slot (flag-board mode of [`BitSet`]).
///
/// Protocol: the sender sets the channel's bit *after* the ring's
/// publishing counter store; the receiver clears the bit only when the
/// ring probed empty and then re-checks the ring, conservatively
/// re-setting the bit if the re-check finds anything. Either the
/// re-check observes the payload or the sender's subsequent `set`
/// re-flags the channel — a bit may be spuriously set (costs one probe),
/// never spuriously clear while data is pending.
pub(super) struct Doorbell<W: World> {
    bits: BitSet<W>,
}

impl<W: World> Doorbell<W> {
    /// Board with one bit per channel slot.
    pub(super) fn new(channels: usize) -> Self {
        Doorbell { bits: BitSet::new(channels.max(1)) }
    }

    /// Sender side: flag `ch` as having pending payloads. Must be called
    /// *after* the ring's publishing store (see type docs).
    pub(super) fn set(&self, ch: usize) {
        self.bits.set(ch);
    }

    /// Receiver side: unflag `ch` (callers re-check the ring afterwards).
    pub(super) fn clear(&self, ch: usize) {
        self.bits.free(ch);
    }

    /// First channel in `channels` whose bit is set, loading each
    /// backing word at most once per contiguous run (one relaxed
    /// word-load per 64 channel slots when `channels` is grouped).
    /// Out-of-table channel indices are never flagged and are skipped
    /// (the sibling channel APIs report `InvalidChannel` for them).
    pub(super) fn poll(&self, channels: &[usize]) -> Option<usize> {
        let mut cur_word = usize::MAX;
        let mut word = 0u64;
        for &ch in channels {
            if ch >= self.bits.capacity() {
                continue;
            }
            let wi = ch / 64;
            if wi != cur_word {
                word = self.bits.snapshot_word(wi);
                cur_word = wi;
            }
            if word & (1u64 << (ch % 64)) != 0 {
                return Some(ch);
            }
        }
        None
    }
}

impl<W: World> McapiRuntime<W> {
    /// The fast-path ring of channel `ch` (lock-free backend only).
    fn ring(&self, ch: usize) -> &ChannelRing<W> {
        self.channels[ch]
            .ring
            .as_ref()
            .expect("connected-channel fast path requires the lock-free backend")
    }

    /// Receiver-side doorbell discipline around `attempt`: on an empty
    /// probe, clear the channel's bit and re-check once so a concurrent
    /// publish-then-set cannot be lost; re-flag conservatively when the
    /// re-check finds anything (the ring may hold more).
    fn with_doorbell_recheck<T>(
        &self,
        ch: usize,
        mut attempt: impl FnMut(&ChannelRing<W>) -> Result<T, Status>,
    ) -> Result<T, Status> {
        let ring = self.ring(ch);
        match attempt(ring) {
            Err(Status::WouldBlock) => {
                self.doorbell.clear(ch);
                obs::bump(obs::ctr::DOORBELL_RECHECK);
                match attempt(ring) {
                    Ok(v) => {
                        self.doorbell.set(ch);
                        Ok(v)
                    }
                    Err(Status::WouldBlockPeerActive) => {
                        self.doorbell.set(ch);
                        Err(Status::WouldBlockPeerActive)
                    }
                    other => other,
                }
            }
            other => other,
        }
    }

    // -- single-operation ring paths (dispatched from `mcapi::mod`) ----------

    /// Lock-free packet send: copy `data` straight into the channel
    /// ring's next slot and ring the doorbell. No pool lease, no abort
    /// path.
    pub(super) fn ring_pkt_send(&self, ch: usize, data: &[u8]) -> Result<(), Status> {
        if data.len() > self.cfg.buf_len {
            return Err(Status::MessageLimit);
        }
        let tx = self.tx_node_of(ch);
        self.fence_check(tx)?;
        self.hb_bump(tx);
        self.check_peer_alive_tx(ch)?;
        // Stage mark: API entry. Seq = next committed insert (u/2; the
        // producer's counter is even here — SPSC, and we are the
        // producer). A retried full send re-emits, and the collector
        // keeps the last attempt (the one that pairs with the commit).
        if obs::tracing() {
            let (u, _) = self.ring(ch).counters_peek();
            obs::emit::<W>(EventKind::SendEnter, ch as u32, u / 2, data.len() as u32);
        }
        match self.ring(ch).send(data) {
            Ok(()) => {
                // Flag AFTER the ring's publishing store (Doorbell docs).
                self.doorbell.set(ch);
                if obs::tracing() {
                    let (u, _) = self.ring(ch).counters_peek();
                    obs::emit::<W>(EventKind::DoorbellSet, ch as u32, (u / 2).saturating_sub(1), 0);
                    obs::bump(obs::ctr::DOORBELL_SET);
                }
                self.chan_waits[ch].wake_all::<W>();
                Ok(())
            }
            Err(InsertStatus::Full) => Err(Status::WouldBlock),
            Err(InsertStatus::FullButConsumerReading) => Err(Status::WouldBlockPeerActive),
        }
    }

    /// Lock-free packet receive: copy the next slot's bytes into `out`.
    pub(super) fn ring_pkt_recv(&self, ch: usize, out: &mut [u8]) -> Result<usize, Status> {
        self.hb_bump(self.rx_node_of(ch));
        let r = self.with_doorbell_recheck(ch, |ring| match ring.recv(out) {
            Ok(n) => Ok(n),
            Err(RecvError::Empty) => Err(Status::WouldBlock),
            Err(RecvError::EmptyButProducerInserting) => Err(Status::WouldBlockPeerActive),
        });
        self.poison_on_drained(ch, r.map(|n| {
            // Space freed: wake senders parked on a full ring.
            self.chan_waits[ch].wake_all::<W>();
            n
        }))
    }

    /// Lock-free scalar send (`width` bytes: 1/2/4/8).
    pub(super) fn ring_sclr_send(&self, ch: usize, value: u64, width: u32) -> Result<(), Status> {
        let tx = self.tx_node_of(ch);
        self.fence_check(tx)?;
        self.hb_bump(tx);
        self.check_peer_alive_tx(ch)?;
        if obs::tracing() {
            let (u, _) = self.ring(ch).counters_peek();
            obs::emit::<W>(EventKind::SendEnter, ch as u32, u / 2, width);
        }
        match self.ring(ch).send_scalar(value, width) {
            Ok(()) => {
                self.doorbell.set(ch);
                if obs::tracing() {
                    let (u, _) = self.ring(ch).counters_peek();
                    obs::emit::<W>(EventKind::DoorbellSet, ch as u32, (u / 2).saturating_sub(1), 0);
                    obs::bump(obs::ctr::DOORBELL_SET);
                }
                self.chan_waits[ch].wake_all::<W>();
                Ok(())
            }
            Err(InsertStatus::Full) => Err(Status::WouldBlock),
            Err(InsertStatus::FullButConsumerReading) => Err(Status::WouldBlockPeerActive),
        }
    }

    /// Lock-free scalar receive expecting `width` bytes; a mismatched
    /// width consumes the scalar and reports `ScalarSizeMismatch`.
    pub(super) fn ring_sclr_recv(&self, ch: usize, width: u32) -> Result<u64, Status> {
        self.hb_bump(self.rx_node_of(ch));
        let r = self.with_doorbell_recheck(ch, |ring| match ring.recv_scalar() {
            Ok(vw) => Ok(vw),
            Err(RecvError::Empty) => Err(Status::WouldBlock),
            Err(RecvError::EmptyButProducerInserting) => Err(Status::WouldBlockPeerActive),
        });
        let (value, stored) = self.poison_on_drained(ch, r)?;
        self.chan_waits[ch].wake_all::<W>();
        if stored != width {
            return Err(Status::ScalarSizeMismatch);
        }
        Ok(value)
    }

    /// `EndpointDead` for senders when the channel's consumer side has
    /// been poisoned — a payload enqueued now could never be consumed.
    /// Host-side load only: zero priced-op cost on the fast path.
    fn check_peer_alive_tx(&self, ch: usize) -> Result<(), Status> {
        if self.chan_poison[ch].load(Ordering::Relaxed) & POISON_RX_DEAD != 0 {
            self.stat_poisons.fetch_add(1, Ordering::Relaxed);
            obs::bump(obs::ctr::POISONS);
            return Err(Status::EndpointDead);
        }
        Ok(())
    }

    /// Receiver-side poison discipline: an *empty* probe on a channel
    /// whose producer side is poisoned becomes `EndpointDead` — and only
    /// an empty probe, so every committed payload drains first (the
    /// ring's floor-division occupancy already hides the dead peer's
    /// rolled-back torn insert).
    fn poison_on_drained<T>(&self, ch: usize, r: Result<T, Status>) -> Result<T, Status> {
        match r {
            Err(Status::WouldBlock)
                if self.chan_poison[ch].load(Ordering::Relaxed) & POISON_TX_DEAD != 0 =>
            {
                self.stat_poisons.fetch_add(1, Ordering::Relaxed);
                obs::bump(obs::ctr::POISONS);
                Err(Status::EndpointDead)
            }
            other => other,
        }
    }

    // -- batched submission / completion --------------------------------------

    /// Batched packet send on an open channel: enqueue as many of
    /// `payloads` as fit, in order, amortizing the per-call API overhead
    /// and (lock-free) the ring's enter/exit counter stores over the
    /// whole prefix. Returns how many packets were enqueued; `Err` only
    /// when none were. The `Locked` backend loops the scalar path (the
    /// reference design has no batch primitive).
    pub fn pkt_send_batch(&self, ch: usize, payloads: &[&[u8]]) -> Result<usize, Status> {
        if payloads.is_empty() {
            return Ok(0);
        }
        match self.cfg.backend {
            BackendKind::Locked => {
                let mut sent = 0;
                for data in payloads {
                    match self.pkt_send(ch, data) {
                        Ok(()) => sent += 1,
                        Err(s) if sent == 0 => return Err(s),
                        Err(_) => break,
                    }
                }
                Ok(sent)
            }
            BackendKind::LockFree => {
                self.charge_api();
                self.channel_ready(ch, ChannelKind::Packet)?;
                // Oversized payloads bound the batch (MessageLimit applies
                // per payload, exactly like the pool path's lease_filled).
                let mut valid = 0;
                while valid < payloads.len() && payloads[valid].len() <= self.cfg.buf_len {
                    valid += 1;
                }
                if valid == 0 {
                    return Err(Status::MessageLimit);
                }
                let tx = self.tx_node_of(ch);
                self.fence_check(tx)?;
                self.hb_bump(tx);
                self.check_peer_alive_tx(ch)?;
                // Stage mark per payload offered; over-emitted enters for
                // the unsent tail never pair and are dropped harmlessly.
                if obs::tracing() {
                    let (u, _) = self.ring(ch).counters_peek();
                    for (i, data) in payloads[..valid].iter().enumerate() {
                        obs::emit::<W>(
                            EventKind::SendEnter,
                            ch as u32,
                            u / 2 + i as u64,
                            data.len() as u32,
                        );
                    }
                }
                match self.ring(ch).send_batch(&payloads[..valid]) {
                    Ok(n) => {
                        self.doorbell.set(ch);
                        if obs::tracing() {
                            let (u, _) = self.ring(ch).counters_peek();
                            for i in 0..n as u64 {
                                obs::emit::<W>(
                                    EventKind::DoorbellSet,
                                    ch as u32,
                                    (u / 2).saturating_sub(n as u64) + i,
                                    n as u32,
                                );
                            }
                            obs::bump(obs::ctr::DOORBELL_SET);
                        }
                        self.chan_waits[ch].wake_all::<W>();
                        Ok(n)
                    }
                    Err(BatchStatus::WouldBlock) => Err(Status::WouldBlock),
                    Err(BatchStatus::PeerActive) => Err(Status::WouldBlockPeerActive),
                }
            }
        }
    }

    /// Batched packet receive: drain up to `max` packets from `ch` into
    /// `out` (one `Vec<u8>` per packet, FIFO order). Returns how many
    /// arrived; `Err` when none were pending.
    pub fn pkt_recv_batch(
        &self,
        ch: usize,
        out: &mut Vec<Vec<u8>>,
        max: usize,
    ) -> Result<usize, Status> {
        if max == 0 {
            return Ok(0);
        }
        match self.cfg.backend {
            BackendKind::Locked => {
                let mut buf = vec![0u8; self.cfg.buf_len];
                let mut got = 0;
                while got < max {
                    match self.pkt_recv(ch, &mut buf) {
                        Ok(n) => {
                            out.push(buf[..n].to_vec());
                            got += 1;
                        }
                        Err(s) if got == 0 => return Err(s),
                        Err(_) => break,
                    }
                }
                Ok(got)
            }
            BackendKind::LockFree => {
                self.charge_api();
                self.channel_ready(ch, ChannelKind::Packet)?;
                self.hb_bump(self.rx_node_of(ch));
                let r = self.with_doorbell_recheck(ch, |ring| match ring.recv_batch(out, max) {
                    Ok(n) => Ok(n),
                    Err(BatchStatus::WouldBlock) => Err(Status::WouldBlock),
                    Err(BatchStatus::PeerActive) => Err(Status::WouldBlockPeerActive),
                });
                self.poison_on_drained(ch, r.map(|n| {
                    self.chan_waits[ch].wake_all::<W>();
                    n
                }))
            }
        }
    }

    /// Zero-copy packet receive: run `f` over the next packet's bytes
    /// *in place* in the ring slot, without copying them out first. The
    /// slot stays leased to the consumer for exactly the duration of
    /// `f` — the producer cannot recycle it until `f` returns and the
    /// ring acks the slot — so the borrow is safe but holding the view
    /// open on a full ring back-pressures the sender (see the
    /// borrow-until-release lease test in `channel_properties`).
    ///
    /// The `Locked` reference backend has no in-place primitive; it
    /// copies through a stack buffer and applies `f` to the copy, so
    /// both backends observe identical bytes and return values.
    pub fn pkt_recv_view<R>(&self, ch: usize, f: impl FnOnce(&[u8]) -> R) -> Result<R, Status> {
        match self.cfg.backend {
            BackendKind::Locked => {
                let mut buf = vec![0u8; self.cfg.buf_len];
                let n = self.pkt_recv(ch, &mut buf)?;
                Ok(f(&buf[..n]))
            }
            BackendKind::LockFree => {
                self.charge_api();
                self.channel_ready(ch, ChannelKind::Packet)?;
                self.hb_bump(self.rx_node_of(ch));
                // `f` is FnOnce but the doorbell recheck may probe twice;
                // the ring only invokes the closure when a payload is
                // actually present, so `f` survives an Empty first probe.
                let mut f = Some(f);
                let r = self.with_doorbell_recheck(ch, |ring| {
                    match ring.recv_with(|bytes| (f.take().expect("view ran twice"))(bytes)) {
                        Ok(v) => Ok(v),
                        Err(RecvError::Empty) => Err(Status::WouldBlock),
                        Err(RecvError::EmptyButProducerInserting) => {
                            Err(Status::WouldBlockPeerActive)
                        }
                    }
                });
                self.poison_on_drained(ch, r.map(|v| {
                    // Slot freed on return from `f`: wake parked senders.
                    self.chan_waits[ch].wake_all::<W>();
                    v
                }))
            }
        }
    }

    /// Batched 64-bit scalar send: enqueue as many of `values` as fit.
    /// A batch of N lock-free scalar sends issues O(1) shared-counter
    /// stores (one enter/exit pair on one line).
    pub fn sclr_send_batch(&self, ch: usize, values: &[u64]) -> Result<usize, Status> {
        if values.is_empty() {
            return Ok(0);
        }
        match self.cfg.backend {
            BackendKind::Locked => {
                let mut sent = 0;
                for &v in values {
                    match self.sclr_send(ch, v) {
                        Ok(()) => sent += 1,
                        Err(s) if sent == 0 => return Err(s),
                        Err(_) => break,
                    }
                }
                Ok(sent)
            }
            BackendKind::LockFree => {
                self.charge_api();
                self.channel_ready(ch, ChannelKind::Scalar)?;
                let tx = self.tx_node_of(ch);
                self.fence_check(tx)?;
                self.hb_bump(tx);
                self.check_peer_alive_tx(ch)?;
                if obs::tracing() {
                    let (u, _) = self.ring(ch).counters_peek();
                    for i in 0..values.len() as u64 {
                        obs::emit::<W>(EventKind::SendEnter, ch as u32, u / 2 + i, 8);
                    }
                }
                match self.ring(ch).send_scalars(values, 8) {
                    Ok(n) => {
                        self.doorbell.set(ch);
                        if obs::tracing() {
                            let (u, _) = self.ring(ch).counters_peek();
                            for i in 0..n as u64 {
                                obs::emit::<W>(
                                    EventKind::DoorbellSet,
                                    ch as u32,
                                    (u / 2).saturating_sub(n as u64) + i,
                                    n as u32,
                                );
                            }
                            obs::bump(obs::ctr::DOORBELL_SET);
                        }
                        self.chan_waits[ch].wake_all::<W>();
                        Ok(n)
                    }
                    Err(BatchStatus::WouldBlock) => Err(Status::WouldBlock),
                    Err(BatchStatus::PeerActive) => Err(Status::WouldBlockPeerActive),
                }
            }
        }
    }

    /// Batched 64-bit scalar receive: drain up to `max` scalars into
    /// `out`. Returns how many arrived; `Err` when none were pending. A
    /// width-mismatched scalar stops the batch and is consumed, exactly
    /// like the single-receive contract (`ScalarSizeMismatch` when it
    /// was the first pending scalar — matching the `Locked` loop).
    pub fn sclr_recv_batch(
        &self,
        ch: usize,
        out: &mut Vec<u64>,
        max: usize,
    ) -> Result<usize, Status> {
        if max == 0 {
            return Ok(0);
        }
        match self.cfg.backend {
            BackendKind::Locked => {
                let mut got = 0;
                while got < max {
                    match self.sclr_recv(ch) {
                        Ok(v) => {
                            out.push(v);
                            got += 1;
                        }
                        Err(s) if got == 0 => return Err(s),
                        Err(_) => break,
                    }
                }
                Ok(got)
            }
            BackendKind::LockFree => {
                self.charge_api();
                self.channel_ready(ch, ChannelKind::Scalar)?;
                self.hb_bump(self.rx_node_of(ch));
                let r = self.with_doorbell_recheck(ch, |ring| match ring.recv_scalars(out, max, 8)
                {
                    Ok(n) => Ok(n),
                    Err(ScalarBatchError::Empty) => Err(Status::WouldBlock),
                    Err(ScalarBatchError::EmptyButProducerInserting) => {
                        Err(Status::WouldBlockPeerActive)
                    }
                    Err(ScalarBatchError::SizeMismatch) => Err(Status::ScalarSizeMismatch),
                });
                self.poison_on_drained(ch, r.map(|n| {
                    self.chan_waits[ch].wake_all::<W>();
                    n
                }))
            }
        }
    }

    // -- width-typed scalars (MCAPI sclr_*_uintN) -----------------------------

    /// Width-carrying scalar send shared by the typed wrappers.
    pub(super) fn sclr_send_w(&self, ch: usize, value: u64, width: u32) -> Result<(), Status> {
        self.charge_api();
        match self.cfg.backend {
            BackendKind::Locked => {
                let tx = self.tx_node_of(ch);
                self.fence_check(tx)?;
                self.hb_bump(tx);
                let (tx_i, rx_i) =
                    self.global.with_read(|| self.channel_ready(ch, ChannelKind::Scalar))?;
                let from = self.global.with_read(|| self.endpoints[tx_i].owner.load());
                self.global.with_write(|| {
                    let QueueImpl::Locked(q) = &self.endpoints[rx_i].queue else {
                        unreachable!();
                    };
                    // Safety: global write lock held.
                    unsafe { q.push(Entry::scalar_w(value, from, width)) }
                })
            }
            BackendKind::LockFree => {
                self.channel_ready(ch, ChannelKind::Scalar)?;
                self.ring_sclr_send(ch, value, width)
            }
        }
    }

    /// Width-checking scalar receive shared by the typed wrappers; a
    /// width mismatch consumes the scalar and reports
    /// `ScalarSizeMismatch` (MCAPI `MCAPI_ERR_SCL_SIZE`).
    pub(super) fn sclr_recv_w(&self, ch: usize, width: u32) -> Result<u64, Status> {
        self.charge_api();
        match self.cfg.backend {
            BackendKind::Locked => {
                self.hb_bump(self.rx_node_of(ch));
                let (_, rx_i) =
                    self.global.with_read(|| self.channel_ready(ch, ChannelKind::Scalar))?;
                self.global.with_write(|| {
                    let QueueImpl::Locked(q) = &self.endpoints[rx_i].queue else {
                        unreachable!();
                    };
                    // Safety: global write lock held.
                    let e = unsafe { q.pop() }.ok_or(Status::WouldBlock)?;
                    if e.len != width {
                        return Err(Status::ScalarSizeMismatch);
                    }
                    Ok(e.scalar)
                })
            }
            BackendKind::LockFree => {
                self.channel_ready(ch, ChannelKind::Scalar)?;
                self.ring_sclr_recv(ch, width)
            }
        }
    }

    /// 8-bit scalar send (MCAPI `sclr_channel_send_uint8`).
    pub fn sclr_send8(&self, ch: usize, value: u8) -> Result<(), Status> {
        self.sclr_send_w(ch, value as u64, 1)
    }

    /// 16-bit scalar send.
    pub fn sclr_send16(&self, ch: usize, value: u16) -> Result<(), Status> {
        self.sclr_send_w(ch, value as u64, 2)
    }

    /// 32-bit scalar send.
    pub fn sclr_send32(&self, ch: usize, value: u32) -> Result<(), Status> {
        self.sclr_send_w(ch, value as u64, 4)
    }

    /// 64-bit scalar send (same as [`McapiRuntime::sclr_send`]).
    pub fn sclr_send64(&self, ch: usize, value: u64) -> Result<(), Status> {
        self.sclr_send_w(ch, value, 8)
    }

    /// 8-bit scalar receive (MCAPI `sclr_channel_recv_uint8`).
    pub fn sclr_recv8(&self, ch: usize) -> Result<u8, Status> {
        self.sclr_recv_w(ch, 1).map(|v| v as u8)
    }

    /// 16-bit scalar receive.
    pub fn sclr_recv16(&self, ch: usize) -> Result<u16, Status> {
        self.sclr_recv_w(ch, 2).map(|v| v as u16)
    }

    /// 32-bit scalar receive.
    pub fn sclr_recv32(&self, ch: usize) -> Result<u32, Status> {
        self.sclr_recv_w(ch, 4).map(|v| v as u32)
    }

    /// 64-bit scalar receive (same as [`McapiRuntime::sclr_recv`]).
    pub fn sclr_recv64(&self, ch: usize) -> Result<u64, Status> {
        self.sclr_recv_w(ch, 8)
    }

    // -- asynchronous packet operations (Figure 3 requests) -------------------

    /// Start an asynchronous packet send; completes via
    /// [`McapiRuntime::wait_pkt_send`]. Mirrors `msg_send_i`, including
    /// the exceptional RECEIVED hop on the synchronous completion path.
    pub fn pkt_send_i(&self, ch: usize, data: &[u8]) -> Result<RequestHandle, Status> {
        self.channel_ready(ch, ChannelKind::Packet)?;
        let h = self.requests.allocate(PendingOp::PktSend { ch })?;
        match self.pkt_send(ch, data) {
            Ok(()) => {
                let _ = self.requests.mark_received(h);
                self.requests.complete(h, Status::Success);
                Ok(h)
            }
            Err(s) if s.is_would_block() => Ok(h), // pending; wait re-drives
            Err(s) => {
                self.requests.complete(h, s);
                Ok(h)
            }
        }
    }

    /// Start an asynchronous packet receive; completes via
    /// [`McapiRuntime::wait_pkt_recv`] (cancellable while pending).
    pub fn pkt_recv_i(&self, ch: usize) -> Result<RequestHandle, Status> {
        self.channel_ready(ch, ChannelKind::Packet)?;
        self.requests.allocate(PendingOp::PktRecv { ch })
    }

    /// Drive a pending packet-send request to completion within
    /// `timeout_ns` (virtual ns in simulated worlds). MCAPI `wait`.
    pub fn wait_pkt_send(
        &self,
        h: RequestHandle,
        ch: usize,
        data: &[u8],
        timeout_ns: u64,
    ) -> Status {
        if self.requests.is_complete(h) {
            return self.requests.reap(h).unwrap_or(Status::InvalidRequest);
        }
        if ch >= self.channels.len() {
            self.requests.complete(h, Status::InvalidChannel);
            return self.requests.reap(h).unwrap_or(Status::InvalidRequest);
        }
        let drive = self.blocking_drive(&self.chan_waits[ch], self.tx_node_of(ch), timeout_ns, || {
            self.pkt_send(ch, data)
        });
        match drive {
            Ok(()) => {
                self.requests.complete(h, Status::Success);
                self.requests.reap(h).unwrap_or(Status::InvalidRequest)
            }
            // Request stays pending across a timeout (re-waitable).
            Err(Status::Timeout) => Status::Timeout,
            Err(s) => {
                self.requests.complete(h, s);
                self.requests.reap(h).unwrap_or(Status::InvalidRequest)
            }
        }
    }

    /// Drive a pending packet-receive request within `timeout_ns`; on
    /// success returns the byte count copied into `out`. MCAPI `wait`.
    pub fn wait_pkt_recv(
        &self,
        h: RequestHandle,
        out: &mut [u8],
        timeout_ns: u64,
    ) -> Result<usize, Status> {
        let PendingOp::PktRecv { ch } = self.requests.slot(h).op() else {
            return Err(Status::InvalidRequest);
        };
        let drive = self.blocking_drive(&self.chan_waits[ch], self.rx_node_of(ch), timeout_ns, || {
            self.pkt_recv(ch, out)
        });
        match drive {
            Ok(n) => {
                self.requests.complete(h, Status::Success);
                let _ = self.requests.reap(h);
                Ok(n)
            }
            // Request stays pending across a timeout (cancellable).
            Err(Status::Timeout) => Err(Status::Timeout),
            Err(s) => {
                self.requests.complete(h, s);
                let _ = self.requests.reap(h);
                Err(s)
            }
        }
    }

    /// Blocking packet receive on an open channel: spin briefly, yield,
    /// then park on the channel's wait cell (doorbell-driven futex)
    /// until a packet arrives, the producing peer is declared dead
    /// (`EndpointDead`, after every committed packet drained), the
    /// channel is torn down (`InvalidChannel`), or `timeout_ns` elapses
    /// (`Timeout`). The parked receiver costs nothing to senders until
    /// it registers; the sender-side wake is one host-atomic load.
    pub fn chan_recv_wait(
        &self,
        ch: usize,
        out: &mut [u8],
        timeout_ns: u64,
    ) -> Result<usize, Status> {
        self.connected_ch(ch)?;
        self.blocking_drive(&self.chan_waits[ch], self.rx_node_of(ch), timeout_ns, || {
            self.pkt_recv(ch, out)
        })
    }

    /// Blocking packet send under an absolute `deadline_ns` (same clock
    /// as [`crate::lockfree::mem::World::now_ns`]): retries the
    /// spin→yield→park progression in exponentially growing backoff
    /// slices ([`RetryBackoff`]) until the packet lands, a terminal
    /// verdict surfaces (`EndpointDead`, `NodeFenced`, teardown), or the
    /// deadline expires with `Status::Timeout` — the caller degrades
    /// gracefully instead of blocking forever on a dying peer.
    pub fn pkt_send_deadline(&self, ch: usize, data: &[u8], deadline_ns: u64) -> Result<(), Status> {
        self.connected_ch(ch)?;
        let node = self.tx_node_of(ch);
        let mut bo = RetryBackoff::new();
        loop {
            let remaining = deadline_ns.saturating_sub(W::now_ns());
            let Some(slice) = bo.next_slice(remaining) else {
                return Err(Status::Timeout);
            };
            match self.blocking_drive(&self.chan_waits[ch], node, slice, || {
                self.pkt_send(ch, data)
            }) {
                Err(Status::Timeout) => continue,
                other => return other,
            }
        }
    }

    /// Blocking packet receive under an absolute deadline with backoff
    /// slicing (see [`Self::pkt_send_deadline`]). On success returns the
    /// byte count copied into `out`.
    pub fn pkt_recv_deadline(
        &self,
        ch: usize,
        out: &mut [u8],
        deadline_ns: u64,
    ) -> Result<usize, Status> {
        self.connected_ch(ch)?;
        let node = self.rx_node_of(ch);
        let mut bo = RetryBackoff::new();
        loop {
            let remaining = deadline_ns.saturating_sub(W::now_ns());
            let Some(slice) = bo.next_slice(remaining) else {
                return Err(Status::Timeout);
            };
            match self.blocking_drive(&self.chan_waits[ch], node, slice, || self.pkt_recv(ch, out))
            {
                Err(Status::Timeout) => continue,
                other => return other,
            }
        }
    }

    // -- doorbell polling ------------------------------------------------------

    /// Poll the doorbell board for the first of `channels` with pending
    /// payloads (lock-free fast path): one relaxed word-load per 64
    /// channel slots, independent of how many channels are polled — the
    /// idle-receiver cost is one cache line at the default table size.
    /// Channels on the `Locked` backend are never flagged; poll them
    /// directly. A `Some` is a hint (the payload may already have been
    /// consumed if polled from a non-consumer thread); `None` is
    /// authoritative up to the doorbell protocol's clear-then-recheck.
    pub fn chan_poll(&self, channels: &[usize]) -> Option<usize> {
        self.doorbell.poll(channels)
    }

    /// Payloads currently buffered on a connected channel (approximate
    /// under concurrency; monitoring only).
    /// Host-side peek of a connected channel ring's monotonic
    /// `(update, ack)` counters. Chaos invariant checks derive the total
    /// committed-insert count (`update / 2`) and full-drain condition
    /// (`update == ack`, both even) from it. `None` when the channel has
    /// no mounted ring (Locked backend, or not connected).
    pub fn chan_counters(&self, ch: usize) -> Option<(u64, u64)> {
        self.channels.get(ch)?.ring.as_ref().map(|r| r.counters_peek())
    }

    pub fn chan_available(&self, ch: usize) -> Result<usize, Status> {
        let slot = self.connected_ch(ch)?;
        Ok(match &slot.ring {
            Some(ring) => ring.len(),
            None => {
                // Locked backend: channel entries live in the receive
                // endpoint's queue (mixed with connection-less messages).
                let rx = slot.rx_ep.load() as usize;
                match &self.endpoints[rx].queue {
                    QueueImpl::Locked(q) => self.global.with_read(|| unsafe { q.len() }),
                    QueueImpl::LockFree(q) => q.len(),
                }
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;

    #[test]
    fn doorbell_set_clear_poll() {
        let d = Doorbell::<RealWorld>::new(32);
        assert_eq!(d.poll(&[0, 5, 9]), None);
        d.set(5);
        d.set(9);
        assert_eq!(d.poll(&[0, 5, 9]), Some(5), "first flagged channel wins");
        d.clear(5);
        assert_eq!(d.poll(&[0, 5, 9]), Some(9));
        d.clear(9);
        assert_eq!(d.poll(&[0, 5, 9]), None);
    }

    #[test]
    fn doorbell_poll_spans_words() {
        let d = Doorbell::<RealWorld>::new(130);
        d.set(129);
        assert_eq!(d.poll(&[1, 64, 129]), Some(129));
        d.clear(129);
        assert_eq!(d.poll(&[1, 64, 129]), None);
    }
}
