//! Automatic liveness plane: heartbeat registry + watchdog hysteresis.
//!
//! Every recovery path in this runtime funnels into
//! [`crate::mcapi::McapiRuntime::declare_node_dead`], but before this
//! module that call was always *explicit* — a hung peer on the real
//! plane stalled its partners until a human intervened. The liveness
//! plane closes the loop:
//!
//! * [`Heartbeats`] — one cache-padded progress epoch per node, bumped
//!   from the hot-path instrumentation points (send/recv entry, park /
//!   unpark transitions). Bumps are **host atomics only** — like the
//!   obs counters they are unpriced on the sim plane, so every pinned
//!   sim-cost gate stays byte-identical whether the watchdog is armed
//!   or not.
//! * [`Watchdog`] — a driver-owned scanner that compares each node's
//!   beat against a configurable silence deadline with hysteresis: a
//!   silent node becomes *suspect*, and only after
//!   [`LivenessCfg::confirm_scans`] consecutive over-deadline scans is
//!   it *confirmed* (at which point the runtime feeds it to
//!   `declare_node_dead`). A node parked in a futex wait is
//!   legitimately idle — the registry's park counter keeps it from ever
//!   being suspected — and a beat that moves clears suspicion (counted
//!   as a *false suspect*, the tuning signal for
//!   [`LivenessCfg::deadline_ns`]).
//! * [`RetryBackoff`] — the timeout-slicing helper behind the
//!   `*_deadline` send/recv variants: short first slice (fast failure
//!   detection while the peer is probably alive), doubling up to a cap
//!   so a dying peer costs bounded wakeups instead of a spin.
//!
//! The watchdog itself holds no references into the runtime: `scan`
//! takes the clock, the registry and an `alive` predicate, so the
//! hysteresis state machine is directly unit-testable over a synthetic
//! deadline × stall-length grid (see the tests below and
//! `tests/liveness_properties.rs`).

use crate::lockfree::mem::CachePadded;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

/// Liveness tuning knobs, carried on
/// [`crate::mcapi::types::RuntimeCfg`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LivenessCfg {
    /// Silence (no heartbeat progress, not parked) before a node
    /// becomes suspect, in [`crate::lockfree::World::timestamp_peek`]
    /// nanoseconds — wall-clock on the real plane, virtual on the sim.
    pub deadline_ns: u64,
    /// Consecutive over-deadline scans before a suspect is confirmed
    /// dead and handed to `declare_node_dead`. Hysteresis: one slow
    /// scan (scheduler hiccup on the scanning thread itself) never
    /// kills a node.
    pub confirm_scans: u32,
}

impl Default for LivenessCfg {
    fn default() -> Self {
        // Real-plane default: 50 ms of silence, confirmed over 3 scans.
        // Generous against scheduler preemption (a healthy peer beats
        // every retry slice, ~1 ms); harnesses override both knobs.
        LivenessCfg { deadline_ns: 50_000_000, confirm_scans: 3 }
    }
}

/// One node's liveness lane: a progress epoch plus a parked-waiter
/// count, padded so producer-heavy and consumer-heavy nodes never
/// false-share while beating from their hot paths.
#[derive(Debug, Default)]
struct NodeBeat {
    /// Monotonic progress epoch; 0 = never participated.
    beat: AtomicU64,
    /// Waiters currently parked in a futex wait (blocking_drive).
    parked: AtomicU32,
}

/// Per-node heartbeat registry. All operations are raw host atomics
/// (never `W::U32`/`W::U64`), bounds-checked to be inert for
/// out-of-range nodes, and relaxed — the watchdog only needs eventual
/// visibility of *progress*, not ordering against the payload.
#[derive(Debug)]
pub struct Heartbeats {
    nodes: Vec<CachePadded<NodeBeat>>,
}

impl Heartbeats {
    /// Registry for `max_nodes` nodes, all at beat 0 (never seen).
    pub fn new(max_nodes: usize) -> Self {
        let mut nodes = Vec::with_capacity(max_nodes);
        for _ in 0..max_nodes {
            nodes.push(CachePadded::new(NodeBeat::default()));
        }
        Heartbeats { nodes }
    }

    /// Nodes tracked.
    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    /// True when the registry tracks no nodes.
    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Record progress for `node`. Inert out of range (callers pass
    /// `usize::MAX` when the owning node is unknown, e.g. a channel
    /// slot that was never connected).
    #[inline]
    pub fn bump(&self, node: usize) {
        if let Some(n) = self.nodes.get(node) {
            n.beat.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Mark `node` as entering a futex park: a parked waiter is idle by
    /// design and must never be suspected.
    #[inline]
    pub fn park(&self, node: usize) {
        if let Some(n) = self.nodes.get(node) {
            n.parked.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Mark `node` as leaving a futex park; the wake itself is
    /// progress, so the beat advances too.
    #[inline]
    pub fn unpark(&self, node: usize) {
        if let Some(n) = self.nodes.get(node) {
            n.parked.fetch_sub(1, Ordering::Relaxed);
            n.beat.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Current beat (0 = never participated / out of range).
    #[inline]
    pub fn beat_peek(&self, node: usize) -> u64 {
        self.nodes.get(node).map_or(0, |n| n.beat.load(Ordering::Relaxed))
    }

    /// Currently parked waiters for `node` (0 out of range).
    #[inline]
    pub fn parked_peek(&self, node: usize) -> u32 {
        self.nodes.get(node).map_or(0, |n| n.parked.load(Ordering::Relaxed))
    }
}

/// Per-node scanner state. `seen` gates the whole lane: a node that
/// never beat is not participating and is never suspected (so an
/// allocated-but-idle node, like a harness's endpoint-only node, can
/// sit silent forever).
#[derive(Debug, Clone, Copy, Default)]
struct Lane {
    seen: bool,
    last_beat: u64,
    last_change_ns: u64,
    suspect_scans: u32,
}

/// What one [`Watchdog::scan`] observed.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct ScanReport {
    /// Nodes over the silence deadline this scan (includes the
    /// confirmed ones — a confirm is the last suspect scan).
    pub suspects: Vec<usize>,
    /// Nodes whose suspicion reached `confirm_scans`: declare these.
    pub confirmed: Vec<usize>,
    /// Previously suspected nodes that made progress again — false
    /// suspects, the deadline-tuning signal.
    pub cleared: Vec<usize>,
}

impl ScanReport {
    /// True when the scan found nothing actionable.
    pub fn is_quiet(&self) -> bool {
        self.suspects.is_empty() && self.confirmed.is_empty() && self.cleared.is_empty()
    }
}

/// The hysteresis state machine. Owned by whoever drives the scan loop
/// (a harness watchdog task on the sim plane, a watchdog thread on the
/// real plane) — the shared runtime only carries the passive
/// [`Heartbeats`] registry.
#[derive(Debug)]
pub struct Watchdog {
    cfg: LivenessCfg,
    lanes: Vec<Lane>,
}

impl Watchdog {
    /// New scanner for up to `max_nodes` nodes.
    pub fn new(cfg: LivenessCfg, max_nodes: usize) -> Self {
        Watchdog { cfg, lanes: vec![Lane::default(); max_nodes] }
    }

    /// The configuration this scanner enforces.
    pub fn cfg(&self) -> LivenessCfg {
        self.cfg
    }

    /// One scan pass at clock `now_ns` over registry `hb`. `alive`
    /// reports the node-epoch view (false = already declared dead):
    /// dead nodes are skipped and their lanes reset, so a node that
    /// `rejoin`s starts from a fresh baseline.
    ///
    /// Suspicion rules, in order, per node:
    /// 1. dead → reset lane, skip;
    /// 2. never beat → skip (not participating);
    /// 3. first sight of a beat → baseline, never suspect on sight;
    /// 4. beat moved → progress; clears any standing suspicion
    ///    (reported in [`ScanReport::cleared`]);
    /// 5. parked waiter(s) → legitimately idle; suspicion resets
    ///    silently and the silence clock restarts;
    /// 6. silent past `deadline_ns` → suspect; confirm after
    ///    `confirm_scans` consecutive suspect scans.
    pub fn scan(
        &mut self,
        now_ns: u64,
        hb: &Heartbeats,
        alive: impl Fn(usize) -> bool,
    ) -> ScanReport {
        let mut report = ScanReport::default();
        for node in 0..self.lanes.len().min(hb.len()) {
            let lane = &mut self.lanes[node];
            if !alive(node) {
                *lane = Lane::default();
                continue;
            }
            let beat = hb.beat_peek(node);
            if !lane.seen {
                if beat == 0 {
                    continue;
                }
                *lane = Lane { seen: true, last_beat: beat, last_change_ns: now_ns, suspect_scans: 0 };
                continue;
            }
            if beat != lane.last_beat {
                if lane.suspect_scans > 0 {
                    report.cleared.push(node);
                }
                lane.last_beat = beat;
                lane.last_change_ns = now_ns;
                lane.suspect_scans = 0;
                continue;
            }
            if hb.parked_peek(node) > 0 {
                lane.last_change_ns = now_ns;
                lane.suspect_scans = 0;
                continue;
            }
            if now_ns.saturating_sub(lane.last_change_ns) >= self.cfg.deadline_ns {
                lane.suspect_scans += 1;
                report.suspects.push(node);
                if lane.suspect_scans >= self.cfg.confirm_scans {
                    report.confirmed.push(node);
                    // Fresh lane: if the zombie rejoins and beats
                    // again, it re-baselines instead of instantly
                    // re-confirming.
                    *lane = Lane::default();
                }
            }
        }
        report
    }

    /// Consume this scanner into a background thread that calls
    /// [`crate::mcapi::McapiRuntime::watchdog_scan_once`] every
    /// `period` — the built-in death-detection loop for real-plane
    /// runtimes, so harnesses no longer hand-drive the scan (sim-plane
    /// runtimes still must: the repair pipeline is priced and needs a
    /// live simulated task).
    ///
    /// Shutdown is clean on both exits: the thread holds only a
    /// [`Weak`] runtime reference, so dropping the last runtime `Arc`
    /// ends the loop by itself, and the returned [`ScannerHandle`]
    /// stops-and-joins on drop (or explicitly via
    /// [`ScannerHandle::stop`]). `period` is slept in ≤ 5 ms slices so
    /// either exit is prompt regardless of the scan period.
    pub fn spawn_scanner(
        self,
        rt: &std::sync::Arc<crate::mcapi::McapiRuntime<crate::lockfree::mem::RealWorld>>,
        period: std::time::Duration,
    ) -> ScannerHandle {
        use std::sync::atomic::AtomicBool;
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let flag = stop.clone();
        let weak = std::sync::Arc::downgrade(rt);
        let join = std::thread::spawn(move || {
            let mut wd = self;
            while !flag.load(Ordering::Acquire) {
                // Upgrade per scan: the runtime dropping out from under
                // us IS the shutdown signal for abandoned handles.
                let Some(rt) = weak.upgrade() else { break };
                rt.watchdog_scan_once(&mut wd);
                drop(rt);
                let mut left = period;
                while !flag.load(Ordering::Acquire) && !left.is_zero() {
                    let slice = left.min(std::time::Duration::from_millis(5));
                    std::thread::sleep(slice);
                    left = left.saturating_sub(slice);
                }
            }
        });
        ScannerHandle { stop, join: Some(join) }
    }
}

/// Handle to a background scanner from [`Watchdog::spawn_scanner`].
/// Dropping it stops and joins the thread; leak it (`std::mem::forget`)
/// only if the runtime's own drop should end the loop instead.
#[derive(Debug)]
pub struct ScannerHandle {
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
    join: Option<std::thread::JoinHandle<()>>,
}

impl ScannerHandle {
    /// Signal the scan loop to exit and join the thread (idempotent;
    /// also what `Drop` does).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Release);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for ScannerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Timeout slicing for the `*_deadline` send/recv variants: first slice
/// short (a live peer usually answers fast), doubling up to `max_ns` so
/// waiting on a dying peer costs O(log) wakeups, never a spin.
#[derive(Debug, Clone, Copy)]
pub struct RetryBackoff {
    next_ns: u64,
    max_ns: u64,
}

impl RetryBackoff {
    /// Default slicing: 100 µs first slice, 5 ms cap.
    pub fn new() -> Self {
        RetryBackoff::with_bounds(100_000, 5_000_000)
    }

    /// Custom first-slice / cap bounds (both clamped to ≥ 1 ns).
    pub fn with_bounds(first_ns: u64, max_ns: u64) -> Self {
        let max_ns = max_ns.max(1);
        RetryBackoff { next_ns: first_ns.clamp(1, max_ns), max_ns }
    }

    /// Next timeout slice, capped at `remaining_ns` of the caller's
    /// deadline budget. Returns `None` once the budget is exhausted.
    pub fn next_slice(&mut self, remaining_ns: u64) -> Option<u64> {
        if remaining_ns == 0 {
            return None;
        }
        let slice = self.next_ns.min(remaining_ns);
        self.next_ns = (self.next_ns.saturating_mul(2)).min(self.max_ns);
        Some(slice)
    }
}

impl Default for RetryBackoff {
    fn default() -> Self {
        RetryBackoff::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_bump_park_roundtrip_and_out_of_range_inert() {
        let hb = Heartbeats::new(2);
        assert_eq!(hb.len(), 2);
        assert!(!hb.is_empty());
        assert_eq!(hb.beat_peek(0), 0);
        hb.bump(0);
        hb.bump(0);
        assert_eq!(hb.beat_peek(0), 2);
        hb.park(1);
        assert_eq!(hb.parked_peek(1), 1);
        hb.unpark(1);
        assert_eq!(hb.parked_peek(1), 0);
        assert_eq!(hb.beat_peek(1), 1, "unpark is progress");
        // Out of range: inert, never panics.
        hb.bump(7);
        hb.park(usize::MAX);
        hb.unpark(usize::MAX);
        assert_eq!(hb.beat_peek(7), 0);
        assert_eq!(hb.parked_peek(7), 0);
    }

    #[test]
    fn never_beaten_node_is_never_suspected() {
        let hb = Heartbeats::new(2);
        let mut wd = Watchdog::new(LivenessCfg { deadline_ns: 100, confirm_scans: 1 }, 2);
        for t in 0..50u64 {
            let r = wd.scan(t * 1_000, &hb, |_| true);
            assert!(r.is_quiet(), "idle node suspected at scan {t}: {r:?}");
        }
    }

    #[test]
    fn silence_confirms_after_exactly_confirm_scans() {
        let hb = Heartbeats::new(1);
        let cfg = LivenessCfg { deadline_ns: 1_000, confirm_scans: 3 };
        let mut wd = Watchdog::new(cfg, 1);
        hb.bump(0);
        assert!(wd.scan(0, &hb, |_| true).is_quiet(), "baseline scan");
        // Scans at 2000/3000: over deadline, suspect but not confirmed.
        let r1 = wd.scan(2_000, &hb, |_| true);
        assert_eq!(r1.suspects, vec![0]);
        assert!(r1.confirmed.is_empty());
        let r2 = wd.scan(3_000, &hb, |_| true);
        assert_eq!(r2.suspects, vec![0]);
        assert!(r2.confirmed.is_empty());
        let r3 = wd.scan(4_000, &hb, |_| true);
        assert_eq!(r3.confirmed, vec![0], "third suspect scan confirms");
    }

    #[test]
    fn progress_clears_standing_suspicion_as_false_suspect() {
        let hb = Heartbeats::new(1);
        let cfg = LivenessCfg { deadline_ns: 1_000, confirm_scans: 3 };
        let mut wd = Watchdog::new(cfg, 1);
        hb.bump(0);
        wd.scan(0, &hb, |_| true);
        assert_eq!(wd.scan(2_000, &hb, |_| true).suspects, vec![0]);
        hb.bump(0); // the stalled node resumes
        let r = wd.scan(3_000, &hb, |_| true);
        assert_eq!(r.cleared, vec![0], "resumed node must be cleared");
        assert!(r.suspects.is_empty() && r.confirmed.is_empty());
        // And the silence clock restarted: no immediate re-suspicion.
        assert!(wd.scan(3_500, &hb, |_| true).is_quiet());
    }

    #[test]
    fn parked_waiter_is_never_suspected() {
        let hb = Heartbeats::new(1);
        let cfg = LivenessCfg { deadline_ns: 1_000, confirm_scans: 1 };
        let mut wd = Watchdog::new(cfg, 1);
        hb.bump(0);
        wd.scan(0, &hb, |_| true);
        hb.park(0);
        for t in 1..100u64 {
            let r = wd.scan(t * 10_000, &hb, |_| true);
            assert!(r.is_quiet(), "parked node suspected at {t}: {r:?}");
        }
        hb.unpark(0);
        // The unpark beat is progress; still quiet.
        assert!(wd.scan(1_000_000, &hb, |_| true).is_quiet());
    }

    #[test]
    fn dead_node_lane_resets_and_rejoin_rebaselines() {
        let hb = Heartbeats::new(1);
        let cfg = LivenessCfg { deadline_ns: 1_000, confirm_scans: 1 };
        let mut wd = Watchdog::new(cfg, 1);
        hb.bump(0);
        wd.scan(0, &hb, |_| true);
        assert_eq!(wd.scan(2_000, &hb, |_| true).confirmed, vec![0]);
        // Declared dead: skipped while the epoch is odd.
        assert!(wd.scan(10_000, &hb, |_| false).is_quiet());
        // Rejoined (alive again) and beating: re-baselines, no instant
        // re-confirm even though the wall clock jumped.
        hb.bump(0);
        assert!(wd.scan(1_000_000, &hb, |_| true).is_quiet());
        assert!(wd.scan(1_000_500, &hb, |_| true).is_quiet());
    }

    /// The hysteresis contract over a deadline × stall-length grid:
    /// with scans every `i` ns, a node that beats, stalls for `s` ns
    /// and resumes is (a) never even suspected when `s < deadline`, and
    /// (b) confirmed exactly once when the stall comfortably exceeds
    /// the confirm horizon `deadline + confirm_scans · i`.
    #[test]
    fn hysteresis_grid_no_false_positives_short_of_deadline() {
        const INTERVAL: u64 = 1_000;
        for &deadline in &[3_000u64, 5_000, 8_000] {
            for &confirm in &[1u32, 2, 3] {
                for stall_steps in 0..16u64 {
                    let stall = stall_steps * INTERVAL;
                    let cfg = LivenessCfg { deadline_ns: deadline, confirm_scans: confirm };
                    let hb = Heartbeats::new(1);
                    let mut wd = Watchdog::new(cfg, 1);
                    let mut confirms = 0usize;
                    let mut suspects = 0usize;
                    let mut cleared = 0usize;
                    let mut now = 0u64;
                    let mut dead = false;
                    // Active phase: beat every scan tick.
                    for _ in 0..10 {
                        hb.bump(0);
                        let r = wd.scan(now, &hb, |_| !dead);
                        confirms += r.confirmed.len();
                        suspects += r.suspects.len();
                        now += INTERVAL;
                    }
                    // Stall phase: scans continue, no beats.
                    let resume_at = now + stall;
                    while now < resume_at {
                        let r = wd.scan(now, &hb, |_| !dead);
                        confirms += r.confirmed.len();
                        suspects += r.suspects.len();
                        if !r.confirmed.is_empty() {
                            dead = true;
                        }
                        now += INTERVAL;
                    }
                    // Resume phase.
                    for _ in 0..10 {
                        hb.bump(0);
                        let r = wd.scan(now, &hb, |_| !dead);
                        confirms += r.confirmed.len();
                        suspects += r.suspects.len();
                        cleared += r.cleared.len();
                        now += INTERVAL;
                    }
                    let ctx = format!(
                        "deadline={deadline} confirm={confirm} stall={stall}: \
                         suspects={suspects} confirms={confirms} cleared={cleared}"
                    );
                    if stall < deadline {
                        assert_eq!(suspects, 0, "false suspicion: {ctx}");
                        assert_eq!(confirms, 0, "false kill: {ctx}");
                    }
                    if stall >= deadline + (u64::from(confirm) + 1) * INTERVAL {
                        assert_eq!(confirms, 1, "missed kill: {ctx}");
                    }
                    assert!(confirms <= 1, "double kill: {ctx}");
                    if suspects > 0 && confirms == 0 {
                        assert!(cleared > 0, "suspicion never cleared: {ctx}");
                    }
                }
            }
        }
    }

    #[test]
    fn backoff_doubles_to_cap_and_respects_budget() {
        let mut bo = RetryBackoff::with_bounds(100, 400);
        assert_eq!(bo.next_slice(u64::MAX), Some(100));
        assert_eq!(bo.next_slice(u64::MAX), Some(200));
        assert_eq!(bo.next_slice(u64::MAX), Some(400));
        assert_eq!(bo.next_slice(u64::MAX), Some(400), "capped");
        assert_eq!(bo.next_slice(150), Some(150), "budget-clipped");
        assert_eq!(bo.next_slice(0), None, "exhausted budget");
        let mut d = RetryBackoff::default();
        assert_eq!(d.next_slice(u64::MAX), Some(100_000));
    }

    #[test]
    fn liveness_cfg_default_is_sane() {
        let cfg = LivenessCfg::default();
        assert!(cfg.deadline_ns >= 1_000_000, "sub-ms default would flap");
        assert!(cfg.confirm_scans >= 2, "no hysteresis by default");
    }
}
