//! MCAPI — the Multicore Communications API runtime.
//!
//! Implements the paper's three communication formats over a shared
//! memory partition (Figure 1 / Figure 2):
//!
//! 1. **Messages** — connection-less, priority-based FIFO between ad-hoc
//!    endpoints;
//! 2. **Packets** — connection-oriented FIFO channels; send buffer is the
//!    caller's, receive buffer comes from the MCAPI pool;
//! 3. **Scalars** — connection-oriented 8/16/32/64-bit values.
//!
//! Two interchangeable data paths ([`types::BackendKind`]):
//!
//! * `Locked` — the reference design: every operation takes the global
//!   user-mode reader/writer lock (itself guarded by one kernel lock).
//! * `LockFree` — the paper's refactoring: NBB receive queues, bit-set
//!   request pool, Figure 3/4 FSMs, atomic metadata. Connected packet
//!   and scalar channels additionally take the [`channel`] fast path:
//!   a dedicated per-channel SPSC ring carrying the payload in its
//!   slots (no pool lease, no copy through the shared pool), batched
//!   submission/completion, and a doorbell board for idle receivers.
//!
//! The runtime is generic over [`crate::lockfree::mem::World`], so the
//! same code runs on real hardware and on the deterministic SMP simulator.

pub mod channel;
pub mod liveness;
pub mod queue;
pub mod request;
pub mod types;

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::lockfree::backoff::Backoff;
use crate::lockfree::fsm::AtomicFsm;
use crate::lockfree::mem::{Atom32, Atom64, World};
use crate::lockfree::nbw::Nbw;
use crate::lockfree::ring::ChannelRing;
use crate::mrapi::rwlock::RwLock;
use crate::obs;
use crate::mrapi::shmem::{Lease, Partition};
use channel::Doorbell;
use liveness::{Heartbeats, RetryBackoff, ScanReport, Watchdog};
use queue::{entry_state, ConsumerGroup, Entry, LockFreeQueue, LockedQueue};
use request::{PendingOp, RequestHandle, RequestPool};
use types::{BackendKind, ChannelKind, EndpointId, RuntimeCfg, Status, PRIORITIES};

/// Endpoint FSM states.
mod ep_state {
    pub const FREE: u32 = 0;
    pub const CREATING: u32 = 1;
    pub const ACTIVE: u32 = 2;
}

/// Channel FSM states.
mod ch_state {
    pub const FREE: u32 = 0;
    pub const CONNECTING: u32 = 1;
    pub const CONNECTED: u32 = 2;
}

/// Channel poison bits (host-side flags set by
/// [`McapiRuntime::declare_node_dead`]): which side of a connected
/// channel belongs to a dead node. Senders surface `EndpointDead` at
/// once when the consumer side is dead; receivers surface it only after
/// every committed payload has drained (the ring's floor-division
/// occupancy makes the drain-first order automatic).
pub(crate) const POISON_TX_DEAD: u32 = 1;
pub(crate) const POISON_RX_DEAD: u32 = 2;

/// Yields a hardened wait loop performs before parking on its wait cell
/// (the spin -> yield -> futex progression).
const YIELDS_BEFORE_PARK: u32 = 4;

/// Eventcount wait cell for the hardened blocking paths. Host-side
/// atomics on purpose: registering or waking waiters must not perturb
/// the priced operation counts the pinned sim cost tests assert, and the
/// sequence word must be readable from inside the simulator's monitor
/// (`World::futex_wait`'s `still` closure runs there).
///
/// Protocol: a parker increments `waiters`, snapshots `seq`, re-polls
/// its condition once, then futex-waits while `seq` is unchanged; a
/// waker that published work bumps `seq` and wakes the cell only when
/// `waiters != 0` — zero cost on the uncontended hot path.
struct WaitCell {
    seq: AtomicU64,
    waiters: AtomicU32,
    /// Observability id for park/unpark trace events: the channel slot,
    /// or `obs::CH_ENDPOINT_BIT | ep` for endpoint cells ([`obs::CH_NONE`]
    /// until tagged). Host atomic, never priced.
    trace_ch: AtomicU32,
}

impl WaitCell {
    fn new() -> Self {
        WaitCell {
            seq: AtomicU64::new(0),
            waiters: AtomicU32::new(0),
            trace_ch: AtomicU32::new(obs::CH_NONE),
        }
    }

    /// Futex address token: the cell's own location (unique and stable;
    /// both worlds key their wait queues by opaque u64).
    fn token(&self) -> u64 {
        self as *const WaitCell as u64
    }

    /// Register as a waiter; returns the sequence snapshot for
    /// [`WaitCell::wait`]. Pair every call with [`WaitCell::finish`].
    fn prepare(&self) -> u64 {
        self.waiters.fetch_add(1, Ordering::SeqCst);
        self.seq.load(Ordering::SeqCst)
    }

    /// Sleep until a wake, the deadline, or a `seq` bump since `seen`.
    fn wait<W: World>(&self, seen: u64, deadline_ns: Option<u64>) {
        W::futex_wait(self.token(), deadline_ns, || {
            self.seq.load(Ordering::SeqCst) == seen
        });
    }

    /// Deregister (must follow every `prepare`).
    fn finish(&self) {
        self.waiters.fetch_sub(1, Ordering::SeqCst);
    }

    /// Wake every parked waiter. Called after publishing whatever the
    /// waiters poll for: a committed message, freed ring space, a poison
    /// flag, or channel teardown.
    fn wake_all<W: World>(&self) {
        if self.waiters.load(Ordering::SeqCst) != 0 {
            self.seq.fetch_add(1, Ordering::SeqCst);
            W::futex_wake(self.token(), usize::MAX);
        }
    }

    /// Wake **one** parked waiter — the targeted doorbell for group
    /// sends, where one message can only ever satisfy one member.
    /// `wake_all` there was a thundering herd: every parked member woke
    /// to race for a single entry, and the losers paid a full
    /// park/unpark round trip per message. The seq bump still
    /// invalidates every in-flight `prepare` snapshot, so the lost-wake
    /// race is unchanged; a woken member that finds nothing re-rings
    /// the bell ([`ctr::WAKE_MISSES`]) so a wake is never absorbed by a
    /// member that didn't need it. Teardown/poison/repair paths keep
    /// broadcasting.
    fn wake_one<W: World>(&self) {
        if self.waiters.load(Ordering::SeqCst) != 0 {
            self.seq.fetch_add(1, Ordering::SeqCst);
            W::futex_wake(self.token(), 1);
        }
    }
}

enum QueueImpl<W: World> {
    Locked(LockedQueue),
    LockFree(LockFreeQueue<W>),
}

struct EndpointSlot<W: World> {
    state: AtomicFsm<W>,
    /// Packed EndpointId (domain<<32 | node<<16 | port), valid when ACTIVE.
    id: W::U64,
    /// Dense node slot of the owner (producer lane index).
    owner: W::U32,
    /// Connected channel + 1 as receiver (0 = none).
    rx_channel: W::U32,
    queue: QueueImpl<W>,
    /// MPMC multi-receiver profile: built lazily on the first
    /// [`McapiRuntime::endpoint_attach_consumer`] (lock-free backend
    /// only). While unattached, send/recv pay one host-atomic load to
    /// skip it — the single-consumer hot path's priced op counts are
    /// unchanged (pinned sim gates stay byte-identical).
    group: OnceLock<ConsumerGroup<W>>,
}

struct ChannelSlot<W: World> {
    state: AtomicFsm<W>,
    kind: W::U32, // 0 = packet, 1 = scalar, 2 = state
    tx_ep: W::U32,
    rx_ep: W::U32,
    tx_open: W::U32,
    rx_open: W::U32,
    /// NBW variable backing a *state* channel (paper §7 future work).
    nbw: Nbw<u64, W>,
    /// Connected-channel fast path: a dedicated SPSC ring whose slots
    /// carry the payload (packet bytes / scalars). `Some` on the
    /// lock-free backend; `None` on the `Locked` baseline, which keeps
    /// the reference pool-lease + locked-queue path end to end.
    /// Pre-allocated at `buf_len` slots like every other runtime table
    /// (MCAPI's static-allocation model — endpoint slots eagerly build
    /// their full per-lane queues the same way); lazily building
    /// kind-sized rings at `connect` would save ~128 KiB at default
    /// config at the cost of interior mutability on this field.
    ring: Option<ChannelRing<W>>,
}

fn pack(id: EndpointId) -> u64 {
    ((id.domain as u64) << 32) | ((id.node as u64) << 16) | id.port as u64 | (1 << 63)
}

/// The MCAPI runtime: one shared-memory communication domain.
pub struct McapiRuntime<W: World> {
    cfg: RuntimeCfg,
    endpoints: Vec<EndpointSlot<W>>,
    channels: Vec<ChannelSlot<W>>,
    requests: RequestPool<W>,
    pool: Partition<W>,
    /// Figure 4 FSM per pooled buffer.
    buffer_fsm: Vec<AtomicFsm<W>>,
    /// Doorbell board for the connected-channel fast path: one bit per
    /// channel slot so an idle receiver polls one cache line regardless
    /// of channel count (see [`channel`]).
    doorbell: Doorbell<W>,
    /// The Figure 1 global lock (used only by the Locked backend).
    global: RwLock<W>,
    /// Per-node liveness epochs: even = alive, odd = declared dead.
    /// Host atomics (unpriced) so hot-path alive checks cost nothing in
    /// the simulator's pinned operation counts.
    liveness: Vec<AtomicU64>,
    /// Host-side shadow of each endpoint's owner node (written once at
    /// creation) so liveness checks avoid a priced table load.
    ep_owner_shadow: Vec<AtomicU32>,
    /// Per-channel poison bits (`POISON_TX_DEAD` / `POISON_RX_DEAD`).
    chan_poison: Vec<AtomicU32>,
    /// Buffer custody: 0 = pooled or queued, `node + 1` = held by that
    /// node mid-operation. Lets `declare_node_dead` reclaim the leases a
    /// dead task was holding. Host-side: custody records sit between the
    /// priced operations they bracket, so an injected kill can never
    /// land inside a record/clear pair (faults fire only at priced ops).
    buffer_holder: Vec<AtomicU32>,
    /// Eventcount cells: one per channel and one per endpoint.
    chan_waits: Vec<WaitCell>,
    ep_waits: Vec<WaitCell>,
    /// Robustness counters (host-side instrumentation for stress/chaos
    /// reports; see `coordinator::metrics`).
    stat_timeouts: AtomicU64,
    stat_poisons: AtomicU64,
    stat_leases_reclaimed: AtomicU64,
    /// Liveness plane: per-node heartbeat registry (host atomics,
    /// unpriced like the obs counters) bumped from the hot-path
    /// instrumentation points and scanned by a driver-owned
    /// [`liveness::Watchdog`].
    hb: Heartbeats,
    /// Host-side shadows of each connected channel's endpoint-owner
    /// nodes, written at `connect`. The authoritative `tx_ep`/`rx_ep`
    /// words are priced `W::U32` loads, which the unpriced fence
    /// checks and heartbeat bumps on the ring fast path must never
    /// touch. `u32::MAX` = never connected.
    chan_tx_node: Vec<AtomicU32>,
    chan_rx_node: Vec<AtomicU32>,
    /// Watchdog verdict counters (always-on ground truth; the obs
    /// `liveness.*` counters mirror these only while tracing is armed).
    stat_suspects: AtomicU64,
    stat_confirms: AtomicU64,
    stat_false_suspects: AtomicU64,
    stat_fence_rejects: AtomicU64,
}

impl<W: World> McapiRuntime<W> {
    /// Build a runtime (normally wrapped in an `Arc` and shared).
    pub fn new(cfg: RuntimeCfg) -> Arc<Self> {
        let endpoints = (0..cfg.max_endpoints)
            .map(|_| EndpointSlot {
                state: AtomicFsm::new(ep_state::FREE),
                id: W::U64::new(0),
                owner: W::U32::new(0),
                rx_channel: W::U32::new(0),
                queue: match cfg.backend {
                    BackendKind::Locked => {
                        // Same per-lane depth as the lock-free NBBs so the
                        // queueing (Little's-law) component of latency is
                        // comparable across backends.
                        QueueImpl::Locked(LockedQueue::new(cfg.nbb_capacity))
                    }
                    BackendKind::LockFree => {
                        QueueImpl::LockFree(LockFreeQueue::new(cfg.max_nodes, cfg.nbb_capacity))
                    }
                },
                group: OnceLock::new(),
            })
            .collect();
        let channels = (0..cfg.max_channels)
            .map(|_| ChannelSlot {
                state: AtomicFsm::new(ch_state::FREE),
                kind: W::U32::new(0),
                tx_ep: W::U32::new(0),
                rx_ep: W::U32::new(0),
                tx_open: W::U32::new(0),
                rx_open: W::U32::new(0),
                nbw: Nbw::new(4, 0),
                ring: match cfg.backend {
                    BackendKind::LockFree => {
                        Some(ChannelRing::new(cfg.nbb_capacity, cfg.buf_len.max(8)))
                    }
                    BackendKind::Locked => None,
                },
            })
            .collect();
        // Tag each fast-path structure with its slot index so trace
        // events carry a stable channel/endpoint id (host atomics; free).
        let channels: Vec<ChannelSlot<W>> = channels;
        for (ch, slot) in channels.iter().enumerate() {
            if let Some(ring) = &slot.ring {
                ring.set_trace_id(ch as u32);
            }
        }
        let endpoints: Vec<EndpointSlot<W>> = endpoints;
        for (ep, slot) in endpoints.iter().enumerate() {
            if let QueueImpl::LockFree(q) = &slot.queue {
                q.set_trace_id(ep as u32);
            }
        }
        let chan_waits: Vec<WaitCell> = (0..cfg.max_channels).map(|_| WaitCell::new()).collect();
        for (ch, cell) in chan_waits.iter().enumerate() {
            cell.trace_ch.store(ch as u32, Ordering::Relaxed);
        }
        let ep_waits: Vec<WaitCell> = (0..cfg.max_endpoints).map(|_| WaitCell::new()).collect();
        for (ep, cell) in ep_waits.iter().enumerate() {
            cell.trace_ch.store(obs::CH_ENDPOINT_BIT | ep as u32, Ordering::Relaxed);
        }
        Arc::new(McapiRuntime {
            endpoints,
            channels,
            requests: RequestPool::new(cfg.max_requests),
            pool: Partition::new(cfg.pool_buffers, cfg.buf_len),
            buffer_fsm: (0..cfg.pool_buffers)
                .map(|_| AtomicFsm::new(entry_state::FREE))
                .collect(),
            doorbell: Doorbell::new(cfg.max_channels),
            global: RwLock::new(),
            liveness: (0..cfg.max_nodes).map(|_| AtomicU64::new(0)).collect(),
            ep_owner_shadow: (0..cfg.max_endpoints).map(|_| AtomicU32::new(0)).collect(),
            chan_poison: (0..cfg.max_channels).map(|_| AtomicU32::new(0)).collect(),
            buffer_holder: (0..cfg.pool_buffers).map(|_| AtomicU32::new(0)).collect(),
            chan_waits,
            ep_waits,
            stat_timeouts: AtomicU64::new(0),
            stat_poisons: AtomicU64::new(0),
            stat_leases_reclaimed: AtomicU64::new(0),
            hb: Heartbeats::new(cfg.max_nodes),
            chan_tx_node: (0..cfg.max_channels).map(|_| AtomicU32::new(u32::MAX)).collect(),
            chan_rx_node: (0..cfg.max_channels).map(|_| AtomicU32::new(u32::MAX)).collect(),
            stat_suspects: AtomicU64::new(0),
            stat_confirms: AtomicU64::new(0),
            stat_false_suspects: AtomicU64::new(0),
            stat_fence_rejects: AtomicU64::new(0),
            cfg,
        })
    }

    /// Runtime configuration.
    pub fn cfg(&self) -> &RuntimeCfg {
        &self.cfg
    }

    /// Selected backend.
    pub fn backend(&self) -> BackendKind {
        self.cfg.backend
    }

    /// Requests currently in flight.
    pub fn requests_in_use(&self) -> usize {
        self.requests.in_use()
    }

    /// Pool buffers currently free.
    pub fn buffers_available(&self) -> usize {
        self.pool.available()
    }

    /// Total pool lease operations (acquire + release attempts) so far —
    /// instrumentation for the fast-path tests asserting a steady-state
    /// connected-channel exchange performs **zero** pool traffic.
    pub fn pool_lease_ops(&self) -> u64 {
        self.pool.lease_ops()
    }

    /// Waits that expired with `Status::Timeout` so far.
    pub fn timeouts_observed(&self) -> u64 {
        self.stat_timeouts.load(Ordering::Relaxed)
    }

    /// Operations that surfaced `Status::EndpointDead` so far.
    pub fn poisons_observed(&self) -> u64 {
        self.stat_poisons.load(Ordering::Relaxed)
    }

    /// Pool leases reclaimed from dead nodes so far.
    pub fn leases_reclaimed(&self) -> u64 {
        self.stat_leases_reclaimed.load(Ordering::Relaxed)
    }

    /// Watchdog suspect scans recorded so far (a node over its silence
    /// deadline; includes the scans that went on to confirm).
    pub fn suspects_observed(&self) -> u64 {
        self.stat_suspects.load(Ordering::Relaxed)
    }

    /// Watchdog confirmations so far (each fed one node to
    /// [`Self::declare_node_dead`]).
    pub fn confirms_observed(&self) -> u64 {
        self.stat_confirms.load(Ordering::Relaxed)
    }

    /// Suspects cleared by later progress — false suspects, the signal
    /// that [`liveness::LivenessCfg::deadline_ns`] is tuned too tight.
    pub fn false_suspects_observed(&self) -> u64 {
        self.stat_false_suspects.load(Ordering::Relaxed)
    }

    /// Operations rejected with `Status::NodeFenced` so far.
    pub fn fence_rejects_observed(&self) -> u64 {
        self.stat_fence_rejects.load(Ordering::Relaxed)
    }

    /// Current heartbeat epoch of `node` (monitoring; 0 = never
    /// participated).
    pub fn heartbeat_peek(&self, node: usize) -> u64 {
        self.hb.beat_peek(node)
    }

    // -- node liveness (dead-peer recovery) -----------------------------------

    /// Whether `node`'s liveness epoch is even (alive). Out-of-range
    /// nodes read as dead.
    pub fn node_alive(&self, node: usize) -> bool {
        self.liveness
            .get(node)
            .map_or(false, |e| e.load(Ordering::SeqCst) & 1 == 0)
    }

    /// Current liveness epoch of `node` (monitoring).
    pub fn liveness_epoch(&self, node: usize) -> u64 {
        self.liveness.get(node).map_or(1, |e| e.load(Ordering::SeqCst))
    }

    /// Declare dense node slot `node` dead and run recovery: bump its
    /// liveness epoch to odd, poison + counter-repair every connected
    /// channel whose producer or consumer side the node owned, reclaim
    /// every pool lease the node was holding, and wake every parked
    /// waiter so it re-checks and surfaces `EndpointDead` (or drains the
    /// committed remainder first). Idempotent per epoch. Returns
    /// `(channels_poisoned, leases_reclaimed)`.
    ///
    /// Models an external health monitor's verdict (heartbeat loss, OS
    /// task-death notification). Must run on a live task: ring repair
    /// and pool release are priced operations, so in simulated worlds
    /// call this from a watchdog task inside the machine.
    pub fn declare_node_dead(&self, node: usize) -> (usize, usize) {
        let Some(epoch) = self.liveness.get(node) else {
            return (0, 0);
        };
        let cur = epoch.load(Ordering::SeqCst);
        if cur & 1 == 1 {
            return (0, 0); // already dead
        }
        epoch.store(cur + 1, Ordering::SeqCst);
        // 1) Poison and repair connected channels touching the node.
        //    Rolling the dead side's odd counter back to even discards a
        //    torn insert / re-exposes an un-acked read (see
        //    `ChannelRing::repair_dead_producer`); the live side can then
        //    drain everything committed before poison surfaces.
        let mut poisoned = 0;
        for (ch, slot) in self.channels.iter().enumerate() {
            if slot.state.state() != ch_state::CONNECTED {
                continue;
            }
            let tx_owner =
                self.ep_owner_shadow[slot.tx_ep.load() as usize].load(Ordering::Relaxed) as usize;
            let rx_owner =
                self.ep_owner_shadow[slot.rx_ep.load() as usize].load(Ordering::Relaxed) as usize;
            let mut bits = 0;
            if tx_owner == node {
                bits |= POISON_TX_DEAD;
                if let Some(ring) = &slot.ring {
                    ring.repair_dead_producer();
                }
            }
            if rx_owner == node {
                bits |= POISON_RX_DEAD;
                if let Some(ring) = &slot.ring {
                    ring.repair_dead_consumer();
                }
            }
            if bits != 0 {
                self.chan_poison[ch].fetch_or(bits, Ordering::SeqCst);
                // Doorbell pollers probe the ring and hit the poison;
                // parked waiters re-check via the cell wake.
                self.doorbell.set(ch);
                self.chan_waits[ch].wake_all::<W>();
                poisoned += 1;
            }
        }
        // 2) Reclaim the pool leases the dead node held mid-operation.
        //    Custody invariant: `holder == node + 1` implies the buffer
        //    is neither in the free pool nor inside a committed queue
        //    entry, so forcing its FSM back to FREE and releasing it can
        //    neither double-free nor steal a live message's buffer.
        let mut reclaimed = 0usize;
        for (i, holder) in self.buffer_holder.iter().enumerate() {
            if holder.load(Ordering::SeqCst) != node as u32 + 1 {
                continue;
            }
            holder.store(0, Ordering::SeqCst);
            let st = self.buffer_fsm[i].state();
            if st != entry_state::FREE {
                let _ = self.buffer_fsm[i].transition(st, entry_state::FREE);
            }
            self.pool.release(Lease {
                index: i,
                offset: i * self.cfg.buf_len,
                len: self.cfg.buf_len,
            });
            reclaimed += 1;
        }
        self.stat_leases_reclaimed.fetch_add(reclaimed as u64, Ordering::Relaxed);
        obs::add(obs::ctr::LEASES_RECLAIMED, reclaimed as u64);
        // 2.5) Repair MPMC consumer groups: roll back the dead node's
        //      torn lane insert / torn home pop, clear its wedged steal
        //      claim, re-enqueue the stolen payloads it committed but
        //      never delivered (exactly-once is preserved — the dead
        //      member never handed them to a caller; the ring requeues
        //      them onto the dead node's own producer-less lane), and
        //      re-deal its orphaned home lanes across the surviving
        //      members (heartbeat-aware group rebalancing: the
        //      watchdog's confirm lands here).
        for (i, epslot) in self.endpoints.iter().enumerate() {
            let Some(g) = epslot.group.get() else {
                continue;
            };
            let (repairs, overflow) = g.repair_dead(node as u32);
            if repairs == 0 && overflow.is_empty() {
                continue;
            }
            for e in overflow {
                // The dead node's lane couldn't absorb the requeue.
                // Re-pushing via `g.push` would write the ORIGINAL
                // producer's SPSC lane — and that producer can be
                // alive and mid-send (the corpse was the thief, not
                // the sender), which would put two writers on one
                // SPSC lane. Return the buffer instead.
                self.drop_entry(&e);
            }
            // Unwedged consumers and the re-enqueued work both need a
            // broadcast re-poll.
            self.ep_waits[i].wake_all::<W>();
        }
        // 3) Wake waiters parked on the dead node's endpoints (blocked
        //    senders re-attempt, see the dead-destination check, and
        //    surface `EndpointDead`).
        for (i, ep) in self.endpoints.iter().enumerate() {
            if ep.state.state() == ep_state::ACTIVE
                && self.ep_owner_shadow[i].load(Ordering::Relaxed) as usize == node
            {
                self.ep_waits[i].wake_all::<W>();
            }
        }
        (poisoned, reclaimed)
    }

    // -- automatic liveness (heartbeat watchdog, fencing, rejoin) -------------

    /// A watchdog scanner configured from this runtime's
    /// [`liveness::LivenessCfg`]. Driver-owned on purpose: the scan
    /// loop lives on whatever task/thread polls it, and the shared
    /// runtime only carries the passive heartbeat registry.
    pub fn new_watchdog(&self) -> Watchdog {
        Watchdog::new(self.cfg.liveness, self.cfg.max_nodes)
    }

    /// One watchdog pass: scan the heartbeat registry against the
    /// configured silence deadline and feed every *confirmed* node to
    /// [`Self::declare_node_dead`] — automatic recovery, no explicit
    /// declaration anywhere. Every scan read is host-side/unpriced
    /// ([`World::timestamp_peek`], heartbeat peeks, liveness epochs),
    /// so an armed watchdog adds **zero** priced sim operations to a
    /// healthy run; only a confirm triggers the (priced) repair
    /// pipeline, which therefore must run on a live task in simulated
    /// worlds. Returns the scan report after declarations.
    pub fn watchdog_scan_once(&self, wd: &mut Watchdog) -> ScanReport {
        let now = W::timestamp_peek();
        let report = wd.scan(now, &self.hb, |n| self.node_alive(n));
        if !report.suspects.is_empty() {
            self.stat_suspects.fetch_add(report.suspects.len() as u64, Ordering::Relaxed);
            obs::add(obs::ctr::LIVENESS_SUSPECTS, report.suspects.len() as u64);
        }
        if !report.cleared.is_empty() {
            self.stat_false_suspects.fetch_add(report.cleared.len() as u64, Ordering::Relaxed);
            obs::add(obs::ctr::LIVENESS_FALSE_SUSPECTS, report.cleared.len() as u64);
        }
        for &node in &report.confirmed {
            self.stat_confirms.fetch_add(1, Ordering::Relaxed);
            obs::bump(obs::ctr::LIVENESS_CONFIRMS);
            self.declare_node_dead(node);
        }
        report
    }

    /// Re-admit a fenced (declared-dead) node: flip its liveness epoch
    /// back to even and beat once so the watchdog re-baselines instead
    /// of instantly re-confirming. State repaired *around* the zombie
    /// is not resurrected — channels it owned stay poisoned until torn
    /// down and reconnected (`close` + `connect`), which is the second
    /// half of the rejoin handshake. Idempotent on an alive node.
    pub fn rejoin(&self, node: usize) -> Result<(), Status> {
        let epoch = self.liveness.get(node).ok_or(Status::InvalidEndpoint)?;
        loop {
            let cur = epoch.load(Ordering::SeqCst);
            if cur & 1 == 0 {
                break;
            }
            if epoch
                .compare_exchange(cur, cur + 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                break;
            }
        }
        self.hb.bump(node);
        Ok(())
    }

    /// `NodeFenced` when the *calling* node has been declared dead
    /// while still running — a fenced zombie, whose sends and claims
    /// must fail fast so it can never corrupt state repaired around
    /// it. Host-side loads only (zero priced hot-path cost);
    /// out-of-range callers are not fenced (they own no repairable
    /// state, and `u32::MAX` is the "never connected" shadow value).
    pub(crate) fn fence_check(&self, node: usize) -> Result<(), Status> {
        match self.liveness.get(node) {
            Some(e) if e.load(Ordering::SeqCst) & 1 == 1 => {
                self.stat_fence_rejects.fetch_add(1, Ordering::Relaxed);
                obs::bump(obs::ctr::LIVENESS_FENCE_REJECTS);
                Err(Status::NodeFenced)
            }
            _ => Ok(()),
        }
    }

    /// Owner node of endpoint `ep` via the host shadow (`usize::MAX`
    /// out of range — inert for the heartbeat/fence helpers).
    #[inline]
    fn ep_owner_node(&self, ep: usize) -> usize {
        self.ep_owner_shadow
            .get(ep)
            .map_or(usize::MAX, |o| o.load(Ordering::Relaxed) as usize)
    }

    /// Producer-side node of connected channel `ch` (host shadow).
    #[inline]
    pub(crate) fn tx_node_of(&self, ch: usize) -> usize {
        self.chan_tx_node
            .get(ch)
            .map_or(usize::MAX, |n| n.load(Ordering::Relaxed) as usize)
    }

    /// Consumer-side node of connected channel `ch` (host shadow).
    #[inline]
    pub(crate) fn rx_node_of(&self, ch: usize) -> usize {
        self.chan_rx_node
            .get(ch)
            .map_or(usize::MAX, |n| n.load(Ordering::Relaxed) as usize)
    }

    /// Heartbeat: record hot-path progress for `node` (inert out of
    /// range; host atomic, unpriced).
    #[inline]
    pub(crate) fn hb_bump(&self, node: usize) {
        self.hb.bump(node);
    }

    fn charge_api(&self) {
        W::work(self.cfg.api_overhead_ns);
    }

    // -- endpoint management ------------------------------------------------

    /// Create an endpoint `(domain, node, port)` owned by dense node slot
    /// `owner`. Returns the endpoint table index.
    pub fn create_endpoint(&self, id: EndpointId, owner: usize) -> Result<usize, Status> {
        self.charge_api();
        if owner >= self.cfg.max_nodes {
            return Err(Status::InvalidEndpoint);
        }
        if self.lookup(id).is_some() {
            return Err(Status::Busy);
        }
        match self.cfg.backend {
            BackendKind::Locked => self.global.with_write(|| self.create_ep_inner(id, owner)),
            BackendKind::LockFree => self.create_ep_inner(id, owner),
        }
    }

    fn create_ep_inner(&self, id: EndpointId, owner: usize) -> Result<usize, Status> {
        for (i, slot) in self.endpoints.iter().enumerate() {
            if slot.state.transition(ep_state::FREE, ep_state::CREATING).is_ok() {
                slot.id.store(pack(id));
                slot.owner.store(owner as u32);
                self.ep_owner_shadow[i].store(owner as u32, Ordering::Relaxed);
                slot.rx_channel.store(0);
                slot.state.transition_exact(ep_state::CREATING, ep_state::ACTIVE);
                return Ok(i);
            }
        }
        Err(Status::Exhausted)
    }

    /// Attach the calling thread as an MPMC consumer of endpoint `ep`,
    /// identified by dense node slot `node` (the identity the crash-
    /// repair machinery keys wedged claims on). First attach builds the
    /// endpoint's [`ConsumerGroup`] and migrates any entries already
    /// committed to the single-consumer queue into it; attach *before*
    /// traffic is the documented pattern — a late attach racing a
    /// single-consumer receiver on another thread keeps that queue's
    /// debug single-consumer guard in force for the migration pop.
    /// Returns the attached-consumer count. Lock-free backend only
    /// (`InvalidRequest` on `Locked`, whose global lock already admits
    /// any number of receivers).
    pub fn endpoint_attach_consumer(&self, ep: usize, node: usize) -> Result<u32, Status> {
        self.charge_api();
        if self.cfg.backend != BackendKind::LockFree {
            return Err(Status::InvalidRequest);
        }
        if node >= self.cfg.max_nodes {
            return Err(Status::InvalidEndpoint);
        }
        let slot = self.active_ep(ep)?;
        let group = slot.group.get_or_init(|| {
            // One SPSC lane per node slot; each lane sized to the whole
            // flag-board composition it replaces (every priority ×
            // capacity), so the migration below always fits and
            // steady-state capacity is comparable.
            let g = ConsumerGroup::new(
                self.cfg.max_nodes.max(1),
                PRIORITIES * self.cfg.nbb_capacity,
            );
            g.set_trace_id(ep as u32);
            g
        });
        let count = group.attach(node as u32);
        // Migrate pending single-consumer entries so nothing committed
        // before the profile switch is stranded. Guarded on occupancy:
        // once a group is active all sends route to the ring, so later
        // attaches see an empty queue and never pop — popping claims
        // the queue's single-consumer debug token, which must stay with
        // the (at most one) thread that drained pre-attach traffic.
        if let QueueImpl::LockFree(q) = &slot.queue {
            if q.len() > 0 {
                while let Ok(e) = q.pop() {
                    if let Err((_, e)) = group.push(e) {
                        // Ring full (producers raced the migration):
                        // return the buffer to the pool, never leak it.
                        self.drop_entry(&e);
                    }
                }
            }
        }
        // Broadcast so parked receivers re-poll through the new route.
        self.ep_waits[ep].wake_all::<W>();
        Ok(count)
    }

    /// Delete an endpoint (must not be connected or running an MPMC
    /// consumer group).
    pub fn delete_endpoint(&self, ep: usize) -> Result<(), Status> {
        self.charge_api();
        let slot = self.endpoints.get(ep).ok_or(Status::InvalidEndpoint)?;
        if slot.rx_channel.load() != 0 {
            return Err(Status::Busy);
        }
        // A consumer group cannot be detached (the OnceLock is shared
        // behind the runtime Arc), so slot reuse would leak the old
        // group's routing onto the new endpoint.
        if slot.group.get().map_or(false, |g| g.active()) {
            return Err(Status::Busy);
        }
        slot.state
            .transition(ep_state::ACTIVE, ep_state::FREE)
            .map_err(|_| Status::InvalidEndpoint)?;
        slot.id.store(0);
        Ok(())
    }

    /// Find the endpoint table index for `id` (MCAPI `get_endpoint`).
    pub fn lookup(&self, id: EndpointId) -> Option<usize> {
        let packed = pack(id);
        self.endpoints
            .iter()
            .position(|s| s.id.load() == packed && s.state.state() == ep_state::ACTIVE)
    }

    fn active_ep(&self, ep: usize) -> Result<&EndpointSlot<W>, Status> {
        let slot = self.endpoints.get(ep).ok_or(Status::InvalidEndpoint)?;
        if slot.state.state() != ep_state::ACTIVE {
            return Err(Status::InvalidEndpoint);
        }
        Ok(slot)
    }

    // -- buffer lease helpers (Figure 4 lifecycle) ---------------------------

    fn lease_filled(&self, data: &[u8], node: usize) -> Result<Lease, Status> {
        if data.len() > self.cfg.buf_len {
            return Err(Status::MessageLimit);
        }
        let lease = self.pool.acquire().ok_or(Status::MemLimit)?;
        // Custody: `node` holds this buffer until it is queued, aborted,
        // or released (host-side store; recorded before the next priced
        // op so an injected kill cannot slip between pool pop and the
        // custody record — faults fire only at priced operations).
        self.buffer_holder[lease.index].store(node as u32 + 1, Ordering::Relaxed);
        // Figure 4: FREE -> RESERVED (claimed) -> ALLOCATED (filled).
        self.buffer_fsm[lease.index].transition_exact(entry_state::FREE, entry_state::RESERVED);
        self.pool.write(&lease, data);
        self.buffer_fsm[lease.index]
            .transition_exact(entry_state::RESERVED, entry_state::ALLOCATED);
        Ok(lease)
    }

    fn lease_of(&self, e: &Entry) -> Lease {
        Lease {
            index: e.buf_index as usize,
            offset: e.buf_index as usize * self.cfg.buf_len,
            len: self.cfg.buf_len,
        }
    }

    fn consume_entry(&self, e: &Entry, out: &mut [u8], node: usize) -> usize {
        if !e.has_buffer() {
            return 0;
        }
        let lease = self.lease_of(e);
        // Custody: the receiving node holds the buffer from pop to
        // release (host-side; see `lease_filled` for why a kill cannot
        // land between the queue pop and this record).
        self.buffer_holder[lease.index].store(node as u32 + 1, Ordering::Relaxed);
        // Figure 4: ALLOCATED -> RECEIVED (head, being read) -> FREE.
        self.buffer_fsm[lease.index]
            .transition_exact(entry_state::ALLOCATED, entry_state::RECEIVED);
        let n = (e.len as usize).min(out.len());
        let copied = self.pool.read(&lease, &mut out[..n]);
        self.buffer_fsm[lease.index]
            .transition_exact(entry_state::RECEIVED, entry_state::FREE);
        self.pool.release(lease);
        self.buffer_holder[lease.index].store(0, Ordering::Relaxed);
        copied
    }

    fn abort_lease(&self, lease: Lease) {
        self.buffer_fsm[lease.index]
            .transition_exact(entry_state::ALLOCATED, entry_state::FREE);
        self.pool.release(lease);
        self.buffer_holder[lease.index].store(0, Ordering::Relaxed);
    }

    /// Last-resort release of a committed entry's buffer without
    /// delivering it (recovery paths only: a salvaged payload whose
    /// re-enqueue found the ring full). Forces the Figure 4 FSM back
    /// to FREE from whatever state the entry reached.
    fn drop_entry(&self, e: &Entry) {
        if !e.has_buffer() {
            return;
        }
        let lease = self.lease_of(e);
        let st = self.buffer_fsm[lease.index].state();
        if st != entry_state::FREE {
            let _ = self.buffer_fsm[lease.index].transition(st, entry_state::FREE);
        }
        self.pool.release(lease);
        self.buffer_holder[lease.index].store(0, Ordering::Relaxed);
        self.stat_leases_reclaimed.fetch_add(1, Ordering::Relaxed);
        obs::add(obs::ctr::LEASES_RECLAIMED, 1);
    }

    // -- connectionless messages ---------------------------------------------

    /// Non-blocking connection-less send from dense node `from` to
    /// endpoint `to`; `priority` 0 (highest) .. 3.
    pub fn msg_send(
        &self,
        from: usize,
        to: EndpointId,
        data: &[u8],
        priority: u8,
    ) -> Result<(), Status> {
        self.charge_api();
        self.fence_check(from)?;
        self.hb.bump(from);
        match self.cfg.backend {
            BackendKind::Locked => {
                // The reference design locks the shared-memory database for
                // *every* subsystem access — endpoint metadata, the buffer
                // pool, the receive queue ("MRAPI lock invocations for
                // every asynchronous request or data exchange"). Each
                // section is a separate lock round-trip; this is the
                // convoy the paper measures, so keep it faithful.
                let ep = self
                    .global
                    .with_read(|| self.lookup(to))
                    .ok_or(Status::InvalidEndpoint)?;
                self.check_dest_alive(ep)?;
                let lease = self.global.with_write(|| self.lease_filled(data, from))?;
                let entry = Entry::buffered(
                    lease.index as u32,
                    data.len() as u32,
                    from as u32,
                    priority % PRIORITIES as u8,
                );
                let res = self.global.with_write(|| {
                    let QueueImpl::Locked(q) = &self.endpoints[ep].queue else {
                        unreachable!("locked backend uses locked queues");
                    };
                    // Safety: the global write lock is held.
                    unsafe { q.push(entry) }.map_err(|s| {
                        self.abort_lease(lease);
                        s
                    })
                });
                if res.is_ok() {
                    // Custody passes to the queue; wake parked receivers.
                    self.buffer_holder[lease.index].store(0, Ordering::Relaxed);
                    self.ep_waits[ep].wake_all::<W>();
                }
                res
            }
            BackendKind::LockFree => {
                let ep = self.lookup(to).ok_or(Status::InvalidEndpoint)?;
                self.check_dest_alive(ep)?;
                let lease = self.lease_filled(data, from)?;
                let entry = Entry::buffered(
                    lease.index as u32,
                    data.len() as u32,
                    from as u32,
                    priority % PRIORITIES as u8,
                );
                // MPMC profile: entries route through the consumer
                // group's shared ring. Deciding costs one host-atomic
                // load when no group was ever attached, so the
                // single-consumer hot path's priced ops are unchanged.
                if let Some(g) = self.endpoints[ep].group.get().filter(|g| g.active()) {
                    return match g.push(entry) {
                        Ok(()) => {
                            self.buffer_holder[lease.index].store(0, Ordering::Relaxed);
                            // Targeted doorbell: one entry satisfies one
                            // member, so wake exactly one — the PR 5
                            // broadcast woke the whole group to race it.
                            // A member that wakes to nothing re-rings
                            // (`wake.misses`), so no wakeup is lost.
                            self.ep_waits[ep].wake_one::<W>();
                            Ok(())
                        }
                        Err((s, _)) => {
                            self.abort_lease(lease);
                            Err(s)
                        }
                    };
                }
                let QueueImpl::LockFree(q) = &self.endpoints[ep].queue else {
                    unreachable!("lockfree backend uses NBB queues");
                };
                match q.push(entry) {
                    Ok(()) => {
                        // Custody passes to the queue; wake parked receivers.
                        self.buffer_holder[lease.index].store(0, Ordering::Relaxed);
                        self.ep_waits[ep].wake_all::<W>();
                        Ok(())
                    }
                    Err((s, _)) => {
                        self.abort_lease(lease);
                        Err(s)
                    }
                }
            }
        }
    }

    /// `EndpointDead` when the destination endpoint's owner node has been
    /// declared dead — a message to it could never be consumed. Host-side
    /// loads only (zero priced-op cost on the hot path).
    fn check_dest_alive(&self, ep: usize) -> Result<(), Status> {
        let owner = self.ep_owner_shadow[ep].load(Ordering::Relaxed) as usize;
        if self.node_alive(owner) {
            Ok(())
        } else {
            self.stat_poisons.fetch_add(1, Ordering::Relaxed);
            obs::bump(obs::ctr::POISONS);
            Err(Status::EndpointDead)
        }
    }

    /// Non-blocking connection-less receive on endpoint table slot `ep`;
    /// copies into `out`, returns the byte count.
    pub fn msg_recv(&self, ep: usize, out: &mut [u8]) -> Result<usize, Status> {
        self.charge_api();
        self.hb.bump(self.ep_owner_node(ep));
        match self.cfg.backend {
            BackendKind::Locked => {
                let entry = self.global.with_write(|| {
                    let slot = self.active_ep(ep)?;
                    let QueueImpl::Locked(q) = &slot.queue else {
                        unreachable!();
                    };
                    // Safety: the global write lock is held.
                    unsafe { q.pop() }.ok_or(Status::WouldBlock)
                })?;
                let node = self.ep_owner_shadow[ep].load(Ordering::Relaxed) as usize;
                // Buffer read + release is a second lock round-trip in the
                // reference design.
                let n = self.global.with_write(|| self.consume_entry(&entry, out, node));
                self.ep_waits[ep].wake_all::<W>();
                Ok(n)
            }
            BackendKind::LockFree => {
                let slot = self.active_ep(ep)?;
                // MPMC profile: pop from the group ring as this
                // thread's attached identity (falling back to the
                // endpoint owner for un-attached callers, e.g. a
                // scavenger draining a dead group). `consume_entry`
                // records custody under the *consumer's* node, so a
                // consumer killed mid-copy is reclaimed by its own
                // node's custody sweep.
                if let Some(g) = slot.group.get().filter(|g| g.active()) {
                    let owner = self.ep_owner_shadow[ep].load(Ordering::Relaxed);
                    let who = ConsumerGroup::<W>::current_who().unwrap_or(owner);
                    // MPMC claims are fenced: a zombie consumer must
                    // not take work the repair pipeline would have to
                    // salvage from it again.
                    self.fence_check(who as usize)?;
                    self.hb.bump(who as usize);
                    let entry = match g.pop(who) {
                        Ok(e) => e,
                        Err(s) => {
                            // Wake-one fallback: this member was rung
                            // but a peer drained the work first. Pass
                            // the doorbell on so a member that still
                            // has work parked behind us is not lost —
                            // the counter proves the herd fix never
                            // drops a wakeup.
                            if s == Status::WouldBlock && g.len() > 0 {
                                obs::bump(obs::ctr::WAKE_MISSES);
                                self.ep_waits[ep].wake_one::<W>();
                            }
                            return Err(s);
                        }
                    };
                    let n = self.consume_entry(&entry, out, who as usize);
                    // Space freed: wake senders parked on a full lane.
                    // Backlog remains → chain the doorbell to the next
                    // parked member (wake-one delivers one wake per
                    // entry; the chain keeps the group saturated).
                    if g.len() > 0 {
                        self.ep_waits[ep].wake_one::<W>();
                    } else {
                        self.ep_waits[ep].wake_all::<W>();
                    }
                    return Ok(n);
                }
                let QueueImpl::LockFree(q) = &slot.queue else {
                    unreachable!();
                };
                let entry = q.pop()?;
                let node = self.ep_owner_shadow[ep].load(Ordering::Relaxed) as usize;
                let n = self.consume_entry(&entry, out, node);
                // Space freed: wake senders parked on a full lane.
                self.ep_waits[ep].wake_all::<W>();
                Ok(n)
            }
        }
    }

    /// Batched connection-less send: enqueue as many of `payloads` as fit,
    /// in order, to endpoint `to` — amortizing endpoint lookup and (on the
    /// lock-free path) the NBB enter/exit counter stores over the whole
    /// prefix. Returns how many messages were enqueued; `Err` only when
    /// none were. The locked backend loops the scalar path (the reference
    /// design has no batch primitive — that asymmetry is part of what the
    /// `micro_lockfree` batch ablation measures).
    pub fn msg_send_batch(
        &self,
        from: usize,
        to: EndpointId,
        payloads: &[&[u8]],
        priority: u8,
    ) -> Result<usize, Status> {
        if payloads.is_empty() {
            return Ok(0);
        }
        match self.cfg.backend {
            BackendKind::Locked => {
                let mut sent = 0;
                for data in payloads {
                    match self.msg_send(from, to, data, priority) {
                        Ok(()) => sent += 1,
                        Err(s) if sent == 0 => return Err(s),
                        Err(_) => break,
                    }
                }
                Ok(sent)
            }
            BackendKind::LockFree => {
                self.charge_api();
                self.fence_check(from)?;
                self.hb.bump(from);
                let ep = self.lookup(to).ok_or(Status::InvalidEndpoint)?;
                self.check_dest_alive(ep)?;
                let prio = priority % PRIORITIES as u8;
                // Lease and fill buffers first; entries become one lane batch.
                let mut entries = Vec::with_capacity(payloads.len());
                let mut lease_err = None;
                for data in payloads {
                    match self.lease_filled(data, from) {
                        Ok(lease) => entries.push(Entry::buffered(
                            lease.index as u32,
                            data.len() as u32,
                            from as u32,
                            prio,
                        )),
                        Err(s) => {
                            lease_err = Some(s);
                            break;
                        }
                    }
                }
                if entries.is_empty() {
                    return Err(lease_err.unwrap_or(Status::WouldBlock));
                }
                let batched: Vec<u32> = entries.iter().map(|e| e.buf_index).collect();
                let QueueImpl::LockFree(q) = &self.endpoints[ep].queue else {
                    unreachable!("lockfree backend uses NBB queues");
                };
                // MPMC profile: one enter/exit counter pair on the
                // sender's own lane covers the whole run
                // (`ShardedRing::send_batch` — stores only, no CAS).
                let result = match self.endpoints[ep].group.get().filter(|g| g.active()) {
                    Some(g) => g.push_batch(&mut entries),
                    None => q.push_batch(&mut entries),
                };
                // Whatever did not go in stays in `entries`: hand its
                // buffers back (Figure 4 abort path). Custody of the
                // enqueued prefix passes to the queue.
                for e in &entries {
                    self.abort_lease(self.lease_of(e));
                }
                let unsent: Vec<u32> = entries.iter().map(|e| e.buf_index).collect();
                for idx in batched {
                    if !unsent.contains(&idx) {
                        self.buffer_holder[idx as usize].store(0, Ordering::Relaxed);
                    }
                }
                if result.is_ok() {
                    self.ep_waits[ep].wake_all::<W>();
                }
                result
            }
        }
    }

    /// Batched connection-less receive: drain up to `max` messages from
    /// `ep` into `out` (one `Vec<u8>` per message, appended in queue
    /// order). Returns how many arrived; `Err` when none were pending.
    pub fn msg_recv_batch(
        &self,
        ep: usize,
        out: &mut Vec<Vec<u8>>,
        max: usize,
    ) -> Result<usize, Status> {
        if max == 0 {
            return Ok(0);
        }
        match self.cfg.backend {
            BackendKind::Locked => {
                let mut buf = vec![0u8; self.cfg.buf_len];
                let mut got = 0;
                while got < max {
                    match self.msg_recv(ep, &mut buf) {
                        Ok(n) => {
                            out.push(buf[..n].to_vec());
                            got += 1;
                        }
                        Err(s) if got == 0 => return Err(s),
                        Err(_) => break,
                    }
                }
                Ok(got)
            }
            BackendKind::LockFree => {
                self.charge_api();
                self.hb.bump(self.ep_owner_node(ep));
                let slot = self.active_ep(ep)?;
                // MPMC profile: drain the group ring one claim at a
                // time under this thread's attached identity.
                if let Some(g) = slot.group.get().filter(|g| g.active()) {
                    let owner = self.ep_owner_shadow[ep].load(Ordering::Relaxed);
                    let who = ConsumerGroup::<W>::current_who().unwrap_or(owner);
                    self.fence_check(who as usize)?;
                    self.hb.bump(who as usize);
                    let mut buf = vec![0u8; self.cfg.buf_len];
                    let mut got = 0;
                    while got < max {
                        match g.pop(who) {
                            Ok(e) => {
                                let len = self.consume_entry(&e, &mut buf, who as usize);
                                out.push(buf[..len].to_vec());
                                got += 1;
                            }
                            Err(s) if got == 0 => return Err(s),
                            Err(_) => break,
                        }
                    }
                    self.ep_waits[ep].wake_all::<W>();
                    return Ok(got);
                }
                let QueueImpl::LockFree(q) = &slot.queue else {
                    unreachable!("lockfree backend uses NBB queues");
                };
                let mut entries = Vec::with_capacity(max.min(64));
                let n = q.pop_batch(&mut entries, max)?;
                let node = self.ep_owner_shadow[ep].load(Ordering::Relaxed) as usize;
                let mut buf = vec![0u8; self.cfg.buf_len];
                for e in &entries {
                    let len = self.consume_entry(e, &mut buf, node);
                    out.push(buf[..len].to_vec());
                }
                self.ep_waits[ep].wake_all::<W>();
                Ok(n)
            }
        }
    }

    /// Number of messages waiting on `ep` (MCAPI `msg_available`).
    pub fn msg_available(&self, ep: usize) -> Result<usize, Status> {
        let slot = self.active_ep(ep)?;
        Ok(match (&slot.queue, self.cfg.backend) {
            (QueueImpl::Locked(q), _) => self.global.with_read(|| unsafe { q.len() }),
            // The group ring and the legacy queue both count: entries
            // committed before the first attach may still sit in the
            // queue briefly (attach migrates them).
            (QueueImpl::LockFree(q), _) => {
                q.len() + slot.group.get().map_or(0, |g| g.len())
            }
        })
    }

    // -- connected channels ---------------------------------------------------

    /// Connect a channel from `tx` to `rx` (both must be active; `rx` not
    /// already connected). Returns the channel table index.
    pub fn connect(&self, tx: EndpointId, rx: EndpointId, kind: ChannelKind) -> Result<usize, Status> {
        self.charge_api();
        let run = || -> Result<usize, Status> {
            let tx_i = self.lookup(tx).ok_or(Status::InvalidEndpoint)?;
            let rx_i = self.lookup(rx).ok_or(Status::InvalidEndpoint)?;
            let ch = self
                .channels
                .iter()
                .position(|c| c.state.transition(ch_state::FREE, ch_state::CONNECTING).is_ok())
                .ok_or(Status::Exhausted)?;
            let slot = &self.channels[ch];
            // Claim the receive side exclusively.
            if self.endpoints[rx_i]
                .rx_channel
                .cas(0, ch as u32 + 1)
                .is_err()
            {
                slot.state.transition_exact(ch_state::CONNECTING, ch_state::FREE);
                return Err(Status::Busy);
            }
            slot.kind.store(match kind {
                ChannelKind::Packet => 0,
                ChannelKind::Scalar => 1,
                ChannelKind::State => 2,
            });
            slot.tx_ep.store(tx_i as u32);
            slot.rx_ep.store(rx_i as u32);
            // Host shadows of the owner nodes for the liveness plane:
            // the ring fast path's fence checks and heartbeat bumps
            // must not pay the priced `tx_ep`/`rx_ep` loads.
            self.chan_tx_node[ch]
                .store(self.ep_owner_shadow[tx_i].load(Ordering::Relaxed), Ordering::Relaxed);
            self.chan_rx_node[ch]
                .store(self.ep_owner_shadow[rx_i].load(Ordering::Relaxed), Ordering::Relaxed);
            slot.tx_open.store(0);
            slot.rx_open.store(0);
            // Fast-path hygiene: a reused channel slot's ring may hold
            // residue from a previous connection — and, after a crash, a
            // torn counter from a peer that died mid-operation. Roll both
            // sides back to even, drain the residue, clear poison and the
            // doorbell bit before publishing the channel (exclusive here:
            // the slot is CONNECTING, claimed by this thread's CAS).
            if let Some(ring) = &slot.ring {
                ring.repair_dead_producer();
                ring.repair_dead_consumer();
                ring.drain();
            }
            self.chan_poison[ch].store(0, Ordering::SeqCst);
            self.doorbell.clear(ch);
            slot.state.transition_exact(ch_state::CONNECTING, ch_state::CONNECTED);
            Ok(ch)
        };
        match self.cfg.backend {
            BackendKind::Locked => self.global.with_write(run),
            BackendKind::LockFree => run(),
        }
    }

    fn connected_ch(&self, ch: usize) -> Result<&ChannelSlot<W>, Status> {
        let slot = self.channels.get(ch).ok_or(Status::InvalidChannel)?;
        if slot.state.state() != ch_state::CONNECTED {
            return Err(Status::InvalidChannel);
        }
        Ok(slot)
    }

    /// Open the send side (must be the owner's endpoint; MCAPI
    /// `open_pkt_send` / `open_sclr_send`).
    pub fn open_send(&self, ch: usize) -> Result<(), Status> {
        self.charge_api();
        let slot = self.connected_ch(ch)?;
        slot.tx_open.cas(0, 1).map(|_| ()).map_err(|_| Status::Busy)
    }

    /// Open the receive side.
    pub fn open_recv(&self, ch: usize) -> Result<(), Status> {
        self.charge_api();
        let slot = self.connected_ch(ch)?;
        slot.rx_open.cas(0, 1).map(|_| ()).map_err(|_| Status::Busy)
    }

    /// Close both sides and release the channel + its receive claim.
    pub fn close(&self, ch: usize) -> Result<(), Status> {
        self.charge_api();
        let slot = self.connected_ch(ch)?;
        let rx = slot.rx_ep.load() as usize;
        slot.state
            .transition(ch_state::CONNECTED, ch_state::FREE)
            .map_err(|_| Status::InvalidChannel)?;
        let _ = self.endpoints[rx].rx_channel.cas(ch as u32 + 1, 0);
        slot.tx_open.store(0);
        slot.rx_open.store(0);
        // A flagged-but-unclosed doorbell bit would make `chan_poll`
        // report this dead channel forever (and starve channels behind
        // it in the poll list) — the receiver can no longer clear it
        // once `channel_ready` fails. `connect` re-clears on slot reuse
        // for the narrow close-races-a-sender window.
        self.doorbell.clear(ch);
        self.chan_poison[ch].store(0, Ordering::SeqCst);
        // Teardown guarantee: anyone parked on this channel re-checks
        // and surfaces `InvalidChannel` instead of sleeping to its
        // deadline.
        self.chan_waits[ch].wake_all::<W>();
        Ok(())
    }

    fn channel_ready(&self, ch: usize, kind: ChannelKind) -> Result<(usize, usize), Status> {
        let slot = self.connected_ch(ch)?;
        let want = match kind {
            ChannelKind::Packet => 0,
            ChannelKind::Scalar => 1,
            ChannelKind::State => 2,
        };
        if slot.kind.load() != want {
            return Err(Status::InvalidChannel);
        }
        if slot.tx_open.load() == 0 || slot.rx_open.load() == 0 {
            return Err(Status::InvalidChannel);
        }
        Ok((slot.tx_ep.load() as usize, slot.rx_ep.load() as usize))
    }

    /// Packet send on an open channel (non-blocking).
    pub fn pkt_send(&self, ch: usize, data: &[u8]) -> Result<(), Status> {
        self.charge_api();
        match self.cfg.backend {
            BackendKind::Locked => {
                let (tx_i, rx_i) =
                    self.global.with_read(|| self.channel_ready(ch, ChannelKind::Packet))?;
                self.fence_check(self.tx_node_of(ch))?;
                self.hb.bump(self.tx_node_of(ch));
                if self.chan_poison[ch].load(Ordering::Relaxed) & POISON_RX_DEAD != 0 {
                    self.stat_poisons.fetch_add(1, Ordering::Relaxed);
                    obs::bump(obs::ctr::POISONS);
                    return Err(Status::EndpointDead);
                }
                let from = self.global.with_read(|| self.endpoints[tx_i].owner.load());
                let lease = self.global.with_write(|| self.lease_filled(data, from as usize))?;
                let entry = Entry::buffered(lease.index as u32, data.len() as u32, from, 0);
                let res = self.global.with_write(|| {
                    let QueueImpl::Locked(q) = &self.endpoints[rx_i].queue else {
                        unreachable!();
                    };
                    // Safety: global write lock held.
                    unsafe { q.push(entry) }.map_err(|s| {
                        self.abort_lease(lease);
                        s
                    })
                });
                if res.is_ok() {
                    self.buffer_holder[lease.index].store(0, Ordering::Relaxed);
                    self.chan_waits[ch].wake_all::<W>();
                }
                res
            }
            BackendKind::LockFree => {
                // Fast path: payload bytes go straight into the channel
                // ring's slot — no pool lease, no abort path, one fewer
                // copy (see `channel`).
                self.channel_ready(ch, ChannelKind::Packet)?;
                self.ring_pkt_send(ch, data)
            }
        }
    }

    /// Packet receive on an open channel (non-blocking). On the `Locked`
    /// reference path the receive buffer is pool-allocated per the spec
    /// (copied out and released here); on the lock-free fast path the
    /// payload comes straight from the channel ring's slot.
    pub fn pkt_recv(&self, ch: usize, out: &mut [u8]) -> Result<usize, Status> {
        self.charge_api();
        match self.cfg.backend {
            BackendKind::Locked => {
                self.hb.bump(self.rx_node_of(ch));
                let popped = self.global.with_write(|| {
                    let (_, rx_i) = self.channel_ready(ch, ChannelKind::Packet)?;
                    let QueueImpl::Locked(q) = &self.endpoints[rx_i].queue else {
                        unreachable!();
                    };
                    // Safety: global write lock held.
                    unsafe { q.pop() }.ok_or(Status::WouldBlock).map(|e| (e, rx_i))
                });
                let (entry, rx_i) = match popped {
                    // Queue empty means everything committed has drained:
                    // only now may a dead producer's poison surface.
                    Err(Status::WouldBlock)
                        if self.chan_poison[ch].load(Ordering::Relaxed) & POISON_TX_DEAD != 0 =>
                    {
                        self.stat_poisons.fetch_add(1, Ordering::Relaxed);
                        obs::bump(obs::ctr::POISONS);
                        return Err(Status::EndpointDead);
                    }
                    other => other?,
                };
                let node = self.ep_owner_shadow[rx_i].load(Ordering::Relaxed) as usize;
                let n = self.global.with_write(|| self.consume_entry(&entry, out, node));
                self.chan_waits[ch].wake_all::<W>();
                Ok(n)
            }
            BackendKind::LockFree => {
                // Fast path: copy straight out of the ring slot (or use
                // `channel`'s batch/zero-copy forms to skip this copy too).
                self.channel_ready(ch, ChannelKind::Packet)?;
                self.ring_pkt_recv(ch, out)
            }
        }
    }

    /// 64-bit scalar send. Width-typed variants (8/16/32-bit, with
    /// receive-side width checking) live in [`channel`]:
    /// `sclr_send8/16/32/64`.
    pub fn sclr_send(&self, ch: usize, value: u64) -> Result<(), Status> {
        self.sclr_send_w(ch, value, 8)
    }

    /// 64-bit scalar receive (width-checked; see [`channel`]).
    pub fn sclr_recv(&self, ch: usize) -> Result<u64, Status> {
        self.sclr_recv_w(ch, 8)
    }

    // -- state channels (paper §7 future work) --------------------------------

    /// Publish the current value on a *state* channel. Never blocks: the
    /// NBW protocol guarantees the writer is never blocked by readers,
    /// and the FIFO requirement is dropped (order indeterminate).
    pub fn state_send(&self, ch: usize, value: u64) -> Result<(), Status> {
        self.charge_api();
        match self.cfg.backend {
            BackendKind::Locked => self.global.with_write(|| {
                self.channel_ready(ch, ChannelKind::State)?;
                self.channels[ch].nbw.write(value);
                Ok(())
            }),
            BackendKind::LockFree => {
                self.channel_ready(ch, ChannelKind::State)?;
                self.channels[ch].nbw.write(value);
                Ok(())
            }
        }
    }

    /// Sample the freshest value on a *state* channel. `WouldBlock` until
    /// the first write; collisions are retried internally (NBW Safety +
    /// Timeliness properties).
    pub fn state_recv(&self, ch: usize) -> Result<u64, Status> {
        self.charge_api();
        let read = || -> Result<u64, Status> {
            self.channel_ready(ch, ChannelKind::State)?;
            let (v, _retries) = self.channels[ch].nbw.read();
            v.ok_or(Status::WouldBlock)
        };
        match self.cfg.backend {
            BackendKind::Locked => self.global.with_write(read),
            BackendKind::LockFree => read(),
        }
    }

    // -- asynchronous operations (requests, Figure 3) -------------------------

    /// Start an asynchronous message send; completes via [`Self::wait_send`].
    pub fn msg_send_i(
        &self,
        from: usize,
        to: EndpointId,
        data: &[u8],
        priority: u8,
    ) -> Result<RequestHandle, Status> {
        let ep = self.lookup(to).ok_or(Status::InvalidEndpoint)?;
        let h = self.requests.allocate(PendingOp::MsgSend { ep })?;
        match self.msg_send(from, to, data, priority) {
            Ok(()) => {
                // Exceptional send path: RECEIVED until receipt confirmed;
                // buffer handoff is synchronous here, so confirm at once.
                let _ = self.requests.mark_received(h);
                self.requests.complete(h, Status::Success);
                Ok(h)
            }
            Err(s) if s.is_would_block() => Ok(h), // pending; wait re-drives
            Err(s) => {
                self.requests.complete(h, s);
                Ok(h)
            }
        }
    }

    /// Start an asynchronous message receive; completes via
    /// [`Self::wait_recv`].
    pub fn msg_recv_i(&self, ep: usize) -> Result<RequestHandle, Status> {
        self.active_ep(ep)?;
        self.requests.allocate(PendingOp::MsgRecv { ep })
    }

    /// Drive `attempt` to completion with the hardened blocking
    /// progression: bounded spinning on `*_BUT_*` peer-active results,
    /// then yields, then a futex park on `cell` bounded by the operation
    /// deadline. `Err(Status::Timeout)` once `timeout_ns` elapses; every
    /// other non-would-block error (poison and teardown included)
    /// surfaces immediately. Waiters are guaranteed to wake for a
    /// message, a poison flag, channel teardown, or the deadline —
    /// whichever comes first.
    /// `node` identifies the caller for the liveness plane: the beat
    /// advances on entry and around every park/unpark transition, and
    /// the registry's parked count keeps the watchdog from suspecting
    /// a legitimately idle waiter (`usize::MAX` = anonymous, inert).
    fn blocking_drive<T>(
        &self,
        cell: &WaitCell,
        node: usize,
        timeout_ns: u64,
        mut attempt: impl FnMut() -> Result<T, Status>,
    ) -> Result<T, Status> {
        self.hb.bump(node);
        let deadline = W::now_ns().saturating_add(timeout_ns);
        let mut bo = Backoff::<W>::new();
        loop {
            match attempt() {
                Ok(v) => return Ok(v),
                Err(s) if s.is_would_block() => {
                    if W::now_ns() >= deadline {
                        self.stat_timeouts.fetch_add(1, Ordering::Relaxed);
                        obs::bump(obs::ctr::TIMEOUTS);
                        return Err(Status::Timeout);
                    }
                    // Table 1: peer mid-operation — spin within budget.
                    if s == Status::WouldBlockPeerActive && bo.immediate() {
                        continue;
                    }
                    if bo.yields() < YIELDS_BEFORE_PARK {
                        bo.yield_now();
                        continue;
                    }
                    // Park: register, re-poll once (an unregistered poll
                    // can miss a publish-then-wake), sleep until a wake
                    // or the deadline. Spurious wakes just re-loop.
                    let seen = cell.prepare();
                    match attempt() {
                        Ok(v) => {
                            cell.finish();
                            return Ok(v);
                        }
                        Err(s2) if s2.is_would_block() => {
                            if obs::tracing() {
                                let tch = cell.trace_ch.load(Ordering::Relaxed);
                                obs::emit::<W>(obs::EventKind::BlockPark, tch, seen, bo.yields());
                                obs::bump(obs::ctr::BLOCK_PARKS);
                            }
                            self.hb.park(node);
                            cell.wait::<W>(seen, Some(deadline));
                            self.hb.unpark(node);
                            if obs::tracing() {
                                let tch = cell.trace_ch.load(Ordering::Relaxed);
                                obs::emit::<W>(obs::EventKind::BlockUnpark, tch, seen, 0);
                            }
                            cell.finish();
                        }
                        Err(e) => {
                            cell.finish();
                            return Err(e);
                        }
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Drive a pending send request to completion within `timeout_ns`
    /// (virtual ns in simulated worlds). MCAPI `wait`.
    pub fn wait_send(
        &self,
        h: RequestHandle,
        from: usize,
        to: EndpointId,
        data: &[u8],
        priority: u8,
        timeout_ns: u64,
    ) -> Status {
        if self.requests.is_complete(h) {
            return self.requests.reap(h).unwrap_or(Status::InvalidRequest);
        }
        let Some(ep) = self.lookup(to) else {
            self.requests.complete(h, Status::InvalidEndpoint);
            return self.requests.reap(h).unwrap_or(Status::InvalidRequest);
        };
        let drive = self.blocking_drive(&self.ep_waits[ep], from, timeout_ns, || {
            self.msg_send(from, to, data, priority)
        });
        match drive {
            Ok(()) => {
                self.requests.complete(h, Status::Success);
                self.requests.reap(h).unwrap_or(Status::InvalidRequest)
            }
            // Request stays pending across a timeout (re-waitable).
            Err(Status::Timeout) => Status::Timeout,
            Err(s) => {
                self.requests.complete(h, s);
                self.requests.reap(h).unwrap_or(Status::InvalidRequest)
            }
        }
    }

    /// Drive a pending receive request within `timeout_ns`; on success
    /// returns the byte count. MCAPI `wait`.
    pub fn wait_recv(
        &self,
        h: RequestHandle,
        out: &mut [u8],
        timeout_ns: u64,
    ) -> Result<usize, Status> {
        let PendingOp::MsgRecv { ep } = self.requests.slot(h).op() else {
            return Err(Status::InvalidRequest);
        };
        let drive = self.blocking_drive(&self.ep_waits[ep], self.ep_owner_node(ep), timeout_ns, || {
            self.msg_recv(ep, out)
        });
        match drive {
            Ok(n) => {
                self.requests.complete(h, Status::Success);
                let _ = self.requests.reap(h);
                Ok(n)
            }
            // Request stays pending across a timeout (cancellable).
            Err(Status::Timeout) => Err(Status::Timeout),
            Err(s) => {
                self.requests.complete(h, s);
                let _ = self.requests.reap(h);
                Err(s)
            }
        }
    }

    // -- deadline / backoff senders -------------------------------------------

    /// Blocking connection-less send under an **absolute** deadline (in
    /// [`World::now_ns`] time) with retry-with-backoff slicing: each
    /// retry runs the spin → yield → futex progression for at most one
    /// [`RetryBackoff`] slice, so waiting on a dying peer costs a few
    /// bounded wakeups (and each slice boundary re-checks fencing and
    /// poison) instead of one long park. `Status::Timeout` once the
    /// deadline passes; callers degrade gracefully instead of blocking
    /// forever on a peer the watchdog has not yet confirmed dead.
    pub fn msg_send_deadline(
        &self,
        from: usize,
        to: EndpointId,
        data: &[u8],
        priority: u8,
        deadline_ns: u64,
    ) -> Result<(), Status> {
        let ep = self.lookup(to).ok_or(Status::InvalidEndpoint)?;
        let mut bo = RetryBackoff::new();
        loop {
            let remaining = deadline_ns.saturating_sub(W::now_ns());
            let Some(slice) = bo.next_slice(remaining) else {
                // The expiring slice already counted itself.
                return Err(Status::Timeout);
            };
            match self.blocking_drive(&self.ep_waits[ep], from, slice, || {
                self.msg_send(from, to, data, priority)
            }) {
                Err(Status::Timeout) => continue,
                other => return other,
            }
        }
    }

    /// Blocking connection-less receive under an absolute deadline with
    /// backoff slicing (see [`Self::msg_send_deadline`]). On success
    /// returns the byte count.
    pub fn msg_recv_deadline(
        &self,
        ep: usize,
        out: &mut [u8],
        deadline_ns: u64,
    ) -> Result<usize, Status> {
        let cell = self.ep_waits.get(ep).ok_or(Status::InvalidEndpoint)?;
        let node = self.ep_owner_node(ep);
        let mut bo = RetryBackoff::new();
        loop {
            let remaining = deadline_ns.saturating_sub(W::now_ns());
            let Some(slice) = bo.next_slice(remaining) else {
                return Err(Status::Timeout);
            };
            match self.blocking_drive(cell, node, slice, || self.msg_recv(ep, out)) {
                Err(Status::Timeout) => continue,
                other => return other,
            }
        }
    }

    /// Wait for the first of `handles` to complete, within `timeout_ns`.
    /// MCAPI `wait_any`. Returns the index of the completed request and
    /// its completion status; the request is reaped.
    ///
    /// Pending *receive* requests complete by **readiness**: data became
    /// available (`Success` — reap the payload with the matching
    /// synchronous receive afterwards) or the producing peer was
    /// declared dead with nothing left to drain (`EndpointDead`).
    /// Pending sends complete only through their own `wait_*` drivers.
    pub fn wait_any(
        &self,
        handles: &[RequestHandle],
        timeout_ns: u64,
    ) -> Result<(usize, Status), Status> {
        self.charge_api();
        if handles.is_empty() {
            return Err(Status::InvalidRequest);
        }
        let deadline = W::now_ns().saturating_add(timeout_ns);
        let mut bo = Backoff::<W>::new();
        loop {
            for (i, &h) in handles.iter().enumerate() {
                if self.requests.is_complete(h) {
                    let s = self.requests.reap(h).unwrap_or(Status::InvalidRequest);
                    return Ok((i, s));
                }
                let ready = match self.requests.slot(h).op() {
                    PendingOp::PktRecv { ch } => {
                        if self.chan_available(ch).unwrap_or(0) > 0 {
                            Some(Status::Success)
                        } else if self.chan_poison[ch].load(Ordering::Relaxed) & POISON_TX_DEAD
                            != 0
                        {
                            // Drained AND producer dead: fault completion.
                            Some(Status::EndpointDead)
                        } else {
                            None
                        }
                    }
                    PendingOp::MsgRecv { ep } => {
                        if self.msg_available(ep).unwrap_or(0) > 0 {
                            Some(Status::Success)
                        } else {
                            None
                        }
                    }
                    _ => None,
                };
                if let Some(s) = ready {
                    if s == Status::EndpointDead {
                        self.stat_poisons.fetch_add(1, Ordering::Relaxed);
                        obs::bump(obs::ctr::POISONS);
                    }
                    self.requests.complete(h, s);
                    let s = self.requests.reap(h).unwrap_or(Status::InvalidRequest);
                    return Ok((i, s));
                }
            }
            if W::now_ns() >= deadline {
                self.stat_timeouts.fetch_add(1, Ordering::Relaxed);
                obs::bump(obs::ctr::TIMEOUTS);
                return Err(Status::Timeout);
            }
            if !bo.immediate() {
                bo.yield_now();
            }
        }
    }

    /// Non-destructive test for completion. MCAPI `test`.
    pub fn test(&self, h: RequestHandle) -> bool {
        self.requests.is_complete(h)
    }

    /// Cancel a pending *receive* request. Sends always complete.
    pub fn cancel(&self, h: RequestHandle) -> Result<(), Status> {
        match self.requests.slot(h).op() {
            PendingOp::MsgRecv { .. } | PendingOp::PktRecv { .. } => self.requests.cancel(h),
            _ => Err(Status::InvalidRequest),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;

    fn rt(backend: BackendKind) -> Arc<McapiRuntime<RealWorld>> {
        McapiRuntime::new(RuntimeCfg { backend, ..Default::default() })
    }

    fn both() -> [Arc<McapiRuntime<RealWorld>>; 2] {
        [rt(BackendKind::Locked), rt(BackendKind::LockFree)]
    }

    #[test]
    fn endpoint_create_lookup_delete() {
        for rt in both() {
            let id = EndpointId::new(0, 1, 5);
            let ep = rt.create_endpoint(id, 1).unwrap();
            assert_eq!(rt.lookup(id), Some(ep));
            assert_eq!(rt.create_endpoint(id, 1).unwrap_err(), Status::Busy);
            rt.delete_endpoint(ep).unwrap();
            assert_eq!(rt.lookup(id), None);
        }
    }

    #[test]
    fn message_roundtrip_both_backends() {
        for rt in both() {
            let dst = EndpointId::new(0, 2, 1);
            let ep = rt.create_endpoint(dst, 2).unwrap();
            rt.msg_send(1, dst, b"hello", 1).unwrap();
            assert_eq!(rt.msg_available(ep).unwrap(), 1);
            let mut buf = [0u8; 64];
            let n = rt.msg_recv(ep, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"hello");
            assert_eq!(rt.msg_recv(ep, &mut buf).unwrap_err(), Status::WouldBlock);
            assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers);
        }
    }

    #[test]
    fn message_priority_order() {
        for rt in both() {
            let dst = EndpointId::new(0, 0, 9);
            let ep = rt.create_endpoint(dst, 0).unwrap();
            rt.msg_send(0, dst, b"low", 3).unwrap();
            rt.msg_send(0, dst, b"high", 0).unwrap();
            let mut buf = [0u8; 8];
            let n = rt.msg_recv(ep, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"high", "priority 0 must dequeue first");
        }
    }

    #[test]
    fn oversized_message_rejected() {
        for rt in both() {
            let dst = EndpointId::new(0, 0, 1);
            rt.create_endpoint(dst, 0).unwrap();
            let big = vec![0u8; rt.cfg().buf_len + 1];
            assert_eq!(rt.msg_send(0, dst, &big, 0).unwrap_err(), Status::MessageLimit);
        }
    }

    #[test]
    fn send_to_unknown_endpoint_fails() {
        for rt in both() {
            assert_eq!(
                rt.msg_send(0, EndpointId::new(9, 9, 9), b"x", 0).unwrap_err(),
                Status::InvalidEndpoint
            );
        }
    }

    #[test]
    fn queue_full_returns_would_block_and_leaks_nothing() {
        for rt in both() {
            let dst = EndpointId::new(0, 1, 1);
            let _ep = rt.create_endpoint(dst, 1).unwrap();
            let mut sent = 0;
            loop {
                match rt.msg_send(0, dst, b"m", 0) {
                    Ok(()) => sent += 1,
                    Err(s) => {
                        assert!(s.is_would_block(), "{s:?}");
                        break;
                    }
                }
            }
            assert!(sent > 0);
            // Buffers: pool must have exactly `sent` leased out.
            assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers - sent);
        }
    }

    #[test]
    fn packet_channel_roundtrip() {
        for rt in both() {
            let a = EndpointId::new(0, 1, 1);
            let b = EndpointId::new(0, 2, 1);
            rt.create_endpoint(a, 1).unwrap();
            rt.create_endpoint(b, 2).unwrap();
            let ch = rt.connect(a, b, ChannelKind::Packet).unwrap();
            // Not open yet.
            assert_eq!(rt.pkt_send(ch, b"x").unwrap_err(), Status::InvalidChannel);
            rt.open_send(ch).unwrap();
            rt.open_recv(ch).unwrap();
            rt.pkt_send(ch, b"packet!").unwrap();
            let mut buf = [0u8; 16];
            let n = rt.pkt_recv(ch, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"packet!");
            rt.close(ch).unwrap();
            assert_eq!(rt.pkt_send(ch, b"x").unwrap_err(), Status::InvalidChannel);
        }
    }

    #[test]
    fn scalar_channel_roundtrip_and_kind_check() {
        for rt in both() {
            let a = EndpointId::new(0, 1, 2);
            let b = EndpointId::new(0, 2, 2);
            rt.create_endpoint(a, 1).unwrap();
            rt.create_endpoint(b, 2).unwrap();
            let ch = rt.connect(a, b, ChannelKind::Scalar).unwrap();
            rt.open_send(ch).unwrap();
            rt.open_recv(ch).unwrap();
            rt.sclr_send(ch, 0xDEAD_BEEF_1234).unwrap();
            assert_eq!(rt.sclr_recv(ch).unwrap(), 0xDEAD_BEEF_1234);
            assert_eq!(rt.sclr_recv(ch).unwrap_err(), Status::WouldBlock);
            // Packet ops on a scalar channel are rejected.
            assert_eq!(rt.pkt_send(ch, b"x").unwrap_err(), Status::InvalidChannel);
        }
    }

    #[test]
    fn rx_endpoint_cannot_be_double_connected() {
        for rt in both() {
            let a = EndpointId::new(0, 1, 3);
            let b = EndpointId::new(0, 2, 3);
            let c = EndpointId::new(0, 3, 3);
            rt.create_endpoint(a, 1).unwrap();
            rt.create_endpoint(b, 2).unwrap();
            rt.create_endpoint(c, 3).unwrap();
            let _ch = rt.connect(a, b, ChannelKind::Packet).unwrap();
            assert_eq!(rt.connect(c, b, ChannelKind::Packet).unwrap_err(), Status::Busy);
        }
    }

    #[test]
    fn async_send_completes_immediately_when_room() {
        for rt in both() {
            let dst = EndpointId::new(0, 1, 7);
            let ep = rt.create_endpoint(dst, 1).unwrap();
            let h = rt.msg_send_i(0, dst, b"async", 0).unwrap();
            assert!(rt.test(h));
            assert_eq!(rt.wait_send(h, 0, dst, b"async", 0, 1_000_000), Status::Success);
            let mut buf = [0u8; 8];
            assert_eq!(rt.msg_recv(ep, &mut buf).unwrap(), 5);
            assert_eq!(rt.requests_in_use(), 0);
        }
    }

    #[test]
    fn async_recv_waits_for_message() {
        for rt in both() {
            let dst = EndpointId::new(0, 1, 8);
            let ep = rt.create_endpoint(dst, 1).unwrap();
            let h = rt.msg_recv_i(ep).unwrap();
            let mut buf = [0u8; 8];
            // Nothing yet: times out.
            assert_eq!(rt.wait_recv(h, &mut buf, 0).unwrap_err(), Status::Timeout);
            rt.msg_send(0, dst, b"late", 0).unwrap();
            let n = rt.wait_recv(h, &mut buf, 1_000_000).unwrap();
            assert_eq!(&buf[..n], b"late");
            assert_eq!(rt.requests_in_use(), 0);
        }
    }

    #[test]
    fn cancel_only_receives() {
        for rt in both() {
            let dst = EndpointId::new(0, 1, 9);
            let ep = rt.create_endpoint(dst, 1).unwrap();
            let hr = rt.msg_recv_i(ep).unwrap();
            rt.cancel(hr).unwrap();
            // A fresh *send* request that is already complete can't cancel.
            let hs = rt.msg_send_i(0, dst, b"x", 0).unwrap();
            assert_eq!(rt.cancel(hs).unwrap_err(), Status::InvalidRequest);
            let _ = rt.wait_send(hs, 0, dst, b"x", 0, 0);
            let mut buf = [0u8; 4];
            let _ = rt.msg_recv(ep, &mut buf);
        }
    }

    #[test]
    fn fifo_order_is_preserved_per_sender() {
        for rt in both() {
            let dst = EndpointId::new(0, 1, 4);
            let ep = rt.create_endpoint(dst, 1).unwrap();
            for i in 0..10u8 {
                rt.msg_send(2, dst, &[i], 0).unwrap();
            }
            let mut buf = [0u8; 4];
            for i in 0..10u8 {
                let n = rt.msg_recv(ep, &mut buf).unwrap();
                assert_eq!((n, buf[0]), (1, i), "FIFO broken at {i}");
            }
        }
    }

    #[test]
    fn state_channel_delivers_freshest_value() {
        for rt in both() {
            let a = EndpointId::new(0, 1, 11);
            let b = EndpointId::new(0, 2, 11);
            rt.create_endpoint(a, 1).unwrap();
            rt.create_endpoint(b, 2).unwrap();
            let ch = rt.connect(a, b, ChannelKind::State).unwrap();
            rt.open_send(ch).unwrap();
            rt.open_recv(ch).unwrap();
            // Nothing published yet.
            assert_eq!(rt.state_recv(ch).unwrap_err(), Status::WouldBlock);
            // Writers never block; readers always see the newest value.
            rt.state_send(ch, 1).unwrap();
            rt.state_send(ch, 2).unwrap();
            rt.state_send(ch, 3).unwrap();
            assert_eq!(rt.state_recv(ch).unwrap(), 3);
            // Sampling again returns the same current value (state, not FIFO).
            assert_eq!(rt.state_recv(ch).unwrap(), 3);
        }
    }

    #[test]
    fn state_ops_rejected_on_fifo_channels() {
        for rt in both() {
            let a = EndpointId::new(0, 1, 12);
            let b = EndpointId::new(0, 2, 12);
            rt.create_endpoint(a, 1).unwrap();
            rt.create_endpoint(b, 2).unwrap();
            let ch = rt.connect(a, b, ChannelKind::Scalar).unwrap();
            rt.open_send(ch).unwrap();
            rt.open_recv(ch).unwrap();
            assert_eq!(rt.state_send(ch, 1).unwrap_err(), Status::InvalidChannel);
            assert_eq!(rt.sclr_send(ch, 1), Ok(()));
        }
    }

    #[test]
    fn batch_send_recv_roundtrip_both_backends() {
        for rt in both() {
            let dst = EndpointId::new(0, 1, 13);
            let ep = rt.create_endpoint(dst, 1).unwrap();
            let payloads: Vec<Vec<u8>> = (0u8..6).map(|i| vec![i; (i + 1) as usize]).collect();
            let refs: Vec<&[u8]> = payloads.iter().map(|p| p.as_slice()).collect();
            assert_eq!(rt.msg_send_batch(0, dst, &refs, 1), Ok(6));
            assert_eq!(rt.msg_available(ep).unwrap(), 6);
            let mut out = Vec::new();
            assert_eq!(rt.msg_recv_batch(ep, &mut out, 4), Ok(4));
            assert_eq!(rt.msg_recv_batch(ep, &mut out, 10), Ok(2));
            assert_eq!(out, payloads, "batch FIFO and payload integrity");
            assert_eq!(
                rt.msg_recv_batch(ep, &mut out, 1).unwrap_err(),
                Status::WouldBlock
            );
            assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers, "no leaked leases");
        }
    }

    #[test]
    fn batch_send_partial_on_full_queue_leaks_nothing() {
        for rt in both() {
            let dst = EndpointId::new(0, 1, 14);
            let ep = rt.create_endpoint(dst, 1).unwrap();
            // Fill one lane to capacity with a batch larger than the ring.
            let big: Vec<Vec<u8>> = (0..rt.cfg().nbb_capacity + 5).map(|_| vec![7u8; 4]).collect();
            let refs: Vec<&[u8]> = big.iter().map(|p| p.as_slice()).collect();
            let sent = rt.msg_send_batch(0, dst, &refs, 0).unwrap();
            assert!(sent >= rt.cfg().nbb_capacity.min(refs.len()) - 1 && sent <= refs.len());
            // Unsent messages must have returned their pool buffers.
            assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers - sent);
            let mut out = Vec::new();
            assert_eq!(rt.msg_recv_batch(ep, &mut out, usize::MAX).unwrap(), sent);
            assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers);
        }
    }

    #[test]
    fn batch_send_respects_message_limit_and_unknown_endpoint() {
        for rt in both() {
            assert_eq!(
                rt.msg_send_batch(0, EndpointId::new(9, 9, 9), &[b"x".as_slice()], 0)
                    .unwrap_err(),
                Status::InvalidEndpoint
            );
            let dst = EndpointId::new(0, 1, 15);
            rt.create_endpoint(dst, 1).unwrap();
            let big = vec![0u8; rt.cfg().buf_len + 1];
            assert_eq!(
                rt.msg_send_batch(0, dst, &[big.as_slice()], 0).unwrap_err(),
                Status::MessageLimit
            );
            assert_eq!(rt.msg_send_batch(0, dst, &[], 0), Ok(0));
        }
    }

    #[test]
    fn buffer_pool_exhaustion_reports_memlimit() {
        let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
            backend: BackendKind::LockFree,
            pool_buffers: 2,
            nbb_capacity: 8,
            ..Default::default()
        });
        let dst = EndpointId::new(0, 1, 1);
        rt.create_endpoint(dst, 1).unwrap();
        rt.msg_send(0, dst, b"a", 0).unwrap();
        rt.msg_send(0, dst, b"b", 0).unwrap();
        assert_eq!(rt.msg_send(0, dst, b"c", 0).unwrap_err(), Status::MemLimit);
    }

    // -- dead-peer recovery ---------------------------------------------------

    fn packet_pair(
        rt: &McapiRuntime<RealWorld>,
        port: u16,
    ) -> (EndpointId, EndpointId, usize) {
        let a = EndpointId::new(0, 1, port);
        let b = EndpointId::new(0, 2, port);
        rt.create_endpoint(a, 1).unwrap();
        rt.create_endpoint(b, 2).unwrap();
        let ch = rt.connect(a, b, ChannelKind::Packet).unwrap();
        rt.open_send(ch).unwrap();
        rt.open_recv(ch).unwrap();
        (a, b, ch)
    }

    #[test]
    fn dead_receiver_fails_senders_immediately() {
        for rt in both() {
            let (_, _, ch) = packet_pair(&rt, 21);
            rt.pkt_send(ch, b"early").unwrap();
            assert!(rt.node_alive(2));
            rt.declare_node_dead(2);
            assert!(!rt.node_alive(2));
            assert_eq!(rt.pkt_send(ch, b"late").unwrap_err(), Status::EndpointDead);
            assert!(rt.poisons_observed() > 0);
        }
    }

    #[test]
    fn dead_producer_drains_committed_then_poisons() {
        for rt in both() {
            let (a, b, ch) = packet_pair(&rt, 22);
            rt.pkt_send(ch, b"one").unwrap();
            rt.pkt_send(ch, b"two").unwrap();
            rt.declare_node_dead(1);
            // Every committed packet drains before the poison surfaces.
            let mut buf = [0u8; 16];
            let n = rt.pkt_recv(ch, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"one");
            let n = rt.pkt_recv(ch, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"two");
            assert_eq!(rt.pkt_recv(ch, &mut buf).unwrap_err(), Status::EndpointDead);
            // The declared node is fenced: its sends fail fast even on a
            // fresh channel until it rejoins (zombie isolation).
            rt.rejoin(1).unwrap();
            // Rejoin + teardown + reconnect resets the poison.
            rt.close(ch).unwrap();
            let ch2 = rt.connect(a, b, ChannelKind::Packet).unwrap();
            rt.open_send(ch2).unwrap();
            rt.open_recv(ch2).unwrap();
            rt.pkt_send(ch2, b"fresh").unwrap();
            let n = rt.pkt_recv(ch2, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"fresh");
        }
    }

    #[test]
    fn msg_send_to_dead_node_fails_but_committed_messages_drain() {
        for rt in both() {
            let dst = EndpointId::new(0, 3, 23);
            let ep = rt.create_endpoint(dst, 3).unwrap();
            rt.msg_send(0, dst, b"ok", 0).unwrap();
            rt.declare_node_dead(3);
            assert_eq!(rt.msg_send(0, dst, b"no", 0).unwrap_err(), Status::EndpointDead);
            // The committed message is still drainable by a scavenger and
            // returns its pool lease.
            let mut buf = [0u8; 8];
            assert_eq!(rt.msg_recv(ep, &mut buf).unwrap(), 2);
            assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers);
        }
    }

    #[test]
    fn declare_node_dead_is_idempotent_per_epoch() {
        let rt = rt(BackendKind::LockFree);
        let (_, _, _ch) = packet_pair(&rt, 24);
        let (poisoned, _) = rt.declare_node_dead(1);
        assert_eq!(poisoned, 1);
        assert_eq!(rt.declare_node_dead(1), (0, 0), "second declaration is a no-op");
        assert_eq!(rt.liveness_epoch(1), 1);
        // Out-of-range nodes are reported dead and declaring them is a no-op.
        assert!(!rt.node_alive(usize::MAX));
        assert_eq!(rt.declare_node_dead(usize::MAX), (0, 0));
    }

    #[test]
    fn chan_recv_wait_message_timeout_and_poison() {
        for rt in both() {
            let (_, _, ch) = packet_pair(&rt, 25);
            rt.pkt_send(ch, b"ready").unwrap();
            let mut buf = [0u8; 16];
            let n = rt.chan_recv_wait(ch, &mut buf, 1_000_000).unwrap();
            assert_eq!(&buf[..n], b"ready");
            // Empty channel: the wait expires.
            assert_eq!(
                rt.chan_recv_wait(ch, &mut buf, 200_000).unwrap_err(),
                Status::Timeout
            );
            assert!(rt.timeouts_observed() > 0);
            // Producer death unblocks the receiver with the poison status.
            rt.declare_node_dead(1);
            assert_eq!(
                rt.chan_recv_wait(ch, &mut buf, 10_000_000).unwrap_err(),
                Status::EndpointDead
            );
        }
    }

    #[test]
    fn parked_receiver_wakes_on_send() {
        let rt = rt(BackendKind::LockFree);
        let (_, _, ch) = packet_pair(&rt, 26);
        let sender = {
            let rt = rt.clone();
            std::thread::spawn(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                rt.pkt_send(ch, b"wake").unwrap();
            })
        };
        let mut buf = [0u8; 16];
        let n = rt.chan_recv_wait(ch, &mut buf, 2_000_000_000).unwrap();
        assert_eq!(&buf[..n], b"wake");
        sender.join().unwrap();
    }

    #[test]
    fn wait_any_readiness_timeout_and_fault_completion() {
        for rt in both() {
            let (_, _, ch) = packet_pair(&rt, 27);
            let h_pkt = rt.pkt_recv_i(ch).unwrap();
            let dst = EndpointId::new(0, 2, 28);
            let ep = rt.create_endpoint(dst, 2).unwrap();
            let h_msg = rt.msg_recv_i(ep).unwrap();
            // Nothing ready: the wait times out, requests stay pending.
            assert_eq!(rt.wait_any(&[h_pkt, h_msg], 0).unwrap_err(), Status::Timeout);
            assert_eq!(rt.requests_in_use(), 2);
            // A message readies the second handle.
            rt.msg_send(0, dst, b"m", 0).unwrap();
            assert_eq!(rt.wait_any(&[h_pkt, h_msg], 1_000_000), Ok((1, Status::Success)));
            let mut buf = [0u8; 8];
            rt.msg_recv(ep, &mut buf).unwrap();
            // Producer death completes the packet handle via the fault path.
            rt.declare_node_dead(1);
            assert_eq!(
                rt.wait_any(&[h_pkt], 1_000_000),
                Ok((0, Status::EndpointDead))
            );
            assert_eq!(rt.requests_in_use(), 0);
        }
    }

    // -- MPMC consumer groups -------------------------------------------------

    #[test]
    fn attach_consumer_rejects_locked_backend_and_bad_args() {
        let locked = rt(BackendKind::Locked);
        let dst = EndpointId::new(0, 1, 30);
        let ep = locked.create_endpoint(dst, 1).unwrap();
        assert_eq!(
            locked.endpoint_attach_consumer(ep, 1).unwrap_err(),
            Status::InvalidRequest
        );
        let free = rt(BackendKind::LockFree);
        assert_eq!(
            free.endpoint_attach_consumer(0, 1).unwrap_err(),
            Status::InvalidEndpoint,
            "attach to a never-created endpoint"
        );
        let ep = free.create_endpoint(dst, 1).unwrap();
        assert_eq!(
            free.endpoint_attach_consumer(ep, free.cfg().max_nodes).unwrap_err(),
            Status::InvalidEndpoint,
            "consumer node out of range"
        );
        assert_eq!(free.endpoint_attach_consumer(ep, 1), Ok(1));
        assert_eq!(free.endpoint_attach_consumer(ep, 2), Ok(2));
    }

    #[test]
    fn attach_migrates_pending_messages_and_blocks_delete() {
        let rt = rt(BackendKind::LockFree);
        let dst = EndpointId::new(0, 2, 31);
        let ep = rt.create_endpoint(dst, 2).unwrap();
        // Committed before any attach: lands in the single-consumer queue.
        rt.msg_send(1, dst, b"early-1", 0).unwrap();
        rt.msg_send(1, dst, b"early-2", 0).unwrap();
        rt.endpoint_attach_consumer(ep, 2).unwrap();
        assert_eq!(rt.msg_available(ep).unwrap(), 2, "migrated, not stranded");
        rt.msg_send(1, dst, b"late", 0).unwrap();
        let mut got = Vec::new();
        let mut buf = [0u8; 16];
        for _ in 0..3 {
            let n = rt.msg_recv(ep, &mut buf).unwrap();
            got.push(buf[..n].to_vec());
        }
        got.sort();
        assert_eq!(got, vec![b"early-1".to_vec(), b"early-2".to_vec(), b"late".to_vec()]);
        assert_eq!(rt.msg_recv(ep, &mut buf).unwrap_err(), Status::WouldBlock);
        assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers, "no leaked leases");
        // An endpoint running a group cannot be deleted (slot reuse
        // would leak the group's routing onto the next endpoint).
        assert_eq!(rt.delete_endpoint(ep).unwrap_err(), Status::Busy);
    }

    #[test]
    fn mpmc_endpoint_serves_concurrent_consumer_threads() {
        // The single-consumer debug guard rejects a second popping
        // thread on a plain lock-free endpoint; with an attached
        // consumer group, N sender threads and M receiver threads all
        // proceed, and every message is delivered exactly once.
        const SENDERS: usize = 2;
        const RECEIVERS: usize = 2;
        const PER: u64 = 400;
        let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
            backend: BackendKind::LockFree,
            ..Default::default()
        });
        let dst = EndpointId::new(0, 2, 32);
        let ep = rt.create_endpoint(dst, 2).unwrap();
        rt.endpoint_attach_consumer(ep, 2).unwrap();
        let total = (SENDERS as u64) * PER;
        let taken = Arc::new(AtomicU64::new(0));
        let got = Arc::new(std::sync::Mutex::new(Vec::new()));
        let mut handles = Vec::new();
        for s in 0..SENDERS {
            let rt = rt.clone();
            handles.push(std::thread::spawn(move || {
                for j in 0..PER {
                    let v = (s as u64) * PER + j;
                    loop {
                        match rt.msg_send(s + 3, dst, &v.to_le_bytes(), 0) {
                            Ok(()) => break,
                            Err(e) => {
                                assert!(
                                    e.is_would_block() || e == Status::MemLimit,
                                    "{e:?}"
                                );
                                std::thread::yield_now();
                            }
                        }
                    }
                }
            }));
        }
        for r in 0..RECEIVERS {
            let rt = rt.clone();
            let taken = taken.clone();
            let got = got.clone();
            handles.push(std::thread::spawn(move || {
                // Each receiver thread attaches under its own node id.
                rt.endpoint_attach_consumer(ep, 4 + r).unwrap();
                let mut buf = [0u8; 16];
                let mut mine = Vec::new();
                while taken.load(Ordering::Relaxed) < total {
                    match rt.msg_recv(ep, &mut buf) {
                        Ok(n) => {
                            assert_eq!(n, 8);
                            mine.push(u64::from_le_bytes(buf[..8].try_into().unwrap()));
                            taken.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(s) => {
                            assert!(s.is_would_block(), "{s:?}");
                            std::thread::yield_now();
                        }
                    }
                }
                got.lock().unwrap().extend(mine);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let mut all = got.lock().unwrap().clone();
        all.sort_unstable();
        let expect: Vec<u64> = (0..total).collect();
        assert_eq!(all, expect, "lost or duplicated messages");
        assert_eq!(rt.buffers_available(), rt.cfg().pool_buffers, "no leaked leases");
    }

    // -- automatic liveness ---------------------------------------------------

    #[test]
    fn fenced_zombie_send_rejected_until_rejoin() {
        for rt in both() {
            let (a, b, ch) = packet_pair(&rt, 41);
            rt.pkt_send(ch, b"pre").unwrap();
            // Node 1 is declared dead while its thread is still running:
            // a fenced zombie.
            rt.declare_node_dead(1);
            assert_eq!(rt.pkt_send(ch, b"zombie").unwrap_err(), Status::NodeFenced);
            assert_eq!(rt.msg_send(1, b, b"zombie", 0).unwrap_err(), Status::NodeFenced);
            assert!(rt.fence_rejects_observed() >= 2);
            // The committed payload still drains on the live side.
            let mut buf = [0u8; 8];
            let n = rt.pkt_recv(ch, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"pre");
            // Rejoin (fresh epoch) + reconnect restores service.
            rt.rejoin(1).unwrap();
            assert!(rt.node_alive(1));
            rt.close(ch).unwrap();
            let ch2 = rt.connect(a, b, ChannelKind::Packet).unwrap();
            rt.open_send(ch2).unwrap();
            rt.open_recv(ch2).unwrap();
            rt.pkt_send(ch2, b"back").unwrap();
            let n = rt.pkt_recv(ch2, &mut buf).unwrap();
            assert_eq!(&buf[..n], b"back");
            // Rejoin is idempotent and rejects out-of-range nodes.
            rt.rejoin(1).unwrap();
            assert_eq!(rt.liveness_epoch(1), 2);
            assert_eq!(rt.rejoin(usize::MAX).unwrap_err(), Status::InvalidEndpoint);
        }
    }

    #[test]
    fn watchdog_confirms_silent_node_and_spares_active_peer() {
        let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
            backend: BackendKind::LockFree,
            liveness: liveness::LivenessCfg { deadline_ns: 1_000_000, confirm_scans: 2 },
            ..Default::default()
        });
        let dst = EndpointId::new(0, 2, 42);
        let ep = rt.create_endpoint(dst, 2).unwrap();
        rt.msg_send(1, dst, b"x", 0).unwrap();
        let mut buf = [0u8; 8];
        rt.msg_recv(ep, &mut buf).unwrap(); // node 2 beats once, then goes silent
        assert!(rt.heartbeat_peek(2) > 0);
        let mut wd = rt.new_watchdog();
        assert!(rt.watchdog_scan_once(&mut wd).is_quiet(), "baseline scan");
        std::thread::sleep(std::time::Duration::from_millis(2));
        rt.msg_send(1, dst, b"y", 0).unwrap(); // node 1 keeps beating
        let r1 = rt.watchdog_scan_once(&mut wd);
        assert_eq!(r1.suspects, vec![2]);
        assert!(r1.confirmed.is_empty(), "hysteresis: one scan never kills");
        std::thread::sleep(std::time::Duration::from_millis(2));
        rt.msg_send(1, dst, b"z", 0).unwrap();
        let r2 = rt.watchdog_scan_once(&mut wd);
        assert_eq!(r2.confirmed, vec![2], "second over-deadline scan confirms");
        assert!(!rt.node_alive(2), "confirm ran declare_node_dead automatically");
        assert!(rt.node_alive(1), "the beating peer is never declared");
        assert!(rt.confirms_observed() == 1 && rt.suspects_observed() >= 2);
        // The dead destination now poisons senders.
        assert_eq!(rt.msg_send(1, dst, b"w", 0).unwrap_err(), Status::EndpointDead);
    }

    #[test]
    fn watchdog_never_confirms_a_parked_receiver() {
        let rt = McapiRuntime::<RealWorld>::new(RuntimeCfg {
            backend: BackendKind::LockFree,
            liveness: liveness::LivenessCfg { deadline_ns: 25_000_000, confirm_scans: 2 },
            ..Default::default()
        });
        let (_, _, ch) = packet_pair(&rt, 43);
        let receiver = {
            let rt = rt.clone();
            std::thread::spawn(move || {
                let mut buf = [0u8; 16];
                rt.chan_recv_wait(ch, &mut buf, 2_000_000_000).map(|n| buf[..n].to_vec())
            })
        };
        let mut wd = rt.new_watchdog();
        for _ in 0..20 {
            rt.watchdog_scan_once(&mut wd);
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        assert_eq!(rt.confirms_observed(), 0, "idle-but-parked waiter declared dead");
        assert!(rt.node_alive(2));
        rt.pkt_send(ch, b"done").unwrap();
        assert_eq!(receiver.join().unwrap().unwrap(), b"done".to_vec());
    }

    #[test]
    fn deadline_senders_surface_timeout_and_complete_with_data() {
        let rt = rt(BackendKind::LockFree);
        let dst = EndpointId::new(0, 2, 44);
        let ep = rt.create_endpoint(dst, 2).unwrap();
        let mut buf = [0u8; 8];
        // Empty endpoint: the receive deadline expires with Timeout.
        let deadline = RealWorld::now_ns() + 3_000_000;
        assert_eq!(rt.msg_recv_deadline(ep, &mut buf, deadline).unwrap_err(), Status::Timeout);
        assert!(RealWorld::now_ns() >= deadline, "returned before the deadline");
        assert!(rt.timeouts_observed() > 0);
        // With data both deadline variants complete well inside budget.
        let deadline = RealWorld::now_ns() + 500_000_000;
        rt.msg_send_deadline(1, dst, b"hi", 0, deadline).unwrap();
        assert_eq!(rt.msg_recv_deadline(ep, &mut buf, deadline).unwrap(), 2);
        assert_eq!(&buf[..2], b"hi");
        // Non-retryable verdicts pass straight through the slicing.
        rt.declare_node_dead(2);
        assert_eq!(
            rt.msg_send_deadline(1, dst, b"x", 0, RealWorld::now_ns() + 500_000_000)
                .unwrap_err(),
            Status::EndpointDead
        );
        assert_eq!(
            rt.msg_recv_deadline(usize::MAX, &mut buf, deadline).unwrap_err(),
            Status::InvalidEndpoint
        );
    }
}
