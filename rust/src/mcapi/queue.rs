//! Endpoint receive queues: the lock-based and lock-free implementations.
//!
//! The queue entry carries a buffer lease plus metadata (a small POD, like
//! the paper's queue entries binding reusable message buffers). Entries
//! move through the Figure 4 FSM in the lock-free backend; the locked
//! backend is the reference design — a plain deque guarded by the global
//! reader/writer lock (acquired by the *runtime*, not here).

use std::cell::UnsafeCell;
use std::collections::VecDeque;

use crate::lockfree::bitset::BitSet;
use crate::lockfree::lanes::{ShardRecvError, ShardSendError, ShardedRing};
use crate::lockfree::mem::World;
use crate::lockfree::nbb::{BatchStatus, InsertStatus, Nbb, ReadStatus};
use crate::mcapi::types::{Status, PRIORITIES};
use crate::obs;
use crate::obs::EventKind;

/// Queue-entry FSM states (Figure 4).
pub mod entry_state {
    /// No buffer associated.
    pub const FREE: u32 = 0;
    /// Entry claimed, buffer not yet linked.
    pub const RESERVED: u32 = 1;
    /// Buffer linked and filled.
    pub const ALLOCATED: u32 = 2;
    /// At the head, being read by the receiver.
    pub const RECEIVED: u32 = 3;
}

/// One queued message/packet: lease metadata (POD; fits an NBB slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Entry {
    /// Buffer index in the shared partition.
    pub buf_index: u32,
    /// Payload length in bytes.
    pub len: u32,
    /// Sender's dense node slot (producer lane).
    pub from_node: u32,
    /// Priority lane it was sent on.
    pub priority: u8,
    /// Scalar payload when the entry carries a scalar (no buffer lease).
    pub scalar: u64,
}

impl Entry {
    /// Entry carrying a pooled buffer.
    pub fn buffered(buf_index: u32, len: u32, from_node: u32, priority: u8) -> Self {
        Entry { buf_index, len, from_node, priority, scalar: 0 }
    }

    /// Entry carrying an inline 64-bit scalar.
    pub fn scalar(value: u64, from_node: u32) -> Self {
        Self::scalar_w(value, from_node, 8)
    }

    /// Entry carrying an inline scalar of `width` bytes (1/2/4/8 — the
    /// MCAPI scalar sizes). The width travels in `len` so the receive
    /// side can reject width mismatches (`Status::ScalarSizeMismatch`).
    pub fn scalar_w(value: u64, from_node: u32, width: u32) -> Self {
        Entry { buf_index: u32::MAX, len: width, from_node, priority: 0, scalar: value }
    }

    /// True when this entry owns a pooled buffer.
    pub fn has_buffer(&self) -> bool {
        self.buf_index != u32::MAX
    }

    /// Encode into the fixed wire layout an MPMC ring slot carries
    /// (see [`ENTRY_WIRE_LEN`]).
    pub fn encode(&self) -> [u8; ENTRY_WIRE_LEN] {
        let mut b = [0u8; ENTRY_WIRE_LEN];
        b[0..4].copy_from_slice(&self.buf_index.to_le_bytes());
        b[4..8].copy_from_slice(&self.len.to_le_bytes());
        b[8..12].copy_from_slice(&self.from_node.to_le_bytes());
        b[12] = self.priority;
        b[16..24].copy_from_slice(&self.scalar.to_le_bytes());
        b
    }

    /// Decode the wire layout back into an [`Entry`]. `None` on a
    /// short slice (never happens for slots sized [`ENTRY_WIRE_LEN`]).
    pub fn decode(b: &[u8]) -> Option<Self> {
        if b.len() < ENTRY_WIRE_LEN {
            return None;
        }
        Some(Entry {
            buf_index: u32::from_le_bytes(b[0..4].try_into().ok()?),
            len: u32::from_le_bytes(b[4..8].try_into().ok()?),
            from_node: u32::from_le_bytes(b[8..12].try_into().ok()?),
            priority: b[12],
            scalar: u64::from_le_bytes(b[16..24].try_into().ok()?),
        })
    }
}

/// Bytes of the [`Entry`] wire layout carried in an MPMC ring slot:
/// `buf_index` LE at 0, `len` LE at 4, `from_node` LE at 8, `priority`
/// at 12 (13..16 reserved), `scalar` LE at 16.
pub const ENTRY_WIRE_LEN: usize = 24;

// ---------------------------------------------------------------------------
// Lock-based reference queue.
// ---------------------------------------------------------------------------

/// Priority deques guarded externally by the runtime's global RwLock —
/// mirrors the reference implementation where the shared-memory database
/// is one lock domain. The `UnsafeCell` is sound because every access goes
/// through the runtime while it holds the global lock (asserted in debug
/// builds via the lock's own state).
pub struct LockedQueue {
    lanes: UnsafeCell<[VecDeque<Entry>; PRIORITIES]>,
    capacity: usize,
}

unsafe impl Send for LockedQueue {}
unsafe impl Sync for LockedQueue {}

impl LockedQueue {
    /// Queue with `capacity` entries per priority lane.
    pub fn new(capacity: usize) -> Self {
        LockedQueue { lanes: UnsafeCell::new(Default::default()), capacity }
    }

    /// Push under the global write lock.
    ///
    /// # Safety
    /// Caller must hold the runtime's global write lock.
    pub unsafe fn push(&self, e: Entry) -> Result<(), Status> {
        let lanes = &mut *self.lanes.get();
        let lane = &mut lanes[e.priority as usize % PRIORITIES];
        if lane.len() >= self.capacity {
            return Err(Status::WouldBlock);
        }
        lane.push_back(e);
        Ok(())
    }

    /// Pop the highest-priority entry under the global write lock.
    ///
    /// # Safety
    /// Caller must hold the runtime's global write lock.
    pub unsafe fn pop(&self) -> Option<Entry> {
        let lanes = &mut *self.lanes.get();
        lanes.iter_mut().find_map(|l| l.pop_front())
    }

    /// Entry count under the global (at least read) lock.
    ///
    /// # Safety
    /// Caller must hold the runtime's global lock.
    pub unsafe fn len(&self) -> usize {
        (*self.lanes.get()).iter().map(|l| l.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// Lock-free queue: composed NBB lanes.
// ---------------------------------------------------------------------------

/// Lock-free receive queue: one SPSC NBB per (priority, producer-node)
/// lane, drained priority-major with a rotating fairness cursor — the
/// NBB composition Kim et al. describe for fan-in patterns.
///
/// # Occupancy bitmap
///
/// The textbook composition scans every `PRIORITIES × producers` lane on
/// every pop, touching each lane's `update` counter — O(lanes) cross-core
/// loads even when the endpoint is idle. Instead, a lock-free occupancy
/// bitmap (one [`BitSet`] word per priority) tracks which lanes *may*
/// hold entries: producers set their lane bit after a successful insert,
/// the consumer clears a bit when it observes the lane empty. A poll of
/// an idle endpoint is then one relaxed word-load per priority — O(1) in
/// the producer count — and a busy poll scans only flagged lanes.
///
/// Lost-wakeup freedom: the producer *completes* the insert (release
/// store) before setting the bit; the consumer re-checks the lane
/// *after* clearing its bit and re-sets the bit if the re-check finds
/// anything. Whichever order the clear and the insert land in, either
/// the consumer's re-check sees the entry or the producer's subsequent
/// `set` re-flags the lane. A bit may be *spuriously* set (lane already
/// drained) — that costs one extra lane probe, never a lost entry.
///
/// # Single-consumer contract
///
/// Flag-board mode is **single-consumer**: the rotation cursor, the
/// word-snapshot scratch and the clear-then-recheck protocol all assume
/// exactly one popping thread (per-endpoint receives are single-consumer
/// by the MCAPI spec; MPMC endpoint profiles need the `Locked` backend
/// or one queue per consumer). Debug/sim builds record the owning
/// consumer thread on the first `pop` and reject any other popping
/// thread with a panic instead of racing silently; release builds trust
/// the contract and pay nothing.
pub struct LockFreeQueue<W: World> {
    /// `lanes[priority][producer]`.
    lanes: Vec<Vec<Nbb<Entry, W>>>,
    /// `occupancy[priority]`, one bit per producer lane.
    occupancy: Vec<BitSet<W>>,
    producers: usize,
    /// Receiver-private rotation cursor (single-consumer by MCAPI spec).
    cursor: UnsafeCell<usize>,
    /// Receiver-private word-snapshot scratch (avoids per-pop allocation
    /// when `producers > 64`).
    scratch: UnsafeCell<Vec<u64>>,
    /// Owning consumer's thread token, claimed on first pop (0 = none).
    /// Debug/sim guard for the single-consumer contract (see type docs);
    /// a plain host atomic so simulated worlds never price it.
    #[cfg(debug_assertions)]
    consumer: std::sync::atomic::AtomicU64,
    /// Observability endpoint id ([`obs::CH_NONE`] when unmounted) plus
    /// push/pop sequence counters for trace events. All host atomics —
    /// never priced, touched only when tracing is enabled (except the
    /// one-time id store at runtime construction).
    trace_id: std::sync::atomic::AtomicU32,
    trace_push_seq: std::sync::atomic::AtomicU64,
    trace_pop_seq: std::sync::atomic::AtomicU64,
}

/// Small monotone per-thread token for the single-consumer debug guard.
#[cfg(debug_assertions)]
fn consumer_token() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static TOKEN: u64 = NEXT.fetch_add(1, Ordering::Relaxed);
    }
    TOKEN.with(|t| *t)
}

unsafe impl<W: World> Send for LockFreeQueue<W> {}
unsafe impl<W: World> Sync for LockFreeQueue<W> {}

impl<W: World> LockFreeQueue<W> {
    /// Queue with `producers` lanes per priority, each of `capacity`.
    pub fn new(producers: usize, capacity: usize) -> Self {
        LockFreeQueue {
            lanes: (0..PRIORITIES)
                .map(|_| (0..producers).map(|_| Nbb::new(capacity)).collect())
                .collect(),
            occupancy: (0..PRIORITIES).map(|_| BitSet::new(producers)).collect(),
            producers,
            cursor: UnsafeCell::new(0),
            scratch: UnsafeCell::new(vec![0u64; (producers + 63) / 64]),
            #[cfg(debug_assertions)]
            consumer: std::sync::atomic::AtomicU64::new(0),
            trace_id: std::sync::atomic::AtomicU32::new(obs::CH_NONE),
            trace_push_seq: std::sync::atomic::AtomicU64::new(0),
            trace_pop_seq: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Tag this queue with its endpoint slot for trace events (the
    /// runtime calls it once at construction; the emitted channel id is
    /// `obs::CH_ENDPOINT_BIT | ep`).
    pub fn set_trace_id(&self, ep: u32) {
        use std::sync::atomic::Ordering;
        self.trace_id.store(obs::CH_ENDPOINT_BIT | ep, Ordering::Relaxed);
    }

    /// Trace-event channel id carried by this queue's events.
    fn trace_ch(&self) -> u32 {
        use std::sync::atomic::Ordering;
        self.trace_id.load(Ordering::Relaxed)
    }

    /// Emit `n` QueuePop trace events (single consumer, so the plain
    /// fetch_add sequence matches delivery order).
    fn note_pops(&self, prio: usize, n: u64) {
        if obs::tracing() {
            use std::sync::atomic::Ordering;
            let seq = self.trace_pop_seq.fetch_add(n, Ordering::Relaxed);
            for i in 0..n {
                obs::emit::<W>(EventKind::QueuePop, self.trace_ch(), seq + i, prio as u32);
            }
            obs::add(obs::ctr::QUEUE_POP, n);
        }
    }

    /// Debug/sim enforcement of the single-consumer contract: the first
    /// popping thread claims the queue; any other popping thread panics.
    #[cfg(debug_assertions)]
    fn assert_single_consumer(&self) {
        use std::sync::atomic::Ordering;
        let token = consumer_token();
        if let Err(owner) =
            self.consumer.compare_exchange(0, token, Ordering::Relaxed, Ordering::Relaxed)
        {
            assert_eq!(
                owner, token,
                "LockFreeQueue flag-board mode is single-consumer: pop from a second \
                 thread (token {token}, owner {owner}); attach a ConsumerGroup with \
                 `endpoint_attach_consumer` for multi-consumer (MPMC) endpoints"
            );
        }
    }

    #[cfg(not(debug_assertions))]
    #[inline]
    fn assert_single_consumer(&self) {}

    /// Producer-side insert (wait-free except the bounded ring).
    pub fn push(&self, e: Entry) -> Result<(), (Status, Entry)> {
        let prio = e.priority as usize % PRIORITIES;
        let lane = e.from_node as usize % self.producers;
        match self.lanes[prio][lane].insert(e) {
            Ok(()) => {
                // Flag AFTER the insert's release store (see type docs).
                self.occupancy[prio].set(lane);
                if obs::tracing() {
                    use std::sync::atomic::Ordering;
                    let seq = self.trace_push_seq.fetch_add(1, Ordering::Relaxed);
                    obs::emit::<W>(EventKind::QueuePush, self.trace_ch(), seq, prio as u32);
                    obs::bump(obs::ctr::QUEUE_PUSH);
                }
                Ok(())
            }
            Err((s, e)) => {
                let status = match s {
                    InsertStatus::Full => Status::WouldBlock,
                    InsertStatus::FullButConsumerReading => Status::WouldBlockPeerActive,
                };
                Err((status, e))
            }
        }
    }

    /// Producer-side batched insert: all entries must target the same
    /// (priority, producer) lane — one batch NBB insert plus at most one
    /// occupancy RMW. Enqueued entries are drained from the front of
    /// `entries`; returns how many went in (`Err` with the Table 1
    /// distinction when none did).
    pub fn push_batch(&self, entries: &mut Vec<Entry>) -> Result<usize, Status> {
        let Some(first) = entries.first() else {
            return Ok(0);
        };
        let prio = first.priority as usize % PRIORITIES;
        let lane = first.from_node as usize % self.producers;
        debug_assert!(
            entries.iter().all(|e| {
                e.priority as usize % PRIORITIES == prio
                    && e.from_node as usize % self.producers == lane
            }),
            "push_batch entries must share one (priority, producer) lane"
        );
        match self.lanes[prio][lane].insert_batch(entries) {
            Ok(n) => {
                self.occupancy[prio].set(lane);
                if obs::tracing() {
                    use std::sync::atomic::Ordering;
                    let seq = self.trace_push_seq.fetch_add(n as u64, Ordering::Relaxed);
                    for i in 0..n as u64 {
                        obs::emit::<W>(EventKind::QueuePush, self.trace_ch(), seq + i, prio as u32);
                    }
                    obs::add(obs::ctr::QUEUE_PUSH, n as u64);
                }
                Ok(n)
            }
            Err(BatchStatus::WouldBlock) => Err(Status::WouldBlock),
            Err(BatchStatus::PeerActive) => Err(Status::WouldBlockPeerActive),
        }
    }

    /// Consumer-side pop: priorities high-to-low; within a priority,
    /// snapshot the occupancy words (one relaxed load each) and probe
    /// only flagged lanes, rotating for fairness. Single consumer only.
    pub fn pop(&self) -> Result<Entry, Status> {
        self.assert_single_consumer();
        let cursor = unsafe { &mut *self.cursor.get() };
        let scratch = unsafe { &mut *self.scratch.get() };
        let mut saw_peer_active = false;
        for (prio, occ) in self.occupancy.iter().enumerate() {
            let mut any = 0u64;
            for wi in 0..occ.num_words() {
                scratch[wi] = occ.snapshot_word(wi);
                any |= scratch[wi];
            }
            if any == 0 {
                continue; // idle priority: cost was num_words loads, no lane probes
            }
            for i in 0..self.producers {
                let lane = (*cursor + i) % self.producers;
                if scratch[lane / 64] & (1u64 << (lane % 64)) == 0 {
                    continue;
                }
                match self.lanes[prio][lane].read() {
                    ReadStatus::Ok(e) => {
                        *cursor = (lane + 1) % self.producers;
                        self.note_pops(prio, 1);
                        return Ok(e);
                    }
                    ReadStatus::EmptyButProducerInserting => saw_peer_active = true,
                    ReadStatus::Empty => {
                        // Stale flag: clear it, then re-check the lane so a
                        // concurrent insert-then-set cannot be lost.
                        occ.free(lane);
                        match self.lanes[prio][lane].read() {
                            ReadStatus::Ok(e) => {
                                occ.set(lane); // conservatively re-flag (may hold more)
                                *cursor = (lane + 1) % self.producers;
                                self.note_pops(prio, 1);
                                return Ok(e);
                            }
                            ReadStatus::EmptyButProducerInserting => {
                                occ.set(lane);
                                saw_peer_active = true;
                            }
                            ReadStatus::Empty => {}
                        }
                    }
                }
            }
        }
        Err(if saw_peer_active {
            Status::WouldBlockPeerActive
        } else {
            Status::WouldBlock
        })
    }

    /// Consumer-side batched pop: drain up to `max` entries into `out`,
    /// priority-major with the same rotation/occupancy discipline as
    /// [`LockFreeQueue::pop`]. Returns how many were appended (`Err` with
    /// the would-block distinction when none were).
    pub fn pop_batch(&self, out: &mut Vec<Entry>, max: usize) -> Result<usize, Status> {
        if max == 0 {
            return Ok(0);
        }
        self.assert_single_consumer();
        let cursor = unsafe { &mut *self.cursor.get() };
        let scratch = unsafe { &mut *self.scratch.get() };
        let mut saw_peer_active = false;
        let mut total = 0usize;
        for (prio, occ) in self.occupancy.iter().enumerate() {
            let mut any = 0u64;
            for wi in 0..occ.num_words() {
                scratch[wi] = occ.snapshot_word(wi);
                any |= scratch[wi];
            }
            if any == 0 {
                continue;
            }
            // Fixed scan base: the cursor moves as lanes are drained, so
            // lane selection must not track it mid-pass.
            let start = *cursor;
            for i in 0..self.producers {
                if total >= max {
                    return Ok(total);
                }
                let lane = (start + i) % self.producers;
                if scratch[lane / 64] & (1u64 << (lane % 64)) == 0 {
                    continue;
                }
                match self.lanes[prio][lane].read_batch(out, max - total) {
                    Ok(n) => {
                        total += n;
                        *cursor = (lane + 1) % self.producers;
                        self.note_pops(prio, n as u64);
                    }
                    Err(BatchStatus::PeerActive) => saw_peer_active = true,
                    Err(BatchStatus::WouldBlock) => {
                        occ.free(lane);
                        match self.lanes[prio][lane].read_batch(out, max - total) {
                            Ok(n) => {
                                occ.set(lane);
                                total += n;
                                *cursor = (lane + 1) % self.producers;
                                self.note_pops(prio, n as u64);
                            }
                            Err(BatchStatus::PeerActive) => {
                                occ.set(lane);
                                saw_peer_active = true;
                            }
                            Err(BatchStatus::WouldBlock) => {}
                        }
                    }
                }
            }
            if total > 0 {
                // Do not spill into lower priorities past a non-empty
                // class: callers drain class-by-class, like `pop`.
                return Ok(total);
            }
        }
        // Only reachable with total == 0 (non-zero passes return above).
        if saw_peer_active {
            Err(Status::WouldBlockPeerActive)
        } else {
            Err(Status::WouldBlock)
        }
    }

    /// Total buffered entries (approximate).
    pub fn len(&self) -> usize {
        self.lanes.iter().flatten().map(|n| n.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// Consumer group: the MPMC multi-receiver endpoint profile.
// ---------------------------------------------------------------------------

thread_local! {
    /// The calling thread's consumer identity for group pops
    /// (`u32::MAX` = not attached), set by [`ConsumerGroup::attach`].
    /// A thread-local mirrors the per-thread consumer token above —
    /// MCAPI receive contexts are thread-affine in both worlds (sim
    /// tasks are threads).
    static GROUP_WHO: std::cell::Cell<u32> = const { std::cell::Cell::new(u32::MAX) };
}

/// Multi-consumer receive queue for one endpoint: M receivers attach
/// and pop concurrently, work-distribution style — each committed
/// entry is delivered to **exactly one** consumer, unordered across
/// consumers (each consumer sees its own claims in claim order).
///
/// Contention-adaptive backing: entries travel through a
/// [`ShardedRing`] — one SPSC lane per sender node (the cached-peer
/// NBB counter protocol), a home-lane assignment per attached member,
/// and lock-free batch work-stealing when a member's home lanes run
/// dry. In the steady state a member drains its home lanes with
/// **zero shared-counter RMWs** (sim-asserted); the shared steal
/// cursor is the only contended word and is touched only on the dry
/// path. The shared-CAS [`crate::lockfree::mpmc::MpmcRing`] remains as
/// the measured baseline (`mpmc_steal_vs_shared`).
///
/// The trade against the flag-board composition is unchanged from the
/// shared-ring generation: cross-producer priority precedence is
/// dropped (per-lane FIFO rules; the priority still travels in the
/// entry metadata) in exchange for multi-consumer pops.
///
/// Producer lanes and consumer identities (`who`) are **dense node
/// slots** on both sides, so [`ConsumerGroup::repair_dead`] can map a
/// dead node straight onto all four roles it can hold (producer, home
/// member, thief, stash owner — PR 3 recovery machinery).
pub struct ConsumerGroup<W: World> {
    ring: ShardedRing<W>,
    /// Consumers attached so far. Host atomic: the runtime's
    /// `group.active()` check on every send/recv must stay unpriced
    /// so the pinned SPSC sim gates remain byte-identical.
    attached: std::sync::atomic::AtomicU32,
}

impl<W: World> ConsumerGroup<W> {
    /// Group over `nodes` per-producer lanes of `cap` entry slots each
    /// (`nodes` is the dense node-slot space: every node can send on
    /// its own lane and attach as a member).
    pub fn new(nodes: usize, cap: usize) -> Self {
        ConsumerGroup {
            ring: ShardedRing::new(nodes.max(1), nodes.max(1), cap.max(2), ENTRY_WIRE_LEN),
            attached: std::sync::atomic::AtomicU32::new(0),
        }
    }

    /// Tag trace events with the owning endpoint slot (events carry
    /// `obs::CH_ENDPOINT_BIT | ep`, keeping them out of the
    /// channel-stage pairing like every other endpoint event).
    pub fn set_trace_id(&self, ep: u32) {
        self.ring.set_trace_id(obs::CH_ENDPOINT_BIT | ep);
    }

    /// Register the calling thread as a consumer with dense node slot
    /// `node`; returns the attached-consumer count. Sets the
    /// thread-local pop identity and deals the new member a fair share
    /// of home lanes (live rebalance).
    pub fn attach(&self, node: u32) -> u32 {
        GROUP_WHO.with(|w| w.set(node));
        let n = self.attached.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1;
        self.ring.attach_member(node);
        n
    }

    /// Re-deal home lanes across the currently attached members —
    /// called after a member is fenced/declared dead so its orphaned
    /// lanes get live homes (they remain stealable in the interim, so
    /// this is a latency fix, not a correctness one).
    pub fn rebalance(&self) {
        self.ring.rebalance();
    }

    /// Home member of producer lane `lane` (`None` = unassigned) —
    /// rebalance observability for tests and the trace CLI.
    pub fn home_of(&self, lane: usize) -> Option<u32> {
        self.ring.home_of(lane)
    }

    /// True once any consumer has attached — the runtime's routing
    /// switch (one relaxed host load, never priced).
    pub fn active(&self) -> bool {
        self.attached.load(std::sync::atomic::Ordering::Relaxed) != 0
    }

    /// Consumers attached so far.
    pub fn attached(&self) -> u32 {
        self.attached.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// The calling thread's attach identity (`None` if it never
    /// attached to any group).
    pub fn current_who() -> Option<u32> {
        GROUP_WHO.with(|w| {
            let v = w.get();
            (v != u32::MAX).then_some(v)
        })
    }

    /// Producer-side insert onto the sender's **own lane**
    /// (`e.from_node`) — the SPSC fast path: stores only, no claim
    /// CAS. Full lanes hand the entry back so the caller can abort
    /// its buffer lease.
    pub fn push(&self, e: Entry) -> Result<(), (Status, Entry)> {
        match self.ring.send(e.from_node, &e.encode()) {
            Ok(()) => Ok(()),
            Err(ShardSendError::Full | ShardSendError::FullButConsumerReading) => {
                Err((Status::WouldBlock, e))
            }
            // `from_node` outside the dense node-slot space: the entry
            // metadata is bogus (wire decode, harness bug) — reject it
            // rather than panic the runtime.
            Err(ShardSendError::BadLane) => Err((Status::InvalidEndpoint, e)),
        }
    }

    /// Producer-side batched insert: one enter/exit counter pair on
    /// the sender's lane amortized over the whole prefix
    /// ([`ShardedRing::send_batch`]). Enqueued entries drain from the
    /// front of `entries`; returns how many went in (`Err` only when
    /// none did).
    pub fn push_batch(&self, entries: &mut Vec<Entry>) -> Result<usize, Status> {
        let Some(first) = entries.first() else {
            return Ok(0);
        };
        let lane = first.from_node;
        let encoded: Vec<[u8; ENTRY_WIRE_LEN]> = entries.iter().map(Entry::encode).collect();
        let refs: Vec<&[u8]> = encoded.iter().map(|b| b.as_slice()).collect();
        match self.ring.send_batch(lane, &refs) {
            Ok(n) => {
                entries.drain(..n);
                Ok(n)
            }
            Err(ShardSendError::Full | ShardSendError::FullButConsumerReading) => {
                Err(Status::WouldBlock)
            }
            Err(ShardSendError::BadLane) => Err(Status::InvalidEndpoint),
        }
    }

    /// Consumer-side pop as member `who` (the runtime passes the
    /// thread's [`ConsumerGroup::current_who`], falling back to the
    /// endpoint owner): staged steals, then home lanes (zero shared
    /// RMWs), then a batch steal from the most backlogged lane.
    pub fn pop(&self, who: u32) -> Result<Entry, Status> {
        match self.ring.recv_as(who, |b| Entry::decode(b)) {
            Ok(Some(e)) => Ok(e),
            Ok(None) => unreachable!("group slots are always ENTRY_WIRE_LEN"),
            // Both flavours decay to WouldBlock here: the runtime's
            // bounded-backoff driver already retries PeerActive-class
            // statuses immediately.
            Err(ShardRecvError::Empty | ShardRecvError::PeerActive) => Err(Status::WouldBlock),
        }
    }

    /// Entries committed but not yet delivered (lanes + stashes;
    /// approximate, unpriced peeks, safe from watchdogs).
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// Repair every transient state dead node `node` left behind, in
    /// all four roles (producer, home member, thief, stash owner),
    /// then re-deal its orphaned home lanes across the surviving
    /// members. Committed-but-undelivered stolen entries are
    /// re-enqueued inside the ring onto the dead node's own
    /// (producer-less) lane — never onto a live producer's SPSC lane —
    /// and the dead member never delivered them, so exactly-once is
    /// preserved. Entries the dead lane could not absorb come back as
    /// overflow; the caller must release their buffers (re-pushing
    /// them would write a live producer's lane). Returns `(repairs,
    /// overflow entries)`.
    pub fn repair_dead(&self, node: u32) -> (usize, Vec<Entry>) {
        let mut overflow = Vec::new();
        let r = self.ring.repair_dead(node, |b| {
            if let Some(e) = Entry::decode(b) {
                overflow.push(e);
            }
        });
        self.ring.rebalance();
        let repairs =
            r.torn_inserts + r.torn_pops + r.cleared_claims + r.discarded_stages + r.requeued;
        (repairs, overflow)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    type LfQueue = LockFreeQueue<RealWorld>;

    #[test]
    fn entry_pod_size_is_cacheline_friendly() {
        assert!(std::mem::size_of::<Entry>() <= 24);
    }

    #[test]
    fn scalar_entries_have_no_buffer() {
        let e = Entry::scalar(42, 1);
        assert!(!e.has_buffer());
        assert!(Entry::buffered(0, 10, 1, 0).has_buffer());
    }

    #[test]
    fn locked_queue_priority_order() {
        let q = LockedQueue::new(8);
        unsafe {
            q.push(Entry::buffered(1, 1, 0, 2)).unwrap();
            q.push(Entry::buffered(2, 1, 0, 0)).unwrap();
            q.push(Entry::buffered(3, 1, 0, 1)).unwrap();
            assert_eq!(q.pop().unwrap().buf_index, 2); // prio 0 first
            assert_eq!(q.pop().unwrap().buf_index, 3);
            assert_eq!(q.pop().unwrap().buf_index, 1);
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn locked_queue_capacity_per_lane() {
        let q = LockedQueue::new(2);
        unsafe {
            q.push(Entry::buffered(0, 1, 0, 0)).unwrap();
            q.push(Entry::buffered(1, 1, 0, 0)).unwrap();
            assert_eq!(q.push(Entry::buffered(2, 1, 0, 0)), Err(Status::WouldBlock));
            // Other lanes unaffected.
            q.push(Entry::buffered(3, 1, 0, 1)).unwrap();
            assert_eq!(q.len(), 3);
        }
    }

    #[test]
    fn lockfree_fifo_per_producer() {
        let q = LfQueue::new(2, 8);
        q.push(Entry::buffered(10, 1, 0, 0)).unwrap();
        q.push(Entry::buffered(11, 1, 0, 0)).unwrap();
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert_eq!((a.buf_index, b.buf_index), (10, 11), "per-producer FIFO");
        assert_eq!(q.pop(), Err(Status::WouldBlock));
    }

    #[test]
    fn lockfree_priority_precedence() {
        let q = LfQueue::new(1, 8);
        q.push(Entry::buffered(1, 1, 0, 3)).unwrap();
        q.push(Entry::buffered(2, 1, 0, 0)).unwrap();
        assert_eq!(q.pop().unwrap().buf_index, 2);
        assert_eq!(q.pop().unwrap().buf_index, 1);
    }

    #[test]
    fn lockfree_fairness_rotates_producers() {
        let q = LfQueue::new(2, 8);
        for i in 0..4 {
            q.push(Entry::buffered(100 + i, 1, 0, 0)).unwrap();
            q.push(Entry::buffered(200 + i, 1, 1, 0)).unwrap();
        }
        let mut from0 = 0;
        let mut from1 = 0;
        for _ in 0..4 {
            let e = q.pop().unwrap();
            if e.buf_index >= 200 {
                from1 += 1;
            } else {
                from0 += 1;
            }
        }
        assert!(from0 >= 1 && from1 >= 1, "rotation starves a producer");
    }

    #[test]
    fn lockfree_full_lane_reports_wouldblock() {
        let q = LfQueue::new(1, 2);
        q.push(Entry::buffered(0, 1, 0, 0)).unwrap();
        q.push(Entry::buffered(1, 1, 0, 0)).unwrap();
        let (status, back) = q.push(Entry::buffered(2, 1, 0, 0)).unwrap_err();
        assert_eq!(status, Status::WouldBlock);
        assert_eq!(back.buf_index, 2);
    }

    #[test]
    fn occupancy_tracks_push_pop() {
        let q = LfQueue::new(2, 4);
        // Idle queue: no bits set anywhere.
        for p in 0..PRIORITIES {
            assert_eq!(q.occupancy[p].count(), 0);
        }
        q.push(Entry::buffered(1, 1, 0, 2)).unwrap();
        assert!(q.occupancy[2].is_set(0), "push must flag its lane");
        assert_eq!(q.pop().unwrap().buf_index, 1);
        // The entry came out; the flag may linger until the next empty
        // probe clears it.
        assert_eq!(q.pop(), Err(Status::WouldBlock));
        assert!(
            !q.occupancy[2].is_set(0),
            "empty probe must clear the stale flag"
        );
        // Cleared flag doesn't lose later entries.
        q.push(Entry::buffered(2, 1, 0, 2)).unwrap();
        assert_eq!(q.pop().unwrap().buf_index, 2);
    }

    #[test]
    fn batch_push_pop_roundtrip() {
        let q = LfQueue::new(2, 8);
        let mut entries: Vec<Entry> =
            (0..5).map(|i| Entry::buffered(i, 1, 1, 0)).collect();
        assert_eq!(q.push_batch(&mut entries), Ok(5));
        assert!(entries.is_empty());
        let mut out = Vec::new();
        assert_eq!(q.pop_batch(&mut out, 3), Ok(3));
        assert_eq!(q.pop_batch(&mut out, 8), Ok(2));
        let got: Vec<u32> = out.iter().map(|e| e.buf_index).collect();
        assert_eq!(got, vec![0, 1, 2, 3, 4], "batch FIFO per lane");
        assert_eq!(q.pop_batch(&mut out, 1), Err(Status::WouldBlock));
    }

    #[test]
    fn batch_pop_respects_priority_classes() {
        let q = LfQueue::new(1, 8);
        q.push(Entry::buffered(10, 1, 0, 1)).unwrap();
        q.push(Entry::buffered(20, 1, 0, 0)).unwrap();
        q.push(Entry::buffered(21, 1, 0, 0)).unwrap();
        let mut out = Vec::new();
        // One call drains only the highest non-empty class.
        assert_eq!(q.pop_batch(&mut out, 8), Ok(2));
        assert_eq!(out.iter().map(|e| e.buf_index).collect::<Vec<_>>(), vec![20, 21]);
        assert_eq!(q.pop_batch(&mut out, 8), Ok(1));
        assert_eq!(out.last().unwrap().buf_index, 10);
    }

    #[test]
    fn batch_push_overflow_hands_back_remainder() {
        let q = LfQueue::new(1, 2);
        let mut entries: Vec<Entry> =
            (0..4).map(|i| Entry::buffered(i, 1, 0, 0)).collect();
        assert_eq!(q.push_batch(&mut entries), Ok(2));
        assert_eq!(entries.len(), 2, "overflow stays with the caller");
        assert_eq!(q.push_batch(&mut entries), Err(Status::WouldBlock));
        assert_eq!(entries.len(), 2);
    }

    #[test]
    fn empty_poll_cost_is_constant_in_producer_count() {
        // The acceptance gate for the occupancy bitmap: polling an
        // all-empty queue charges the simulated memory system one word
        // load per priority, independent of how many producer lanes
        // exist (the seed scanned every lane's NBB counter).
        use crate::os::{AffinityMode, OsProfile};
        use crate::sim::{Machine, MachineCfg, SimWorld};
        let accesses = |producers: usize| {
            let m = Machine::new(MachineCfg::new(
                1,
                OsProfile::linux_rt(),
                AffinityMode::SingleCore,
            ));
            let stats = m.run_tasks(1, |_| {
                move || {
                    let q = LockFreeQueue::<SimWorld>::new(producers, 4);
                    for _ in 0..10 {
                        assert_eq!(q.pop(), Err(Status::WouldBlock));
                    }
                }
            });
            stats.hits + stats.misses
        };
        let small = accesses(2);
        let large = accesses(32);
        assert_eq!(
            small, large,
            "empty-poll line accesses must not scale with producers"
        );
        // 10 polls x PRIORITIES word snapshots, nothing else.
        assert_eq!(small, 10 * PRIORITIES as u64);
    }

    #[test]
    #[cfg(debug_assertions)]
    fn second_consumer_thread_is_rejected_in_debug() {
        // The single-consumer guard: once a thread has popped, a pop
        // from any other thread must panic instead of racing the cursor
        // and the clear-then-recheck protocol.
        let q = Arc::new(LfQueue::new(1, 4));
        q.push(Entry::scalar(1, 0)).unwrap();
        q.push(Entry::scalar(2, 0)).unwrap();
        let claimer = {
            let q = q.clone();
            std::thread::spawn(move || {
                assert_eq!(q.pop().unwrap().scalar, 1);
            })
        };
        claimer.join().unwrap();
        let intruder = {
            let q = q.clone();
            std::thread::spawn(move || {
                let _ = q.pop(); // must panic: queue owned by `claimer`
            })
        };
        assert!(
            intruder.join().is_err(),
            "second consumer thread must be rejected in debug builds"
        );
    }

    #[test]
    fn lockfree_mpsc_stress() {
        const PER: u64 = 30_000;
        let q = Arc::new(LfQueue::new(2, 32));
        let producers: Vec<_> = (0..2u32)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..PER {
                        let mut e = Entry::buffered(i as u32, 8, p, 0);
                        e.scalar = i;
                        loop {
                            match q.push(e) {
                                Ok(()) => break,
                                Err((_, back)) => {
                                    e = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let mut next = [0u64; 2];
        let mut got = 0;
        while got < 2 * PER {
            if let Ok(e) = q.pop() {
                let lane = e.from_node as usize;
                assert_eq!(e.scalar, next[lane], "per-producer FIFO violated");
                next[lane] += 1;
                got += 1;
            } else {
                std::thread::yield_now();
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn entry_wire_codec_roundtrips() {
        let cases = [
            Entry::buffered(7, 123, 3, 2),
            Entry::scalar(0xDEAD_BEEF_0BAD_F00D, 5),
            Entry::scalar_w(0xFF, 1, 1),
            Entry { buf_index: u32::MAX, len: 0, from_node: 0, priority: 255, scalar: u64::MAX },
        ];
        for e in cases {
            let wire = e.encode();
            assert_eq!(Entry::decode(&wire), Some(e));
        }
        assert_eq!(Entry::decode(&[0u8; ENTRY_WIRE_LEN - 1]), None);
    }

    #[test]
    fn consumer_group_distributes_exactly_once() {
        let g = ConsumerGroup::<RealWorld>::new(8, 8);
        assert!(!g.active());
        assert_eq!(g.attach(2), 1);
        assert_eq!(g.attach(3), 2);
        assert!(g.active());
        assert_eq!(ConsumerGroup::<RealWorld>::current_who(), Some(3));
        for i in 0..6u64 {
            g.push(Entry::scalar(i, 1)).unwrap();
        }
        assert_eq!(g.len(), 6);
        // Two members interleave (one may batch-steal the whole lane);
        // the union is exactly the sent set, each entry delivered once.
        let mut got = Vec::new();
        let mut turn = 0;
        while got.len() < 6 {
            let who = if turn % 2 == 0 { 2 } else { 3 };
            turn += 1;
            if let Ok(e) = g.pop(who) {
                got.push(e.scalar);
            }
            assert!(turn < 100, "group never drained");
        }
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3, 4, 5]);
        assert_eq!(g.pop(2), Err(Status::WouldBlock));
        assert_eq!(g.pop(3), Err(Status::WouldBlock));
    }

    #[test]
    fn consumer_group_full_hands_entry_back() {
        let g = ConsumerGroup::<RealWorld>::new(2, 2);
        g.push(Entry::scalar(1, 0)).unwrap();
        g.push(Entry::scalar(2, 0)).unwrap();
        let (s, back) = g.push(Entry::scalar(3, 0)).unwrap_err();
        assert_eq!(s, Status::WouldBlock);
        assert_eq!(back.scalar, 3);
    }

    #[test]
    fn consumer_group_batch_push_drains_prefix() {
        let g = ConsumerGroup::<RealWorld>::new(4, 4);
        let mut entries: Vec<Entry> = (0..6u64).map(|i| Entry::scalar(i, 1)).collect();
        assert_eq!(g.push_batch(&mut entries), Ok(4));
        assert_eq!(entries.len(), 2, "overflow stays with the caller");
        assert_eq!(g.push_batch(&mut entries), Err(Status::WouldBlock));
        // An unattached in-range identity can still drain via stealing.
        let mut got: Vec<u64> = (0..4).map(|_| g.pop(3).unwrap().scalar).collect();
        got.sort_unstable();
        assert_eq!(got, vec![0, 1, 2, 3]);
        let mut empty = Vec::new();
        assert_eq!(g.push_batch(&mut empty), Ok(0));
    }

    #[test]
    fn consumer_group_repair_requeues_dead_thief_stash() {
        let g = ConsumerGroup::<RealWorld>::new(8, 4);
        g.push(Entry::scalar(41, 1)).unwrap();
        g.push(Entry::scalar(42, 1)).unwrap();
        // Member 6 steals the lane's batch, delivers one entry, then
        // dies with the second still staged in its stash.
        assert_eq!(g.pop(6).unwrap().scalar, 41);
        let (repairs, overflow) = g.repair_dead(6);
        assert_eq!(repairs, 1, "the staged entry is requeued in-ring");
        assert!(overflow.is_empty(), "dead lane had room: no overflow");
        // The requeued entry landed back in the ring (on the dead
        // node's own lane, not the live producer's) and a survivor
        // drains it.
        assert_eq!(g.len(), 1);
        assert_eq!(g.pop(0).unwrap().scalar, 42);
        // Live peers are untouched.
        assert_eq!(g.repair_dead(7), (0, Vec::new()));
    }

    #[test]
    fn consumer_group_rebalances_on_attach_and_repair() {
        let g = ConsumerGroup::<RealWorld>::new(4, 4);
        g.attach(0);
        assert_eq!(g.home_of(0), Some(0));
        assert_eq!(g.home_of(3), Some(0));
        g.attach(1);
        // Round-robin over {0, 1}: lanes alternate homes.
        assert_eq!(g.home_of(0), Some(0));
        assert_eq!(g.home_of(1), Some(1));
        // Member 0 dies: its lanes re-home onto the survivor.
        g.repair_dead(0);
        for lane in 0..4 {
            assert_eq!(g.home_of(lane), Some(1), "orphaned lane re-homed");
        }
    }
}
