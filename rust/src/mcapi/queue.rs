//! Endpoint receive queues: the lock-based and lock-free implementations.
//!
//! The queue entry carries a buffer lease plus metadata (a small POD, like
//! the paper's queue entries binding reusable message buffers). Entries
//! move through the Figure 4 FSM in the lock-free backend; the locked
//! backend is the reference design — a plain deque guarded by the global
//! reader/writer lock (acquired by the *runtime*, not here).

use std::cell::UnsafeCell;
use std::collections::VecDeque;

use crate::lockfree::mem::World;
use crate::lockfree::nbb::{InsertStatus, Nbb, ReadStatus};
use crate::mcapi::types::{Status, PRIORITIES};

/// Queue-entry FSM states (Figure 4).
pub mod entry_state {
    /// No buffer associated.
    pub const FREE: u32 = 0;
    /// Entry claimed, buffer not yet linked.
    pub const RESERVED: u32 = 1;
    /// Buffer linked and filled.
    pub const ALLOCATED: u32 = 2;
    /// At the head, being read by the receiver.
    pub const RECEIVED: u32 = 3;
}

/// One queued message/packet: lease metadata (POD; fits an NBB slot).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Entry {
    /// Buffer index in the shared partition.
    pub buf_index: u32,
    /// Payload length in bytes.
    pub len: u32,
    /// Sender's dense node slot (producer lane).
    pub from_node: u32,
    /// Priority lane it was sent on.
    pub priority: u8,
    /// Scalar payload when the entry carries a scalar (no buffer lease).
    pub scalar: u64,
}

impl Entry {
    /// Entry carrying a pooled buffer.
    pub fn buffered(buf_index: u32, len: u32, from_node: u32, priority: u8) -> Self {
        Entry { buf_index, len, from_node, priority, scalar: 0 }
    }

    /// Entry carrying an inline scalar.
    pub fn scalar(value: u64, from_node: u32) -> Self {
        Entry { buf_index: u32::MAX, len: 0, from_node, priority: 0, scalar: value }
    }

    /// True when this entry owns a pooled buffer.
    pub fn has_buffer(&self) -> bool {
        self.buf_index != u32::MAX
    }
}

// ---------------------------------------------------------------------------
// Lock-based reference queue.
// ---------------------------------------------------------------------------

/// Priority deques guarded externally by the runtime's global RwLock —
/// mirrors the reference implementation where the shared-memory database
/// is one lock domain. The `UnsafeCell` is sound because every access goes
/// through the runtime while it holds the global lock (asserted in debug
/// builds via the lock's own state).
pub struct LockedQueue {
    lanes: UnsafeCell<[VecDeque<Entry>; PRIORITIES]>,
    capacity: usize,
}

unsafe impl Send for LockedQueue {}
unsafe impl Sync for LockedQueue {}

impl LockedQueue {
    /// Queue with `capacity` entries per priority lane.
    pub fn new(capacity: usize) -> Self {
        LockedQueue { lanes: UnsafeCell::new(Default::default()), capacity }
    }

    /// Push under the global write lock.
    ///
    /// # Safety
    /// Caller must hold the runtime's global write lock.
    pub unsafe fn push(&self, e: Entry) -> Result<(), Status> {
        let lanes = &mut *self.lanes.get();
        let lane = &mut lanes[e.priority as usize % PRIORITIES];
        if lane.len() >= self.capacity {
            return Err(Status::WouldBlock);
        }
        lane.push_back(e);
        Ok(())
    }

    /// Pop the highest-priority entry under the global write lock.
    ///
    /// # Safety
    /// Caller must hold the runtime's global write lock.
    pub unsafe fn pop(&self) -> Option<Entry> {
        let lanes = &mut *self.lanes.get();
        lanes.iter_mut().find_map(|l| l.pop_front())
    }

    /// Entry count under the global (at least read) lock.
    ///
    /// # Safety
    /// Caller must hold the runtime's global lock.
    pub unsafe fn len(&self) -> usize {
        (*self.lanes.get()).iter().map(|l| l.len()).sum()
    }
}

// ---------------------------------------------------------------------------
// Lock-free queue: composed NBB lanes.
// ---------------------------------------------------------------------------

/// Lock-free receive queue: one SPSC NBB per (priority, producer-node)
/// lane, drained priority-major with a rotating fairness cursor — the
/// NBB composition Kim et al. describe for fan-in patterns.
pub struct LockFreeQueue<W: World> {
    /// `lanes[priority][producer]`.
    lanes: Vec<Vec<Nbb<Entry, W>>>,
    producers: usize,
    /// Receiver-private rotation cursor (single-consumer by MCAPI spec).
    cursor: UnsafeCell<usize>,
}

unsafe impl<W: World> Send for LockFreeQueue<W> {}
unsafe impl<W: World> Sync for LockFreeQueue<W> {}

impl<W: World> LockFreeQueue<W> {
    /// Queue with `producers` lanes per priority, each of `capacity`.
    pub fn new(producers: usize, capacity: usize) -> Self {
        LockFreeQueue {
            lanes: (0..PRIORITIES)
                .map(|_| (0..producers).map(|_| Nbb::new(capacity)).collect())
                .collect(),
            producers,
            cursor: UnsafeCell::new(0),
        }
    }

    /// Producer-side insert (wait-free except the bounded ring).
    pub fn push(&self, e: Entry) -> Result<(), (Status, Entry)> {
        let lane = &self.lanes[e.priority as usize % PRIORITIES][e.from_node as usize % self.producers];
        lane.insert(e).map_err(|(s, e)| {
            let status = match s {
                InsertStatus::Full => Status::WouldBlock,
                InsertStatus::FullButConsumerReading => Status::WouldBlockPeerActive,
            };
            (status, e)
        })
    }

    /// Consumer-side pop: scan priorities high-to-low, rotating across
    /// producer lanes for fairness. Single consumer only.
    pub fn pop(&self) -> Result<Entry, Status> {
        let cursor = unsafe { &mut *self.cursor.get() };
        let mut saw_peer_active = false;
        for prio in 0..PRIORITIES {
            for i in 0..self.producers {
                let lane = (*cursor + i) % self.producers;
                match self.lanes[prio][lane].read() {
                    ReadStatus::Ok(e) => {
                        *cursor = (lane + 1) % self.producers;
                        return Ok(e);
                    }
                    ReadStatus::EmptyButProducerInserting => saw_peer_active = true,
                    ReadStatus::Empty => {}
                }
            }
        }
        Err(if saw_peer_active {
            Status::WouldBlockPeerActive
        } else {
            Status::WouldBlock
        })
    }

    /// Total buffered entries (approximate).
    pub fn len(&self) -> usize {
        self.lanes.iter().flatten().map(|n| n.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    type LfQueue = LockFreeQueue<RealWorld>;

    #[test]
    fn entry_pod_size_is_cacheline_friendly() {
        assert!(std::mem::size_of::<Entry>() <= 24);
    }

    #[test]
    fn scalar_entries_have_no_buffer() {
        let e = Entry::scalar(42, 1);
        assert!(!e.has_buffer());
        assert!(Entry::buffered(0, 10, 1, 0).has_buffer());
    }

    #[test]
    fn locked_queue_priority_order() {
        let q = LockedQueue::new(8);
        unsafe {
            q.push(Entry::buffered(1, 1, 0, 2)).unwrap();
            q.push(Entry::buffered(2, 1, 0, 0)).unwrap();
            q.push(Entry::buffered(3, 1, 0, 1)).unwrap();
            assert_eq!(q.pop().unwrap().buf_index, 2); // prio 0 first
            assert_eq!(q.pop().unwrap().buf_index, 3);
            assert_eq!(q.pop().unwrap().buf_index, 1);
            assert!(q.pop().is_none());
        }
    }

    #[test]
    fn locked_queue_capacity_per_lane() {
        let q = LockedQueue::new(2);
        unsafe {
            q.push(Entry::buffered(0, 1, 0, 0)).unwrap();
            q.push(Entry::buffered(1, 1, 0, 0)).unwrap();
            assert_eq!(q.push(Entry::buffered(2, 1, 0, 0)), Err(Status::WouldBlock));
            // Other lanes unaffected.
            q.push(Entry::buffered(3, 1, 0, 1)).unwrap();
            assert_eq!(q.len(), 3);
        }
    }

    #[test]
    fn lockfree_fifo_per_producer() {
        let q = LfQueue::new(2, 8);
        q.push(Entry::buffered(10, 1, 0, 0)).unwrap();
        q.push(Entry::buffered(11, 1, 0, 0)).unwrap();
        let a = q.pop().unwrap();
        let b = q.pop().unwrap();
        assert_eq!((a.buf_index, b.buf_index), (10, 11), "per-producer FIFO");
        assert_eq!(q.pop(), Err(Status::WouldBlock));
    }

    #[test]
    fn lockfree_priority_precedence() {
        let q = LfQueue::new(1, 8);
        q.push(Entry::buffered(1, 1, 0, 3)).unwrap();
        q.push(Entry::buffered(2, 1, 0, 0)).unwrap();
        assert_eq!(q.pop().unwrap().buf_index, 2);
        assert_eq!(q.pop().unwrap().buf_index, 1);
    }

    #[test]
    fn lockfree_fairness_rotates_producers() {
        let q = LfQueue::new(2, 8);
        for i in 0..4 {
            q.push(Entry::buffered(100 + i, 1, 0, 0)).unwrap();
            q.push(Entry::buffered(200 + i, 1, 1, 0)).unwrap();
        }
        let mut from0 = 0;
        let mut from1 = 0;
        for _ in 0..4 {
            let e = q.pop().unwrap();
            if e.buf_index >= 200 {
                from1 += 1;
            } else {
                from0 += 1;
            }
        }
        assert!(from0 >= 1 && from1 >= 1, "rotation starves a producer");
    }

    #[test]
    fn lockfree_full_lane_reports_wouldblock() {
        let q = LfQueue::new(1, 2);
        q.push(Entry::buffered(0, 1, 0, 0)).unwrap();
        q.push(Entry::buffered(1, 1, 0, 0)).unwrap();
        let (status, back) = q.push(Entry::buffered(2, 1, 0, 0)).unwrap_err();
        assert_eq!(status, Status::WouldBlock);
        assert_eq!(back.buf_index, 2);
    }

    #[test]
    fn lockfree_mpsc_stress() {
        const PER: u64 = 30_000;
        let q = Arc::new(LfQueue::new(2, 32));
        let producers: Vec<_> = (0..2u32)
            .map(|p| {
                let q = q.clone();
                std::thread::spawn(move || {
                    for i in 0..PER {
                        let mut e = Entry::buffered(i as u32, 8, p, 0);
                        e.scalar = i;
                        loop {
                            match q.push(e) {
                                Ok(()) => break,
                                Err((_, back)) => {
                                    e = back;
                                    std::thread::yield_now();
                                }
                            }
                        }
                    }
                })
            })
            .collect();
        let mut next = [0u64; 2];
        let mut got = 0;
        while got < 2 * PER {
            if let Ok(e) = q.pop() {
                let lane = e.from_node as usize;
                assert_eq!(e.scalar, next[lane], "per-producer FIFO violated");
                next[lane] += 1;
                got += 1;
            } else {
                std::thread::yield_now();
            }
        }
        for p in producers {
            p.join().unwrap();
        }
        assert_eq!(q.len(), 0);
    }
}
