//! Asynchronous request objects (Figure 3).
//!
//! Every non-blocking MCAPI operation (`*_i`) allocates a request from a
//! fixed pool. In the paper's refactoring the pool allocator is the
//! lock-free **bit set** (step 3) and the per-request status booleans
//! became the Figure 3 FSM:
//!
//! ```text
//! FREE -> VALID -> {COMPLETED | RECEIVED -> COMPLETED | CANCELLED} -> FREE
//! ```
//!
//! `RECEIVED` is the exceptional asynchronous-send state: the request is
//! held until the receive side confirms buffer ownership transfer.

use crate::lockfree::bitset::BitSet;
use crate::lockfree::fsm::AtomicFsm;
use crate::lockfree::mem::{Atom32, World};
use crate::mcapi::types::Status;

/// Figure 3 FSM states.
pub mod request_state {
    /// Available for allocation.
    pub const FREE: u32 = 0;
    /// Allocated; operation pending.
    pub const VALID: u32 = 1;
    /// Async send landed; awaiting buffer-receipt confirmation.
    pub const RECEIVED: u32 = 2;
    /// Operation finished (success or error recorded).
    pub const COMPLETED: u32 = 3;
    /// Receive cancelled (sends always complete).
    pub const CANCELLED: u32 = 4;
}
use request_state::*;

/// What a pending request is waiting to do (re-driven by `wait`/`test`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PendingOp {
    /// Connectionless message send to endpoint table slot.
    MsgSend { ep: usize },
    /// Connectionless message receive from endpoint table slot.
    MsgRecv { ep: usize },
    /// Packet send on channel table slot.
    PktSend { ch: usize },
    /// Packet receive on channel table slot.
    PktRecv { ch: usize },
    /// Nothing (slot idle).
    None,
}

/// Handle to a pool slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestHandle(pub usize);

/// One pool slot: FSM + operation descriptor + completion record.
pub struct RequestSlot<W: World> {
    /// Figure 3 state machine.
    pub fsm: AtomicFsm<W>,
    /// Operation to re-drive (encoded; see [`PendingOp`]).
    op_kind: W::U32,
    op_arg: W::U32,
    /// Completion status (valid once COMPLETED).
    result: W::U32,
}

impl<W: World> RequestSlot<W> {
    fn new() -> Self {
        RequestSlot {
            fsm: AtomicFsm::new(FREE),
            op_kind: W::U32::new(0),
            op_arg: W::U32::new(0),
            result: W::U32::new(0),
        }
    }

    fn set_op(&self, op: PendingOp) {
        let (k, a) = encode(op);
        self.op_kind.store(k);
        self.op_arg.store(a);
    }

    /// The operation this request re-drives.
    pub fn op(&self) -> PendingOp {
        decode(self.op_kind.load(), self.op_arg.load())
    }
}

fn encode(op: PendingOp) -> (u32, u32) {
    match op {
        PendingOp::None => (0, 0),
        PendingOp::MsgSend { ep } => (1, ep as u32),
        PendingOp::MsgRecv { ep } => (2, ep as u32),
        PendingOp::PktSend { ch } => (3, ch as u32),
        PendingOp::PktRecv { ch } => (4, ch as u32),
    }
}

fn decode(k: u32, a: u32) -> PendingOp {
    match k {
        1 => PendingOp::MsgSend { ep: a as usize },
        2 => PendingOp::MsgRecv { ep: a as usize },
        3 => PendingOp::PktSend { ch: a as usize },
        4 => PendingOp::PktRecv { ch: a as usize },
        _ => PendingOp::None,
    }
}

fn encode_status(s: Status) -> u32 {
    match s {
        Status::Success => 0,
        Status::Timeout => 1,
        Status::Cancelled => 2,
        Status::MemLimit => 3,
        Status::MessageLimit => 4,
        Status::EndpointDead => 6,
        _ => 5,
    }
}

fn decode_status(v: u32) -> Status {
    match v {
        0 => Status::Success,
        1 => Status::Timeout,
        2 => Status::Cancelled,
        3 => Status::MemLimit,
        4 => Status::MessageLimit,
        6 => Status::EndpointDead,
        _ => Status::InvalidRequest,
    }
}

/// The request pool: bit-set allocator over FSM slots.
pub struct RequestPool<W: World> {
    alloc: BitSet<W>,
    slots: Vec<RequestSlot<W>>,
}

impl<W: World> RequestPool<W> {
    /// Pool of `cap` requests.
    pub fn new(cap: usize) -> Self {
        RequestPool { alloc: BitSet::new(cap), slots: (0..cap).map(|_| RequestSlot::new()).collect() }
    }

    /// Allocate a request for `op`; FREE -> VALID.
    pub fn allocate(&self, op: PendingOp) -> Result<RequestHandle, Status> {
        let idx = self.alloc.alloc().ok_or(Status::Exhausted)?;
        let slot = &self.slots[idx];
        // The bit set grants exclusive ownership, so the slot must be FREE.
        slot.fsm.transition_exact(FREE, VALID);
        slot.set_op(op);
        Ok(RequestHandle(idx))
    }

    /// Slot accessor.
    pub fn slot(&self, h: RequestHandle) -> &RequestSlot<W> {
        &self.slots[h.0]
    }

    /// Mark an async-send request as landed-awaiting-confirmation
    /// (VALID -> RECEIVED), the paper's exceptional send path.
    pub fn mark_received(&self, h: RequestHandle) -> Result<(), u32> {
        self.slots[h.0].fsm.transition(VALID, RECEIVED)
    }

    /// Complete a request with `status` (VALID|RECEIVED -> COMPLETED).
    pub fn complete(&self, h: RequestHandle, status: Status) {
        let slot = &self.slots[h.0];
        slot.result.store(encode_status(status));
        if slot.fsm.transition(VALID, COMPLETED).is_err() {
            slot.fsm.transition_exact(RECEIVED, COMPLETED);
        }
    }

    /// Cancel a pending receive (VALID -> CANCELLED -> FREE). Sends cannot
    /// be cancelled (they always complete) — callers enforce op kind.
    pub fn cancel(&self, h: RequestHandle) -> Result<(), Status> {
        let slot = &self.slots[h.0];
        slot.fsm
            .transition(VALID, CANCELLED)
            .map_err(|_| Status::InvalidRequest)?;
        slot.result.store(encode_status(Status::Cancelled));
        slot.set_op(PendingOp::None);
        slot.fsm.transition_exact(CANCELLED, FREE);
        self.alloc.free(h.0);
        Ok(())
    }

    /// Reap a COMPLETED request: read its status and return the slot to
    /// the pool (COMPLETED -> FREE).
    pub fn reap(&self, h: RequestHandle) -> Result<Status, Status> {
        let slot = &self.slots[h.0];
        slot.fsm
            .transition(COMPLETED, FREE)
            .map_err(|_| Status::InvalidRequest)?;
        let status = decode_status(slot.result.load());
        slot.set_op(PendingOp::None);
        self.alloc.free(h.0);
        Ok(status)
    }

    /// Non-destructive completion test.
    pub fn is_complete(&self, h: RequestHandle) -> bool {
        self.slots[h.0].fsm.state() == COMPLETED
    }

    /// Requests currently allocated (VALID/RECEIVED/COMPLETED).
    pub fn in_use(&self) -> usize {
        self.alloc.count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    type Pool = RequestPool<RealWorld>;

    #[test]
    fn lifecycle_free_valid_completed_free() {
        let p = Pool::new(4);
        let h = p.allocate(PendingOp::MsgRecv { ep: 3 }).unwrap();
        assert_eq!(p.slot(h).op(), PendingOp::MsgRecv { ep: 3 });
        assert!(!p.is_complete(h));
        p.complete(h, Status::Success);
        assert!(p.is_complete(h));
        assert_eq!(p.reap(h), Ok(Status::Success));
        assert_eq!(p.in_use(), 0);
    }

    #[test]
    fn exceptional_send_path_via_received() {
        let p = Pool::new(2);
        let h = p.allocate(PendingOp::MsgSend { ep: 0 }).unwrap();
        p.mark_received(h).unwrap();
        assert_eq!(p.slot(h).fsm.state(), RECEIVED);
        p.complete(h, Status::Success);
        assert_eq!(p.reap(h), Ok(Status::Success));
    }

    #[test]
    fn cancel_pending_receive() {
        let p = Pool::new(2);
        let h = p.allocate(PendingOp::MsgRecv { ep: 1 }).unwrap();
        p.cancel(h).unwrap();
        assert_eq!(p.in_use(), 0);
        // Slot is reusable immediately.
        let h2 = p.allocate(PendingOp::MsgRecv { ep: 2 }).unwrap();
        assert_eq!(h2.0, h.0, "lowest slot reused");
    }

    #[test]
    fn cancel_completed_request_fails() {
        let p = Pool::new(2);
        let h = p.allocate(PendingOp::MsgRecv { ep: 0 }).unwrap();
        p.complete(h, Status::Success);
        assert_eq!(p.cancel(h), Err(Status::InvalidRequest));
        let _ = p.reap(h);
    }

    #[test]
    fn reap_before_completion_fails() {
        let p = Pool::new(2);
        let h = p.allocate(PendingOp::MsgSend { ep: 0 }).unwrap();
        assert_eq!(p.reap(h), Err(Status::InvalidRequest));
        p.complete(h, Status::Timeout);
        assert_eq!(p.reap(h), Ok(Status::Timeout));
    }

    #[test]
    fn pool_exhaustion() {
        let p = Pool::new(2);
        let _a = p.allocate(PendingOp::None).unwrap();
        let _b = p.allocate(PendingOp::None).unwrap();
        assert_eq!(p.allocate(PendingOp::None).unwrap_err(), Status::Exhausted);
    }

    #[test]
    fn concurrent_allocation_is_exclusive() {
        let p = Arc::new(Pool::new(64));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let p = p.clone();
                std::thread::spawn(move || {
                    let mut got = Vec::new();
                    for _ in 0..16 {
                        got.push(p.allocate(PendingOp::None).unwrap().0);
                    }
                    got
                })
            })
            .collect();
        let mut all: Vec<usize> = handles
            .into_iter()
            .flat_map(|h| h.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), 64, "duplicate request slots handed out");
    }

    #[test]
    fn status_roundtrip_through_slot() {
        for s in [
            Status::Success,
            Status::Timeout,
            Status::Cancelled,
            Status::MemLimit,
            Status::MessageLimit,
            Status::EndpointDead,
        ] {
            let p = Pool::new(1);
            let h = p.allocate(PendingOp::None).unwrap();
            p.complete(h, s);
            assert_eq!(p.reap(h), Ok(s));
        }
    }
}
