//! MRAPI — the Multicore Resource Management API substrate.
//!
//! The paper's Figure 1 stack builds MCAPI on MRAPI: a shared-memory
//! partition holds all resource structures and metadata, guarded in the
//! reference implementation by **a single user-mode reader/writer lock
//! whose state changes are themselves guarded by a single OS kernel lock**
//! — the red oval of Figure 1 and the bottleneck the whole paper is about.
//!
//! * [`shmem`] — the shared-memory partition: a fixed arena of slots with
//!   offset-based addressing (mirroring the SysVR4 `shmget`/`shmat` model
//!   the reference implementation portably wraps).
//! * [`rwlock`] — the user-mode reader/writer lock over one kernel lock:
//!   the **lock-based baseline** whose removal the paper measures.
//! * [`sync`] — user-mode mutexes and counting semaphores built on the
//!   same kernel-lock portability layer.
//! * [`node`] — domains, nodes and run-up/run-down with atomic state
//!   verification (contribution 4 of the refactoring).
//! * [`resource`] — the metadata resource tree with filtered views and
//!   change-triggered callbacks.

pub mod node;
pub mod resource;
pub mod rwlock;
pub mod shmem;
pub mod sync;

pub use node::{Domain, NodeRegistry, NodeState};
pub use resource::{ResourceKind, ResourceTree};
pub use rwlock::RwLock;
pub use shmem::Partition;
pub use sync::{Mutex, Semaphore};
