//! The user-mode reader/writer lock of the MRAPI reference implementation.
//!
//! Paper, Section 2: "A user-mode reader/writer lock controls access to
//! the partition and a single OS kernel lock guards changes to the
//! reader/writer lock. Effectively, all write access to the global shared
//! memory is serialized and the readers are blocked if a write is in
//! progress."
//!
//! That design is reproduced literally: reader/writer counts live in
//! user-mode words, but *every* state change takes the kernel lock, and
//! blocked acquirers sleep on the kernel lock too (re-checking on wake).
//! This is intentionally the paper's baseline, not a modern rwlock — its
//! cost profile (kernel entries on contention, convoying on multicore) is
//! what Table 2 measures.

use crate::lockfree::mem::{Atom32, CachePadded, KernelLock, World};

/// Lock-based baseline reader/writer lock, generic over the world.
///
/// The state words are line-padded: `readers` is hammered by every
/// reader's fetch-add/sub while `writer` is polled by readers and
/// written by the writer — on one line the reader counter traffic would
/// keep invalidating the writer flag (and the kernel lock state) for
/// every core. The *protocol* stays the paper's baseline (do not "fix"
/// the convoy); padding only removes incidental false sharing so Table 2
/// measures the design, not the struct layout.
pub struct RwLock<W: World> {
    kernel: CachePadded<W::Lock>,
    readers: CachePadded<W::U32>,
    writer: CachePadded<W::U32>,
}

impl<W: World> Default for RwLock<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: World> RwLock<W> {
    /// New, unheld.
    pub fn new() -> Self {
        RwLock {
            kernel: CachePadded::new(W::Lock::new()),
            readers: CachePadded::new(W::U32::new(0)),
            writer: CachePadded::new(W::U32::new(0)),
        }
    }

    /// Acquire shared (read) access; writers block readers.
    pub fn read_lock(&self) {
        loop {
            // The kernel lock guards the rwlock state words; contended
            // acquires *block* in the kernel (the paper: "readers are
            // blocked if a write is in progress") — a writer holds the
            // kernel lock for its whole critical section.
            self.kernel.acquire();
            if self.writer.load() == 0 {
                self.readers.fetch_add(1);
                self.kernel.release();
                return;
            }
            self.kernel.release();
            W::yield_now();
        }
    }

    /// Release shared access.
    pub fn read_unlock(&self) {
        let prev = self.readers.fetch_add(u32::MAX); // wrapping -1
        assert!(prev > 0, "read_unlock without read_lock");
    }

    /// Acquire exclusive (write) access; blocks out readers and writers.
    ///
    /// The kernel lock is held until [`RwLock::write_unlock`] — all write
    /// access to the global shared memory is serialized through one OS
    /// lock, and any task touching the database meanwhile *blocks* in the
    /// kernel. This is the reference design's convoy source that Table 2
    /// measures; do not "fix" it.
    pub fn write_lock(&self) {
        self.kernel.acquire();
        // Wait out any in-flight readers (they never hold the kernel lock
        // across their critical section).
        while self.readers.load() != 0 {
            W::yield_now();
        }
        self.writer.store(1);
    }

    /// Release exclusive access.
    pub fn write_unlock(&self) {
        let prev = self.writer.load();
        assert_eq!(prev, 1, "write_unlock without write_lock");
        self.writer.store(0);
        self.kernel.release();
    }

    /// Run `f` under the write lock.
    pub fn with_write<R>(&self, f: impl FnOnce() -> R) -> R {
        self.write_lock();
        let r = f();
        self.write_unlock();
        r
    }

    /// Run `f` under the read lock.
    pub fn with_read<R>(&self, f: impl FnOnce() -> R) -> R {
        self.read_lock();
        let r = f();
        self.read_unlock();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::atomic::{AtomicU32, AtomicUsize, Ordering};
    use std::sync::Arc;

    type RLock = RwLock<RealWorld>;

    #[test]
    fn writers_are_exclusive() {
        let lock = Arc::new(RLock::new());
        let value = Arc::new(AtomicU32::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let lock = lock.clone();
            let value = value.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..2_000 {
                    lock.with_write(|| {
                        let v = value.load(Ordering::Relaxed);
                        value.store(v + 1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(value.load(Ordering::Relaxed), 8_000);
    }

    #[test]
    fn readers_share() {
        let lock = Arc::new(RLock::new());
        let concurrent = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let lock = lock.clone();
            let concurrent = concurrent.clone();
            let peak = peak.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..500 {
                    lock.with_read(|| {
                        let now = concurrent.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::yield_now();
                        concurrent.fetch_sub(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // At least sometimes two readers overlapped (not guaranteed on a
        // 1-core box, so only assert it never exceeded the thread count).
        assert!(peak.load(Ordering::SeqCst) <= 4);
    }

    #[test]
    fn readers_excluded_during_write() {
        let lock = Arc::new(RLock::new());
        let in_write = Arc::new(AtomicU32::new(0));
        let violations = Arc::new(AtomicU32::new(0));
        let writer = {
            let lock = lock.clone();
            let in_write = in_write.clone();
            std::thread::spawn(move || {
                for _ in 0..1_000 {
                    lock.with_write(|| {
                        in_write.store(1, Ordering::SeqCst);
                        in_write.store(0, Ordering::SeqCst);
                    });
                }
            })
        };
        let reader = {
            let lock = lock.clone();
            let in_write = in_write.clone();
            let violations = violations.clone();
            std::thread::spawn(move || {
                for _ in 0..1_000 {
                    lock.with_read(|| {
                        if in_write.load(Ordering::SeqCst) == 1 {
                            violations.fetch_add(1, Ordering::SeqCst);
                        }
                    });
                }
            })
        };
        writer.join().unwrap();
        reader.join().unwrap();
        assert_eq!(violations.load(Ordering::SeqCst), 0);
    }

    #[test]
    #[should_panic(expected = "read_unlock without")]
    fn unbalanced_read_unlock_panics() {
        RLock::new().read_unlock();
    }

    #[test]
    #[should_panic(expected = "write_unlock without")]
    fn unbalanced_write_unlock_panics() {
        RLock::new().write_unlock();
    }
}
