//! The shared-memory partition: fixed arena with offset addressing.
//!
//! MRAPI organises "data exchange structures, metadata and buffers ... in
//! a single shared memory partition" that can be initialised from a disk
//! image at startup. This module reproduces that model: a fixed-size byte
//! arena carved into typed slots addressed by offsets (not pointers, so a
//! partition image is position-independent, as SysVR4 `shmat` demands).
//!
//! Payload buffers hand out `(offset, len)` leases; the content lives in
//! one contiguous allocation, matching the paper's observation that the
//! primary I/O cost is transferring *ownership* of these buffers, not
//! their bytes.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::lockfree::freelist::FreeList;
use crate::lockfree::mem::World;

/// A fixed partition of `count` buffers, each `buf_len` bytes, with a
/// lock-free lease pool.
pub struct Partition<W: World> {
    arena: Box<[UnsafeCell<u8>]>,
    buf_len: usize,
    pool: FreeList<W>,
    /// Synthetic region base for simulator cost accounting.
    region: u64,
    /// Acquire + release attempt counter. Instrumentation only — a plain
    /// host atomic on purpose, so simulated worlds never price it: the
    /// connected-channel fast-path tests assert **zero** lease traffic on
    /// a steady-state packet exchange via this counter.
    lease_ops: AtomicU64,
}

unsafe impl<W: World> Send for Partition<W> {}
unsafe impl<W: World> Sync for Partition<W> {}

/// A leased buffer: offset-addressed view into the partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Lease {
    /// Buffer index in the partition.
    pub index: usize,
    /// Byte offset of the buffer start.
    pub offset: usize,
    /// Buffer capacity in bytes.
    pub len: usize,
}

impl<W: World> Partition<W> {
    /// Allocate a partition of `count` buffers of `buf_len` bytes.
    pub fn new(count: usize, buf_len: usize) -> Self {
        assert!(count >= 1 && buf_len >= 1);
        let arena = (0..count * buf_len)
            .map(|_| UnsafeCell::new(0u8))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        Partition {
            arena,
            buf_len,
            pool: FreeList::new_full(count),
            region: W::alloc_region(count * buf_len),
            lease_ops: AtomicU64::new(0),
        }
    }

    /// Number of buffers.
    pub fn capacity(&self) -> usize {
        self.arena.len() / self.buf_len
    }

    /// Bytes per buffer.
    pub fn buf_len(&self) -> usize {
        self.buf_len
    }

    /// Free buffers remaining (approximate under concurrency).
    pub fn available(&self) -> usize {
        self.pool.free_count()
    }

    /// Total acquire + release attempts so far (instrumentation; see the
    /// field docs — not priced by simulated worlds).
    pub fn lease_ops(&self) -> u64 {
        self.lease_ops.load(Ordering::Relaxed)
    }

    /// Lease a buffer from the pool (lock-free). `None` when exhausted.
    pub fn acquire(&self) -> Option<Lease> {
        self.lease_ops.fetch_add(1, Ordering::Relaxed);
        let index = self.pool.pop()?;
        Some(Lease { index, offset: index * self.buf_len, len: self.buf_len })
    }

    /// Return a lease to the pool (lock-free).
    pub fn release(&self, lease: Lease) {
        self.lease_ops.fetch_add(1, Ordering::Relaxed);
        self.pool.push(lease.index);
    }

    /// Copy `data` into the leased buffer. Panics if it does not fit.
    /// Charges the simulated memory system for the payload movement.
    ///
    /// Safety contract (enforced by the lease pool): a lease grants
    /// exclusive access to its buffer between `acquire` and `release`.
    pub fn write(&self, lease: &Lease, data: &[u8]) {
        assert!(data.len() <= lease.len, "payload exceeds buffer");
        W::touch(self.region + lease.offset as u64, data.len().max(1), true);
        // One bulk copy. Sound: the lease grants exclusive access to
        // `arena[offset..offset+len]`, UnsafeCell<u8> slots are contiguous
        // and have the layout of u8 (EXPERIMENTS.md §Perf: ~2.3x on the
        // 192-byte path over the byte-wise loop).
        unsafe {
            let dst = self.arena[lease.offset].get();
            std::ptr::copy_nonoverlapping(data.as_ptr(), dst, data.len());
        }
    }

    /// Copy up to `out.len()` bytes out of the leased buffer; returns the
    /// byte count copied.
    pub fn read(&self, lease: &Lease, out: &mut [u8]) -> usize {
        let n = out.len().min(lease.len);
        W::touch(self.region + lease.offset as u64, n.max(1), false);
        // Bulk copy; see `write` for the soundness argument.
        unsafe {
            let src = self.arena[lease.offset].get();
            std::ptr::copy_nonoverlapping(src, out.as_mut_ptr(), n);
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    type RPart = Partition<RealWorld>;

    #[test]
    fn acquire_release_roundtrip() {
        let p = RPart::new(4, 64);
        assert_eq!(p.available(), 4);
        let a = p.acquire().unwrap();
        assert_eq!(p.available(), 3);
        p.release(a);
        assert_eq!(p.available(), 4);
    }

    #[test]
    fn exhaustion_returns_none() {
        let p = RPart::new(2, 8);
        let _a = p.acquire().unwrap();
        let _b = p.acquire().unwrap();
        assert!(p.acquire().is_none());
    }

    #[test]
    fn write_read_payload() {
        let p = RPart::new(2, 32);
        let lease = p.acquire().unwrap();
        p.write(&lease, b"hello mcapi");
        let mut out = [0u8; 11];
        assert_eq!(p.read(&lease, &mut out), 11);
        assert_eq!(&out, b"hello mcapi");
    }

    #[test]
    fn leases_do_not_overlap() {
        let p = RPart::new(3, 16);
        let a = p.acquire().unwrap();
        let b = p.acquire().unwrap();
        p.write(&a, &[0xAA; 16]);
        p.write(&b, &[0xBB; 16]);
        let mut out = [0u8; 16];
        p.read(&a, &mut out);
        assert!(out.iter().all(|&x| x == 0xAA));
    }

    #[test]
    #[should_panic(expected = "exceeds buffer")]
    fn oversized_write_panics() {
        let p = RPart::new(1, 4);
        let lease = p.acquire().unwrap();
        p.write(&lease, &[0; 5]);
    }

    #[test]
    fn concurrent_lease_churn_is_exclusive() {
        let p = Arc::new(RPart::new(8, 64));
        let mut handles = Vec::new();
        for t in 0..4u8 {
            let p = p.clone();
            handles.push(std::thread::spawn(move || {
                for round in 0..5_000u32 {
                    if let Some(lease) = p.acquire() {
                        let pattern = t.wrapping_add(round as u8);
                        p.write(&lease, &[pattern; 64]);
                        let mut out = [0u8; 64];
                        p.read(&lease, &mut out);
                        assert!(
                            out.iter().all(|&x| x == pattern),
                            "buffer shared while leased"
                        );
                        p.release(lease);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(p.available(), 8);
    }
}
