//! Domains, nodes and reliable run-up/run-down.
//!
//! MRAPI organises resources under *domains* containing *nodes* (tasks
//! mapped to OS processes/threads). Refactoring step 4 of the paper:
//! "Ensure all runtime access to communication metadata is done with
//! atomic operations to allow reliable node run-up and rundown" — node
//! lifecycle states here are an [`AtomicFsm`] so concurrent init/finalize
//! races resolve deterministically.

use crate::lockfree::fsm::AtomicFsm;
use crate::lockfree::mem::World;

/// Node lifecycle states (FSM).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u32)]
pub enum NodeState {
    /// Slot unused.
    Absent = 0,
    /// `node_init` in progress.
    Initializing = 1,
    /// Fully running.
    Running = 2,
    /// `node_finalize` in progress.
    Finalizing = 3,
}

/// A domain: a namespace of nodes with an access policy boundary (the
/// paper notes security benefits of authenticating cross-domain access).
pub struct Domain<W: World> {
    /// Domain identifier.
    pub id: u32,
    nodes: Vec<AtomicFsm<W>>,
}

impl<W: World> Domain<W> {
    /// Domain with capacity for `max_nodes` nodes.
    pub fn new(id: u32, max_nodes: usize) -> Self {
        Domain {
            id,
            nodes: (0..max_nodes).map(|_| AtomicFsm::new(NodeState::Absent as u32)).collect(),
        }
    }

    /// Capacity.
    pub fn max_nodes(&self) -> usize {
        self.nodes.len()
    }

    /// Run-up: claim `node` and bring it to `Running`. Fails if the slot
    /// is not `Absent` (duplicate init or mid-rundown).
    pub fn node_init(&self, node: usize) -> Result<(), NodeState> {
        let fsm = &self.nodes[node];
        fsm.transition(NodeState::Absent as u32, NodeState::Initializing as u32)
            .map_err(decode)?;
        // Metadata publication would happen here; mark fully running.
        fsm.transition_exact(NodeState::Initializing as u32, NodeState::Running as u32);
        Ok(())
    }

    /// Run-down: take `node` from `Running` back to `Absent`.
    pub fn node_finalize(&self, node: usize) -> Result<(), NodeState> {
        let fsm = &self.nodes[node];
        fsm.transition(NodeState::Running as u32, NodeState::Finalizing as u32)
            .map_err(decode)?;
        fsm.transition_exact(NodeState::Finalizing as u32, NodeState::Absent as u32);
        Ok(())
    }

    /// Current state of `node`.
    pub fn node_state(&self, node: usize) -> NodeState {
        decode_state(self.nodes[node].state())
    }

    /// Count of running nodes.
    pub fn running(&self) -> usize {
        self.nodes
            .iter()
            .filter(|f| f.state() == NodeState::Running as u32)
            .count()
    }
}

fn decode_state(v: u32) -> NodeState {
    match v {
        0 => NodeState::Absent,
        1 => NodeState::Initializing,
        2 => NodeState::Running,
        3 => NodeState::Finalizing,
        _ => unreachable!("invalid node state {v}"),
    }
}

fn decode(v: u32) -> NodeState {
    decode_state(v)
}

/// Registry of domains (the process-wide MRAPI database slice).
pub struct NodeRegistry<W: World> {
    domains: Vec<Domain<W>>,
}

impl<W: World> NodeRegistry<W> {
    /// `domains` domains of `max_nodes` each, ids 0..domains.
    pub fn new(domains: usize, max_nodes: usize) -> Self {
        NodeRegistry {
            domains: (0..domains).map(|d| Domain::new(d as u32, max_nodes)).collect(),
        }
    }

    /// Access a domain.
    pub fn domain(&self, id: usize) -> &Domain<W> {
        &self.domains[id]
    }

    /// Total running nodes across domains.
    pub fn total_running(&self) -> usize {
        self.domains.iter().map(|d| d.running()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::Arc;

    #[test]
    fn init_finalize_cycle() {
        let d = Domain::<RealWorld>::new(0, 4);
        assert_eq!(d.node_state(1), NodeState::Absent);
        d.node_init(1).unwrap();
        assert_eq!(d.node_state(1), NodeState::Running);
        assert_eq!(d.running(), 1);
        d.node_finalize(1).unwrap();
        assert_eq!(d.node_state(1), NodeState::Absent);
    }

    #[test]
    fn duplicate_init_rejected() {
        let d = Domain::<RealWorld>::new(0, 2);
        d.node_init(0).unwrap();
        assert_eq!(d.node_init(0), Err(NodeState::Running));
    }

    #[test]
    fn finalize_absent_rejected() {
        let d = Domain::<RealWorld>::new(0, 2);
        assert_eq!(d.node_finalize(0), Err(NodeState::Absent));
    }

    #[test]
    fn concurrent_init_single_winner() {
        let d = Arc::new(Domain::<RealWorld>::new(0, 1));
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let d = d.clone();
                std::thread::spawn(move || d.node_init(0).is_ok() as u32)
            })
            .collect();
        let winners: u32 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        assert_eq!(winners, 1);
        assert_eq!(d.running(), 1);
    }

    #[test]
    fn registry_counts_across_domains() {
        let r = NodeRegistry::<RealWorld>::new(2, 2);
        r.domain(0).node_init(0).unwrap();
        r.domain(1).node_init(1).unwrap();
        assert_eq!(r.total_running(), 2);
        assert_eq!(r.domain(0).id, 0);
        assert_eq!(r.domain(1).id, 1);
    }

    #[test]
    fn concurrent_init_finalize_churn_is_consistent() {
        let d = Arc::new(Domain::<RealWorld>::new(0, 4));
        let handles: Vec<_> = (0..4)
            .map(|node| {
                let d = d.clone();
                std::thread::spawn(move || {
                    for _ in 0..5_000 {
                        d.node_init(node).unwrap();
                        d.node_finalize(node).unwrap();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(d.running(), 0);
    }
}
