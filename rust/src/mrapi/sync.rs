//! MRAPI user-mode synchronization: mutexes and counting semaphores.
//!
//! "User-mode mutexes, semaphores and reader/writer locks are built on top
//! of this base" (the SysVR4-style kernel lock). These are the primitives
//! the lock-based MCAPI baseline and application code use; the lock-free
//! refactoring removes them from the data path but node run-up/run-down
//! still relies on them.

use crate::lockfree::mem::{Atom32, KernelLock, World};

/// User-mode mutex over the world's kernel lock.
pub struct Mutex<W: World> {
    kernel: W::Lock,
    held: W::U32,
}

impl<W: World> Default for Mutex<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W: World> Mutex<W> {
    /// New, unheld.
    pub fn new() -> Self {
        Mutex { kernel: W::Lock::new(), held: W::U32::new(0) }
    }

    /// Acquire.
    pub fn lock(&self) {
        loop {
            self.kernel.acquire();
            if self.held.load() == 0 {
                self.held.store(1);
                self.kernel.release();
                return;
            }
            self.kernel.release();
            W::yield_now();
        }
    }

    /// Try to acquire without blocking.
    pub fn try_lock(&self) -> bool {
        self.kernel.acquire();
        let free = self.held.load() == 0;
        if free {
            self.held.store(1);
        }
        self.kernel.release();
        free
    }

    /// Release.
    pub fn unlock(&self) {
        self.kernel.acquire();
        assert_eq!(self.held.load(), 1, "unlock of unheld mutex");
        self.held.store(0);
        self.kernel.release();
    }

    /// Run `f` under the mutex.
    pub fn with<R>(&self, f: impl FnOnce() -> R) -> R {
        self.lock();
        let r = f();
        self.unlock();
        r
    }
}

/// Counting semaphore built on the kernel lock (SysVR4 `semop` shape).
pub struct Semaphore<W: World> {
    kernel: W::Lock,
    count: W::U32,
}

impl<W: World> Semaphore<W> {
    /// New with `initial` permits.
    pub fn new(initial: u32) -> Self {
        Semaphore { kernel: W::Lock::new(), count: W::U32::new(initial) }
    }

    /// Acquire one permit, blocking (spin+yield) until available.
    pub fn wait(&self) {
        loop {
            if self.try_wait() {
                return;
            }
            W::yield_now();
        }
    }

    /// Try to acquire a permit.
    pub fn try_wait(&self) -> bool {
        self.kernel.acquire();
        let c = self.count.load();
        let ok = c > 0;
        if ok {
            self.count.store(c - 1);
        }
        self.kernel.release();
        ok
    }

    /// Release one permit.
    pub fn post(&self) {
        self.kernel.acquire();
        let c = self.count.load();
        self.count.store(c + 1);
        self.kernel.release();
    }

    /// Current permit count (racy snapshot).
    pub fn permits(&self) -> u32 {
        self.count.load()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::mem::RealWorld;
    use std::sync::atomic::{AtomicU32, Ordering};
    use std::sync::Arc;

    #[test]
    fn mutex_excludes() {
        let m = Arc::new(Mutex::<RealWorld>::new());
        let v = Arc::new(AtomicU32::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let m = m.clone();
                let v = v.clone();
                std::thread::spawn(move || {
                    for _ in 0..2_000 {
                        m.with(|| {
                            let x = v.load(Ordering::Relaxed);
                            v.store(x + 1, Ordering::Relaxed);
                        });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(v.load(Ordering::Relaxed), 8_000);
    }

    #[test]
    fn try_lock_fails_when_held() {
        let m = Mutex::<RealWorld>::new();
        assert!(m.try_lock());
        assert!(!m.try_lock());
        m.unlock();
        assert!(m.try_lock());
        m.unlock();
    }

    #[test]
    #[should_panic(expected = "unlock of unheld")]
    fn unbalanced_unlock_panics() {
        Mutex::<RealWorld>::new().unlock();
    }

    #[test]
    fn semaphore_counts() {
        let s = Semaphore::<RealWorld>::new(2);
        assert!(s.try_wait());
        assert!(s.try_wait());
        assert!(!s.try_wait());
        s.post();
        assert!(s.try_wait());
        assert_eq!(s.permits(), 0);
    }

    #[test]
    fn semaphore_bounds_concurrency() {
        let s = Arc::new(Semaphore::<RealWorld>::new(2));
        let inside = Arc::new(AtomicU32::new(0));
        let peak = Arc::new(AtomicU32::new(0));
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let s = s.clone();
                let inside = inside.clone();
                let peak = peak.clone();
                std::thread::spawn(move || {
                    for _ in 0..500 {
                        s.wait();
                        let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        inside.fetch_sub(1, Ordering::SeqCst);
                        s.post();
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "semaphore over-admitted");
        assert_eq!(s.permits(), 2);
    }
}
