//! The MRAPI metadata resource tree.
//!
//! "Finally metadata management, including filtered resource trees and
//! change triggered actions, is provided." Resources (nodes, endpoints,
//! channels, buffers) hang off a tree; views can be filtered by kind and
//! registered callbacks fire on attribute changes.

use std::collections::BTreeMap;
use std::sync::Mutex;

/// Kinds of resources tracked in the tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum ResourceKind {
    /// MRAPI domain.
    Domain,
    /// MRAPI node.
    Node,
    /// MCAPI endpoint.
    Endpoint,
    /// MCAPI channel.
    Channel,
    /// Shared-memory buffer pool.
    BufferPool,
}

/// One resource entry.
#[derive(Debug, Clone)]
pub struct Resource {
    /// Kind.
    pub kind: ResourceKind,
    /// Display name.
    pub name: String,
    /// Parent id (0 = root).
    pub parent: u64,
    /// Attribute map.
    pub attrs: BTreeMap<String, i64>,
}

type Trigger = Box<dyn Fn(u64, &str, i64) + Send>;

/// Tree of resources with filtered iteration and change triggers.
///
/// Metadata operations are control-plane (node bring-up, tooling), not the
/// data path, so an ordinary mutex is appropriate here — the paper removed
/// locks from the *exchange* path, not from management metadata.
pub struct ResourceTree {
    inner: Mutex<Inner>,
}

#[derive(Default)]
struct Inner {
    next_id: u64,
    entries: BTreeMap<u64, Resource>,
    triggers: Vec<(u64, String, Trigger)>,
}

impl Default for ResourceTree {
    fn default() -> Self {
        Self::new()
    }
}

impl ResourceTree {
    /// Empty tree.
    pub fn new() -> Self {
        ResourceTree { inner: Mutex::new(Inner { next_id: 1, ..Default::default() }) }
    }

    /// Register a resource; returns its id.
    pub fn add(&self, kind: ResourceKind, name: &str, parent: u64) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        inner.entries.insert(
            id,
            Resource { kind, name: to_owned(name), parent, attrs: BTreeMap::new() },
        );
        id
    }

    /// Remove a resource and its descendants; returns how many were removed.
    pub fn remove(&self, id: u64) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let mut doomed = vec![id];
        let mut i = 0;
        while i < doomed.len() {
            let parent = doomed[i];
            doomed.extend(
                inner
                    .entries
                    .iter()
                    .filter(|(_, r)| r.parent == parent)
                    .map(|(&cid, _)| cid),
            );
            i += 1;
        }
        let mut removed = 0;
        for d in doomed {
            removed += inner.entries.remove(&d).is_some() as usize;
        }
        removed
    }

    /// Set an attribute, firing any matching change triggers.
    pub fn set_attr(&self, id: u64, key: &str, value: i64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        let Some(r) = inner.entries.get_mut(&id) else {
            return false;
        };
        r.attrs.insert(to_owned(key), value);
        // Collect matching triggers, then fire outside the entry borrow.
        let fires: Vec<usize> = inner
            .triggers
            .iter()
            .enumerate()
            .filter(|(_, (tid, tkey, _))| *tid == id && tkey == key)
            .map(|(i, _)| i)
            .collect();
        for i in fires {
            let (tid, tkey, cb) = &inner.triggers[i];
            debug_assert_eq!(*tid, id);
            cb(id, tkey, value);
        }
        true
    }

    /// Read an attribute.
    pub fn attr(&self, id: u64, key: &str) -> Option<i64> {
        self.inner.lock().unwrap().entries.get(&id)?.attrs.get(key).copied()
    }

    /// Register a change trigger on `(id, key)`.
    pub fn on_change(&self, id: u64, key: &str, cb: impl Fn(u64, &str, i64) + Send + 'static) {
        self.inner
            .lock()
            .unwrap()
            .triggers
            .push((id, to_owned(key), Box::new(cb)));
    }

    /// Snapshot of resources of `kind` (filtered view).
    pub fn filtered(&self, kind: ResourceKind) -> Vec<(u64, Resource)> {
        self.inner
            .lock()
            .unwrap()
            .entries
            .iter()
            .filter(|(_, r)| r.kind == kind)
            .map(|(&id, r)| (id, r.clone()))
            .collect()
    }

    /// Total resources.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

fn to_owned(s: &str) -> String {
    s.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicI64, Ordering};
    use std::sync::Arc;

    #[test]
    fn add_and_filter() {
        let t = ResourceTree::new();
        let d = t.add(ResourceKind::Domain, "d0", 0);
        let n = t.add(ResourceKind::Node, "n0", d);
        t.add(ResourceKind::Endpoint, "ep0", n);
        t.add(ResourceKind::Endpoint, "ep1", n);
        assert_eq!(t.filtered(ResourceKind::Endpoint).len(), 2);
        assert_eq!(t.filtered(ResourceKind::Node).len(), 1);
        assert_eq!(t.len(), 4);
    }

    #[test]
    fn remove_cascades_to_descendants() {
        let t = ResourceTree::new();
        let d = t.add(ResourceKind::Domain, "d0", 0);
        let n = t.add(ResourceKind::Node, "n0", d);
        t.add(ResourceKind::Endpoint, "ep0", n);
        assert_eq!(t.remove(d), 3);
        assert!(t.is_empty());
    }

    #[test]
    fn attrs_roundtrip() {
        let t = ResourceTree::new();
        let n = t.add(ResourceKind::Node, "n", 0);
        assert!(t.set_attr(n, "priority", 7));
        assert_eq!(t.attr(n, "priority"), Some(7));
        assert_eq!(t.attr(n, "missing"), None);
        assert!(!t.set_attr(999, "x", 0));
    }

    #[test]
    fn change_trigger_fires() {
        let t = ResourceTree::new();
        let n = t.add(ResourceKind::Node, "n", 0);
        let seen = Arc::new(AtomicI64::new(0));
        let seen2 = seen.clone();
        t.on_change(n, "qdepth", move |_, _, v| {
            seen2.store(v, Ordering::SeqCst);
        });
        t.set_attr(n, "qdepth", 42);
        assert_eq!(seen.load(Ordering::SeqCst), 42);
        // Different key: no fire.
        t.set_attr(n, "other", 1);
        assert_eq!(seen.load(Ordering::SeqCst), 42);
    }
}
