//! The stress-test coordinator (Section 4) and experiment matrix
//! (Section 6).
//!
//! * [`topology`] — declarative message topologies: channels between
//!   nodes with a type (message/packet/scalar) and a transaction count,
//!   parseable from the TOML-subset config format.
//! * [`metrics`] — throughput/latency/yield accounting per channel and
//!   aggregated per run.
//! * [`runner`] — the paper's processing routine: one task per node,
//!   nested dispatch over configured channels, transaction IDs tracked to
//!   completion, yield on `WouldBlock`; drivers for both the real host
//!   and the deterministic SMP simulator.
//! * [`experiment`] — the Section 6 test matrix (OS profile × cores ×
//!   message type × backend × affinity) and the Table 2 / Figure 7 /
//!   Figure 8 report generators.
//! * [`chaos`] — fault-injection harness: stress workloads under
//!   deterministic kills/stalls with recovery-invariant checking and
//!   reproducible per-seed reports (seeded mode + kill/stall sweeps).
//! * [`mpmc`] — the N×M multi-consumer harness: producers fan work into
//!   one MPMC endpoint, a consumer group drains it, exactly-once judged
//!   under fault-free, seeded-chaos and kill-sweep modes.
//! * [`trace`] — the same drivers with the [`crate::obs`] plane armed:
//!   drained stage-latency histograms, trace exporters, and the
//!   event-stream replay verdict.
//! * [`abandon`] — the real-thread abandonment harness: OS threads that
//!   park forever mid-operation on `RealWorld`, recovered end-to-end by
//!   the armed heartbeat watchdog with **zero** explicit
//!   `declare_node_dead` calls, judged by the same
//!   no-loss/no-dup/no-leak invariants.

pub mod abandon;
pub mod chaos;
pub mod experiment;
pub mod metrics;
pub mod mpmc;
pub mod runner;
pub mod topology;
pub mod trace;

pub use abandon::{run_abandon, run_abandon_seeded, AbandonOpts, AbandonRole};
pub use chaos::{
    run_delay_sweep, run_kill_sweep, run_seeded, run_stall_sweep, ChaosOpts, ChaosReport,
    Scenario, Victim,
};
pub use mpmc::{
    run_mpmc_chaos, run_mpmc_kill_sweep, run_mpmc_skewed, run_mpmc_steal_kill_sweep,
    run_mpmc_steal_storm, run_mpmc_stress, run_mpmc_two_victims, MpmcOpts, MpmcReport,
};
pub use experiment::{Cell, CellResult, Matrix};
pub use metrics::StressReport;
pub use runner::{run_pingpong_real, run_pingpong_sim, run_stress_real, run_stress_sim, StressOpts};
pub use topology::{ChannelSpec, MsgKind, Topology};
pub use trace::{run_traced_chaos, run_traced_stress, TraceOpts, TraceRun};
