//! The stress-test node routine and its two drivers (real host / SMP sim).
//!
//! One task per node, the Section 4 processing loop: set up all channels,
//! then iterate round-robin — senders transmit transaction IDs 1..=count,
//! receivers track them to completion, everybody yields on `WouldBlock`.
//! The loop exits when every send channel has transmitted its last ID and
//! every receive channel has accepted it.

use std::sync::{Arc, Mutex};

use crate::lockfree::mem::{Atom32, RealWorld, World};
use crate::mcapi::types::{RuntimeCfg, Status};
use crate::mcapi::McapiRuntime;
use crate::sim::{Machine, SimWorld};
use crate::util::histogram::Histogram;

use super::metrics::StressReport;
use super::topology::{ChannelSpec, MsgKind, Topology};

/// Stress options.
#[derive(Debug, Clone, Copy)]
pub struct StressOpts {
    /// Payload bytes for messages/packets (paper: "typical message and
    /// packet sizes are around twenty four bytes").
    pub payload_len: usize,
    /// Payloads moved per API call: 1 = the paper's scalar loop; > 1
    /// drives the batched runtime paths — `msg_send_batch`/`msg_recv_batch`
    /// for connection-less messages, `pkt_send_batch`/`pkt_recv_batch`
    /// and `sclr_send_batch`/`sclr_recv_batch` for connected channels
    /// (amortized counter stores on the ring fast path). *State*
    /// channels ignore this (newest-wins has no batch semantics).
    pub batch: usize,
}

impl Default for StressOpts {
    fn default() -> Self {
        StressOpts { payload_len: 24, batch: 1 }
    }
}

impl StressOpts {
    /// Default options with a message batch size.
    pub fn with_batch(batch: usize) -> Self {
        StressOpts { batch: batch.max(1), ..Default::default() }
    }
}

const MAGIC: u64 = 0x4D43_4150_4921_2014; // "MCAPI!" 2014

fn encode(tx: u64, stamp: u64, buf: &mut [u8]) {
    buf[0..8].copy_from_slice(&tx.to_le_bytes());
    buf[8..16].copy_from_slice(&stamp.to_le_bytes());
    let sum = tx ^ stamp ^ MAGIC;
    buf[16..24].copy_from_slice(&sum.to_le_bytes());
}

fn decode(buf: &[u8]) -> Option<(u64, u64)> {
    let tx = u64::from_le_bytes(buf[0..8].try_into().ok()?);
    let stamp = u64::from_le_bytes(buf[8..16].try_into().ok()?);
    let sum = u64::from_le_bytes(buf[16..24].try_into().ok()?);
    (tx ^ stamp ^ MAGIC == sum).then_some((tx, stamp))
}

/// Decode a *received* payload of `n` bytes. A short receive (`n` < the
/// 24-byte frame) is corruption — the stale tail of the receive buffer
/// must never be decoded as if the wire had produced it.
fn decode_received(buf: &[u8], n: usize) -> Option<(u64, u64)> {
    if n < 24 {
        return None;
    }
    decode(&buf[..n])
}

/// Cross-task rendezvous board: per-channel readiness flags and the
/// channel-table index chosen by the connecting sender. Built on world
/// atoms so waiting charges simulated time correctly.
struct Board<W: World> {
    rx_ready: Vec<W::U32>,
    rx_open: Vec<W::U32>,
    ch_index: Vec<W::U32>,
}

impl<W: World> Board<W> {
    fn new(channels: usize) -> Self {
        Board {
            rx_ready: (0..channels).map(|_| W::U32::new(0)).collect(),
            rx_open: (0..channels).map(|_| W::U32::new(0)).collect(),
            ch_index: (0..channels).map(|_| W::U32::new(0)).collect(),
        }
    }
}

struct Plan {
    /// Topology node id (kept for diagnostics).
    #[allow(dead_code)]
    node: u16,
    dense: usize,
    sends: Vec<(usize, ChannelSpec)>,
    recvs: Vec<(usize, ChannelSpec)>,
}

fn make_plans(topo: &Topology) -> Vec<Plan> {
    let nodes = topo.nodes();
    nodes
        .iter()
        .enumerate()
        .map(|(dense, &node)| Plan {
            node,
            dense,
            sends: topo
                .channels
                .iter()
                .enumerate()
                .filter(|(_, c)| c.from.0 == node)
                .map(|(i, c)| (i, *c))
                .collect(),
            recvs: topo
                .channels
                .iter()
                .enumerate()
                .filter(|(_, c)| c.to.0 == node)
                .map(|(i, c)| (i, *c))
                .collect(),
        })
        .collect()
}

struct ChannelOutcome {
    delivered: u64,
    latency: Histogram,
    order_violations: u64,
}

/// Per-node results accumulated by the driver.
#[derive(Default)]
struct NodeOutcome {
    yields: u64,
    recv: Vec<ChannelOutcome>,
}

/// The Section 4 processing routine for one node.
fn node_task<W: World>(
    rt: &McapiRuntime<W>,
    board: &Board<W>,
    plan: &Plan,
    opts: StressOpts,
) -> NodeOutcome {
    use crate::mcapi::types::ChannelKind;

    // --- setup: create my endpoints; receivers announce, senders connect.
    let mut recv_eps = Vec::new(); // (ci, spec, ep index)
    for (ci, spec) in &plan.recvs {
        let ep = rt
            .create_endpoint(spec.rx_endpoint(), plan.dense)
            .expect("create rx endpoint");
        recv_eps.push((*ci, *spec, ep));
        board.rx_ready[*ci].store(1);
    }
    let mut send_chs = Vec::new(); // (ci, spec, Option<channel index>)
    for (ci, spec) in &plan.sends {
        match spec.kind {
            MsgKind::Message => {
                // Connectionless: wait for the receive endpoint to appear.
                while board.rx_ready[*ci].load() == 0 {
                    W::yield_now();
                }
                send_chs.push((*ci, *spec, None));
            }
            MsgKind::Packet | MsgKind::Scalar | MsgKind::State => {
                let kind = match spec.kind {
                    MsgKind::Packet => ChannelKind::Packet,
                    MsgKind::Scalar => ChannelKind::Scalar,
                    _ => ChannelKind::State,
                };
                rt.create_endpoint(spec.tx_endpoint(), plan.dense)
                    .expect("create tx endpoint");
                while board.rx_ready[*ci].load() == 0 {
                    W::yield_now();
                }
                let ch = rt
                    .connect(spec.tx_endpoint(), spec.rx_endpoint(), kind)
                    .expect("connect channel");
                rt.open_send(ch).expect("open send side");
                board.ch_index[*ci].store(ch as u32 + 1);
                send_chs.push((*ci, *spec, Some(ch)));
            }
        }
    }
    // Receivers of connected channels: learn the index, open, announce.
    let mut recv_chs = Vec::new(); // (spec, ep, Option<ch>)
    for (ci, spec, ep) in &recv_eps {
        if spec.kind == MsgKind::Message {
            board.rx_open[*ci].store(1);
            recv_chs.push((*spec, *ep, None));
        } else {
            while board.ch_index[*ci].load() == 0 {
                W::yield_now();
            }
            let ch = board.ch_index[*ci].load() as usize - 1;
            rt.open_recv(ch).expect("open recv side");
            board.rx_open[*ci].store(1);
            recv_chs.push((*spec, *ep, Some(ch)));
        }
    }
    // Senders wait until the receive side is open (connected kinds).
    for (ci, spec, _) in &send_chs {
        if *spec != plan.sends.iter().find(|(i, _)| i == ci).unwrap().1 {
            unreachable!();
        }
        while board.rx_open[*ci].load() == 0 {
            W::yield_now();
        }
    }

    // --- measurement loop.
    let mut yields = 0u64;
    let mut next_tx: Vec<u64> = send_chs.iter().map(|_| 1).collect();
    let mut recv_state: Vec<(u64, ChannelOutcome)> = recv_chs
        .iter()
        .map(|_| {
            (1u64, ChannelOutcome { delivered: 0, latency: Histogram::new(), order_violations: 0 })
        })
        .collect();
    let mut buf = vec![0u8; opts.payload_len.max(24)];

    let mut batch_bufs: Vec<Vec<u8>> = Vec::new();
    let mut batch_msgs: Vec<Vec<u8>> = Vec::new();
    let mut batch_sclr_tx: Vec<u64> = Vec::new();
    let mut batch_sclr_rx: Vec<u64> = Vec::new();

    loop {
        let mut all_done = true;
        // Send dispatch.
        for (si, (_ci, spec, ch)) in send_chs.iter().enumerate() {
            if next_tx[si] > spec.count {
                continue;
            }
            all_done = false;
            let now = W::now_ns();
            // Batched paths: stamp and ship up to `batch` pending
            // transaction IDs in one runtime call (messages, packets and
            // scalars; state channels have no batch semantics).
            if opts.batch > 1 && spec.kind != MsgKind::State {
                let remaining = spec.count - next_tx[si] + 1;
                let k = remaining.min(opts.batch as u64) as usize;
                let result = match spec.kind {
                    MsgKind::Message | MsgKind::Packet => {
                        batch_bufs.resize_with(k, Vec::new);
                        for (i, b) in batch_bufs.iter_mut().enumerate() {
                            b.resize(opts.payload_len.max(24), 0);
                            encode(next_tx[si] + i as u64, now, b);
                        }
                        let refs: Vec<&[u8]> = batch_bufs.iter().map(|b| b.as_slice()).collect();
                        if spec.kind == MsgKind::Message {
                            rt.msg_send_batch(plan.dense, spec.rx_endpoint(), &refs, 0)
                        } else {
                            rt.pkt_send_batch(ch.unwrap(), &refs)
                        }
                    }
                    MsgKind::Scalar => {
                        batch_sclr_tx.clear();
                        batch_sclr_tx.resize(k, now);
                        rt.sclr_send_batch(ch.unwrap(), &batch_sclr_tx)
                    }
                    MsgKind::State => unreachable!("state channels are not batched"),
                };
                match result {
                    Ok(n) => next_tx[si] += n as u64,
                    Err(Status::WouldBlock)
                    | Err(Status::WouldBlockPeerActive)
                    | Err(Status::MemLimit) => {
                        yields += 1;
                        W::yield_now();
                    }
                    Err(e) => panic!("batch send failed on channel {spec:?}: {e:?}"),
                }
                continue;
            }
            let result = match spec.kind {
                MsgKind::Message => {
                    encode(next_tx[si], now, &mut buf);
                    rt.msg_send(plan.dense, spec.rx_endpoint(), &buf[..opts.payload_len.max(24)], 0)
                }
                MsgKind::Packet => {
                    encode(next_tx[si], now, &mut buf);
                    rt.pkt_send(ch.unwrap(), &buf[..opts.payload_len.max(24)])
                }
                MsgKind::Scalar => rt.sclr_send(ch.unwrap(), now),
                // State: newest-wins publication; never blocks. Pack the
                // transaction id into the low 20 bits of the stamp.
                MsgKind::State => {
                    rt.state_send(ch.unwrap(), (now << 20) | (next_tx[si] & 0xF_FFFF))
                }
            };
            match result {
                Ok(()) => next_tx[si] += 1,
                Err(Status::WouldBlock)
                | Err(Status::WouldBlockPeerActive)
                | Err(Status::MemLimit) => {
                    yields += 1;
                    W::yield_now();
                }
                Err(e) => panic!("send failed on channel {spec:?}: {e:?}"),
            }
        }
        // Receive dispatch.
        for (ri, (spec, ep, ch)) in recv_chs.iter().enumerate() {
            let (expect, outcome) = &mut recv_state[ri];
            if *expect > spec.count {
                continue;
            }
            all_done = false;
            // Batched paths: drain up to `batch` in one call.
            if opts.batch > 1 && spec.kind != MsgKind::State {
                if spec.kind == MsgKind::Scalar {
                    batch_sclr_rx.clear();
                    match rt.sclr_recv_batch(ch.unwrap(), &mut batch_sclr_rx, opts.batch) {
                        Ok(_) => {
                            let now = W::now_ns();
                            for &stamp in &batch_sclr_rx {
                                outcome.latency.record(now.saturating_sub(stamp));
                                outcome.delivered += 1;
                                *expect += 1;
                            }
                        }
                        Err(Status::WouldBlock) | Err(Status::WouldBlockPeerActive) => {
                            yields += 1;
                            W::yield_now();
                        }
                        Err(e) => panic!("batch recv failed on channel {spec:?}: {e:?}"),
                    }
                    continue;
                }
                batch_msgs.clear();
                let r = if spec.kind == MsgKind::Message {
                    rt.msg_recv_batch(*ep, &mut batch_msgs, opts.batch)
                } else {
                    rt.pkt_recv_batch(ch.unwrap(), &mut batch_msgs, opts.batch)
                };
                match r {
                    Ok(_) => {
                        let now = W::now_ns();
                        for msg in &batch_msgs {
                            let (tx, stamp) = decode_received(msg, msg.len())
                                .expect("short or corrupted payload");
                            if tx != *expect {
                                outcome.order_violations += 1;
                            }
                            outcome.latency.record(now.saturating_sub(stamp));
                            outcome.delivered += 1;
                            *expect += 1;
                        }
                    }
                    Err(Status::WouldBlock) | Err(Status::WouldBlockPeerActive) => {
                        yields += 1;
                        W::yield_now();
                    }
                    Err(e) => panic!("batch recv failed on channel {spec:?}: {e:?}"),
                }
                continue;
            }
            let result: Result<(u64, u64), Status> = match spec.kind {
                MsgKind::Message => rt.msg_recv(*ep, &mut buf).map(|n| {
                    decode_received(&buf, n).expect("short or corrupted message payload")
                }),
                MsgKind::Packet => rt.pkt_recv(ch.unwrap(), &mut buf).map(|n| {
                    decode_received(&buf, n).expect("short or corrupted packet payload")
                }),
                MsgKind::Scalar => rt.sclr_recv(ch.unwrap()).map(|stamp| (*expect, stamp)),
                MsgKind::State => rt
                    .state_recv(ch.unwrap())
                    .map(|packed| (packed & 0xF_FFFF, packed >> 20)),
            };
            match result {
                Ok((tx, stamp)) if spec.kind == MsgKind::State => {
                    // State semantics: values may be skipped (newest wins);
                    // completion = observing the final transaction. Only
                    // *fresh* observations count as deliveries.
                    if tx >= *expect {
                        let now = W::now_ns();
                        outcome.latency.record(now.saturating_sub(stamp));
                        outcome.delivered += 1;
                        *expect = tx + 1; // next fresh value
                    } else {
                        yields += 1;
                        W::yield_now();
                    }
                }
                Ok((tx, stamp)) => {
                    let now = W::now_ns();
                    if tx != *expect {
                        outcome.order_violations += 1;
                    }
                    outcome.latency.record(now.saturating_sub(stamp));
                    outcome.delivered += 1;
                    *expect += 1;
                }
                Err(Status::WouldBlock) | Err(Status::WouldBlockPeerActive) => {
                    yields += 1;
                    W::yield_now();
                }
                Err(e) => panic!("recv failed on channel {spec:?}: {e:?}"),
            }
        }
        if all_done {
            break;
        }
    }

    NodeOutcome { yields, recv: recv_state.into_iter().map(|(_, o)| o).collect() }
}

fn aggregate(outcomes: Vec<NodeOutcome>, elapsed_ns: u64, sim: Option<crate::sim::MachineStats>) -> StressReport {
    let mut latency = Histogram::new();
    let mut delivered = 0;
    let mut yields = 0;
    let mut order_violations = 0;
    for o in outcomes {
        yields += o.yields;
        for c in o.recv {
            delivered += c.delivered;
            order_violations += c.order_violations;
            latency.merge(&c.latency);
        }
    }
    StressReport {
        delivered,
        elapsed_ns,
        latency,
        yields,
        order_violations,
        // Robustness counters are runtime-wide; the run_stress_* drivers
        // fill them from the runtime after aggregation.
        timeouts: 0,
        poisons: 0,
        leases_reclaimed: 0,
        sim,
    }
}

/// Copy the runtime-wide robustness counters into a report.
fn fill_robustness<W: World>(report: &mut StressReport, rt: &McapiRuntime<W>) {
    report.timeouts = rt.timeouts_observed();
    report.poisons = rt.poisons_observed();
    report.leases_reclaimed = rt.leases_reclaimed();
}

/// Run a topology on the real host with OS threads.
pub fn run_stress_real(cfg: RuntimeCfg, topo: &Topology, opts: StressOpts) -> StressReport {
    let rt = McapiRuntime::<RealWorld>::new(cfg);
    let board = Arc::new(Board::<RealWorld>::new(topo.channels.len()));
    let plans = make_plans(topo);
    let results = Arc::new(Mutex::new(Vec::new()));
    let start = std::time::Instant::now();
    let handles: Vec<_> = plans
        .into_iter()
        .map(|plan| {
            let rt = rt.clone();
            let board = board.clone();
            let results = results.clone();
            std::thread::spawn(move || {
                let out = node_task(&rt, &board, &plan, opts);
                results.lock().unwrap().push(out);
            })
        })
        .collect();
    for h in handles {
        h.join().expect("stress node panicked");
    }
    let elapsed_ns = start.elapsed().as_nanos() as u64;
    let outcomes = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    let mut report = aggregate(outcomes, elapsed_ns, None);
    fill_robustness(&mut report, &rt);
    report
}

/// Run a topology on the deterministic SMP simulator.
pub fn run_stress_sim(machine: &Machine, cfg: RuntimeCfg, topo: &Topology, opts: StressOpts) -> StressReport {
    let rt = McapiRuntime::<SimWorld>::new(cfg);
    let board = Arc::new(Board::<SimWorld>::new(topo.channels.len()));
    let plans = make_plans(topo);
    let results = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = plans
        .into_iter()
        .map(|plan| {
            let rt = rt.clone();
            let board = board.clone();
            let results = results.clone();
            machine.spawn(move || {
                let out = node_task(&rt, &board, &plan, opts);
                results.lock().unwrap().push(out);
            })
        })
        .collect();
    let stats = machine.run(handles);
    let outcomes = Arc::try_unwrap(results).ok().unwrap().into_inner().unwrap();
    let mut report = aggregate(outcomes, stats.virtual_ns, Some(stats));
    fill_robustness(&mut report, &rt);
    report
}

// ---------------------------------------------------------------------------
// Ping-pong latency (one outstanding message) — the Figure 8 measurement.
// ---------------------------------------------------------------------------

/// One-way latency via request/response with a single outstanding
/// transaction: node 0 stamps and sends on the forward channel, node 1
/// echoes the stamp back, node 0 records RTT/2. This isolates the
/// *per-exchange* cost from queueing (Little's law) effects — with the
/// streaming stress, saturated queues make latency track 1/throughput and
/// the paper's 25x lock-removal speedup would be invisible.
fn pingpong_task<W: World>(
    rt: &McapiRuntime<W>,
    board: &Board<W>,
    plan: &Plan,
    kind: MsgKind,
    count: u64,
) -> Histogram {
    use crate::mcapi::types::ChannelKind;
    let fwd = plan.sends.first().copied();
    let back = plan.recvs.first().copied();
    let mut latency = Histogram::new();
    // Reuse the regular setup machinery by running a tiny custom loop: the
    // plans here always have exactly one send + one recv channel per node.
    let (sci, sspec) = fwd.expect("pingpong plan has a send channel");
    let (rci, rspec) = back.expect("pingpong plan has a recv channel");

    // Setup (same rendezvous protocol as node_task).
    let rx_ep = rt.create_endpoint(rspec.rx_endpoint(), plan.dense).expect("rx ep");
    board.rx_ready[rci].store(1);
    let mut send_ch = None;
    if kind != MsgKind::Message {
        let ck = if kind == MsgKind::Packet { ChannelKind::Packet } else { ChannelKind::Scalar };
        rt.create_endpoint(sspec.tx_endpoint(), plan.dense).expect("tx ep");
        while board.rx_ready[sci].load() == 0 {
            W::yield_now();
        }
        let ch = rt.connect(sspec.tx_endpoint(), sspec.rx_endpoint(), ck).expect("connect");
        rt.open_send(ch).expect("open send");
        board.ch_index[sci].store(ch as u32 + 1);
        send_ch = Some(ch);
    } else {
        while board.rx_ready[sci].load() == 0 {
            W::yield_now();
        }
    }
    let mut recv_ch = None;
    if kind != MsgKind::Message {
        while board.ch_index[rci].load() == 0 {
            W::yield_now();
        }
        let ch = board.ch_index[rci].load() as usize - 1;
        rt.open_recv(ch).expect("open recv");
        board.rx_open[rci].store(1);
        recv_ch = Some(ch);
    } else {
        board.rx_open[rci].store(1);
    }
    while board.rx_open[sci].load() == 0 {
        W::yield_now();
    }

    let mut buf = [0u8; 24];
    let send = |stamp: u64, tx: u64, buf: &mut [u8; 24]| -> Result<(), Status> {
        match kind {
            MsgKind::Message => {
                encode(tx, stamp, buf);
                rt.msg_send(plan.dense, sspec.rx_endpoint(), buf, 0)
            }
            MsgKind::Packet => {
                encode(tx, stamp, buf);
                rt.pkt_send(send_ch.unwrap(), buf)
            }
            MsgKind::Scalar => rt.sclr_send(send_ch.unwrap(), stamp),
            MsgKind::State => unimplemented!("ping-pong needs FIFO semantics; state channels deliver newest-wins"),
        }
    };
    let recv = |buf: &mut [u8; 24]| -> Result<(u64, u64), Status> {
        match kind {
            MsgKind::Message => rt
                .msg_recv(rx_ep, buf)
                .map(|n| decode_received(&buf[..], n).expect("short or corrupted payload")),
            MsgKind::Packet => rt
                .pkt_recv(recv_ch.unwrap(), buf)
                .map(|n| decode_received(&buf[..], n).expect("short or corrupted payload")),
            MsgKind::Scalar => rt.sclr_recv(recv_ch.unwrap()).map(|stamp| (0, stamp)),
            MsgKind::State => unimplemented!("ping-pong needs FIFO semantics; state channels deliver newest-wins"),
        }
    };

    if plan.dense == 0 {
        // Initiator: stamped ping, await echo, record RTT/2.
        for tx in 1..=count {
            let t0 = W::now_ns();
            let mut v = send(t0, tx, &mut buf);
            while let Err(s) = v {
                assert!(s.is_would_block() || s == Status::MemLimit, "{s:?}");
                W::yield_now();
                v = send(t0, tx, &mut buf);
            }
            loop {
                match recv(&mut buf) {
                    Ok((_, stamp)) => {
                        let rtt = W::now_ns().saturating_sub(stamp);
                        latency.record(rtt / 2);
                        break;
                    }
                    Err(s) if s.is_would_block() => W::yield_now(),
                    Err(s) => panic!("pingpong recv: {s:?}"),
                }
            }
        }
    } else {
        // Echoer: forward every stamp straight back.
        for tx in 1..=count {
            let stamp;
            loop {
                match recv(&mut buf) {
                    Ok((_, s)) => {
                        stamp = s;
                        break;
                    }
                    Err(s) if s.is_would_block() => W::yield_now(),
                    Err(s) => panic!("pingpong recv: {s:?}"),
                }
            }
            let mut v = send(stamp, tx, &mut buf);
            while let Err(s) = v {
                assert!(s.is_would_block() || s == Status::MemLimit, "{s:?}");
                W::yield_now();
                v = send(stamp, tx, &mut buf);
            }
        }
    }
    latency
}

/// Run the ping-pong latency measurement on the simulator; returns the
/// one-way latency histogram (RTT/2 samples) plus machine stats.
pub fn run_pingpong_sim(
    machine: &Machine,
    cfg: RuntimeCfg,
    kind: MsgKind,
    count: u64,
) -> (Histogram, crate::sim::MachineStats) {
    let topo = Topology::ping_pong(kind, count);
    let rt = McapiRuntime::<SimWorld>::new(cfg);
    let board = Arc::new(Board::<SimWorld>::new(topo.channels.len()));
    let plans = make_plans(&topo);
    let results = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = plans
        .into_iter()
        .map(|plan| {
            let rt = rt.clone();
            let board = board.clone();
            let results = results.clone();
            machine.spawn(move || {
                let hist = pingpong_task(&rt, &board, &plan, kind, count);
                results.lock().unwrap().push(hist);
            })
        })
        .collect();
    let stats = machine.run(handles);
    let mut merged = Histogram::new();
    for h in results.lock().unwrap().iter() {
        merged.merge(h);
    }
    (merged, stats)
}

/// Ping-pong latency on the real host.
pub fn run_pingpong_real(cfg: RuntimeCfg, kind: MsgKind, count: u64) -> Histogram {
    let topo = Topology::ping_pong(kind, count);
    let rt = McapiRuntime::<RealWorld>::new(cfg);
    let board = Arc::new(Board::<RealWorld>::new(topo.channels.len()));
    let plans = make_plans(&topo);
    let results = Arc::new(Mutex::new(Vec::new()));
    let handles: Vec<_> = plans
        .into_iter()
        .map(|plan| {
            let rt = rt.clone();
            let board = board.clone();
            let results = results.clone();
            std::thread::spawn(move || {
                let hist = pingpong_task(&rt, &board, &plan, kind, count);
                results.lock().unwrap().push(hist);
            })
        })
        .collect();
    for h in handles {
        h.join().expect("pingpong node panicked");
    }
    let mut merged = Histogram::new();
    for h in results.lock().unwrap().iter() {
        merged.merge(h);
    }
    merged
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mcapi::types::BackendKind;
    use crate::os::{AffinityMode, OsProfile};
    use crate::sim::MachineCfg;

    fn opts() -> StressOpts {
        StressOpts::default()
    }

    #[test]
    fn payload_codec_roundtrip() {
        let mut buf = [0u8; 24];
        encode(42, 12345, &mut buf);
        assert_eq!(decode(&buf), Some((42, 12345)));
        buf[3] ^= 0xFF;
        assert_eq!(decode(&buf), None, "corruption must be detected");
    }

    #[test]
    fn real_one_way_message_both_backends() {
        for backend in [BackendKind::Locked, BackendKind::LockFree] {
            let topo = Topology::one_way(MsgKind::Message, 300);
            let r = run_stress_real(RuntimeCfg::with_backend(backend), &topo, opts());
            assert_eq!(r.delivered, 300, "{backend:?}");
            assert_eq!(r.order_violations, 0, "{backend:?}");
            assert_eq!(r.latency.count(), 300);
        }
    }

    #[test]
    fn real_all_kinds_lockfree() {
        for kind in MsgKind::all() {
            let topo = Topology::one_way(kind, 200);
            let r = run_stress_real(RuntimeCfg::default(), &topo, opts());
            assert_eq!(r.delivered, 200, "{kind:?}");
            assert_eq!(r.order_violations, 0);
        }
    }

    #[test]
    fn real_ping_pong_and_fan_in() {
        let r = run_stress_real(
            RuntimeCfg::default(),
            &Topology::ping_pong(MsgKind::Message, 150),
            opts(),
        );
        assert_eq!(r.delivered, 300);
        let r = run_stress_real(
            RuntimeCfg::default(),
            &Topology::fan_in(3, MsgKind::Message, 100),
            opts(),
        );
        assert_eq!(r.delivered, 300);
        assert_eq!(r.order_violations, 0, "per-producer FIFO must hold under fan-in");
    }

    #[test]
    fn sim_one_way_all_kinds_deterministic() {
        for kind in MsgKind::all() {
            let run = || {
                let m = Machine::new(MachineCfg::new(
                    2,
                    OsProfile::linux_rt(),
                    AffinityMode::PinnedSpread,
                ));
                let topo = Topology::one_way(kind, 100);
                run_stress_sim(&m, RuntimeCfg::default(), &topo, opts())
            };
            let a = run();
            let b = run();
            assert_eq!(a.delivered, 100, "{kind:?}");
            assert_eq!(a.order_violations, 0);
            assert_eq!(a.elapsed_ns, b.elapsed_ns, "sim must be deterministic ({kind:?})");
            assert_eq!(a.sim.unwrap(), b.sim.unwrap());
            assert!(a.latency_mean_ns() > 0.0);
        }
    }

    #[test]
    fn batched_messages_roundtrip_real_and_sim() {
        // Real host, both backends, batch 8.
        for backend in [BackendKind::Locked, BackendKind::LockFree] {
            let topo = Topology::one_way(MsgKind::Message, 300);
            let r = run_stress_real(
                RuntimeCfg::with_backend(backend),
                &topo,
                StressOpts::with_batch(8),
            );
            assert_eq!(r.delivered, 300, "{backend:?}");
            assert_eq!(r.order_violations, 0, "{backend:?}");
        }
        // Simulator: deterministic, and count not a batch multiple.
        let run = || {
            let m = Machine::new(MachineCfg::new(
                2,
                OsProfile::linux_rt(),
                AffinityMode::PinnedSpread,
            ));
            let topo = Topology::one_way(MsgKind::Message, 101);
            run_stress_sim(&m, RuntimeCfg::default(), &topo, StressOpts::with_batch(7))
        };
        let a = run();
        let b = run();
        assert_eq!(a.delivered, 101);
        assert_eq!(a.order_violations, 0);
        assert_eq!(a.elapsed_ns, b.elapsed_ns, "batched sim must stay deterministic");
    }

    #[test]
    fn batching_amortizes_exchange_cost_in_sim() {
        // The same message workload with batch 16 amortizes per-call API
        // overhead and the NBB enter/exit counter stores: virtual
        // completion time must strictly improve over the scalar loop.
        let run = |batch: usize| {
            let m = Machine::new(MachineCfg::new(
                2,
                OsProfile::linux_rt(),
                AffinityMode::PinnedSpread,
            ));
            let topo = Topology::one_way(MsgKind::Message, 400);
            run_stress_sim(&m, RuntimeCfg::default(), &topo, StressOpts::with_batch(batch))
        };
        let single = run(1);
        let batched = run(16);
        assert_eq!(single.delivered, batched.delivered);
        assert_eq!(batched.order_violations, 0);
        assert!(
            batched.elapsed_ns < single.elapsed_ns,
            "batch 16 should finish sooner: {batched:?} vs {single:?}"
        );
    }

    #[test]
    fn batched_packets_and_scalars_roundtrip_real_and_sim() {
        // `--batch` now drives connected channels too: same delivery and
        // ordering guarantees as the scalar loop, on both backends.
        for kind in [MsgKind::Packet, MsgKind::Scalar] {
            for backend in [BackendKind::Locked, BackendKind::LockFree] {
                let topo = Topology::one_way(kind, 300);
                let r = run_stress_real(
                    RuntimeCfg::with_backend(backend),
                    &topo,
                    StressOpts::with_batch(8),
                );
                assert_eq!(r.delivered, 300, "{kind:?}/{backend:?}");
                assert_eq!(r.order_violations, 0, "{kind:?}/{backend:?}");
            }
            // Simulator: deterministic, and count not a batch multiple.
            let run = || {
                let m = Machine::new(MachineCfg::new(
                    2,
                    OsProfile::linux_rt(),
                    AffinityMode::PinnedSpread,
                ));
                let topo = Topology::one_way(kind, 101);
                run_stress_sim(&m, RuntimeCfg::default(), &topo, StressOpts::with_batch(7))
            };
            let a = run();
            let b = run();
            assert_eq!(a.delivered, 101, "{kind:?}");
            assert_eq!(a.order_violations, 0, "{kind:?}");
            assert_eq!(a.elapsed_ns, b.elapsed_ns, "batched {kind:?} sim must stay deterministic");
        }
    }

    #[test]
    fn packet_batching_amortizes_on_the_ring_fast_path() {
        // Connected-channel acceptance: batch 16 over the SPSC ring
        // amortizes per-call API overhead and the enter/exit counter
        // stores — virtual completion time must strictly improve.
        let run = |batch: usize| {
            let m = Machine::new(MachineCfg::new(
                2,
                OsProfile::linux_rt(),
                AffinityMode::PinnedSpread,
            ));
            let topo = Topology::one_way(MsgKind::Packet, 400);
            run_stress_sim(&m, RuntimeCfg::default(), &topo, StressOpts::with_batch(batch))
        };
        let single = run(1);
        let batched = run(16);
        assert_eq!(single.delivered, batched.delivered);
        assert_eq!(batched.order_violations, 0);
        assert!(
            batched.elapsed_ns < single.elapsed_ns,
            "packet batch 16 should finish sooner: {batched:?} vs {single:?}"
        );
    }

    #[test]
    fn sim_lockfree_beats_locked_on_multicore() {
        // The headline effect, in miniature.
        let run = |backend| {
            let m = Machine::new(MachineCfg::new(
                4,
                OsProfile::linux_rt(),
                AffinityMode::PinnedSpread,
            ));
            let topo = Topology::one_way(MsgKind::Message, 200);
            run_stress_sim(&m, RuntimeCfg::with_backend(backend), &topo, opts())
        };
        let locked = run(BackendKind::Locked);
        let lockfree = run(BackendKind::LockFree);
        assert!(
            lockfree.elapsed_ns < locked.elapsed_ns,
            "lock-free must win on multicore: {lockfree:?} vs {locked:?}"
        );
    }
}
