//! Trace-enabled drivers: run a coordinator workload with the
//! observability plane armed, then drain, pair, replay-check and export.
//!
//! The obs plane is process-global (per-thread lane rings plus one
//! counter registry), so traced runs must not overlap: both drivers
//! reset the plane, arm it around exactly one run, and hand back
//! everything drained as a [`TraceRun`]. The CLI `trace` subcommand and
//! `scripts/bench_snapshot.sh` sit on top of these.

use crate::mcapi::types::RuntimeCfg;
use crate::obs::{self, Collector, ReplayReport};
use crate::os::{AffinityMode, OsProfile};
use crate::sim::{Machine, MachineCfg};

use super::chaos::{run_seeded, ChaosOpts, ChaosReport};
use super::metrics::StressReport;
use super::runner::{run_stress_real, run_stress_sim, StressOpts};
use super::topology::{MsgKind, Topology};

/// Options for a traced stress run.
#[derive(Debug, Clone, Copy)]
pub struct TraceOpts {
    /// Message kind for the one-way topology.
    pub kind: MsgKind,
    /// Transactions to stream.
    pub tx: u64,
    /// Simulated cores (sim plane only).
    pub cores: usize,
    /// Payloads per API call (1 = the paper's scalar loop).
    pub batch: usize,
    /// Run on the real host instead of the simulator.
    pub real: bool,
}

impl Default for TraceOpts {
    fn default() -> Self {
        TraceOpts { kind: MsgKind::Packet, tx: 400, cores: 2, batch: 1, real: false }
    }
}

/// Everything one traced run produced.
pub struct TraceRun {
    /// Drained events, paired into per-channel stage histograms.
    pub collector: Collector,
    /// FIFO / no-loss / no-dup verdict re-derived from the events alone.
    pub replay: ReplayReport,
    /// `(name, value)` snapshot of the counter registry.
    pub counters: Vec<(String, u64)>,
    /// Lane-ring records lost to overflow (0 in every gate).
    pub dropped: u64,
    /// Per-lane `(high_water, dropped)` drop watermarks, lane order.
    pub lanes: Vec<(u64, u64)>,
    /// The stress report (stress runs only).
    pub stress: Option<StressReport>,
    /// The chaos harness's own verdict (chaos runs only).
    pub chaos: Option<ChaosReport>,
}

impl TraceRun {
    /// Total events drained.
    pub fn events(&self) -> usize {
        self.collector.events.len()
    }

    /// Replay verdict for gating. Steady runs require a strict pass. A
    /// chaos run admits the same API-boundary holes the chaos harness
    /// itself documents: a victim killed between a priced ring store
    /// and the host-side emit right after it loses exactly that one
    /// mark — at most one committed-but-unmarked message
    /// (`recvs == commits + 1`), and at most a one-message
    /// acked-but-unreturned gap on consumer kills (`lost <= 1`).
    /// Duplicates are never admissible.
    pub fn replay_pass(&self) -> bool {
        if self.chaos.is_none() {
            return self.replay.pass;
        }
        self.replay.pass
            || (self.replay.dups == 0
                && self.replay.lost <= 1
                && self.replay.recvs <= self.replay.commits + 1)
    }

    /// Counter-registry value by name (0 when the run never touched it).
    fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| n == name).map_or(0, |(_, v)| *v)
    }

    /// The machine-readable snapshot line `scripts/bench_snapshot.sh`
    /// greps into `BENCH_trace.json`.
    pub fn bench_json_line(&self) -> String {
        let m = self.collector.merged_stages();
        let lane_peak = self.lanes.iter().map(|(hw, _)| *hw).max().unwrap_or(0);
        format!(
            "BENCH_JSON: {{\"trace_events\": {}, \"trace_dropped\": {}, \
             \"trace_lane_peak\": {}, \
             \"trace_send_commit_p50_ns\": {}, \"trace_send_commit_p99_ns\": {}, \
             \"trace_commit_doorbell_p99_ns\": {}, \"trace_doorbell_wakeup_p99_ns\": {}, \
             \"trace_wakeup_recv_p99_ns\": {}, \"trace_replay_pass\": {}, \
             \"liveness_suspects\": {}, \"liveness_confirms\": {}, \
             \"liveness_false_suspects\": {}, \"liveness_fence_rejects\": {}}}",
            self.events(),
            self.dropped,
            lane_peak,
            m.send_commit.p50(),
            m.send_commit.p99(),
            m.commit_doorbell.p99(),
            m.doorbell_wakeup.p99(),
            m.wakeup_recv.p99(),
            u32::from(self.replay_pass()),
            self.counter("liveness.suspects"),
            self.counter("liveness.confirms"),
            self.counter("liveness.false_suspects"),
            self.counter("liveness.fence_rejects")
        )
    }

    /// Human-readable per-stage summary.
    pub fn summary_text(&self) -> String {
        let m = self.collector.merged_stages();
        let mut out = String::new();
        out.push_str("stage              count    mean_ns      p50      p99     p999\n");
        for (h, name) in m.by_stage().iter().zip(obs::STAGES) {
            out.push_str(&format!(
                "{name:<16} {:>7} {:>10.0} {:>8} {:>8} {:>8}\n",
                h.count(),
                h.mean(),
                h.p50(),
                h.p99(),
                h.p999()
            ));
        }
        out.push_str(&format!(
            "events={} dropped={} channels={}\n{}",
            self.events(),
            self.dropped,
            self.collector.channels().len(),
            self.replay.text
        ));
        out
    }
}

/// Reset + arm the global plane for exactly one run.
fn arm() {
    obs::set_enabled(false);
    obs::reset();
    obs::set_enabled(true);
}

/// Disarm, drain every lane, pair and verdict.
fn disarm_and_collect(stress: Option<StressReport>, chaos: Option<ChaosReport>) -> TraceRun {
    obs::set_enabled(false);
    let events = obs::drain();
    let dropped = obs::dropped();
    let lanes = obs::lanes_snapshot();
    let counters = obs::counters_snapshot();
    let collector = Collector::from_events(events);
    let replay = collector.replay_check();
    TraceRun { collector, replay, counters, dropped, lanes, stress, chaos }
}

/// Run a one-way stress topology with tracing armed.
pub fn run_traced_stress(cfg: RuntimeCfg, opts: TraceOpts) -> TraceRun {
    arm();
    let topo = Topology::one_way(opts.kind, opts.tx);
    let sopts = StressOpts::with_batch(opts.batch);
    let report = if opts.real {
        run_stress_real(cfg, &topo, sopts)
    } else {
        let machine = Machine::new(MachineCfg::new(
            opts.cores,
            OsProfile::linux_rt(),
            AffinityMode::PinnedSpread,
        ));
        run_stress_sim(&machine, cfg, &topo, sopts)
    };
    disarm_and_collect(Some(report), None)
}

/// Run a seeded chaos scenario with tracing armed: the trace replay is
/// a second ground truth, independent of the harness's ring-counter
/// invariants.
pub fn run_traced_chaos(seed: u64) -> TraceRun {
    arm();
    let report = run_seeded(&ChaosOpts { seed, ..ChaosOpts::default() });
    disarm_and_collect(None, Some(report))
}

#[cfg(test)]
#[cfg(feature = "obs-trace")]
mod tests {
    use super::*;

    #[test]
    fn traced_sim_stress_populates_stages_and_passes_replay() {
        let _g = obs::test_guard();
        let run = run_traced_stress(
            RuntimeCfg::default(),
            TraceOpts { tx: 64, ..TraceOpts::default() },
        );
        assert_eq!(run.stress.as_ref().unwrap().delivered, 64);
        assert_eq!(run.dropped, 0);
        assert!(run.replay_pass(), "{}", run.replay.text);
        let m = run.collector.merged_stages();
        for (h, name) in m.by_stage().iter().zip(obs::STAGES) {
            assert_eq!(h.count(), 64, "stage {name}");
        }
        assert!(run.counters.iter().any(|(n, v)| n == "ring.send" && *v == 64));
        // Per-lane drop watermarks ride along: at least one lane
        // buffered events this run, and nothing overflowed.
        assert!(run.lanes.iter().any(|(hw, _)| *hw > 0), "{:?}", run.lanes);
        assert!(run.lanes.iter().all(|(_, dr)| *dr == 0), "{:?}", run.lanes);
        let line = run.bench_json_line();
        assert!(line.contains("\"trace_replay_pass\": 1"), "{line}");
        assert!(line.contains("\"trace_lane_peak\""), "{line}");
        assert!(run.collector.chrome_trace_json().contains("\"traceEvents\""));
    }

    #[test]
    fn traced_chaos_seed_replay_is_clean() {
        let _g = obs::test_guard();
        let run = run_traced_chaos(1);
        let chaos = run.chaos.as_ref().unwrap();
        assert!(chaos.pass, "{}", chaos.text);
        assert!(run.replay_pass(), "{}", run.replay.text);
        assert!(run.events() > 0);
    }

    #[test]
    fn plane_is_disarmed_after_a_traced_run() {
        let _g = obs::test_guard();
        let _ = run_traced_stress(
            RuntimeCfg::default(),
            TraceOpts { tx: 8, ..TraceOpts::default() },
        );
        assert!(!obs::tracing(), "drivers must leave tracing off");
    }
}
