//! Stress-run metrics: throughput, one-way latency, retry behaviour.

use crate::util::histogram::Histogram;

/// Per-channel receive-side metrics.
#[derive(Clone, Default)]
pub struct ChannelMetrics {
    /// Messages delivered.
    pub delivered: u64,
    /// One-way latency samples (ns; virtual ns on the simulator).
    pub latency: Histogram,
    /// Sequence violations observed (must stay 0).
    pub order_violations: u64,
}

/// Aggregated result of one stress run.
#[derive(Clone)]
pub struct StressReport {
    /// Total messages delivered across channels.
    pub delivered: u64,
    /// Wall/virtual time of the whole run (ns).
    pub elapsed_ns: u64,
    /// Merged one-way latency histogram.
    pub latency: Histogram,
    /// Total sender+receiver yields (convoy indicator).
    pub yields: u64,
    /// Sequence violations (must be 0 — checked by tests).
    pub order_violations: u64,
    /// Waits that expired with `Status::Timeout` (robustness counter).
    pub timeouts: u64,
    /// Operations that surfaced `Status::EndpointDead` (robustness counter).
    pub poisons: u64,
    /// Pool leases reclaimed from dead nodes (robustness counter).
    pub leases_reclaimed: u64,
    /// Simulator statistics when run on the sim plane.
    pub sim: Option<crate::sim::MachineStats>,
}

impl StressReport {
    /// Throughput in messages per second.
    pub fn throughput(&self) -> f64 {
        if self.elapsed_ns == 0 {
            0.0
        } else {
            self.delivered as f64 * 1e9 / self.elapsed_ns as f64
        }
    }

    /// Throughput in thousands of messages per second (Figure 7's unit).
    pub fn kmsgs_per_s(&self) -> f64 {
        self.throughput() / 1e3
    }

    /// Mean one-way latency (ns).
    pub fn latency_mean_ns(&self) -> f64 {
        self.latency.mean()
    }
}

impl std::fmt::Debug for StressReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "StressReport {{ delivered: {}, elapsed: {} ns, X: {:.1} kmsg/s, lat mean: {:.0} ns, p99: {} ns, yields: {}, timeouts: {}, poisons: {}, reclaimed: {} }}",
            self.delivered,
            self.elapsed_ns,
            self.kmsgs_per_s(),
            self.latency_mean_ns(),
            self.latency.p99(),
            self.yields,
            self.timeouts,
            self.poisons,
            self.leases_reclaimed
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let mut latency = Histogram::new();
        latency.record(1_000);
        let r = StressReport {
            delivered: 1_000,
            elapsed_ns: 1_000_000_000,
            latency,
            yields: 3,
            order_violations: 0,
            timeouts: 0,
            poisons: 0,
            leases_reclaimed: 0,
            sim: None,
        };
        assert!((r.throughput() - 1_000.0).abs() < 1e-9);
        assert!((r.kmsgs_per_s() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn zero_elapsed_is_zero_throughput() {
        let r = StressReport {
            delivered: 10,
            elapsed_ns: 0,
            latency: Histogram::new(),
            yields: 0,
            order_violations: 0,
            timeouts: 0,
            poisons: 0,
            leases_reclaimed: 0,
            sim: None,
        };
        assert_eq!(r.throughput(), 0.0);
    }
}
