//! Real-thread abandonment harness: automatic liveness on `RealWorld`.
//!
//! The sim-plane chaos harness (`chaos.rs`) injects kills at exact
//! priced-op indices, but its monitor *explicitly* declares the victim
//! dead. This harness closes the loop the tentpole promises: an OS
//! thread **abandons** its role mid-stream — parks forever at a seeded
//! operation boundary, the real-plane analog of a kill — and nothing in
//! the scenario ever calls [`McapiRuntime::declare_node_dead`]. The
//! armed heartbeat watchdog must notice the silence on its own, confirm
//! through the suspect hysteresis, and run the same repair pipeline;
//! the live peer must unblock through its deadline/backoff sender with
//! `Timeout` then `EndpointDead`, and the judge holds the harness to
//! the usual bar: every committed frame delivered or drained exactly
//! once, nothing torn, nothing leaked, and the live peer never falsely
//! declared.
//!
//! The abandoned producer is additionally woken *after* the verdict and
//! made to attempt one more send: a fenced zombie must fail fast with
//! [`Status::NodeFenced`] instead of corrupting the repaired channel.
//!
//! Timings are chosen for CI flake-resistance, not latency: a 150 ms
//! silence deadline with 3 confirm scans means a live-but-descheduled
//! thread would need four consecutive 150 ms starvations to be falsely
//! confirmed, while the whole scenario still finishes in well under a
//! second.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::lockfree::mem::RealWorld;
use crate::lockfree::World;
use crate::mcapi::liveness::LivenessCfg;
use crate::mcapi::types::{BackendKind, ChannelKind, EndpointId, RuntimeCfg, Status};
use crate::mcapi::McapiRuntime;

use super::chaos::{frame, parse_frame};

/// Dense node slot owning the producer-side endpoint.
const NODE_PROD: usize = 1;
/// Dense node slot owning the consumer-side endpoint.
const NODE_CONS: usize = 2;
/// Per-attempt deadline for the live peer's deadline senders (wall ns).
const SLICE_NS: u64 = 5_000_000;

/// Which role abandons its thread mid-stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AbandonRole {
    /// The producer parks forever between two sends.
    Producer,
    /// The consumer parks forever between two receives.
    Consumer,
}

impl AbandonRole {
    /// Stable report label.
    pub fn label(self) -> &'static str {
        match self {
            Self::Producer => "producer",
            Self::Consumer => "consumer",
        }
    }
}

/// Abandonment scenario parameters.
#[derive(Debug, Clone, Copy)]
pub struct AbandonOpts {
    /// Which role abandons.
    pub role: AbandonRole,
    /// Frames the producer streams when nobody abandons.
    pub messages: u64,
    /// Operation boundary (0-based attempt index) at which the victim
    /// parks forever; clamped below `messages` so it always fires.
    pub abandon_at: u64,
    /// Watchdog silence deadline (milliseconds of wall time).
    pub deadline_ms: u64,
    /// Watchdog scan period (milliseconds).
    pub scan_period_ms: u64,
    /// Consecutive over-deadline scans before a confirm.
    pub confirm_scans: u32,
}

impl Default for AbandonOpts {
    fn default() -> Self {
        AbandonOpts {
            role: AbandonRole::Producer,
            messages: 48,
            abandon_at: 17,
            deadline_ms: 150,
            scan_period_ms: 10,
            confirm_scans: 3,
        }
    }
}

/// A finished abandonment run: report text plus the verdict. Timings
/// are wall-clock, so the text is *not* byte-reproducible — only the
/// verdict and the invariant counts are.
#[derive(Debug, Clone)]
pub struct AbandonReport {
    /// Human-readable summary.
    pub text: String,
    /// True when every invariant held.
    pub pass: bool,
}

/// Run one abandonment scenario end to end. See the module docs for
/// the choreography; the caller thread acts as the judge and the final
/// scavenger of committed-but-undelivered frames.
pub fn run_abandon(opts: &AbandonOpts) -> AbandonReport {
    let messages = opts.messages.max(1);
    let abandon_at = opts.abandon_at.min(messages - 1);
    let cfg = RuntimeCfg {
        backend: BackendKind::LockFree,
        max_nodes: 4,
        liveness: LivenessCfg {
            deadline_ns: opts.deadline_ms.max(1) * 1_000_000,
            confirm_scans: opts.confirm_scans.max(1),
        },
        ..Default::default()
    };
    let rt = McapiRuntime::<RealWorld>::new(cfg);
    let src = EndpointId::new(0, NODE_PROD as u16, 7);
    let dst = EndpointId::new(0, NODE_CONS as u16, 7);
    rt.create_endpoint(src, NODE_PROD).unwrap();
    rt.create_endpoint(dst, NODE_CONS).unwrap();
    let ch = rt.connect(src, dst, ChannelKind::Packet).unwrap();
    rt.open_send(ch).unwrap();
    rt.open_recv(ch).unwrap();

    // Wakes the parked zombie once the verdict is in (so its thread can
    // be joined; a wake before this flag is a spurious unpark).
    let release = Arc::new(AtomicBool::new(false));

    // Built-in background watchdog. The ONLY death-detection mechanism
    // in the scenario — nothing below calls `declare_node_dead`, and
    // nothing hand-drives `watchdog_scan_once` either: the scanner
    // thread is the runtime's own (`Watchdog::spawn_scanner`).
    let mut watchdog = rt
        .new_watchdog()
        .spawn_scanner(&rt, Duration::from_millis(opts.scan_period_ms.max(1)));

    // Producer (node 1): streams checksummed frames through the
    // deadline sender. Returns `(confirmed sends, exit status, zombie
    // send verdict)`.
    let producer = {
        let (rt, release) = (rt.clone(), release.clone());
        let abandon = (opts.role == AbandonRole::Producer).then_some(abandon_at);
        thread::spawn(move || {
            let mut sent = 0u64;
            let mut exit = None;
            let mut zombie = None;
            let mut ops = 0u64;
            while sent < messages {
                if abandon == Some(ops) {
                    // Abandon: park forever at an operation boundary —
                    // the thread is alive to the OS, dead to its peers.
                    while !release.load(Ordering::Acquire) {
                        thread::park_timeout(Duration::from_millis(20));
                    }
                    // Woken inside a repaired world: the zombie's one
                    // further send must fail fast on the epoch fence.
                    zombie = Some(rt.pkt_send(ch, &frame(sent)));
                    break;
                }
                ops += 1;
                let fr = frame(sent);
                match rt.pkt_send_deadline(ch, &fr, RealWorld::now_ns() + SLICE_NS) {
                    Ok(()) => sent += 1,
                    Err(Status::Timeout) => {}
                    Err(s) => {
                        exit = Some(s);
                        break;
                    }
                }
            }
            (sent, exit, zombie)
        })
    };

    // Consumer (node 2): blocking receives through the deadline
    // receiver. Returns `(frames in order, torn count, exit status)`.
    let consumer = {
        let (rt, release) = (rt.clone(), release.clone());
        let abandon = (opts.role == AbandonRole::Consumer).then_some(abandon_at);
        thread::spawn(move || {
            let mut got = Vec::new();
            let mut torn = 0u64;
            let mut exit = None;
            let mut ops = 0u64;
            let mut buf = [0u8; 64];
            while (got.len() as u64) < messages {
                if abandon == Some(ops) {
                    while !release.load(Ordering::Acquire) {
                        thread::park_timeout(Duration::from_millis(20));
                    }
                    // A woken consumer zombie does NO further API work:
                    // receives are never fenced (scavengers must drain
                    // dead endpoints), so touching the channel here
                    // would steal a frame from the judge's drain.
                    break;
                }
                ops += 1;
                match rt.pkt_recv_deadline(ch, &mut buf, RealWorld::now_ns() + SLICE_NS) {
                    Ok(n) => match parse_frame(&buf[..n]) {
                        Some(seq) => got.push(seq),
                        None => torn += 1,
                    },
                    Err(Status::Timeout) => {}
                    Err(s) => {
                        exit = Some(s);
                        break;
                    }
                }
            }
            (got, torn, exit)
        })
    };

    // Join the live peer first: it can only exit once the watchdog's
    // automatic confirm poisons the channel, so this join IS the
    // end-to-end detection gate. Then stop the watchdog immediately so
    // the now-silent (but alive) peer is never falsely confirmed while
    // the epilogue runs.
    let (victim_node, peer_node) = match opts.role {
        AbandonRole::Producer => (NODE_PROD, NODE_CONS),
        AbandonRole::Consumer => (NODE_CONS, NODE_PROD),
    };
    // A late-abandoning consumer can let the producer finish its whole
    // stream before the silence deadline even elapses, so after the
    // live join give the watchdog a bounded window to confirm, then
    // shut it down before the now-silent (but alive) peer's lane could
    // ever mature into a false confirm.
    let await_confirm = |rt: &McapiRuntime<RealWorld>| {
        let t0 = Instant::now();
        while rt.node_alive(victim_node) && t0.elapsed() < Duration::from_secs(10) {
            thread::sleep(Duration::from_millis(opts.scan_period_ms.max(1)));
        }
    };
    let (sent, prod_exit, zombie, got, torn, cons_exit);
    match opts.role {
        AbandonRole::Producer => {
            let c = consumer.join().unwrap();
            await_confirm(&rt);
            watchdog.stop();
            release.store(true, Ordering::Release);
            let p = producer.join().unwrap();
            (sent, prod_exit, zombie) = p;
            (got, torn, cons_exit) = c;
        }
        AbandonRole::Consumer => {
            let p = producer.join().unwrap();
            await_confirm(&rt);
            watchdog.stop();
            release.store(true, Ordering::Release);
            let c = consumer.join().unwrap();
            (sent, prod_exit, zombie) = p;
            (got, torn, cons_exit) = c;
        }
    }

    // Scavenge: committed frames the dead consumer never claimed drain
    // here (receives are unfenced by design). With a dead producer the
    // live consumer already drained to the poison, so this is empty.
    let mut drained = Vec::new();
    let mut torn_total = torn;
    let mut buf = [0u8; 64];
    loop {
        match rt.pkt_recv(ch, &mut buf) {
            Ok(n) => match parse_frame(&buf[..n]) {
                Some(seq) => drained.push(seq),
                None => torn_total += 1,
            },
            Err(_) => break, // empty, or empty + poison
        }
    }

    // Judge.
    let (committed, settled) = match rt.chan_counters(ch) {
        Some((u, a)) => (u / 2, u % 2 == 0 && a % 2 == 0 && u == a),
        None => (0, false),
    };
    let combined: Vec<u64> = got.iter().chain(drained.iter()).copied().collect();
    let expected: Vec<u64> = (0..committed).collect();
    let live_exit = match opts.role {
        AbandonRole::Producer => cons_exit,
        AbandonRole::Consumer => prod_exit,
    };
    let mut fails = Vec::new();
    if torn_total != 0 {
        fails.push(format!("{torn_total} torn frames"));
    }
    if !settled {
        fails.push("ring counters not settled after drain".into());
    }
    if sent != committed {
        fails.push(format!("{sent} sends confirmed but ring committed {committed}"));
    }
    if combined != expected {
        fails.push("delivered+drained != committed prefix (loss/dup/reorder)".into());
    }
    if rt.node_alive(victim_node) {
        fails.push("watchdog never declared the abandoned node".into());
    }
    if rt.confirms_observed() < 1 {
        fails.push("no automatic watchdog confirm recorded".into());
    }
    if !rt.node_alive(peer_node) {
        fails.push("the live peer was falsely declared dead".into());
    }
    // The live producer may legitimately finish its whole stream when
    // the consumer abandons late; only a *blocked* peer must have been
    // unblocked by the poison.
    let peer_completed = opts.role == AbandonRole::Consumer && sent == messages;
    if !peer_completed && live_exit != Some(Status::EndpointDead) {
        fails.push(format!(
            "live peer exited with {live_exit:?}, expected Some(EndpointDead)"
        ));
    }
    if opts.role == AbandonRole::Producer && !matches!(zombie, Some(Err(Status::NodeFenced))) {
        fails.push(format!(
            "woken zombie send returned {zombie:?}, expected Err(NodeFenced)"
        ));
    }
    if rt.buffers_available() != rt.cfg().pool_buffers {
        fails.push(format!(
            "{} pool leases leaked",
            rt.cfg().pool_buffers - rt.buffers_available()
        ));
    }

    let verdict = if fails.is_empty() {
        "PASS".to_string()
    } else {
        format!("FAIL[{}]", fails.join("; "))
    };
    let text = format!(
        "abandon role={} abandon_at={abandon_at} msgs={messages} committed={committed} \
         delivered={} drained={} sent={sent} torn={torn_total} suspects={} confirms={} \
         false_suspects={} fence_rejects={} timeouts={} verdict={verdict}",
        opts.role.label(),
        got.len(),
        drained.len(),
        rt.suspects_observed(),
        rt.confirms_observed(),
        rt.false_suspects_observed(),
        rt.fence_rejects_observed(),
        rt.timeouts_observed(),
    );
    AbandonReport { text, pass: fails.is_empty() }
}

/// Seeded wrapper for the CI matrix: the seed picks the abandoning role
/// and the operation boundary it parks at, reproducibly.
pub fn run_abandon_seeded(seed: u64) -> AbandonReport {
    let opts = AbandonOpts::default();
    let role = if seed % 2 == 0 { AbandonRole::Consumer } else { AbandonRole::Producer };
    let abandon_at = 1 + (seed.wrapping_mul(7919)) % (opts.messages - 2);
    run_abandon(&AbandonOpts { role, abandon_at, ..opts })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abandoned_producer_is_detected_and_fenced() {
        let r = run_abandon(&AbandonOpts { role: AbandonRole::Producer, ..Default::default() });
        assert!(r.pass, "{}", r.text);
    }

    #[test]
    fn abandoned_consumer_is_detected_and_drained() {
        let r = run_abandon(&AbandonOpts { role: AbandonRole::Consumer, ..Default::default() });
        assert!(r.pass, "{}", r.text);
    }

    #[test]
    fn seeded_runs_cover_both_roles() {
        assert_eq!(
            (run_abandon_seeded(2).pass, run_abandon_seeded(3).pass),
            (true, true)
        );
    }
}
