//! N×M MPMC endpoint harness: many producers fan checksummed frames
//! into one multi-consumer endpoint, a consumer group drains it, and a
//! set-based judge checks **exactly-once** delivery — fault-free, under
//! seeded chaos, and under kill-point sweeps with either role as the
//! victim.
//!
//! The judge is deliberately set-based, not FIFO-based: dead-consumer
//! recovery salvages wedged claims and *re-enqueues* them
//! ([`crate::mcapi::queue::ConsumerGroup::repair_dead`]), so global
//! FIFO order is not preserved across a repair — but the delivered
//! multiset must still equal the sent set exactly. The admissible
//! API-boundary holes mirror the SPSC chaos harness, per victim:
//!
//! * a killed **consumer** may lose at most one message per kill — the
//!   one it acknowledged but never returned to the caller;
//! * a killed **producer** may *add* at most one message per kill that
//!   its caller never saw confirmed — committed by the ring, delivered
//!   downstream, but the sender died before `msg_send` returned `Ok`.
//!
//! Duplicates and torn frames are never admissible, and every pool
//! lease must be accounted for after recovery.

use std::collections::BTreeSet;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::lockfree::World;
use crate::mcapi::types::{BackendKind, EndpointId, RuntimeCfg};
use crate::mcapi::McapiRuntime;
use crate::os::{AffinityMode, OsProfile};
use crate::sim::faults::{sweep_kill_points, FaultAction, FaultPlan, OpWindow};
use crate::sim::{Machine, MachineCfg, SimWorld};

use super::chaos::{frame, parse_frame, Victim};

/// Dense node slot owning the MPMC endpoint (the watchdog's node —
/// never a fault target, and the fallback claimant for the final
/// drain).
const NODE_EP: usize = 0;

/// MPMC harness parameters.
#[derive(Debug, Clone, Copy)]
pub struct MpmcOpts {
    /// Producer tasks (spawn-order tasks `0..producers`).
    pub producers: usize,
    /// Consumer tasks (spawn-order tasks `producers..producers+consumers`).
    pub consumers: usize,
    /// Frames **per producer**.
    pub messages: u64,
    /// Seed for [`FaultPlan::from_seed`] in [`run_mpmc_chaos`].
    pub seed: u64,
    /// Asymmetric-consumer knob: yields injected before each of
    /// consumer 0's receive attempts (0 = symmetric). A slowed member's
    /// home lanes back up, so its peers must steal to keep the stream
    /// balanced — [`run_mpmc_skewed`] judges exactly-once under that
    /// imbalance.
    pub slow_factor: u64,
}

impl Default for MpmcOpts {
    fn default() -> Self {
        MpmcOpts { producers: 2, consumers: 2, messages: 12, seed: 1, slow_factor: 0 }
    }
}

/// A finished MPMC run: deterministic report text plus the verdict.
#[derive(Debug, Clone)]
pub struct MpmcReport {
    /// Human-readable, byte-for-byte reproducible per seed.
    pub text: String,
    /// True when every invariant held.
    pub pass: bool,
    /// Frames delivered in-band (consumer pops, excluding salvage).
    pub delivered: usize,
}

/// Everything observable after one machine run (host-side state only).
struct Outcome {
    /// Sequences each producer saw confirmed (`msg_send` returned `Ok`).
    sent: Vec<u64>,
    /// Sequences the consumer group delivered, claim order per consumer.
    delivered: Vec<u64>,
    /// Sequences the watchdog drained after everyone stopped.
    drained: Vec<u64>,
    torn: u64,
    /// Per worker task (producers then consumers): finished cleanly.
    clean: Vec<bool>,
    leaked: u64,
    reclaimed: u64,
    vtime_ns: u64,
    prod_window: Option<OpWindow>,
    cons_window: Option<OpWindow>,
}

fn run_mpmc(opts: &MpmcOpts, plan: FaultPlan) -> Outcome {
    let producers = opts.producers.max(1);
    let consumers = opts.consumers.max(1);
    let messages = opts.messages;
    let slow_factor = opts.slow_factor;
    let workers = producers + consumers;
    let m = Machine::new(MachineCfg::new(
        4,
        OsProfile::linux_rt(),
        AffinityMode::PinnedSpread,
    ));
    let cfg = RuntimeCfg {
        backend: BackendKind::LockFree,
        max_nodes: 1 + workers,
        nbb_capacity: 8,
        pool_buffers: 64,
        ..Default::default()
    };
    let rt = McapiRuntime::<SimWorld>::new(cfg);
    let dst = EndpointId::new(0, NODE_EP as u16, 1);

    // Host-side coordination (unpriced; invisible to the op indices the
    // fault plan keys on for the victims).
    let ready = Arc::new(AtomicBool::new(false));
    let ep_slot = Arc::new(AtomicUsize::new(usize::MAX));
    let halt = Arc::new(AtomicBool::new(false));
    let clean: Vec<Arc<AtomicBool>> =
        (0..workers).map(|_| Arc::new(AtomicBool::new(false))).collect();
    let sent = Arc::new(Mutex::new(Vec::new()));
    let delivered = Arc::new(Mutex::new(Vec::new()));
    let drained = Arc::new(Mutex::new(Vec::new()));
    let torn = Arc::new(AtomicU64::new(0));
    let leaked = Arc::new(AtomicU64::new(0));
    let windows = Arc::new(Mutex::new((None::<OpWindow>, None::<OpWindow>)));
    let mark = messages / 2;

    let mut handles = Vec::with_capacity(workers + 1);

    // Tasks 0..P: producers. Producer `p` owns node `1 + p` and streams
    // the global sequences `p*messages .. (p+1)*messages`, recording
    // each one host-side only *after* `msg_send` confirms it.
    for p in 0..producers {
        let (rt, ready) = (rt.clone(), ready.clone());
        let (clean, windows, sent) = (clean[p].clone(), windows.clone(), sent.clone());
        handles.push(m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let node = 1 + p;
            'stream: for j in 0..messages {
                let seq = p as u64 * messages + j;
                let fr = frame(seq);
                // Bracket the priced-op window of producer 0's
                // mid-stream send for the kill sweep.
                let start =
                    if p == 0 && j == mark { Some(SimWorld::op_count()) } else { None };
                loop {
                    match rt.msg_send(node, dst, &fr, 0) {
                        Ok(()) => {
                            sent.lock().unwrap().push(seq);
                            break;
                        }
                        Err(s) if s.is_would_block() => SimWorld::yield_now(),
                        Err(_) => break 'stream,
                    }
                }
                if let Some(s) = start {
                    windows.lock().unwrap().0 =
                        Some(OpWindow { task: p, start: s, end: SimWorld::op_count() });
                }
            }
            clean.store(true, Ordering::SeqCst);
        }));
    }

    // Tasks P..P+C: consumers. Consumer `c` owns node `1+P+c`, attaches
    // to the group, and claim-drains until the watchdog raises `halt`.
    for c in 0..consumers {
        let (rt, ready, ep_slot) = (rt.clone(), ready.clone(), ep_slot.clone());
        let (clean, windows) = (clean[producers + c].clone(), windows.clone());
        let (delivered, torn, halt) = (delivered.clone(), torn.clone(), halt.clone());
        handles.push(m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let ep = ep_slot.load(Ordering::SeqCst);
            let node = 1 + producers + c;
            rt.endpoint_attach_consumer(ep, node).unwrap();
            let mut buf = [0u8; 64];
            let mut got_mine = 0u64;
            loop {
                // Asymmetric-consumer skew: consumer 0 runs slow, its
                // backlog must flow to the others via stealing.
                if c == 0 {
                    for _ in 0..slow_factor {
                        SimWorld::yield_now();
                    }
                }
                // Bracket consumer 0's receive attempts until its first
                // successful claim; the last bracket written covers the
                // successful pop (kill-sweep probe window).
                let start = if c == 0 && got_mine == 0 {
                    Some(SimWorld::op_count())
                } else {
                    None
                };
                let r = rt.msg_recv(ep, &mut buf);
                if let Some(s) = start {
                    windows.lock().unwrap().1 = Some(OpWindow {
                        task: producers + c,
                        start: s,
                        end: SimWorld::op_count(),
                    });
                }
                match r {
                    Ok(n) => {
                        got_mine += 1;
                        match parse_frame(&buf[..n]) {
                            Some(seq) => delivered.lock().unwrap().push(seq),
                            None => {
                                torn.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    }
                    Err(s) if s.is_would_block() => {
                        if halt.load(Ordering::SeqCst) {
                            break;
                        }
                        SimWorld::yield_now();
                    }
                    Err(_) => break,
                }
            }
            clean.store(true, Ordering::SeqCst);
        }));
    }

    // Last task: watchdog. Never a fault target. Creates the endpoint
    // (so a victim killed at op 0 cannot wedge the rendezvous), declares
    // abnormal deaths, raises `halt` once the stream has drained, then
    // salvages anything recovery re-exposed and audits the pool.
    {
        let (rt, ready, ep_slot) = (rt.clone(), ready.clone(), ep_slot.clone());
        let clean_flags: Vec<Arc<AtomicBool>> = clean.clone();
        let (drained, torn, leaked) = (drained.clone(), torn.clone(), leaked.clone());
        let halt = halt.clone();
        handles.push(m.spawn(move || {
            let ep = rt.create_endpoint(dst, NODE_EP).unwrap();
            ep_slot.store(ep, Ordering::SeqCst);
            ready.store(true, Ordering::SeqCst);
            let mut declared = vec![false; workers];
            let mut stable = 0u32;
            let mut buf = [0u8; 64];
            loop {
                let mut all_done = true;
                let mut prod_done = true;
                let mut cons_done = true;
                for t in 0..workers {
                    let done = SimWorld::task_done(t);
                    all_done &= done;
                    if t < producers {
                        prod_done &= done;
                    } else {
                        cons_done &= done;
                    }
                    if done && !declared[t] && !clean_flags[t].load(Ordering::SeqCst) {
                        // Worker task `t` owns node `1 + t` on both
                        // sides of the split.
                        rt.declare_node_dead(1 + t);
                        declared[t] = true;
                    }
                }
                // Fallback claimant, in-loop: with every consumer gone
                // the producers would wedge on a full lane, so the
                // endpoint owner claims while they finish streaming.
                if cons_done && !prod_done {
                    while let Ok(n) = rt.msg_recv(ep, &mut buf) {
                        match parse_frame(&buf[..n]) {
                            Some(seq) => drained.lock().unwrap().push(seq),
                            None => {
                                torn.fetch_add(1, Ordering::SeqCst);
                            }
                        }
                    }
                }
                // Raise `halt` only after the producers stopped, every
                // abnormal death was declared (salvage re-enqueued), and
                // the endpoint stayed empty for a few consecutive polls.
                if prod_done && rt.msg_available(ep).unwrap_or(0) == 0 {
                    stable += 1;
                    if stable >= 3 {
                        halt.store(true, Ordering::SeqCst);
                    }
                } else {
                    stable = 0;
                }
                if all_done {
                    break;
                }
                SimWorld::yield_now();
            }
            // Salvage: claims wedged by consumers that died after `halt`
            // were re-enqueued by their declare; drain them here as the
            // fallback claimant (the endpoint owner never attaches).
            let mut buf = [0u8; 64];
            while let Ok(n) = rt.msg_recv(ep, &mut buf) {
                match parse_frame(&buf[..n]) {
                    Some(seq) => drained.lock().unwrap().push(seq),
                    None => {
                        torn.fetch_add(1, Ordering::SeqCst);
                    }
                }
            }
            let free = rt.buffers_available() as u64;
            leaked.store(
                (rt.cfg().pool_buffers as u64).saturating_sub(free),
                Ordering::SeqCst,
            );
        }));
    }

    m.set_faults(plan);
    let stats = m.run(handles);

    let (w0, w1) = *windows.lock().unwrap();
    Outcome {
        sent: sent.lock().unwrap().clone(),
        delivered: delivered.lock().unwrap().clone(),
        drained: drained.lock().unwrap().clone(),
        torn: torn.load(Ordering::SeqCst),
        clean: clean.iter().map(|c| c.load(Ordering::SeqCst)).collect(),
        leaked: leaked.load(Ordering::SeqCst),
        reclaimed: rt.leases_reclaimed(),
        vtime_ns: stats.virtual_ns,
        prod_window: w0,
        cons_window: w1,
    }
}

/// Set-based exactly-once judge; returns `(missing, extra, failures)`.
fn judge(out: &Outcome, opts: &MpmcOpts) -> (u64, u64, Vec<String>) {
    let producers = opts.producers.max(1);
    let total = producers as u64 * opts.messages;
    let mut fails = Vec::new();
    if out.torn != 0 {
        fails.push(format!("{} torn frames", out.torn));
    }
    let killed_prod = out.clean[..producers].iter().filter(|c| !**c).count() as u64;
    let killed_cons = out.clean[producers..].iter().filter(|c| !**c).count() as u64;
    let sent: BTreeSet<u64> = out.sent.iter().copied().collect();
    let mut observed: Vec<u64> =
        out.delivered.iter().chain(out.drained.iter()).copied().collect();
    observed.sort_unstable();
    if observed.windows(2).any(|w| w[0] == w[1]) {
        fails.push("duplicate delivery".into());
    }
    let observed_set: BTreeSet<u64> = observed.iter().copied().collect();
    if let Some(&bad) = observed_set.iter().find(|s| **s >= total) {
        fails.push(format!("unknown sequence {bad} delivered"));
    }
    // Missing: confirmed to a sender, never seen again. Only a killed
    // consumer's ack-boundary can eat one, one per kill.
    let missing = sent.difference(&observed_set).count() as u64;
    if missing > killed_cons {
        fails.push(format!(
            "{missing} confirmed messages missing ({killed_cons} consumer kills admit \
             at most {killed_cons})"
        ));
    }
    // Extra: delivered but never confirmed. Only a producer killed
    // between the ring commit and its `Ok` can add one, one per kill.
    let extra = observed_set.difference(&sent).count() as u64;
    if extra > killed_prod {
        fails.push(format!(
            "{extra} unconfirmed messages delivered ({killed_prod} producer kills admit \
             at most {killed_prod})"
        ));
    }
    if out.leaked != 0 {
        fails.push(format!("{} pool leases leaked", out.leaked));
    }
    (missing, extra, fails)
}

fn fmt_event((t, k, a): (usize, u64, FaultAction)) -> String {
    match a {
        FaultAction::Kill => format!("kill(t{t}@{k})"),
        FaultAction::Stall(ns) => format!("stall(t{t}@{k},{ns}ns)"),
        FaultAction::Delay(ns) => format!("delay(t{t}@{k},{ns}ns)"),
    }
}

fn fmt_line(prefix: &str, out: &Outcome, missing: u64, extra: u64, fails: &[String]) -> String {
    let verdict = if fails.is_empty() {
        "PASS".to_string()
    } else {
        format!("FAIL[{}]", fails.join("; "))
    };
    let clean: Vec<&str> =
        out.clean.iter().map(|c| if *c { "t" } else { "f" }).collect();
    format!(
        "{prefix} sent={} delivered={} drained={} missing={missing} extra={extra} \
         torn={} leaked={} reclaimed={} clean=[{}] vtime_ns={} verdict={verdict}",
        out.sent.len(),
        out.delivered.len(),
        out.drained.len(),
        out.torn,
        out.leaked,
        out.reclaimed,
        clean.join(""),
        out.vtime_ns,
    )
}

/// Fault-free N×M stress: every frame confirmed, delivered in-band,
/// exactly once, nothing leaked.
pub fn run_mpmc_stress(opts: &MpmcOpts) -> MpmcReport {
    let out = run_mpmc(opts, FaultPlan::new());
    let (missing, extra, mut fails) = judge(&out, opts);
    let total = opts.producers.max(1) as u64 * opts.messages;
    if out.sent.len() as u64 != total {
        fails.push(format!("only {}/{total} sends confirmed", out.sent.len()));
    }
    if out.clean.iter().any(|c| !c) {
        fails.push("a fault-free worker did not finish clean".into());
    }
    let prefix = format!(
        "mpmc producers={} consumers={} msgs={}",
        opts.producers, opts.consumers, opts.messages
    );
    MpmcReport {
        text: fmt_line(&prefix, &out, missing, extra, &fails),
        pass: fails.is_empty(),
        delivered: out.delivered.len(),
    }
}

/// Steal-storm stress: **one** hot producer lane, every consumer's
/// home lanes otherwise dry — so beyond the hot lane's own home every
/// delivery is a batch steal through the shared cursor. The set-based
/// judge still demands exactly-once; the report carries the
/// process-wide steal-counter delta as the saturation signal (lower
/// bound only — concurrent harnesses also steal).
pub fn run_mpmc_steal_storm(opts: &MpmcOpts) -> MpmcReport {
    let storm = MpmcOpts {
        producers: 1,
        consumers: opts.consumers.max(2),
        // One lane carries what the N-producer runs spread out.
        messages: opts.messages * opts.producers.max(1) as u64,
        ..*opts
    };
    let steals_before = crate::obs::counter(crate::obs::ctr::MPMC_STEALS);
    let out = run_mpmc(&storm, FaultPlan::new());
    let steals =
        crate::obs::counter(crate::obs::ctr::MPMC_STEALS).saturating_sub(steals_before);
    let (missing, extra, mut fails) = judge(&out, &storm);
    if out.sent.len() as u64 != storm.messages {
        fails.push(format!("only {}/{} sends confirmed", out.sent.len(), storm.messages));
    }
    if out.clean.iter().any(|c| !c) {
        fails.push("a fault-free worker did not finish clean".into());
    }
    let prefix = format!(
        "mpmc-steal-storm consumers={} msgs={} steal_batches>={steals}",
        storm.consumers, storm.messages
    );
    MpmcReport {
        text: fmt_line(&prefix, &out, missing, extra, &fails),
        pass: fails.is_empty(),
        delivered: out.delivered.len(),
    }
}

/// Asymmetric-consumer stress: consumer 0 is slowed by
/// [`MpmcOpts::slow_factor`] yields per receive attempt (default 16
/// when unset), so its home-lane backlog must drain through its peers'
/// steals. Exactly-once under imbalance.
pub fn run_mpmc_skewed(opts: &MpmcOpts) -> MpmcReport {
    let skew = MpmcOpts {
        slow_factor: if opts.slow_factor == 0 { 16 } else { opts.slow_factor },
        ..*opts
    };
    let out = run_mpmc(&skew, FaultPlan::new());
    let (missing, extra, mut fails) = judge(&out, &skew);
    let total = skew.producers.max(1) as u64 * skew.messages;
    if out.sent.len() as u64 != total {
        fails.push(format!("only {}/{total} sends confirmed", out.sent.len()));
    }
    if out.clean.iter().any(|c| !c) {
        fails.push("a fault-free worker did not finish clean".into());
    }
    let prefix = format!(
        "mpmc-skew producers={} consumers={} msgs={} slow_factor={}",
        skew.producers, skew.consumers, skew.messages, skew.slow_factor
    );
    MpmcReport {
        text: fmt_line(&prefix, &out, missing, extra, &fails),
        pass: fails.is_empty(),
        delivered: out.delivered.len(),
    }
}

/// Kill-during-steal sweep: the steal-storm topology (one hot lane)
/// re-homes the lane away from consumer 0, so consumer 0's bracketed
/// first claim **is** a batch steal — sweeping kills across that
/// window exercises every priced op of the steal protocol (claim CAS,
/// busy-wait loads, staged slot reads, the single `ack` advance, the
/// claim release) and judges exactly-once at each point.
pub fn run_mpmc_steal_kill_sweep(opts: &MpmcOpts) -> MpmcReport {
    let storm = MpmcOpts {
        producers: 1,
        consumers: opts.consumers.max(2),
        messages: opts.messages * opts.producers.max(1) as u64,
        ..*opts
    };
    run_mpmc_kill_sweep(Victim::Consumer, &storm)
}

/// Seeded chaos on the N×M topology: a 1–3 event fault plan over the
/// worker tasks (the watchdog is never a target). Deterministic: the
/// same opts produce the same report byte-for-byte.
pub fn run_mpmc_chaos(opts: &MpmcOpts) -> MpmcReport {
    let workers = opts.producers.max(1) + opts.consumers.max(1);
    let plan = FaultPlan::from_seed(opts.seed, workers, 400);
    let events: Vec<String> = plan.events().map(fmt_event).collect();
    let out = run_mpmc(opts, plan);
    let (missing, extra, fails) = judge(&out, opts);
    let prefix = format!(
        "mpmc-chaos seed={} producers={} consumers={} msgs={} events=[{}]",
        opts.seed,
        opts.producers,
        opts.consumers,
        opts.messages,
        events.join(",")
    );
    MpmcReport {
        text: fmt_line(&prefix, &out, missing, extra, &fails),
        pass: fails.is_empty(),
        delivered: out.delivered.len(),
    }
}

/// Kill-point sweep over the MPMC plane: probe the victim's priced-op
/// window (producer 0's mid-stream send, or consumer 0's first claim),
/// then kill the victim at every op index inside it, one fresh machine
/// per point. Every point must uphold exactly-once within the victim's
/// admissible hole.
pub fn run_mpmc_kill_sweep(victim: Victim, opts: &MpmcOpts) -> MpmcReport {
    let probe = run_mpmc(opts, FaultPlan::new());
    let (_, _, probe_fails) = judge(&probe, opts);
    let window = match victim {
        Victim::Producer => probe.prod_window,
        Victim::Consumer => probe.cons_window,
    };
    let Some(window) = window else {
        return MpmcReport {
            text: format!(
                "mpmc-sweep victim={} verdict=FAIL[probe run never reached the \
                 bracketed operation]",
                victim.label()
            ),
            pass: false,
            delivered: probe.delivered.len(),
        };
    };
    let mut pass = probe_fails.is_empty();
    let delivered = probe.delivered.len();
    let mut lines = vec![format!(
        "mpmc-sweep victim={} producers={} consumers={} msgs={} window={}..{} points={} probe={}",
        victim.label(),
        opts.producers,
        opts.consumers,
        opts.messages,
        window.start,
        window.end,
        window.len(),
        if pass { "PASS" } else { "FAIL" }
    )];
    for (k, plan) in sweep_kill_points(window) {
        let out = run_mpmc(opts, plan);
        let (missing, extra, fails) = judge(&out, opts);
        pass &= fails.is_empty();
        lines.push(fmt_line(&format!("  kill@{k}"), &out, missing, extra, &fails));
    }
    lines.push(format!("sweep verdict={}", if pass { "PASS" } else { "FAIL" }));
    MpmcReport { text: lines.join("\n"), pass, delivered }
}

/// Simultaneous multi-node death: kill **two distinct victims** in one
/// run — any role pairing — and judge exactly-once under the per-role
/// kill budgets (`missing <= consumer kills`, `extra <= producer
/// kills`). Kill points come from the probed mid-operation windows; a
/// repeated role targets the sibling task at the same per-task op index
/// (the workloads are symmetric, and any priced-op index is a valid
/// death point). Deterministic: same opts, same report byte-for-byte.
pub fn run_mpmc_two_victims(first: Victim, second: Victim, opts: &MpmcOpts) -> MpmcReport {
    let producers = opts.producers.max(1);
    let consumers = opts.consumers.max(1);
    let probe = run_mpmc(opts, FaultPlan::new());
    let (_, _, probe_fails) = judge(&probe, opts);
    let window_of = |v: Victim| match v {
        Victim::Producer => probe.prod_window,
        Victim::Consumer => probe.cons_window,
    };
    let (Some(w1), Some(w2)) = (window_of(first), window_of(second)) else {
        return MpmcReport {
            text: format!(
                "mpmc-two-victims roles={}+{} verdict=FAIL[probe run never reached the \
                 bracketed operation]",
                first.label(),
                second.label()
            ),
            pass: false,
            delivered: probe.delivered.len(),
        };
    };
    let task_of = |v: Victim, instance: usize| match v {
        Victim::Producer => instance % producers,
        Victim::Consumer => producers + instance % consumers,
    };
    let mid = |w: OpWindow| w.start + w.len() / 2;
    let t1 = task_of(first, 0);
    let t2 = task_of(second, if first == second { 1 } else { 0 });
    let plan = FaultPlan::new().kill(t1, mid(w1)).kill(t2, mid(w2));
    let events: Vec<String> = plan.events().map(fmt_event).collect();
    let out = run_mpmc(opts, plan);
    let (missing, extra, mut fails) = judge(&out, opts);
    if !probe_fails.is_empty() {
        fails.push("probe run failed".into());
    }
    let prefix = format!(
        "mpmc-two-victims roles={}+{} producers={} consumers={} msgs={} events=[{}]",
        first.label(),
        second.label(),
        opts.producers,
        opts.consumers,
        opts.messages,
        events.join(",")
    );
    MpmcReport {
        text: fmt_line(&prefix, &out, missing, extra, &fails),
        pass: fails.is_empty(),
        delivered: out.delivered.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_free_mpmc_delivers_exactly_once() {
        let opts = MpmcOpts { messages: 10, ..Default::default() };
        let r = run_mpmc_stress(&opts);
        assert!(r.pass, "{}", r.text);
        assert_eq!(r.delivered, 20, "{}", r.text);
    }

    #[test]
    fn seeded_mpmc_chaos_passes_and_reproduces() {
        for seed in 1..=3u64 {
            let opts = MpmcOpts { seed, messages: 10, ..Default::default() };
            let a = run_mpmc_chaos(&opts);
            assert!(a.pass, "seed {seed}: {}", a.text);
            let b = run_mpmc_chaos(&opts);
            assert_eq!(a.text, b.text, "seed {seed} report must reproduce exactly");
        }
    }

    #[test]
    fn two_simultaneous_victims_keep_exactly_once() {
        let opts = MpmcOpts { messages: 10, ..Default::default() };
        for (a, b) in [
            (Victim::Producer, Victim::Producer),
            (Victim::Producer, Victim::Consumer),
            (Victim::Consumer, Victim::Consumer),
        ] {
            let r = run_mpmc_two_victims(a, b, &opts);
            assert!(r.pass, "{}+{}: {}", a.label(), b.label(), r.text);
        }
    }

    #[test]
    fn single_consumer_group_still_passes() {
        let opts = MpmcOpts { consumers: 1, messages: 8, ..Default::default() };
        let r = run_mpmc_stress(&opts);
        assert!(r.pass, "{}", r.text);
        assert_eq!(r.delivered, 16, "{}", r.text);
    }

    #[test]
    fn steal_storm_delivers_exactly_once() {
        let opts = MpmcOpts { consumers: 3, messages: 8, ..Default::default() };
        let r = run_mpmc_steal_storm(&opts);
        assert!(r.pass, "{}", r.text);
    }

    #[test]
    fn skewed_consumer_keeps_exactly_once() {
        let opts = MpmcOpts { messages: 10, ..Default::default() };
        let r = run_mpmc_skewed(&opts);
        assert!(r.pass, "{}", r.text);
    }
}
