//! The Section 6 test matrix and the Table 2 / Figure 7 / Figure 8
//! report generators.
//!
//! Dimensions (paper, Section 6): Windows vs. Linux (OS cost profile),
//! single core vs. multicore, message type, lock-based vs. lock-free
//! FIFO, and CPU affinity (pinned vs. free). Each cell runs the Section 4
//! stress topology — a single one-way channel, 1000 transactions — on the
//! deterministic SMP simulator.

use crate::mcapi::types::{BackendKind, RuntimeCfg};
use crate::os::{AffinityMode, OsProfile};
use crate::sim::{Machine, MachineCfg};

use super::metrics::StressReport;
use super::runner::{run_pingpong_sim, run_stress_sim, StressOpts};
use super::topology::{MsgKind, Topology};
use crate::util::histogram::Histogram;

/// Cores used for the "multicore" configurations (the paper's KVM guests
/// had four).
pub const MULTI_CORES: usize = 4;

/// One cell of the test matrix.
#[derive(Debug, Clone, Copy)]
pub struct Cell {
    /// OS cost profile.
    pub os: OsProfile,
    /// Virtual core count (1 = the "single core" column).
    pub cores: usize,
    /// Payload type.
    pub kind: MsgKind,
    /// Data-path backend.
    pub backend: BackendKind,
    /// Placement: pinned-spread ("Affinity Task") or free ("Task").
    pub affinity: AffinityMode,
}

impl Cell {
    /// Human-readable cell id, e.g. `linux/4c/message/lockfree/task`.
    pub fn id(&self) -> String {
        format!(
            "{}/{}c/{}/{}/{}",
            self.os.name,
            self.cores,
            self.kind.label(),
            self.backend.label(),
            self.affinity.label()
        )
    }
}

/// Measured outcome of one cell.
#[derive(Debug, Clone)]
pub struct CellResult {
    /// The cell.
    pub cell: Cell,
    /// Stress report.
    pub report: StressReport,
}

impl CellResult {
    /// Figure 7 unit.
    pub fn kmsgs_per_s(&self) -> f64 {
        self.report.kmsgs_per_s()
    }
}

/// Run one matrix cell on the simulator (streaming throughput).
pub fn run_cell(cell: Cell, transactions: u64) -> CellResult {
    let affinity = if cell.cores == 1 { AffinityMode::SingleCore } else { cell.affinity };
    let machine = Machine::new(MachineCfg::new(cell.cores, cell.os, affinity));
    let topo = Topology::one_way(cell.kind, transactions);
    let cfg = RuntimeCfg::with_backend(cell.backend);
    let report = run_stress_sim(&machine, cfg, &topo, StressOpts::default());
    CellResult { cell, report }
}

/// Run one matrix cell's ping-pong latency (one outstanding transaction);
/// returns the one-way latency histogram. This is the Figure 8 latency
/// measurement — isolated from queueing effects.
pub fn run_cell_latency(cell: Cell, transactions: u64) -> Histogram {
    let affinity = if cell.cores == 1 { AffinityMode::SingleCore } else { cell.affinity };
    let machine = Machine::new(MachineCfg::new(cell.cores, cell.os, affinity));
    let cfg = RuntimeCfg::with_backend(cell.backend);
    let (hist, _stats) = run_pingpong_sim(&machine, cfg, cell.kind, transactions);
    hist
}

/// The full Section 6 matrix runner with report generators.
pub struct Matrix {
    /// Transactions per channel (paper: 1000).
    pub transactions: u64,
}

impl Default for Matrix {
    fn default() -> Self {
        Matrix { transactions: 1000 }
    }
}

impl Matrix {
    /// Construct with a transaction budget (tests use smaller counts).
    pub fn new(transactions: u64) -> Self {
        Matrix { transactions }
    }

    fn oses() -> [OsProfile; 2] {
        [OsProfile::windows(), OsProfile::linux_rt()]
    }

    fn affinities() -> [AffinityMode; 2] {
        [AffinityMode::Free, AffinityMode::PinnedSpread]
    }

    /// **Table 2** — lock-based multicore throughput speedup relative to
    /// single core (values < 1 are the migration penalty). Returns rows
    /// `(os, kind, speedup_task, speedup_affinity)`.
    pub fn table2(&self) -> Vec<(String, String, f64, f64)> {
        let mut rows = Vec::new();
        for os in Self::oses() {
            for kind in MsgKind::all() {
                let single = run_cell(
                    Cell {
                        os,
                        cores: 1,
                        kind,
                        backend: BackendKind::Locked,
                        affinity: AffinityMode::SingleCore,
                    },
                    self.transactions,
                );
                let mut speedups = [0.0f64; 2];
                for (i, affinity) in Self::affinities().into_iter().enumerate() {
                    let multi = run_cell(
                        Cell {
                            os,
                            cores: MULTI_CORES,
                            kind,
                            backend: BackendKind::Locked,
                            affinity,
                        },
                        self.transactions,
                    );
                    // Throughput speedup = test / original (eq. 6-1).
                    speedups[i] = multi.report.throughput() / single.report.throughput();
                }
                rows.push((
                    os.name.to_string(),
                    kind.label().to_string(),
                    speedups[0],
                    speedups[1],
                ));
            }
        }
        rows
    }

    /// **Figure 7** — absolute throughput (kmsg/s) for the full matrix.
    pub fn fig7(&self) -> Vec<CellResult> {
        let mut out = Vec::new();
        for os in Self::oses() {
            for kind in MsgKind::all() {
                for backend in [BackendKind::Locked, BackendKind::LockFree] {
                    out.push(run_cell(
                        Cell { os, cores: 1, kind, backend, affinity: AffinityMode::SingleCore },
                        self.transactions,
                    ));
                    for affinity in Self::affinities() {
                        out.push(run_cell(
                            Cell { os, cores: MULTI_CORES, kind, backend, affinity },
                            self.transactions,
                        ));
                    }
                }
            }
        }
        out
    }

    /// **Figure 8** — lock-free latency speedup (eq. 6-2:
    /// `original latency / test latency`) per configuration, positioned at
    /// the lock-free throughput. Returns
    /// `(config label, lockfree kmsg/s, latency speedup)`.
    pub fn fig8(&self) -> Vec<(String, f64, f64)> {
        let mut out = Vec::new();
        for os in Self::oses() {
            for kind in MsgKind::all() {
                let mut configs: Vec<(String, usize, AffinityMode)> = vec![(
                    format!("{}/1c/{}", os.name, kind.label()),
                    1,
                    AffinityMode::SingleCore,
                )];
                for affinity in Self::affinities() {
                    configs.push((
                        format!("{}/{}c/{}/{}", os.name, MULTI_CORES, kind.label(), affinity.label()),
                        MULTI_CORES,
                        affinity,
                    ));
                }
                for (label, cores, affinity) in configs {
                    // Bubble position: lock-free *streaming* throughput.
                    let lockfree_x = run_cell(
                        Cell { os, cores, kind, backend: BackendKind::LockFree, affinity },
                        self.transactions,
                    );
                    // Bubble size: ping-pong latency speedup (eq. 6-2).
                    let locked_lat = run_cell_latency(
                        Cell { os, cores, kind, backend: BackendKind::Locked, affinity },
                        self.transactions,
                    );
                    let lockfree_lat = run_cell_latency(
                        Cell { os, cores, kind, backend: BackendKind::LockFree, affinity },
                        self.transactions,
                    );
                    let speedup = locked_lat.mean() / lockfree_lat.mean();
                    out.push((label, lockfree_x.kmsgs_per_s(), speedup));
                }
            }
        }
        out
    }
}

/// Markdown printer for Table 2.
pub fn print_table2(rows: &[(String, String, f64, f64)]) -> String {
    let mut s = String::from(
        "| OS | Message type | Task (free) | Affinity Task |\n|---|---|---|---|\n",
    );
    for (os, kind, task, aff) in rows {
        s.push_str(&format!("| {os} | {kind} | {task:.2}x | {aff:.2}x |\n"));
    }
    s
}

/// Markdown printer for Figure 7.
pub fn print_fig7(cells: &[CellResult]) -> String {
    let mut s = String::from("| Configuration | Throughput (kmsg/s) | Mean latency (ns) |\n|---|---|---|\n");
    for c in cells {
        s.push_str(&format!(
            "| {} | {:.1} | {:.0} |\n",
            c.cell.id(),
            c.kmsgs_per_s(),
            c.report.latency_mean_ns()
        ));
    }
    s
}

/// Markdown printer for Figure 8.
pub fn print_fig8(rows: &[(String, f64, f64)]) -> String {
    let mut s = String::from(
        "| Configuration | Lock-free throughput (kmsg/s) | Latency speedup |\n|---|---|---|\n",
    );
    for (label, x, sp) in rows {
        s.push_str(&format!("| {label} | {x:.1} | {sp:.1}x |\n"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // Full-matrix shape assertions live in rust/tests/ (integration);
    // these unit tests cover single cells to stay fast.

    #[test]
    fn cell_ids_are_unique_in_fig7_order() {
        // Construct the id set without running anything.
        let mut ids = std::collections::HashSet::new();
        for os in Matrix::oses() {
            for kind in MsgKind::all() {
                for backend in [BackendKind::Locked, BackendKind::LockFree] {
                    ids.insert(
                        Cell { os, cores: 1, kind, backend, affinity: AffinityMode::SingleCore }
                            .id(),
                    );
                    for affinity in Matrix::affinities() {
                        ids.insert(
                            Cell { os, cores: MULTI_CORES, kind, backend, affinity }.id(),
                        );
                    }
                }
            }
        }
        assert_eq!(ids.len(), 2 * 3 * 2 * 3);
    }

    #[test]
    fn single_cell_runs_and_reports() {
        let r = run_cell(
            Cell {
                os: OsProfile::linux_rt(),
                cores: 2,
                kind: MsgKind::Message,
                backend: BackendKind::LockFree,
                affinity: AffinityMode::PinnedSpread,
            },
            50,
        );
        assert_eq!(r.report.delivered, 50);
        assert!(r.kmsgs_per_s() > 0.0);
        assert_eq!(r.report.order_violations, 0);
    }

    #[test]
    fn printers_emit_markdown_tables() {
        let t2 = print_table2(&[("linux".into(), "message".into(), 0.23, 0.22)]);
        assert!(t2.contains("| linux | message | 0.23x | 0.22x |"));
        let f8 = print_fig8(&[("x".into(), 100.0, 25.0)]);
        assert!(f8.contains("25.0x"));
    }
}
