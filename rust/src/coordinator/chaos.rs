//! Chaos harness: stress workloads under deterministic fault injection.
//!
//! Runs a producer/consumer exchange on the DES machine while a
//! [`crate::sim::faults::FaultPlan`] kills, stalls or delays the victim
//! tasks at exact priced-op indices, then checks the recovery
//! invariants the runtime promises:
//!
//! * **No committed message lost** — everything the dead peer finished
//!   publishing is delivered to the live side or salvaged by the
//!   watchdog after [`McapiRuntime::declare_node_dead`] repairs the
//!   ring. The only admissible hole is the API-boundary case: a
//!   consumer killed *after* acknowledging a message but *before*
//!   returning it to the caller (at most one, only on consumer kills).
//! * **No duplicates, no torn payloads** — sequence numbers strictly
//!   increase and every frame checksum verifies.
//! * **Every lease accounted** — after recovery and salvage the buffer
//!   pool is back to its full size (dead tasks' mid-operation leases
//!   are reclaimed, everything committed was drained).
//! * **Every waiter woken** — blocked peers return `EndpointDead` or
//!   `Timeout`; the machine run terminating at all proves no one
//!   deadlocked (the scheduler panics on a deadlock with no timed
//!   waiter).
//!
//! Because the simulator is deterministic, the per-seed report is
//! reproducible **byte-for-byte**: same seed, same report. Two modes:
//! [`run_seeded`] derives a small random plan from a seed (the CI gate
//! runs a fixed seed matrix), and [`run_kill_sweep`] measures the
//! priced-op window of one `pkt_send`/`pkt_recv` on a probe run and
//! then kills the victim at *every* index inside it, one fresh machine
//! per point — the acceptance sweep.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::lockfree::World;
use crate::mcapi::liveness::LivenessCfg;
use crate::mcapi::types::{BackendKind, ChannelKind, EndpointId, RuntimeCfg, Status};
use crate::mcapi::McapiRuntime;
use crate::os::{AffinityMode, OsProfile};
use crate::sim::faults::{
    sweep_delay_points, sweep_kill_points, sweep_stall_points, FaultAction, FaultPlan, OpWindow,
};
use crate::sim::{Machine, MachineCfg, SimWorld};

/// Spawn-order task id of the producer (fault victim 0).
const TASK_PROD: usize = 0;
/// Spawn-order task id of the consumer (fault victim 1).
const TASK_CONS: usize = 1;
/// Dense node slot owning the producer-side endpoint.
const NODE_PROD: usize = 1;
/// Dense node slot owning the consumer-side endpoint.
const NODE_CONS: usize = 2;

/// Payloads per batched API call in the `PktBatch` scenario.
const CHAOS_BATCH: usize = 4;

/// Which workload runs under fault injection.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scenario {
    /// Connected packet channel (zero-copy SPSC ring fast path).
    Pkt,
    /// Connectionless messages (lock-free queue + pool leases).
    Msg,
    /// Connected scalar channel (checksummed 64-bit frames).
    Sclr,
    /// Connected packet channel through the batched submit/drain API
    /// (`pkt_send_batch`/`pkt_recv_batch`, [`CHAOS_BATCH`] per call).
    PktBatch,
}

impl Scenario {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "pkt" | "packet" => Some(Self::Pkt),
            "msg" | "message" => Some(Self::Msg),
            "sclr" | "scalar" => Some(Self::Sclr),
            "pkt_batch" | "pktbatch" | "batch" => Some(Self::PktBatch),
            _ => None,
        }
    }

    /// Stable report label.
    pub fn label(self) -> &'static str {
        match self {
            Self::Pkt => "pkt",
            Self::Msg => "msg",
            Self::Sclr => "sclr",
            Self::PktBatch => "pkt_batch",
        }
    }

    /// Largest admissible consumer-kill hole: a victim killed between
    /// acknowledging and returning loses one message on the scalar
    /// paths, but up to a whole batch on the batched drain (the ring
    /// acks the batch with one counter pair, so everything copied out
    /// but not yet returned dies with the caller).
    fn admissible_hole(self) -> u64 {
        match self {
            Self::PktBatch => CHAOS_BATCH as u64,
            _ => 1,
        }
    }
}

/// Which side a kill sweep targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Victim {
    /// Kill the producer inside its send.
    Producer,
    /// Kill the consumer inside its receive.
    Consumer,
}

impl Victim {
    /// Parse from CLI text.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "prod" | "producer" | "tx" => Some(Self::Producer),
            "cons" | "consumer" | "rx" => Some(Self::Consumer),
            _ => None,
        }
    }

    /// Stable report label.
    pub fn label(self) -> &'static str {
        match self {
            Self::Producer => "producer",
            Self::Consumer => "consumer",
        }
    }
}

/// Chaos run parameters.
#[derive(Debug, Clone, Copy)]
pub struct ChaosOpts {
    /// Workload under test.
    pub scenario: Scenario,
    /// Seed for [`FaultPlan::from_seed`].
    pub seed: u64,
    /// Messages the producer streams.
    pub messages: u64,
    /// Per-wait deadline for the blocking receive (virtual ns).
    pub recv_timeout_ns: u64,
}

impl Default for ChaosOpts {
    fn default() -> Self {
        ChaosOpts {
            scenario: Scenario::Pkt,
            seed: 1,
            messages: 24,
            recv_timeout_ns: 2_000_000,
        }
    }
}

/// A finished chaos run: deterministic report text plus the verdict.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    /// Human-readable, byte-for-byte reproducible per seed.
    pub text: String,
    /// True when every invariant held.
    pub pass: bool,
}

// ---------------------------------------------------------------------------
// Self-describing frames: seq + checksum, so tears are detectable.
// ---------------------------------------------------------------------------

pub(crate) const FRAME_MAGIC: u64 = 0x5AFE_C0DE_D00D_F01D;
pub(crate) const FRAME_LEN: usize = 16;

pub(crate) fn frame(seq: u64) -> [u8; FRAME_LEN] {
    let mut f = [0u8; FRAME_LEN];
    f[..8].copy_from_slice(&seq.to_le_bytes());
    f[8..].copy_from_slice(&(seq ^ FRAME_MAGIC).to_le_bytes());
    f
}

pub(crate) fn parse_frame(b: &[u8]) -> Option<u64> {
    if b.len() != FRAME_LEN {
        return None;
    }
    let seq = u64::from_le_bytes(b[..8].try_into().ok()?);
    let sum = u64::from_le_bytes(b[8..].try_into().ok()?);
    if seq ^ FRAME_MAGIC == sum {
        Some(seq)
    } else {
        None
    }
}

/// Scalar frames pack a 32-bit sequence and a 32-bit checksum into one
/// 64-bit scalar, so a torn scalar is detectable just like a torn
/// packet frame.
fn sclr_frame(seq: u64) -> u64 {
    (seq << 32) | u64::from((seq as u32) ^ (FRAME_MAGIC as u32))
}

fn parse_sclr(v: u64) -> Option<u64> {
    let seq = v >> 32;
    if (v as u32) == ((seq as u32) ^ (FRAME_MAGIC as u32)) {
        Some(seq)
    } else {
        None
    }
}

/// Record one received packet frame into `into` (or count it torn).
fn record_bytes(into: &Mutex<Vec<u64>>, torn: &AtomicU64, b: &[u8]) {
    match parse_frame(b) {
        Some(seq) => into.lock().unwrap().push(seq),
        None => {
            torn.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// Record one received scalar frame into `into` (or count it torn).
fn record_sclr(into: &Mutex<Vec<u64>>, torn: &AtomicU64, v: u64) {
    match parse_sclr(v) {
        Some(seq) => into.lock().unwrap().push(seq),
        None => {
            torn.fetch_add(1, Ordering::SeqCst);
        }
    }
}

// ---------------------------------------------------------------------------
// One scenario run under a fault plan.
// ---------------------------------------------------------------------------

/// Everything observable after one machine run (host-side state only —
/// no priced operations happen after the machine stops).
struct Outcome {
    delivered: Vec<u64>,
    drained: Vec<u64>,
    torn: u64,
    producer_clean: bool,
    consumer_clean: bool,
    consumer_exit: Option<Status>,
    /// Ring ground truth `update/2` (Pkt only).
    ring_committed: Option<u64>,
    /// Counters even and fully acknowledged after salvage (Pkt only).
    ring_settled: bool,
    leaked: u64,
    reclaimed: u64,
    poisons: u64,
    timeouts: u64,
    /// Watchdog suspect scans (armed runs only; 0 otherwise).
    suspects: u64,
    /// Watchdog confirmations — automatic `declare_node_dead` calls.
    confirms: u64,
    /// Suspects cleared by later progress (hysteresis at work).
    false_suspects: u64,
    /// Liveness verdicts at the end of the run.
    prod_alive: bool,
    cons_alive: bool,
    vtime_ns: u64,
    prod_window: Option<OpWindow>,
    cons_window: Option<OpWindow>,
}

fn run_scenario(
    scenario: Scenario,
    plan: FaultPlan,
    messages: u64,
    recv_timeout_ns: u64,
) -> Outcome {
    run_scenario_with(scenario, plan, messages, recv_timeout_ns, None)
}

/// Like [`run_scenario`], but with the heartbeat watchdog optionally
/// armed: when `liveness` is `Some`, the monitor task drives
/// [`McapiRuntime::watchdog_scan_once`] on every poll, so node deaths
/// are detected *automatically* — the explicit `task_done`-based
/// declarations below stay as the sim-plane backstop (a killed sim task
/// stops beating, so the armed watchdog usually wins the race).
fn run_scenario_with(
    scenario: Scenario,
    plan: FaultPlan,
    messages: u64,
    recv_timeout_ns: u64,
    liveness: Option<LivenessCfg>,
) -> Outcome {
    let m = Machine::new(MachineCfg::new(
        4,
        OsProfile::linux_rt(),
        AffinityMode::PinnedSpread,
    ));
    let cfg = RuntimeCfg {
        backend: BackendKind::LockFree,
        max_nodes: 4,
        nbb_capacity: 8,
        pool_buffers: 64,
        liveness: liveness.unwrap_or_default(),
        ..Default::default()
    };
    let rt = McapiRuntime::<SimWorld>::new(cfg);
    let dst = EndpointId::new(0, NODE_CONS as u16, 1);
    let src = EndpointId::new(0, NODE_PROD as u16, 1);

    // Host-side coordination (unpriced; invisible to the op indices the
    // fault plan keys on for the victims).
    let ready = Arc::new(AtomicBool::new(false));
    // Pkt: channel table index. Msg: rx endpoint table index.
    let target = Arc::new(AtomicUsize::new(usize::MAX));
    let clean_prod = Arc::new(AtomicBool::new(false));
    let clean_cons = Arc::new(AtomicBool::new(false));
    let prod_declared = Arc::new(AtomicBool::new(false));
    let delivered = Arc::new(Mutex::new(Vec::new()));
    let drained = Arc::new(Mutex::new(Vec::new()));
    let torn = Arc::new(AtomicU64::new(0));
    let leaked = Arc::new(AtomicU64::new(0));
    let consumer_exit = Arc::new(Mutex::new(None));
    let windows = Arc::new(Mutex::new((None::<OpWindow>, None::<OpWindow>)));
    let mark = messages / 2;

    // Task 0: producer. Streams `messages` checksummed frames; yields on
    // would-block; stops when its peer is declared dead.
    let producer = {
        let (rt, ready, target) = (rt.clone(), ready.clone(), target.clone());
        let (clean, windows) = (clean_prod.clone(), windows.clone());
        m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let t = target.load(Ordering::SeqCst);
            let mut sent = 0u64;
            let mut bracketed = false;
            'stream: while sent < messages {
                let take = match scenario {
                    Scenario::PktBatch => CHAOS_BATCH.min((messages - sent) as usize),
                    _ => 1,
                };
                let frames: Vec<[u8; FRAME_LEN]> =
                    (sent..sent + take as u64).map(frame).collect();
                // Bracket the priced-op window of the mid-stream send
                // covering frame `mark` for the kill/stall sweeps (probe
                // runs read it back).
                let start = if !bracketed && sent + take as u64 > mark {
                    bracketed = true;
                    Some(SimWorld::op_count())
                } else {
                    None
                };
                loop {
                    let r = match scenario {
                        Scenario::Pkt => rt.pkt_send(t, &frames[0]).map(|()| 1),
                        Scenario::Msg => rt.msg_send(NODE_PROD, dst, &frames[0], 0).map(|()| 1),
                        Scenario::Sclr => rt.sclr_send(t, sclr_frame(sent)).map(|()| 1),
                        Scenario::PktBatch => {
                            let refs: Vec<&[u8]> =
                                frames.iter().map(|f| f.as_slice()).collect();
                            rt.pkt_send_batch(t, &refs)
                        }
                    };
                    match r {
                        Ok(n) => {
                            sent += n as u64;
                            break;
                        }
                        Err(s) if s.is_would_block() => SimWorld::yield_now(),
                        Err(_) => break 'stream, // peer declared dead
                    }
                }
                if let Some(s) = start {
                    windows.lock().unwrap().0 =
                        Some(OpWindow { task: TASK_PROD, start: s, end: SimWorld::op_count() });
                }
            }
            clean.store(true, Ordering::SeqCst);
        })
    };

    // Task 1: consumer. Blocking receives with a deadline; records every
    // frame; exits on full count or terminal status (EndpointDead after
    // the committed remainder drained).
    let consumer = {
        let (rt, ready, target) = (rt.clone(), ready.clone(), target.clone());
        let (clean, windows) = (clean_cons.clone(), windows.clone());
        let (delivered, torn) = (delivered.clone(), torn.clone());
        let (consumer_exit, prod_declared) = (consumer_exit.clone(), prod_declared.clone());
        m.spawn(move || {
            while !ready.load(Ordering::SeqCst) {
                SimWorld::yield_now();
            }
            let t = target.load(Ordering::SeqCst);
            let mut buf = [0u8; 64];
            let mut exit = None;
            let mut bracket_at = None;
            loop {
                let have = delivered.lock().unwrap().len() as u64;
                if have >= messages {
                    break;
                }
                // Bracket the receive attempt covering frame `mark`;
                // re-bracket while stuck at the same count so the probe
                // window ends up covering the successful receive.
                let start = if have >= mark && bracket_at.map_or(true, |b| b == have) {
                    bracket_at = Some(have);
                    Some(SimWorld::op_count())
                } else {
                    None
                };
                let r = match scenario {
                    Scenario::Pkt => rt
                        .chan_recv_wait(t, &mut buf, recv_timeout_ns)
                        .map(|n| record_bytes(&delivered, &torn, &buf[..n])),
                    Scenario::Msg => match rt.msg_recv(t, &mut buf) {
                        Ok(n) => {
                            record_bytes(&delivered, &torn, &buf[..n]);
                            Ok(())
                        }
                        Err(s) if s.is_would_block() => {
                            SimWorld::yield_now();
                            Err(Status::Timeout)
                        }
                        Err(e) => Err(e),
                    },
                    Scenario::Sclr => match rt.sclr_recv(t) {
                        Ok(v) => {
                            record_sclr(&delivered, &torn, v);
                            Ok(())
                        }
                        Err(s) if s.is_would_block() => {
                            SimWorld::yield_now();
                            Err(Status::Timeout)
                        }
                        Err(e) => Err(e),
                    },
                    Scenario::PktBatch => {
                        let mut batch = Vec::new();
                        match rt.pkt_recv_batch(t, &mut batch, CHAOS_BATCH) {
                            Ok(_) => {
                                for p in &batch {
                                    record_bytes(&delivered, &torn, p);
                                }
                                Ok(())
                            }
                            Err(s) if s.is_would_block() => {
                                SimWorld::yield_now();
                                Err(Status::Timeout)
                            }
                            Err(e) => Err(e),
                        }
                    }
                };
                if let Some(s) = start {
                    windows.lock().unwrap().1 =
                        Some(OpWindow { task: TASK_CONS, start: s, end: SimWorld::op_count() });
                }
                match r {
                    Ok(()) => {}
                    Err(Status::Timeout) => {
                        // The connectionless path has no per-endpoint
                        // poison: once the producer is declared dead and
                        // repaired, an empty queue stays empty.
                        if scenario == Scenario::Msg
                            && prod_declared.load(Ordering::SeqCst)
                            && rt.msg_available(t).unwrap_or(0) == 0
                        {
                            exit = Some(Status::EndpointDead);
                            break;
                        }
                    }
                    Err(s) => {
                        exit = Some(s);
                        break;
                    }
                }
            }
            *consumer_exit.lock().unwrap() = exit;
            clean.store(true, Ordering::SeqCst);
        })
    };

    // Task 2: watchdog. Never a fault target. Does the whole setup (so a
    // victim killed at op 0 cannot wedge the rendezvous), then monitors
    // the victims, declares abnormal deaths to the runtime, and finally
    // salvages whatever committed data recovery re-exposed.
    let watchdog = {
        let (rt, ready, target) = (rt.clone(), ready.clone(), target.clone());
        let (clean_prod, clean_cons) = (clean_prod.clone(), clean_cons.clone());
        let (drained, torn, leaked) = (drained.clone(), torn.clone(), leaked.clone());
        let prod_declared = prod_declared.clone();
        m.spawn(move || {
            match scenario {
                Scenario::Pkt | Scenario::PktBatch | Scenario::Sclr => {
                    let kind = if scenario == Scenario::Sclr {
                        ChannelKind::Scalar
                    } else {
                        ChannelKind::Packet
                    };
                    rt.create_endpoint(src, NODE_PROD).unwrap();
                    rt.create_endpoint(dst, NODE_CONS).unwrap();
                    let ch = rt.connect(src, dst, kind).unwrap();
                    rt.open_send(ch).unwrap();
                    rt.open_recv(ch).unwrap();
                    target.store(ch, Ordering::SeqCst);
                }
                Scenario::Msg => {
                    let ep = rt.create_endpoint(dst, NODE_CONS).unwrap();
                    target.store(ep, Ordering::SeqCst);
                }
            }
            ready.store(true, Ordering::SeqCst);
            let mut declared = [false; 2];
            let mut wd = liveness.map(|_| rt.new_watchdog());
            loop {
                // Armed runs: every scan is host-side (unpriced) reads of
                // the heartbeat shadows; a confirm feeds the same
                // `declare_node_dead` pipeline the explicit path uses.
                if let Some(wd) = wd.as_mut() {
                    rt.watchdog_scan_once(wd);
                }
                let d0 = SimWorld::task_done(TASK_PROD);
                let d1 = SimWorld::task_done(TASK_CONS);
                if d0 && !declared[0] && !clean_prod.load(Ordering::SeqCst) {
                    rt.declare_node_dead(NODE_PROD);
                    declared[0] = true;
                    prod_declared.store(true, Ordering::SeqCst);
                }
                if d1 && !declared[1] && !clean_cons.load(Ordering::SeqCst) {
                    rt.declare_node_dead(NODE_CONS);
                    declared[1] = true;
                }
                if d0 && d1 {
                    break;
                }
                SimWorld::yield_now();
            }
            // Salvage: recovery rolled any torn counter back, so every
            // committed frame is now readable exactly once.
            let t = target.load(Ordering::SeqCst);
            let mut buf = [0u8; 64];
            loop {
                let r = match scenario {
                    Scenario::Pkt | Scenario::PktBatch => {
                        rt.pkt_recv(t, &mut buf).map(|n| record_bytes(&drained, &torn, &buf[..n]))
                    }
                    Scenario::Msg => {
                        rt.msg_recv(t, &mut buf).map(|n| record_bytes(&drained, &torn, &buf[..n]))
                    }
                    Scenario::Sclr => rt.sclr_recv(t).map(|v| record_sclr(&drained, &torn, v)),
                };
                match r {
                    Ok(()) => {}
                    Err(_) => break, // empty (or empty + poison)
                }
            }
            // Lease audit: after reclamation + salvage the pool is whole.
            let free = rt.buffers_available() as u64;
            leaked.store((rt.cfg().pool_buffers as u64).saturating_sub(free), Ordering::SeqCst);
        })
    };

    m.set_faults(plan);
    let stats = m.run(vec![producer, consumer, watchdog]);

    let (ring_committed, ring_settled) = match scenario {
        Scenario::Pkt | Scenario::Sclr => match rt.chan_counters(target.load(Ordering::SeqCst)) {
            Some((u, a)) => (Some(u / 2), u % 2 == 0 && a % 2 == 0 && u == a),
            None => (None, false),
        },
        // A batch issues one counter pair for the whole run of payloads,
        // so `update/2` counts calls, not messages: settle-check only,
        // and infer the committed prefix from the sequences themselves.
        Scenario::PktBatch => match rt.chan_counters(target.load(Ordering::SeqCst)) {
            Some((u, a)) => (None, u % 2 == 0 && a % 2 == 0 && u == a),
            None => (None, false),
        },
        Scenario::Msg => (None, true),
    };
    let (w0, w1) = *windows.lock().unwrap();
    Outcome {
        delivered: delivered.lock().unwrap().clone(),
        drained: drained.lock().unwrap().clone(),
        torn: torn.load(Ordering::SeqCst),
        producer_clean: clean_prod.load(Ordering::SeqCst),
        consumer_clean: clean_cons.load(Ordering::SeqCst),
        consumer_exit: *consumer_exit.lock().unwrap(),
        ring_committed,
        ring_settled,
        leaked: leaked.load(Ordering::SeqCst),
        reclaimed: rt.leases_reclaimed(),
        poisons: rt.poisons_observed(),
        timeouts: rt.timeouts_observed(),
        suspects: rt.suspects_observed(),
        confirms: rt.confirms_observed(),
        false_suspects: rt.false_suspects_observed(),
        prod_alive: rt.node_alive(NODE_PROD),
        cons_alive: rt.node_alive(NODE_CONS),
        vtime_ns: stats.virtual_ns,
        prod_window: w0,
        cons_window: w1,
    }
}

// ---------------------------------------------------------------------------
// Invariant judging.
// ---------------------------------------------------------------------------

/// Check the recovery invariants; returns `(committed, gap, failures)`.
/// `max_hole` is the scenario's admissible consumer-kill hole (see
/// [`Scenario::admissible_hole`]).
fn judge(out: &Outcome, max_hole: u64) -> (u64, u64, Vec<String>) {
    let mut fails = Vec::new();
    if out.torn != 0 {
        fails.push(format!("{} torn frames", out.torn));
    }
    if !out.ring_settled {
        fails.push("ring counters not settled after salvage".into());
    }
    let combined: Vec<u64> =
        out.delivered.iter().chain(out.drained.iter()).copied().collect();
    // Ground truth for Pkt comes from the ring's monotonic counters; the
    // connectionless queue has none, so the committed prefix is inferred
    // from the highest sequence observed (FIFO commits are a prefix).
    let committed = match out.ring_committed {
        Some(c) => c,
        None => combined.iter().max().map_or(0, |m| m + 1),
    };
    if combined.iter().any(|&s| s >= committed) {
        fails.push("sequence beyond the committed prefix".into());
    }
    let gap = committed.saturating_sub(combined.len() as u64);
    match gap {
        0 => {
            let expected: Vec<u64> = (0..committed).collect();
            if combined != expected {
                fails.push("delivered+drained != committed prefix (loss/dup/reorder)".into());
            }
        }
        g if g <= max_hole => {
            // Only admissible hole: the consumer died between
            // acknowledging and reporting to the caller — one message on
            // scalar paths, up to one batch on the batched drain. The
            // hole is FIFO-contiguous, right after the last delivery.
            if out.consumer_clean {
                fails.push(format!("{g} committed messages missing without a consumer kill"));
            }
            let hole = out.delivered.len() as u64;
            let expected: Vec<u64> =
                (0..committed).filter(|&s| s < hole || s >= hole + g).collect();
            if combined != expected {
                fails.push(format!(
                    "missing messages are not the ack-boundary hole (expected seqs {hole}..{})",
                    hole + g
                ));
            }
        }
        n => fails.push(format!("{n} committed messages missing")),
    }
    if out.leaked != 0 {
        fails.push(format!("{} pool leases leaked", out.leaked));
    }
    // A live consumer must have exited for a reason the API defines.
    if out.consumer_clean {
        match out.consumer_exit {
            None | Some(Status::EndpointDead) => {}
            Some(s) => fails.push(format!("consumer exited with unexpected {s:?}")),
        }
    }
    (committed, gap, fails)
}

fn fmt_event((t, k, a): (usize, u64, FaultAction)) -> String {
    match a {
        FaultAction::Kill => format!("kill(t{t}@{k})"),
        FaultAction::Stall(ns) => format!("stall(t{t}@{k},{ns}ns)"),
        FaultAction::Delay(ns) => format!("delay(t{t}@{k},{ns}ns)"),
    }
}

fn fmt_line(prefix: &str, out: &Outcome, committed: u64, gap: u64, fails: &[String]) -> String {
    let verdict = if fails.is_empty() {
        "PASS".to_string()
    } else {
        format!("FAIL[{}]", fails.join("; "))
    };
    format!(
        "{prefix} committed={committed} delivered={} drained={} gap={gap} torn={} \
         leaked={} reclaimed={} poisons={} timeouts={} confirms={} prod_clean={} \
         cons_clean={} vtime_ns={} verdict={verdict}",
        out.delivered.len(),
        out.drained.len(),
        out.torn,
        out.leaked,
        out.reclaimed,
        out.poisons,
        out.timeouts,
        out.confirms,
        out.producer_clean,
        out.consumer_clean,
        out.vtime_ns,
    )
}

// ---------------------------------------------------------------------------
// Drivers.
// ---------------------------------------------------------------------------

/// Run one seeded chaos scenario: derive a 1–3 event fault plan from the
/// seed, run the workload, judge the invariants. Deterministic: the same
/// opts produce the same report byte-for-byte.
pub fn run_seeded(opts: &ChaosOpts) -> ChaosReport {
    let plan = FaultPlan::from_seed(opts.seed, 2, 400);
    let events: Vec<String> = plan.events().map(fmt_event).collect();
    let out = run_scenario(opts.scenario, plan, opts.messages, opts.recv_timeout_ns);
    let (committed, gap, fails) = judge(&out, opts.scenario.admissible_hole());
    let prefix = format!(
        "chaos seed={} scenario={} msgs={} events=[{}]",
        opts.seed,
        opts.scenario.label(),
        opts.messages,
        events.join(",")
    );
    ChaosReport { text: fmt_line(&prefix, &out, committed, gap, &fails), pass: fails.is_empty() }
}

/// Kill-point sweep: measure the victim's priced-op window around one
/// mid-stream send (producer) or receive (consumer) on a fault-free
/// probe run, then kill the victim at every op index inside the window,
/// one fresh machine per point. Every point must uphold every recovery
/// invariant.
pub fn run_kill_sweep(scenario: Scenario, victim: Victim, messages: u64) -> ChaosReport {
    let opts = ChaosOpts { scenario, messages, ..Default::default() };
    let probe = run_scenario(scenario, FaultPlan::new(), messages, opts.recv_timeout_ns);
    let (_, _, probe_fails) = judge(&probe, scenario.admissible_hole());
    let window = match victim {
        Victim::Producer => probe.prod_window,
        Victim::Consumer => probe.cons_window,
    };
    let Some(window) = window else {
        return ChaosReport {
            text: format!(
                "sweep scenario={} victim={} verdict=FAIL[probe run never reached the \
                 bracketed operation]",
                scenario.label(),
                victim.label()
            ),
            pass: false,
        };
    };
    let mut pass = probe_fails.is_empty();
    let mut lines = vec![format!(
        "sweep scenario={} victim={} window={}..{} points={} probe={}",
        scenario.label(),
        victim.label(),
        window.start,
        window.end,
        window.len(),
        if pass { "PASS" } else { "FAIL" }
    )];
    for (k, plan) in sweep_kill_points(window) {
        let out = run_scenario(scenario, plan, messages, opts.recv_timeout_ns);
        let (committed, gap, fails) = judge(&out, scenario.admissible_hole());
        pass &= fails.is_empty();
        lines.push(fmt_line(&format!("  kill@{k}"), &out, committed, gap, &fails));
    }
    lines.push(format!("sweep verdict={}", if pass { "PASS" } else { "FAIL" }));
    ChaosReport { text: lines.join("\n"), pass }
}

/// Stall-point sweep: like [`run_kill_sweep`], but instead of killing
/// the victim it freezes the victim for `stall_ns` of virtual time at
/// every priced-op index inside the probed window. A stall kills no
/// one, so the bar is *strictly higher* than the kill sweep's: every
/// point must deliver the complete stream with both sides finishing
/// clean — no gap, no salvage, no leases leaked. This is the liveness
/// gate for the peer-active handshakes (`WouldBlockPeerActive`,
/// doorbell re-check): a consumer frozen mid-acknowledge or a producer
/// frozen mid-publish must delay, never wedge or corrupt, the stream.
pub fn run_stall_sweep(
    scenario: Scenario,
    victim: Victim,
    messages: u64,
    stall_ns: u64,
) -> ChaosReport {
    let opts = ChaosOpts { scenario, messages, ..Default::default() };
    let probe = run_scenario(scenario, FaultPlan::new(), messages, opts.recv_timeout_ns);
    let (_, _, probe_fails) = judge(&probe, scenario.admissible_hole());
    let window = match victim {
        Victim::Producer => probe.prod_window,
        Victim::Consumer => probe.cons_window,
    };
    let Some(window) = window else {
        return ChaosReport {
            text: format!(
                "stall-sweep scenario={} victim={} verdict=FAIL[probe run never reached \
                 the bracketed operation]",
                scenario.label(),
                victim.label()
            ),
            pass: false,
        };
    };
    let mut pass = probe_fails.is_empty();
    let mut lines = vec![format!(
        "stall-sweep scenario={} victim={} stall_ns={} window={}..{} points={} probe={}",
        scenario.label(),
        victim.label(),
        stall_ns,
        window.start,
        window.end,
        window.len(),
        if pass { "PASS" } else { "FAIL" }
    )];
    for (k, plan) in sweep_stall_points(window, stall_ns) {
        let out = run_scenario(scenario, plan, messages, opts.recv_timeout_ns);
        let (committed, gap, mut fails) = judge(&out, scenario.admissible_hole());
        if !(out.producer_clean && out.consumer_clean) {
            fails.push("a stalled victim did not finish clean".into());
        }
        if (out.delivered.len() as u64) < messages {
            fails.push(format!(
                "stalled run delivered {}/{messages} in-band",
                out.delivered.len()
            ));
        }
        pass &= fails.is_empty();
        lines.push(fmt_line(&format!("  stall@{k}"), &out, committed, gap, &fails));
    }
    lines.push(format!("sweep verdict={}", if pass { "PASS" } else { "FAIL" }));
    ChaosReport { text: lines.join("\n"), pass }
}

/// Scheduling-delay sweep with the heartbeat watchdog **armed**: the
/// victim is delayed (stall + deschedule) for `delay_ns` at every
/// priced-op index inside the probed window while the monitor drives
/// [`McapiRuntime::watchdog_scan_once`] on every poll. The bar is the
/// stall sweep's (full in-band delivery, both sides clean, no leaks)
/// *plus* the liveness-plane acceptance criterion: the watchdog must
/// never confirm a delayed-but-alive node at **any** sweep point — the
/// silence deadline sits well above the injected delay, and the
/// suspect→confirm hysteresis absorbs what the deadline does not.
pub fn run_delay_sweep(
    scenario: Scenario,
    victim: Victim,
    messages: u64,
    delay_ns: u64,
) -> ChaosReport {
    let cfg = LivenessCfg {
        deadline_ns: delay_ns.saturating_mul(5).max(200_000),
        confirm_scans: 3,
    };
    let opts = ChaosOpts { scenario, messages, ..Default::default() };
    let probe =
        run_scenario_with(scenario, FaultPlan::new(), messages, opts.recv_timeout_ns, Some(cfg));
    let (_, _, probe_fails) = judge(&probe, scenario.admissible_hole());
    let window = match victim {
        Victim::Producer => probe.prod_window,
        Victim::Consumer => probe.cons_window,
    };
    let Some(window) = window else {
        return ChaosReport {
            text: format!(
                "delay-sweep scenario={} victim={} verdict=FAIL[probe run never reached \
                 the bracketed operation]",
                scenario.label(),
                victim.label()
            ),
            pass: false,
        };
    };
    let mut pass = probe_fails.is_empty() && probe.confirms == 0;
    let mut lines = vec![format!(
        "delay-sweep scenario={} victim={} delay_ns={} deadline_ns={} confirm_scans={} \
         window={}..{} points={} probe={}",
        scenario.label(),
        victim.label(),
        delay_ns,
        cfg.deadline_ns,
        cfg.confirm_scans,
        window.start,
        window.end,
        window.len(),
        if pass { "PASS" } else { "FAIL" }
    )];
    for (k, plan) in sweep_delay_points(window, delay_ns) {
        let out = run_scenario_with(scenario, plan, messages, opts.recv_timeout_ns, Some(cfg));
        let (committed, gap, mut fails) = judge(&out, scenario.admissible_hole());
        if !(out.producer_clean && out.consumer_clean) {
            fails.push("a delayed victim did not finish clean".into());
        }
        if (out.delivered.len() as u64) < messages {
            fails.push(format!(
                "delayed run delivered {}/{messages} in-band",
                out.delivered.len()
            ));
        }
        if out.confirms != 0 {
            fails.push(format!(
                "watchdog confirmed {} merely-delayed node(s) dead",
                out.confirms
            ));
        }
        if !(out.prod_alive && out.cons_alive) {
            fails.push("a delayed-but-alive node ended the run declared dead".into());
        }
        pass &= fails.is_empty();
        lines.push(fmt_line(
            &format!(
                "  delay@{k} suspects={} false_suspects={}",
                out.suspects, out.false_suspects
            ),
            &out,
            committed,
            gap,
            &fails,
        ));
    }
    lines.push(format!("sweep verdict={}", if pass { "PASS" } else { "FAIL" }));
    ChaosReport { text: lines.join("\n"), pass }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_free_run_delivers_everything() {
        for scenario in
            [Scenario::Pkt, Scenario::Msg, Scenario::Sclr, Scenario::PktBatch]
        {
            let out = run_scenario(scenario, FaultPlan::new(), 12, 2_000_000);
            let (committed, gap, fails) = judge(&out, scenario.admissible_hole());
            assert!(fails.is_empty(), "{scenario:?}: {fails:?}");
            assert_eq!(committed, 12, "{scenario:?}");
            assert_eq!(gap, 0, "{scenario:?}");
            assert_eq!(out.delivered.len(), 12, "{scenario:?}");
            assert!(out.producer_clean && out.consumer_clean, "{scenario:?}");
            assert!(
                out.prod_window.is_some() && out.cons_window.is_some(),
                "{scenario:?}"
            );
        }
    }

    #[test]
    fn seeded_runs_pass_and_reproduce_byte_for_byte() {
        for scenario in [Scenario::Pkt, Scenario::Msg] {
            for seed in 1..=4u64 {
                let opts = ChaosOpts { scenario, seed, messages: 12, ..Default::default() };
                let a = run_seeded(&opts);
                assert!(a.pass, "seed {seed} {scenario:?}: {}", a.text);
                let b = run_seeded(&opts);
                assert_eq!(a.text, b.text, "seed {seed} report must reproduce exactly");
            }
        }
    }

    #[test]
    fn frame_checksum_catches_corruption() {
        let f = frame(7);
        assert_eq!(parse_frame(&f), Some(7));
        let mut bad = f;
        bad[3] ^= 0x40;
        assert_eq!(parse_frame(&bad), None);
        assert_eq!(parse_frame(&f[..12]), None);
    }

    #[test]
    fn scalar_frame_checksum_catches_corruption() {
        let v = sclr_frame(9);
        assert_eq!(parse_sclr(v), Some(9));
        assert_eq!(parse_sclr(v ^ 0x10), None);
        assert_eq!(parse_sclr(v ^ (0x10 << 32)), None);
    }

    #[test]
    fn delay_sweep_never_declares_a_live_node() {
        let r = run_delay_sweep(Scenario::Pkt, Victim::Producer, 12, 40_000);
        assert!(r.pass, "{}", r.text);
    }

    #[test]
    fn armed_watchdog_coexists_with_seeded_faults() {
        let cfg = LivenessCfg { deadline_ns: 200_000, confirm_scans: 3 };
        for seed in 1..=3u64 {
            let plan = FaultPlan::from_seed(seed, 2, 400);
            let out = run_scenario_with(Scenario::Pkt, plan, 12, 2_000_000, Some(cfg));
            let (_, _, fails) = judge(&out, Scenario::Pkt.admissible_hole());
            assert!(fails.is_empty(), "seed {seed}: {fails:?}");
        }
    }

    #[test]
    fn seeded_runs_pass_on_new_scenarios() {
        for scenario in [Scenario::Sclr, Scenario::PktBatch] {
            for seed in 1..=2u64 {
                let opts = ChaosOpts { scenario, seed, messages: 12, ..Default::default() };
                let a = run_seeded(&opts);
                assert!(a.pass, "seed {seed} {scenario:?}: {}", a.text);
                let b = run_seeded(&opts);
                assert_eq!(a.text, b.text, "seed {seed} report must reproduce exactly");
            }
        }
    }
}
