//! Declarative message topologies.
//!
//! "The communication paths and directions are configured by a
//! declarative message topology designed by the authors, and each
//! operation is marked with a monotonically increasing transaction ID so
//! it can be tracked to completion."

use crate::mcapi::types::EndpointId;
use crate::util::config::Document;
use crate::{Error, Result};

/// Channel payload type in a topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MsgKind {
    /// Connection-less message.
    Message,
    /// Connected packet channel.
    Packet,
    /// Connected scalar channel.
    Scalar,
    /// Connected state channel (NBW; order indeterminate, newest wins).
    /// Extension of the paper's §7 future work.
    State,
}

impl MsgKind {
    /// Parse from config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "message" | "msg" => Some(Self::Message),
            "packet" | "pkt" => Some(Self::Packet),
            "scalar" | "sclr" => Some(Self::Scalar),
            "state" | "nbw" => Some(Self::State),
            _ => None,
        }
    }

    /// Stable report label.
    pub fn label(&self) -> &'static str {
        match self {
            Self::Message => "message",
            Self::Packet => "packet",
            Self::Scalar => "scalar",
            Self::State => "state",
        }
    }

    /// The paper's three FIFO kinds (matrix iteration; `State` is the
    /// §7 extension and is excluded from the paper's matrix).
    pub fn all() -> [MsgKind; 3] {
        [Self::Message, Self::Packet, Self::Scalar]
    }
}

/// One directed channel in a topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChannelSpec {
    /// Sending node (dense id) and port.
    pub from: (u16, u16),
    /// Receiving node (dense id) and port.
    pub to: (u16, u16),
    /// Payload type.
    pub kind: MsgKind,
    /// Transactions to exchange (IDs 1..=count).
    pub count: u64,
}

impl ChannelSpec {
    /// Receive-side endpoint id (domain 0 convention).
    pub fn rx_endpoint(&self) -> EndpointId {
        EndpointId::new(0, self.to.0, self.to.1)
    }

    /// Send-side endpoint id.
    pub fn tx_endpoint(&self) -> EndpointId {
        EndpointId::new(0, self.from.0, self.from.1)
    }
}

/// A full topology: the channel list plus the node set it implies.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    /// Directed channels.
    pub channels: Vec<ChannelSpec>,
}

impl Topology {
    /// The simple example from Section 4: one one-way channel between two
    /// nodes, 1000 transactions.
    pub fn one_way(kind: MsgKind, count: u64) -> Self {
        Topology {
            channels: vec![ChannelSpec { from: (0, 1), to: (1, 1), kind, count }],
        }
    }

    /// A ping/pong pair of one-way channels (bidirectional stress).
    pub fn ping_pong(kind: MsgKind, count: u64) -> Self {
        Topology {
            channels: vec![
                ChannelSpec { from: (0, 1), to: (1, 1), kind, count },
                ChannelSpec { from: (1, 2), to: (0, 2), kind, count },
            ],
        }
    }

    /// Fan-in: `n` producers to one consumer (tests MPSC composition).
    pub fn fan_in(n: u16, kind: MsgKind, count: u64) -> Self {
        Topology {
            channels: (0..n)
                .map(|i| ChannelSpec { from: (i + 1, 1), to: (0, 100 + i), kind, count })
                .collect(),
        }
    }

    /// Dense node ids participating (sorted, deduplicated).
    pub fn nodes(&self) -> Vec<u16> {
        let mut ids: Vec<u16> = self
            .channels
            .iter()
            .flat_map(|c| [c.from.0, c.to.0])
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids
    }

    /// Total transactions across channels.
    pub fn total_transactions(&self) -> u64 {
        self.channels.iter().map(|c| c.count).sum()
    }

    /// Parse from the TOML-subset format:
    ///
    /// ```toml
    /// [[channel]]
    /// from = "0:1"      # node:port
    /// to = "1:1"
    /// kind = "message"  # message | packet | scalar
    /// count = 1000
    /// ```
    pub fn parse(text: &str) -> Result<Self> {
        let doc = Document::parse(text)?;
        let specs = doc
            .arrays
            .get("channel")
            .ok_or_else(|| Error::Config("topology needs at least one [[channel]]".into()))?;
        let mut channels = Vec::new();
        for (i, t) in specs.iter().enumerate() {
            let ctx = |m: &str| Error::Config(format!("[[channel]] #{}: {}", i + 1, m));
            let ep = |key: &str| -> Result<(u16, u16)> {
                let s = t
                    .get(key)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| ctx(&format!("missing `{key} = \"node:port\"`")))?;
                let (n, p) = s
                    .split_once(':')
                    .ok_or_else(|| ctx(&format!("`{key}` must be \"node:port\", got `{s}`")))?;
                Ok((
                    n.parse().map_err(|_| ctx(&format!("bad node in `{s}`")))?,
                    p.parse().map_err(|_| ctx(&format!("bad port in `{s}`")))?,
                ))
            };
            let kind = t
                .get("kind")
                .and_then(|v| v.as_str())
                .and_then(MsgKind::parse)
                .ok_or_else(|| ctx("missing/invalid `kind` (message|packet|scalar)"))?;
            let count = t
                .get("count")
                .map(|v| v.as_int().ok_or_else(|| ctx("`count` must be an integer")))
                .transpose()?
                .unwrap_or(1000) as u64;
            let from = ep("from")?;
            let to = ep("to")?;
            if from == to {
                return Err(ctx("channel endpoints must differ"));
            }
            channels.push(ChannelSpec { from, to, kind, count });
        }
        Ok(Topology { channels })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_shape() {
        let t = Topology::one_way(MsgKind::Message, 1000);
        assert_eq!(t.channels.len(), 1);
        assert_eq!(t.nodes(), vec![0, 1]);
        assert_eq!(t.total_transactions(), 1000);

        let p = Topology::ping_pong(MsgKind::Scalar, 10);
        assert_eq!(p.channels.len(), 2);
        assert_eq!(p.nodes(), vec![0, 1]);

        let f = Topology::fan_in(3, MsgKind::Packet, 5);
        assert_eq!(f.nodes(), vec![0, 1, 2, 3]);
        assert_eq!(f.total_transactions(), 15);
    }

    #[test]
    fn parse_full_topology() {
        let t = Topology::parse(
            r#"
            # two channels
            [[channel]]
            from = "0:1"
            to = "1:1"
            kind = "message"
            count = 500
            [[channel]]
            from = "1:2"
            to = "0:2"
            kind = "scalar"
            "#,
        )
        .unwrap();
        assert_eq!(t.channels.len(), 2);
        assert_eq!(t.channels[0].count, 500);
        assert_eq!(t.channels[1].count, 1000, "count defaults to 1000");
        assert_eq!(t.channels[1].kind, MsgKind::Scalar);
        assert_eq!(t.channels[0].rx_endpoint(), EndpointId::new(0, 1, 1));
    }

    #[test]
    fn parse_errors_are_specific() {
        let e = Topology::parse("x = 1").unwrap_err().to_string();
        assert!(e.contains("[[channel]]"), "{e}");
        let e = Topology::parse("[[channel]]\nfrom = \"0:1\"\nto = \"0:1\"\nkind = \"msg\"")
            .unwrap_err()
            .to_string();
        assert!(e.contains("must differ"), "{e}");
        let e = Topology::parse("[[channel]]\nfrom = \"0-1\"\nto = \"1:1\"\nkind = \"msg\"")
            .unwrap_err()
            .to_string();
        assert!(e.contains("node:port"), "{e}");
    }

    #[test]
    fn kind_parse_labels() {
        for k in MsgKind::all() {
            assert_eq!(MsgKind::parse(k.label()), Some(k));
        }
    }
}
