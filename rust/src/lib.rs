//! # mcapi-lockfree
//!
//! Reproduction of *"Performance Impact of Lock-Free Algorithms on Multicore
//! Communication APIs"* (K. Eric Harper, Thijmen de Gooijer, ABB Corporate
//! Research, 2014).
//!
//! The crate implements, from scratch:
//!
//! * [`os`] — the portability layer the paper's MRAPI port needed: atomics,
//!   CPU affinity, timed delay/yield, and parameterised OS *cost profiles*
//!   (Linux-with-rt-extensions vs. Windows Server) used by the simulator.
//! * [`lockfree`] — the paper's algorithm toolbox: the Kopetz non-blocking
//!   write protocol (NBW), the Kim non-blocking buffer (NBB), the lock-free
//!   bit-set request allocator, buffer free-lists and atomic finite state
//!   machines.
//! * [`mrapi`] — the Multicore Resource Management API substrate: shared
//!   memory partitions, user-mode reader/writer locks over a single kernel
//!   lock (the *lock-based baseline*), semaphores, nodes/domains and
//!   resource trees.
//! * [`mcapi`] — the Multicore Communications API: connection-less messages,
//!   packet channels and scalar channels, with *both* the lock-based
//!   reference backend and the refactored lock-free backend.
//! * [`sim`] — a deterministic discrete-event SMP simulator (virtual cores,
//!   MESI-like cache-line directory, memory-bus queue, futex/kernel-lock
//!   costs, scheduling quanta and affinity) used to reproduce the paper's
//!   single-core vs. multicore matrix on hosts with any core count.
//! * [`coordinator`] — the stress-test harness: declarative topologies,
//!   client/server node loops with transaction IDs, the experiment matrix
//!   behind Table 2 and Figures 7/8, and report printers.
//! * [`model`] — the Queueing-Petri-Net–style performance model (Section 5):
//!   a native mean-value-analysis solver plus a bridge that executes the
//!   JAX/Pallas-authored model AOT-compiled to an XLA artifact.
//! * [`runtime`] — the PJRT bridge: loads `artifacts/*.hlo.txt` produced by
//!   `python/compile/aot.py` and executes them from Rust.
//! * [`harness`] — a small statistics/benchmark framework (criterion-like)
//!   used by `cargo bench` targets, built in-tree because the reproduction
//!   is fully offline.
//! * [`obs`] — the zero-perturbation observability plane: per-lane lock-free
//!   event rings, per-channel stage-latency histograms (send→commit→
//!   doorbell→wakeup→recv), a named counter registry, chrome-trace/NDJSON
//!   exporters and a trace-replay invariant checker. Gated by the
//!   `obs-trace` feature (default on) + a runtime enable (default off);
//!   adds zero priced simulator operations either way.
//! * [`util`] — hand-rolled substrates: PRNG, histogram, TOML-subset config
//!   parser, property-testing helper and CLI argument parsing.
//!
//! Python (`python/compile/`) authors the L2 queueing model and the L1
//! Pallas kernel; it runs only at build time (`make artifacts`) and never on
//! the request path.

pub mod coordinator;
pub mod harness;
pub mod lockfree;
pub mod mcapi;
pub mod model;
pub mod mrapi;
pub mod obs;
pub mod os;
pub mod runtime;
pub mod sim;
pub mod util;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Crate-wide error type. (Hand-rolled `Display`/`Error` impls: the
/// reproduction builds fully offline, so no `thiserror`.)
#[derive(Debug)]
pub enum Error {
    /// MCAPI status code mapped to an error (anything except `Success`).
    Status(crate::mcapi::types::Status),
    /// Configuration / topology parse problem.
    Config(String),
    /// PJRT / XLA runtime problem.
    Runtime(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Status(s) => write!(f, "mcapi status: {s:?}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Io(e) => e.fmt(f),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}
