//! Minimal benchmark harness (offline substitute for criterion).
//!
//! Two modes:
//!
//! * [`time_fn`] — wall-clock micro-benchmarks: warmup, N timed
//!   iterations, robust statistics. Used for the real-host lock-free
//!   structure benches.
//! * deterministic experiment benches (the Table 2 / Figure benches) run
//!   their workload once on the simulator — virtual time is exact, so no
//!   repetition is needed — and print the paper-shaped tables via the
//!   printers in [`crate::coordinator::experiment`].

use std::time::Instant;

/// Statistics over per-iteration nanosecond samples.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark label.
    pub name: String,
    /// Timed iterations.
    pub iters: u64,
    /// Mean ns/iter.
    pub mean_ns: f64,
    /// Median ns/iter.
    pub p50_ns: u64,
    /// 99th percentile ns/iter.
    pub p99_ns: u64,
    /// Fastest iteration.
    pub min_ns: u64,
    /// Slowest iteration.
    pub max_ns: u64,
    /// Standard deviation.
    pub stddev_ns: f64,
}

impl BenchStats {
    /// Throughput in operations per second implied by the mean.
    pub fn ops_per_sec(&self) -> f64 {
        if self.mean_ns == 0.0 {
            0.0
        } else {
            1e9 / self.mean_ns
        }
    }

    /// One markdown row: `| name | mean | p50 | p99 | min | ops/s |`.
    pub fn row(&self) -> String {
        format!(
            "| {} | {:.0} | {} | {} | {} | {:.0} |",
            self.name, self.mean_ns, self.p50_ns, self.p99_ns, self.min_ns,
            self.ops_per_sec()
        )
    }
}

/// Markdown header matching [`BenchStats::row`].
pub fn header() -> String {
    "| bench | mean ns | p50 | p99 | min | ops/s |\n|---|---|---|---|---|---|".into()
}

/// Time `f` for `iters` iterations after `warmup` untimed ones.
///
/// `f` receives the iteration index; its return value is black-boxed so
/// the optimizer cannot elide the work.
pub fn time_fn<R>(name: &str, warmup: u64, iters: u64, mut f: impl FnMut(u64) -> R) -> BenchStats {
    assert!(iters > 0);
    for i in 0..warmup {
        std::hint::black_box(f(i));
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for i in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f(i));
        samples.push(t0.elapsed().as_nanos() as u64);
    }
    stats_from(name, samples)
}

/// Time `f` once per batch of `batch` inner operations — for operations
/// too fast to time individually. Reports per-operation statistics.
pub fn time_batched<R>(
    name: &str,
    warmup: u64,
    batches: u64,
    batch: u64,
    mut f: impl FnMut(u64) -> R,
) -> BenchStats {
    assert!(batches > 0 && batch > 0);
    for i in 0..warmup {
        std::hint::black_box(f(i));
    }
    let mut samples = Vec::with_capacity(batches as usize);
    let mut n = 0u64;
    for _ in 0..batches {
        let t0 = Instant::now();
        for _ in 0..batch {
            std::hint::black_box(f(n));
            n += 1;
        }
        samples.push((t0.elapsed().as_nanos() as u64) / batch);
    }
    stats_from(name, samples)
}

fn stats_from(name: &str, mut samples: Vec<u64>) -> BenchStats {
    samples.sort_unstable();
    let n = samples.len() as u64;
    let sum: u128 = samples.iter().map(|&s| s as u128).sum();
    let mean = sum as f64 / n as f64;
    let var = samples
        .iter()
        .map(|&s| {
            let d = s as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n as f64;
    let q = |p: f64| samples[(((n - 1) as f64 * p).round() as usize).min(samples.len() - 1)];
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_ns: mean,
        p50_ns: q(0.50),
        p99_ns: q(0.99),
        min_ns: samples[0],
        max_ns: *samples.last().unwrap(),
        stddev_ns: var.sqrt(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_produces_ordered_stats() {
        let s = time_fn("spin", 5, 50, |i| {
            let mut acc = i;
            for _ in 0..100 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(1);
            }
            acc
        });
        assert_eq!(s.iters, 50);
        assert!(s.min_ns <= s.p50_ns && s.p50_ns <= s.p99_ns && s.p99_ns <= s.max_ns);
        assert!(s.mean_ns > 0.0);
        assert!(s.ops_per_sec() > 0.0);
    }

    #[test]
    fn batched_reports_per_op() {
        let s = time_batched("noop", 1, 10, 1000, |i| i);
        assert!(s.mean_ns < 1_000.0, "per-op mean should be tiny: {}", s.mean_ns);
    }

    #[test]
    fn row_is_markdown() {
        let s = time_fn("x", 0, 3, |i| i);
        assert!(s.row().starts_with("| x |"));
        assert!(header().contains("ops/s"));
    }

    #[test]
    #[should_panic]
    fn zero_iters_rejected() {
        time_fn("x", 0, 0, |i| i);
    }
}
