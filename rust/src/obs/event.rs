//! Fixed-size binary trace records.
//!
//! Every event the hot paths emit is one 32-byte little-endian record —
//! fixed size so the per-lane rings can carry them without allocation,
//! torn-read-free slot copies, or length framing. The encoding is the
//! wire/disk format too: the NDJSON and chrome-trace exporters decode
//! from exactly these bytes, and the round-trip is property-tested.

/// Encoded record size in bytes (half a cache line: two records per
/// line keeps the ring slot array dense without straddling).
pub const RECORD_LEN: usize = 32;

/// Channel-id namespace bit: ids with this bit set are **endpoint**
/// indices (connectionless queue / endpoint wait cells), not connected-
/// channel indices. Keeps one `u32` id space for both tables.
pub const CH_ENDPOINT_BIT: u32 = 1 << 24;

/// "No channel attribution" sentinel.
pub const CH_NONE: u32 = u32::MAX;

/// What happened. The first five kinds are the per-message stage marks
/// the collector pairs into the four stage-latency histograms:
///
/// ```text
/// SendEnter --(send_commit)--> SendCommit --(commit_doorbell)-->
/// DoorbellSet --(doorbell_wakeup)--> Wakeup --(wakeup_recv)--> RecvReturn
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum EventKind {
    /// Sender entered the channel API (before the ring insert).
    SendEnter = 1,
    /// Ring publish: the producer's even counter store made the payload
    /// visible.
    SendCommit = 2,
    /// Doorbell bit set for the channel (receiver can now see it).
    DoorbellSet = 3,
    /// Receiver observed the payload available (first successful probe).
    Wakeup = 4,
    /// Payload handed back to the receiving caller.
    RecvReturn = 5,
    /// Connectionless queue push committed (aux = priority).
    QueuePush = 6,
    /// Connectionless queue pop returned an entry.
    QueuePop = 7,
    /// Blocking path parked on its wait cell (aux = yields beforehand).
    BlockPark = 8,
    /// Blocking path woke from its wait cell.
    BlockUnpark = 9,
    /// MPMC producer won a slot claim (seq = claimed position, aux =
    /// run length: 1 for a single send, k for a batched claim).
    MpmcClaim = 10,
    /// MPMC slot published (sequence word released to consumers).
    MpmcPublish = 11,
    /// MPMC consumer won a slot claim — "stole" the position from the
    /// other consumers in the group.
    MpmcSteal = 12,
}

impl EventKind {
    /// Inverse of the `repr(u8)` discriminant; `None` for junk bytes.
    pub fn from_u8(v: u8) -> Option<Self> {
        Some(match v {
            1 => Self::SendEnter,
            2 => Self::SendCommit,
            3 => Self::DoorbellSet,
            4 => Self::Wakeup,
            5 => Self::RecvReturn,
            6 => Self::QueuePush,
            7 => Self::QueuePop,
            8 => Self::BlockPark,
            9 => Self::BlockUnpark,
            10 => Self::MpmcClaim,
            11 => Self::MpmcPublish,
            12 => Self::MpmcSteal,
            _ => return None,
        })
    }

    /// Stable export label (NDJSON `kind`, chrome-trace `name`).
    pub fn label(self) -> &'static str {
        match self {
            Self::SendEnter => "send_enter",
            Self::SendCommit => "send_commit",
            Self::DoorbellSet => "doorbell_set",
            Self::Wakeup => "wakeup",
            Self::RecvReturn => "recv_return",
            Self::QueuePush => "queue_push",
            Self::QueuePop => "queue_pop",
            Self::BlockPark => "block_park",
            Self::BlockUnpark => "block_unpark",
            Self::MpmcClaim => "mpmc_claim",
            Self::MpmcPublish => "mpmc_publish",
            Self::MpmcSteal => "mpmc_steal",
        }
    }

    /// Every kind, for exhaustive round-trip tests.
    pub fn all() -> [Self; 12] {
        [
            Self::SendEnter,
            Self::SendCommit,
            Self::DoorbellSet,
            Self::Wakeup,
            Self::RecvReturn,
            Self::QueuePush,
            Self::QueuePop,
            Self::BlockPark,
            Self::BlockUnpark,
            Self::MpmcClaim,
            Self::MpmcPublish,
            Self::MpmcSteal,
        ]
    }
}

/// One decoded trace event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// What happened.
    pub kind: EventKind,
    /// Channel id (or `CH_ENDPOINT_BIT | endpoint`, or `CH_NONE`).
    pub channel: u32,
    /// Per-channel message sequence (ring `update/2` message index for
    /// the stage kinds; a monotonic per-queue counter for queue kinds).
    pub seq: u64,
    /// Timestamp: `World::timestamp_peek()` nanoseconds — wall clock on
    /// the real plane, the emitting task's virtual clock on the sim.
    pub ts_ns: u64,
    /// Kind-specific extra (payload length, batch count, priority, ...).
    pub aux: u32,
    /// Originating lane (per-thread ring index). Not part of the wire
    /// record — the collector fills it in at drain time from which ring
    /// the record came out of.
    pub lane: u32,
}

impl Event {
    /// Encode to the fixed 32-byte wire record (lane is *not* encoded).
    ///
    /// Layout (little-endian):
    /// `[0] kind | [1..4] zero | [4..8] channel | [8..16] seq |
    ///  [16..24] ts_ns | [24..28] aux | [28..32] zero`
    pub fn encode(&self) -> [u8; RECORD_LEN] {
        let mut b = [0u8; RECORD_LEN];
        b[0] = self.kind as u8;
        b[4..8].copy_from_slice(&self.channel.to_le_bytes());
        b[8..16].copy_from_slice(&self.seq.to_le_bytes());
        b[16..24].copy_from_slice(&self.ts_ns.to_le_bytes());
        b[24..28].copy_from_slice(&self.aux.to_le_bytes());
        b
    }

    /// Decode a wire record; `None` when the kind byte is invalid (a
    /// corrupt or torn record must never silently become an event).
    pub fn decode(b: &[u8; RECORD_LEN]) -> Option<Event> {
        let kind = EventKind::from_u8(b[0])?;
        Some(Event {
            kind,
            channel: u32::from_le_bytes(b[4..8].try_into().unwrap()),
            seq: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            ts_ns: u64::from_le_bytes(b[16..24].try_into().unwrap()),
            aux: u32::from_le_bytes(b[24..28].try_into().unwrap()),
            lane: 0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_every_kind_and_extremes() {
        for kind in EventKind::all() {
            for (channel, seq, ts_ns, aux) in [
                (0u32, 0u64, 0u64, 0u32),
                (3, 7, 1_234_567_890, 24),
                (CH_ENDPOINT_BIT | 12, u64::MAX, u64::MAX, u32::MAX),
                (CH_NONE, 1 << 63, 1, 1),
            ] {
                let ev = Event { kind, channel, seq, ts_ns, aux, lane: 0 };
                let rec = ev.encode();
                assert_eq!(Event::decode(&rec), Some(ev), "{kind:?}");
            }
        }
    }

    #[test]
    fn junk_kind_bytes_are_rejected() {
        let mut rec = Event {
            kind: EventKind::SendCommit,
            channel: 1,
            seq: 2,
            ts_ns: 3,
            aux: 4,
            lane: 0,
        }
        .encode();
        rec[0] = 0;
        assert_eq!(Event::decode(&rec), None);
        rec[0] = 200;
        assert_eq!(Event::decode(&rec), None);
    }

    #[test]
    fn record_is_exactly_32_bytes_and_reserved_bytes_zero() {
        let rec = Event {
            kind: EventKind::Wakeup,
            channel: u32::MAX,
            seq: u64::MAX,
            ts_ns: u64::MAX,
            aux: u32::MAX,
            lane: 9,
        }
        .encode();
        assert_eq!(rec.len(), RECORD_LEN);
        assert_eq!(&rec[1..4], &[0, 0, 0], "reserved bytes must stay zero");
        assert_eq!(&rec[28..32], &[0, 0, 0, 0]);
    }
}
