//! Named monotonic counter registry.
//!
//! Generalizes the ad-hoc per-runtime `stat_timeouts` /` stat_poisons` /
//! `stat_leases_reclaimed` fields into one process-wide table: hot paths
//! bump a pre-registered counter by index (one relaxed host-atomic add —
//! never a priced operation), exporters snapshot the whole table by
//! name. The per-runtime accessors (`timeouts_observed()` & co.) stay as
//! the per-instance ground truth — this registry is the *process* view
//! the `trace` CLI and metrics snapshot export.
//!
//! Cells are pre-allocated (`MAX_COUNTERS`) and padded so bumping one
//! counter never takes a lock or false-shares with its neighbours; the
//! name table behind a mutex is touched only by `register`/`snapshot`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::lockfree::CachePadded;

/// Well-known counter ids, registered (in this order) by
/// [`CounterRegistry::new`], so hot paths bump by constant index.
pub mod ctr {
    /// NBB `insert` committed.
    pub const NBB_INSERT: usize = 0;
    /// NBB `read` returned an item.
    pub const NBB_READ: usize = 1;
    /// NBB insert rejected: ring full (either Table 1 flavour).
    pub const NBB_FULL: usize = 2;
    /// NBB read found nothing (either Table 1 flavour).
    pub const NBB_EMPTY: usize = 3;
    /// Connected-channel ring publishes (messages + scalars).
    pub const RING_SEND: usize = 4;
    /// Connected-channel ring consumptions.
    pub const RING_RECV: usize = 5;
    /// Lock-free queue pushes committed.
    pub const QUEUE_PUSH: usize = 6;
    /// Lock-free queue pops returned an entry.
    pub const QUEUE_POP: usize = 7;
    /// Doorbell bits set after a publish.
    pub const DOORBELL_SET: usize = 8;
    /// Doorbell clear-then-recheck round trips that re-set the bit.
    pub const DOORBELL_RECHECK: usize = 9;
    /// Blocking waits that escalated to a futex park.
    pub const BLOCK_PARKS: usize = 10;
    /// Waits that expired with `Status::Timeout`.
    pub const TIMEOUTS: usize = 11;
    /// Operations that surfaced `Status::EndpointDead`.
    pub const POISONS: usize = 12;
    /// Pool leases reclaimed from dead nodes.
    pub const LEASES_RECLAIMED: usize = 13;
    /// Trace records dropped on lane-ring overflow (mirrored at drain).
    pub const TRACE_DROPPED: usize = 14;
    /// MPMC ring slots published (singles + batch members).
    pub const MPMC_PUBLISH: usize = 15;
    /// MPMC ring payloads consumed (tombstone skips excluded).
    pub const MPMC_CONSUME: usize = 16;
    /// MPMC wedged-claim repairs (tombstones + salvages).
    pub const MPMC_REPAIRS: usize = 17;
    /// Watchdog suspect scans (a node over its silence deadline).
    pub const LIVENESS_SUSPECTS: usize = 18;
    /// Watchdog confirmations (each ran `declare_node_dead`).
    pub const LIVENESS_CONFIRMS: usize = 19;
    /// Suspects cleared by later progress (deadline tuned too tight).
    pub const LIVENESS_FALSE_SUSPECTS: usize = 20;
    /// Operations rejected with `Status::NodeFenced`.
    pub const LIVENESS_FENCE_REJECTS: usize = 21;
    /// Doorbell wake-one fallbacks: a woken member found nothing and
    /// re-rang the bell (proves wake-one loses no wakeups).
    pub const WAKE_MISSES: usize = 22;
    /// Sharded-MPMC steal batches committed (one per `ack` advance).
    pub const MPMC_STEALS: usize = 23;

    /// `(id, name)` for every builtin, in registration order.
    pub const BUILTIN: [(usize, &str); 24] = [
        (NBB_INSERT, "nbb.insert"),
        (NBB_READ, "nbb.read"),
        (NBB_FULL, "nbb.full"),
        (NBB_EMPTY, "nbb.empty"),
        (RING_SEND, "ring.send"),
        (RING_RECV, "ring.recv"),
        (QUEUE_PUSH, "queue.push"),
        (QUEUE_POP, "queue.pop"),
        (DOORBELL_SET, "doorbell.set"),
        (DOORBELL_RECHECK, "doorbell.recheck"),
        (BLOCK_PARKS, "block.parks"),
        (TIMEOUTS, "timeouts"),
        (POISONS, "poisons"),
        (LEASES_RECLAIMED, "leases.reclaimed"),
        (TRACE_DROPPED, "trace.dropped"),
        (MPMC_PUBLISH, "mpmc.publish"),
        (MPMC_CONSUME, "mpmc.consume"),
        (MPMC_REPAIRS, "mpmc.repairs"),
        (LIVENESS_SUSPECTS, "liveness.suspects"),
        (LIVENESS_CONFIRMS, "liveness.confirms"),
        (LIVENESS_FALSE_SUSPECTS, "liveness.false_suspects"),
        (LIVENESS_FENCE_REJECTS, "liveness.fence_rejects"),
        (WAKE_MISSES, "wake.misses"),
        (MPMC_STEALS, "mpmc.steals"),
    ];
}

/// Maximum counters the registry can hold (builtins + dynamic).
pub const MAX_COUNTERS: usize = 64;

/// Process-wide monotonic counter table.
pub struct CounterRegistry {
    /// Registered names, index == counter id.
    names: Mutex<Vec<String>>,
    /// Value cells — always `MAX_COUNTERS`, so `bump` is lock-free.
    cells: Vec<CachePadded<AtomicU64>>,
}

impl CounterRegistry {
    /// Registry pre-seeded with the [`ctr`] builtins.
    pub fn new() -> Self {
        let names = ctr::BUILTIN.iter().map(|(_, n)| n.to_string()).collect::<Vec<_>>();
        debug_assert!(names.len() <= MAX_COUNTERS);
        CounterRegistry {
            names: Mutex::new(names),
            cells: (0..MAX_COUNTERS).map(|_| CachePadded::new(AtomicU64::new(0))).collect(),
        }
    }

    /// Register a counter by name; returns its id, or the existing id if
    /// the name is already taken (idempotent). `None` once the table is
    /// full — callers must not silently lose a counter, so they should
    /// surface this (it cannot happen with the builtin set alone).
    pub fn register(&self, name: &str) -> Option<usize> {
        let mut names = self.names.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(id) = names.iter().position(|n| n == name) {
            return Some(id);
        }
        if names.len() >= MAX_COUNTERS {
            return None;
        }
        names.push(name.to_string());
        Some(names.len() - 1)
    }

    /// Add 1 to counter `id` (relaxed host atomic — never priced).
    #[inline]
    pub fn bump(&self, id: usize) {
        self.add(id, 1);
    }

    /// Add `n` to counter `id`.
    #[inline]
    pub fn add(&self, id: usize, n: u64) {
        if let Some(cell) = self.cells.get(id) {
            cell.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Current value of counter `id`.
    pub fn get(&self, id: usize) -> u64 {
        self.cells.get(id).map_or(0, |c| c.load(Ordering::Relaxed))
    }

    /// `(name, value)` for every registered counter, in id order.
    pub fn snapshot(&self) -> Vec<(String, u64)> {
        let names = self.names.lock().unwrap_or_else(|e| e.into_inner());
        names
            .iter()
            .enumerate()
            .map(|(i, n)| (n.clone(), self.cells[i].load(Ordering::Relaxed)))
            .collect()
    }

    /// Zero every value (session reset; names stay registered).
    pub fn reset(&self) {
        for c in &self.cells {
            c.store(0, Ordering::Relaxed);
        }
    }
}

impl Default for CounterRegistry {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_are_preregistered_in_id_order() {
        let r = CounterRegistry::new();
        let snap = r.snapshot();
        for (id, name) in ctr::BUILTIN {
            assert_eq!(snap[id].0, name);
            assert_eq!(snap[id].1, 0);
        }
    }

    #[test]
    fn bump_add_get_and_reset() {
        let r = CounterRegistry::new();
        r.bump(ctr::TIMEOUTS);
        r.add(ctr::TIMEOUTS, 4);
        assert_eq!(r.get(ctr::TIMEOUTS), 5);
        r.reset();
        assert_eq!(r.get(ctr::TIMEOUTS), 0);
    }

    #[test]
    fn dynamic_registration_is_idempotent_and_bounded() {
        let r = CounterRegistry::new();
        let a = r.register("my.subsystem.widgets").unwrap();
        let b = r.register("my.subsystem.widgets").unwrap();
        assert_eq!(a, b);
        assert!(a >= ctr::BUILTIN.len());
        r.bump(a);
        assert_eq!(r.get(a), 1);
        // Existing names keep resolving even once the table fills.
        let mut filled = 0;
        for i in 0..MAX_COUNTERS {
            if r.register(&format!("filler.{i}")).is_some() {
                filled += 1;
            }
        }
        assert!(filled < MAX_COUNTERS, "table must eventually report full");
        assert_eq!(r.register("my.subsystem.widgets"), Some(a));
        assert_eq!(r.register("one.too.many"), None);
    }

    #[test]
    fn out_of_range_ids_are_inert() {
        let r = CounterRegistry::new();
        r.bump(MAX_COUNTERS + 5); // must not panic
        assert_eq!(r.get(MAX_COUNTERS + 5), 0);
    }
}
