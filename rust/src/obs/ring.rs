//! Per-lane SPSC event ring — the tracing plane dogfooding the repo's
//! own ring design.
//!
//! Same counter discipline as [`crate::lockfree::ring::ChannelRing`]
//! (padded head/tail on separate lines, the producer re-loads the
//! consumer's counter only on apparent full), but built on **plain
//! `std::sync::atomic`** words: host-side atomics are the one kind of
//! memory the simulator never prices, so pushing a trace event costs
//! zero priced operations — the whole point of the plane. Producer is
//! the thread that owns the lane (each emitting thread gets its own
//! ring, see [`super`]); consumer is the collector draining it.
//!
//! Overflow is **never silent**: when the ring is full the record is
//! dropped and the `dropped` counter incremented — exactly one bump per
//! lost record, asserted by the overflow-accounting test.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::lockfree::CachePadded;

use super::event::RECORD_LEN;

/// Lock-free SPSC ring of encoded 32-byte trace records.
pub struct EventRing {
    /// Producer counter: records ever pushed (writer-owned line).
    head: CachePadded<AtomicU64>,
    /// Consumer counter: records ever popped (reader-owned line).
    tail: CachePadded<AtomicU64>,
    /// Producer-private snapshot of `tail`, re-loaded only on apparent
    /// full (an atomic only so the ring stays `Sync`; one writer).
    cached_tail: CachePadded<AtomicU64>,
    /// Records dropped on overflow — exact, monotonic.
    dropped: AtomicU64,
    /// Peak occupancy ever observed by the producer at push time — the
    /// per-lane drop *watermark*: how close the lane came to (or how
    /// far past) overflow. Written only by the producer (plain
    /// load/max/store is race-free), read by the metrics exporter.
    high_water: AtomicU64,
    slots: Box<[UnsafeCell<[u8; RECORD_LEN]>]>,
    cap: u64,
}

// The head/tail protocol guarantees the producer and consumer never
// address the same slot (standard SPSC argument: tail <= head <= tail+cap
// and each side only advances its own counter after its slot access).
unsafe impl Sync for EventRing {}
unsafe impl Send for EventRing {}

impl EventRing {
    /// Ring with `cap` record slots (`cap >= 1`).
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "event ring capacity must be >= 1");
        let slots = (0..cap)
            .map(|_| UnsafeCell::new([0u8; RECORD_LEN]))
            .collect::<Vec<_>>()
            .into_boxed_slice();
        EventRing {
            head: CachePadded::new(AtomicU64::new(0)),
            tail: CachePadded::new(AtomicU64::new(0)),
            cached_tail: CachePadded::new(AtomicU64::new(0)),
            dropped: AtomicU64::new(0),
            high_water: AtomicU64::new(0),
            slots,
            cap: cap as u64,
        }
    }

    /// Producer side (lane-owning thread only): append one record.
    /// Returns `false` — and bumps the drop counter by exactly one —
    /// when the ring is full even after refreshing the tail snapshot.
    pub fn push(&self, rec: &[u8; RECORD_LEN]) -> bool {
        let h = self.head.load(Ordering::Relaxed);
        let mut t = self.cached_tail.load(Ordering::Relaxed);
        if h.wrapping_sub(t) >= self.cap {
            t = self.tail.load(Ordering::Acquire);
            self.cached_tail.store(t, Ordering::Relaxed);
            if h.wrapping_sub(t) >= self.cap {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            }
        }
        unsafe { *self.slots[(h % self.cap) as usize].get() = *rec };
        self.head.store(h + 1, Ordering::Release);
        // Occupancy against the freshest tail snapshot we hold — a
        // conservative (never-under) upper bound, cheap enough for the
        // push path since it touches producer-owned state only.
        let occ = (h + 1).wrapping_sub(t);
        if occ > self.high_water.load(Ordering::Relaxed) {
            self.high_water.store(occ, Ordering::Relaxed);
        }
        true
    }

    /// Consumer side (one drainer at a time): pop the oldest record.
    pub fn pop(&self) -> Option<[u8; RECORD_LEN]> {
        let t = self.tail.load(Ordering::Relaxed);
        let h = self.head.load(Ordering::Acquire);
        if t == h {
            return None;
        }
        let rec = unsafe { *self.slots[(t % self.cap) as usize].get() };
        self.tail.store(t + 1, Ordering::Release);
        Some(rec)
    }

    /// Records currently buffered (monitoring; racy under concurrency).
    pub fn len(&self) -> usize {
        let h = self.head.load(Ordering::Relaxed);
        let t = self.tail.load(Ordering::Relaxed);
        h.wrapping_sub(t) as usize
    }

    /// True when no records are buffered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slot capacity.
    pub fn capacity(&self) -> usize {
        self.cap as usize
    }

    /// Records dropped on overflow so far (exact).
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Zero the drop counter (collector reset between sessions).
    pub fn reset_dropped(&self) {
        self.dropped.store(0, Ordering::Relaxed);
    }

    /// Peak records buffered at any push so far (the lane's drop
    /// watermark; `>= capacity()` means the lane actually overflowed).
    pub fn high_water(&self) -> u64 {
        self.high_water.load(Ordering::Relaxed)
    }

    /// Zero the watermark (collector reset between sessions).
    pub fn reset_high_water(&self) {
        self.high_water.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::super::event::{Event, EventKind};
    use super::*;
    use std::sync::Arc;

    fn rec(seq: u64) -> [u8; RECORD_LEN] {
        Event { kind: EventKind::SendCommit, channel: 1, seq, ts_ns: seq * 10, aux: 0, lane: 0 }
            .encode()
    }

    #[test]
    fn fifo_and_wraparound() {
        let r = EventRing::new(4);
        for round in 0..50u64 {
            assert!(r.push(&rec(round)));
            let got = Event::decode(&r.pop().unwrap()).unwrap();
            assert_eq!(got.seq, round);
        }
        assert!(r.pop().is_none());
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn overflow_accounting_is_exact_never_silent() {
        let r = EventRing::new(8);
        let mut accepted = 0u64;
        for i in 0..20u64 {
            if r.push(&rec(i)) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 8, "exactly cap records fit");
        assert_eq!(r.dropped(), 12, "every rejected push counted exactly once");
        // The 8 survivors are the oldest 8, in order — drops never tear
        // or reorder what was already committed.
        for want in 0..8u64 {
            let got = Event::decode(&r.pop().unwrap()).unwrap();
            assert_eq!(got.seq, want);
        }
        assert!(r.pop().is_none());
        // Space freed: pushes flow again, the drop counter stands still.
        assert!(r.push(&rec(99)));
        assert_eq!(r.dropped(), 12);
    }

    #[test]
    fn high_water_tracks_peak_occupancy() {
        let r = EventRing::new(8);
        assert_eq!(r.high_water(), 0);
        for i in 0..3u64 {
            r.push(&rec(i));
        }
        assert_eq!(r.high_water(), 3);
        r.pop().unwrap();
        r.pop().unwrap();
        // The producer measures against its cached tail snapshot
        // (refreshed only on apparent full), so the watermark is a
        // conservative never-under bound: pops it has not observed do
        // not lower the measured occupancy.
        r.push(&rec(3));
        assert_eq!(r.high_water(), 4);
        // Overflow pins the watermark at capacity.
        for i in 0..20u64 {
            r.push(&rec(100 + i));
        }
        assert_eq!(r.high_water(), 8);
        assert!(r.dropped() > 0);
        r.reset_high_water();
        assert_eq!(r.high_water(), 0);
    }

    #[test]
    fn concurrent_spsc_drain_loses_nothing() {
        const N: u64 = 100_000;
        let r = Arc::new(EventRing::new(256));
        let producer = {
            let r = r.clone();
            std::thread::spawn(move || {
                let mut pushed = 0u64;
                for i in 0..N {
                    if r.push(&rec(i)) {
                        pushed += 1;
                    }
                }
                pushed
            })
        };
        let mut seen = 0u64;
        let mut last = None::<u64>;
        loop {
            match r.pop() {
                Some(b) => {
                    let ev = Event::decode(&b).unwrap();
                    if let Some(p) = last {
                        assert!(ev.seq > p, "ring reordered events");
                    }
                    last = Some(ev.seq);
                    seen += 1;
                }
                None => {
                    if producer.is_finished() && r.is_empty() {
                        break;
                    }
                    std::hint::spin_loop();
                }
            }
        }
        let pushed = producer.join().unwrap();
        assert_eq!(seen, pushed, "accepted records all drained");
        assert_eq!(pushed + r.dropped(), N, "accepted + dropped == offered");
    }
}
