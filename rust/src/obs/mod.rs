//! Zero-perturbation observability plane: per-lane event rings, stage
//! latency attribution, and a named counter registry.
//!
//! The paper's argument is a measured latency delta, so the harness must
//! be able to show *where* a message's nanoseconds go without disturbing
//! the hot path it measures. Everything here is therefore built from
//! the two ingredients the simulator never prices:
//!
//! * **host-side `std::sync::atomic` state** (the established pattern of
//!   `chan_poison`, the liveness epochs, the `stat_*` counters), and
//! * **unpriced peeks** ([`World::timestamp_peek`], `counters_peek`).
//!
//! So the overhead contract is strict and sim-assertable: with tracing
//! disabled *or enabled*, instrumentation adds **zero priced
//! operations** — the pinned coherence gates (PR 1–2) stay
//! byte-identical either way (`tests/trace_properties.rs` asserts it).
//! On the real plane a disabled trace point costs one relaxed load of
//! the global enable flag.
//!
//! # Architecture
//!
//! Hot paths call [`emit`] (events) and [`bump`]/[`add`] (counters).
//! Each emitting thread lazily registers its own SPSC [`EventRing`]
//! (per-core in the pinned-task model) and pushes fixed 32-byte
//! [`Event`] records into it — dogfooding the repo's own padded /
//! cached-peer-counter ring design; overflow is counted exactly, never
//! silent. A [`Collector`] drains every lane, pairs the stage marks
//! into per-channel stage-latency histograms, and exports NDJSON /
//! chrome-trace / metrics-snapshot JSON; its replay checker re-derives
//! the FIFO / no-loss / no-dup invariants from the event stream alone.
//!
//! # Gating
//!
//! Compile-time: the `obs-trace` cargo feature (default on) — without
//! it every trace point compiles to nothing. Runtime: [`set_enabled`]
//! (default **off**); [`tracing`] is the one-relaxed-load check every
//! trace point performs first.

mod collect;
mod counters;
mod event;
mod ring;

pub use collect::{Collector, ReplayReport, StageSet, STAGES};
pub use counters::{ctr, CounterRegistry, MAX_COUNTERS};
pub use event::{Event, EventKind, CH_ENDPOINT_BIT, CH_NONE, RECORD_LEN};
pub use ring::EventRing;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::lockfree::World;

/// Capacity (records) of each per-lane event ring: 64 Ki × 32 B = 2 MiB
/// per lane, enough for ~13k traced messages between collector drains.
pub const RING_CAPACITY: usize = 1 << 16;

/// Runtime enable flag. Host atomic: reading it is never priced.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// The process-wide sink: every lane ring + the counter registry.
struct TraceSink {
    lanes: Mutex<Vec<Arc<EventRing>>>,
    counters: CounterRegistry,
}

fn sink() -> &'static TraceSink {
    static SINK: OnceLock<TraceSink> = OnceLock::new();
    SINK.get_or_init(|| TraceSink {
        lanes: Mutex::new(Vec::new()),
        counters: CounterRegistry::new(),
    })
}

thread_local! {
    /// This thread's lane: `(lane index, its ring)`, registered on first
    /// emit. The ring is never unregistered — a lane that outlives its
    /// thread just drains empty.
    static LANE: std::cell::RefCell<Option<(u32, Arc<EventRing>)>> =
        const { std::cell::RefCell::new(None) };
}

/// True when tracing is compiled in *and* runtime-enabled — the guard
/// every trace point checks first (one relaxed host-atomic load; a
/// constant `false` when the `obs-trace` feature is off).
#[inline(always)]
pub fn tracing() -> bool {
    #[cfg(feature = "obs-trace")]
    {
        ENABLED.load(Ordering::Relaxed)
    }
    #[cfg(not(feature = "obs-trace"))]
    {
        false
    }
}

/// Flip the runtime enable flag. Returns the effective state (`false`
/// forever when the `obs-trace` feature is compiled out).
pub fn set_enabled(on: bool) -> bool {
    #[cfg(feature = "obs-trace")]
    {
        ENABLED.store(on, Ordering::SeqCst);
        on
    }
    #[cfg(not(feature = "obs-trace"))]
    {
        let _ = on;
        false
    }
}

/// Emit one trace event, timestamped with `W`'s unpriced clock peek.
/// No-op unless [`tracing`] — callers just call it unconditionally, or
/// pre-check `tracing()` themselves when arguments need computing.
#[inline]
pub fn emit<W: World>(kind: EventKind, channel: u32, seq: u64, aux: u32) {
    #[cfg(feature = "obs-trace")]
    {
        if !ENABLED.load(Ordering::Relaxed) {
            return;
        }
        emit_at(kind, channel, seq, W::timestamp_peek(), aux);
    }
    #[cfg(not(feature = "obs-trace"))]
    {
        let _ = (kind, channel, seq, aux);
    }
}

/// Emit with an explicit timestamp (exporters/tests; [`emit`] for hot
/// paths). Registers this thread's lane ring on first use.
pub fn emit_at(kind: EventKind, channel: u32, seq: u64, ts_ns: u64, aux: u32) {
    LANE.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            let s = sink();
            let mut lanes = s.lanes.lock().unwrap_or_else(|e| e.into_inner());
            let ring = Arc::new(EventRing::new(RING_CAPACITY));
            lanes.push(ring.clone());
            *slot = Some(((lanes.len() - 1) as u32, ring));
        }
        let (_, ring) = slot.as_ref().unwrap();
        ring.push(&Event { kind, channel, seq, ts_ns, aux, lane: 0 }.encode());
    });
}

/// Bump a registry counter by 1. No-op unless [`tracing`].
#[inline]
pub fn bump(id: usize) {
    if tracing() {
        sink().counters.bump(id);
    }
}

/// Add `n` to a registry counter. No-op unless [`tracing`].
#[inline]
pub fn add(id: usize, n: u64) {
    if tracing() {
        sink().counters.add(id, n);
    }
}

/// Register a counter by name (see [`CounterRegistry::register`]).
pub fn register_counter(name: &str) -> Option<usize> {
    sink().counters.register(name)
}

/// Current value of a registry counter.
pub fn counter(id: usize) -> u64 {
    sink().counters.get(id)
}

/// `(name, value)` snapshot of the whole counter registry.
pub fn counters_snapshot() -> Vec<(String, u64)> {
    sink().counters.snapshot()
}

/// Drain every lane ring into decoded events (lane field filled from
/// the ring index). Records dropped on overflow so far are mirrored
/// into the `trace.dropped` counter. Holding the lane table lock for
/// the whole drain serializes concurrent collectors (SPSC stays SPSC).
pub fn drain() -> Vec<Event> {
    let s = sink();
    let lanes = s.lanes.lock().unwrap_or_else(|e| e.into_inner());
    let mut out = Vec::new();
    for (lane, ring) in lanes.iter().enumerate() {
        while let Some(rec) = ring.pop() {
            if let Some(mut ev) = Event::decode(&rec) {
                ev.lane = lane as u32;
                out.push(ev);
            }
        }
    }
    let dropped: u64 = lanes.iter().map(|r| r.dropped()).sum();
    let have = s.counters.get(ctr::TRACE_DROPPED);
    s.counters.add(ctr::TRACE_DROPPED, dropped.saturating_sub(have));
    out
}

/// Serialize tests that arm the process-global plane — the sink is
/// shared across the whole test binary, so concurrent traced tests
/// would cross-contaminate each other's drains.
#[doc(hidden)]
pub fn test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Total records dropped on lane-ring overflow so far.
pub fn dropped() -> u64 {
    let s = sink();
    let lanes = s.lanes.lock().unwrap_or_else(|e| e.into_inner());
    lanes.iter().map(|r| r.dropped()).sum()
}

/// Per-lane `(high_water, dropped)` in lane-index order — the drop
/// watermarks the metrics export records so "how close did each lane
/// come to overflow" survives into the snapshot, not just the
/// aggregate drop count.
pub fn lanes_snapshot() -> Vec<(u64, u64)> {
    let s = sink();
    let lanes = s.lanes.lock().unwrap_or_else(|e| e.into_inner());
    lanes.iter().map(|r| (r.high_water(), r.dropped())).collect()
}

/// Reset the plane between sessions: discard buffered events, zero the
/// drop accounting and every counter. Call with tracing disabled (or
/// accept losing concurrently-emitted events).
pub fn reset() {
    let s = sink();
    let lanes = s.lanes.lock().unwrap_or_else(|e| e.into_inner());
    for ring in lanes.iter() {
        while ring.pop().is_some() {}
        ring.reset_dropped();
        ring.reset_high_water();
    }
    s.counters.reset();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lockfree::RealWorld;

    /// The sink is process-global; serialize the tests that enable it.
    fn guard() -> std::sync::MutexGuard<'static, ()> {
        test_guard()
    }

    #[test]
    fn disabled_emit_is_inert() {
        let _g = guard();
        set_enabled(false);
        reset();
        emit::<RealWorld>(EventKind::SendCommit, 1, 0, 0);
        bump(ctr::RING_SEND);
        assert!(drain().is_empty());
        assert_eq!(counter(ctr::RING_SEND), 0);
    }

    #[cfg(feature = "obs-trace")]
    #[test]
    fn enabled_emit_drains_with_lane_and_counters() {
        let _g = guard();
        reset();
        set_enabled(true);
        for seq in 0..10u64 {
            emit::<RealWorld>(EventKind::SendCommit, 7, seq, 24);
            bump(ctr::RING_SEND);
        }
        set_enabled(false);
        let events = drain();
        assert_eq!(events.len(), 10);
        assert!(events.iter().all(|e| e.channel == 7 && e.kind == EventKind::SendCommit));
        assert!(events.windows(2).all(|w| w[0].seq < w[1].seq));
        assert_eq!(counter(ctr::RING_SEND), 10);
        assert_eq!(dropped(), 0);
        let snap = counters_snapshot();
        assert!(snap.iter().any(|(n, v)| n == "ring.send" && *v == 10));
        reset();
        assert_eq!(counter(ctr::RING_SEND), 0);
    }

    #[cfg(feature = "obs-trace")]
    #[test]
    fn timestamps_come_from_the_world_clock() {
        let _g = guard();
        reset();
        set_enabled(true);
        let t0 = crate::os::monotonic_ns();
        emit::<RealWorld>(EventKind::Wakeup, 0, 0, 0);
        let t1 = crate::os::monotonic_ns();
        set_enabled(false);
        let events = drain();
        assert_eq!(events.len(), 1);
        assert!(events[0].ts_ns >= t0 && events[0].ts_ns <= t1);
    }
}
