//! Collector: drained events → stage-latency histograms, exporters, and
//! the trace-replay invariant checker.
//!
//! The collector pairs the five per-message stage marks by `(channel,
//! seq)` into the four stage latencies:
//!
//! | stage | from → to | what it measures |
//! |---|---|---|
//! | `send_commit` | `SendEnter` → `SendCommit` | API entry to ring publish (incl. full-ring retries: the *last* enter before the commit wins) |
//! | `commit_doorbell` | `SendCommit` → `DoorbellSet` | publish to receiver-visible doorbell |
//! | `doorbell_wakeup` | `DoorbellSet` → `Wakeup` | doorbell to the receiver's first successful probe (poll or futex-wake latency) |
//! | `wakeup_recv` | `Wakeup` → `RecvReturn` | probe to payload handed to the caller (slot copy + ack) |
//!
//! Timestamps come from each *emitting* task's clock: exact deltas
//! within one side (send→commit, wakeup→recv), cross-task deltas are
//! exact on the real plane (one wall clock) and approximate on the sim
//! (per-task virtual clocks) — negative skews clamp to zero.
//!
//! The replay checker re-derives the FIFO / no-loss / no-dup invariants
//! from nothing but the event stream, giving the chaos harness a second
//! ground truth independent of the ring counters.

use std::collections::BTreeMap;

use crate::util::Histogram;

use super::event::{Event, EventKind, CH_ENDPOINT_BIT};

/// Stage names, pairing order.
pub const STAGES: [&str; 4] =
    ["send_commit", "commit_doorbell", "doorbell_wakeup", "wakeup_recv"];

/// The four per-channel stage-latency histograms.
#[derive(Debug, Default)]
pub struct StageSet {
    /// `SendEnter` → `SendCommit`.
    pub send_commit: Histogram,
    /// `SendCommit` → `DoorbellSet`.
    pub commit_doorbell: Histogram,
    /// `DoorbellSet` → `Wakeup`.
    pub doorbell_wakeup: Histogram,
    /// `Wakeup` → `RecvReturn`.
    pub wakeup_recv: Histogram,
}

impl StageSet {
    /// Histograms in [`STAGES`] order.
    pub fn by_stage(&self) -> [&Histogram; 4] {
        [&self.send_commit, &self.commit_doorbell, &self.doorbell_wakeup, &self.wakeup_recv]
    }

    fn record(&mut self, stage: usize, ns: u64) {
        match stage {
            0 => self.send_commit.record(ns),
            1 => self.commit_doorbell.record(ns),
            2 => self.doorbell_wakeup.record(ns),
            3 => self.wakeup_recv.record(ns),
            _ => unreachable!("stage index"),
        }
    }

    /// Fold `other` into `self` (per-channel → merged view).
    pub fn merge(&mut self, other: &StageSet) {
        self.send_commit.merge(&other.send_commit);
        self.commit_doorbell.merge(&other.commit_doorbell);
        self.doorbell_wakeup.merge(&other.doorbell_wakeup);
        self.wakeup_recv.merge(&other.wakeup_recv);
    }

    /// Compact JSON object, one [`Histogram::to_json`] per stage.
    pub fn to_json(&self) -> String {
        let h = self.by_stage();
        format!(
            "{{\"send_commit\":{},\"commit_doorbell\":{},\"doorbell_wakeup\":{},\"wakeup_recv\":{}}}",
            h[0].to_json(),
            h[1].to_json(),
            h[2].to_json(),
            h[3].to_json()
        )
    }
}

/// One completed stage span (for the chrome-trace duration events).
#[derive(Debug, Clone, Copy)]
struct Span {
    channel: u32,
    seq: u64,
    stage: usize,
    start_ns: u64,
    dur_ns: u64,
}

/// Stage-mark timestamps pending completion for one `(channel, seq)`.
type Pending = [Option<u64>; 5];

fn mark_index(kind: EventKind) -> Option<usize> {
    Some(match kind {
        EventKind::SendEnter => 0,
        EventKind::SendCommit => 1,
        EventKind::DoorbellSet => 2,
        EventKind::Wakeup => 3,
        EventKind::RecvReturn => 4,
        _ => return None,
    })
}

/// Drained-event aggregator. Feed with [`Collector::ingest`] (events in
/// timestamp order — [`Collector::from_events`] sorts for you), then
/// read the histograms / exports.
#[derive(Debug, Default)]
pub struct Collector {
    /// Every ingested event, in ingest order.
    pub events: Vec<Event>,
    channels: BTreeMap<u32, StageSet>,
    pending: BTreeMap<(u32, u64), Pending>,
    spans: Vec<Span>,
}

impl Collector {
    /// Empty collector.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from a drained batch: stable-sorts by timestamp (preserving
    /// per-lane emit order on ties) and ingests everything.
    pub fn from_events(mut events: Vec<Event>) -> Self {
        events.sort_by_key(|e| e.ts_ns);
        let mut c = Collector::new();
        for ev in events {
            c.ingest(ev);
        }
        c
    }

    /// Feed one event: stores it, and on a `RecvReturn` completes the
    /// `(channel, seq)` pair chain into stage samples. Repeated marks for
    /// the same `(channel, seq)` overwrite — the last attempt wins (a
    /// send retried on a full ring re-enters; only the successful pass
    /// pairs with the commit).
    pub fn ingest(&mut self, ev: Event) {
        self.events.push(ev);
        let Some(idx) = mark_index(ev.kind) else {
            return;
        };
        // Stage pairing applies to connected channels only; queue and
        // park events ride along in the dump but have no stage chain.
        if ev.channel & CH_ENDPOINT_BIT != 0 {
            return;
        }
        let key = (ev.channel, ev.seq);
        let marks = self.pending.entry(key).or_default();
        marks[idx] = Some(ev.ts_ns);
        if idx == 4 {
            let marks = self.pending.remove(&key).unwrap();
            let set = self.channels.entry(ev.channel).or_default();
            for stage in 0..4 {
                if let (Some(a), Some(b)) = (marks[stage], marks[stage + 1]) {
                    let dur = b.saturating_sub(a);
                    set.record(stage, dur);
                    self.spans.push(Span {
                        channel: ev.channel,
                        seq: ev.seq,
                        stage,
                        start_ns: a.min(b),
                        dur_ns: dur,
                    });
                }
            }
        }
    }

    /// Per-channel stage histograms (connected channels only).
    pub fn channels(&self) -> &BTreeMap<u32, StageSet> {
        &self.channels
    }

    /// All channels folded into one stage set.
    pub fn merged_stages(&self) -> StageSet {
        let mut all = StageSet::default();
        for set in self.channels.values() {
            all.merge(set);
        }
        all
    }

    // -- exporters ----------------------------------------------------------

    /// NDJSON: one JSON object per event per line, in ingest order.
    pub fn ndjson(&self) -> String {
        let mut out = String::with_capacity(self.events.len() * 96);
        for ev in &self.events {
            out.push_str(&format!(
                "{{\"kind\":\"{}\",\"ch\":{},\"seq\":{},\"ts_ns\":{},\"aux\":{},\"lane\":{}}}\n",
                ev.kind.label(),
                ev.channel,
                ev.seq,
                ev.ts_ns,
                ev.aux,
                ev.lane
            ));
        }
        out
    }

    /// Chrome-trace JSON (open in `chrome://tracing` / Perfetto): every
    /// raw event as an instant, every completed stage as a duration
    /// event. `pid` = channel id, `tid` = lane; timestamps in µs.
    pub fn chrome_trace_json(&self) -> String {
        let mut items = Vec::with_capacity(self.events.len() + self.spans.len());
        for ev in &self.events {
            items.push(format!(
                "{{\"name\":\"{}\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{:.3},\"pid\":{},\"tid\":{},\
                 \"args\":{{\"seq\":{},\"aux\":{}}}}}",
                ev.kind.label(),
                ev.ts_ns as f64 / 1000.0,
                ev.channel,
                ev.lane,
                ev.seq,
                ev.aux
            ));
        }
        for sp in &self.spans {
            items.push(format!(
                "{{\"name\":\"{}\",\"ph\":\"X\",\"ts\":{:.3},\"dur\":{:.3},\"pid\":{},\
                 \"tid\":{},\"args\":{{\"seq\":{}}}}}",
                STAGES[sp.stage],
                sp.start_ns as f64 / 1000.0,
                sp.dur_ns as f64 / 1000.0,
                sp.channel,
                sp.stage,
                sp.seq
            ));
        }
        format!(
            "{{\"displayTimeUnit\":\"ns\",\"traceEvents\":[{}]}}\n",
            items.join(",\n")
        )
    }

    /// Metrics snapshot JSON: event totals, the counter registry, merged
    /// and per-channel stage histograms, and per-lane drop watermarks
    /// (`lanes` = `(high_water, dropped)` per lane in lane order, e.g.
    /// from [`crate::obs::lanes_snapshot`]).
    pub fn metrics_json(
        &self,
        counters: &[(String, u64)],
        dropped: u64,
        lanes: &[(u64, u64)],
    ) -> String {
        let ctrs = counters
            .iter()
            .map(|(n, v)| format!("\"{}\":{}", n.replace('"', ""), v))
            .collect::<Vec<_>>()
            .join(",");
        let chans = self
            .channels
            .iter()
            .map(|(ch, set)| format!("\"{ch}\":{}", set.to_json()))
            .collect::<Vec<_>>()
            .join(",");
        let lanes = lanes
            .iter()
            .enumerate()
            .map(|(i, (hw, dr))| {
                format!("{{\"lane\":{i},\"high_water\":{hw},\"dropped\":{dr}}}")
            })
            .collect::<Vec<_>>()
            .join(",");
        format!(
            "{{\"events\":{},\"dropped\":{},\"lanes\":[{}],\"counters\":{{{}}},\"stages\":{},\
             \"channels\":{{{}}}}}\n",
            self.events.len(),
            dropped,
            lanes,
            ctrs,
            self.merged_stages().to_json(),
            chans
        )
    }

    // -- replay checker -----------------------------------------------------

    /// Re-validate FIFO / no-loss / no-dup from the event stream alone.
    ///
    /// Per connected channel, in stream order: `SendCommit` sequences
    /// must increase by exactly 1 from the first observed (the producer
    /// publishes a gapless, duplicate-free sequence), `RecvReturn`
    /// sequences likewise (the consumer receives that sequence in order,
    /// possibly a shorter prefix — in-flight or crash-salvaged tails are
    /// not loss), and nothing may be received before it was committed.
    pub fn replay_check(&self) -> ReplayReport {
        let mut per_chan: BTreeMap<u32, (Vec<u64>, Vec<u64>)> = BTreeMap::new();
        for ev in &self.events {
            if ev.channel & CH_ENDPOINT_BIT != 0 {
                continue;
            }
            match ev.kind {
                EventKind::SendCommit => {
                    per_chan.entry(ev.channel).or_default().0.push(ev.seq)
                }
                EventKind::RecvReturn => {
                    per_chan.entry(ev.channel).or_default().1.push(ev.seq)
                }
                _ => {}
            }
        }
        let mut rep = ReplayReport {
            channels: per_chan.len(),
            ..ReplayReport::default()
        };
        let mut fails = Vec::new();
        for (ch, (commits, recvs)) in &per_chan {
            rep.commits += commits.len() as u64;
            rep.recvs += recvs.len() as u64;
            for (what, seqs) in [("commit", commits), ("recv", recvs)] {
                for w in seqs.windows(2) {
                    if w[1] <= w[0] {
                        rep.dups += 1;
                        fails.push(format!("ch{ch}: {what} seq {} after {} (dup/reorder)", w[1], w[0]));
                    } else if w[1] != w[0] + 1 {
                        rep.lost += w[1] - w[0] - 1;
                        fails.push(format!("ch{ch}: {what} gap {}..{}", w[0] + 1, w[1]));
                    }
                }
            }
            if let (Some(&rf), Some(&cf)) = (recvs.first(), commits.first()) {
                if rf < cf {
                    fails.push(format!("ch{ch}: recv seq {rf} before first commit {cf}"));
                }
            }
            if recvs.len() > commits.len() {
                fails.push(format!(
                    "ch{ch}: {} recvs exceed {} commits",
                    recvs.len(),
                    commits.len()
                ));
            }
        }
        rep.pass = fails.is_empty();
        rep.text = if rep.pass {
            format!(
                "replay channels={} commits={} recvs={} verdict=PASS",
                rep.channels, rep.commits, rep.recvs
            )
        } else {
            format!(
                "replay channels={} commits={} recvs={} verdict=FAIL[{}]",
                rep.channels,
                rep.commits,
                rep.recvs,
                fails.join("; ")
            )
        };
        rep
    }
}

/// Verdict of [`Collector::replay_check`].
#[derive(Debug, Clone, Default)]
pub struct ReplayReport {
    /// Connected channels that emitted commit/recv events.
    pub channels: usize,
    /// Total `SendCommit` events checked.
    pub commits: u64,
    /// Total `RecvReturn` events checked.
    pub recvs: u64,
    /// Sequence-gap messages (loss).
    pub lost: u64,
    /// Duplicate / reordered sequences.
    pub dups: u64,
    /// True when every invariant held.
    pub pass: bool,
    /// One-line report.
    pub text: String,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: EventKind, ch: u32, seq: u64, ts: u64) -> Event {
        Event { kind, channel: ch, seq, ts_ns: ts, aux: 0, lane: 0 }
    }

    fn full_chain(ch: u32, seq: u64, t0: u64) -> [Event; 5] {
        [
            ev(EventKind::SendEnter, ch, seq, t0),
            ev(EventKind::SendCommit, ch, seq, t0 + 10),
            ev(EventKind::DoorbellSet, ch, seq, t0 + 15),
            ev(EventKind::Wakeup, ch, seq, t0 + 40),
            ev(EventKind::RecvReturn, ch, seq, t0 + 52),
        ]
    }

    #[test]
    fn pairing_populates_all_four_stages() {
        let mut events = Vec::new();
        for seq in 0..8 {
            events.extend(full_chain(3, seq, seq * 1000));
        }
        let c = Collector::from_events(events);
        let set = &c.channels()[&3];
        for (h, name) in set.by_stage().iter().zip(STAGES) {
            assert_eq!(h.count(), 8, "stage {name}");
        }
        assert_eq!(set.send_commit.max(), 10);
        assert_eq!(set.commit_doorbell.max(), 5);
        assert_eq!(set.doorbell_wakeup.max(), 25);
        assert_eq!(set.wakeup_recv.max(), 12);
        assert!(c.replay_check().pass);
    }

    #[test]
    fn retried_send_enter_uses_last_attempt() {
        let mut events = vec![ev(EventKind::SendEnter, 1, 0, 0)]; // failed attempt
        events.extend(full_chain(1, 0, 500));
        let c = Collector::from_events(events);
        // 510 - 500, not 510 - 0.
        assert_eq!(c.channels()[&1].send_commit.max(), 10);
    }

    #[test]
    fn replay_flags_gap_dup_and_early_recv() {
        let base: Vec<Event> = [0, 1, 3]
            .iter()
            .map(|&s| ev(EventKind::SendCommit, 2, s, s * 10))
            .collect();
        let r = Collector::from_events(base).replay_check();
        assert!(!r.pass);
        assert_eq!(r.lost, 1);

        let dup = vec![
            ev(EventKind::RecvReturn, 2, 4, 10),
            ev(EventKind::RecvReturn, 2, 4, 20),
        ];
        let r = Collector::from_events(dup).replay_check();
        assert!(!r.pass);
        assert_eq!(r.dups, 1);

        let early = vec![
            ev(EventKind::SendCommit, 2, 5, 10),
            ev(EventKind::RecvReturn, 2, 4, 20),
        ];
        assert!(!Collector::from_events(early).replay_check().pass);
    }

    #[test]
    fn unreceived_tail_is_not_loss() {
        let mut events = Vec::new();
        for seq in 0..6 {
            events.push(ev(EventKind::SendCommit, 0, seq, seq * 10));
        }
        for seq in 0..4 {
            events.push(ev(EventKind::RecvReturn, 0, seq, 1000 + seq * 10));
        }
        let r = Collector::from_events(events).replay_check();
        assert!(r.pass, "{}", r.text);
        assert_eq!((r.commits, r.recvs), (6, 4));
    }

    #[test]
    fn exports_are_wellformed() {
        let mut events = Vec::new();
        for seq in 0..3 {
            events.extend(full_chain(1, seq, seq * 100));
        }
        let c = Collector::from_events(events);
        let chrome = c.chrome_trace_json();
        assert!(chrome.contains("\"traceEvents\""));
        assert!(chrome.contains("\"ph\":\"X\""));
        assert!(chrome.contains("doorbell_wakeup"));
        let nd = c.ndjson();
        assert_eq!(nd.lines().count(), 15);
        assert!(nd.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
        let metrics = c.metrics_json(&[("timeouts".into(), 2)], 0, &[(37, 0), (64, 5)]);
        assert!(metrics.contains("\"timeouts\":2"));
        assert!(metrics.contains("\"wakeup_recv\""));
        assert!(metrics.contains("\"lanes\":[{\"lane\":0,\"high_water\":37,\"dropped\":0},{\"lane\":1,\"high_water\":64,\"dropped\":5}]"));
    }

    #[test]
    fn queue_events_ride_along_without_stage_pairing() {
        let events = vec![
            ev(EventKind::QueuePush, CH_ENDPOINT_BIT | 2, 0, 5),
            ev(EventKind::QueuePop, CH_ENDPOINT_BIT | 2, 0, 9),
        ];
        let c = Collector::from_events(events);
        assert!(c.channels().is_empty());
        assert_eq!(c.events.len(), 2);
        assert!(c.replay_check().pass);
    }
}
