//! Native Mean Value Analysis of the paper's QPN (closed network with a
//! delay station — the cores — and one FIFO queueing station — the
//! memory bus).
//!
//! Mirrors `python/compile/kernels/ref.py::mva_ref`; the unit tests pin
//! both to the same closed forms so the artifact cross-check in
//! [`super::qpn`] is meaningful.

/// Workload parameters for one message type (nanoseconds), matching the
/// L2 model's calibration (python/compile/model.py DEFAULTS).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Workload {
    /// Memory operations (cache-line touches) per message exchange.
    pub nops: f64,
    /// Per-core think time per message (ns). The Figure 6 grid scales
    /// this with the core count so the system target rate is constant.
    pub z: f64,
    /// On-core cache hit cost (ns).
    pub thit: f64,
    /// Main-memory service time per miss (ns).
    pub tmem: f64,
}

impl Workload {
    /// The paper's "message" workload.
    pub fn message() -> Self {
        Workload { nops: 52.0, z: 1300.0, thit: 2.0, tmem: 60.0 }
    }

    /// The paper's "packet" workload.
    pub fn packet() -> Self {
        Workload { nops: 60.0, z: 1400.0, thit: 2.0, tmem: 60.0 }
    }

    /// The paper's "scalar" workload.
    pub fn scalar() -> Self {
        Workload { nops: 24.0, z: 900.0, thit: 2.0, tmem: 60.0 }
    }

    /// By-name lookup (message | packet | scalar).
    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "message" => Some(Self::message()),
            "packet" => Some(Self::packet()),
            "scalar" => Some(Self::scalar()),
            _ => None,
        }
    }

    /// MVA station demands at cache hit rate `h`:
    /// `(d_think, d_bus)` in ns per message.
    pub fn demands(&self, h: f64) -> (f64, f64) {
        (self.z + self.nops * h * self.thit, self.nops * (1.0 - h) * self.tmem)
    }

    /// The workload's target rate for `cores` (msgs/s): one message per
    /// `z/cores` ns system-wide — Figure 6's 100% line (z already scaled).
    pub fn target_rate(&self, cores: u32) -> f64 {
        cores as f64 / self.z * 1e9
    }
}

/// MVA solution for one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MvaResult {
    /// Throughput (messages per second).
    pub throughput: f64,
    /// Memory-bus utilization in [0, 1].
    pub utilization: f64,
    /// Fraction of the target rate achieved.
    pub target_fraction: f64,
    /// Mean bus queue length.
    pub queue_len: f64,
}

/// Exact MVA for `cores` customers.
pub fn mva(w: &Workload, h: f64, cores: u32) -> MvaResult {
    assert!((0.0..=1.0).contains(&h), "hit rate in [0,1]");
    assert!(cores >= 1);
    let (d_think, d_bus) = w.demands(h);
    let mut q = 0.0f64;
    let mut x = 0.0f64;
    for n in 1..=cores {
        let r_bus = d_bus * (1.0 + q);
        x = n as f64 / (d_think + r_bus);
        q = x * r_bus;
    }
    let throughput = x * 1e9;
    MvaResult {
        throughput,
        utilization: (x * d_bus).clamp(0.0, 1.0),
        target_fraction: throughput / w.target_rate(cores),
        queue_len: q,
    }
}

/// The theoretical maximum exchange rate (msgs/s) the model admits for a
/// workload at hit rate `h`: pure memory/cache transaction time, no
/// queueing, no think time — the paper's 630 k msgs/s figure.
pub fn theoretical_max(w: &Workload, h: f64) -> f64 {
    let per_msg = w.nops * (h * w.thit + (1.0 - h) * w.tmem);
    1e9 / per_msg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_customer_closed_form() {
        // X = 1/(d_think + d_bus) with no queueing.
        let w = Workload::message();
        let r = mva(&w, 0.9, 1);
        let (dt, db) = w.demands(0.9);
        assert!((r.throughput - 1e9 / (dt + db)).abs() < 1.0);
        assert!((r.utilization - db / (dt + db)).abs() < 1e-9);
    }

    #[test]
    fn zero_bus_demand_is_delay_only() {
        let w = Workload { nops: 10.0, z: 500.0, thit: 2.0, tmem: 60.0 };
        let r = mva(&w, 1.0, 4);
        // d_bus = 0: X = n / d_think exactly, utilization 0.
        assert!((r.throughput - 4.0 / 520.0 * 1e9).abs() < 1.0);
        assert_eq!(r.utilization, 0.0);
    }

    #[test]
    fn utilization_monotone_in_cores_and_bounded() {
        let w = Workload::message();
        let mut last = 0.0;
        for c in 1..=8 {
            let r = mva(&w, 0.6, c);
            assert!(r.utilization >= last - 1e-12);
            assert!(r.utilization <= 1.0);
            last = r.utilization;
        }
    }

    #[test]
    fn throughput_increases_with_hit_rate() {
        let w = Workload::packet();
        let mut last = 0.0;
        for i in 0..=10 {
            let r = mva(&w, i as f64 / 10.0, 2);
            assert!(r.throughput > last);
            last = r.throughput;
        }
    }

    #[test]
    fn calibration_matches_paper_630k() {
        // Paper Section 5: ~630,000 messages/s theoretical maximum
        // (memory transactions only, at the reference hit rate).
        let max = theoretical_max(&Workload::message(), 0.5);
        assert!(
            (500_000.0..800_000.0).contains(&max),
            "theoretical max {max} out of the paper's band"
        );
    }

    #[test]
    fn workloads_ordering() {
        let m = Workload::message();
        let p = Workload::packet();
        let s = Workload::scalar();
        assert!(s.nops < m.nops && m.nops <= p.nops);
        assert_eq!(Workload::by_name("scalar"), Some(s));
        assert_eq!(Workload::by_name("nope"), None);
    }

    #[test]
    #[should_panic(expected = "hit rate")]
    fn bad_hit_rate_rejected() {
        mva(&Workload::message(), 1.5, 1);
    }
}
