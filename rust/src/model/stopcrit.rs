//! The refactoring stop criterion (Section 5).
//!
//! "Simulation of model configurations ... gave us a theoretical maximum
//! message throughput rate of 630,000 messages per second or one message
//! every 0.63 microsecond. The minimum measured elapsed latency of the
//! lock-free implementation on Linux is seven microseconds, an order of
//! magnitude higher than the theoretical maximum. However, the
//! theoretical maximum only considers ... cache and memory transactions
//! ... and excludes CPU time, atomic instructions and OS tasks."
//!
//! The verdict: keep refactoring while measured latency is dominated by
//! *lock overhead* (removable); stop when the residual gap over the
//! memory-bound minimum is within the CPU/OS budget the model excludes.

use super::analytic::{theoretical_max, Workload};

/// Stop-criterion outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StopVerdict {
    /// Model's memory-bound minimum exchange time (ns).
    pub model_min_ns: f64,
    /// Measured minimum latency (ns).
    pub measured_min_ns: f64,
    /// measured / model ratio.
    pub ratio: f64,
    /// True when further lock-removal is unlikely to pay off.
    pub stop: bool,
}

/// Gap budget: the paper accepts roughly an order of magnitude between
/// the memory-only model and a real exchange (CPU + atomics + OS). Above
/// this, something structural (i.e. locking) is still in the path.
pub const GAP_BUDGET: f64 = 15.0;

/// Reference hit rate for the theoretical-maximum calculation. At 0.5 the
/// message workload's pure memory-transaction time is ~1.6 us per exchange
/// — the paper's "630,000 messages per second / 0.63 us" calibration point
/// (their per-direction figure; ours is the full one-way exchange).
pub const REFERENCE_HIT_RATE: f64 = 0.5;

/// Evaluate the criterion for a workload at hit rate `h` against a
/// measured minimum one-way latency.
pub fn stop_criterion(w: &Workload, h: f64, measured_min_ns: f64) -> StopVerdict {
    let model_min_ns = 1e9 / theoretical_max(w, h);
    let ratio = measured_min_ns / model_min_ns;
    StopVerdict { model_min_ns, measured_min_ns, ratio, stop: ratio <= GAP_BUDGET }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn papers_numbers_stop() {
        // Paper: 7 us measured vs the memory-only model minimum => an
        // order-of-magnitude-ish gap attributed to CPU cost => stop.
        let w = Workload::message();
        let v = stop_criterion(&w, REFERENCE_HIT_RATE, 7_000.0);
        let max = theoretical_max(&w, REFERENCE_HIT_RATE);
        assert!((500_000.0..800_000.0).contains(&max), "calibration: {max}");
        assert!(v.ratio > 2.0 && v.ratio < GAP_BUDGET, "ratio {}", v.ratio);
        assert!(v.stop);
    }

    #[test]
    fn lock_dominated_latency_keeps_going() {
        // A lock-based exchange at ~100 us is way over budget: keep
        // refactoring.
        let v = stop_criterion(&Workload::message(), REFERENCE_HIT_RATE, 100_000.0);
        assert!(!v.stop);
    }

    #[test]
    fn ratio_math() {
        let w = Workload::message();
        let v = stop_criterion(&w, REFERENCE_HIT_RATE, 2.0 * v_model(&w));
        assert!((v.ratio - 2.0).abs() < 1e-9);
    }

    fn v_model(w: &Workload) -> f64 {
        1e9 / theoretical_max(w, REFERENCE_HIT_RATE)
    }
}
