//! The AOT model bridge: run the JAX/Pallas-authored QPN sweep and MVA
//! solver from Rust via PJRT.
//!
//! Artifact contract (python/compile/aot.py): both modules take six
//! `f32[256]` vectors `(h, ncores, nops, z, thit, tmem)` and return a
//! tuple of `f32[256]` vectors — `(X, U, F)` for the sweep,
//! `(X, U, F, Q)` for MVA. The Figure 6 grid builder below mirrors
//! `model.figure6_grid` (including the per-core think-time scaling).

use crate::model::analytic::Workload;
use crate::runtime::{artifact_dir, ArtifactSpec, Executable, F32Input, PjrtRuntime};
use crate::{Error, Result};

/// Batch size the artifacts were built for.
pub const BATCH: usize = 256;

/// One Figure 6 grid point with model outputs.
#[derive(Debug, Clone, Copy)]
pub struct Fig6Point {
    /// Cache hit rate.
    pub hit_rate: f64,
    /// Core count.
    pub cores: u32,
    /// Throughput (msgs/s).
    pub throughput: f64,
    /// Bus utilization.
    pub utilization: f64,
    /// Fraction of the target rate.
    pub target_fraction: f64,
}

/// Loaded AOT model executables.
pub struct QpnModel {
    mva: Executable,
    sweep: Option<Executable>,
}

impl QpnModel {
    /// Load and compile the artifacts (requires `make artifacts`).
    pub fn load(rt: &PjrtRuntime) -> Result<Self> {
        let dir = artifact_dir().ok_or_else(|| {
            Error::Runtime("artifacts/ not found — run `make artifacts`".into())
        })?;
        let mva = rt.load_hlo_text(dir.join(ArtifactSpec::MvaSolver.file_name()))?;
        // The sweep is optional (heavier artifact); fall back gracefully.
        let sweep_path = dir.join(ArtifactSpec::QpnSweep.file_name());
        let sweep =
            if sweep_path.exists() { Some(rt.load_hlo_text(sweep_path)?) } else { None };
        Ok(QpnModel { mva, sweep })
    }

    /// True when the discrete-time sweep artifact is available.
    pub fn has_sweep(&self) -> bool {
        self.sweep.is_some()
    }

    fn grid(w: &Workload, cores: &[u32], hits: &[f64]) -> (Vec<f32>, [Vec<f32>; 5], usize) {
        let mut h = Vec::new();
        let mut nc = Vec::new();
        let mut z = Vec::new();
        for &c in cores {
            for &hh in hits {
                h.push(hh as f32);
                nc.push(c as f32);
                // Per-core think time scales with core count (constant
                // system demand) — must match model.figure6_grid.
                z.push((w.z * c as f64) as f32);
            }
        }
        let valid = h.len();
        assert!(valid <= BATCH, "grid larger than artifact batch");
        let pad = |v: &mut Vec<f32>| {
            let last = *v.last().expect("non-empty grid");
            v.resize(BATCH, last);
        };
        pad(&mut h);
        pad(&mut nc);
        pad(&mut z);
        let nops = vec![w.nops as f32; BATCH];
        let thit = vec![w.thit as f32; BATCH];
        let tmem = vec![w.tmem as f32; BATCH];
        (h.clone(), [nc, nops, z, thit, tmem], valid)
    }

    fn run(
        exe: &Executable,
        w: &Workload,
        cores: &[u32],
        hits: &[f64],
    ) -> Result<Vec<Fig6Point>> {
        let (h, [nc, nops, z, thit, tmem], valid) = Self::grid(w, cores, hits);
        let dims = [BATCH as i64];
        let outs = exe.run_f32(&[
            F32Input::vec(&h, &dims),
            F32Input::vec(&nc, &dims),
            F32Input::vec(&nops, &dims),
            F32Input::vec(&z, &dims),
            F32Input::vec(&thit, &dims),
            F32Input::vec(&tmem, &dims),
        ])?;
        if outs.len() < 3 {
            return Err(Error::Runtime(format!(
                "model artifact returned {} outputs, expected >= 3",
                outs.len()
            )));
        }
        Ok((0..valid)
            .map(|i| Fig6Point {
                hit_rate: h[i] as f64,
                cores: nc[i] as u32,
                throughput: outs[0][i] as f64,
                utilization: outs[1][i] as f64,
                target_fraction: outs[2][i] as f64,
            })
            .collect())
    }

    /// Figure 6 via the **analytic MVA kernel** artifact.
    pub fn fig6_mva(
        &self,
        w: &Workload,
        cores: &[u32],
        hits: &[f64],
    ) -> Result<Vec<Fig6Point>> {
        Self::run(&self.mva, w, cores, hits)
    }

    /// Figure 6 via the **discrete-time simulation sweep** artifact
    /// (the Pallas `qpn_step` kernel inside a scan).
    pub fn fig6_sweep(
        &self,
        w: &Workload,
        cores: &[u32],
        hits: &[f64],
    ) -> Result<Vec<Fig6Point>> {
        let sweep = self
            .sweep
            .as_ref()
            .ok_or_else(|| Error::Runtime("qpn_sweep artifact missing".into()))?;
        Self::run(sweep, w, cores, hits)
    }

    /// Default Figure 6 hit-rate axis (0.50 .. 1.00 in 0.02 steps).
    pub fn default_hits() -> Vec<f64> {
        (0..26).map(|i| 0.5 + 0.02 * i as f64).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::analytic;

    fn model() -> Option<(PjrtRuntime, QpnModel)> {
        // Skip (not fail) when artifacts have not been built; the
        // integration tests in rust/tests/ require them.
        let rt = PjrtRuntime::cpu().ok()?;
        let m = QpnModel::load(&rt).ok()?;
        Some((rt, m))
    }

    #[test]
    fn artifact_mva_matches_native_mva() {
        let Some((_rt, m)) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let w = Workload::message();
        let hits = [0.5, 0.8, 0.95];
        let pts = m.fig6_mva(&w, &[1, 2], &hits).unwrap();
        assert_eq!(pts.len(), 6);
        for p in &pts {
            // Native demands must scale z by cores, like the grid does.
            let scaled = Workload { z: w.z * p.cores as f64, ..w };
            let native = analytic::mva(&scaled, p.hit_rate, p.cores);
            let rel = (p.throughput - native.throughput).abs() / native.throughput;
            assert!(rel < 1e-3, "artifact {} vs native {}", p.throughput, native.throughput);
            assert!((p.utilization - native.utilization).abs() < 1e-3);
        }
    }

    #[test]
    fn sweep_has_fig6_shape() {
        let Some((_rt, m)) = model() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        if !m.has_sweep() {
            return;
        }
        let w = Workload::message();
        let hits = [0.5, 0.7, 0.9];
        let pts = m.fig6_sweep(&w, &[1, 2], &hits).unwrap();
        // Throughput fraction monotone in h for each core count; two-core
        // utilization >= single-core at equal h.
        for c in 0..2 {
            let series = &pts[c * 3..c * 3 + 3];
            assert!(series[0].target_fraction <= series[2].target_fraction + 1e-3);
        }
        for i in 0..3 {
            assert!(pts[3 + i].utilization >= pts[i].utilization - 0.02);
        }
    }
}
