//! The Section 5 performance model, Rust side.
//!
//! Two solvers, cross-checked against each other and against the paper:
//!
//! * [`analytic`] — native Mean Value Analysis of the closed network
//!   (cores = delay station, shared memory bus = FIFO queue). Used as an
//!   always-available fallback and as the cross-check for the artifact.
//! * [`qpn`] — executes the JAX/Pallas-authored model that
//!   `python/compile/aot.py` lowered to `artifacts/*.hlo.txt`, via the
//!   PJRT CPU client. This is the L2/L1 compute path: the discrete-time
//!   QPN sweep (Figure 6) and the batched MVA kernel.
//! * [`stopcrit`] — the paper's refactoring stop criterion: compare the
//!   measured lock-free exchange latency against the model's theoretical
//!   minimum; refactoring may stop when the residual gap is explained by
//!   CPU cost, not locking (Section 5's 7 µs vs 0.63 µs discussion).

pub mod analytic;
pub mod qpn;
pub mod stopcrit;

pub use analytic::{MvaResult, Workload};
pub use qpn::{Fig6Point, QpnModel};
pub use stopcrit::{stop_criterion, StopVerdict};
