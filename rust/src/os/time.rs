//! Monotonic time, explicit context switch (yield) and timed delay —
//! the portability additions the paper made to MRAPI (Section 3).

use std::sync::OnceLock;
use std::time::{Duration, Instant};

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Monotonic nanoseconds since process start.
pub fn monotonic_ns() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_nanos() as u64
}

/// Explicit context switch: give up the processor to another ready task.
/// (MRAPI extension; the simulator's `World::yield_now` mirrors this.)
pub fn yield_now() {
    std::thread::yield_now();
}

/// Timed delay with nanosecond argument (MRAPI extension).
pub fn delay_ns(ns: u64) {
    std::thread::sleep(Duration::from_nanos(ns));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_is_monotonic() {
        let a = monotonic_ns();
        let b = monotonic_ns();
        assert!(b >= a);
    }

    #[test]
    fn delay_advances_clock() {
        let a = monotonic_ns();
        delay_ns(1_000_000); // 1 ms
        assert!(monotonic_ns() - a >= 900_000);
    }

    #[test]
    fn yield_does_not_panic() {
        yield_now();
    }
}
