//! OS portability layer (the paper's MRAPI porting contribution).
//!
//! The paper's MRAPI port added: portable access to atomic CPU
//! instructions, explicit context switching (yield) and timed delay, CPU
//! affinity control, and OS-specific synchronization primitives. This
//! module provides those, plus the parameterised **OS cost profiles** the
//! deterministic SMP simulator uses to stand in for the paper's
//! Windows Server 2008 / Fedora 15 rt guests (see DESIGN.md §3).

pub mod affinity;
pub mod profile;
pub mod time;

pub use affinity::{available_cores, pin_to_core, AffinityMode};
pub use profile::OsProfile;
pub use time::{delay_ns, monotonic_ns, yield_now};

/// Cache line size assumed throughout (x86-64 and most ARM SoCs).
pub const CACHE_LINE: usize = 64;

// Canonical home is the memory-backend module next to the atom traits it
// wraps; re-exported here for the OS-layer constants' neighbours.
pub use crate::lockfree::mem::CachePadded;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_padded_is_line_aligned() {
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), CACHE_LINE);
        assert!(std::mem::size_of::<CachePadded<u64>>() >= CACHE_LINE);
    }
}
