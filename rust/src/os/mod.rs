//! OS portability layer (the paper's MRAPI porting contribution).
//!
//! The paper's MRAPI port added: portable access to atomic CPU
//! instructions, explicit context switching (yield) and timed delay, CPU
//! affinity control, and OS-specific synchronization primitives. This
//! module provides those, plus the parameterised **OS cost profiles** the
//! deterministic SMP simulator uses to stand in for the paper's
//! Windows Server 2008 / Fedora 15 rt guests (see DESIGN.md §3).

pub mod affinity;
pub mod profile;
pub mod time;

pub use affinity::{available_cores, pin_to_core, AffinityMode};
pub use profile::OsProfile;
pub use time::{delay_ns, monotonic_ns, yield_now};

/// Cache line size assumed throughout (x86-64 and most ARM SoCs).
pub const CACHE_LINE: usize = 64;

/// Pads a value to a full cache line to prevent false sharing between
/// adjacent atomics — the paper's Section 6 notes the exchange cost is
/// dominated by cache-line ownership transfer, so unrelated hot words must
/// not share a line.
#[repr(align(64))]
#[derive(Debug, Default)]
pub struct CachePadded<T>(pub T);

impl<T> CachePadded<T> {
    /// Wrap a value.
    pub const fn new(value: T) -> Self {
        CachePadded(value)
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_padded_is_line_aligned() {
        assert_eq!(std::mem::align_of::<CachePadded<u8>>(), CACHE_LINE);
        assert!(std::mem::size_of::<CachePadded<u64>>() >= CACHE_LINE);
    }
}
