//! CPU affinity control (`sched_setaffinity` on Linux).
//!
//! The paper's stress tests run in three placements (Section 4): all
//! threads pinned to one core, threads free to migrate, and threads pinned
//! one-per-core. [`AffinityMode`] names those; [`pin_to_core`] applies a
//! pinning on the real host (the simulator applies it in virtual space).

/// The three stress-test placements from Section 4.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AffinityMode {
    /// All tasks pinned to a single core ("single core" column).
    SingleCore,
    /// No pinning; the scheduler may migrate tasks ("Task" column).
    Free,
    /// Tasks pinned round-robin across all cores ("Affinity Task" column).
    PinnedSpread,
}

impl AffinityMode {
    /// Parse from CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "single" | "single-core" | "one" => Some(Self::SingleCore),
            "free" | "none" | "task" => Some(Self::Free),
            "pinned" | "spread" | "affinity" => Some(Self::PinnedSpread),
            _ => None,
        }
    }

    /// Stable label for reports.
    pub fn label(&self) -> &'static str {
        match self {
            Self::SingleCore => "single",
            Self::Free => "task",
            Self::PinnedSpread => "affinity",
        }
    }
}

/// Number of cores available to this process.
pub fn available_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Pin the calling thread to `core` (mod the available core count).
/// Returns false (and leaves affinity unchanged) if the syscall fails.
///
/// Declared against glibc directly (no `libc` crate — the build is fully
/// offline): `cpu_set_t` is a fixed 1024-bit mask on Linux.
#[cfg(target_os = "linux")]
pub fn pin_to_core(core: usize) -> bool {
    #[repr(C)]
    struct CpuSet {
        bits: [u64; 16], // 1024 CPUs, glibc's sizeof(cpu_set_t) == 128
    }
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const CpuSet) -> i32;
    }
    let mut set = CpuSet { bits: [0; 16] };
    let c = core % available_cores();
    set.bits[(c / 64) % 16] |= 1u64 << (c % 64);
    unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), &set) == 0 }
}

/// Non-Linux fallback: report failure, do nothing.
#[cfg(not(target_os = "linux"))]
pub fn pin_to_core(_core: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modes_parse_and_label() {
        assert_eq!(AffinityMode::parse("single"), Some(AffinityMode::SingleCore));
        assert_eq!(AffinityMode::parse("task"), Some(AffinityMode::Free));
        assert_eq!(AffinityMode::parse("affinity"), Some(AffinityMode::PinnedSpread));
        assert_eq!(AffinityMode::parse("bogus"), None);
        assert_eq!(AffinityMode::PinnedSpread.label(), "affinity");
    }

    #[test]
    fn at_least_one_core() {
        assert!(available_cores() >= 1);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn pinning_to_core_zero_succeeds() {
        assert!(pin_to_core(0));
    }
}
