//! OS cost profiles: the simulator's stand-in for the paper's two guest
//! operating systems (DESIGN.md §3, substitution table).
//!
//! The paper measured Microsoft Windows Server 2008 and Fedora 15 Linux
//! with rt extensions on identical KVM guests. What differs between them,
//! for this workload, is the *cost structure* of kernel entry, the
//! dispatcher/futex path, context switches and scheduling latency — not
//! the algorithmics. A profile captures those constants (nanoseconds) so
//! the deterministic SMP simulator can reproduce both columns of Table 2.
//!
//! Values are order-of-magnitude figures from public measurements of the
//! era (lmbench on 2.6-rt kernels; Windows Server 2008 dispatcher studies
//! cited in the paper's [9]); EXPERIMENTS.md records the calibration.

/// Nanosecond cost constants for one simulated operating system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OsProfile {
    /// Display name ("linux" / "windows").
    pub name: &'static str,
    /// Kernel entry/exit for a contended lock operation (futex / dispatcher).
    pub syscall_ns: u64,
    /// Full context switch (save/restore + scheduler).
    pub context_switch_ns: u64,
    /// Wakeup-to-run latency after a blocked task is signalled.
    pub sched_latency_ns: u64,
    /// Uncontended user-mode lock acquire+release (fast path).
    pub lock_fast_ns: u64,
    /// Explicit yield (`sched_yield` / `SwitchToThread`).
    pub yield_ns: u64,
    /// Scheduling quantum before a runnable peer preempts.
    pub quantum_ns: u64,
    /// True when even the *uncontended* lock path enters the kernel
    /// (Windows dispatcher objects); Linux futexes stay in user mode.
    pub kernel_always: bool,
}

impl OsProfile {
    /// Fedora 15 + rt extensions: cheap futex fast path, quick switches,
    /// short rt quantum. The *low* uncontended cost is what makes the
    /// multicore convoy penalty so much larger on Linux in Table 2 —
    /// single-core lock-based throughput is high, so there is more to lose.
    pub const fn linux_rt() -> Self {
        OsProfile {
            name: "linux",
            syscall_ns: 300,
            context_switch_ns: 1_800,
            sched_latency_ns: 1_100,
            lock_fast_ns: 150,
            yield_ns: 350,
            quantum_ns: 100_000,
            kernel_always: false,
        }
    }

    /// Windows Server 2008 R2: kernel dispatcher objects make even the
    /// uncontended path enter the kernel more often; switches and wakeups
    /// are heavier, quantum is longer.
    pub const fn windows() -> Self {
        OsProfile {
            name: "windows",
            syscall_ns: 1_000,
            context_switch_ns: 1_400,
            sched_latency_ns: 350,
            lock_fast_ns: 260,
            yield_ns: 700,
            quantum_ns: 180_000,
            kernel_always: true,
        }
    }

    /// Parse from CLI/config text.
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "linux" | "linux-rt" | "fedora" => Some(Self::linux_rt()),
            "windows" | "win" | "win2008" => Some(Self::windows()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_both() {
        assert_eq!(OsProfile::parse("linux").unwrap().name, "linux");
        assert_eq!(OsProfile::parse("windows").unwrap().name, "windows");
        assert!(OsProfile::parse("beos").is_none());
    }

    #[test]
    fn linux_fast_path_cheaper_than_windows() {
        // The Table 2 asymmetry depends on this ordering: Linux stays in
        // user mode uncontended (cheap fast path, lots to lose on
        // multicore); Windows enters the kernel even uncontended (slow
        // single-core baseline, relatively mild multicore penalty).
        let l = OsProfile::linux_rt();
        let w = OsProfile::windows();
        assert!(!l.kernel_always && w.kernel_always);
        assert!(l.lock_fast_ns < w.syscall_ns, "linux uncontended must be cheaper");
        assert!(l.syscall_ns < w.syscall_ns);
    }
}
