//! Minimal CLI argument parser (offline substitute for clap).
//!
//! Model: `binary <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may use `--key=value` or `--key value`; unknown keys are reported
//! by the caller via [`Args::finish`].

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Parsed command line.
#[derive(Debug, Default, Clone)]
pub struct Args {
    /// First non-flag token, if any.
    pub command: Option<String>,
    /// Remaining positionals.
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of tokens (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(tokens: I) -> Result<Args> {
        let mut args = Args::default();
        let mut iter = tokens.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: everything after is positional.
                    args.positional.extend(iter.by_ref());
                    break;
                }
                let (key, val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => {
                        // `--key value` unless the next token is a flag or
                        // missing, then it is a boolean `true`.
                        let takes_value = iter
                            .peek()
                            .map(|n| !n.starts_with("--"))
                            .unwrap_or(false);
                        if takes_value {
                            (body.to_string(), iter.next().unwrap())
                        } else {
                            (body.to_string(), "true".to_string())
                        }
                    }
                };
                if args.options.insert(key.clone(), val).is_some() {
                    return Err(Error::Config(format!("duplicate option --{key}")));
                }
            } else if args.command.is_none() {
                args.command = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    /// Parse from the process environment.
    pub fn from_env() -> Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Integer option.
    pub fn get_u64(&self, key: &str) -> Result<Option<u64>> {
        self.mark(key);
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| Error::Config(format!("--{key} expects an integer, got `{v}`"))),
        }
    }

    /// Integer option with default.
    pub fn get_u64_or(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.get_u64(key)?.unwrap_or(default))
    }

    /// Float option with default.
    pub fn get_f64_or(&self, key: &str, default: f64) -> Result<f64> {
        self.mark(key);
        match self.options.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .map_err(|_| Error::Config(format!("--{key} expects a float, got `{v}`"))),
        }
    }

    /// Boolean flag (present without value, or explicit true/false).
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        matches!(self.options.get(key).map(|s| s.as_str()), Some("true") | Some("1"))
    }

    /// Error if any provided option was never consumed (typo protection).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for key in self.options.keys() {
            if !consumed.iter().any(|c| c == key) {
                return Err(Error::Config(format!("unknown option --{key}")));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("stress --cores 4 --backend lockfree topo.toml");
        assert_eq!(a.command.as_deref(), Some("stress"));
        assert_eq!(a.get_u64("cores").unwrap(), Some(4));
        assert_eq!(a.get("backend"), Some("lockfree"));
        assert_eq!(a.positional, vec!["topo.toml"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("run --cores=8");
        assert_eq!(a.get_u64("cores").unwrap(), Some(8));
    }

    #[test]
    fn boolean_flags() {
        let a = parse("run --verbose --affinity");
        assert!(a.flag("verbose"));
        assert!(a.flag("affinity"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("run --fast --out x.txt");
        assert!(a.flag("fast"));
        assert_eq!(a.get("out"), Some("x.txt"));
    }

    #[test]
    fn double_dash_stops_parsing() {
        let a = parse("run -- --not-a-flag");
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn duplicate_rejected() {
        assert!(Args::parse(["--x", "1", "--x", "2"].map(String::from)).is_err());
    }

    #[test]
    fn unknown_option_caught_by_finish() {
        let a = parse("run --nope 3");
        assert!(a.finish().is_err());
        let b = parse("run --cores 3");
        assert_eq!(b.get_u64("cores").unwrap(), Some(3));
        assert!(b.finish().is_ok());
    }

    #[test]
    fn bad_integer_reports_key() {
        let a = parse("run --cores banana");
        let err = a.get_u64("cores").unwrap_err().to_string();
        assert!(err.contains("cores"), "{err}");
    }
}
