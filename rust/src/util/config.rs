//! TOML-subset parser for topology and experiment configuration.
//!
//! Supports the subset the coordinator needs:
//!
//! * `key = value` pairs with string, integer, float, boolean and
//!   homogeneous inline-array values;
//! * `[section]` and repeated `[[array-of-tables]]` headers;
//! * `#` comments and blank lines.
//!
//! No datetimes, no dotted keys, no multi-line strings — topology files do
//! not need them. Errors carry line numbers.

use std::collections::BTreeMap;

use crate::{Error, Result};

/// A parsed configuration value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Quoted string.
    Str(String),
    /// 64-bit signed integer.
    Int(i64),
    /// 64-bit float.
    Float(f64),
    /// Boolean.
    Bool(bool),
    /// Homogeneous array.
    Array(Vec<Value>),
}

impl Value {
    /// As string, if it is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As integer (accepting exact floats too).
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    /// As float (accepting integers).
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// One table of key/value pairs.
pub type Table = BTreeMap<String, Value>;

/// Parsed document: top-level table, named tables, arrays-of-tables.
#[derive(Debug, Default, Clone)]
pub struct Document {
    /// Keys before any section header.
    pub root: Table,
    /// `[name]` sections.
    pub tables: BTreeMap<String, Table>,
    /// `[[name]]` sections in file order.
    pub arrays: BTreeMap<String, Vec<Table>>,
}

impl Document {
    /// Parse a document from text.
    pub fn parse(text: &str) -> Result<Document> {
        enum Target {
            Root,
            Table(String),
            Array(String, usize),
        }
        let mut doc = Document::default();
        let mut target = Target::Root;

        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| Error::Config(format!("line {}: {}", lineno + 1, msg));

            if let Some(name) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
                let name = name.trim().to_string();
                if name.is_empty() {
                    return Err(err("empty [[array]] name"));
                }
                let list = doc.arrays.entry(name.clone()).or_default();
                list.push(Table::new());
                target = Target::Array(name, list.len() - 1);
            } else if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                let name = name.trim().to_string();
                if name.is_empty() {
                    return Err(err("empty [table] name"));
                }
                doc.tables.entry(name.clone()).or_default();
                target = Target::Table(name);
            } else {
                let (key, val) = line
                    .split_once('=')
                    .ok_or_else(|| err("expected `key = value`"))?;
                let key = key.trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let value = parse_value(val.trim())
                    .map_err(|m| err(&format!("bad value for `{key}`: {m}")))?;
                let table = match &target {
                    Target::Root => &mut doc.root,
                    Target::Table(name) => doc.tables.get_mut(name).unwrap(),
                    Target::Array(name, i) => &mut doc.arrays.get_mut(name).unwrap()[*i],
                };
                table.insert(key.to_string(), value);
            }
        }
        Ok(doc)
    }

    /// Fetch from the root table.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.root.get(key)
    }
}

fn strip_comment(line: &str) -> &str {
    // A `#` inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> std::result::Result<Value, String> {
    if s.is_empty() {
        return Err("empty value".into());
    }
    if let Some(rest) = s.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or("unterminated string")?;
        if inner.contains('"') {
            return Err("embedded quote".into());
        }
        return Ok(Value::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').ok_or("unterminated array")?;
        let mut items = Vec::new();
        let trimmed = inner.trim();
        if !trimmed.is_empty() {
            for part in split_top_level(trimmed) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(format!("unrecognised value `{s}`"))
}

/// Split on commas that are not inside quotes or nested brackets.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_and_comments() {
        let doc = Document::parse(
            r#"
            # topology
            name = "simple"   # trailing
            cores = 4
            rate = 0.5
            rt = true
            "#,
        )
        .unwrap();
        assert_eq!(doc.get("name").unwrap().as_str(), Some("simple"));
        assert_eq!(doc.get("cores").unwrap().as_int(), Some(4));
        assert_eq!(doc.get("rate").unwrap().as_float(), Some(0.5));
        assert_eq!(doc.get("rt").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn tables_and_arrays_of_tables() {
        let doc = Document::parse(
            r#"
            [machine]
            cores = 2
            [[channel]]
            from = "n1:0"
            to = "n2:0"
            [[channel]]
            from = "n2:1"
            to = "n1:1"
            "#,
        )
        .unwrap();
        assert_eq!(doc.tables["machine"]["cores"].as_int(), Some(2));
        let chans = &doc.arrays["channel"];
        assert_eq!(chans.len(), 2);
        assert_eq!(chans[1]["from"].as_str(), Some("n2:1"));
    }

    #[test]
    fn inline_arrays() {
        let doc = Document::parse(r#"hits = [0.5, 0.75, 1.0]"#).unwrap();
        let arr = doc.get("hits").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].as_float(), Some(1.0));
    }

    #[test]
    fn nested_arrays() {
        let doc = Document::parse(r#"m = [[1, 2], [3, 4]]"#).unwrap();
        let outer = doc.get("m").unwrap().as_array().unwrap();
        assert_eq!(outer.len(), 2);
        assert_eq!(outer[1].as_array().unwrap()[0].as_int(), Some(3));
    }

    #[test]
    fn hash_inside_string_not_comment() {
        let doc = Document::parse(r##"tag = "a#b""##).unwrap();
        assert_eq!(doc.get("tag").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = Document::parse("ok = 1\nbroken").unwrap_err();
        assert!(e.to_string().contains("line 2"), "{e}");
    }

    #[test]
    fn bad_value_rejected() {
        assert!(Document::parse("x = @nope").is_err());
        assert!(Document::parse("x = \"unterminated").is_err());
        assert!(Document::parse("x = [1, 2").is_err());
    }

    #[test]
    fn empty_array() {
        let doc = Document::parse("x = []").unwrap();
        assert_eq!(doc.get("x").unwrap().as_array().unwrap().len(), 0);
    }
}
