//! Hand-rolled substrates: PRNG, histogram, TOML-subset parser, property
//! testing and CLI parsing.
//!
//! The reproduction builds fully offline, so the usual ecosystem crates
//! (rand, hdrhistogram, serde/toml, proptest, clap, criterion) are
//! re-implemented here at the scale this project needs. Each is a small,
//! tested module rather than a full clone.

pub mod args;
pub mod config;
pub mod histogram;
pub mod prop;
pub mod rng;

pub use args::Args;
pub use config::Value;
pub use histogram::Histogram;
pub use rng::XorShift;
