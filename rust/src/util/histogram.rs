//! Log-bucketed latency histogram (HDR-style, fixed footprint).
//!
//! Records u64 nanosecond samples into 2^k log2 buckets with 16 linear
//! sub-buckets each, supporting count/mean/percentiles with bounded
//! (~6%) relative quantile error — plenty for the paper's latency-speedup
//! factors, which span 2x–25x.

const SUB_BITS: u32 = 4; // 16 linear sub-buckets per octave
const SUB: usize = 1 << SUB_BITS;
const OCTAVES: usize = 40; // covers up to ~2^40 ns (~18 min)

/// Fixed-size log-linear histogram of u64 samples.
#[derive(Clone)]
pub struct Histogram {
    counts: Vec<u64>,
    total: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    /// Empty histogram.
    pub fn new() -> Self {
        Self {
            counts: vec![0; OCTAVES * SUB],
            total: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    fn index(value: u64) -> usize {
        let v = value.max(1);
        let msb = 63 - v.leading_zeros();
        if msb < SUB_BITS {
            return v as usize;
        }
        let octave = (msb - SUB_BITS + 1) as usize;
        let sub = (v >> (msb - SUB_BITS)) as usize & (SUB - 1);
        ((octave * SUB) + sub + SUB).min(OCTAVES * SUB - 1)
    }

    /// Lower bound of the bucket a value falls into (used for quantiles).
    fn bucket_floor(idx: usize) -> u64 {
        if idx < SUB {
            return idx as u64;
        }
        let octave = (idx - SUB) / SUB;
        let sub = (idx - SUB) % SUB;
        // Invert index(): msb = octave + SUB_BITS - 1; the sub-bucket adds
        // sub units of base/SUB.
        let base = 1u64 << (octave as u32 + SUB_BITS - 1);
        base + (sub as u64) * (base >> SUB_BITS)
    }

    /// Record one sample.
    pub fn record(&mut self, value: u64) {
        self.counts[Self::index(value)] += 1;
        self.total += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    /// Merge another histogram into this one.
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.total += other.total;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Number of samples recorded.
    pub fn count(&self) -> u64 {
        self.total
    }

    /// Arithmetic mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum as f64 / self.total as f64
        }
    }

    /// Exact minimum sample (0 when empty).
    pub fn min(&self) -> u64 {
        if self.total == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact maximum sample.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Approximate quantile `q` in [0,1] (bucket lower bound).
    pub fn quantile(&self, q: f64) -> u64 {
        if self.total == 0 {
            return 0;
        }
        let target = ((q.clamp(0.0, 1.0)) * self.total as f64).ceil() as u64;
        let target = target.max(1);
        let mut seen = 0;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return Self::bucket_floor(i).max(self.min).min(self.max);
            }
        }
        self.max
    }

    /// Median shorthand.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 99th percentile shorthand.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// 99.9th percentile shorthand (tail latency — the paper's Figure 8
    /// is a tail story; mean alone hides it).
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Compact JSON serialization: counts, mean and the quantile ladder.
    /// Flat integers (mean rounded) so snapshot tooling can diff fields
    /// without float-noise churn.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"n\":{},\"mean_ns\":{},\"min_ns\":{},\"p50_ns\":{},\"p99_ns\":{},\
             \"p999_ns\":{},\"max_ns\":{}}}",
            self.total,
            self.mean().round() as u64,
            self.min(),
            self.p50(),
            self.p99(),
            self.p999(),
            self.max()
        )
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Histogram {{ n: {}, mean: {:.1}, min: {}, p50: {}, p99: {}, max: {} }}",
            self.total,
            self.mean(),
            self.min(),
            self.p50(),
            self.p99(),
            self.max()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zero() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.quantile(0.5), 0);
    }

    #[test]
    fn exact_min_max_mean() {
        let mut h = Histogram::new();
        for v in [10, 20, 30] {
            h.record(v);
        }
        assert_eq!(h.min(), 10);
        assert_eq!(h.max(), 30);
        assert!((h.mean() - 20.0).abs() < 1e-9);
    }

    #[test]
    fn quantile_bounded_error() {
        let mut h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        let p50 = h.quantile(0.5) as f64;
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.10, "p50={p50}");
        let p99 = h.quantile(0.99) as f64;
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.10, "p99={p99}");
    }

    #[test]
    fn small_values_exact() {
        let mut h = Histogram::new();
        for v in 0..16u64 {
            h.record(v);
        }
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 15);
    }

    #[test]
    fn merge_equals_combined() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut c = Histogram::new();
        for v in 1..500u64 {
            a.record(v);
            c.record(v);
        }
        for v in 500..1000u64 {
            b.record(v * 7);
            c.record(v * 7);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.quantile(0.9), c.quantile(0.9));
        assert_eq!(a.max(), c.max());
    }

    #[test]
    fn huge_values_clamp_not_panic() {
        let mut h = Histogram::new();
        h.record(u64::MAX);
        assert_eq!(h.max(), u64::MAX);
        assert!(h.quantile(1.0) <= u64::MAX);
    }

    #[test]
    fn p999_sits_in_the_tail() {
        let mut h = Histogram::new();
        for v in 1..=100_000u64 {
            h.record(v);
        }
        let p999 = h.p999() as f64;
        assert!((p999 - 99_900.0).abs() / 99_900.0 < 0.10, "p999={p999}");
        assert!(h.p999() >= h.p99());
        assert!(h.p99() >= h.p50());
    }

    #[test]
    fn to_json_is_flat_and_complete() {
        let mut h = Histogram::new();
        for v in [5u64, 10, 15] {
            h.record(v);
        }
        let j = h.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        for key in ["\"n\":3", "\"mean_ns\":10", "\"min_ns\":5", "\"p50_ns\":", "\"p99_ns\":", "\"p999_ns\":", "\"max_ns\":15"] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        // Empty histograms serialize to all-zero fields, not junk.
        let e = Histogram::new().to_json();
        assert!(e.contains("\"n\":0") && e.contains("\"max_ns\":0"));
    }
}
