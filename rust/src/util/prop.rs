//! Tiny property-testing harness (offline substitute for proptest).
//!
//! Runs a property over `cases` pseudo-random inputs drawn from a
//! generator closure; on failure it reports the seed so the case can be
//! replayed exactly. No shrinking — generators here produce small values
//! by construction.

use super::rng::XorShift;

/// Run `property` over `cases` inputs from `gen`. Panics with the failing
/// seed on the first violated case.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: u32,
    mut gen: impl FnMut(&mut XorShift) -> T,
    mut property: impl FnMut(&T) -> bool,
) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).max(1);
        let mut rng = XorShift::new(seed);
        let input = gen(&mut rng);
        if !property(&input) {
            panic!(
                "property `{name}` failed (case {i}, seed {seed:#x}):\n  input = {input:?}\n\
                 replay with MCAPI_PROP_SEED={seed}"
            );
        }
    }
}

/// Like [`check`] but the property returns `Result` with a reason.
pub fn check_res<T: std::fmt::Debug>(
    name: &str,
    cases: u32,
    mut gen: impl FnMut(&mut XorShift) -> T,
    mut property: impl FnMut(&T) -> Result<(), String>,
) {
    let base = base_seed();
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64).max(1);
        let mut rng = XorShift::new(seed);
        let input = gen(&mut rng);
        if let Err(reason) = property(&input) {
            panic!(
                "property `{name}` failed (case {i}, seed {seed:#x}): {reason}\n  input = {input:?}\n\
                 replay with MCAPI_PROP_SEED={seed}"
            );
        }
    }
}

fn base_seed() -> u64 {
    std::env::var("MCAPI_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        // Fixed default: CI runs are reproducible; set MCAPI_PROP_SEED to
        // explore a different region.
        .unwrap_or(0xC0FFEE_2014)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("count", 50, |r| r.below(10), |_| {
            n += 1;
            true
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |r| r.below(100), |v| *v < 1_000_000 && false || *v == u64::MAX);
    }

    #[test]
    fn generators_are_deterministic_per_case() {
        let mut first: Vec<u64> = Vec::new();
        check("gen1", 5, |r| r.below(1000), |v| {
            first.push(*v);
            true
        });
        let mut second: Vec<u64> = Vec::new();
        check("gen2", 5, |r| r.below(1000), |v| {
            second.push(*v);
            true
        });
        assert_eq!(first, second);
    }
}
