//! Deterministic xorshift64* PRNG.
//!
//! Used by property tests, workload generators and the simulator's
//! tie-breaking. Never used where the paper requires determinism from
//! *algorithm* state (the sim itself is deterministic; the PRNG only
//! seeds workloads).

/// xorshift64* — fast, passes BigCrush for our purposes, one u64 of state.
#[derive(Debug, Clone)]
pub struct XorShift {
    state: u64,
}

impl XorShift {
    /// Create from a seed; a zero seed is mapped to a fixed non-zero value.
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed } }
    }

    /// Next raw u64.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in `[0, bound)`; `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        // Multiply-shift trick avoids modulo bias well enough for tests.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = XorShift::new(42);
        let mut b = XorShift::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut r = XorShift::new(0);
        assert_ne!(r.next_u64(), r.next_u64());
    }

    #[test]
    fn below_respects_bound() {
        let mut r = XorShift::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn range_inclusive() {
        let mut r = XorShift::new(9);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = XorShift::new(11);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut r = XorShift::new(1234);
        let mut buckets = [0u32; 8];
        for _ in 0..8000 {
            buckets[r.below(8) as usize] += 1;
        }
        for b in buckets {
            assert!((700..1300).contains(&b), "bucket {b}");
        }
    }
}
