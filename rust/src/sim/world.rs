//! `SimWorld`: the simulator-backed implementation of
//! [`crate::lockfree::mem::World`].
//!
//! A thread-local context installed by [`Machine::spawn`] ties the calling
//! thread to its task; every atomic operation, payload copy, yield and
//! kernel-lock transition is priced on the machine before taking effect.
//! The *values* still live in real `std` atomics so the Rust aliasing
//! rules hold, but because the machine monitor serializes execution, the
//! virtual-time order is the observable order.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};

use super::machine::{alloc_region, Machine};
use crate::lockfree::mem::{Atom32, Atom64, KernelLock, World};

thread_local! {
    static CTX: RefCell<Option<(Machine, usize)>> = const { RefCell::new(None) };
}

pub(crate) fn install_ctx(machine: Machine, task: usize) {
    CTX.with(|c| *c.borrow_mut() = Some((machine, task)));
}

pub(crate) fn clear_ctx() {
    CTX.with(|c| *c.borrow_mut() = None);
}

/// The task id of the calling thread on `machine` (panics if the thread is
/// not one of that machine's tasks).
pub(crate) fn current_task(_machine: &Machine) -> usize {
    CTX.with(|c| {
        c.borrow()
            .as_ref()
            .map(|(_, id)| *id)
            .expect("SimWorld operation outside a simulated task")
    })
}

fn with_machine<R>(f: impl FnOnce(&Machine) -> R) -> R {
    CTX.with(|c| {
        let borrow = c.borrow();
        let (machine, _) = borrow
            .as_ref()
            .expect("SimWorld operation outside a simulated task (spawn via sim::Machine)");
        f(machine)
    })
}

/// Simulator-priced world. See module docs.
pub struct SimWorld;

impl SimWorld {
    /// True when the calling thread is a simulated task.
    pub fn has_ctx() -> bool {
        CTX.with(|c| c.borrow().is_some())
    }

    /// Park the calling task on `addr` while `cond` holds (raw futex-wait,
    /// exposed for tests and custom primitives).
    ///
    /// `cond` is evaluated *inside* the machine monitor: it must not call
    /// any charged `SimWorld` operation (use [`Atom32::peek`]/raw atomics),
    /// or the monitor mutex self-deadlocks.
    pub fn futex_wait_on(addr: u64, cond: impl FnOnce() -> bool) {
        with_machine(|m| m.op(|ctx| ctx.futex_wait(addr, cond)))
    }

    /// [`SimWorld::futex_wait_on`] with an optional absolute virtual
    /// deadline; the scheduler wakes the task (spuriously) once virtual
    /// time passes the deadline, so timed waits can never deadlock the
    /// machine.
    pub fn futex_wait_deadline_on(addr: u64, deadline: Option<u64>, cond: impl FnOnce() -> bool) {
        with_machine(|m| m.op(|ctx| ctx.futex_wait_deadline(addr, deadline, cond)))
    }

    /// Wake up to `n` tasks parked on `addr`.
    pub fn futex_wake_on(addr: u64, n: usize) -> usize {
        with_machine(|m| m.op(|ctx| ctx.futex_wake(addr, n)))
    }

    /// Priced-op count of the calling task (unpriced; fault-sweep probes
    /// use it to bracket the op-index window of a target operation).
    pub fn op_count() -> u64 {
        CTX.with(|c| {
            let borrow = c.borrow();
            let (machine, id) = borrow
                .as_ref()
                .expect("SimWorld operation outside a simulated task");
            machine.task_ops(*id)
        })
    }

    /// Atomic-RMW count of the calling task (unpriced; the work-stealing
    /// gates diff it across a home-lane drain to assert the steady state
    /// performs zero shared-counter CAS operations).
    pub fn rmw_count() -> u64 {
        CTX.with(|c| {
            let borrow = c.borrow();
            let (machine, id) = borrow
                .as_ref()
                .expect("SimWorld operation outside a simulated task");
            machine.task_rmws(*id)
        })
    }

    /// Whether `task` on the calling task's machine has finished —
    /// normally or by injected kill (unpriced). Watchdog tasks poll it
    /// to detect a peer's death without perturbing a fault sweep's op
    /// indices.
    pub fn task_done(task: usize) -> bool {
        CTX.with(|c| {
            let borrow = c.borrow();
            let (machine, _) = borrow
                .as_ref()
                .expect("SimWorld operation outside a simulated task");
            machine.task_done(task)
        })
    }

    /// The calling task's id on its machine (spawn order).
    pub fn task_id() -> usize {
        CTX.with(|c| {
            c.borrow()
                .as_ref()
                .map(|(_, id)| *id)
                .expect("SimWorld operation outside a simulated task")
        })
    }
}

/// 32-bit atom priced by the machine (value in a real atomic, address in
/// the synthetic cache-line space).
pub struct SimAtom32 {
    value: AtomicU32,
    addr: u64,
}

impl Atom32 for SimAtom32 {
    fn new(v: u32) -> Self {
        SimAtom32 { value: AtomicU32::new(v), addr: alloc_region(64) }
    }

    fn load(&self) -> u32 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, false, false);
                self.value.load(Ordering::Relaxed)
            })
        })
    }

    // Ordering does not change coherence traffic: a relaxed load still
    // has to bring the line in, so it is priced exactly like `load`
    // (only `peek` bypasses accounting, and only outside protocols).
    fn load_relaxed(&self) -> u32 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, false, false);
                self.value.load(Ordering::Relaxed)
            })
        })
    }

    fn store(&self, v: u32) {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, false);
                self.value.store(v, Ordering::Relaxed)
            })
        })
    }

    fn cas(&self, current: u32, new: u32) -> Result<u32, u32> {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value
                    .compare_exchange(current, new, Ordering::Relaxed, Ordering::Relaxed)
            })
        })
    }

    fn fetch_add(&self, v: u32) -> u32 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value.fetch_add(v, Ordering::Relaxed)
            })
        })
    }

    fn fetch_or(&self, v: u32) -> u32 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value.fetch_or(v, Ordering::Relaxed)
            })
        })
    }

    fn fetch_and(&self, v: u32) -> u32 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value.fetch_and(v, Ordering::Relaxed)
            })
        })
    }

    fn peek(&self) -> u32 {
        self.value.load(Ordering::Relaxed)
    }
}

/// 64-bit atom priced by the machine.
pub struct SimAtom64 {
    value: AtomicU64,
    addr: u64,
}

impl Atom64 for SimAtom64 {
    fn new(v: u64) -> Self {
        SimAtom64 { value: AtomicU64::new(v), addr: alloc_region(64) }
    }

    fn load(&self) -> u64 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, false, false);
                self.value.load(Ordering::Relaxed)
            })
        })
    }

    // Priced like `load`; see SimAtom32::load_relaxed.
    fn load_relaxed(&self) -> u64 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, false, false);
                self.value.load(Ordering::Relaxed)
            })
        })
    }

    fn store(&self, v: u64) {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, false);
                self.value.store(v, Ordering::Relaxed)
            })
        })
    }

    fn cas(&self, current: u64, new: u64) -> Result<u64, u64> {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value
                    .compare_exchange(current, new, Ordering::Relaxed, Ordering::Relaxed)
            })
        })
    }

    fn fetch_add(&self, v: u64) -> u64 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value.fetch_add(v, Ordering::Relaxed)
            })
        })
    }

    fn fetch_or(&self, v: u64) -> u64 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value.fetch_or(v, Ordering::Relaxed)
            })
        })
    }

    fn fetch_and(&self, v: u64) -> u64 {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.mem_access(self.addr, true, true);
                self.value.fetch_and(v, Ordering::Relaxed)
            })
        })
    }

    fn peek(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// FIFO kernel lock priced by the machine: a ticket lock whose contended
/// path blocks in the kernel. Ticket order makes lock handoff strictly
/// FIFO — the behaviour of rt-futex / dispatcher-object queues that
/// produces the paper's multicore *convoys*: a releaser re-requesting the
/// lock queues behind every already-waiting task and pays a full
/// block/wake cycle per critical section.
pub struct SimKernelLock {
    next: AtomicU32,
    serving: AtomicU32,
    addr: u64,
}

impl KernelLock for SimKernelLock {
    fn new() -> Self {
        SimKernelLock {
            next: AtomicU32::new(0),
            serving: AtomicU32::new(0),
            addr: alloc_region(64),
        }
    }

    fn acquire(&self) {
        with_machine(|m| {
            // Take a ticket (user-mode RMW; on kernel_always profiles the
            // entry itself is a syscall).
            let my = m.op(|ctx| {
                ctx.lock_fast();
                ctx.mem_access(self.addr, true, true);
                self.next.fetch_add(1, Ordering::Relaxed)
            });
            loop {
                let acquired = m.op(|ctx| {
                    ctx.mem_access(self.addr + 64, false, false);
                    self.serving.load(Ordering::Relaxed) == my
                });
                if acquired {
                    return;
                }
                // Not our turn: block in the kernel until a release wakes
                // us (wake-all; non-owners re-check and re-block).
                m.op(|ctx| {
                    ctx.syscall();
                    let serving = &self.serving;
                    ctx.futex_wait(self.addr, || serving.load(Ordering::Relaxed) != my);
                });
            }
        })
    }

    fn release(&self) {
        with_machine(|m| {
            m.op(|ctx| {
                ctx.lock_fast();
                ctx.mem_access(self.addr + 64, true, true);
                self.serving.fetch_add(1, Ordering::Relaxed);
                if ctx.futex_waiters(self.addr) > 0 {
                    ctx.syscall();
                    ctx.futex_wake(self.addr, usize::MAX);
                }
            })
        })
    }
}

impl World for SimWorld {
    type U32 = SimAtom32;
    type U64 = SimAtom64;
    type Lock = SimKernelLock;

    fn yield_now() {
        with_machine(|m| m.op(|ctx| ctx.yield_now()))
    }

    fn spin_hint() {
        with_machine(|m| m.op(|ctx| ctx.charge(4)))
    }

    fn touch(region: u64, bytes: usize, write: bool) {
        with_machine(|m| m.op(|ctx| ctx.touch(region, bytes, write)))
    }

    fn work(ns: u64) {
        with_machine(|m| m.op(|ctx| ctx.charge(ns)))
    }

    fn now_ns() -> u64 {
        with_machine(|m| m.op(|ctx| ctx.now()))
    }

    // Unpriced peek of the calling task's virtual clock: the timestamp
    // source for src/obs/ trace events. Deliberately bypasses the
    // monitor's pricing (no `m.op`), so instrumented runs keep the exact
    // hit/miss/op counts of uninstrumented ones. 0 off-plane — exporter
    // threads outside any task emit epoch-less events rather than panic.
    fn timestamp_peek() -> u64 {
        CTX.with(|c| {
            c.borrow()
                .as_ref()
                .map_or(0, |(machine, id)| machine.task_clock(*id))
        })
    }

    fn alloc_region(bytes: usize) -> u64 {
        alloc_region(bytes)
    }

    // Trait-level parking maps straight onto the machine futex. The
    // `still` closure runs inside the monitor: peek()/raw atomics only.
    fn futex_wait(addr: u64, deadline_ns: Option<u64>, still: impl FnOnce() -> bool) {
        with_machine(|m| m.op(|ctx| ctx.futex_wait_deadline(addr, deadline_ns, still)))
    }

    fn futex_wake(addr: u64, n: usize) {
        with_machine(|m| m.op(|ctx| ctx.futex_wake(addr, n)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::os::{AffinityMode, OsProfile};
    use crate::sim::{Machine, MachineCfg};
    use std::sync::Arc;

    fn machine(cores: usize) -> Machine {
        Machine::new(MachineCfg::new(
            cores,
            OsProfile::linux_rt(),
            AffinityMode::PinnedSpread,
        ))
    }

    #[test]
    #[should_panic(expected = "outside a simulated task")]
    fn sim_atom_outside_task_panics() {
        let a = SimAtom32::new(0);
        let _ = a.load();
    }

    #[test]
    fn kernel_lock_mutual_exclusion_in_sim() {
        let m = machine(4);
        let lock = Arc::new(SimKernelLock::new());
        let shared = Arc::new(AtomicU32::new(0));
        let stats = m.run_tasks(4, |_| {
            let lock = lock.clone();
            let shared = shared.clone();
            move || {
                for _ in 0..100 {
                    lock.acquire();
                    // Unsynchronized RMW protected only by the lock; the
                    // monitor serializes real execution, but virtual-time
                    // mutual exclusion must still hold for the count to be
                    // exact under preemption/blocking.
                    let v = shared.load(Ordering::Relaxed);
                    SimWorld::work(50);
                    shared.store(v + 1, Ordering::Relaxed);
                    lock.release();
                }
            }
        });
        assert_eq!(shared.load(Ordering::Relaxed), 400);
        assert!(stats.syscalls > 0, "contention must hit the kernel: {stats:?}");
    }

    #[test]
    fn contended_lock_costs_more_on_multicore() {
        let run = |cores: usize| {
            let m = machine(cores);
            let lock = Arc::new(SimKernelLock::new());
            m.run_tasks(2, |_| {
                let lock = lock.clone();
                move || {
                    for _ in 0..200 {
                        lock.acquire();
                        SimWorld::work(100);
                        lock.release();
                    }
                }
            })
        };
        let s1 = run(1);
        let s4 = run(4);
        // The paper's core observation: the same lock-based code slows
        // down when spread across cores (line ping-pong + convoying).
        assert!(
            s4.virtual_ns > s1.virtual_ns,
            "multicore should be slower: {s1:?} vs {s4:?}"
        );
    }

    #[test]
    fn lockfree_counter_speeds_up_on_multicore_vs_lock() {
        // Sanity for the headline effect: atomic fetch_add scales much
        // better than lock/unlock around the same work.
        let atomic_run = |cores: usize| {
            let m = machine(cores);
            let a = Arc::new(SimAtom32::new(0));
            m.run_tasks(2, |_| {
                let a = a.clone();
                move || {
                    for _ in 0..200 {
                        a.fetch_add(1);
                        SimWorld::work(100);
                    }
                }
            })
        };
        let lock_run = |cores: usize| {
            let m = machine(cores);
            let l = Arc::new(SimKernelLock::new());
            m.run_tasks(2, |_| {
                let l = l.clone();
                move || {
                    for _ in 0..200 {
                        l.acquire();
                        SimWorld::work(100);
                        l.release();
                    }
                }
            })
        };
        let a4 = atomic_run(4);
        let l4 = lock_run(4);
        assert!(
            l4.virtual_ns > a4.virtual_ns,
            "locks should cost more than atomics on multicore: {a4:?} vs {l4:?}"
        );
    }

    #[test]
    fn payload_touch_charges_lines() {
        let m = machine(1);
        let stats = m.run_tasks(1, |_| {
            || {
                let region = <SimWorld as World>::alloc_region(256);
                SimWorld::touch(region, 256, true); // 4 lines, all cold
                SimWorld::touch(region, 256, false); // now resident: hits
            }
        });
        assert_eq!(stats.misses, 4, "{stats:?}");
        assert_eq!(stats.hits, 4, "{stats:?}");
    }

    #[test]
    fn now_ns_is_virtual() {
        let m = machine(1);
        let stats = m.run_tasks(1, |_| {
            || {
                let t0 = SimWorld::now_ns();
                SimWorld::work(12_345);
                let t1 = SimWorld::now_ns();
                assert!(t1 - t0 >= 12_345);
            }
        });
        assert!(stats.virtual_ns >= 12_345);
    }
}
