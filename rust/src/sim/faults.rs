//! Deterministic fault injection for the DES machine.
//!
//! The monitor already serializes every priced operation (see
//! [`super::machine`]), so "kill task 3 at its 117th priced op" is a
//! perfectly reproducible event: the k-th time task 3 enters
//! `Machine::op`, the plan fires *before* the operation takes effect.
//! That is exactly the thin window the paper's Table 1 statuses guard —
//! between an NBB `enter` and `exit` counter store — and the same
//! forced-interleaving idea dynamic race detectors use to make rare
//! windows certain.
//!
//! Three fault shapes:
//!
//! * [`FaultAction::Kill`] — the task dies at that instant (its op never
//!   executes). Peers keep running; the machine does **not** abort. This
//!   models a crashed/cancelled task that may hold leases or have a
//!   counter parked at an odd (mid-operation) value.
//! * [`FaultAction::Stall`] — the task's virtual clock jumps by N ns
//!   before the op executes, and the scheduler hands the machine to the
//!   peers in the meantime: preemption mid-operation. Peers observe the
//!   half-open window (`*_BUT_*` statuses) for the whole stall.
//! * [`FaultAction::Delay`] — like `Stall`, but the task is also rotated
//!   to the back of its core's ready queue (an involuntary context
//!   switch rather than pure clock skew).
//!
//! [`FaultPlan::from_seed`] derives a reproducible random plan from a
//! seed via xorshift64*; [`sweep_kill_points`] / [`sweep_stall_points`]
//! enumerate *every* fault point inside an operation window measured
//! with [`super::SimWorld::op_count`] — the chaos harness runs one fresh
//! machine per point, which is how the acceptance sweep proves no kill
//! index inside `pkt_send`/`pkt_recv` can lose or duplicate a committed
//! message.

use std::collections::BTreeMap;

/// What happens to a task at a planned fault point.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    /// The task dies; its pending op never executes. Peers keep running.
    Kill,
    /// The task's clock jumps by this many virtual ns before the op
    /// executes (preemption mid-operation); peers run in the gap.
    Stall(u64),
    /// Clock jump plus rotation to the back of the core's ready queue.
    Delay(u64),
}

/// Unwind payload used for injected kills. `Machine::spawn` recognises it
/// and turns the unwind into a clean single-task death (no machine
/// abort, no panic propagation out of `Machine::run`).
pub struct InjectedKill;

/// A reproducible schedule of fault events, keyed by `(task, op index)`:
/// the event fires immediately before the task's `at_op`-th priced
/// operation (0-based, counted per task in spawn order).
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    events: BTreeMap<(usize, u64), FaultAction>,
}

impl FaultPlan {
    /// Empty plan.
    pub fn new() -> Self {
        Self::default()
    }

    /// Kill `task` immediately before its `at_op`-th priced operation.
    pub fn kill(mut self, task: usize, at_op: u64) -> Self {
        self.events.insert((task, at_op), FaultAction::Kill);
        self
    }

    /// Stall `task` for `ns` virtual nanoseconds at its `at_op`-th op.
    pub fn stall(mut self, task: usize, at_op: u64, ns: u64) -> Self {
        self.events.insert((task, at_op), FaultAction::Stall(ns));
        self
    }

    /// Delay (stall + deschedule) `task` at its `at_op`-th op.
    pub fn delay(mut self, task: usize, at_op: u64, ns: u64) -> Self {
        self.events.insert((task, at_op), FaultAction::Delay(ns));
        self
    }

    /// True when no events remain.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.events.len()
    }

    /// Iterate the scheduled events as `(task, at_op, action)`.
    pub fn events(&self) -> impl Iterator<Item = (usize, u64, FaultAction)> + '_ {
        self.events.iter().map(|(&(t, k), &a)| (t, k, a))
    }

    /// Remove and return the event for `(task, op)`, if any. One-shot:
    /// each planned event fires at most once.
    pub(crate) fn take(&mut self, task: usize, op: u64) -> Option<FaultAction> {
        self.events.remove(&(task, op))
    }

    /// Derive a reproducible plan from a seed: one to three events over
    /// `tasks` tasks, op indices in `0..max_op`, actions weighted
    /// towards kills (the interesting case for recovery).
    pub fn from_seed(seed: u64, tasks: usize, max_op: u64) -> Self {
        assert!(tasks >= 1 && max_op >= 1);
        let mut rng = Rng64::new(seed);
        let count = 1 + (rng.next() % 3) as usize;
        let mut plan = FaultPlan::new();
        for _ in 0..count {
            let task = (rng.next() % tasks as u64) as usize;
            let at_op = rng.next() % max_op;
            plan = match rng.next() % 4 {
                0 | 1 => plan.kill(task, at_op),
                2 => plan.stall(task, at_op, 500 + rng.next() % 20_000),
                _ => plan.delay(task, at_op, 500 + rng.next() % 20_000),
            };
        }
        plan
    }
}

/// The priced-op index window a task spent inside a target operation,
/// measured on a probe run via [`super::SimWorld::op_count`] before and
/// after the call of interest.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OpWindow {
    /// Task id (spawn order) the window belongs to.
    pub task: usize,
    /// First priced-op index inside the operation.
    pub start: u64,
    /// One past the last priced-op index inside the operation.
    pub end: u64,
}

impl OpWindow {
    /// Number of fault points in the window.
    pub fn len(&self) -> u64 {
        self.end.saturating_sub(self.start)
    }

    /// True when the window contains no ops.
    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// Enumerate a kill plan for every priced-op index inside `window` —
/// run each plan on a fresh machine to sweep all death points inside
/// the target operation.
pub fn sweep_kill_points(window: OpWindow) -> impl Iterator<Item = (u64, FaultPlan)> {
    (window.start..window.end).map(move |k| (k, FaultPlan::new().kill(window.task, k)))
}

/// Enumerate a stall plan (of `ns` virtual ns) for every priced-op index
/// inside `window`.
pub fn sweep_stall_points(window: OpWindow, ns: u64) -> impl Iterator<Item = (u64, FaultPlan)> {
    (window.start..window.end).map(move |k| (k, FaultPlan::new().stall(window.task, k, ns)))
}

/// Enumerate a delay plan (stall + deschedule, `ns` virtual ns) for
/// every priced-op index inside `window` — the scheduling-delay analog
/// of [`sweep_stall_points`]: the victim loses the CPU *and* the clock,
/// which is exactly the window a liveness watchdog is most tempted to
/// misread as death.
pub fn sweep_delay_points(window: OpWindow, ns: u64) -> impl Iterator<Item = (u64, FaultPlan)> {
    (window.start..window.end).map(move |k| (k, FaultPlan::new().delay(window.task, k, ns)))
}

/// xorshift64* PRNG — tiny, seedable, no external dependencies, and
/// stable across platforms so seed reports reproduce byte-for-byte.
#[derive(Debug, Clone)]
pub struct Rng64(u64);

impl Rng64 {
    /// Seeded constructor (zero seeds are remapped; xorshift fixpoints
    /// at zero).
    pub fn new(seed: u64) -> Self {
        Rng64(if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed })
    }

    /// Next 64-bit value.
    pub fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_take_is_one_shot() {
        let mut p = FaultPlan::new().kill(1, 5).stall(2, 7, 100);
        assert_eq!(p.len(), 2);
        assert_eq!(p.take(1, 5), Some(FaultAction::Kill));
        assert_eq!(p.take(1, 5), None);
        assert_eq!(p.take(2, 7), Some(FaultAction::Stall(100)));
        assert!(p.is_empty());
    }

    #[test]
    fn from_seed_is_reproducible_and_seed_sensitive() {
        let a: Vec<_> = FaultPlan::from_seed(42, 4, 1000).events().collect();
        let b: Vec<_> = FaultPlan::from_seed(42, 4, 1000).events().collect();
        assert_eq!(a, b, "same seed must give the same plan");
        assert!(!a.is_empty() && a.len() <= 3);
        let mut differs = false;
        for s in 1..=16u64 {
            let c: Vec<_> = FaultPlan::from_seed(s, 4, 1000).events().collect();
            if c != a {
                differs = true;
                break;
            }
        }
        assert!(differs, "different seeds should usually differ");
    }

    #[test]
    fn sweep_covers_every_point_once() {
        let w = OpWindow { task: 3, start: 10, end: 14 };
        let points: Vec<_> = sweep_kill_points(w).collect();
        assert_eq!(points.len(), 4);
        for (i, (k, plan)) in points.iter().enumerate() {
            assert_eq!(*k, 10 + i as u64);
            let evs: Vec<_> = plan.events().collect();
            assert_eq!(evs, vec![(3, *k, FaultAction::Kill)]);
        }
        assert!(OpWindow { task: 0, start: 5, end: 5 }.is_empty());
        let delays: Vec<_> = sweep_delay_points(w, 777).collect();
        assert_eq!(delays.len(), 4);
        for (i, (k, plan)) in delays.iter().enumerate() {
            assert_eq!(*k, 10 + i as u64);
            let evs: Vec<_> = plan.events().collect();
            assert_eq!(evs, vec![(3, *k, FaultAction::Delay(777))]);
        }
    }

    #[test]
    fn rng_streams_are_deterministic() {
        let mut a = Rng64::new(7);
        let mut b = Rng64::new(7);
        for _ in 0..64 {
            assert_eq!(a.next(), b.next());
        }
        // Zero seed is remapped, not a fixpoint.
        let mut z = Rng64::new(0);
        assert_ne!(z.next(), 0);
    }
}
